"""Sequence state recovery (§3.2): migration + partial recomputation.

The KV cache of a failed attention rank is gone, but every sequence's
prompt and decoded token ids still live in host memory.  Migration
requeues each sequence on a healthy rank; its next prefill consumes
``prompt + decoded`` (the concatenated new prompt), so completed decode
steps are never redone — only the KV prefill is recomputed.

Recovery is step-level: the in-flight generation step on *every* executor
is rolled back (block log §3.3) and its sampled tokens discarded, because
layer-level checkpoints could leave inconsistent KV across layers.
"""
from __future__ import annotations

from typing import Iterable, List, Sequence

from repro.serving.request import Request, RequestState


def plan_migration(reqs: Sequence[Request], target_loads: dict
                   ) -> List[tuple]:
    """Assign each request to the least-loaded healthy executor.

    target_loads: {dp_rank: current_num_requests} for healthy ranks.
    Returns [(req, dp_rank)] and updates loads greedily.
    """
    assert target_loads, "no healthy attention ranks to migrate to"
    loads = dict(target_loads)
    out = []
    # longest sequences first: balances the re-prefill work
    for req in sorted(reqs, key=lambda r: -r.num_tokens):
        rank = min(loads, key=lambda k: loads[k])
        loads[rank] += 1
        out.append((req, rank))
    return out


def prepare_for_migration(req: Request) -> Request:
    """Partial-recomputation accounting; the request keeps its identity."""
    req.rebuild_prompt_for_migration()
    req.recomputed_tokens += req.num_tokens   # KV to re-prefill
    return req
