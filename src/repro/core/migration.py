"""Sequence state recovery (§3.2): migration, KV-block streaming, and
partial recomputation.

Two ways to move a live sequence to another executor or instance:

* **KV-block streaming** (FailSafe-style standby sync): while the source
  device is still reachable, the request's *live pool blocks* plus its
  per-slot recurrent state are extracted (:class:`KVBlocks`) and
  installed into freshly allocated blocks on the target.  Cost is
  O(prefix bytes) of copy — no recompute — so takeover latency stays
  flat in prompt length.
* **Token replay re-prefill** (the verified fallback): the KV cache of a
  *failed* device is gone, but every sequence's prompt and decoded token
  ids still live in host memory.  Migration requeues each sequence on a
  healthy rank; its next prefill consumes ``prompt + decoded``, so
  completed decode steps are never redone — only the KV prefill is
  recomputed.

Both paths are token-exact: sampling is position-seeded, so the target
continues the same token stream either way (parity is asserted in
tests/test_paged_serving.py).

Recovery is step-level: the in-flight generation step on *every* executor
is rolled back (block log §3.3) and its sampled tokens discarded, because
layer-level checkpoints could leave inconsistent KV across layers.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, List, Optional, Sequence

from repro.serving.request import Request


@dataclass
class KVBlocks:
    """One request's device state, extracted for KV-block streaming.

    ``pool_blocks``/``state`` are flat leaf lists aligned with the paged
    cache's flatten order (``cache_ops.gather_request_blocks``): pool
    leaves carry (L, nblk, bs, *rest) gathered blocks, state leaves the
    (L, 1, ...) per-slot recurrent state; the other kind is ``None``.
    """
    block_size: int
    num_blocks: int              # nblk — table span of the valid prefix
    valid_len: int               # cache positions 0..valid_len-1 are live
    pool_blocks: List[Any]
    state: List[Any]
    last_token: int              # feeds the target's next decode step
    # per table index: False marks a window-released (dead) block — no
    # payload rows ship for it and the target installs its trash
    # sentinel instead of allocating a real block.  None = all live.
    live_mask: Optional[List[bool]] = None

    @property
    def tokens_streamed(self) -> int:
        return self.valid_len

    def nbytes(self) -> int:
        return sum(int(x.nbytes) for x in self.pool_blocks + self.state
                   if x is not None)


def plan_migration(reqs: Sequence[Request], target_loads: dict
                   ) -> List[tuple]:
    """Assign each request to the least-loaded healthy executor.

    target_loads: {dp_rank: current_num_requests} for healthy ranks.
    Returns [(req, dp_rank)] and updates loads greedily.
    """
    assert target_loads, "no healthy attention ranks to migrate to"
    loads = dict(target_loads)
    out = []
    # longest sequences first: balances the re-prefill work
    for req in sorted(reqs, key=lambda r: -r.num_tokens):
        rank = min(loads, key=lambda k: loads[k])
        loads[rank] += 1
        out.append((req, rank))
    return out


def prepare_for_migration(req: Request, streamed: bool = False) -> Request:
    """Migration accounting; the request keeps its identity.

    ``streamed=True`` marks a KV-block-streamed move: no prefill is
    recomputed, so ``recomputed_tokens`` stays put (if the stream install
    later fails, the fallback requeue charges it via
    :func:`charge_replay`)."""
    req.rebuild_prompt_for_migration()
    if not streamed:
        charge_replay(req)
    return req


def charge_replay(req: Request) -> Request:
    """Partial-recomputation accounting: the whole live prefix is about
    to be re-prefilled on the target."""
    req.recomputed_tokens += req.num_tokens
    return req
