"""Log-based block-table recovery (§3.3) + content-hash prefix cache.

During a generation step every block operation (allocate / append /
ref / free / cache-acquire / register / table-set) is appended to a
per-step undo log, ARIES-style.  On a mid-step failure the log is rolled
back in reverse, returning the block manager + block tables to the exact
state at the step boundary.  At the start of each step the previous log
is discarded (the step committed).

The log records *inverse information* (prev ref counts, table positions,
hash mappings) so undo is exact even for idempotence-breaking sequences.

Device-pool consistency has two strategies (the executor picks one):

* **row-level undo** (default): at plan time the step's complete write
  set is known (decode write destinations, prefill chunk rows, COW
  copies), so the executor captures just those pool rows and rollback
  scatters them back — O(write set), not O(pool), and the pool buffers
  are free to be donated/aliased into the compiled update on TPU.
* **functional snapshot** (legacy): an O(1) reference to the immutable
  cache pytree at the step boundary.  Exact, but pins the pre-step pool
  buffers and forbids donation.

Prefix cache
============
``BlockManager`` doubles as a vLLM-style content-hash block cache: a
*full* block whose tokens (and whole prefix before it) are known is
registered under a chain digest ``H(parent_digest || tokens)``.  A later
request whose prompt starts with the same token blocks acquires the
physical blocks by digest (ref-count shared, zero prefill work); when
the last owner frees a registered block it parks on a cached-free LRU —
still addressable by digest, evicted only when the allocator runs dry.
Partial-prefix reuse is copy-on-write at the divergence block: the
scheduler finds a cached child block sharing the first ``q`` tokens and
plans a device copy of those rows into the request's private block.
"""
from __future__ import annotations

import hashlib
from collections import OrderedDict
from dataclasses import dataclass
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

ROOT_DIGEST = b""


def block_digest(parent: bytes, tokens: Sequence[int]) -> bytes:
    """Chain digest of one full block: H(parent || token ids)."""
    h = hashlib.sha256(parent)
    h.update(b"|")
    h.update(b",".join(str(int(t)).encode() for t in tokens))
    return h.digest()


def prompt_digests(tokens: Sequence[int], block_size: int) -> List[bytes]:
    """Chain digests of every *full* block of a token sequence."""
    out: List[bytes] = []
    parent = ROOT_DIGEST
    for i in range(len(tokens) // block_size):
        parent = block_digest(parent,
                              tokens[i * block_size:(i + 1) * block_size])
        out.append(parent)
    return out


@dataclass(frozen=True)
class BlockOp:
    kind: str     # 'alloc' | 'free' | 'append' | 'ref' | 'unref'
    #               | 'cache_acquire' | 'hash_set' | 'table_set'
    block_id: int
    seq_id: Optional[int] = None
    prev_ref: int = 0         # ref count before the op (for free/ref/...)
    meta: Any = None          # op-specific inverse info (digest, index...)


class _Frame:
    """One uncommitted step's undo payload: block ops + pool rollback."""
    __slots__ = ("ops", "pool_undo", "pool_snapshot")

    def __init__(self):
        self.ops: List[BlockOp] = []
        self.pool_undo = None
        self.pool_snapshot = None


def _undo_op(op: BlockOp, manager: "BlockManager",
             tables: Dict[int, "BlockTable"]) -> None:
    if op.kind == "alloc":
        # undoing an allocation decrements the ref count / deletes
        manager._undo_alloc(op.block_id)
    elif op.kind == "free":
        manager._undo_free(op.block_id, op.prev_ref)
    elif op.kind == "append":
        tables[op.seq_id]._undo_append(op.block_id)
    elif op.kind == "ref":
        manager._set_ref(op.block_id, op.prev_ref)
    elif op.kind == "unref":
        manager._set_ref(op.block_id, op.prev_ref)
    elif op.kind == "cache_acquire":
        manager._undo_cache_acquire(op.block_id, op.prev_ref)
    elif op.kind == "hash_set":
        manager._undo_register(op.block_id)
    elif op.kind == "table_set":
        idx, prev_bid = op.meta
        tables[op.seq_id].blocks[idx] = prev_bid
    else:  # pragma: no cover
        raise ValueError(op.kind)


class _OldestRecorder:
    """Record-only view of a log's *oldest* frame.

    The overlap pipeline drains step N-1 after step N has already been
    planned (its frame pushed on top): bookkeeping ops that belong to
    the draining step — decode-grown prefix registrations, finish
    frees — must land in N-1's frame, not N's, so a later rollback of
    N never undoes N-1's committed outcome."""

    def __init__(self, log: "BlockLog"):
        self._log = log

    def record(self, op: BlockOp) -> None:
        self._log._frames[0].ops.append(op)


class BlockLog:
    """Per-executor undo log of uncommitted step *frames*.

    The lockstep engine keeps exactly one frame (cleared at each step
    boundary — the historical behaviour).  The overlap pipeline keeps up
    to two: the in-flight step plus the plan-ahead step stacked on top.
    Frames commit oldest-first and roll back newest-first, so the §3.3
    undo stays exact whichever way the pipeline resolves."""

    def __init__(self):
        self._frames: List[_Frame] = [_Frame()]
        self.steps_committed = 0

    def begin_step(self) -> None:
        """Previous step fully completed -> its log is no longer needed."""
        self._frames = [_Frame()]
        self.steps_committed += 1

    def record(self, op: BlockOp) -> None:
        self._frames[-1].ops.append(op)

    # -- multi-frame surface (overlap pipeline) -------------------------------

    @property
    def num_frames(self) -> int:
        return len(self._frames)

    def push_frame(self) -> None:
        """Open a new uncommitted frame on top (plan-ahead step)."""
        self._frames.append(_Frame())

    def commit_oldest(self) -> None:
        """The oldest uncommitted frame's step reached its boundary."""
        self._frames.pop(0)
        if not self._frames:
            self._frames.append(_Frame())
        self.steps_committed += 1

    def oldest(self):
        """A record-only view targeting the oldest frame (drain-phase
        bookkeeping of the step about to commit)."""
        return self if len(self._frames) == 1 else _OldestRecorder(self)

    def undo_newest(self, manager: "BlockManager",
                    tables: Dict[int, "BlockTable"]) -> int:
        """Roll back and drop the newest frame's ops (reverse order).
        Callers restore its pool rows first via ``take_pool_undo``."""
        frame = self._frames.pop()
        if not self._frames:
            self._frames.append(_Frame())
        for op in reversed(frame.ops):
            _undo_op(op, manager, tables)
        return len(frame.ops)

    # -- pool consistency (the device-side half of §3.3) ----------------------

    def snapshot_pools(self, cache) -> None:
        """Legacy strategy: remember the paged-cache value at the step
        boundary.  The cache is a pytree of immutable jax arrays, so this
        is an O(1) reference, not a copy — restoring it discards every
        in-flight pool write exactly.  It pins the pre-step pool buffers,
        which forbids donating/aliasing them into the compiled update;
        row-level undo (below) is the donation-friendly replacement."""
        self._frames[-1].pool_snapshot = cache

    def take_pool_snapshot(self):
        """The cache value to restore on rollback (None once committed)."""
        frame = self._frames[-1]
        snap = frame.pool_snapshot
        frame.pool_snapshot = None
        return snap

    def record_pool_undo(self, undo) -> None:
        """Row-level strategy: stash the captured write-set rows
        (``cache_ops.capture_pool_rows``) for the in-flight step."""
        self._frames[-1].pool_undo = undo

    def take_pool_undo(self):
        frame = self._frames[-1]
        undo = frame.pool_undo
        frame.pool_undo = None
        return undo

    def peek_pool_undo(self):
        """Non-destructive read of the newest frame's captured write
        set — the speculative-decode verify phase restores the *rejected*
        rows from it mid-compute while the full payload stays armed."""
        return self._frames[-1].pool_undo

    def has_pool_state(self) -> bool:
        return any(f.pool_undo is not None or f.pool_snapshot is not None
                   for f in self._frames)

    def __len__(self) -> int:
        return sum(len(f.ops) for f in self._frames)

    def undo_all(self, manager: "BlockManager",
                 tables: Dict[int, "BlockTable"]) -> int:
        """Roll back every op of every uncommitted frame, newest frame
        first, each frame in reverse order.  Returns the ops undone."""
        n = 0
        for frame in reversed(self._frames):
            for op in reversed(frame.ops):
                _undo_op(op, manager, tables)
            n += len(frame.ops)
        self._frames = [_Frame()]
        return n


class BlockTable:
    """Per-sequence ordered list of physical block ids (host metadata).

    Entries may be *released* in place (sliding-window configs free
    blocks the attention window has moved past): the slot keeps its
    index — position ``p`` still maps to ``blocks[p // bs]`` — but
    points at the pool's trash block, whose rows every reader masks."""

    def __init__(self, seq_id: int):
        self.seq_id = seq_id
        self.blocks: List[int] = []

    def append_block(self, block_id: int, log: Optional[BlockLog] = None):
        self.blocks.append(block_id)
        if log is not None:
            log.record(BlockOp("append", block_id, self.seq_id))

    def _undo_append(self, block_id: int):
        assert self.blocks and self.blocks[-1] == block_id, \
            f"undo mismatch: table tail {self.blocks[-1:]} vs {block_id}"
        self.blocks.pop()

    def set_block(self, index: int, block_id: int,
                  log: Optional[BlockLog] = None) -> None:
        """Replace entry ``index`` (window release / undo thereof)."""
        prev = self.blocks[index]
        self.blocks[index] = block_id
        if log is not None:
            log.record(BlockOp("table_set", block_id, self.seq_id,
                               meta=(index, prev)))

    def num_blocks(self) -> int:
        return len(self.blocks)


class BlockManager:
    """Free-list block allocator with ref counts + content-hash reuse."""

    def __init__(self, num_blocks: int, block_size: int):
        self.num_blocks = num_blocks
        self.block_size = block_size
        self._free: List[int] = list(range(num_blocks - 1, -1, -1))
        self._ref: Dict[int, int] = {}
        # content-hash prefix cache
        self._hash: Dict[bytes, int] = {}        # digest -> bid
        self._bid_hash: Dict[int, bytes] = {}
        self._bid_tokens: Dict[int, Tuple[int, ...]] = {}
        self._bid_parent: Dict[int, bytes] = {}
        self._children: Dict[bytes, set] = {}    # parent digest -> {bid}
        # ref==0 blocks whose content is still cache-addressable (LRU)
        self._cached_free: "OrderedDict[int, None]" = OrderedDict()
        self.cache_hits = 0
        self.cache_evictions = 0

    # -- public ops (logged) -------------------------------------------------

    def allocate(self, log: Optional[BlockLog] = None) -> int:
        if self._free:
            bid = self._free.pop()
        elif self._cached_free:
            # evict the least-recently-parked cached block; its content
            # is overwritten by the new owner (the row-level undo
            # captures those rows, so rollback stays exact) — only the
            # digest mapping is lost, which costs future hits, never
            # correctness
            bid, _ = self._cached_free.popitem(last=False)
            self._drop_hash(bid)
            self.cache_evictions += 1
        else:
            raise RuntimeError("out of KV blocks")
        self._ref[bid] = 1
        if log is not None:
            log.record(BlockOp("alloc", bid))
        return bid

    def free(self, block_id: int, log: Optional[BlockLog] = None) -> None:
        prev = self._ref.get(block_id, 0)
        assert prev > 0, f"double free of block {block_id}"
        if log is not None:
            log.record(BlockOp("free", block_id, prev_ref=prev))
        if prev == 1:
            del self._ref[block_id]
            if block_id in self._bid_hash:
                self._cached_free[block_id] = None    # park, keep content
            else:
                self._free.append(block_id)
        else:
            self._ref[block_id] = prev - 1

    def add_ref(self, block_id: int, log: Optional[BlockLog] = None) -> None:
        prev = self._ref.get(block_id, 0)
        assert prev > 0
        if log is not None:
            log.record(BlockOp("ref", block_id, prev_ref=prev))
        self._ref[block_id] = prev + 1

    # -- prefix cache ---------------------------------------------------------

    def lookup(self, digest: bytes) -> Optional[int]:
        """The block holding this digest's content (None on miss).  Read
        only — the block may be live (ref > 0) or parked cached-free."""
        return self._hash.get(digest)

    def acquire_cached(self, digest: bytes,
                       log: Optional[BlockLog] = None) -> Optional[int]:
        """Take a ref-counted share of the cached block for ``digest``.

        A parked (ref==0) block is revived off the cached-free list; a
        live one just gains a reference.  Returns None on miss."""
        bid = self._hash.get(digest)
        if bid is None:
            return None
        prev = self._ref.get(bid, 0)
        if prev == 0:
            del self._cached_free[bid]
        self._ref[bid] = prev + 1
        self.cache_hits += 1
        if log is not None:
            log.record(BlockOp("cache_acquire", bid, prev_ref=prev,
                               meta=digest))
        return bid

    def register(self, bid: int, digest: bytes, parent: bytes,
                 tokens: Sequence[int],
                 log: Optional[BlockLog] = None) -> None:
        """Publish a freshly written *full* block under its chain digest
        (first writer wins; re-registration of a live digest is a no-op)."""
        if digest in self._hash or bid in self._bid_hash:
            return
        assert self._ref.get(bid, 0) > 0, \
            f"registering unallocated block {bid}"
        self._hash[digest] = bid
        self._bid_hash[bid] = digest
        self._bid_tokens[bid] = tuple(int(t) for t in tokens)
        self._bid_parent[bid] = parent
        self._children.setdefault(parent, set()).add(bid)
        if log is not None:
            log.record(BlockOp("hash_set", bid, meta=digest))

    def children_of(self, parent: bytes
                    ) -> Iterable[Tuple[int, Tuple[int, ...]]]:
        """(bid, tokens) of cached blocks whose prefix chain ends at
        ``parent`` — the COW divergence candidates."""
        for bid in self._children.get(parent, ()):
            yield bid, self._bid_tokens[bid]

    def _drop_hash(self, bid: int) -> None:
        digest = self._bid_hash.pop(bid, None)
        if digest is None:
            return
        if self._hash.get(digest) == bid:
            del self._hash[digest]
        parent = self._bid_parent.pop(bid)
        kids = self._children.get(parent)
        if kids is not None:
            kids.discard(bid)
            if not kids:
                del self._children[parent]
        self._bid_tokens.pop(bid, None)

    # -- undo internals (called by BlockLog only) ------------------------------

    def _undo_alloc(self, block_id: int) -> None:
        ref = self._ref.get(block_id, 0)
        assert ref >= 1, f"undo alloc of unallocated block {block_id}"
        if ref == 1:
            del self._ref[block_id]
            # an eviction that fed this alloc is not replayed: the digest
            # mapping is already gone (perf loss only, content restored
            # by the row-level pool undo)
            self._free.append(block_id)
        else:
            self._ref[block_id] = ref - 1

    def _undo_free(self, block_id: int, prev_ref: int) -> None:
        if block_id in self._ref:
            self._ref[block_id] = prev_ref
        else:
            if block_id in self._cached_free:
                del self._cached_free[block_id]
            else:
                self._free.remove(block_id)
            self._ref[block_id] = prev_ref

    def _undo_cache_acquire(self, block_id: int, prev_ref: int) -> None:
        if prev_ref == 0:
            del self._ref[block_id]
            self._cached_free[block_id] = None
        else:
            self._ref[block_id] = prev_ref

    def _undo_register(self, block_id: int) -> None:
        self._drop_hash(block_id)

    def _set_ref(self, block_id: int, ref: int) -> None:
        self._ref[block_id] = ref

    # -- introspection ---------------------------------------------------------

    def ref_count(self, block_id: int) -> int:
        return self._ref.get(block_id, 0)

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_allocatable(self) -> int:
        """Blocks an allocation can claim: plain free + evictable cached."""
        return len(self._free) + len(self._cached_free)

    @property
    def num_allocated(self) -> int:
        return len(self._ref)

    @property
    def num_cached(self) -> int:
        """Registered (content-addressable) blocks, live or parked."""
        return len(self._bid_hash)

    def snapshot(self):
        """Hashable state snapshot (for property tests)."""
        return (tuple(sorted(self._free)),
                tuple(sorted(self._ref.items())),
                tuple(sorted(self._cached_free)),
                tuple(sorted((d, b) for d, b in self._hash.items())))
