"""Log-based block-table recovery (§3.3).

During a generation step every block operation (allocate / append /
ref / unref / free) is appended to a per-step undo log, ARIES-style.  On a
mid-step failure the log is rolled back in reverse, returning the block
manager + block tables to the exact state at the step boundary.  At the
start of each step the previous log is discarded (the step committed).

The log records *inverse information* (prev ref counts, table positions)
so undo is exact even for idempotence-breaking sequences.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional


@dataclass(frozen=True)
class BlockOp:
    kind: str                 # 'alloc' | 'free' | 'append' | 'ref' | 'unref'
    block_id: int
    seq_id: Optional[int] = None
    prev_ref: int = 0         # ref count before the op (for free/ref/unref)


class BlockLog:
    """Per-executor undo log, cleared at each generation-step boundary."""

    def __init__(self):
        self._ops: List[BlockOp] = []
        self.steps_committed = 0
        self._pool_snapshot = None

    def begin_step(self) -> None:
        """Previous step fully completed -> its log is no longer needed."""
        self._ops.clear()
        self._pool_snapshot = None
        self.steps_committed += 1

    def record(self, op: BlockOp) -> None:
        self._ops.append(op)

    # -- pool consistency (the device-side half of §3.3) ----------------------

    def snapshot_pools(self, cache) -> None:
        """Remember the paged-cache value at the step boundary.  The cache
        is a pytree of immutable jax arrays, so this is an O(1) reference,
        not a copy — the functional analogue of the block-op undo records:
        restoring it discards every in-flight pool write exactly.

        Memory note: between the step's first pool update and ``commit``
        (one ``compute`` call — commit follows immediately), the pre-step
        buffers stay pinned alongside the updated ones.  A functional
        update holds input+output live anyway, so the snapshot adds no
        extra peak today, but it does forbid donating/aliasing the pool
        buffers into the update.  If that aliasing is ever wanted on TPU,
        replace this with a row-level undo of just the step's write set
        (write_bid/write_off + the prefill's block ids, all known at plan
        time) — see ROADMAP paged-KV follow-ups."""
        self._pool_snapshot = cache

    def take_pool_snapshot(self):
        """The cache value to restore on rollback (None once committed)."""
        snap = self._pool_snapshot
        self._pool_snapshot = None
        return snap

    def __len__(self) -> int:
        return len(self._ops)

    def undo_all(self, manager: "BlockManager",
                 tables: Dict[int, "BlockTable"]) -> int:
        """Roll back every op of the in-flight step, in reverse order.

        Returns the number of ops undone."""
        n = len(self._ops)
        for op in reversed(self._ops):
            if op.kind == "alloc":
                # undoing an allocation decrements the ref count / deletes
                manager._undo_alloc(op.block_id)
            elif op.kind == "free":
                manager._undo_free(op.block_id, op.prev_ref)
            elif op.kind == "append":
                tables[op.seq_id]._undo_append(op.block_id)
            elif op.kind == "ref":
                manager._set_ref(op.block_id, op.prev_ref)
            elif op.kind == "unref":
                manager._set_ref(op.block_id, op.prev_ref)
            else:  # pragma: no cover
                raise ValueError(op.kind)
        self._ops.clear()
        return n


class BlockTable:
    """Per-sequence ordered list of physical block ids (host metadata)."""

    def __init__(self, seq_id: int):
        self.seq_id = seq_id
        self.blocks: List[int] = []

    def append_block(self, block_id: int, log: Optional[BlockLog] = None):
        self.blocks.append(block_id)
        if log is not None:
            log.record(BlockOp("append", block_id, self.seq_id))

    def _undo_append(self, block_id: int):
        assert self.blocks and self.blocks[-1] == block_id, \
            f"undo mismatch: table tail {self.blocks[-1:]} vs {block_id}"
        self.blocks.pop()

    def num_blocks(self) -> int:
        return len(self.blocks)


class BlockManager:
    """Free-list block allocator with ref counts (prefix sharing ready)."""

    def __init__(self, num_blocks: int, block_size: int):
        self.num_blocks = num_blocks
        self.block_size = block_size
        self._free: List[int] = list(range(num_blocks - 1, -1, -1))
        self._ref: Dict[int, int] = {}

    # -- public ops (logged) -------------------------------------------------

    def allocate(self, log: Optional[BlockLog] = None) -> int:
        if not self._free:
            raise RuntimeError("out of KV blocks")
        bid = self._free.pop()
        self._ref[bid] = 1
        if log is not None:
            log.record(BlockOp("alloc", bid))
        return bid

    def free(self, block_id: int, log: Optional[BlockLog] = None) -> None:
        prev = self._ref.get(block_id, 0)
        assert prev > 0, f"double free of block {block_id}"
        if log is not None:
            log.record(BlockOp("free", block_id, prev_ref=prev))
        if prev == 1:
            del self._ref[block_id]
            self._free.append(block_id)
        else:
            self._ref[block_id] = prev - 1

    def add_ref(self, block_id: int, log: Optional[BlockLog] = None) -> None:
        prev = self._ref.get(block_id, 0)
        assert prev > 0
        if log is not None:
            log.record(BlockOp("ref", block_id, prev_ref=prev))
        self._ref[block_id] = prev + 1

    # -- undo internals (called by BlockLog only) ------------------------------

    def _undo_alloc(self, block_id: int) -> None:
        ref = self._ref.get(block_id, 0)
        assert ref >= 1, f"undo alloc of unallocated block {block_id}"
        if ref == 1:
            del self._ref[block_id]
            self._free.append(block_id)
        else:
            self._ref[block_id] = ref - 1

    def _undo_free(self, block_id: int, prev_ref: int) -> None:
        if block_id in self._ref:
            self._ref[block_id] = prev_ref
        else:
            self._free.remove(block_id)
            self._ref[block_id] = prev_ref

    def _set_ref(self, block_id: int, ref: int) -> None:
        self._ref[block_id] = ref

    # -- introspection ---------------------------------------------------------

    def ref_count(self, block_id: int) -> int:
        return self._ref.get(block_id, 0)

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_allocated(self) -> int:
        return len(self._ref)

    def snapshot(self):
        """Hashable state snapshot (for property tests)."""
        return (tuple(sorted(self._free)),
                tuple(sorted(self._ref.items())))
