"""RecoveryManager: the ReviveMoE pipeline (§3, Fig. 3).

On an actionable fault:
  ① the failed device is isolated (its executor process terminated),
  ② active sequences migrate off failed attention ranks with partial
     recomputation (§3.2),
  ③ every surviving executor rolls back its in-flight block-table log to
     the step boundary (§3.3),
  ④ MoE weight integrity is restored per the Fig. 4 flowchart —
     redundant experts / role switch / missing experts (§3.4),
  ⑤ the communication domain is destroyed and recreated with compacted
     logical ranks (§3.5),
  ⑥ the computation graph for the new domain is produced by cached
     compilation — precompiled failure-scenario executables when
     available (§3.6) — and inference resumes.

Every stage is wall-clock timed into the paper's Table-1 categories so
benchmarks/recovery_time.py can reproduce Figure 5.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.core.fault_codes import Action, FaultEvent
from repro.core.migration import plan_migration, prepare_for_migration
from repro.core.weights import (MoERecoveryKind, MoERecoveryPlan,
                                plan_moe_recovery)
from repro.serving.request import RequestState

CATEGORIES = ("engine", "executor_processes", "distributed_groups", "xccl",
              "role_switch", "generator", "read_cache", "compile", "other")


@dataclass
class RecoveryReport:
    event: FaultEvent
    scenario: str                       # e.g. 'attn', 'moe+redundant', ...
    mode: str                           # collocated | disaggregated
    timings: Dict[str, float] = field(default_factory=dict)
    actions: List[str] = field(default_factory=list)
    moe_plan: Optional[MoERecoveryPlan] = None
    migrated: int = 0
    blocks_rolled_back: int = 0
    compile_source: str = ""
    ok: bool = True

    @property
    def total_s(self) -> float:
        return sum(self.timings.values())

    def cost_inputs(self) -> Dict[str, float]:
        """Measured inputs for the fleet RecoveryArbiter's cost model:
        the downtime this revive actually cost, split into the terms the
        arbiter's estimates are built from."""
        return {
            "total_s": self.total_s,
            "weights_s": self.timings.get("generator", 0.0),
            "compile_s": (self.timings.get("compile", 0.0)
                          + self.timings.get("read_cache", 0.0)),
            "comm_s": (self.timings.get("xccl", 0.0)
                       + self.timings.get("distributed_groups", 0.0)),
            "migrated": float(self.migrated),
        }

    def summary(self) -> str:
        cats = ", ".join(f"{k}={v * 1e3:.1f}ms"
                         for k, v in sorted(self.timings.items()) if v > 0)
        return (f"[{self.scenario}/{self.mode}] total={self.total_s:.3f}s "
                f"migrated={self.migrated} undo={self.blocks_rolled_back} "
                f"compile={self.compile_source} :: {cats}")


class _T:
    def __init__(self, report: RecoveryReport, key: str):
        self.r, self.k = report, key

    def __enter__(self):
        self.t0 = time.perf_counter()

    def __exit__(self, *exc):
        self.r.timings[self.k] = self.r.timings.get(self.k, 0.0) + (
            time.perf_counter() - self.t0)


class RecoveryManager:
    def __init__(self, engine):
        self.engine = engine
        self.policy = engine.ecfg.policy

    # -- pipeline ----------------------------------------------------------------

    def recover(self, event: FaultEvent) -> RecoveryReport:
        eng = self.engine
        report = RecoveryReport(event=event, scenario="?",
                                mode=eng.ecfg.mode)
        if event.action is Action.IGNORE:   # L1/L2
            report.scenario = "benign"
            report.actions.append("logged only (L1/L2)")
            return report

        device = eng.domain.device(event.rank)
        is_attn = "attn" in device.role
        is_moe_weights = (eng.cfg.moe is not None and
                          (("moe" in device.role) or eng.ecfg.mode ==
                           "collocated"))

        # ① isolate: pause inference, terminate only the failed process
        with _T(report, "other"):
            device.alive = False
            failed_dp = None
            failed_moe = None
            for ex in eng.dp_executors:
                if ex.physical_id == event.rank:
                    failed_dp = ex
                    ex.fail_device()
                    ex.terminate_process()
            for mex in eng.moe_executors:
                if mex.physical_id == event.rank:
                    failed_moe = mex
                    mex.fail_device()
            eng.monitor.unregister(event.rank)
            report.actions.append(f"isolated device {event.rank} "
                                  f"({device.role})")

        # ③ block-table + pool recovery on all surviving executors —
        # BEFORE any migration, so streamed KV blocks land on targets
        # whose tables and pools already agree (rollback-then-migrate)
        with _T(report, "other"):
            undone = 0
            for ex in eng.dp_executors:
                if ex.alive and ex.cache is not None:
                    undone += ex.rollback_inflight()
            report.blocks_rolled_back = undone
            report.actions.append(f"rolled back {undone} block ops")

        # ② sequence state recovery (attention ranks).  The failed rank's
        # device memory is gone, so its KV cannot stream: token-replay
        # re-prefill is the (verified) fallback here.
        if failed_dp is not None and is_attn:
            with _T(report, "other"):
                reqs = failed_dp.scheduler.drain()
                report.migrated, _ = self._migrate(reqs, exclude=failed_dp)
                report.actions.append(
                    f"migrated {report.migrated} sequences "
                    f"(partial recomputation)")

        # ④ weight integrity
        role_switch_pid = None
        if is_moe_weights and failed_moe is not None or (
                is_moe_weights and eng.ecfg.mode == "collocated"
                and failed_dp is not None):
            plan = self._recover_moe_weights(event, report,
                                             failed_dp, failed_moe)
            report.moe_plan = plan
            if plan is not None and plan.kind is MoERecoveryKind.ROLE_SWITCH:
                role_switch_pid = eng.dp_executors[plan.donor_rank].physical_id
            report.scenario = ("moe+" + plan.kind.value) if plan else "attn"
        else:
            report.scenario = "attn"

        # ⑤ recreate communications with compacted ranks
        with _T(report, "xccl"):
            rec = eng.domain.rebuild(role_switch_physical=role_switch_pid)
            report.actions.append(
                f"comm domain v{rec['version']} rebuilt; rank changes: "
                f"{rec['rank_changes']}")
        with _T(report, "distributed_groups"):
            # torch-group analogue: world group intact, subgroups reassigned
            eng.world_group = [ex.physical_id for ex in eng.dp_executors
                               if ex.alive] + \
                              [m.physical_id for m in eng.moe_executors
                               if m.device_alive]

        # ⑥ cached graph compilation for the new domain version
        with _T(report, "read_cache"):
            pass  # timed inside get_or_compile; split below
        key_hit_before = ("decode", eng.domain.version, None) in eng.graph_cache
        t0 = time.perf_counter()
        eng.get_compiled("decode")
        tm = eng.graph_cache.timings[-1]
        report.compile_source = tm.source
        report.timings["read_cache"] = report.timings.get(
            "read_cache", 0.0) + tm.read_cache_s
        report.timings["compile"] = report.timings.get(
            "compile", 0.0) + tm.compile_s
        leftover = (time.perf_counter() - t0) - tm.read_cache_s - tm.compile_s
        report.timings["other"] = report.timings.get("other", 0.0) + max(
            leftover, 0.0)
        report.actions.append(
            f"graph for domain v{eng.domain.version}: {tm.source} "
            f"(precompiled hit={key_hit_before})")

        # resume + integrity check
        with _T(report, "other"):
            if eng.cfg.moe is not None:
                checks, alive = eng.expert_integrity()
                report.actions.append(
                    f"expert shards alive={alive}")
        return report

    # -- helpers ----------------------------------------------------------------------

    def _migrate(self, reqs, exclude):
        """Re-home sequences onto healthy ranks.  ``reqs`` items may be
        bare Requests (replay re-prefill — the source device is dead) or
        ``(req, KVBlocks|None)`` pairs from a healthy donor
        (``drop_attention_state(collect_kv=True)``): streamed blocks
        install directly, everything else re-prefills.

        Returns ``(migrated, streamed)`` counts."""
        eng = self.engine
        healthy = {ex.dp_rank: ex.scheduler.num_requests
                   for ex in eng.dp_executors
                   if ex.alive and ex.cache is not None and ex is not exclude}
        items = [(r, None) if not isinstance(r, tuple) else r for r in reqs]
        live = [(r, kv) for r, kv in items
                if r.state != RequestState.FINISHED]
        if not live:
            return 0, 0
        payloads = dict((id(r), kv) for r, kv in live)
        streamed = 0
        for req, rank in plan_migration([r for r, _ in live], healthy):
            kv = payloads[id(req)]
            prepare_for_migration(req, streamed=kv is not None)
            target = next(ex for ex in eng.dp_executors
                          if ex.dp_rank == rank)
            if kv is not None and target.import_kv_blocks(req, kv):
                streamed += 1
                continue
            if kv is not None:
                from repro.core.migration import charge_replay
                charge_replay(req)   # stream install failed: replay
            req.dp_rank = rank
            target.scheduler.add_request(req)
        return len(live), streamed

    def _recover_moe_weights(self, event, report, failed_dp, failed_moe
                             ) -> Optional[MoERecoveryPlan]:
        eng = self.engine
        emap = eng.expert_map
        failed_ep_rank = (failed_moe.ep_rank if failed_moe is not None
                          else failed_dp.ep_rank)
        if failed_ep_rank is None:
            return None
        with _T(report, "other"):
            affected = emap.fail_rank(failed_ep_rank)
            report.actions.append(
                f"EP rank {failed_ep_rank} lost (experts {affected[:8]}"
                f"{'...' if len(affected) > 8 else ''})")
            donor = self._pick_donor(exclude_pid=event.rank)
        plan = plan_moe_recovery(emap, self.policy, donor)

        if plan.kind is MoERecoveryKind.REDUNDANT_EXPERTS:
            with _T(report, "other"):
                eng.runtime = emap.runtime()
                eng.reassemble_params()
                report.actions.append(
                    "dropped dead replicas from logical-to-physical map")

        elif plan.kind is MoERecoveryKind.MISSING_EXPERTS:
            with _T(report, "other"):
                emap.mask_experts(plan.lost_logicals)
                eng.runtime = emap.runtime()
                eng.reassemble_params()
                report.actions.append(
                    f"masked {len(plan.lost_logicals)} lost experts in the "
                    f"gating function" +
                    (" [accuracy warning: EP < threshold]"
                     if plan.accuracy_warning else ""))

        elif plan.kind is MoERecoveryKind.ROLE_SWITCH and plan.background:
            # §4.3 combined mode: mask the lost experts NOW (serve with the
            # incomplete expert set — downtime stays at missing-experts
            # level) and restore full weight integrity in the background.
            with _T(report, "other"):
                emap.mask_experts(plan.lost_logicals)
                eng.runtime = emap.runtime()
                eng.reassemble_params()
                eng.pending_switches.append(plan)
                report.actions.append(
                    f"masked {len(plan.lost_logicals)} lost experts; role "
                    f"switch dp{plan.donor_rank} deferred to background")

        elif plan.kind is MoERecoveryKind.ROLE_SWITCH:
            donor_ex = eng.dp_executors[plan.donor_rank]
            with _T(report, "role_switch"):
                # migrate the donor's residents — the donor device is
                # healthy, so their KV blocks *stream* to the targets
                # instead of re-prefilling — then drop its attention duty
                reqs = donor_ex.drop_attention_state(collect_kv=True)
                n, n_streamed = self._migrate(reqs, exclude=donor_ex)
                report.migrated += n
                donor_ex.ep_rank = failed_ep_rank
                report.actions.append(
                    f"role switch: dp{plan.donor_rank} -> moe ep-rank "
                    f"{failed_ep_rank}; migrated {n} of its sequences "
                    f"({n_streamed} KV-streamed)")
            with _T(report, "generator"):
                # the lost experts' only copies are gone: load from disk
                from repro.serving.weights_util import (
                    load_expert_shard_from_checkpoint)
                template = eng.shards[failed_ep_rank]
                shard = load_expert_shard_from_checkpoint(
                    eng.ckpt_path, template, failed_ep_rank, eng.ep_size,
                    workdir=eng.ecfg.workdir)
                if failed_moe is not None:
                    # the switched device now hosts this EP rank
                    new_moe = type(failed_moe)(
                        physical_id=donor_ex.physical_id,
                        ep_rank=failed_ep_rank, shard=shard)
                    eng.moe_executors.append(new_moe)
                else:
                    donor_ex.shard = shard
                emap.install_rank(failed_ep_rank)
                eng.runtime = emap.runtime()
                eng.reassemble_params()
                report.actions.append(
                    f"reloaded EP rank {failed_ep_rank} weights from disk")

        # first-k dense FFN layers (§3.4): a shard lost and NOT recovered
        # compromises its TP group; attention rebalances tokens over the
        # healthy groups.  A role switch recovers the shard -> no rebalance.
        if eng.dense_groups is not None:
            recovered = (plan.kind is MoERecoveryKind.ROLE_SWITCH
                         and not plan.background)
            if not recovered:
                with _T(report, "other"):
                    group = failed_ep_rank % eng.dense_groups.num_groups
                    if eng.dense_groups.alive[group]:
                        eng.dense_groups.fail_shard(group)
                    w = eng.dense_groups.routing_weights()
                    report.actions.append(
                        f"dense-FFN TP group {group} compromised; token "
                        f"routing rebalanced to {w}")
        return plan

    def complete_background_switch(self, plan: MoERecoveryPlan) -> Dict:
        """Finish a deferred role switch while service keeps running
        (§4.3): load the lost shard from disk onto the donor, unmask, and
        restore full weight integrity.  Returns stage timings (these are
        NOT downtime — inference continued throughout)."""
        eng = self.engine
        emap = eng.expert_map
        timings: Dict[str, float] = {}
        donor_ex = eng.dp_executors[plan.donor_rank]
        failed_ep_rank = None
        # the rank whose experts are masked is the one to restore
        for r in range(eng.ep_size):
            if any(not emap.slot_alive[s] for s in emap.rank_slots(r)):
                failed_ep_rank = r
                break
        assert failed_ep_rank is not None
        t0 = time.perf_counter()
        reqs = donor_ex.drop_attention_state(collect_kv=True)
        self._migrate(reqs, exclude=donor_ex)
        donor_ex.ep_rank = failed_ep_rank
        timings["role_switch"] = time.perf_counter() - t0
        t0 = time.perf_counter()
        from repro.serving.weights_util import (
            load_expert_shard_from_checkpoint)
        shard = load_expert_shard_from_checkpoint(
            eng.ckpt_path, eng.shards[failed_ep_rank], failed_ep_rank,
            eng.ep_size, workdir=eng.ecfg.workdir)
        donor_ex.shard = shard
        restored = emap.install_rank(failed_ep_rank)
        eng.runtime = emap.runtime()
        eng.reassemble_params()
        timings["generator"] = time.perf_counter() - t0
        timings["restored_experts"] = float(len(restored))
        return timings

    def _pick_donor(self, exclude_pid: int) -> Optional[int]:
        """A healthy DP rank that could switch to MoE duty (needs >=2
        attention ranks left so attention service continues)."""
        eng = self.engine
        if eng.ecfg.mode != "disaggregated":
            return None
        healthy = [ex for ex in eng.dp_executors
                   if ex.alive and ex.cache is not None
                   and ex.physical_id != exclude_pid]
        if len(healthy) < 2:
            return None
        # least loaded donor
        return min(healthy, key=lambda e: e.scheduler.num_requests).dp_rank
