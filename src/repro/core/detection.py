"""Failure detection (§3.1): heartbeats + annotation polling.

Two complementary detectors, as in the paper:

* :class:`AnnotationPoller` — the device-plugin path: a side actor
  periodically reads node annotations written by the (here: injected)
  NPU fault reporter and converts them into recovery triggers based on
  their L1–L6 severity.
* :class:`HeartbeatMonitor` — the engine path: every executor heartbeats
  each engine step; a rank silent for ``timeout_steps`` raises a
  HEARTBEAT_TIMEOUT fault (catches hangs that never annotate).
"""
from __future__ import annotations

from typing import Dict, List, Set

from repro.core.fault_codes import Action, ErrorType, FaultEvent, Severity
from repro.core.faults import FaultInjector


class HeartbeatMonitor:
    def __init__(self, timeout_steps: int = 2):
        self.timeout_steps = timeout_steps
        self.last_beat: Dict[int, int] = {}
        self._reported: Set[int] = set()

    def register(self, physical_id: int, step: int = 0) -> None:
        self.last_beat[physical_id] = step

    def unregister(self, physical_id: int) -> None:
        self.last_beat.pop(physical_id, None)
        self._reported.discard(physical_id)

    def beat(self, physical_id: int, step: int) -> None:
        self.last_beat[physical_id] = step

    def check(self, step: int) -> List[FaultEvent]:
        events = []
        for pid, last in self.last_beat.items():
            if step - last >= self.timeout_steps and pid not in self._reported:
                self._reported.add(pid)
                events.append(FaultEvent(
                    rank=pid, severity=Severity.L5,
                    error_type=ErrorType.HEARTBEAT_TIMEOUT,
                    component="attn",
                    detail=f"no heartbeat for {step - last} steps"))
        return events


class AnnotationPoller:
    """Ray-actor analogue that watches node annotations for fault codes."""

    def __init__(self, injector: FaultInjector):
        self.injector = injector
        self.ignored: List[FaultEvent] = []

    def poll(self) -> List[FaultEvent]:
        """Return only events whose severity warrants action (L3+)."""
        actionable = []
        for ev in self.injector.drain_annotations():
            if ev.action is Action.IGNORE:
                self.ignored.append(ev)   # L1/L2: log only
            else:
                actionable.append(ev)
        return actionable


class StragglerDetector:
    """Slowdown detection — the paper's §6 stated future work.

    A single slow device stalls the whole MoE system (every collective
    waits for it), yet it never reports a fault code.  We keep a rolling
    window of per-device step durations; a device whose median exceeds
    ``ratio`` × the fleet median for ``patience`` consecutive checks is
    flagged with an L4 COMPUTE_FAULT — ReviveMoE then treats it exactly
    like a failed device (isolate + migrate), which is cheaper than
    letting it throttle every step.
    """

    def __init__(self, ratio: float = 3.0, window: int = 8,
                 patience: int = 2, min_samples: int = 4):
        self.ratio = ratio
        self.window = window
        self.patience = patience
        self.min_samples = min_samples
        self.samples: Dict[int, List[float]] = {}
        self.strikes: Dict[int, int] = {}
        self._reported: Set[int] = set()

    def forgive(self, physical_id: int) -> None:
        """Rejoin support: a device returning to service (cleared
        transient fault) starts with a clean slate — old samples,
        strikes and the reported flag would otherwise re-isolate it
        immediately on stale data."""
        self.samples.pop(physical_id, None)
        self.strikes.pop(physical_id, None)
        self._reported.discard(physical_id)

    def record(self, physical_id: int, duration_s: float) -> None:
        buf = self.samples.setdefault(physical_id, [])
        buf.append(duration_s)
        if len(buf) > self.window:
            buf.pop(0)

    def _median(self, xs: List[float]) -> float:
        s = sorted(xs)
        n = len(s)
        return s[n // 2] if n % 2 else 0.5 * (s[n // 2 - 1] + s[n // 2])

    def suspects(self) -> Dict[int, float]:
        """Soft signal: devices currently over ``ratio`` × fleet median,
        *before* patience promotes them to a hard L4 fault.  The fleet's
        RecoveryArbiter consumes this to drain an instance proactively
        (substitute a spare / shift traffic) instead of waiting for the
        straggler to throttle every collective step."""
        devs = {pid: buf for pid, buf in self.samples.items()
                if len(buf) >= self.min_samples}
        if len(devs) < 2:
            return {}
        medians = {pid: self._median(buf) for pid, buf in devs.items()}
        fleet = self._median(list(medians.values()))
        if fleet <= 0:
            return {}
        return {pid: m / fleet for pid, m in medians.items()
                if m > self.ratio * fleet and pid not in self._reported}

    def check(self) -> List[FaultEvent]:
        devs = {pid: buf for pid, buf in self.samples.items()
                if len(buf) >= self.min_samples}
        if len(devs) < 2:
            return []
        medians = {pid: self._median(buf) for pid, buf in devs.items()}
        fleet = self._median(list(medians.values()))
        events = []
        for pid, m in medians.items():
            if pid in self._reported:
                continue
            if fleet > 0 and m > self.ratio * fleet:
                self.strikes[pid] = self.strikes.get(pid, 0) + 1
                if self.strikes[pid] >= self.patience:
                    self._reported.add(pid)
                    events.append(FaultEvent(
                        rank=pid, severity=Severity.L4,
                        error_type=ErrorType.COMPUTE_FAULT,
                        component="attn",
                        detail=f"straggler: {m * 1e3:.1f}ms vs fleet "
                               f"median {fleet * 1e3:.1f}ms"))
            else:
                self.strikes[pid] = 0
        return events
