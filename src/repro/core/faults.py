"""Deterministic fault injection.

The injector plays the role of the physical failure: it schedules faults
at (engine step, device) granularity.  ``mid_step`` faults fire *inside*
an executor's generation step — after block-table mutations have been
logged but before the step commits — exercising the §3.3 undo path.
Fired faults surface as node annotations (the Kubernetes device-plugin
analogue) that the detection layer polls.

Campaign extensions: faults are *clearable* (a transient link flap ends
with :meth:`clear`, after which the same rank may fault again) and the
injector de-duplicates annotations — while a rank is down, further
scheduled faults on it are swallowed instead of re-annotating, so one
injector can drive recurring fault processes without double-reporting.
:meth:`reset` returns the injector to its initial state so it can be
reused across campaign episodes without leaking schedules, annotations
or down-rank state between seeds.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Set

from repro.core.fault_codes import ErrorType, FaultEvent, Severity


@dataclass
class ScheduledFault:
    at_step: int
    physical_id: int
    severity: Severity = Severity.L6
    error_type: ErrorType = ErrorType.HBM_ECC
    component: str = "attn"           # what the device was doing
    mid_step: bool = False            # fire inside the generation step
    fired: bool = False
    # recurring faults re-arm after :meth:`FaultInjector.clear` —
    # the flapping-link shape (fault -> clear -> re-fault on one rank)
    recurring: bool = False


class SimulatedDeviceFailure(Exception):
    def __init__(self, event: FaultEvent):
        super().__init__(str(event))
        self.event = event


class FaultInjector:
    def __init__(self):
        self.scheduled: List[ScheduledFault] = []
        self.annotations: List[FaultEvent] = []   # "node annotations"
        self._down: Set[int] = set()              # ranks fired, not cleared
        self.deduped = 0                          # swallowed duplicates

    def schedule(self, at_step: int, physical_id: int, *,
                 severity: Severity = Severity.L6,
                 error_type: ErrorType = ErrorType.HBM_ECC,
                 component: str = "attn", mid_step: bool = False,
                 recurring: bool = False) -> ScheduledFault:
        """Schedule a fault; returns the handle (usable with cancel()).

        Scheduling is idempotent: an identical still-pending entry is
        returned instead of duplicated, so campaign episodes may replay
        overlapping schedules onto one injector.
        """
        for f in self.scheduled:
            if (not f.fired and f.at_step == at_step
                    and f.physical_id == physical_id
                    and f.mid_step == mid_step
                    and f.error_type is error_type
                    and f.severity is severity):
                self.deduped += 1
                return f
        f = ScheduledFault(at_step, physical_id, severity, error_type,
                           component, mid_step, recurring=recurring)
        self.scheduled.append(f)
        return f

    def _fire(self, f: ScheduledFault) -> Optional[FaultEvent]:
        f.fired = True
        if f.physical_id in self._down:
            # the rank is already down and un-cleared: swallow the
            # duplicate instead of re-annotating (recovery already ran)
            self.deduped += 1
            return None
        self._down.add(f.physical_id)
        ev = FaultEvent(rank=f.physical_id, severity=f.severity,
                        error_type=f.error_type, component=f.component)
        self.annotations.append(ev)
        return ev

    @staticmethod
    def _due(f: ScheduledFault, step: int) -> bool:
        # a re-armed recurring fault has an at_step in the past: it fires
        # on the first step after the clear, not never
        return (f.at_step == step
                or (f.recurring and step >= f.at_step))

    def pre_step_faults(self, step: int) -> List[FaultEvent]:
        """Faults firing at a step boundary: annotate and return them."""
        out = []
        for f in self.scheduled:
            if not f.fired and not f.mid_step and self._due(f, step):
                ev = self._fire(f)
                if ev is not None:
                    out.append(ev)
        return out

    def maybe_fail_mid_step(self, step: int, physical_id: int) -> None:
        """Called from inside an executor's step; raises on a hit."""
        for f in self.scheduled:
            if (not f.fired and f.mid_step and self._due(f, step)
                    and f.physical_id == physical_id):
                ev = self._fire(f)
                if ev is not None:
                    raise SimulatedDeviceFailure(ev)

    # -- campaign lifecycle ------------------------------------------------------

    def clear(self, physical_id: int) -> bool:
        """The transient condition ended (link restored, thermals back in
        range): the rank may fault again.  Recurring faults on this rank
        re-arm.  Returns True if the rank was down."""
        was_down = physical_id in self._down
        self._down.discard(physical_id)
        for f in self.scheduled:
            if f.fired and f.recurring and f.physical_id == physical_id:
                f.fired = False
        return was_down

    def cancel(self, fault: Optional[ScheduledFault] = None, *,
               physical_id: Optional[int] = None) -> int:
        """Remove pending (unfired) schedule entries — a specific handle,
        every entry for one rank, or (no arguments) all of them.
        Returns the number removed."""
        def keep(f: ScheduledFault) -> bool:
            if f.fired:
                return True
            if fault is not None:
                return f is not fault
            if physical_id is not None:
                return f.physical_id != physical_id
            return False
        kept = [f for f in self.scheduled if keep(f)]
        removed = len(self.scheduled) - len(kept)
        self.scheduled = kept
        return removed

    def reset(self) -> None:
        """Back to pristine: no schedules, no annotations, no down ranks.
        Lets one injector be reused across campaign episodes without
        state leaking between seeds."""
        self.scheduled = []
        self.annotations = []
        self._down = set()
        self.deduped = 0

    def drain_annotations(self) -> List[FaultEvent]:
        out, self.annotations = self.annotations, []
        return out
