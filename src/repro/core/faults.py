"""Deterministic fault injection.

The injector plays the role of the physical failure: it schedules faults
at (engine step, device) granularity.  ``mid_step`` faults fire *inside*
an executor's generation step — after block-table mutations have been
logged but before the step commits — exercising the §3.3 undo path.
Fired faults surface as node annotations (the Kubernetes device-plugin
analogue) that the detection layer polls.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List

from repro.core.fault_codes import ErrorType, FaultEvent, Severity


@dataclass
class ScheduledFault:
    at_step: int
    physical_id: int
    severity: Severity = Severity.L6
    error_type: ErrorType = ErrorType.HBM_ECC
    component: str = "attn"           # what the device was doing
    mid_step: bool = False            # fire inside the generation step
    fired: bool = False


class SimulatedDeviceFailure(Exception):
    def __init__(self, event: FaultEvent):
        super().__init__(str(event))
        self.event = event


class FaultInjector:
    def __init__(self):
        self.scheduled: List[ScheduledFault] = []
        self.annotations: List[FaultEvent] = []   # "node annotations"

    def schedule(self, at_step: int, physical_id: int, *,
                 severity: Severity = Severity.L6,
                 error_type: ErrorType = ErrorType.HBM_ECC,
                 component: str = "attn", mid_step: bool = False) -> None:
        self.scheduled.append(ScheduledFault(
            at_step, physical_id, severity, error_type, component, mid_step))

    def pre_step_faults(self, step: int) -> List[FaultEvent]:
        """Faults firing at a step boundary: annotate and return them."""
        out = []
        for f in self.scheduled:
            if not f.fired and not f.mid_step and f.at_step == step:
                f.fired = True
                ev = FaultEvent(rank=f.physical_id, severity=f.severity,
                                error_type=f.error_type,
                                component=f.component)
                self.annotations.append(ev)
                out.append(ev)
        return out

    def maybe_fail_mid_step(self, step: int, physical_id: int) -> None:
        """Called from inside an executor's step; raises on a hit."""
        for f in self.scheduled:
            if (not f.fired and f.mid_step and f.at_step == step
                    and f.physical_id == physical_id):
                f.fired = True
                ev = FaultEvent(rank=physical_id, severity=f.severity,
                                error_type=f.error_type,
                                component=f.component)
                self.annotations.append(ev)
                raise SimulatedDeviceFailure(ev)

    def drain_annotations(self) -> List[FaultEvent]:
        out, self.annotations = self.annotations, []
        return out
