"""Weight-integrity planning (§3.4, Fig. 4 flowchart).

When a failure involves MoE weights, decide between:
  1. redundant experts  — every lost expert still has a live replica;
                          drop dead slots from the map (fast, lossless).
  2. role switch        — repurpose a replicated attention DP rank as the
                          new MoE rank; expert weights re-load from disk
                          (slow, lossless).
  3. missing experts    — mask lost experts' routing logits; accuracy
                          impact is negligible for EP >= 32 (§4.2).

Also models the dense-FFN TP-group handling for the first-k dense layers
(DeepSeek V3 / Kimi K2): a compromised TP group is removed and attention
rebalances its outgoing tokens over healthy groups.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import List, Optional

from repro.core.expert_map import ExpertMap


class MoERecoveryKind(enum.Enum):
    REDUNDANT_EXPERTS = "redundant_experts"
    ROLE_SWITCH = "role_switch"
    MISSING_EXPERTS = "missing_experts"


@dataclass(frozen=True)
class RecoveryPolicy:
    allow_role_switch: bool = True
    allow_missing_experts: bool = True
    # §4.2: <= 1/32 of experts lost has negligible accuracy impact
    min_ep_for_missing: int = 32
    # §4.3: run the role switch in the background while serving with the
    # (possibly incomplete) current expert set
    background_role_switch: bool = False


@dataclass
class MoERecoveryPlan:
    kind: MoERecoveryKind
    lost_logicals: List[int] = field(default_factory=list)
    donor_rank: Optional[int] = None     # DP rank switched to MoE duty
    accuracy_warning: bool = False       # missing-experts below EP threshold
    background: bool = False             # serve degraded while switching

    def describe(self) -> str:
        s = f"{self.kind.value}"
        if self.lost_logicals:
            s += f" lost={self.lost_logicals[:8]}" + (
                "..." if len(self.lost_logicals) > 8 else "")
        if self.donor_rank is not None:
            s += f" donor=dp{self.donor_rank}"
        if self.accuracy_warning:
            s += " [WARN: EP below missing-expert threshold]"
        return s


def plan_moe_recovery(expert_map: ExpertMap, policy: RecoveryPolicy,
                      donor_rank: Optional[int]) -> MoERecoveryPlan:
    """Fig. 4: choose the recovery action after ``fail_rank`` was applied.

    donor_rank: a healthy, replicated attention DP rank that could be
    switched to MoE duty (None if unavailable).
    """
    lost = expert_map.fully_lost()
    if not lost:
        # every expert on the failed rank is replicated elsewhere
        return MoERecoveryPlan(MoERecoveryKind.REDUNDANT_EXPERTS)
    ep_ok = expert_map.ep_size >= policy.min_ep_for_missing
    can_switch = policy.allow_role_switch and donor_rank is not None
    if can_switch and not (policy.background_role_switch and
                           policy.allow_missing_experts):
        return MoERecoveryPlan(MoERecoveryKind.ROLE_SWITCH,
                               lost_logicals=lost, donor_rank=donor_rank)
    if can_switch and policy.background_role_switch:
        # §4.3 combined mode: mask now, restore full integrity in background
        return MoERecoveryPlan(MoERecoveryKind.ROLE_SWITCH,
                               lost_logicals=lost, donor_rank=donor_rank,
                               background=True,
                               accuracy_warning=not ep_ok)
    if policy.allow_missing_experts:
        return MoERecoveryPlan(MoERecoveryKind.MISSING_EXPERTS,
                               lost_logicals=lost,
                               accuracy_warning=not ep_ok)
    raise RuntimeError(
        f"unrecoverable: experts {lost} lost, role switch unavailable, "
        f"missing-experts disallowed")


# ---------------------------------------------------------------------------
# dense-FFN TP groups (first-k dense layers of DeepSeek V3 / Kimi K2)
# ---------------------------------------------------------------------------

class DenseFFNGroups:
    """Replicated TP groups serving the first-k dense FFN layers.

    A lost shard compromises its whole TP group; attention then rebalances
    outgoing tokens evenly over the healthy groups (§3.4)."""

    def __init__(self, num_groups: int, tp_size: int = 4):
        assert num_groups >= 1
        self.num_groups = num_groups
        self.tp_size = tp_size
        self.alive = [True] * num_groups

    def fail_shard(self, group: int) -> None:
        assert 0 <= group < self.num_groups
        self.alive[group] = False

    def num_healthy(self) -> int:
        return sum(self.alive)

    def routing_weights(self) -> List[float]:
        """Token fractions per group: even over healthy, 0 for compromised."""
        h = self.num_healthy()
        if h == 0:
            raise RuntimeError("all dense-FFN TP groups compromised")
        return [1.0 / h if a else 0.0 for a in self.alive]
