"""Graph precompilation and cached compilation (§3.6).

The computation graph is compiled per (phase, domain version, shape
bucket).  Recovery changes the domain version (the post-failure world),
so a fresh executable is needed before inference can resume.  Three tiers,
mirroring the paper's Figure 5 categories:

* **precompiled**  — ReviveMoE precompiles executables for anticipated
  failure scenarios at startup; recovery-time cost is a dict lookup
  ("Read Cache" ~ 0, "Compile" ~ 0).
* **cached compile** — JAX's persistent compilation cache on disk plays
  the role of the saved Dynamo/Ascend-IR cache: the HLO is re-lowered but
  the expensive backend compile is served from disk.
* **cold compile** — nothing cached; the full compile (the paper's 12.9
  minute case, scaled down to our model sizes).

Every compile is timed and the (read_cache_s, compile_s, source) triple
is what benchmarks/recovery_time.py reports.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax


@dataclass
class CompileTiming:
    source: str            # 'precompiled' | 'cached' | 'cold'
    read_cache_s: float
    compile_s: float
    key: Tuple = ()


class GraphCache:
    def __init__(self, persist_dir: Optional[str] = None):
        """persist_dir: enables the on-disk compilation cache tier."""
        self.persist_dir = persist_dir
        if persist_dir:
            jax.config.update("jax_compilation_cache_dir", persist_dir)
            jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)
            jax.config.update("jax_persistent_cache_min_entry_size_bytes", 0)
        self._exec: Dict[Tuple, Any] = {}
        self.timings: list[CompileTiming] = []

    def __contains__(self, key) -> bool:
        return key in self._exec

    def precompile(self, key: Tuple, fn: Callable, arg_shapes: Tuple,
                   static_argnames=(), donate_argnums=()) -> CompileTiming:
        """AOT lower+compile now so recovery finds a ready executable.

        ``donate_argnums`` donates those inputs' buffers to the outputs
        (the engine donates the KV pool into decode/chunk steps — safe
        because the §3.3 row-level undo snapshots the written rows on the
        host *before* the step runs)."""
        t0 = time.perf_counter()
        lowered = jax.jit(fn, static_argnames=static_argnames,
                          donate_argnums=donate_argnums).lower(*arg_shapes)
        t1 = time.perf_counter()
        compiled = lowered.compile()
        t2 = time.perf_counter()
        self._exec[key] = compiled
        tm = CompileTiming("precompiled", t1 - t0, t2 - t1, key)
        self.timings.append(tm)
        return tm

    def get_or_compile(self, key: Tuple, fn: Callable, arg_shapes: Tuple,
                       donate_argnums=()) -> Tuple[Any, CompileTiming]:
        """Recovery-time lookup: precompiled hit is ~free; otherwise a real
        (possibly persistent-cache-served) compile happens and is timed."""
        if key in self._exec:
            tm = CompileTiming("precompiled", 0.0, 0.0, key)
            self.timings.append(tm)
            return self._exec[key], tm
        t0 = time.perf_counter()
        lowered = jax.jit(fn, donate_argnums=donate_argnums).lower(*arg_shapes)
        t1 = time.perf_counter()
        compiled = lowered.compile()
        t2 = time.perf_counter()
        self._exec[key] = compiled
        source = "cached" if self.persist_dir else "cold"
        tm = CompileTiming(source, t1 - t0, t2 - t1, key)
        self.timings.append(tm)
        return compiled, tm

    def invalidate(self, predicate: Callable[[Tuple], bool]) -> int:
        drop = [k for k in self._exec if predicate(k)]
        for k in drop:
            del self._exec[k]
        return len(drop)
