"""Logical→physical expert mapping with redundancy (§3.4).

The host-side authority over expert placement.  Physical slots live on EP
ranks; redundant slots replicate (by default the hottest = first R)
logical experts.  Recovery mutates this map — dropping dead replicas,
masking fully-lost experts, or re-installing a rank after a role switch —
and re-emits the device-side :class:`MoERuntime` arrays.  The compiled
graph never changes: recovery is a data update (the paper's point about
"removing the failed experts from the logical-to-physical mapping").
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Set

import jax.numpy as jnp
import numpy as np

from repro.configs.base import MoEConfig
from repro.models.moe import MAX_REPLICAS, MoERuntime, physical_experts


class ExpertMap:
    def __init__(self, moe: MoEConfig, ep_size: int,
                 hot_experts: Optional[Sequence[int]] = None):
        self.moe = moe
        self.ep_size = ep_size
        E_log, R = moe.num_experts, moe.num_redundant_experts
        self.E_phys = physical_experts(moe)
        assert self.E_phys % ep_size == 0, (self.E_phys, ep_size)
        self.slots_per_rank = self.E_phys // ep_size
        # slot -> logical expert (base slots then replicas of hot experts)
        hot = list(hot_experts) if hot_experts is not None else list(range(R))
        assert len(hot) == R
        self.slot_logical: List[int] = list(range(E_log)) + hot
        self.slot_alive: List[bool] = [True] * self.E_phys
        self.masked: Set[int] = set()

    # -- placement queries ---------------------------------------------------

    def rank_of_slot(self, slot: int) -> int:
        return slot // self.slots_per_rank

    def rank_slots(self, rank: int) -> range:
        return range(rank * self.slots_per_rank,
                     (rank + 1) * self.slots_per_rank)

    def replicas_of(self, logical: int) -> List[int]:
        return [s for s, l in enumerate(self.slot_logical)
                if l == logical and self.slot_alive[s]]

    def fully_lost(self) -> List[int]:
        """Logical experts with zero alive replicas (and not yet masked)."""
        alive_logicals = {self.slot_logical[s]
                          for s in range(self.E_phys) if self.slot_alive[s]}
        return [e for e in range(self.moe.num_experts)
                if e not in alive_logicals and e not in self.masked]

    # -- recovery mutations ------------------------------------------------------

    def fail_rank(self, rank: int) -> List[int]:
        """Mark all slots of an EP rank dead. Returns affected logicals."""
        affected = []
        for s in self.rank_slots(rank):
            if self.slot_alive[s]:
                self.slot_alive[s] = False
                affected.append(self.slot_logical[s])
        return affected

    def mask_experts(self, logicals: Sequence[int]) -> None:
        """§3.4 'missing experts': routing logits masked to -inf."""
        self.masked.update(logicals)

    def install_rank(self, rank: int) -> List[int]:
        """Role switch complete: the rank's slots are healthy again
        (weights were re-loaded from disk onto the switched device)."""
        restored = []
        for s in self.rank_slots(rank):
            if not self.slot_alive[s]:
                self.slot_alive[s] = True
                restored.append(self.slot_logical[s])
        # a restored expert no longer needs masking
        self.masked -= set(restored)
        return restored

    def rebalance_replicas(self, usage_counts) -> Dict[int, int]:
        """Re-point the *alive* redundant slots at the currently hottest
        experts (the paper: "redundant experts are typically selected
        based on usage frequency").  Slot placement is fixed (weights must
        be copied by the caller); returns {slot: new_logical} moves.

        Fault-tolerance interaction (§4.3): the hottest experts end up
        double-covered, but a cold expert's last copy can still be lost —
        which is exactly why role switching exists.
        """
        E = self.moe.num_experts
        order = sorted(range(E), key=lambda e: -usage_counts[e])
        moves: Dict[int, int] = {}
        assigned: Set[int] = set()
        for s in range(E, self.E_phys):
            if not self.slot_alive[s]:
                continue
            rank = self.rank_of_slot(s)
            # anti-affinity: a replica on the same rank as every existing
            # copy gives zero fault isolation — pick the hottest expert
            # whose alive copies all live on *other* ranks
            want = None
            for e in order:
                if e in assigned:
                    continue
                if any(self.rank_of_slot(r) == rank
                       for r in self.replicas_of(e) if r != s):
                    continue
                want = e
                break
            if want is None:
                continue
            assigned.add(want)
            if self.slot_logical[s] != want:
                moves[s] = want
                self.slot_logical[s] = want
        return moves

    # -- device-side arrays ---------------------------------------------------------

    def runtime(self) -> MoERuntime:
        E_log = self.moe.num_experts
        l2p = np.zeros((E_log, MAX_REPLICAS), np.int32)
        count = np.zeros((E_log,), np.int32)
        mask = np.ones((E_log,), bool)
        for e in range(E_log):
            reps = self.replicas_of(e)[:MAX_REPLICAS]
            count[e] = len(reps)
            for i, s in enumerate(reps):
                l2p[e, i] = s
            if e in self.masked or not reps:
                mask[e] = False
        return MoERuntime(jnp.asarray(l2p), jnp.asarray(count),
                          jnp.asarray(mask))

    # -- introspection -----------------------------------------------------------------

    def coverage(self) -> float:
        """Fraction of logical experts with >=1 alive replica (masked
        experts still count as lost — masking hides, not restores)."""
        E = self.moe.num_experts
        alive_logicals = {self.slot_logical[s]
                          for s in range(self.E_phys) if self.slot_alive[s]}
        return len([e for e in range(E) if e in alive_logicals]) / E

    def describe(self) -> str:
        dead = [s for s in range(self.E_phys) if not self.slot_alive[s]]
        return (f"ExpertMap(E_log={self.moe.num_experts}, "
                f"E_phys={self.E_phys}, ep={self.ep_size}, "
                f"dead_slots={dead}, masked={sorted(self.masked)})")
