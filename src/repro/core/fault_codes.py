"""Fault taxonomy (§3.1): six severity levels, L1 benign → L6 critical.

Mirrors the Huawei NPU device-plugin fault reporting consumed by
ReviveMoE: each fault carries an event id, alarm time, severity and error
type.  The severity decides the action:

  L1–L2  benign / transient         -> log only, no action
  L3–L4  recoverable device errors  -> ReviveMoE recovery, device may rejoin
  L5–L6  critical hardware faults   -> full isolation + ReviveMoE recovery
"""
from __future__ import annotations

import enum
import itertools
import time
from dataclasses import dataclass, field


class Severity(enum.IntEnum):
    L1 = 1
    L2 = 2
    L3 = 3
    L4 = 4
    L5 = 5
    L6 = 6


class Action(enum.Enum):
    IGNORE = "ignore"
    RECOVER = "recover"
    ISOLATE_AND_RECOVER = "isolate_and_recover"


def action_for(severity: Severity) -> Action:
    if severity <= Severity.L2:
        return Action.IGNORE
    if severity <= Severity.L4:
        return Action.RECOVER
    return Action.ISOLATE_AND_RECOVER


class ErrorType(enum.Enum):
    HBM_ECC = "hbm_ecc"
    LINK_DOWN = "link_down"
    OVER_TEMP = "over_temp"
    DRIVER_HANG = "driver_hang"
    COMPUTE_FAULT = "compute_fault"
    HEARTBEAT_TIMEOUT = "heartbeat_timeout"


_event_counter = itertools.count(1)


@dataclass(frozen=True)
class FaultEvent:
    rank: int                     # logical rank of the affected device
    severity: Severity
    error_type: ErrorType
    component: str                # 'attn' | 'moe'
    event_id: int = field(default_factory=lambda: next(_event_counter))
    alarm_time: float = field(default_factory=time.monotonic)
    detail: str = ""

    @property
    def action(self) -> Action:
        return action_for(self.severity)

    def __str__(self) -> str:
        return (f"FaultEvent#{self.event_id}[{self.severity.name} "
                f"{self.error_type.value} rank={self.rank} "
                f"component={self.component} -> {self.action.value}]")
