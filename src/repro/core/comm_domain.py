"""Communication-domain modelling and rank compaction (§3.5).

Tracks the logical-rank assignment of every device across the attention
and MoE groups.  On failure the failed device is treated as *inaccessible*
(it physically remains, but no operation may touch it):

* default world group stays intact — we only rebuild subgroups,
* XCCL-style domains are destroyed and recreated: the trampoline domain
  (between experts, MA-disaggregated only) first, then the
  attention↔expert domain,
* logical ranks are *compacted*: if rank ℓ_A fails, every rank ℓ > ℓ_A
  decrements by one; in a role switch, the switched device C takes ℓ_A
  directly, then remaining gaps compact.

``version`` increments on every rebuild — it is the key under which the
computation graph must be (re-)compiled (§3.6).
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple


@dataclass
class DeviceRank:
    physical_id: int
    logical_rank: int
    role: str            # 'attn' | 'moe' | 'attn+moe' (collocated)
    alive: bool = True


class CommDomain:
    def __init__(self, n_attn: int, n_moe: int, collocated: bool):
        """collocated: attention and MoE share devices (n_moe ignored)."""
        self.collocated = collocated
        self.version = 0
        self.rebuild_log: List[Dict] = []
        self.ranks: List[DeviceRank] = []
        if collocated:
            for i in range(n_attn):
                self.ranks.append(DeviceRank(i, i, "attn+moe"))
        else:
            for i in range(n_attn):
                self.ranks.append(DeviceRank(i, i, "attn"))
            for j in range(n_moe):
                self.ranks.append(DeviceRank(n_attn + j, j, "moe"))

    # -- queries ---------------------------------------------------------------

    def device(self, physical_id: int) -> DeviceRank:
        for r in self.ranks:
            if r.physical_id == physical_id:
                return r
        raise KeyError(physical_id)

    def group(self, role_substr: str, alive_only=True) -> List[DeviceRank]:
        return [r for r in self.ranks
                if role_substr in r.role and (r.alive or not alive_only)]

    @property
    def world_size(self) -> int:
        return sum(r.alive for r in self.ranks)

    def logical_map(self, role_substr: str) -> Dict[int, int]:
        """physical_id -> logical rank within the role group."""
        return {r.physical_id: r.logical_rank
                for r in self.group(role_substr)}

    # -- failure + compaction (§3.5) ---------------------------------------------

    def fail(self, physical_id: int) -> DeviceRank:
        r = self.device(physical_id)
        r.alive = False
        return r

    def compact(self, role_substr: str,
                switched_physical: Optional[int] = None) -> Dict[int, Tuple[int, int]]:
        """Close logical-rank gaps left by dead devices in one role group.

        If ``switched_physical`` is given (role switch), that device takes
        the failed device's logical rank directly; remaining gaps close by
        decrementing subsequent ranks.  Returns {physical_id: (old, new)}.
        """
        changes: Dict[int, Tuple[int, int]] = {}
        members = self.group(role_substr, alive_only=False)
        dead = sorted(r.logical_rank for r in members if not r.alive)
        if switched_physical is not None and dead:
            target = dead.pop(0)
            sw = self.device(switched_physical)
            changes[sw.physical_id] = (sw.logical_rank, target)
            sw.logical_rank = target
            sw.role = role_substr
            sw.alive = True
        # decrement every alive rank above each remaining gap
        for gap in reversed(dead):
            for r in self.group(role_substr):
                if r.logical_rank > gap:
                    changes.setdefault(r.physical_id,
                                       (r.logical_rank, r.logical_rank))
                    old = changes[r.physical_id][0]
                    r.logical_rank -= 1
                    changes[r.physical_id] = (old, r.logical_rank)
        return changes

    # -- rebuild (timed; the XCCL destroy/create analogue) -------------------------

    def rebuild(self, role_switch_physical: Optional[int] = None) -> Dict:
        t0 = time.perf_counter()
        stages = []
        if not self.collocated:
            stages.append("destroy_trampoline_domain")   # inter-expert
        stages.append("destroy_attn_expert_domain")
        attn_changes = self.compact("attn") if not self.collocated else {}
        moe_role = "moe" if not self.collocated else "attn+moe"
        moe_changes = self.compact(moe_role,
                                   switched_physical=role_switch_physical)
        stages.append("create_attn_expert_domain")
        if not self.collocated:
            stages.append("create_trampoline_domain")
        self.version += 1
        rec = {
            "version": self.version,
            "stages": stages,
            "rank_changes": {**attn_changes, **moe_changes},
            "world_size": self.world_size,
            "elapsed_s": time.perf_counter() - t0,
        }
        self.rebuild_log.append(rec)
        return rec
