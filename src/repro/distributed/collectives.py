"""Distributed MoE application — the XCCL dispatch/combine analogue.

``MoEDist`` (default, ``gather_psum``): 2D-sharded experts —
expert *slots* over 'model' (EP), each expert's FFN dim over 'data'
(expert-TP) — so trillion-parameter expert banks fit 256 chips
(e.g. Kimi K2: 2.2 TB of experts → 8.6 GB/chip).  Tokens arrive sharded
over DP; dispatch = chunked all-gather over 'data' (the microbatching the
paper uses to overlap attention and MoE, §2.2), combine = psum over
'model' (expert-slot partials) + psum_scatter over 'data' (FFN-dim
partials + return to DP sharding).  The 'pod' axis stays pure DP: each
pod is an independent EP group, exactly the paper's
one-instance-per-pod deployment.

``MoEDistA2A``: explicit all-to-all dispatch/combine (A2E/E2A analogue,
MegaScale-style) — tokens travel to expert owners instead of being
replicated.  Collective volume per layer is O(T·k·D/ep · 2) vs
O(T·D·(1 + 1/dp)) for gather_psum; the §Perf pass quantifies both.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.moe import (MoERuntime, dispatch_compute_combine_fused,
                              dispatch_fn, experts_compute, group_by_expert,
                              physical_experts, route, select_replicas)

try:
    from jax.experimental.shard_map import shard_map
except ImportError:  # newer jax
    from jax import shard_map

# max tokens materialized per all-gathered dispatch chunk (bounds the
# transient activation: 64k × 8192 × bf16 ≈ 1 GiB)
MAX_GATHERED_TOKENS = 65536


class MoEDist:
    """gather_psum with 2D expert sharding (slots × FFN-dim)."""

    name = "gather_psum"

    def __init__(self, mesh, dp_axes: Tuple[str, ...] = ("data",),
                 ep_axis: str = "model", tp_axis: str = "data"):
        self.mesh = mesh
        self.dp_axes = dp_axes
        self.ep_axis = ep_axis
        self.tp_axis = tp_axis
        self.dp_size = math.prod(
            mesh.shape[a] for a in dp_axes) if dp_axes else 1
        self.ep_size = mesh.shape[ep_axis]
        self.tp_size = mesh.shape[tp_axis]
        self.pod_size = mesh.shape.get("pod", 1)

    # tokens processed together in one EP group (= one pod)
    def group_tokens(self, global_tokens: int) -> int:
        return max(1, global_tokens // self.pod_size)

    def cap_for(self, global_tokens: int, moe) -> int:
        """Per-expert capacity for one dispatch chunk."""
        from repro.models.moe import capacity
        T_group = self.group_tokens(global_tokens)
        n_chunks = max(1, -(-T_group // MAX_GATHERED_TOKENS))
        chunk = max(1, T_group // n_chunks)
        return capacity(chunk * moe.top_k, physical_experts(moe),
                        moe.capacity_factor, floor=moe.min_capacity)

    def expert_specs(self):
        """PartitionSpecs for stacked expert leaves (L, E, D, F)/(L, E, F, D)."""
        return {
            "gate": P(None, self.ep_axis, None, self.tp_axis),
            "up": P(None, self.ep_axis, None, self.tp_axis),
            "down": P(None, self.ep_axis, self.tp_axis, None),
        }

    def apply(self, p, cfg: ModelConfig, x_flat, runtime: MoERuntime,
              cap: int):
        moe = cfg.moe
        e_phys = physical_experts(moe)
        assert e_phys % self.ep_size == 0, (e_phys, self.ep_size)
        e_local = e_phys // self.ep_size
        ep_axis, tp_axis, dp = self.ep_axis, self.tp_axis, self.dp_axes
        T_global = x_flat.shape[0]
        # tiny batches (long_500k decode: B=1) cannot shard over DP;
        # tokens stay replicated and the gather/scatter legs drop out
        replicated = T_global % self.dp_size != 0
        T_group = self.group_tokens(T_global)
        n_chunks = (1 if replicated
                    else max(1, -(-T_group // MAX_GATHERED_TOKENS)))
        mesh = self.mesh

        def inner(router_w, gate_w, up_w, down_w, x_loc, rt):
            T_loc, D = x_loc.shape
            assert T_loc % n_chunks == 0, (T_loc, n_chunks)
            offset = jax.lax.axis_index(ep_axis) * e_local
            xc = x_loc.reshape(n_chunks, T_loc // n_chunks, D)

            def one_chunk(carry, x_chunk):
                # dispatch: replicate this chunk's tokens across the EP
                # group (chunked all-gather = microbatched A2E)
                if replicated:
                    xg = x_chunk
                else:
                    xg = jax.lax.all_gather(x_chunk, tp_axis, axis=0,
                                            tiled=True)
                weights, sel, aux = route(router_w, xg, rt, moe)
                phys, alive = select_replicas(sel, rt)
                y = dispatch_fn(cfg)(
                    xg, weights, phys, alive, gate_w, up_w, down_w,
                    cap=cap, expert_offset=offset, e_local=e_local)
                # combine: expert-slot partials over EP, FFN-dim partials
                # over expert-TP (+ scatter back to the DP layout) = E2A
                y = jax.lax.psum(y, ep_axis)
                if replicated:
                    y = jax.lax.psum(y, tp_axis)
                else:
                    y = jax.lax.psum_scatter(y, tp_axis,
                                             scatter_dimension=0, tiled=True)
                return carry + aux, y

            aux, ys = jax.lax.scan(one_chunk, 0.0, xc)
            y = ys.reshape(T_loc, D)
            axes = tuple(dp) + (ep_axis,)
            aux = jax.lax.psum(aux, axes) / (
                math.prod(mesh.shape[a] for a in axes) * n_chunks)
            return y, aux

        tok_spec = P(None, None) if replicated else P(dp, None)
        espec = self.expert_specs()
        fn = shard_map(
            inner, mesh=mesh,
            in_specs=(P(None, None),                   # router (replicated)
                      P(*espec["gate"][1:]), P(*espec["up"][1:]),
                      P(*espec["down"][1:]),
                      tok_spec,
                      MoERuntime(P(None, None), P(None), P(None))),
            out_specs=(tok_spec, P()),
            check_rep=False,
        )
        return fn(p["router"], p["gate"], p["up"], p["down"], x_flat,
                  runtime)


class MoEDistA2A(MoEDist):
    """Explicit all-to-all dispatch/combine (A2E/E2A analogue).

    Tokens enter sharded over (dp..., ep); each rank sends its tokens' k
    copies to the owning EP rank and receives outputs back.  Expert
    weights keep the same 2D sharding, so the FFN-dim partials still
    psum over the tp axis — but the token payload on the wire is only
    the routed copies, not a full replication.
    """

    name = "a2a"

    def cap_for(self, global_tokens: int, moe) -> int:
        """Per-(src,dst) send capacity: expected T_loc·k/ep, padded."""
        from repro.models.moe import capacity
        T_loc = max(1, global_tokens // (self.dp_size * self.ep_size))
        return capacity(T_loc * moe.top_k, self.ep_size,
                        moe.capacity_factor, floor=moe.min_capacity)

    def apply(self, p, cfg: ModelConfig, x_flat, runtime: MoERuntime,
              cap: int):
        moe = cfg.moe
        e_phys = physical_experts(moe)
        e_local = e_phys // self.ep_size
        ep_axis, tp_axis, dp = self.ep_axis, self.tp_axis, self.dp_axes
        ep = self.ep_size
        token_axes = tuple(dp) + (ep_axis,)
        mesh = self.mesh

        def inner(router_w, gate_w, up_w, down_w, x_loc, rt):
            T, D = x_loc.shape
            k = moe.top_k
            my_rank = jax.lax.axis_index(ep_axis)
            weights, sel, aux = route(router_w, x_loc, rt, moe)
            phys, alive = select_replicas(sel, rt)            # (T, k)
            dest = phys // e_local                            # owner rank
            N = T * k
            order, s_dest, s_pos = group_by_expert(
                dest.reshape(N), alive.reshape(N), ep, cap)
            tok = jnp.arange(N, dtype=jnp.int32) // k

            send = jnp.zeros((ep, cap, D), x_loc.dtype)
            send = send.at[s_dest, s_pos].set(x_loc[tok[order]],
                                              mode="drop")
            send_e = jnp.full((ep, cap), e_phys, jnp.int32).at[
                s_dest, s_pos].set(phys.reshape(N)[order], mode="drop")

            # A2E: token copies travel to their expert's owner rank
            recv = jax.lax.all_to_all(send, ep_axis, 0, 0, tiled=False)
            recv_e = jax.lax.all_to_all(send_e, ep_axis, 0, 0, tiled=False)
            rt_tokens = recv.reshape(ep * cap, D)
            rt_e = recv_e.reshape(ep * cap) - my_rank * e_local
            rt_ok = (rt_e >= 0) & (rt_e < e_local)

            cap2 = min(ep * cap, max(8, int(
                moe.capacity_factor * ep * cap / max(e_local, 1))))
            if cfg.moe_fused:
                # the fused kernel re-derives the grouping from its own
                # sort pass; received tokens act as top-1 routed tokens
                y_recv = dispatch_compute_combine_fused(
                    rt_tokens, jnp.ones((ep * cap, 1), jnp.float32),
                    rt_e[:, None], rt_ok[:, None], gate_w, up_w, down_w,
                    cap=cap2, expert_offset=0, e_local=e_local)
                # FFN-dim partials combine over the expert-TP axis
                y_recv = jax.lax.psum(y_recv, tp_axis).astype(x_loc.dtype)
            else:
                order2, d_e, d_p = group_by_expert(rt_e, rt_ok, e_local,
                                                   cap2)
                buf = jnp.zeros((e_local, cap2, D), x_loc.dtype)
                buf = buf.at[d_e, d_p].set(rt_tokens[order2], mode="drop")
                out_buf = experts_compute(gate_w, up_w, down_w, buf)
                # FFN-dim partials combine over the expert-TP axis
                out_buf = jax.lax.psum(out_buf, tp_axis)
                y_sorted = out_buf.at[d_e, d_p].get(mode="fill",
                                                    fill_value=0.0)
                y_recv = jnp.zeros((ep * cap, D), x_loc.dtype).at[
                    order2].set(y_sorted)

            # E2A: expert outputs travel home
            back = jax.lax.all_to_all(y_recv.reshape(ep, cap, D),
                                      ep_axis, 0, 0, tiled=False)
            y_flat_sorted = back.at[s_dest, s_pos].get(
                mode="fill", fill_value=0.0)                   # (N, D)
            y_flat = jnp.zeros((N, D), x_loc.dtype).at[order].set(
                y_flat_sorted)
            y = (y_flat.reshape(T, k, D) *
                 weights[..., None].astype(x_loc.dtype)).sum(axis=1)
            aux = jax.lax.psum(aux, token_axes) / math.prod(
                mesh.shape[a] for a in token_axes)
            return y, aux

        espec = self.expert_specs()
        fn = shard_map(
            inner, mesh=mesh,
            in_specs=(P(None, None),
                      P(*espec["gate"][1:]), P(*espec["up"][1:]),
                      P(*espec["down"][1:]),
                      P(token_axes, None),
                      MoERuntime(P(None, None), P(None), P(None))),
            out_specs=(P(token_axes, None), P()),
            check_rep=False,
        )
        return fn(p["router"], p["gate"], p["up"], p["down"], x_flat,
                  runtime)


def make_moe_dist(mesh, impl: str, dp_axes=("data",), ep_axis="model"):
    """impl may be any ``ModelConfig.MOE_IMPLS`` value; the '_fused'
    suffix only changes the *local* compute (selected via cfg at apply
    time), so both suffixed names map onto the same dist class."""
    base = "a2a" if impl.startswith("a2a") else "gather_psum"
    cls = {"gather_psum": MoEDist, "a2a": MoEDistA2A}[base]
    return cls(mesh, dp_axes, ep_axis)
