"""Sharding rules: param/cache/batch PartitionSpecs per architecture.

Baseline policy (DESIGN.md §6):
* batch over the DP axes (("pod",)+"data" when multi-pod),
* routed experts over 'model' (EP) — the paper's deployment style,
* dense FFN / mamba channels over 'model' (Megatron TP),
* attention: paper-faithful TP=1 (replicated over 'model') for MoE
  families; head-sharded TP for the big dense models where head counts
  divide (they do not fit a chip replicated),
* vocab (embed/lm_head) over 'model' (padded to a multiple of 2048),
* decode caches: batch over DP when divisible (long_500k B=1 replicates).

Every rule degrades to replication when a dimension does not divide the
axis — correctness first, the §Perf pass tunes the exceptions.
"""
from __future__ import annotations

from typing import Tuple

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig


def _keys(path) -> Tuple[str, ...]:
    out = []
    for k in path:
        if hasattr(k, "key"):
            out.append(str(k.key))
        elif hasattr(k, "name"):
            out.append(str(k.name))
        else:
            out.append(str(k))
    return tuple(out)


class ShardingRules:
    def __init__(self, mesh, cfg: ModelConfig):
        self.mesh = mesh
        self.cfg = cfg
        self.model_size = mesh.shape["model"]
        self.dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
        self.dp_size = 1
        for a in self.dp:
            self.dp_size *= mesh.shape[a]
        # attention TP only where heads divide the model axis; MoE
        # families keep the paper's attention-DP/TP=1 layout unless heads
        # divide (costless to shard the projections when they do).
        self.attn_tp = (cfg.num_heads > 0 and
                        cfg.num_heads % self.model_size == 0)
        self.kv_tp = (cfg.num_kv_heads > 0 and
                      cfg.num_kv_heads % self.model_size == 0)
        self.data_size = mesh.shape.get("data", 1)
        # FSDP-style 2D weight sharding for dense models too large for
        # 16-way TP alone (e.g. nemotron-340B: 680 GB bf16 -> 42 GB/chip
        # at TP16; 2D over (data, model) -> 2.7 GB/chip).  GSPMD streams
        # the per-layer all-gather inside the layer scan.
        dense_bytes = self._non_expert_param_bytes()
        self.fsdp = dense_bytes / self.model_size > 12e9

    def _non_expert_param_bytes(self) -> float:
        cfg = self.cfg
        D = cfg.d_model
        per_layer = 0.0
        if cfg.num_heads:
            Dh = cfg.resolved_head_dim()
            per_layer += D * (cfg.num_heads + 2 * cfg.num_kv_heads) * Dh \
                + cfg.num_heads * Dh * D
        if cfg.moe is None and cfg.d_ff:
            per_layer += 3 * D * cfg.d_ff
        if cfg.mamba is not None:
            di = cfg.mamba.expand * D
            per_layer += 2 * D * di * 2
        n = cfg.num_layers + cfg.encoder_layers
        return (per_layer * n + 2 * cfg.vocab_size * D) * 2.0  # bf16

    # -- params ------------------------------------------------------------------

    def _div(self, dim: int) -> bool:
        return dim % self.model_size == 0

    def param_spec(self, path, leaf) -> P:
        keys = _keys(path)
        name = keys[-1]
        shape = leaf.shape
        nd = len(shape)

        wide = ("data", "model")
        wide_size = self.data_size * self.model_size

        def col():  # shard last dim
            if self.fsdp and shape[-1] % wide_size == 0:
                return P(*([None] * (nd - 1) + [wide]))
            if self._div(shape[-1]):
                return P(*([None] * (nd - 1) + ["model"]))
            return P()

        def row(axis_from_end=2):  # shard dim -2
            sp = [None] * nd
            if self.fsdp and shape[-axis_from_end] % wide_size == 0:
                sp[nd - axis_from_end] = wide
                return P(*sp)
            if self._div(shape[-axis_from_end]):
                sp[nd - axis_from_end] = "model"
                return P(*sp)
            return P()

        if name == "embed":
            if self.fsdp and shape[-1] % self.data_size == 0:
                return P("model", "data")
            return P("model", None)
        if name == "lm_head":
            if self.fsdp and shape[0] % self.data_size == 0:
                return P("data", "model")
            return P(None, "model")
        if "moe" in keys and name in ("gate", "up", "down"):
            # (L, E_phys, D, F): 2D — expert slots over 'model' (EP),
            # FFN dim over 'data' (expert-TP); matches MoEDist.expert_specs
            sp = [None] * nd
            sp[nd - 3] = "model"
            tp_dim = (nd - 1) if name in ("gate", "up") else (nd - 2)
            if shape[tp_dim] % self.data_size == 0:
                sp[tp_dim] = "data"
            return P(*sp)
        if name == "router":
            return P()
        # attention projections
        if name in ("wq",):
            return col() if (self.attn_tp or self.fsdp) else P()
        if name in ("wk", "wv"):
            # under FSDP the flat projection dim shards 2D regardless of
            # head boundaries (GSPMD reshards at the reshape); otherwise
            # kv-head TP only when heads divide
            return col() if (self.kv_tp or self.fsdp) else P()
        if name == "wo":
            return row() if (self.attn_tp or self.fsdp) else P()
        # MLA
        if name in ("wdq", "wuq"):
            return col() if self.attn_tp else P()
        if name in ("wuk", "wuv"):
            # (..., H, dn, R) / (..., H, R, dv): shard the head axis
            if self.attn_tp:
                sp = [None] * nd
                sp[nd - 3] = "model"
                return P(*sp)
            return P()
        if name in ("wdkv", "wkr", "q_norm", "kv_norm"):
            return P()
        # dense FFN
        if name in ("w_gate", "w_up"):
            return col()
        if name == "w_down":
            return row()
        # mamba (channel = d_inner parallel)
        if name in ("in_proj", "dt_proj"):
            return col()
        if name in ("x_proj", "out_proj", "A_log"):
            return row()
        if name in ("conv_w",):
            return col()
        if name in ("conv_b", "dt_bias", "D_skip"):
            return col() if self._div(shape[-1]) else P()
        # norms and everything else: replicated
        return P()

    def params_shardings(self, param_specs):
        return jax.tree_util.tree_map_with_path(
            lambda path, leaf: NamedSharding(
                self.mesh, self.param_spec(path, leaf)),
            param_specs)

    # -- activations / batch ---------------------------------------------------------

    def batch_spec(self, batch_size: int) -> P:
        if batch_size % self.dp_size == 0:
            return P(self.dp)
        if "data" in self.dp and batch_size % self.mesh.shape["data"] == 0:
            return P(("data",))
        return P()

    def data_shardings(self, batch_specs, batch_size: int):
        bspec = self.batch_spec(batch_size)

        def one(path, leaf):
            sp = [None] * len(leaf.shape)
            if len(leaf.shape) >= 1 and leaf.shape[0] == batch_size \
                    and bspec != P():
                sp[0] = bspec[0]
            return NamedSharding(self.mesh, P(*sp))

        return jax.tree_util.tree_map_with_path(one, batch_specs)

    # -- decode cache ------------------------------------------------------------------

    def cache_shardings(self, cache_specs, batch_size: int):
        """Decode-cache sharding: batch over DP, plus a 'model'-axis shard
        on the widest cache dimension:

        * GQA K/V (..., B, W, Hkv, Dh): kv-heads over 'model' when they
          divide, else the window W (context-parallel decode — the
          GQA-kv<TP production layout).
        * MLA latent (..., B, W, R): window over 'model' (R stays whole
          for the absorbed matmuls).
        * Mamba states (..., d_conv|d_inner, d_inner|N): d_inner over
          'model' (matches the channel-parallel mamba weights).
        """
        cfg = self.cfg
        bspec = self.batch_spec(batch_size)
        Dh = cfg.resolved_head_dim() if cfg.num_heads else 0
        Hkv = cfg.num_kv_heads
        d_inner = (cfg.mamba.expand * cfg.d_model) if cfg.mamba else 0
        mla_dims = ((cfg.mla.kv_lora_rank, cfg.mla.qk_rope_head_dim)
                    if cfg.mla else ())

        def one(path, leaf):
            shape = leaf.shape
            nd = len(shape)
            sp = [None] * nd
            model_axis = None
            if nd >= 4 and shape[-1] == Dh and shape[-2] == Hkv:
                # GQA-style K/V cache
                if self.kv_tp:
                    model_axis = nd - 2
                elif shape[-3] % self.model_size == 0:
                    model_axis = nd - 3          # context-parallel window
            elif nd >= 3 and mla_dims and shape[-1] in mla_dims:
                if shape[-2] % self.model_size == 0:
                    model_axis = nd - 2          # latent window
            elif d_inner and nd >= 2 and shape[-1] == d_inner:
                model_axis = nd - 1              # mamba conv state
            elif d_inner and nd >= 2 and shape[-2] == d_inner:
                model_axis = nd - 2              # mamba ssm state
            if model_axis is not None:
                sp[model_axis] = "model"
            if bspec != P():
                dims = [i for i, s in enumerate(shape)
                        if s == batch_size and i != model_axis]
                if dims:
                    sp[dims[0]] = bspec[0]
            return NamedSharding(self.mesh, P(*sp))

        return jax.tree_util.tree_map_with_path(one, cache_specs)

    def replicated(self, specs):
        return jax.tree_util.tree_map(
            lambda _: NamedSharding(self.mesh, P()), specs)
