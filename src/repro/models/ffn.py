"""Dense feed-forward variants: SwiGLU and squared-ReLU (Nemotron-4)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, split_keys


def ffn_init(key, d_model: int, d_ff: int, activation: str, dtype=jnp.float32):
    if activation == "swiglu":
        ks = split_keys(key, 3)
        return {
            "w_gate": dense_init(ks[0], d_model, d_ff, dtype),
            "w_up": dense_init(ks[1], d_model, d_ff, dtype),
            "w_down": dense_init(ks[2], d_ff, d_model, dtype),
        }
    if activation == "relu2":
        ks = split_keys(key, 2)
        return {
            "w_up": dense_init(ks[0], d_model, d_ff, dtype),
            "w_down": dense_init(ks[1], d_ff, d_model, dtype),
        }
    raise ValueError(activation)


def ffn_apply(p, x, activation: str):
    if activation == "swiglu":
        return (jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]
    if activation == "relu2":
        return jnp.square(jax.nn.relu(x @ p["w_up"])) @ p["w_down"]
    raise ValueError(activation)
