"""Composable model assembly for all assigned architecture families.

``Model`` is a pure-functional wrapper: ``init`` builds a param pytree,
``loss``/``prefill``/``decode_step`` are jit-able functions of it.

Layer stacking: repeated layers are stored stacked on a leading axis and
iterated with ``lax.scan`` (compile time stays O(1) in depth for the 61-96
layer configs).  Heterogeneous-depth families (MoE first-k-dense, Jamba
periods) use one stack per homogeneous group.

Two decode-cache representations:

* **Paged** (``init_paged_cache``/``prefill_paged``/``decode_step_paged``)
  — per-layer block pools addressed through block tables; the serving
  engine's only compiled cache.  Attention state has no batch axis
  (requests own blocks), recurrent SSM state stays per-slot.
* **Ring** (``init_cache``/``prefill``/``decode_step``) — per-slot ring
  buffers (window = sliding_window or max_seq); the reference decode
  semantics used by dry-runs/training-eval and the parity oracle for the
  paged path (tests/test_paged_serving.py).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as A
from repro.models import ffn as F
from repro.models import mamba as M
from repro.models import moe as MoE
from repro.models.layers import (
    cross_entropy_loss,
    embed_init,
    padded_vocab_size,
    rms_norm,
    split_keys,
    stack_init,
    take_layer,
)


# ---------------------------------------------------------------------------
# block init / forward / decode for one (mixer, ffn) combination
# ---------------------------------------------------------------------------

def _mixer_init(key, cfg: ModelConfig, kind: str, dtype):
    if kind == "attn":
        if cfg.attention_type == "mla":
            return A.mla_init(key, cfg, dtype)
        return A.gqa_init(key, cfg, dtype)
    if kind == "mamba":
        return M.mamba_init(key, cfg, dtype)
    raise ValueError(kind)


def _block_init(key, cfg: ModelConfig, mixer: str, ffn_kind: Optional[str],
                dtype, cross: bool = False):
    D = cfg.d_model
    ks = split_keys(key, 4)
    p: Dict[str, Any] = {
        "ln1": jnp.ones((D,), dtype),
        "mixer": _mixer_init(ks[0], cfg, mixer, dtype),
    }
    if cross:
        p["ln_cross"] = jnp.ones((D,), dtype)
        p["cross"] = A.gqa_init(ks[1], cfg, dtype)
    if ffn_kind == "dense":
        p["ln2"] = jnp.ones((D,), dtype)
        p["ffn"] = F.ffn_init(ks[2], D, cfg.d_ff, cfg.activation, dtype)
    elif ffn_kind == "dense_first":
        p["ln2"] = jnp.ones((D,), dtype)
        p["ffn"] = F.ffn_init(ks[2], D, cfg.moe.dense_d_ff or cfg.d_ff,
                              cfg.activation, dtype)
    elif ffn_kind == "moe":
        p["ln2"] = jnp.ones((D,), dtype)
        p["moe"] = MoE.moe_init(ks[3], cfg, dtype)
    elif ffn_kind is None:
        pass
    else:
        raise ValueError(ffn_kind)
    return p


class Model:
    def __init__(self, cfg: ModelConfig, dtype=jnp.float32,
                 moe_dist=None):
        """moe_dist: optional distributed MoE applier
        (``repro.distributed.collectives.MoEDist``); None = single rank."""
        cfg.validate()
        self.cfg = cfg
        self.dtype = dtype
        self.moe_dist = moe_dist
        self.vpad = padded_vocab_size(cfg)

    # -- structure ---------------------------------------------------------

    def layer_groups(self):
        """(group_name, n_layers, mixer, ffn_kind, cross) per stack."""
        cfg = self.cfg
        if cfg.family == "ssm":
            return [("layers", cfg.num_layers, "mamba", None, False)]
        if cfg.family == "audio":
            return [
                ("enc_layers", cfg.encoder_layers, "attn", "dense", False),
                ("layers", cfg.num_layers, "attn", "dense", True),
            ]
        if cfg.hybrid_period:
            return [("periods", cfg.num_layers // cfg.hybrid_period,
                     "hybrid", None, False)]
        if cfg.moe is not None:
            groups = []
            if cfg.moe.first_k_dense:
                groups.append(("dense_layers", cfg.moe.first_k_dense,
                               "attn", "dense_first", False))
            groups.append(("layers", cfg.num_layers - cfg.moe.first_k_dense,
                           "attn", "moe", False))
            return groups
        # dense / vlm
        return [("layers", cfg.num_layers, "attn", "dense", False)]

    def _period_init(self, key, dtype):
        """One Jamba period: hybrid_period sublayers, attention at
        hybrid_attn_index, MoE on odd sublayers."""
        cfg = self.cfg
        subs = {}
        ks = split_keys(key, cfg.hybrid_period)
        for i in range(cfg.hybrid_period):
            mixer = "attn" if i == cfg.hybrid_attn_index else "mamba"
            ffn_kind = "moe" if (i % cfg.moe.moe_layer_period == 1) else "dense"
            subs[f"sub_{i}"] = _block_init(ks[i], cfg, mixer, ffn_kind, dtype)
        return subs

    # -- init ---------------------------------------------------------------

    def init(self, key) -> Dict[str, Any]:
        cfg, dtype = self.cfg, self.dtype
        ks = split_keys(key, 8)
        params: Dict[str, Any] = {
            "embed": embed_init(ks[0], self.vpad, cfg.d_model, dtype),
            "final_norm": jnp.ones((cfg.d_model,), dtype),
            "lm_head": embed_init(ks[1], self.vpad, cfg.d_model, dtype).T,
        }
        gi = 2
        for name, n, mixer, ffn_kind, cross in self.layer_groups():
            if mixer == "hybrid":
                params[name] = stack_init(
                    ks[gi], n, lambda k: self._period_init(k, dtype))
            else:
                params[name] = stack_init(
                    ks[gi], n,
                    functools.partial(_block_init, cfg=cfg, mixer=mixer,
                                      ffn_kind=ffn_kind, dtype=dtype,
                                      cross=cross))
            gi += 1
        if cfg.family == "audio":
            params["enc_norm"] = jnp.ones((cfg.d_model,), dtype)
        return params

    def param_specs(self):
        key = jax.random.PRNGKey(0)
        return jax.eval_shape(lambda: self.init(key))

    def count_params(self) -> int:
        specs = self.param_specs()
        return sum(int(jnp.prod(jnp.array(l.shape)))
                   for l in jax.tree_util.tree_leaves(specs))

    def default_runtime(self) -> Optional[MoE.MoERuntime]:
        if self.cfg.moe is None:
            return None
        return MoE.default_runtime(self.cfg.moe)

    @property
    def supports_chunked_prefill(self) -> bool:
        """Chunked prefill runs prompt tokens as *virtual decode slots*
        against the paged pools (``decode_step_paged`` over a per-token
        page context), which requires every mixer to be attention (the
        paged cache is then pure pools with no batch axis, so the same
        cache pytree serves any chunk width).  Recurrent mixers (SSM,
        hybrid periods) carry per-slot state that must be threaded
        sequentially — those models keep whole-prompt prefills.  VLM /
        audio prefills embed non-token inputs and are excluded too."""
        if self.cfg.family in ("vlm", "audio"):
            return False
        return all(mixer == "attn"
                   for _, _, mixer, _, _ in self.layer_groups())

    # -- moe application ----------------------------------------------------

    def _moe(self, p, x, runtime, cap):
        """x: (B, S, D) or (B, D)."""
        shape = x.shape
        x2 = x.reshape(-1, shape[-1])
        if self.moe_dist is not None:
            y, aux = self.moe_dist.apply(p, self.cfg, x2, runtime, cap)
        else:
            y, aux = MoE.moe_apply_local(p, self.cfg, x2, runtime, cap=cap)
        y = y + MoE.shared_expert_apply(p, self.cfg, x2)
        return y.reshape(shape), aux

    def _cap(self, n_tokens: int) -> int:
        if self.moe_dist is not None:
            return self.moe_dist.cap_for(n_tokens, self.cfg.moe)
        return MoE.capacity(n_tokens * self.cfg.moe.top_k,
                            MoE.physical_experts(self.cfg.moe),
                            self.cfg.moe.capacity_factor,
                            floor=self.cfg.moe.min_capacity)

    # -- full-sequence block forward -----------------------------------------

    def _block_fwd(self, p, x, positions, *, mixer, ffn_kind, runtime, cap,
                   causal=True, enc_out=None, enc_positions=None,
                   build_cache=False, max_seq=0, paged=False):
        """Returns (x, cache_entry, aux)."""
        cfg = self.cfg
        aux = 0.0
        cache_entry = None
        h = rms_norm(x, p["ln1"], cfg.norm_eps)
        if mixer == "attn":
            if cfg.attention_type == "mla":
                if build_cache:
                    out, cache_entry = self._mla_fwd_cache(
                        p["mixer"], h, positions, max_seq, paged=paged)
                else:
                    out = A.mla_forward(p["mixer"], cfg, h, positions,
                                        causal=causal,
                                        window=cfg.sliding_window)
            else:
                if build_cache:
                    out, cache_entry = self._gqa_fwd_cache(
                        p["mixer"], h, positions, max_seq, paged=paged)
                else:
                    out = A.gqa_forward(p["mixer"], cfg, h, positions,
                                        causal=causal,
                                        window=cfg.sliding_window)
        elif mixer == "mamba":
            if build_cache:
                out, cache_entry = M.mamba_forward(p["mixer"], cfg, h,
                                                   return_state=True)
            else:
                out = M.mamba_forward(p["mixer"], cfg, h)
        else:
            raise ValueError(mixer)
        x = x + out
        if enc_out is not None and "cross" in p:
            hc = rms_norm(x, p["ln_cross"], cfg.norm_eps)
            x = x + A.gqa_forward(p["cross"], cfg, hc, positions,
                                  causal=False, kv_input=enc_out,
                                  kv_positions=enc_positions, use_rope=False)
        if ffn_kind in ("dense", "dense_first"):
            h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
            x = x + F.ffn_apply(p["ffn"], h2, cfg.activation)
        elif ffn_kind == "moe":
            h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
            y, aux = self._moe(p["moe"], h2, runtime, cap)
            x = x + y
        return x, cache_entry, aux

    def _gqa_fwd_cache(self, p, h, positions, max_seq, paged=False):
        cfg = self.cfg
        out, (k, v) = A.gqa_forward_with_kv(p, cfg, h, positions)
        if paged:
            # raw (B, S, Hkv, Dh), rope applied — ready for pool blocks
            return out, {"k": k, "v": v}
        entry = _ring_from_full(k, v, positions, cfg.sliding_window, max_seq)
        return out, entry

    def _mla_fwd_cache(self, p, h, positions, max_seq, paged=False):
        cfg = self.cfg
        out, (c_kv, k_rope) = A.mla_forward_with_cache(p, cfg, h, positions)
        if paged:
            # fused latent row (B, S, 1, R + dr) matching the pool layout
            ckr = jnp.concatenate([c_kv, k_rope], axis=-1)[:, :, None, :]
            return out, {"ckr": ckr}
        entry = _ring_from_full_mla(c_kv, k_rope, positions,
                                    cfg.sliding_window, max_seq)
        return out, entry

    # -- stack iteration ------------------------------------------------------

    def _run_stack(self, stacked, x, body: Callable, n: int, cache=None):
        """body(p_layer, x, cache_slice) -> (x, cache_entry, aux).

        Returns (x, stacked_cache_entries, total_aux)."""
        if self.cfg.remat:
            body = jax.checkpoint(body)
        if self.cfg.scan_layers and n > 1:
            if cache is not None and self.cfg.decode_cache_carry:
                # §Perf A4: the cache rides the scan carry and is updated
                # in place with DUS — XLA can alias the buffer instead of
                # copying the whole cache through xs/ys every step.
                def carry_body(carry, xs):
                    x, aux, cache_full = carry
                    p, i = xs
                    csl = jax.tree_util.tree_map(
                        lambda c: jax.lax.dynamic_index_in_dim(
                            c, i, 0, keepdims=False), cache_full)
                    x, entry, a = body(p, x, csl)
                    cache_full = jax.tree_util.tree_map(
                        lambda c, e: jax.lax.dynamic_update_index_in_dim(
                            c, e.astype(c.dtype), i, 0), cache_full, entry)
                    return (x, aux + a, cache_full), None
                (x, aux, new_cache), _ = jax.lax.scan(
                    carry_body, (x, 0.0, cache),
                    (stacked, jnp.arange(n)))
                return x, new_cache, aux
            def scan_body(carry, xs):
                x, aux = carry
                if cache is None:
                    p = xs
                    x, entry, a = body(p, x, None)
                else:
                    p, csl = xs
                    x, entry, a = body(p, x, csl)
                return (x, aux + a), entry
            xs = stacked if cache is None else (stacked, cache)
            (x, aux), entries = jax.lax.scan(scan_body, (x, 0.0), xs)
            return x, entries, aux
        # unrolled
        aux = 0.0
        entries = []
        for i in range(n):
            p = take_layer(stacked, i)
            csl = take_layer(cache, i) if cache is not None else None
            x, entry, a = body(p, x, csl)
            aux = aux + a
            entries.append(entry)
        if entries and entries[0] is not None:
            entries = jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs), *entries)
        else:
            entries = None
        return x, entries, aux

    # -- full forward ---------------------------------------------------------

    def _trunk(self, params, x, positions, runtime, *, build_cache=False,
               max_seq=0, enc_out=None, enc_positions=None, paged=False):
        """Run all layer groups. x: (B, S, D). Returns (x, caches, aux)."""
        cfg = self.cfg
        caches: Dict[str, Any] = {}
        total_aux = 0.0
        cap = self._cap(x.shape[0] * x.shape[1]) if cfg.moe else 0

        for name, n, mixer, ffn_kind, cross in self.layer_groups():
            if name == "enc_layers":
                continue  # encoder handled separately
            if mixer == "hybrid":
                def body(p, x, _):
                    return self._period_fwd(p, x, positions, runtime, cap,
                                            build_cache=build_cache,
                                            max_seq=max_seq, paged=paged)
            else:
                def body(p, x, _, _mx=mixer, _fk=ffn_kind, _cr=cross):
                    return self._block_fwd(
                        p, x, positions, mixer=_mx, ffn_kind=_fk,
                        runtime=runtime, cap=cap,
                        enc_out=enc_out if _cr else None,
                        enc_positions=enc_positions if _cr else None,
                        build_cache=build_cache, max_seq=max_seq,
                        paged=paged)
            x, entries, aux = self._run_stack(params[name], x, body, n)
            total_aux += aux
            if build_cache and entries is not None:
                caches[name] = entries
        return x, caches, total_aux

    def _period_fwd(self, p, x, positions, runtime, cap, *, build_cache,
                    max_seq, paged=False):
        """One Jamba period (unrolled heterogeneous sublayers)."""
        cfg = self.cfg
        aux = 0.0
        attn_entry = None
        ssm_entries = []
        for i in range(cfg.hybrid_period):
            mixer = "attn" if i == cfg.hybrid_attn_index else "mamba"
            ffn_kind = "moe" if (i % cfg.moe.moe_layer_period == 1) else "dense"
            x, entry, a = self._block_fwd(
                p[f"sub_{i}"], x, positions, mixer=mixer, ffn_kind=ffn_kind,
                runtime=runtime, cap=cap, build_cache=build_cache,
                max_seq=max_seq, paged=paged)
            aux += a
            if build_cache:
                if mixer == "attn":
                    attn_entry = entry
                else:
                    ssm_entries.append(entry)
        entry = None
        if build_cache:
            ssm = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs),
                                         *ssm_entries)
            entry = {"attn": attn_entry, "ssm": ssm}
        return x, entry, aux

    def _encode(self, params, frames, runtime):
        """Audio encoder over precomputed frame embeddings (B, F, D)."""
        cfg = self.cfg
        positions = jnp.arange(frames.shape[1])
        def body(p, x, _):
            return self._block_fwd(p, x, positions, mixer="attn",
                                   ffn_kind="dense", runtime=runtime,
                                   cap=0, causal=False)
        x, _, _ = self._run_stack(params["enc_layers"], frames, body,
                                  cfg.encoder_layers)
        return rms_norm(x, params["enc_norm"], cfg.norm_eps)

    def _embed_inputs(self, params, batch):
        """Family-specific input embedding. Returns (x, positions)."""
        cfg = self.cfg
        if cfg.family == "vlm":
            tok = params["embed"][batch["tokens"]]
            x = jnp.concatenate(
                [batch["patches"].astype(tok.dtype), tok], axis=1)
        else:
            x = params["embed"][batch["tokens"]]
        positions = jnp.arange(x.shape[1])
        return x, positions

    def logits_full(self, params, batch, runtime=None, *,
                    build_cache=False, max_seq=0, paged=False):
        """Full-sequence forward. Returns (logits, caches, aux)."""
        cfg = self.cfg
        runtime = runtime if runtime is not None else self.default_runtime()
        x, positions = self._embed_inputs(params, batch)
        enc_out = enc_positions = None
        if cfg.family == "audio":
            enc_out = self._encode(params, batch["frames"].astype(x.dtype),
                                   runtime)
            enc_positions = jnp.arange(enc_out.shape[1])
        x, caches, aux = self._trunk(params, x, positions, runtime,
                                     build_cache=build_cache, max_seq=max_seq,
                                     enc_out=enc_out,
                                     enc_positions=enc_positions,
                                     paged=paged)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = x @ params["lm_head"]
        if build_cache and cfg.family == "audio":
            # decoder-layer cache = self-attn ring + precomputed cross K/V,
            # scanned together at decode time (leading dim = layer).
            caches["layers"] = {"self": caches["layers"],
                                "cross": self._cross_kv(params, enc_out)}
        return logits, caches, aux

    def _cross_kv(self, params, enc_out):
        """Precompute per-layer cross-attention K/V from encoder output."""
        cfg = self.cfg
        Dh = cfg.resolved_head_dim()
        def one(p):
            k = (enc_out @ p["cross"]["wk"]).reshape(
                enc_out.shape[0], enc_out.shape[1], cfg.num_kv_heads, Dh)
            v = (enc_out @ p["cross"]["wv"]).reshape(
                enc_out.shape[0], enc_out.shape[1], cfg.num_kv_heads, Dh)
            return {"k": k, "v": v}
        return jax.vmap(one)(params["layers"])

    # -- public APIs -----------------------------------------------------------

    def loss(self, params, batch, runtime=None):
        cfg = self.cfg
        logits, _, aux = self.logits_full(params, batch, runtime)
        if cfg.family == "vlm":
            # loss over text positions only (they sit after the patches)
            logits = logits[:, cfg.num_patches:]
        labels = batch["tokens"][:, 1:]
        mask = batch["loss_mask"][:, 1:].astype(jnp.float32)
        ce = cross_entropy_loss(logits[:, :-1], labels, mask, cfg.vocab_size)
        aux_coef = cfg.moe.aux_loss_coef if cfg.moe else 0.0
        nlayers_moe = max(self._num_moe_layers(), 1)
        total = ce + aux_coef * aux / nlayers_moe
        return total, {"ce": ce, "aux": aux}

    def _num_moe_layers(self) -> int:
        cfg = self.cfg
        if cfg.moe is None:
            return 0
        if cfg.hybrid_period:
            per = sum(1 for i in range(cfg.hybrid_period)
                      if i % cfg.moe.moe_layer_period == 1)
            return per * (cfg.num_layers // cfg.hybrid_period)
        return cfg.num_layers - cfg.moe.first_k_dense

    def prefill(self, params, batch, runtime=None, max_seq: int = 0):
        """Prefill: full forward + decode cache. Returns (last_logits, cache).

        max_seq: ring-buffer size for the decode cache (>= prompt len).
        """
        cfg = self.cfg
        S = (batch["tokens"].shape[1] + (cfg.num_patches or 0)
             if cfg.family == "vlm" else batch["tokens"].shape[1])
        max_seq = max_seq or S
        logits, caches, _ = self.logits_full(params, batch, runtime,
                                             build_cache=True,
                                             max_seq=max_seq)
        B = logits.shape[0]
        if "lengths" in batch:
            last = logits[jnp.arange(B), batch["lengths"] - 1]
            pos = batch["lengths"]
        else:
            last = logits[:, -1]
            pos = jnp.full((B,), S, jnp.int32)
        caches["pos"] = pos.astype(jnp.int32)
        return last, caches

    def prefill_paged(self, params, batch, runtime=None):
        """Prefill for the paged serving cache.

        Returns ``(last_logits, raw)`` where ``raw`` mirrors the paged
        cache structure with *raw per-token* leaves: attention layers
        carry (B, S, ...) K/V rows ready to scatter into pool blocks
        (``cache_ops.install_prefill``), non-attention mixers carry their
        final recurrent state (B, ...) for the request's batch slot.
        """
        logits, caches, _ = self.logits_full(params, batch, runtime,
                                             build_cache=True, paged=True)
        B = logits.shape[0]
        if "lengths" in batch:
            last = logits[jnp.arange(B), batch["lengths"] - 1]
        else:
            last = logits[:, -1]
        return last, caches

    def init_paged_cache(self, batch: int, num_blocks: int,
                         block_size: int, dtype=None):
        """Block-pool decode cache — the serving engine's compiled cache.

        Attention layers get per-layer K/V pools with **no batch axis**
        (requests own physical blocks, addressed through block tables);
        non-attention mixers (Mamba state) keep fixed-size per-slot state
        with a batch axis.  The pools carry one extra trailing *trash*
        block (id == ``num_blocks``) that idle batch slots write into, so
        a full decode batch never touches live blocks.
        """
        cfg = self.cfg
        dtype = dtype or self.dtype
        nb = num_blocks + 1  # + trash block
        caches: Dict[str, Any] = {}
        for name, n, mixer, ffn_kind, cross in self.layer_groups():
            if name == "enc_layers":
                continue
            if cross:
                raise ValueError(
                    "paged serving does not support encoder-decoder "
                    "(audio) models")
            if mixer == "hybrid":
                attn_c = _stack_cache(
                    lambda: A.gqa_paged_pools(cfg, nb, block_size, dtype), n)
                ssm_c = _stack_cache(
                    lambda: _stack_cache(
                        lambda: M.mamba_init_state(cfg, batch, dtype),
                        cfg.hybrid_period - 1), n)
                caches[name] = {"attn": attn_c, "ssm": ssm_c}
            elif mixer == "mamba":
                caches[name] = _stack_cache(
                    lambda: M.mamba_init_state(cfg, batch, dtype), n)
            elif cfg.attention_type == "mla":
                caches[name] = _stack_cache(
                    lambda: A.mla_paged_pools(cfg, nb, block_size, dtype), n)
            else:
                caches[name] = _stack_cache(
                    lambda: A.gqa_paged_pools(cfg, nb, block_size, dtype), n)
        return caches

    def decode_step_paged(self, params, cache, token, page, runtime=None):
        """One decode step against the paged cache.

        token: (B,) int32; ``page`` carries the per-step paging arrays:
        ``tables`` (B, max_blk) int32 block tables, ``seq_lens`` (B,)
        valid length *including* this step's token, ``write_bid``/
        ``write_off`` (B,) physical destination of the incoming token
        (idle slots point at the trash block with seq_len 0).  Returns
        (logits, new_cache); positions derive from seq_lens, so the
        cache carries no per-slot position state.
        """
        cfg = self.cfg
        runtime = runtime if runtime is not None else self.default_runtime()
        x = params["embed"][token]                       # (B, D)
        B = x.shape[0]
        cap = self._cap(B) if cfg.moe else 0
        new_cache = dict(cache)
        for name, n, mixer, ffn_kind, cross in self.layer_groups():
            if name == "enc_layers":
                continue
            if mixer == "hybrid":
                def body(p, x, csl):
                    return self._period_decode_paged(p, x, csl, page,
                                                     runtime, cap)
            else:
                def body(p, x, csl, _mx=mixer, _fk=ffn_kind):
                    return self._block_decode_paged(
                        p, x, csl, page, runtime, cap,
                        mixer=_mx, ffn_kind=_fk)
            x, entries, _ = self._run_stack(params[name], x, body, n,
                                            cache=cache[name])
            new_cache[name] = entries
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = x @ params["lm_head"]
        return logits, new_cache

    def _block_decode_paged(self, p, x, csl, page, runtime, cap, *,
                            mixer, ffn_kind):
        from repro.kernels.ops import _on_cpu
        cfg = self.cfg
        if (cfg.decode_impl == "megakernel" and mixer == "attn"
                and ffn_kind == "moe" and self.moe_dist is None):
            return self._block_decode_megastep(p, x, csl, page, runtime,
                                               cap)
        aux = 0.0
        use_pallas = not _on_cpu()
        h = rms_norm(x, p["ln1"], cfg.norm_eps)
        if mixer == "attn":
            if cfg.attention_type == "mla":
                out, entry = A.mla_decode_paged(p["mixer"], cfg, h, csl,
                                                page, use_pallas=use_pallas)
            else:
                out, entry = A.gqa_decode_paged(p["mixer"], cfg, h, csl,
                                                page, use_pallas=use_pallas)
        else:
            out, entry = M.mamba_decode(p["mixer"], cfg, h, csl)
        x = x + out
        if ffn_kind in ("dense", "dense_first"):
            h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
            x = x + F.ffn_apply(p["ffn"], h2, cfg.activation)
        elif ffn_kind == "moe":
            h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
            y, aux = self._moe(p["moe"], h2, runtime, cap)
            x = x + y
        return x, entry, aux

    def _block_decode_megastep(self, p, x, csl, page, runtime, cap):
        """One attention+MoE block through ``ops.decode_megastep``: the
        whole attention -> residual -> norm -> route -> expert FFN
        (routed + shared) -> combine chain is a single kernel launch
        (jnp oracle on CPU).
        QKV projection + rope + the pool token write stay outside — they
        are one fused GEMM/scatter shared with the composed path, and
        keeping the write in XLA keeps the §3.3 row-level undo manifest
        valid unchanged.  All paging arrays and MoERuntime tables ride
        in as data: recovery mutations never recompile (§3.4)."""
        from repro.kernels import ops
        from repro.kernels.ops import _on_cpu
        cfg = self.cfg
        use_pallas = not _on_cpu()
        h = rms_norm(x, p["ln1"], cfg.norm_eps)
        if cfg.attention_type == "mla":
            q, token = A.mla_decode_q_token(p["mixer"], cfg, h, page)
            entry = A.mla_write_token(csl, page, token)
            k_pool = v_pool = entry["ckr"]
            q = q.astype(k_pool.dtype)
            w_post = A.mla_post_matrix(p["mixer"], cfg)
        else:
            q, k, v = A.gqa_decode_qkv(p["mixer"], cfg, h, page)
            entry = A.gqa_write_token(csl, page, k, v)
            k_pool, v_pool = entry["k"], entry["v"]
            w_post = p["mixer"]["wo"]
        starts = A.window_starts(cfg, page["seq_lens"])
        if starts is None:
            starts = jnp.zeros_like(page["seq_lens"])
        moe_p = p["moe"]
        shared = moe_p.get("shared")
        y, _ = ops.decode_megastep(
            q, k_pool, v_pool, page["tables"], page["seq_lens"], starts,
            x, w_post, p["ln2"], moe_p["router"],
            runtime.logical_to_physical, runtime.replica_count,
            runtime.expert_mask, moe_p["gate"], moe_p["up"],
            moe_p["down"], jnp.int32(0),
            shared["w_gate"] if shared else None,
            shared["w_up"] if shared else None,
            shared["w_down"] if shared else None,
            top_k=cfg.moe.top_k, cap=cap,
            e_local=MoE.physical_experts(cfg.moe), eps=cfg.norm_eps,
            use_pallas=use_pallas)
        return y, entry, 0.0

    def _period_decode_paged(self, p, x, csl, page, runtime, cap):
        cfg = self.cfg
        si = 0
        new_ssm = []
        new_attn = None
        for i in range(cfg.hybrid_period):
            mixer = "attn" if i == cfg.hybrid_attn_index else "mamba"
            ffn_kind = "moe" if (i % cfg.moe.moe_layer_period == 1) else "dense"
            sub_c = csl["attn"] if mixer == "attn" else take_layer(
                csl["ssm"], si)
            x, entry, _ = self._block_decode_paged(
                p[f"sub_{i}"], x, sub_c, page, runtime, cap,
                mixer=mixer, ffn_kind=ffn_kind)
            if mixer == "attn":
                new_attn = entry
            else:
                new_ssm.append(entry)
                si += 1
        ssm = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *new_ssm)
        return x, {"attn": new_attn, "ssm": ssm}, 0.0

    def init_cache(self, batch: int, max_seq: int, dtype=None):
        """Fresh (empty) decode cache — used by the decode dry-runs."""
        cfg = self.cfg
        dtype = dtype or self.dtype
        caches: Dict[str, Any] = {}
        for name, n, mixer, ffn_kind, cross in self.layer_groups():
            if name == "enc_layers":
                continue
            if mixer == "hybrid":
                attn_c = _stack_cache(
                    lambda: A.gqa_init_cache(cfg, batch, max_seq, dtype), n)
                ssm_c = _stack_cache(
                    lambda: _stack_cache(
                        lambda: M.mamba_init_state(cfg, batch, dtype),
                        cfg.hybrid_period - 1), n)
                caches[name] = {"attn": attn_c, "ssm": ssm_c}
            elif mixer == "mamba":
                caches[name] = _stack_cache(
                    lambda: M.mamba_init_state(cfg, batch, dtype), n)
            else:
                if cfg.attention_type == "mla":
                    caches[name] = _stack_cache(
                        lambda: A.mla_init_cache(cfg, batch, max_seq, dtype), n)
                else:
                    caches[name] = _stack_cache(
                        lambda: A.gqa_init_cache(cfg, batch, max_seq, dtype), n)
                if cross:
                    Dh = cfg.resolved_head_dim()
                    kshape = (n, batch, cfg.encoder_seq, cfg.num_kv_heads, Dh)
                    caches[name] = {
                        "self": caches[name],
                        "cross": {"k": jnp.zeros(kshape, dtype),
                                  "v": jnp.zeros(kshape, dtype)},
                    }
        caches["pos"] = jnp.zeros((batch,), jnp.int32)
        return caches

    def decode_step(self, params, cache, token, runtime=None):
        """One decode step. token: (B,) int32. Returns (logits, new_cache)."""
        cfg = self.cfg
        runtime = runtime if runtime is not None else self.default_runtime()
        pos = cache["pos"]
        x = params["embed"][token]                       # (B, D)
        B = x.shape[0]
        cap = self._cap(B) if cfg.moe else 0
        new_cache = dict(cache)
        for name, n, mixer, ffn_kind, cross in self.layer_groups():
            if name == "enc_layers":
                continue
            if mixer == "hybrid":
                def body(p, x, csl):
                    return self._period_decode(p, x, csl, pos, runtime, cap)
            else:
                def body(p, x, csl, _mx=mixer, _fk=ffn_kind, _cr=cross):
                    return self._block_decode(p, x, csl, pos, runtime, cap,
                                              mixer=_mx, ffn_kind=_fk,
                                              cross=_cr, cache=cache)
            x, entries, _ = self._run_stack(params[name], x, body, n,
                                            cache=cache[name])
            new_cache[name] = entries
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = x @ params["lm_head"]
        new_cache["pos"] = pos + 1
        return logits, new_cache

    def _block_decode(self, p, x, csl, pos, runtime, cap, *, mixer, ffn_kind,
                      cross, cache):
        cfg = self.cfg
        aux = 0.0
        self_csl = csl["self"] if cross else csl
        h = rms_norm(x, p["ln1"], cfg.norm_eps)
        if mixer == "attn":
            if cfg.attention_type == "mla":
                out, entry = A.mla_decode(p["mixer"], cfg, h, self_csl, pos)
            else:
                out, entry = A.gqa_decode(p["mixer"], cfg, h, self_csl, pos)
        else:
            out, entry = M.mamba_decode(p["mixer"], cfg, h, self_csl)
        x = x + out
        if cross and "cross" in p:
            hc = rms_norm(x, p["ln_cross"], cfg.norm_eps)
            ck, cv = csl["cross"]["k"], csl["cross"]["v"]
            valid = jnp.ones((x.shape[0], ck.shape[1]), bool)
            x = x + A.gqa_cross_decode(p["cross"], cfg, hc, ck, cv, valid)
            entry = {"self": entry, "cross": csl["cross"]}
        if ffn_kind in ("dense", "dense_first"):
            h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
            x = x + F.ffn_apply(p["ffn"], h2, cfg.activation)
        elif ffn_kind == "moe":
            h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
            y, aux = self._moe(p["moe"], h2, runtime, cap)
            x = x + y
        return x, entry, aux

    def _period_decode(self, p, x, csl, pos, runtime, cap):
        cfg = self.cfg
        si = 0
        new_ssm = []
        new_attn = None
        for i in range(cfg.hybrid_period):
            mixer = "attn" if i == cfg.hybrid_attn_index else "mamba"
            ffn_kind = "moe" if (i % cfg.moe.moe_layer_period == 1) else "dense"
            sub_c = csl["attn"] if mixer == "attn" else take_layer(
                csl["ssm"], si)
            x, entry, _ = self._block_decode(
                p[f"sub_{i}"], x, sub_c, pos, runtime, cap,
                mixer=mixer, ffn_kind=ffn_kind, cross=False, cache=None)
            if mixer == "attn":
                new_attn = entry
            else:
                new_ssm.append(entry)
                si += 1
        ssm = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *new_ssm)
        return x, {"attn": new_attn, "ssm": ssm}, 0.0


# ---------------------------------------------------------------------------
# cache helpers
# ---------------------------------------------------------------------------

def _stack_cache(make_one, n: int):
    one = make_one()
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (n,) + x.shape), one)


def _ring_from_full(k, v, positions, window, max_seq):
    """Arrange full-prefill K/V (B,S,Hkv,Dh) into a ring cache (B,W,...)."""
    B, S = k.shape[0], k.shape[1]
    W = min(window or max_seq, max_seq)
    if S <= W:
        kc = jnp.zeros((B, W) + k.shape[2:], k.dtype)
        vc = jnp.zeros((B, W) + v.shape[2:], v.dtype)
        slots = positions % W
        kc = kc.at[:, slots].set(k)
        vc = vc.at[:, slots].set(v)
    else:
        tail = positions[S - W:]
        slots = tail % W
        kc = jnp.zeros((B, W) + k.shape[2:], k.dtype).at[:, slots].set(
            k[:, S - W:])
        vc = jnp.zeros((B, W) + v.shape[2:], v.dtype).at[:, slots].set(
            v[:, S - W:])
    return A.GQACache(kc, vc)


def _ring_from_full_mla(c_kv, k_rope, positions, window, max_seq):
    B, S = c_kv.shape[0], c_kv.shape[1]
    W = min(window or max_seq, max_seq)
    if S <= W:
        cc = jnp.zeros((B, W, c_kv.shape[-1]), c_kv.dtype)
        rc = jnp.zeros((B, W, k_rope.shape[-1]), k_rope.dtype)
        slots = positions % W
        cc = cc.at[:, slots].set(c_kv)
        rc = rc.at[:, slots].set(k_rope)
    else:
        tail = positions[S - W:]
        slots = tail % W
        cc = jnp.zeros((B, W, c_kv.shape[-1]), c_kv.dtype).at[:, slots].set(
            c_kv[:, S - W:])
        rc = jnp.zeros((B, W, k_rope.shape[-1]), k_rope.dtype).at[:, slots].set(
            k_rope[:, S - W:])
    return A.MLACache(cc, rc)
