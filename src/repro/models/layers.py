"""Shared model primitives: norms, rotary embeddings, init helpers."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

# Vocab is padded to a multiple of this so embed/lm_head shard cleanly over
# the 16-way model axis (Megatron-style vocab padding; padding rows are
# never routed to and their logits are masked at the loss).
VOCAB_PAD_MULTIPLE = 2048


def padded_vocab_size(cfg: ModelConfig) -> int:
    m = VOCAB_PAD_MULTIPLE
    return ((cfg.vocab_size + m - 1) // m) * m


def rms_norm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)).astype(dtype) * w


def dense_init(key, in_dim: int, out_dim: int, dtype=jnp.float32) -> jnp.ndarray:
    scale = 1.0 / math.sqrt(in_dim)
    return (jax.random.normal(key, (in_dim, out_dim), jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, dim: int, dtype=jnp.float32) -> jnp.ndarray:
    return (jax.random.normal(key, (vocab, dim), jnp.float32) * 0.02).astype(dtype)


def split_keys(key, n: int):
    return list(jax.random.split(key, n))


def rope_sincos(positions: jnp.ndarray, dim: int, theta: float):
    """sin/cos tables for given integer positions.

    positions: (...,) int32 -> returns sin, cos with shape (..., dim/2).
    """
    assert dim % 2 == 0, dim
    inv_freq = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    angles = positions.astype(jnp.float32)[..., None] * inv_freq  # (..., dim/2)
    return jnp.sin(angles), jnp.cos(angles)


def apply_rope(x: jnp.ndarray, sin: jnp.ndarray, cos: jnp.ndarray) -> jnp.ndarray:
    """Rotate pairs. x: (..., dim); sin/cos broadcastable to (..., dim/2)."""
    x1, x2 = jnp.split(x, 2, axis=-1)
    # sin/cos enter as (..., dim/2); broadcast over head axes as needed.
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def activation_fn(name: str):
    if name == "swiglu":
        # handled by caller (two projections); this is the gate nonlinearity
        return jax.nn.silu
    if name == "relu2":
        return lambda x: jnp.square(jax.nn.relu(x))
    raise ValueError(name)


def stack_init(key, n: int, init_fn):
    """Initialize ``n`` copies of a param pytree, stacked on a leading axis."""
    keys = jax.random.split(key, n)
    return jax.vmap(init_fn)(keys)


def take_layer(stacked, i: int):
    """Slice layer ``i`` out of a stacked param pytree (python-int index)."""
    return jax.tree_util.tree_map(lambda x: x[i], stacked)


def cross_entropy_loss(logits: jnp.ndarray, labels: jnp.ndarray,
                       mask: jnp.ndarray, vocab_size: int) -> jnp.ndarray:
    """Mean next-token CE over positions where mask=1.

    logits: (B, S, Vpad) — padded vocab columns are excluded via logit mask.
    labels: (B, S) int32, mask: (B, S) {0,1}.
    """
    vpad = logits.shape[-1]
    logits = logits.astype(jnp.float32)
    if vpad > vocab_size:
        col = jnp.arange(vpad) < vocab_size
        logits = jnp.where(col[None, None, :], logits, -1e30)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    denom = jnp.maximum(mask.sum(), 1.0)
    return (nll * mask).sum() / denom
