"""Mixture-of-experts layer with ReviveMoE-aware routing.

Design (DESIGN.md §6, §1.4):

* Experts live in **physical slots**: ``E_phys = num_experts +
  num_redundant_experts``.  Redundant slots hold replicas of (by default
  the first R) logical experts — the paper's load-balancing replicas that
  double as fault-tolerance spares (§3.4).
* Routing happens over **logical** expert ids, then a
  :class:`MoERuntime` table maps (logical id, token) -> physical slot.
  ReviveMoE recovery mutates only this table (drop a dead replica, mask a
  lost expert) — a *data* change, never a recompile.  This mirrors the
  paper's "remove failed experts from the logical-to-physical mapping".
* The distributed implementation is ``gather_psum`` (MA-collocated
  analogue): activations are replicated across the EP ('model') axis, each
  EP rank gathers the tokens routed to its local experts, computes, and the
  partial outputs are combined with a psum — the XCCL combine analogue.
  An explicit all-to-all variant (A2E/E2A analogue) lives in
  ``repro.distributed.collectives`` and is selected with
  ``cfg.moe_impl='a2a'``.
* The local dispatch->FFN->combine has two implementations with
  identical semantics: :func:`dispatch_compute_combine` (dense-scatter
  capacity buffer) and :func:`dispatch_compute_combine_fused` (the
  fused Pallas pipeline in ``repro.kernels.moe_fused``), selected by a
  'fused' suffix on ``cfg.moe_impl``.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, MoEConfig
from repro.models.layers import dense_init, split_keys

MAX_REPLICAS = 2  # base slot + at most one redundant replica per expert


class MoERuntime(NamedTuple):
    """Host-controlled routing state; mutated by ReviveMoE recovery."""

    logical_to_physical: jnp.ndarray  # (E_log, MAX_REPLICAS) int32
    replica_count: jnp.ndarray        # (E_log,) int32 >= 0 (0 = expert lost)
    expert_mask: jnp.ndarray          # (E_log,) bool; False = masked (§3.4)


def default_runtime(moe: MoEConfig) -> MoERuntime:
    E, R = moe.num_experts, moe.num_redundant_experts
    l2p = jnp.stack(
        [jnp.arange(E, dtype=jnp.int32),
         jnp.where(jnp.arange(E) < R, E + jnp.arange(E), 0).astype(jnp.int32)],
        axis=1,
    )
    count = jnp.where(jnp.arange(E) < R, 2, 1).astype(jnp.int32)
    return MoERuntime(l2p, count, jnp.ones((E,), dtype=bool))


def physical_experts(moe: MoEConfig) -> int:
    return moe.num_experts + moe.num_redundant_experts


def moe_init(key, cfg: ModelConfig, dtype=jnp.float32):
    """Router + physical expert bank. Replica slots start as true copies."""
    moe = cfg.moe
    D, F = cfg.d_model, moe.expert_d_ff
    E_log = moe.num_experts
    R = moe.num_redundant_experts
    ks = split_keys(key, 4)
    gate = jax.vmap(lambda k: dense_init(k, D, F, dtype))(
        jax.random.split(ks[0], E_log))
    up = jax.vmap(lambda k: dense_init(k, D, F, dtype))(
        jax.random.split(ks[1], E_log))
    down = jax.vmap(lambda k: dense_init(k, F, D, dtype))(
        jax.random.split(ks[2], E_log))
    # physical bank: logical experts then replicas of experts [0, R)
    phys_to_logical = jnp.concatenate(
        [jnp.arange(E_log), jnp.arange(R)]).astype(jnp.int32)
    params = {
        "router": dense_init(ks[3], D, E_log, dtype),
        "gate": gate[phys_to_logical],
        "up": up[phys_to_logical],
        "down": down[phys_to_logical],
    }
    if moe.num_shared_experts:
        from repro.models.ffn import ffn_init
        params["shared"] = ffn_init(
            jax.random.fold_in(key, 7), D,
            moe.num_shared_experts * moe.expert_d_ff, "swiglu", dtype)
    return params


def route(router_w, x_flat, runtime: MoERuntime, moe: MoEConfig):
    """Top-k routing over logical experts with the §3.4 failure mask.

    Returns (weights (T,k) f32, sel (T,k) int32 logical ids, aux_loss).
    """
    T = x_flat.shape[0]
    logits = (x_flat @ router_w).astype(jnp.float32)        # (T, E_log)
    logits = jnp.where(runtime.expert_mask[None, :], logits, -jnp.inf)
    gates = jax.nn.softmax(logits, axis=-1)
    weights, sel = jax.lax.top_k(gates, moe.top_k)
    weights = weights / jnp.maximum(
        weights.sum(axis=-1, keepdims=True), 1e-9)
    # GShard load-balance auxiliary loss over healthy experts.
    E = moe.num_experts
    onehot = jax.nn.one_hot(sel, E, dtype=jnp.float32).sum(axis=1)  # (T,E)
    frac_tokens = onehot.mean(axis=0)
    frac_prob = gates.mean(axis=0)
    aux = E * jnp.sum(frac_tokens * frac_prob)
    return weights, sel, aux


def select_replicas(sel, runtime: MoERuntime):
    """Map logical selections to physical slots, balancing over replicas.

    Tokens alternate between replicas of the same logical expert — the
    paper's redundant experts double throughput on hot experts while every
    replica remains a valid recovery target.
    """
    T, k = sel.shape
    count = jnp.maximum(runtime.replica_count[sel], 1)           # (T,k)
    replica = (jnp.arange(T)[:, None] + jnp.arange(k)[None, :]) % count
    phys = jnp.take_along_axis(
        runtime.logical_to_physical[sel], replica[..., None], axis=-1
    )[..., 0]
    # experts with replica_count==0 are fully lost; mask contributions later
    alive = runtime.replica_count[sel] > 0
    return phys.astype(jnp.int32), alive


def capacity(tokens_times_k: int, e_phys: int, cf: float,
             floor: int = 8) -> int:
    c = int(math.ceil(cf * tokens_times_k / max(e_phys, 1)))
    return max(floor, min(tokens_times_k, c))


def group_by_expert(ids, ok, n_groups: int, cap: int):
    """The single sort pass shared by every dispatch implementation.

    ids: (N,) int32 group ids; ok: (N,) bool validity.  Returns
    (order, group, slot): ``order`` sorts the flat copies by group id
    (invalid entries last), ``group``/``slot`` are each sorted element's
    scatter coordinates, with invalid and over-capacity elements mapped
    out of bounds to (n_groups, cap) so ``mode='drop'`` scatters drop
    them.  Drop semantics live here and nowhere else — the dense path,
    the fused kernel's slot tables, and the A2A send/receive legs all
    consume this helper.
    """
    N = ids.shape[0]
    key = jnp.where(ok, ids, n_groups)           # dropped sort last
    order = jnp.argsort(key, stable=True)
    sorted_k = key[order]
    first = jnp.searchsorted(sorted_k, sorted_k, side="left")
    pos = jnp.arange(N, dtype=jnp.int32) - first.astype(jnp.int32)
    keep = (sorted_k < n_groups) & (pos < cap)
    group = jnp.where(keep, sorted_k, n_groups)
    slot = jnp.where(keep, pos, cap)
    return order, group, slot


def experts_compute(gate_w, up_w, down_w, buf):
    """Batched expert FFN. buf: (E_local, C, D) -> (E_local, C, D)."""
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, gate_w))
    h = h * jnp.einsum("ecd,edf->ecf", buf, up_w)
    return jnp.einsum("ecf,efd->ecd", h, down_w)


def dispatch_compute_combine(x_flat, weights, phys, alive,
                             gate_w, up_w, down_w, *,
                             cap: int, expert_offset, e_local: int):
    """Capacity-based dispatch -> expert FFN -> weighted combine.

    Pure local computation over the expert slots
    ``[expert_offset, expert_offset + e_local)``; tokens routed elsewhere
    are dropped locally (they are served by another EP rank, whose partial
    output arrives via the caller's psum/all-to-all).

    x_flat: (T, D); weights/phys/alive: (T, k).
    """
    T, D = x_flat.shape
    k = phys.shape[1]
    N = T * k
    e_id = phys.reshape(N) - expert_offset
    ok = (e_id >= 0) & (e_id < e_local) & alive.reshape(N)
    tok = jnp.arange(N, dtype=jnp.int32) // k
    order, scatter_e, scatter_p = group_by_expert(e_id, ok, e_local, cap)

    buf = jnp.zeros((e_local, cap, D), x_flat.dtype)
    buf = buf.at[scatter_e, scatter_p].set(
        x_flat[tok[order]], mode="drop")

    out_buf = experts_compute(gate_w, up_w, down_w, buf)   # (E_local, C, D)

    y_sorted = out_buf.at[scatter_e, scatter_p].get(
        mode="fill", fill_value=0.0)                        # (N, D)
    y_flat = jnp.zeros((N, D), x_flat.dtype).at[order].set(y_sorted)
    y = (y_flat.reshape(T, k, D)
         * weights[..., None].astype(x_flat.dtype)).sum(axis=1)
    return y


def use_pallas_default() -> bool:
    """Pallas kernels compile natively on TPU; on CPU the jnp fallback is
    the fast path (interpret mode is for parity tests only)."""
    return jax.default_backend() not in ("cpu",)


def dispatch_compute_combine_fused(x_flat, weights, phys, alive,
                                   gate_w, up_w, down_w, *,
                                   cap: int, expert_offset, e_local: int,
                                   use_pallas: Optional[bool] = None):
    """Fused-pipeline twin of :func:`dispatch_compute_combine`.

    One sort pass groups tokens per expert; gather -> grouped SwiGLU FFN
    -> weighted scatter-combine run in a single Pallas kernel (see
    ``repro.kernels.moe_fused``), skipping the dense (E_local, cap, D)
    HBM capacity buffer and the (N, D) unsort of the dense path.
    """
    from repro.kernels import ops
    if use_pallas is None:
        use_pallas = use_pallas_default()
    return ops.moe_dispatch_ffn_combine(
        x_flat, gate_w, up_w, down_w, weights, phys, alive,
        jnp.asarray(expert_offset, jnp.int32),
        cap=cap, e_local=e_local, use_pallas=use_pallas)


def dispatch_fn(cfg: ModelConfig):
    """Local dispatch->FFN->combine implementation selected by
    ``cfg.moe_impl``: dense-scatter or the fused Pallas pipeline."""
    return (dispatch_compute_combine_fused if cfg.moe_fused
            else dispatch_compute_combine)


def moe_apply_local(p, cfg: ModelConfig, x_flat, runtime: MoERuntime, *,
                    cap: int, expert_offset=0, e_local: Optional[int] = None):
    """Single-rank MoE application over local expert slots.

    Shared experts and the router run on the caller side (replicated /
    TP-sharded by GSPMD); this function is what runs inside shard_map for
    the distributed path.  ``cfg.moe_impl`` endings in 'fused' route the
    dispatch->FFN->combine through the fused Pallas pipeline.
    Returns (y (T,D), aux_loss scalar).
    """
    moe = cfg.moe
    e_local = e_local if e_local is not None else physical_experts(moe)
    weights, sel, aux = route(p["router"], x_flat, runtime, moe)
    phys, alive = select_replicas(sel, runtime)
    y = dispatch_fn(cfg)(
        x_flat, weights, phys, alive, p["gate"], p["up"], p["down"],
        cap=cap, expert_offset=expert_offset, e_local=e_local)
    return y, aux


def shared_expert_apply(p, cfg: ModelConfig, x):
    if cfg.moe and cfg.moe.num_shared_experts and "shared" in p:
        from repro.models.ffn import ffn_apply
        return ffn_apply(p["shared"], x, "swiglu")
    return 0.0
