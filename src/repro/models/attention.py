"""Attention mixers: GQA (full/chunked-flash/decode), MLA, sliding window.

Full-sequence attention uses a chunked online-softmax ("flash") formulation
in pure JAX so peak memory stays bounded at 32k context: the (Sq, Skv)
score matrix is never materialized.  Decode paths operate against a
(ring-buffered when windowed) KV cache and update it in place.

Serving decode runs against paged block pools (``gqa_decode_paged`` /
``mla_decode_paged`` over ``ops.paged_attention`` — Pallas kernel on TPU,
jnp oracle on CPU); the ring-buffer decode here is the reference
semantics the paged path is proven against, and remains the dry-run /
training-eval path.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import apply_rope, dense_init, rms_norm, rope_sincos, split_keys

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# chunked flash attention (full sequence)
# ---------------------------------------------------------------------------

def _flash_one_q_chunk(qc, k, v, q_pos_c, kv_pos, *, causal, window,
                       kv_chunk, kv_chunks_limit, scale):
    """Online-softmax over kv chunks for one q chunk.

    qc: (B, Qc, Hkv, G, Dh); k: (B, Skv, Hkv, Dh); v: (B, Skv, Hkv, Dv).
    kv_chunks_limit: number of kv chunks this q chunk may attend to
    (static, derived from causality) — chunks beyond it are skipped.
    """
    B, Qc, Hkv, G, Dh = qc.shape
    Dv = v.shape[-1]
    nkv = kv_chunks_limit

    k_used = k[:, : nkv * kv_chunk].reshape(B, nkv, kv_chunk, Hkv, Dh)
    v_used = v[:, : nkv * kv_chunk].reshape(B, nkv, kv_chunk, Hkv, Dv)
    kv_pos_used = kv_pos[: nkv * kv_chunk].reshape(nkv, kv_chunk)

    def body(carry, xs):
        acc, m, l = carry
        kc, vc, pos_kv = xs
        s = jnp.einsum("bqkgd,bskd->bqkgs", qc, kc,
                       preferred_element_type=jnp.float32) * scale
        mask = jnp.ones((Qc, kv_chunk), dtype=bool)
        if causal:
            mask &= q_pos_c[:, None] >= pos_kv[None, :]
        if window:
            mask &= (q_pos_c[:, None] - pos_kv[None, :]) < window
        s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bqkgs,bskv->bqkgv", p.astype(vc.dtype), vc,
            preferred_element_type=jnp.float32)
        return (acc_new, m_new, l_new), None

    acc0 = jnp.zeros((B, Qc, Hkv, G, Dv), jnp.float32)
    m0 = jnp.full((B, Qc, Hkv, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Qc, Hkv, G), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(
        body, (acc0, m0, l0),
        (k_used.swapaxes(0, 1), v_used.swapaxes(0, 1), kv_pos_used))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.astype(qc.dtype)


def flash_attention(q, k, v, q_pos, kv_pos, *, causal=True, window=0,
                    q_chunk=2048, kv_chunk=1024):
    """q: (B,Sq,H,Dh), k: (B,Skv,Hkv,Dh), v: (B,Skv,Hkv,Dv) -> (B,Sq,H,Dv).

    q_pos: (Sq,) int32 absolute positions; kv_pos: (Skv,).
    The python loop over q chunks keeps per-chunk kv scan bounds *static*,
    so causal attention skips future kv chunks entirely (no wasted FLOPs
    on fully-masked blocks).
    """
    B, Sq, H, Dh = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    assert H % Hkv == 0, (H, Hkv)
    G = H // Hkv
    scale = 1.0 / math.sqrt(Dh)

    q_chunk = min(q_chunk, Sq)
    while Sq % q_chunk:
        q_chunk //= 2
    kv_chunk = min(kv_chunk, Skv)
    while Skv % kv_chunk:
        kv_chunk //= 2
    assert q_chunk >= 1 and kv_chunk >= 1, (Sq, q_chunk, Skv, kv_chunk)
    nq = Sq // q_chunk
    nkv_total = Skv // kv_chunk

    qg = q.reshape(B, Sq, Hkv, G, Dh)
    outs = []
    for i in range(nq):
        qc = jax.lax.dynamic_slice_in_dim(qg, i * q_chunk, q_chunk, axis=1)
        q_pos_c = jax.lax.dynamic_slice_in_dim(q_pos, i * q_chunk, q_chunk)
        if causal:
            # q positions in this chunk are q_pos[i*qc : (i+1)*qc]; when both
            # sides share the same position grid (self-attn), kv chunks past
            # the q chunk end are fully masked -> skip them statically.
            limit = min(nkv_total, (i + 1) * q_chunk // kv_chunk)
            limit = max(limit, 1)
        else:
            limit = nkv_total
        outs.append(_flash_one_q_chunk(
            qc, k, v, q_pos_c, kv_pos, causal=causal, window=window,
            kv_chunk=kv_chunk, kv_chunks_limit=limit, scale=scale))
    out = jnp.concatenate(outs, axis=1)
    return out.reshape(B, Sq, H, v.shape[-1])


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------

def gqa_init(key, cfg: ModelConfig, dtype=jnp.float32):
    D = cfg.d_model
    Dh = cfg.resolved_head_dim()
    ks = split_keys(key, 4)
    return {
        "wq": dense_init(ks[0], D, cfg.num_heads * Dh, dtype),
        "wk": dense_init(ks[1], D, cfg.num_kv_heads * Dh, dtype),
        "wv": dense_init(ks[2], D, cfg.num_kv_heads * Dh, dtype),
        "wo": dense_init(ks[3], cfg.num_heads * Dh, D, dtype),
    }


def gqa_forward(p, cfg: ModelConfig, x, positions, *, causal=True,
                kv_input=None, kv_positions=None, window=0, use_rope=True,
                return_kv=False):
    """Full-sequence GQA. kv_input overrides the kv source (cross-attn)."""
    B, S, D = x.shape
    Dh = cfg.resolved_head_dim()
    H, Hkv = cfg.num_heads, cfg.num_kv_heads
    src = x if kv_input is None else kv_input
    kv_pos = positions if kv_positions is None else kv_positions

    q = (x @ p["wq"]).reshape(B, S, H, Dh)
    k = (src @ p["wk"]).reshape(B, src.shape[1], Hkv, Dh)
    v = (src @ p["wv"]).reshape(B, src.shape[1], Hkv, Dh)
    if use_rope:
        sin_q, cos_q = rope_sincos(positions, Dh, cfg.rope_theta)
        sin_k, cos_k = rope_sincos(kv_pos, Dh, cfg.rope_theta)
        q = apply_rope(q, sin_q[None, :, None, :], cos_q[None, :, None, :])
        k = apply_rope(k, sin_k[None, :, None, :], cos_k[None, :, None, :])
    out = flash_attention(q, k, v, positions, kv_pos, causal=causal,
                          window=window)
    y = out.reshape(B, S, H * Dh) @ p["wo"]
    if return_kv:
        return y, (k, v)
    return y


def gqa_forward_with_kv(p, cfg: ModelConfig, x, positions):
    """Prefill variant: returns (y, (k, v)) with rope already applied to k,
    ready to be placed into the decode ring cache."""
    return gqa_forward(p, cfg, x, positions, causal=True,
                       window=cfg.sliding_window, return_kv=True)


class GQACache(NamedTuple):
    k: jnp.ndarray  # (B, W, Hkv, Dh) ring buffer (W = window or max_seq)
    v: jnp.ndarray


def gqa_init_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype=jnp.float32):
    W = cfg.sliding_window or max_seq
    W = min(W, max_seq)
    Dh = cfg.resolved_head_dim()
    shape = (batch, W, cfg.num_kv_heads, Dh)
    return GQACache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))


def _ring_validity(pos: jnp.ndarray, W: int):
    """For each ring slot s, the absolute position it currently holds and
    whether it is valid, given current token position ``pos`` (B,)."""
    s = jnp.arange(W)[None, :]                      # (1, W)
    cur = (pos % W)[:, None]                        # (B, 1)
    delta = (cur - s) % W                           # age of slot
    slot_pos = pos[:, None] - delta                 # absolute position held
    valid = slot_pos >= 0
    return slot_pos, valid


def gqa_decode(p, cfg: ModelConfig, x, cache: GQACache, pos, *, use_rope=True):
    """One-token decode. x: (B, D); pos: (B,) absolute position of x.

    Writes the new kv into the ring slot, attends over valid slots.
    """
    B, D = x.shape
    Dh = cfg.resolved_head_dim()
    H, Hkv = cfg.num_heads, cfg.num_kv_heads
    G = H // Hkv
    W = cache.k.shape[1]

    q = (x @ p["wq"]).reshape(B, H, Dh)
    k = (x @ p["wk"]).reshape(B, Hkv, Dh)
    v = (x @ p["wv"]).reshape(B, Hkv, Dh)
    if use_rope:
        sin, cos = rope_sincos(pos, Dh, cfg.rope_theta)  # (B, Dh/2)
        q = apply_rope(q, sin[:, None, :], cos[:, None, :])
        k = apply_rope(k, sin[:, None, :], cos[:, None, :])

    slot = pos % W
    k_cache = cache.k.at[jnp.arange(B), slot].set(k.astype(cache.k.dtype))
    v_cache = cache.v.at[jnp.arange(B), slot].set(v.astype(cache.v.dtype))

    slot_pos, valid = _ring_validity(pos, W)
    if cfg.sliding_window:
        valid &= (pos[:, None] - slot_pos) < cfg.sliding_window

    qg = q.reshape(B, Hkv, G, Dh)
    s = jnp.einsum("bkgd,bwkd->bkgw", qg, k_cache,
                   preferred_element_type=jnp.float32) / math.sqrt(Dh)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    pw = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgw,bwkd->bkgd", pw.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    out = out.reshape(B, H * Dh).astype(x.dtype)
    return out @ p["wo"], GQACache(k_cache, v_cache)


def gqa_paged_pools(cfg: ModelConfig, num_blocks: int, block_size: int,
                    dtype=jnp.float32):
    """One layer's paged K/V pools: (num_blocks, block_size, Hkv, Dh)."""
    Dh = cfg.resolved_head_dim()
    shape = (num_blocks, block_size, cfg.num_kv_heads, Dh)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def window_starts(cfg: ModelConfig, seq_lens):
    """Sliding-window lower bound per sequence (None = full attention)."""
    if not cfg.sliding_window:
        return None
    return jnp.maximum(seq_lens - cfg.sliding_window, 0)


def gqa_decode_qkv(p, cfg: ModelConfig, x, page, *, use_rope=True):
    """Shared q/k/v projection (+rope at ``seq_lens - 1``) for the paged
    decode paths — the composed chain and the decode megakernel both
    start from exactly these tensors."""
    B, D = x.shape
    Dh = cfg.resolved_head_dim()
    H, Hkv = cfg.num_heads, cfg.num_kv_heads
    pos = page["seq_lens"] - 1
    q = (x @ p["wq"]).reshape(B, H, Dh)
    k = (x @ p["wk"]).reshape(B, Hkv, Dh)
    v = (x @ p["wv"]).reshape(B, Hkv, Dh)
    if use_rope:
        sin, cos = rope_sincos(pos, Dh, cfg.rope_theta)
        q = apply_rope(q, sin[:, None, :], cos[:, None, :])
        k = apply_rope(k, sin[:, None, :], cos[:, None, :])
    return q, k, v


def gqa_write_token(pools, page, k, v):
    """Scatter one incoming token's K/V into its (block, offset) rows
    (idle batch slots hit the trash block)."""
    k_pool = pools["k"].at[page["write_bid"], page["write_off"]].set(
        k.astype(pools["k"].dtype))
    v_pool = pools["v"].at[page["write_bid"], page["write_off"]].set(
        v.astype(pools["v"].dtype))
    return {"k": k_pool, "v": v_pool}


def gqa_decode_paged(p, cfg: ModelConfig, x, pools, page, *,
                     use_pallas: bool = False, use_rope=True):
    """One-token decode against paged K/V pools (one layer).

    x: (B, D); pools: {"k","v"} (nb, bs, Hkv, Dh); page: the per-step
    paging arrays — ``tables`` (B, max_blk), ``seq_lens`` (B,) valid
    length *including* the incoming token, ``write_bid``/``write_off``
    (B,) the physical slot position ``seq_lens - 1`` lands in (idle batch
    slots point at the trash block).
    """
    from repro.kernels import ops
    B, D = x.shape
    Dh = cfg.resolved_head_dim()
    H = cfg.num_heads
    q, k, v = gqa_decode_qkv(p, cfg, x, page, use_rope=use_rope)
    new_pools = gqa_write_token(pools, page, k, v)
    out = ops.paged_attention(q, new_pools["k"], new_pools["v"],
                              page["tables"], page["seq_lens"],
                              window_starts(cfg, page["seq_lens"]),
                              use_pallas=use_pallas)
    y = out.reshape(B, H * Dh).astype(x.dtype) @ p["wo"]
    return y, new_pools


def mla_paged_pools(cfg: ModelConfig, num_blocks: int, block_size: int,
                    dtype=jnp.float32):
    """One layer's paged latent pool: (nb, bs, 1, R + dr).

    MLA decode attends in the latent space, so one fused pool holds
    ``concat([c_kv, k_rope])`` per token (the Hkv=1 axis matches the
    paged-attention kernel's pool layout).
    """
    m = cfg.mla
    shape = (num_blocks, block_size, 1,
             m.kv_lora_rank + m.qk_rope_head_dim)
    return {"ckr": jnp.zeros(shape, dtype)}


def mla_decode_q_token(p, cfg: ModelConfig, x, page):
    """Absorbed latent query + fused pool token row for one MLA decode
    step.  The query is pre-scaled by ``sqrt(R+dr)/sqrt(dn+dr)`` so the
    paged-attention kernel's ``1/sqrt(R+dr)`` yields the MLA scale."""
    m = cfg.mla
    dn, dr = m.qk_nope_head_dim, m.qk_rope_head_dim
    R = m.kv_lora_rank
    pos = page["seq_lens"] - 1
    q_nope, q_rope, c_kv, k_rope, sin, cos = _mla_qkr(p, cfg, x, pos)
    q_rope = apply_rope(q_rope, sin[:, None, :], cos[:, None, :])  # (B,H,dr)
    k_rope = apply_rope(k_rope, sin, cos)                          # (B,dr)
    q_lat = jnp.einsum("bhd,hdr->bhr", q_nope, p["wuk"])           # (B,H,R)
    token = jnp.concatenate([c_kv, k_rope], axis=-1)               # (B,R+dr)
    q_eff = jnp.concatenate([q_lat, q_rope], axis=-1) * (
        math.sqrt(R + dr) / math.sqrt(dn + dr))
    return q_eff, token


def mla_write_token(pools, page, token):
    """Scatter one incoming token's fused latent row into its block."""
    pool = pools["ckr"].at[page["write_bid"], page["write_off"], 0].set(
        token.astype(pools["ckr"].dtype))
    return {"ckr": pool}


def mla_post_matrix(p, cfg: ModelConfig):
    """Absorbed post-attention projection (H*(R+dr), D): ``wuv`` folded
    into ``wo``, zero rows for the rope columns — so the megakernel's
    single ``out @ w_post`` matmul equals the composed slice-then-two-
    einsum readout.  At deployment scale cache this per weight version;
    here it is rebuilt inside the jitted step (smoke-size folding)."""
    m = cfg.mla
    H = cfg.num_heads
    dr, dv = m.qk_rope_head_dim, m.v_head_dim
    D = p["wo"].shape[1]
    wov = jnp.einsum("hrv,hvd->hrd", p["wuv"], p["wo"].reshape(H, dv, D))
    return jnp.concatenate(
        [wov, jnp.zeros((H, dr, D), wov.dtype)], axis=1).reshape(-1, D)


def mla_decode_paged(p, cfg: ModelConfig, x, pools, page, *,
                     use_pallas: bool = False):
    """Absorbed-matmul MLA decode over the fused latent pool.

    Scores are ``q_lat . c_kv + q_rope . k_rope``, which is exactly one
    paged-attention call on the concatenated pool; the value readout uses
    the same pool (output columns beyond R are discarded).
    """
    from repro.kernels import ops
    B, D = x.shape
    m = cfg.mla
    H = cfg.num_heads
    dv = m.v_head_dim
    R = m.kv_lora_rank

    q_eff, token = mla_decode_q_token(p, cfg, x, page)
    new_pools = mla_write_token(pools, page, token)
    pool = new_pools["ckr"]
    out = ops.paged_attention(q_eff.astype(pool.dtype), pool, pool,
                              page["tables"], page["seq_lens"],
                              window_starts(cfg, page["seq_lens"]),
                              use_pallas=use_pallas)
    o_lat = out[..., :R]                                           # (B,H,R)
    o = jnp.einsum("bhr,hrv->bhv", o_lat.astype(x.dtype), p["wuv"])
    return o.reshape(B, H * dv) @ p["wo"], new_pools


def gqa_cross_decode(p, cfg: ModelConfig, x, ck, cv, kv_valid):
    """Cross-attention decode against precomputed encoder kv.

    ck/cv: (B, F, Hkv, Dh); kv_valid: (B, F) bool.
    """
    B, D = x.shape
    Dh = cfg.resolved_head_dim()
    H, Hkv = cfg.num_heads, cfg.num_kv_heads
    G = H // Hkv
    q = (x @ p["wq"]).reshape(B, Hkv, G, Dh)
    s = jnp.einsum("bkgd,bfkd->bkgf", q, ck,
                   preferred_element_type=jnp.float32) / math.sqrt(Dh)
    s = jnp.where(kv_valid[:, None, None, :], s, NEG_INF)
    pw = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgf,bfkd->bkgd", pw.astype(cv.dtype), cv,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, H * Dh).astype(x.dtype) @ p["wo"]


# ---------------------------------------------------------------------------
# MLA (multi-head latent attention, DeepSeek/MiniCPM3 style)
# ---------------------------------------------------------------------------

def mla_init(key, cfg: ModelConfig, dtype=jnp.float32):
    m = cfg.mla
    D, H = cfg.d_model, cfg.num_heads
    dn, dr, dv = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim
    ks = split_keys(key, 7)
    return {
        "wdq": dense_init(ks[0], D, m.q_lora_rank, dtype),
        "q_norm": jnp.ones((m.q_lora_rank,), dtype),
        "wuq": dense_init(ks[1], m.q_lora_rank, H * (dn + dr), dtype),
        "wdkv": dense_init(ks[2], D, m.kv_lora_rank, dtype),
        "kv_norm": jnp.ones((m.kv_lora_rank,), dtype),
        "wkr": dense_init(ks[3], D, dr, dtype),
        "wuk": dense_init(ks[4], m.kv_lora_rank, H * dn, dtype)
            .reshape(m.kv_lora_rank, H, dn).transpose(1, 2, 0),  # (H, dn, R)
        "wuv": dense_init(ks[5], m.kv_lora_rank, H * dv, dtype)
            .reshape(m.kv_lora_rank, H, dv).transpose(1, 0, 2),  # (H, R, dv)
        "wo": dense_init(ks[6], H * dv, D, dtype),
    }


def _mla_qkr(p, cfg, x, positions):
    """Shared q / latent / rope-key computation. x: (B,S,D) or (B,D)."""
    m = cfg.mla
    H = cfg.num_heads
    dn, dr = m.qk_nope_head_dim, m.qk_rope_head_dim
    q_lat = rms_norm(x @ p["wdq"], p["q_norm"], cfg.norm_eps)
    q_all = (q_lat @ p["wuq"]).reshape(*x.shape[:-1], H, dn + dr)
    q_nope, q_rope = q_all[..., :dn], q_all[..., dn:]
    c_kv = rms_norm(x @ p["wdkv"], p["kv_norm"], cfg.norm_eps)
    k_rope = x @ p["wkr"]  # (..., dr), shared across heads
    sin, cos = rope_sincos(positions, dr, cfg.rope_theta)
    return q_nope, q_rope, c_kv, k_rope, sin, cos


def mla_forward(p, cfg: ModelConfig, x, positions, *, causal=True, window=0,
                return_cache=False):
    """Full-sequence MLA: latent expanded to per-head k/v, flash attention."""
    B, S, D = x.shape
    m = cfg.mla
    H = cfg.num_heads
    dn, dr, dv = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim
    q_nope, q_rope, c_kv, k_rope, sin, cos = _mla_qkr(p, cfg, x, positions)
    q_rope = apply_rope(q_rope, sin[None, :, None, :], cos[None, :, None, :])
    k_rope = apply_rope(k_rope, sin[None, :, :], cos[None, :, :])
    k_nope = jnp.einsum("bsr,hdr->bshd", c_kv, p["wuk"])
    v = jnp.einsum("bsr,hrv->bshv", c_kv, p["wuv"])
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (B, S, H, dr))],
        axis=-1)
    out = flash_attention(q, k, v, positions, positions, causal=causal,
                          window=window)
    y = out.reshape(B, S, H * dv) @ p["wo"]
    if return_cache:
        return y, (c_kv, k_rope)
    return y


def mla_forward_with_cache(p, cfg: ModelConfig, x, positions):
    """Prefill variant: returns (y, (c_kv, k_rope)) for the latent cache."""
    return mla_forward(p, cfg, x, positions, causal=True,
                       window=cfg.sliding_window, return_cache=True)


class MLACache(NamedTuple):
    c_kv: jnp.ndarray    # (B, W, R) latent ring buffer
    k_rope: jnp.ndarray  # (B, W, dr)


def mla_init_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype=jnp.float32):
    m = cfg.mla
    W = cfg.sliding_window or max_seq
    W = min(W, max_seq)
    return MLACache(
        jnp.zeros((batch, W, m.kv_lora_rank), dtype),
        jnp.zeros((batch, W, m.qk_rope_head_dim), dtype),
    )


def mla_decode(p, cfg: ModelConfig, x, cache: MLACache, pos):
    """Absorbed-matmul MLA decode: attention runs in the latent space, the
    full per-head K/V is never materialized (the DeepSeek serving trick)."""
    B, D = x.shape
    m = cfg.mla
    H = cfg.num_heads
    dn, dr, dv = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim
    W = cache.c_kv.shape[1]

    q_nope, q_rope, c_kv, k_rope, sin, cos = _mla_qkr(p, cfg, x, pos)
    q_rope = apply_rope(q_rope, sin[:, None, :], cos[:, None, :])   # (B,H,dr)
    k_rope = apply_rope(k_rope, sin, cos)                            # (B,dr)

    slot = pos % W
    c_cache = cache.c_kv.at[jnp.arange(B), slot].set(c_kv.astype(cache.c_kv.dtype))
    r_cache = cache.k_rope.at[jnp.arange(B), slot].set(k_rope.astype(cache.k_rope.dtype))

    slot_pos, valid = _ring_validity(pos, W)
    if cfg.sliding_window:
        valid &= (pos[:, None] - slot_pos) < cfg.sliding_window

    q_lat = jnp.einsum("bhd,hdr->bhr", q_nope, p["wuk"])  # absorb W_uk
    s = (jnp.einsum("bhr,bwr->bhw", q_lat, c_cache,
                    preferred_element_type=jnp.float32)
         + jnp.einsum("bhd,bwd->bhw", q_rope, r_cache,
                      preferred_element_type=jnp.float32))
    s = s / math.sqrt(dn + dr)
    s = jnp.where(valid[:, None, :], s, NEG_INF)
    pw = jax.nn.softmax(s, axis=-1)
    o_lat = jnp.einsum("bhw,bwr->bhr", pw.astype(c_cache.dtype), c_cache,
                       preferred_element_type=jnp.float32)
    o = jnp.einsum("bhr,hrv->bhv", o_lat.astype(x.dtype), p["wuv"])
    return o.reshape(B, H * dv) @ p["wo"], MLACache(c_cache, r_cache)
