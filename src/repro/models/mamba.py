"""Mamba-1 selective SSM block (falcon-mamba, Jamba mixer).

Full-sequence path runs a `lax.scan` over time (O(S) state recurrence —
the sub-quadratic property long_500k relies on); decode is a single O(1)
state update.  The chunked Pallas formulation lives in
``repro.kernels.ssm_scan``.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init, split_keys


class MambaState(NamedTuple):
    conv: jnp.ndarray  # (B, d_conv, d_inner) rolling window of conv inputs
    ssm: jnp.ndarray   # (B, d_inner, N)


def mamba_dims(cfg: ModelConfig):
    m = cfg.mamba
    d_inner = m.expand * cfg.d_model
    return d_inner, m.d_state, m.d_conv, m.resolved_dt_rank(cfg.d_model)


def mamba_init(key, cfg: ModelConfig, dtype=jnp.float32):
    D = cfg.d_model
    d_inner, N, d_conv, dt_rank = mamba_dims(cfg)
    ks = split_keys(key, 5)
    # S4D-real initialization for A
    A = jnp.broadcast_to(jnp.arange(1, N + 1, dtype=jnp.float32),
                         (d_inner, N))
    return {
        "in_proj": dense_init(ks[0], D, 2 * d_inner, dtype),
        "conv_w": (jax.random.normal(ks[1], (d_conv, d_inner)) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((d_inner,), dtype),
        "x_proj": dense_init(ks[2], d_inner, dt_rank + 2 * N, dtype),
        "dt_proj": dense_init(ks[3], dt_rank, d_inner, dtype),
        "dt_bias": jnp.full((d_inner,), -4.6, dtype),  # softplus^-1(0.01)
        "A_log": jnp.log(A).astype(dtype),
        "D_skip": jnp.ones((d_inner,), dtype),
        "out_proj": dense_init(ks[4], d_inner, D, dtype),
    }


def mamba_init_state(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> MambaState:
    d_inner, N, d_conv, _ = mamba_dims(cfg)
    return MambaState(
        jnp.zeros((batch, d_conv, d_inner), dtype),
        jnp.zeros((batch, d_inner, N), dtype),
    )


def _ssm_coeffs(p, cfg: ModelConfig, u):
    """Shared input-dependent SSM coefficients. u: (..., d_inner)."""
    _, N, _, dt_rank = mamba_dims(cfg)
    proj = u @ p["x_proj"]
    dt_raw = proj[..., :dt_rank]
    B_ssm = proj[..., dt_rank:dt_rank + N]
    C_ssm = proj[..., dt_rank + N:]
    dt = jax.nn.softplus(dt_raw @ p["dt_proj"] + p["dt_bias"])  # (..., d_inner)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))                # (d_inner, N)
    return dt, A, B_ssm, C_ssm


def mamba_forward(p, cfg: ModelConfig, x, *, return_state=False):
    """Full-sequence mamba. x: (B, S, D) -> (B, S, D) [, MambaState]."""
    B, S, D = x.shape
    d_inner, N, d_conv, dt_rank = mamba_dims(cfg)

    xz = x @ p["in_proj"]
    u, z = jnp.split(xz, 2, axis=-1)                            # (B,S,d_inner)

    # causal depthwise conv over time
    u_pad = jnp.pad(u, ((0, 0), (d_conv - 1, 0), (0, 0)))
    conv = sum(
        u_pad[:, i:i + S, :] * p["conv_w"][i][None, None, :]
        for i in range(d_conv)
    ) + p["conv_b"]
    u = jax.nn.silu(conv)

    dt, A, B_ssm, C_ssm = _ssm_coeffs(p, cfg, u)

    def step(h, xs):
        u_t, dt_t, B_t, C_t = xs       # (B,d_inner),(B,d_inner),(B,N),(B,N)
        dA = jnp.exp(dt_t[..., None] * A[None])                 # (B,d_inner,N)
        dBu = (dt_t * u_t)[..., None] * B_t[:, None, :]
        h = dA * h.astype(jnp.float32) + dBu.astype(jnp.float32)
        y_t = jnp.einsum("bdn,bn->bd", h, C_t.astype(jnp.float32))
        return h, y_t.astype(u_t.dtype)

    h0 = jnp.zeros((B, d_inner, N), jnp.float32)
    h_final, ys = jax.lax.scan(
        step, h0,
        (u.swapaxes(0, 1), dt.swapaxes(0, 1),
         B_ssm.swapaxes(0, 1), C_ssm.swapaxes(0, 1)))
    y = ys.swapaxes(0, 1) + u * p["D_skip"]
    y = y * jax.nn.silu(z)
    out = y @ p["out_proj"]
    if return_state:
        # conv state = last d_conv raw (pre-conv) inputs
        raw = jnp.split(xz, 2, axis=-1)[0]
        if S >= d_conv:
            conv_state = raw[:, -d_conv:, :]
        else:
            conv_state = jnp.pad(raw, ((0, 0), (d_conv - S, 0), (0, 0)))
        return out, MambaState(conv_state.astype(x.dtype),
                               h_final.astype(x.dtype))
    return out


def mamba_decode(p, cfg: ModelConfig, x, state: MambaState):
    """Single-token decode. x: (B, D) -> (B, D), new state."""
    B, D = x.shape
    d_inner, N, d_conv, _ = mamba_dims(cfg)
    xz = x @ p["in_proj"]
    u_raw, z = jnp.split(xz, 2, axis=-1)                        # (B, d_inner)

    conv_buf = jnp.concatenate(
        [state.conv[:, 1:, :], u_raw[:, None, :].astype(state.conv.dtype)],
        axis=1)                                                 # (B,d_conv,di)
    conv = jnp.einsum("bcd,cd->bd", conv_buf.astype(jnp.float32),
                      p["conv_w"].astype(jnp.float32)) + p["conv_b"]
    u = jax.nn.silu(conv).astype(x.dtype)

    dt, A, B_ssm, C_ssm = _ssm_coeffs(p, cfg, u)
    dA = jnp.exp(dt[..., None] * A[None])                       # (B,d_inner,N)
    dBu = (dt * u)[..., None] * B_ssm[:, None, :]
    h = dA * state.ssm.astype(jnp.float32) + dBu.astype(jnp.float32)
    y = jnp.einsum("bdn,bn->bd", h, C_ssm.astype(jnp.float32)).astype(x.dtype)
    y = (y + u * p["D_skip"]) * jax.nn.silu(z)
    return y @ p["out_proj"], MambaState(conv_buf, h.astype(state.ssm.dtype))
