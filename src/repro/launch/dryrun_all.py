"""Orchestrate the full dry-run matrix: 10 archs × 4 shapes × 2 meshes.

Each combo runs in a fresh subprocess (jax device-count env must be set
pre-import; failures stay isolated) with a timeout.  Results are cached
as JSON under results/dryrun/ — re-running skips completed combos.

  PYTHONPATH=src python -m repro.launch.dryrun_all [--only-single-pod]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

from repro.configs import ASSIGNED_ARCHS, INPUT_SHAPES

RESULTS_DIR = os.path.join(os.path.dirname(__file__),
                           "../../../results/dryrun")


def combo_path(arch: str, shape: str, mesh: str) -> str:
    return os.path.abspath(os.path.join(
        RESULTS_DIR, f"{arch}__{shape}__{mesh}.json"))


def run_combo(arch: str, shape: str, multi_pod: bool,
              timeout_s: int = 1500) -> dict:
    mesh = "2x16x16" if multi_pod else "16x16"
    out = combo_path(arch, shape, mesh)
    if os.path.exists(out):
        with open(out) as f:
            return json.load(f)
    cmd = [sys.executable, "-m", "repro.launch.dryrun",
           "--arch", arch, "--shape", shape, "--out", out]
    if multi_pod:
        cmd += ["--multi-pod", "--no-extrapolate"]
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "../.."))
    t0 = time.time()
    try:
        proc = subprocess.run(cmd, capture_output=True, text=True,
                              timeout=timeout_s, env=env)
        ok = proc.returncode == 0 and os.path.exists(out)
        if not ok:
            rec = {"arch": arch, "shape": shape, "mesh": mesh,
                   "error": proc.stderr[-3000:], "elapsed_s":
                       time.time() - t0}
            with open(out + ".err", "w") as f:
                json.dump(rec, f, indent=2)
            return rec
        with open(out) as f:
            return json.load(f)
    except subprocess.TimeoutExpired:
        rec = {"arch": arch, "shape": shape, "mesh": mesh,
               "error": f"timeout after {timeout_s}s"}
        with open(out + ".err", "w") as f:
            json.dump(rec, f, indent=2)
        return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--only-single-pod", action="store_true")
    ap.add_argument("--only-multi-pod", action="store_true")
    ap.add_argument("--timeout", type=int, default=1500)
    args = ap.parse_args(argv)
    os.makedirs(RESULTS_DIR, exist_ok=True)
    meshes = []
    if not args.only_multi_pod:
        meshes.append(False)
    if not args.only_single_pod:
        meshes.append(True)
    total = ok = 0
    t0 = time.time()
    for multi_pod in meshes:
        for arch in ASSIGNED_ARCHS:
            for shape in INPUT_SHAPES:
                total += 1
                rec = run_combo(arch, shape, multi_pod, args.timeout)
                status = "ERR " if "error" in rec else "ok  "
                if "error" not in rec:
                    ok += 1
                print(f"[{time.time() - t0:7.0f}s] {status} {arch:24s} "
                      f"{shape:12s} {'2x16x16' if multi_pod else '16x16'}",
                      flush=True)
    print(f"done: {ok}/{total} combos succeeded")
    return 0 if ok == total else 1


if __name__ == "__main__":
    sys.exit(main())
