"""Training launcher: real steps on local devices, or AOT against the
production mesh.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-moe-a2.7b \
      --smoke --steps 50
  PYTHONPATH=src python -m repro.launch.train --arch kimi-k2-1t-a32b \
      --aot            # lower+compile train_4k for the 16x16 mesh
"""
from __future__ import annotations

import argparse
import sys


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config, real training on local devices")
    ap.add_argument("--aot", action="store_true",
                    help="AOT lower+compile for the production mesh")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args(argv)

    if args.aot:
        import os
        os.environ["XLA_FLAGS"] = (
            "--xla_force_host_platform_device_count=512 "
            + os.environ.get("XLA_FLAGS", ""))
        from repro.launch.dryrun import run_dryrun
        run_dryrun(args.arch, "train_4k", multi_pod=args.multi_pod,
                   extrapolate=False)
        return 0

    from repro.configs import get_smoke_config, get_config
    from repro.models.model import Model
    from repro.training.data import DataConfig, make_batch
    from repro.training.train_loop import train
    import numpy as np

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = Model(cfg)
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                    batch_size=args.batch)

    def batches():
        step = 0
        while True:
            b = make_batch(dc, step)
            if cfg.family == "vlm":
                P = cfg.num_patches
                b = {"tokens": b["tokens"],
                     "patches": np.random.default_rng(step).normal(
                         size=(args.batch, P, cfg.d_model)).astype("float32"),
                     "loss_mask": b["loss_mask"]}
            if cfg.family == "audio":
                b["frames"] = np.random.default_rng(step).normal(
                    size=(args.batch, cfg.encoder_seq, cfg.d_model)
                ).astype("float32")
            yield b
            step += 1

    params, history = train(model, batches(), args.steps, log_every=10)
    for h in history:
        print(f"step {h['step']:5d} loss {h['loss']:.4f} "
              f"lr {h['lr']:.2e} gnorm {h['grad_norm']:.2f} "
              f"({h['elapsed_s']:.1f}s)")
    print(f"final loss: {history[-1]['loss']:.4f}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
