"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) combo.

Proves the distribution config is coherent without hardware: 512
placeholder CPU devices stand in for 2 pods × 256 chips.  For each combo
we ``.lower().compile()`` the real step function, print
``memory_analysis()`` (fits/doesn't) and ``cost_analysis()`` (FLOPs,
bytes), parse the collective ops out of the partitioned HLO, and emit the
three roofline terms (EXPERIMENTS.md §Roofline).

Usage:
  python -m repro.launch.dryrun --arch kimi-k2-1t-a32b --shape train_4k
  python -m repro.launch.dryrun --arch ... --shape ... --multi-pod
  python -m repro.launch.dryrun --list
"""
# The placeholder devices MUST be configured before any jax import —
# device count locks on first backend init.
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

import argparse
import json
import re
import sys
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import ALL_ARCHS, INPUT_SHAPES, get_config
from repro.distributed.collectives import make_moe_dist
from repro.distributed.sharding import ShardingRules
from repro.launch.mesh import dp_axes, make_production_mesh
from repro.launch.specs import make_step, step_arg_specs
from repro.models.model import Model
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

# -- TPU v5e hardware constants (per chip) -----------------------------------
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
LINK_BW = 50e9               # bytes/s per ICI link

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "u64": 8}

_COLL_RE = re.compile(
    r"=\s*(?P<shape>[\w\[\],{}() ]+?)\s*"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\(")
_ARR_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _ARR_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def parse_collectives(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Per-op-type: count, result bytes (per device), est. wire traffic.

    Wire-traffic model (ring algorithms, group size n):
      all-reduce       2·S·(n-1)/n      S = per-device operand bytes
      all-gather       S·(n-1)/n        S = per-device *result* bytes
      reduce-scatter   S·(n-1)          S = per-device result (S·n input)
      all-to-all       S·(n-1)/n
      collective-permute  S
    """
    stats: Dict[str, Dict[str, float]] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if m is None or "-done" in line.split("=")[0]:
            continue
        op = m.group("op")
        size = _shape_bytes(m.group("shape"))
        n = 1
        gi = _GROUPS_IOTA_RE.search(line)
        if gi:
            n = int(gi.group(2))
        else:
            gl = _GROUPS_LIST_RE.search(line)
            if gl:
                n = len(gl.group(1).split(","))
        if n <= 1:
            n = 2  # conservative: unknown group
        frac = (n - 1) / n
        if op == "all-reduce":
            wire = 2 * size * frac
        elif op == "all-gather":
            wire = size * frac
        elif op == "reduce-scatter":
            wire = size * (n - 1)
        elif op == "all-to-all":
            wire = size * frac
        else:
            wire = size
        s = stats.setdefault(op, {"count": 0, "bytes": 0.0, "wire": 0.0})
        s["count"] += 1
        s["bytes"] += size
        s["wire"] += wire
    return stats


def model_flops_params(cfg) -> Dict[str, float]:
    """Active / total matmul params for MODEL_FLOPS (6·N·D or 2·N·D)."""
    model = Model(cfg, dtype=jnp.bfloat16)
    specs = model.param_specs()
    total = expert = embed = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(specs)[0]:
        keys = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
        n = 1
        for s in leaf.shape:
            n *= s
        total += n
        if "moe" in keys and keys[-1] in ("gate", "up", "down"):
            expert += n
        if keys[-1] == "embed":
            embed += n
    active = total - embed - expert
    if cfg.moe is not None and expert:
        from repro.models.moe import physical_experts
        active += expert * cfg.moe.top_k / physical_experts(cfg.moe)
    return {"total": float(total), "active": float(active),
            "expert": float(expert)}


# perf-experiment knobs (set by run_dryrun)
_FORCE_ATTN_TP = False
_DONATE = False


def _jit_step(step, in_sh, kind: str):
    donate = ()
    if _DONATE:
        donate = (0, 1) if kind == "train" else (
            (1,) if kind == "decode" else ())
    return jax.jit(step, in_shardings=in_sh, donate_argnums=donate)


def layer_units(cfg) -> int:
    """Number of repeated 'layer units' for cost extrapolation.

    unit = plain layer (dense/ssm/vlm), MoE layer (moe families,
    excluding the fixed first-k dense layers), Jamba period, or
    encoder+decoder layer pair (audio).
    """
    if cfg.hybrid_period:
        return cfg.num_layers // cfg.hybrid_period
    if cfg.family == "audio":
        return cfg.num_layers  # == encoder_layers
    if cfg.moe is not None:
        return cfg.num_layers - cfg.moe.first_k_dense
    return cfg.num_layers


def with_units(cfg, n_units: int):
    import dataclasses
    if cfg.hybrid_period:
        return dataclasses.replace(cfg, num_layers=n_units * cfg.hybrid_period)
    if cfg.family == "audio":
        return dataclasses.replace(cfg, num_layers=n_units,
                                   encoder_layers=n_units)
    if cfg.moe is not None:
        return dataclasses.replace(
            cfg, num_layers=n_units + cfg.moe.first_k_dense)
    return dataclasses.replace(cfg, num_layers=n_units)


def _cost_of(cfg, shape, mesh, moe_impl: str):
    """Compile an UNROLLED depth-reduced variant and return
    (flops, bytes, wire_bytes, collectives) per device.

    XLA cost_analysis counts a while-loop body once (verified), so the
    full-depth scanned module undercounts; we compile unrolled at 2 and 4
    layer-units and extrapolate linearly — exact for homogeneous stacks.
    """
    dist = (make_moe_dist(mesh, moe_impl, dp_axes=dp_axes(mesh))
            if cfg.moe is not None else None)
    model = Model(cfg, dtype=jnp.bfloat16, moe_dist=dist)
    step = make_step(model, shape.kind)
    args = step_arg_specs(model, cfg, shape)
    in_sh = build_in_shardings(model, cfg, shape, mesh, args)
    compiled = _jit_step(step, in_sh, shape.kind).lower(*args).compile()
    cost = compiled.cost_analysis() or {}
    coll = parse_collectives(compiled.as_text())
    wire = sum(s["wire"] for s in coll.values())
    return (float(cost.get("flops", 0.0)),
            float(cost.get("bytes accessed", 0.0)), wire, coll)


def extrapolated_cost(cfg, shape, mesh, moe_impl: str):
    """Linear-in-depth cost model from 2- and 4-unit unrolled compiles."""
    import dataclasses
    units_full = layer_units(cfg)
    u_small, u_big = 2, 4
    cfg_u = dataclasses.replace(cfg, scan_layers=False)
    f2, b2, w2, _ = _cost_of(with_units(cfg_u, u_small), shape, mesh,
                             moe_impl)
    f4, b4, w4, c4 = _cost_of(with_units(cfg_u, u_big), shape, mesh,
                              moe_impl)
    du = u_big - u_small

    def ext(small, big):
        per = (big - small) / du
        return small + (units_full - u_small) * per, per

    flops, flops_per = ext(f2, f4)
    bytes_, bytes_per = ext(b2, b4)
    wire, wire_per = ext(w2, w4)
    return {
        "flops": flops, "bytes": bytes_, "wire": wire,
        "per_unit": {"flops": flops_per, "bytes": bytes_per,
                     "wire": wire_per},
        "fixed": {"flops": f2 - 2 * flops_per, "bytes": b2 - 2 * bytes_per,
                  "wire": w2 - 2 * wire_per},
        "units": units_full,
        "collectives_4unit": c4,
    }


def build_in_shardings(model: Model, cfg, shape, mesh, args):
    rules = ShardingRules(mesh, cfg)
    if _FORCE_ATTN_TP:
        # uneven head sharding (GSPMD pads internally), perf experiment
        rules.attn_tp = True
        rules.kv_tp = True
    B = shape.global_batch
    params_sh = rules.params_shardings(args[0])
    if shape.kind == "train":
        opt_sh = jax.tree_util.tree_map_with_path(
            lambda path, leaf: NamedSharding(
                mesh, rules.param_spec(path, leaf)), args[1])
        batch_sh = rules.data_shardings(args[2], B)
        return (params_sh, opt_sh, batch_sh)
    if shape.kind == "prefill":
        batch_sh = rules.data_shardings(args[1], B)
        rt_sh = rules.replicated(args[2])
        return (params_sh, batch_sh, rt_sh)
    cache_sh = rules.cache_shardings(args[1], B)
    tok_sh = NamedSharding(mesh, rules.batch_spec(B))
    rt_sh = rules.replicated(args[3])
    return (params_sh, cache_sh, tok_sh, rt_sh)


def apply_cfg_patch(cfg, patch: Optional[Dict]):
    """dataclasses.replace with dotted keys for nested moe fields,
    e.g. {"moe.min_capacity": 1, "sliding_window": 4096}."""
    import dataclasses
    if not patch:
        return cfg
    top, moe_kw = {}, {}
    for k, v in patch.items():
        if k.startswith("moe."):
            moe_kw[k[4:]] = v
        else:
            top[k] = v
    if moe_kw:
        top["moe"] = dataclasses.replace(cfg.moe, **moe_kw)
    return dataclasses.replace(cfg, **top)


def run_dryrun(arch: str, shape_name: str, *, multi_pod: bool = False,
               moe_impl: str = "gather_psum", save_hlo: Optional[str] = None,
               extrapolate: bool = True, verbose: bool = True,
               cfg_patch: Optional[Dict] = None,
               force_attn_tp: bool = False, donate_state: bool = False
               ) -> Dict:
    t_start = time.perf_counter()
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    cfg = get_config(arch, shape_name)
    shape = INPUT_SHAPES[shape_name]
    import dataclasses
    cfg = dataclasses.replace(cfg, remat=(shape.kind == "train"))
    if cfg.moe is not None:
        # thread the impl into the config so a '_fused' choice also
        # selects the fused local compute inside shard_map
        cfg = dataclasses.replace(cfg, moe_impl=moe_impl)
    cfg = apply_cfg_patch(cfg, cfg_patch)
    global _FORCE_ATTN_TP, _DONATE
    _FORCE_ATTN_TP = force_attn_tp
    _DONATE = donate_state
    dist = (make_moe_dist(mesh, moe_impl, dp_axes=dp_axes(mesh))
            if cfg.moe is not None else None)
    model = Model(cfg, dtype=jnp.bfloat16, moe_dist=dist)
    step = make_step(model, shape.kind)
    args = step_arg_specs(model, cfg, shape)
    in_sh = build_in_shardings(model, cfg, shape, mesh, args)

    t0 = time.perf_counter()
    lowered = _jit_step(step, in_sh, shape.kind).lower(*args)
    t1 = time.perf_counter()
    compiled = lowered.compile()
    t2 = time.perf_counter()

    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    if save_hlo:
        with open(save_hlo, "w") as f:
            f.write(hlo)
    coll = parse_collectives(hlo)

    # exact per-device costs via depth extrapolation (see extrapolated_cost)
    if extrapolate:
        ext = extrapolated_cost(cfg, shape, mesh, moe_impl)
    else:  # multi-pod runs only prove lower+compile; roofline is 1-pod
        ext = {"flops": 0.0, "bytes": 0.0,
               "wire": sum(s["wire"] for s in coll.values()),
               "per_unit": {}, "fixed": {}, "units": layer_units(cfg),
               "collectives_4unit": {}}
    flops = ext["flops"]
    bytes_acc = ext["bytes"]
    wire = ext["wire"]
    compute_s = flops / PEAK_FLOPS
    memory_s = bytes_acc / HBM_BW
    coll_s = wire / LINK_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": coll_s}
    dominant = max(terms, key=terms.get)

    mp = model_flops_params(cfg)
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    mult = 6.0 if shape.kind == "train" else 2.0
    model_flops = mult * mp["active"] * tokens
    hlo_flops_total = flops * n_chips
    useful = model_flops / hlo_flops_total if hlo_flops_total else 0.0

    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": int(n_chips), "kind": shape.kind, "moe_impl":
            moe_impl if cfg.moe else None,
        "lower_s": t1 - t0, "compile_s": t2 - t1,
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "code_bytes": mem.generated_code_size_in_bytes,
        },
        "per_device_flops": flops,
        "per_device_bytes": bytes_acc,
        "cost_extrapolation": {k: ext[k] for k in
                               ("per_unit", "fixed", "units")},
        "collectives": coll,                    # full scanned module
        "collectives_4unit": ext["collectives_4unit"],
        "collective_wire_bytes": wire,
        "roofline": {**terms, "dominant": dominant},
        "model_flops": model_flops,
        "hlo_flops_total": hlo_flops_total,
        "useful_flops_ratio": useful,
        "params": mp,
        "elapsed_s": time.perf_counter() - t_start,
    }
    if verbose:
        gb = 1 << 30
        print(f"== {arch} × {shape_name} × {rec['mesh']} "
              f"({shape.kind}, moe_impl={rec['moe_impl']}) ==")
        print(f"  lower {rec['lower_s']:.1f}s  compile {rec['compile_s']:.1f}s")
        print(f"  memory/device: args {mem.argument_size_in_bytes / gb:.2f} GiB"
              f"  temp {mem.temp_size_in_bytes / gb:.2f} GiB"
              f"  out {mem.output_size_in_bytes / gb:.2f} GiB")
        print(f"  per-device: {flops / 1e12:.2f} TFLOP, "
              f"{bytes_acc / 1e9:.1f} GB accessed, "
              f"wire {wire / 1e9:.3f} GB")
        print(f"  roofline: compute {compute_s * 1e3:.2f} ms | memory "
              f"{memory_s * 1e3:.2f} ms | collective {coll_s * 1e3:.2f} ms "
              f"-> {dominant}")
        print(f"  MODEL_FLOPS/HLO_FLOPS = {useful:.3f}")
        for op, s in sorted(coll.items()):
            print(f"    {op:20s} n={s['count']:4d} bytes={s['bytes']/1e9:.3f}GB"
                  f" wire={s['wire']/1e9:.3f}GB")
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ALL_ARCHS)
    ap.add_argument("--shape", choices=list(INPUT_SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--moe-impl", default="gather_psum",
                    choices=["gather_psum", "a2a", "gather_psum_fused",
                             "a2a_fused"])
    ap.add_argument("--out", default=None, help="write JSON record here")
    ap.add_argument("--save-hlo", default=None)
    ap.add_argument("--no-extrapolate", action="store_true",
                    help="skip the cost-extrapolation compiles")
    ap.add_argument("--list", action="store_true")
    # perf-experiment knobs (§Perf)
    ap.add_argument("--min-capacity", type=int, default=None)
    ap.add_argument("--capacity-factor", type=float, default=None)
    ap.add_argument("--force-attn-tp", action="store_true")
    ap.add_argument("--donate", action="store_true")
    ap.add_argument("--cache-carry", action="store_true")
    ap.add_argument("--num-heads", type=int, default=None,
                    help="pad/override head count (perf experiment)")
    args = ap.parse_args(argv)
    if args.list:
        for a in ALL_ARCHS:
            print(a)
        return 0
    assert args.arch and args.shape, "--arch and --shape required"
    patch = {}
    if args.min_capacity is not None:
        patch["moe.min_capacity"] = args.min_capacity
    if args.capacity_factor is not None:
        patch["moe.capacity_factor"] = args.capacity_factor
    if args.cache_carry:
        patch["decode_cache_carry"] = True
    if args.num_heads is not None:
        patch["num_heads"] = args.num_heads
        patch["num_kv_heads"] = args.num_heads
    rec = run_dryrun(args.arch, args.shape, multi_pod=args.multi_pod,
                     moe_impl=args.moe_impl, save_hlo=args.save_hlo,
                     extrapolate=not args.no_extrapolate,
                     cfg_patch=patch or None,
                     force_attn_tp=args.force_attn_tp,
                     donate_state=args.donate)
    if args.out:
        os.makedirs(os.path.dirname(os.path.abspath(args.out)), exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(rec, f, indent=2)
    return 0


if __name__ == "__main__":
    sys.exit(main())
