"""Serving launcher: a FlowServe instance with ReviveMoE recovery.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-moe-a2.7b \
      --mode disaggregated --requests 8 --inject-fault moe
"""
from __future__ import annotations

import argparse
import sys

import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-moe-a2.7b")
    ap.add_argument("--mode", default="disaggregated",
                    choices=["collocated", "disaggregated"])
    ap.add_argument("--num-dp", type=int, default=2)
    ap.add_argument("--num-moe", type=int, default=2)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--inject-fault", default=None,
                    choices=[None, "attn", "moe"])
    ap.add_argument("--fault-step", type=int, default=5)
    ap.add_argument("--workdir", default="/tmp/repro_serve")
    args = ap.parse_args(argv)

    from repro.configs import get_smoke_config
    from repro.core.fault_codes import ErrorType, Severity
    from repro.serving.engine import EngineConfig, InferenceEngine

    cfg = get_smoke_config(args.arch)
    ec = EngineConfig(mode=args.mode, num_dp=args.num_dp,
                      num_moe=args.num_moe, max_batch=4, max_seq=128,
                      block_size=16, num_blocks=256, workdir=args.workdir)
    print(f"building engine: {args.arch} ({args.mode}, "
          f"{args.num_dp} DP + {args.num_moe if cfg.moe else 0} MoE ranks)")
    eng = InferenceEngine(cfg, ec)
    print("init timings:",
          {k: f"{v:.2f}s" for k, v in eng.init_timings.items()})

    rng = np.random.default_rng(0)
    reqs = [eng.submit(list(rng.integers(0, cfg.vocab_size, 12)),
                       args.max_new) for _ in range(args.requests)]

    if args.inject_fault:
        pid = (args.num_dp if args.inject_fault == "moe"
               and args.mode == "disaggregated" else 1)
        eng.injector.schedule(args.fault_step, pid, severity=Severity.L6,
                              error_type=ErrorType.HBM_ECC,
                              component=args.inject_fault, mid_step=True)
        print(f"scheduled {args.inject_fault} fault on device {pid} "
              f"at step {args.fault_step}")

    eng.run(max_steps=500)
    done = sum(r.state.value == "finished" for r in reqs)
    print(f"finished {done}/{len(reqs)} requests in {eng.step_no} steps")
    for rep in eng.reports:
        print("RECOVERY:", rep.summary())
        for a in rep.actions:
            print("   -", a)
    return 0 if done == len(reqs) else 1


if __name__ == "__main__":
    sys.exit(main())
