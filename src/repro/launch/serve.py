"""Serving launcher: FlowServe instance(s) with ReviveMoE recovery.

Single instance:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-moe-a2.7b \
      --mode disaggregated --requests 8 --inject-fault moe

Fleet mode — N instances + K hot spares behind the cluster router, with
restart-vs-revive-vs-spare arbitration and optional full-instance loss:
  PYTHONPATH=src python -m repro.launch.serve --fleet 3 --spares 1 \
      --requests 24 --inject-fault moe --lose-instance 1
"""
from __future__ import annotations

import argparse
import sys

import numpy as np


def _run_fleet(args, cfg) -> int:
    from repro.core.fault_codes import ErrorType, Severity
    from repro.fleet import PoissonTraffic, build_fleet
    from repro.serving.engine import EngineConfig

    ec = EngineConfig(mode=args.mode, num_dp=args.num_dp,
                      num_moe=args.num_moe, max_batch=4, max_seq=128,
                      block_size=16, num_blocks=256,
                      decode_impl=args.decode_impl,
                      overlap=args.overlap,
                      workdir=args.workdir)
    if args.http is not None:
        # HTTP mode: arrivals come from clients, not a synthetic trace
        fleet = build_fleet(cfg, ec, instances=args.fleet,
                            spares=args.spares,
                            force_policy=args.force_policy,
                            replenish_spares=args.replenish_spares,
                            kv_stream=not args.no_kv_stream)
        from repro.serving.frontend import serve_http
        serve_http(fleet, host=args.http_host, port=args.http)
        return 0
    traffic = PoissonTraffic(args.rate, cfg.vocab_size, prompt_len=12,
                             max_new_tokens=args.max_new, seed=0,
                             limit=args.requests)
    print(f"building fleet: {args.fleet} x [{args.arch} {args.mode} "
          f"{args.num_dp}DP+{args.num_moe if cfg.moe else 0}MoE] + "
          f"{args.spares} spare(s)")
    fleet = build_fleet(cfg, ec, instances=args.fleet,
                        spares=args.spares,
                        force_policy=args.force_policy, traffic=traffic,
                        replenish_spares=args.replenish_spares,
                        kv_stream=not args.no_kv_stream)
    if args.inject_fault:
        pid = (args.num_dp if args.inject_fault == "moe"
               and args.mode == "disaggregated" else 1)
        fleet.instances[0].engine.injector.schedule(
            args.fault_step, pid, severity=Severity.L6,
            error_type=ErrorType.HBM_ECC, component=args.inject_fault,
            mid_step=True)
        print(f"scheduled {args.inject_fault} device fault on instance 0 "
              f"pid {pid} at engine step {args.fault_step}")
    lost = False
    for _ in range(4000):
        fleet.tick()
        if (args.lose_instance is not None and not lost
                and fleet.ticks == 2 * args.fault_step):
            print(f"injecting full loss of instance {args.lose_instance}")
            fleet.lose_instance(args.lose_instance)
            lost = True
        if traffic.exhausted and fleet.requests and not fleet.unfinished:
            break
    done = sum(r.state.value == "finished" for r in fleet.requests)
    ttfts = sorted(fleet.ttfts())
    print(f"\nfinished {done}/{len(fleet.requests)} requests in "
          f"{fleet.ticks} ticks ({fleet.now_s:.2f}s virtual)")
    if ttfts:
        print(f"TTFT p50={ttfts[len(ttfts) // 2] * 1e3:.0f}ms "
              f"max={ttfts[-1] * 1e3:.0f}ms")
    for line in fleet.log:
        print(" ", line)
    return 0 if done == len(fleet.requests) else 1


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-moe-a2.7b")
    ap.add_argument("--mode", default="disaggregated",
                    choices=["collocated", "disaggregated"])
    ap.add_argument("--num-dp", type=int, default=2)
    ap.add_argument("--num-moe", type=int, default=2)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--inject-fault", default=None,
                    choices=[None, "attn", "moe"])
    ap.add_argument("--fault-step", type=int, default=5)
    ap.add_argument("--workdir", default="/tmp/repro_serve")
    ap.add_argument("--fleet", type=int, default=0, metavar="N",
                    help="run N instances behind the fleet router")
    ap.add_argument("--spares", type=int, default=0, metavar="K",
                    help="pre-warm K hot-spare instances (fleet mode)")
    ap.add_argument("--rate", type=float, default=50.0,
                    help="open-loop Poisson arrival rate (fleet mode)")
    ap.add_argument("--force-policy", default=None,
                    choices=[None, "revive", "restart", "spare"],
                    help="pin the recovery arbiter (fleet mode)")
    ap.add_argument("--lose-instance", type=int, default=None,
                    metavar="IID", help="inject a full-instance loss "
                    "(fleet mode)")
    ap.add_argument("--replenish-spares", action="store_true",
                    help="rebuild consumed standbys in the background "
                    "(fleet mode)")
    ap.add_argument("--decode-impl", default=None,
                    choices=[None, "composed", "megakernel"],
                    help="decode/chunk step implementation (megakernel "
                    "= fused attention+MoE step; default: model config)")
    ap.add_argument("--no-kv-stream", action="store_true",
                    help="force token-replay re-prefill on migration "
                    "(disable KV-block streaming)")
    ap.add_argument("--overlap", action="store_true",
                    help="async pipelined engine: plan step N+1 while "
                    "step N runs on device (token streams stay "
                    "bit-identical to lockstep)")
    ap.add_argument("--http", type=int, default=None, metavar="PORT",
                    help="serve an OpenAI-style HTTP front end "
                    "(/v1/completions with SSE streaming, /health, "
                    "/instances, /control) instead of a synthetic "
                    "request batch; 0 picks a free port")
    ap.add_argument("--http-host", default="127.0.0.1")
    args = ap.parse_args(argv)
    if args.http is not None and args.fleet == 0:
        args.fleet = 1              # the front end drives a FleetRouter

    from repro.configs import get_smoke_config
    from repro.core.fault_codes import ErrorType, Severity
    from repro.serving.engine import EngineConfig, InferenceEngine

    cfg = get_smoke_config(args.arch)
    if args.fleet > 0:
        return _run_fleet(args, cfg)
    ec = EngineConfig(mode=args.mode, num_dp=args.num_dp,
                      num_moe=args.num_moe, max_batch=4, max_seq=128,
                      block_size=16, num_blocks=256, workdir=args.workdir,
                      decode_impl=args.decode_impl, overlap=args.overlap)
    print(f"building engine: {args.arch} ({args.mode}, "
          f"{args.num_dp} DP + {args.num_moe if cfg.moe else 0} MoE ranks)")
    eng = InferenceEngine(cfg, ec)
    print("init timings:",
          {k: f"{v:.2f}s" for k, v in eng.init_timings.items()})

    rng = np.random.default_rng(0)
    reqs = [eng.submit(list(rng.integers(0, cfg.vocab_size, 12)),
                       args.max_new) for _ in range(args.requests)]

    if args.inject_fault:
        pid = (args.num_dp if args.inject_fault == "moe"
               and args.mode == "disaggregated" else 1)
        eng.injector.schedule(args.fault_step, pid, severity=Severity.L6,
                              error_type=ErrorType.HBM_ECC,
                              component=args.inject_fault, mid_step=True)
        print(f"scheduled {args.inject_fault} fault on device {pid} "
              f"at step {args.fault_step}")

    eng.run(max_steps=500)
    done = sum(r.state.value == "finished" for r in reqs)
    print(f"finished {done}/{len(reqs)} requests in {eng.step_no} steps")
    ttfts = sorted(r.ttft_s for r in reqs if r.ttft_s is not None)
    if ttfts:
        # single-engine mode has no virtual clock: wall TTFT is the metric
        print(f"TTFT p50={ttfts[len(ttfts) // 2] * 1e3:.0f}ms "
              f"max={ttfts[-1] * 1e3:.0f}ms")
    for rep in eng.reports:
        print("RECOVERY:", rep.summary())
        for a in rep.actions:
            print("   -", a)
    return 0 if done == len(reqs) else 1


if __name__ == "__main__":
    sys.exit(main())
