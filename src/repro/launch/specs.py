"""ShapeDtypeStruct stand-ins for every model input (no allocation).

``input_specs(cfg, shape)`` returns the step-function argument specs for
the given input shape's kind; ``make_step(model, kind)`` returns the
function to lower.  Modality frontends are stubs per the assignment:
audio supplies precomputed frame embeddings, VLM precomputed patch
embeddings.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import InputShape, ModelConfig
from repro.models.model import Model
from repro.training.optimizer import OptimizerConfig, init_adamw


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def batch_specs(cfg: ModelConfig, shape: InputShape, *, with_loss_mask: bool,
                dtype=jnp.bfloat16) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    if cfg.family == "vlm":
        P = cfg.num_patches
        out = {"tokens": sds((B, S - P), jnp.int32),
               "patches": sds((B, P, cfg.d_model), dtype)}
        if with_loss_mask:
            out["loss_mask"] = sds((B, S - P), jnp.int32)
        return out
    out = {"tokens": sds((B, S), jnp.int32)}
    if cfg.family == "audio":
        out["frames"] = sds((B, cfg.encoder_seq, cfg.d_model), dtype)
    if with_loss_mask:
        out["loss_mask"] = sds((B, S), jnp.int32)
    return out


def decode_specs(model: Model, shape: InputShape) -> Tuple:
    """(cache_specs, token_specs) for a serve_step with a seq_len-deep cache."""
    B, S = shape.global_batch, shape.seq_len
    cache = jax.eval_shape(lambda: model.init_cache(B, S))
    tokens = sds((B,), jnp.int32)
    return cache, tokens


def runtime_specs(model: Model):
    rt = model.default_runtime()
    if rt is None:
        return None
    return jax.tree_util.tree_map(
        lambda x: sds(x.shape, x.dtype), rt)


def make_step(model: Model, kind: str, opt_cfg: OptimizerConfig = None
              ) -> Callable:
    if kind == "train":
        from repro.training.train_loop import make_train_step
        return make_train_step(model, opt_cfg or OptimizerConfig())
    if kind == "prefill":
        def prefill_step(params, batch, runtime):
            last, cache = model.prefill(params, batch, runtime)
            next_tok = jnp.argmax(last, axis=-1).astype(jnp.int32)
            return next_tok, cache
        return prefill_step
    if kind == "decode":
        def serve_step(params, cache, tokens, runtime):
            logits, cache = model.decode_step(params, cache, tokens, runtime)
            next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            return next_tok, cache
        return serve_step
    raise ValueError(kind)


def step_arg_specs(model: Model, cfg: ModelConfig, shape: InputShape,
                   dtype=jnp.bfloat16) -> Tuple:
    """Argument ShapeDtypeStructs matching make_step's signature."""
    params = model.param_specs()
    if shape.kind == "train":
        opt = jax.eval_shape(init_adamw, params)
        batch = batch_specs(cfg, shape, with_loss_mask=True, dtype=dtype)
        return (params, opt, batch)
    if shape.kind == "prefill":
        batch = batch_specs(cfg, shape, with_loss_mask=False, dtype=dtype)
        return (params, batch, runtime_specs(model))
    cache, tokens = decode_specs(model, shape)
    return (params, cache, tokens, runtime_specs(model))
