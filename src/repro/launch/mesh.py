"""Production mesh builders.

Single pod: 16×16 = 256 chips, axes ("data", "model").
Multi pod:  2×16×16 = 512 chips, axes ("pod", "data", "model").

Defined as functions so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def dp_axes(mesh) -> tuple:
    """The data-parallel axes of a production mesh (pod folds into DP)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def make_host_mesh():
    """1×1 mesh over the real local device(s) — for CPU tests of the
    distributed code paths."""
    n = len(jax.devices())
    return jax.make_mesh((1, n), ("data", "model"))
