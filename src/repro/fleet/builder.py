"""Convenience construction of a homogeneous fleet.

All instances (spares included) share one workdir: the first build
writes ``weights.npz`` and every later build restores it, so the fleet
is *weight-identical* — the precondition for exact cross-instance token
replay — and they share the on-disk XLA compile cache, so spares warm up
from cached compiles the way a real standby would.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.configs.base import ModelConfig
from repro.fleet.arbiter import CostModel, RecoveryArbiter
from repro.fleet.instance import FleetInstance, InstanceState
from repro.fleet.router import FleetRouter
from repro.fleet.spares import SparePool
from repro.serving.engine import EngineConfig, InferenceEngine


def build_fleet(cfg: ModelConfig, ecfg: EngineConfig, *,
                instances: int = 2, spares: int = 0,
                force_policy: Optional[str] = None,
                soft_patience: int = 1,
                traffic=None, replenish_spares: bool = False,
                kv_stream: bool = True,
                prefix_affinity: bool = False) -> FleetRouter:
    """``replenish_spares`` turns on background standby repair (one
    rebuild per router tick after an activation); ``kv_stream=False``
    forces token-replay re-prefill on every migration (the verified
    fallback path); ``prefix_affinity`` biases admission so shared
    prompt prefixes land on the instance whose block cache holds them."""
    if instances < 1:
        raise ValueError(f"instances must be >= 1, got {instances!r}")
    if spares < 0:
        raise ValueError(f"spares must be >= 0, got {spares!r}")

    def _engine() -> InferenceEngine:
        # each engine gets its own config object (engines mutate theirs)
        return InferenceEngine(cfg, dataclasses.replace(ecfg))

    members = [FleetInstance(i, _engine()) for i in range(instances)]
    pool = SparePool(
        lambda iid: FleetInstance(iid, _engine(), InstanceState.SPARE),
        size=spares, auto_replenish=replenish_spares) if spares else None
    arbiter = RecoveryArbiter(
        CostModel(members[0].engine.init_timings),
        force_policy=force_policy, soft_patience=soft_patience)
    return FleetRouter(members, spares=pool, arbiter=arbiter,
                       traffic=traffic, kv_stream=kv_stream,
                       prefix_affinity=prefix_affinity)
