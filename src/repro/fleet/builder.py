"""Convenience construction of a homogeneous fleet.

All instances (spares included) share one workdir: the first build
writes ``weights.npz`` and every later build restores it, so the fleet
is *weight-identical* — the precondition for exact cross-instance token
replay — and they share the on-disk XLA compile cache, so spares warm up
from cached compiles the way a real standby would.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

from repro.configs.base import ModelConfig
from repro.fleet.arbiter import CostModel, RecoveryArbiter
from repro.fleet.instance import FleetInstance, InstanceState
from repro.fleet.router import FleetRouter
from repro.fleet.spares import SparePool
from repro.serving.engine import EngineConfig, InferenceEngine


def build_fleet(cfg: ModelConfig, ecfg: EngineConfig, *,
                instances: int = 2, spares: int = 0,
                force_policy: Optional[str] = None,
                soft_patience: int = 1,
                traffic=None, replenish_spares: bool = False,
                kv_stream: bool = True,
                prefix_affinity: bool = False,
                cost_profile=None,
                max_backlog: int = 256) -> FleetRouter:
    """``replenish_spares`` turns on background standby repair (one
    rebuild per router tick after an activation); ``kv_stream=False``
    forces token-replay re-prefill on every migration (the verified
    fallback path); ``prefix_affinity`` biases admission so shared
    prompt prefixes land on the instance whose block cache holds them.
    A ``cost_profile`` (:class:`~repro.fleet.chaos.VirtualCostProfile`)
    switches clock and cost model to pinned virtual costs — the chaos-
    campaign determinism mode."""
    if instances < 1:
        raise ValueError(f"instances must be >= 1, got {instances!r}")
    if spares < 0:
        raise ValueError(f"spares must be >= 0, got {spares!r}")

    def _engine() -> InferenceEngine:
        # each engine gets its own config object (engines mutate theirs)
        return InferenceEngine(cfg, dataclasses.replace(ecfg))

    members = [FleetInstance(i, _engine()) for i in range(instances)]
    pool = SparePool(
        lambda iid: FleetInstance(iid, _engine(), InstanceState.SPARE),
        size=spares, auto_replenish=replenish_spares) if spares else None
    cost = (cost_profile.cost_model() if cost_profile is not None
            else CostModel(members[0].engine.init_timings))
    arbiter = RecoveryArbiter(cost, force_policy=force_policy,
                              soft_patience=soft_patience)
    return FleetRouter(members, spares=pool, arbiter=arbiter,
                       traffic=traffic, kv_stream=kv_stream,
                       prefix_affinity=prefix_affinity,
                       cost_profile=cost_profile,
                       max_backlog=max_backlog)


def build_multi_model_fleet(
        models: Dict[str, Tuple[ModelConfig, EngineConfig]], *,
        counts: Dict[str, int],
        spares: Optional[Dict[str, int]] = None,
        force_policy: Optional[str] = None,
        soft_patience: int = 1,
        traffic=None, kv_stream: bool = True,
        cost_profile=None, max_backlog: int = 256,
        rebalance: bool = True) -> FleetRouter:
    """A fleet serving several model configs behind one router.

    ``models`` maps model_id -> (ModelConfig, EngineConfig); each model
    needs its own workdir (weights differ).  ``counts`` says how many
    serving instances each model gets; ``spares`` how many standbys per
    model (pooled — acquisition is model-matched).  With ``rebalance``,
    the router gets a rebuilder per model, so a model that loses its
    last instance can evict-and-rebalance an over-provisioned peer."""
    if not models:
        raise ValueError("build_multi_model_fleet needs >= 1 model")

    def _engine(model_id: str) -> InferenceEngine:
        cfg, ecfg = models[model_id]
        return InferenceEngine(cfg, dataclasses.replace(ecfg))

    def _make(iid: int, model_id: str,
              state: InstanceState = InstanceState.SERVING
              ) -> FleetInstance:
        return FleetInstance(iid, _engine(model_id), state,
                             model_id=model_id)

    members, iid = [], 0
    for model_id in sorted(counts):
        for _ in range(counts[model_id]):
            members.append(_make(iid, model_id))
            iid += 1
    if not members:
        raise ValueError("counts produced an empty fleet")

    pool = None
    spare_specs = [m for m in sorted(spares or {})
                   for _ in range(((spares or {})[m]))]
    if spare_specs:
        cursor = {"i": 0}

        def _spare_factory(sid: int) -> FleetInstance:
            model_id = spare_specs[cursor["i"] % len(spare_specs)]
            cursor["i"] += 1
            return _make(sid, model_id, InstanceState.SPARE)

        pool = SparePool(_spare_factory, size=len(spare_specs))

    cost = (cost_profile.cost_model() if cost_profile is not None
            else CostModel(members[0].engine.init_timings))
    arbiter = RecoveryArbiter(cost, force_policy=force_policy,
                              soft_patience=soft_patience)
    rebuilders = ({m: (lambda i, m=m: _make(i, m)) for m in models}
                  if rebalance else None)
    return FleetRouter(members, spares=pool, arbiter=arbiter,
                       traffic=traffic, kv_stream=kv_stream,
                       cost_profile=cost_profile,
                       rebuilders=rebuilders, max_backlog=max_backlog)
