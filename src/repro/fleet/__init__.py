"""Fleet control plane: multi-instance serving for ReviveMoE.

The paper's headline claim — in-place revive beats drain-and-restart
*because a restart stalls the whole instance* — is a fleet-level claim:
it only shows up when N instances serve open-loop traffic and one of
them gets hurt.  This package is that layer:

* :class:`FleetInstance` / :class:`FleetRouter` — N ``InferenceEngine``
  instances behind a cluster router with continuous admission,
  per-instance load tracking and Poisson/trace-driven open-loop traffic.
* :class:`SparePool` — pre-warmed standbys (weights loaded, graphs
  compiled) that can substitute for a failed instance.
* cross-instance live request migration — in-flight requests on a dying
  instance re-admit elsewhere with prompt + generated-prefix re-prefill;
  position-seeded sampling keeps the replayed tokens identical.
* :class:`RecoveryArbiter` — per fault, chooses ReviveMoE in-place
  recovery vs drain-and-restart vs spare substitution from an explicit
  cost model fed by measured ``RecoveryReport`` / init timings.
"""
from repro.fleet.arbiter import ArbiterDecision, CostModel, RecoveryArbiter
from repro.fleet.builder import build_fleet, build_multi_model_fleet
from repro.fleet.chaos import (CampaignEvent, CampaignResult,
                               CampaignRunner, CampaignSchedule,
                               VirtualCostProfile, fleet_topology,
                               slo_burn)
from repro.fleet.instance import FleetInstance, InstanceState
from repro.fleet.router import FleetHealth, FleetRouter
from repro.fleet.spares import SparePool
from repro.fleet.traffic import (Arrival, DiurnalTraffic, MixedTraffic,
                                 PoissonTraffic, TraceTraffic)

__all__ = [
    "ArbiterDecision", "CostModel", "RecoveryArbiter", "build_fleet",
    "build_multi_model_fleet", "CampaignEvent", "CampaignResult",
    "CampaignRunner", "CampaignSchedule", "VirtualCostProfile",
    "fleet_topology", "slo_burn", "FleetInstance", "InstanceState",
    "FleetHealth", "FleetRouter", "SparePool", "Arrival",
    "DiurnalTraffic", "MixedTraffic", "PoissonTraffic", "TraceTraffic",
]
