"""Pre-warmed hot spares (FailSafe-style standby substitution).

A spare is a fully built ``InferenceEngine``: weights loaded from the
shared fleet checkpoint, serving graphs compiled (and failure-scenario
graphs precompiled) via the shared on-disk ``GraphCache``.  Activation
is therefore a control-plane action — flip state, re-home requests — not
an init: the multi-second build cost was paid at provisioning time.
"""
from __future__ import annotations

import time
from typing import Callable, List, Optional

from repro.fleet.instance import FleetInstance, InstanceState


class SparePool:
    def __init__(self, factory: Callable[[int], FleetInstance],
                 size: int, first_iid: int = 1000,
                 auto_replenish: bool = False):
        """factory(iid) must return a built, SPARE-state FleetInstance.

        ``first_iid`` namespaces spare ids away from the serving set.
        ``auto_replenish``: after an activation, rebuild a standby in the
        background (one per router tick) instead of letting the pool
        shrink — the fleet's steady-state spare capacity self-heals.
        """
        self._factory = factory
        self._next_iid = first_iid
        self.target_size = size
        self.auto_replenish = auto_replenish
        self.warm: List[FleetInstance] = []
        self.activations = 0
        self.replenishments = 0
        self.warmup_s: List[float] = []
        for _ in range(size):
            self._provision()

    def _provision(self) -> FleetInstance:
        t0 = time.perf_counter()
        inst = self._factory(self._next_iid)
        self.warmup_s.append(time.perf_counter() - t0)
        inst.state = InstanceState.SPARE
        self._next_iid += 1
        self.warm.append(inst)
        return inst

    @property
    def available(self) -> int:
        return len(self.warm)

    def available_for(self, model_id: Optional[str] = None) -> int:
        """Warm standbys able to serve ``model_id`` (None = any)."""
        return sum(1 for inst in self.warm if inst.serves(model_id))

    def acquire(self, model_id: Optional[str] = None
                ) -> Optional[FleetInstance]:
        """Hand a warm standby to the router (None if the pool is dry).
        With ``model_id``, only a matching spare qualifies — a standby
        built for another model config is useless for this fault."""
        for i, inst in enumerate(self.warm):
            if inst.serves(model_id):
                inst = self.warm.pop(i)
                inst.state = InstanceState.SERVING
                self.activations += 1
                return inst
        return None

    @property
    def deficit(self) -> int:
        return max(0, self.target_size - self.available)

    def maybe_replenish(self) -> Optional[FleetInstance]:
        """Background capacity repair, called once per router tick:
        rebuild at most one standby when the pool is below target.  The
        build runs on a new host, off the serving path, so it costs no
        virtual fleet time."""
        if not self.auto_replenish or not self.deficit:
            return None
        inst = self._provision()
        self.replenishments += 1
        return inst

    def replenish(self) -> FleetInstance:
        """Provision a fresh standby immediately (manual capacity repair)."""
        return self._provision()
