"""Chaos campaign driver: seeded fleet-scale fault schedules.

A *campaign* is a long randomized fault schedule — correlated rack
losses, cascading stragglers, flapping links, spot-preemption waves,
rolling upgrades — layered onto a diurnal traffic trace and replayed
against a live fleet.  The campaign is scored by **SLO-burn** (the
integral of windowed p99 TTFT/TPOT excess over target: how much SLO was
burned, for how long) and emits a *failure-forensics* document: per
recovery, the arbiter's decision, the cost actually charged, and the
counterfactual prices of the actions it did not take — so "arbiter vs
forced revive/restart/spare-only" is a first-class comparison rather
than a number to eyeball.

Determinism contract: a campaign is a pure function of
``(schedule seed, traffic seed, fleet composition, VirtualCostProfile)``.
The profile pins every duration the virtual clock, the cost model and
the forensics log ever see (wall time never enters), so the same seed
produces a byte-identical forensics JSON — the reproducibility gate CI
enforces nightly.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.fault_codes import ErrorType, Severity
from repro.fleet.arbiter import CostModel
from repro.fleet.instance import InstanceState
from repro.fleet.router import FleetRouter

# -- deterministic virtual costs --------------------------------------------------


@dataclass(frozen=True)
class VirtualCostProfile:
    """Pinned per-action durations for campaign mode.

    With a profile installed on the router, the virtual clock charges
    these instead of wall measurements: recovery mechanics still really
    execute (revive revives, spares substitute, KV blocks stream), but
    every second on the clock — and every observation fed to the
    measurement-driven :class:`~repro.fleet.arbiter.CostModel` — is a
    deterministic function of the campaign seed.  The defaults keep the
    paper's ordering: revive ≪ spare swap ≪ restart.

    ``jitter`` > 0 replaces each *recovery* charge (revive / restart /
    spare swap — not the step clock) with a seeded lognormal draw
    around its base: ``base * LogNormal(0, jitter)`` from an rng keyed
    on ``(jitter_seed, action kind, per-kind event index)``.  Costs
    stay a pure function of the profile — the same seed replays a
    byte-identical forensics document — but the arbiter now trains its
    cost model against dispersed observations instead of constants.
    ``jitter=0`` (default) reproduces the constant-cost behavior
    exactly."""
    step_s: float = 0.02               # one engine step (decode tick)
    revive_s: float = 0.03             # in-place revive stall
    restart_s: float = 2.5             # full instance relaunch
    spare_swap_s: float = 0.05         # control-plane substitution
    per_token_prefill_s: float = 2e-4  # token-replay re-prefill rate
    per_block_stream_s: float = 2e-5   # KV-block streaming rate
    jitter: float = 0.0                # lognormal sigma on recovery costs
    jitter_seed: int = 0

    # stable kind ids: part of the determinism contract (renumbering
    # would silently change every jittered campaign)
    _KIND_IDS = {"revive": 0, "restart": 1, "spare": 2}

    def event_cost(self, kind: str, index: int, base_s: float) -> float:
        """The charge for the ``index``-th recovery of ``kind``: the
        pinned base, scaled by this event's seeded lognormal draw when
        jitter is on.  Rounded so forensics stay byte-comparable."""
        if self.jitter <= 0.0:
            return base_s
        rng = np.random.default_rng(
            [self.jitter_seed, self._KIND_IDS.get(kind, 3), index])
        return round(base_s * float(rng.lognormal(0.0, self.jitter)), 6)

    def cost_model(self, **kw) -> CostModel:
        """A CostModel seeded purely from the profile (no wall-clock
        build timings), so arbiter estimates are campaign-deterministic
        before the first measurement arrives."""
        return CostModel({"restart": self.restart_s},
                         per_token_prefill_s=self.per_token_prefill_s,
                         per_block_stream_s=self.per_block_stream_s,
                         **kw)


# -- schedule ---------------------------------------------------------------------


@dataclass(frozen=True)
class CampaignEvent:
    """One scheduled chaos action, keyed on the fleet's virtual clock."""
    at_s: float
    kind: str          # see CampaignRunner._apply for the dispatch table
    iid: int
    ranks: Tuple[int, ...] = ()
    severity: int = 6
    error_type: str = "hbm_ecc"
    slowdown: float = 1.0              # straggler slowdown ratio
    note: str = ""


def fleet_topology(router: FleetRouter) -> Dict[int, Dict]:
    """Snapshot the fleet's layout for schedule generation: per serving
    instance, its model and the physical ranks of each comm-domain group
    (the 'rack' granularity for correlated loss)."""
    topo: Dict[int, Dict] = {}
    for inst in router.serving():
        groups: Dict[str, List[int]] = {}
        for dev in inst.engine.domain.ranks:
            groups.setdefault(dev.role, []).append(dev.physical_id)
        topo[inst.iid] = {
            "model_id": inst.model_id,
            "groups": {g: sorted(p) for g, p in sorted(groups.items())},
        }
    return topo


class CampaignSchedule:
    """Seeded generator of composable fault processes.

    Each ``.proc(...)`` call layers one process onto the schedule; the
    composition order is part of the seed contract (same seed + same
    composition = same events).  ``build()`` returns the merged,
    time-sorted event list."""

    def __init__(self, seed: int, horizon_s: float):
        if horizon_s <= 0:
            raise ValueError(f"horizon_s must be > 0, got {horizon_s!r}")
        self.seed = seed
        self.horizon_s = horizon_s
        self.rng = np.random.default_rng(seed)
        self.events: List[CampaignEvent] = []

    # -- internals ---------------------------------------------------------------

    def _poisson_times(self, rate_per_s: float,
                       t0: float = 0.0) -> List[float]:
        times, t = [], t0
        while True:
            t += float(self.rng.exponential(1.0 / rate_per_s))
            if t >= self.horizon_s:
                return times
            times.append(t)

    def _pick(self, seq: Sequence):
        return seq[int(self.rng.integers(len(seq)))]

    # -- fault processes ---------------------------------------------------------

    def device_faults(self, topology: Dict[int, Dict], *,
                      rate_per_s: float,
                      severity: int = 6,
                      error_type: str = "hbm_ecc") -> "CampaignSchedule":
        """Background hazard: independent single-device faults across
        the fleet at ``rate_per_s`` (the paper's base failure process)."""
        iids = sorted(topology)
        for t in self._poisson_times(rate_per_s):
            iid = self._pick(iids)
            ranks = [p for g in topology[iid]["groups"].values()
                     for p in g]
            self.events.append(CampaignEvent(
                t, "device_fault", iid, ranks=(self._pick(ranks),),
                severity=severity, error_type=error_type,
                note="background hazard"))
        return self

    def rack_loss(self, topology: Dict[int, Dict], *,
                  rate_per_s: float) -> "CampaignSchedule":
        """Correlated loss: every rank sharing one comm-domain group of
        one instance faults at the same instant (power feed / ToR switch
        takes the whole rack)."""
        iids = sorted(topology)
        for t in self._poisson_times(rate_per_s):
            iid = self._pick(iids)
            group = self._pick(sorted(topology[iid]["groups"]))
            ranks = tuple(topology[iid]["groups"][group])
            self.events.append(CampaignEvent(
                t, "rack_loss", iid, ranks=ranks,
                note=f"rack={group}"))
        return self

    def cascading_stragglers(self, topology: Dict[int, Dict], *,
                             start_s: float, spacing_s: float,
                             n: int = 3, slowdown: float = 4.0,
                             duration_s: float = 5.0
                             ) -> "CampaignSchedule":
        """A slow device every ``spacing_s`` on successive instances —
        the creeping-degradation shape that only soft signals catch.
        Each straggler clears after ``duration_s``."""
        iids = sorted(topology)
        for k in range(n):
            t = start_s + k * spacing_s
            if t >= self.horizon_s:
                break
            iid = iids[k % len(iids)]
            ranks = [p for g in topology[iid]["groups"].values()
                     for p in g]
            rank = self._pick(ranks)
            self.events.append(CampaignEvent(
                t, "straggler", iid, ranks=(rank,), slowdown=slowdown,
                note=f"cascade {k + 1}/{n}"))
            self.events.append(CampaignEvent(
                min(t + duration_s, self.horizon_s), "straggler_clear",
                iid, ranks=(rank,), note=f"cascade {k + 1}/{n} over"))
        return self

    def flapping_link(self, topology: Dict[int, Dict], *,
                      start_s: float, n_flaps: int = 3,
                      down_s: float = 2.0,
                      up_s: float = 4.0) -> "CampaignSchedule":
        """One rank's link faults, clears, re-faults ``n_flaps`` times —
        the transient shape where the device should *rejoin* after each
        clear instead of staying isolated."""
        iids = sorted(topology)
        iid = self._pick(iids)
        ranks = [p for g in topology[iid]["groups"].values() for p in g]
        rank = self._pick(ranks)
        t = start_s
        for k in range(n_flaps):
            if t >= self.horizon_s:
                break
            self.events.append(CampaignEvent(
                t, "device_fault", iid, ranks=(rank,), severity=4,
                error_type="link_down", note=f"flap {k + 1}/{n_flaps}"))
            t_clear = min(t + down_s, self.horizon_s)
            self.events.append(CampaignEvent(
                t_clear, "fault_clear", iid, ranks=(rank,),
                note=f"flap {k + 1}/{n_flaps} cleared"))
            t = t_clear + up_s
        return self

    def spot_wave(self, topology: Dict[int, Dict], *,
                  at_s: float, n_instances: int = 1,
                  notice_s: float = 5.0) -> "CampaignSchedule":
        """Spot-preemption wave: ``n_instances`` whole hosts disappear at
        ``at_s``, each with ``notice_s`` of advance notice (the cloud's
        two-minute warning) — a *planned* fault the router should drain,
        not abort."""
        iids = sorted(topology)
        victims = list(self.rng.choice(
            iids, size=min(n_instances, len(iids)), replace=False))
        for iid in victims:
            t_notice = max(0.0, at_s - notice_s)
            self.events.append(CampaignEvent(
                t_notice, "spot_notice", int(iid),
                note=f"preemption at t={at_s:g}s"))
            self.events.append(CampaignEvent(
                at_s, "spot_preempt", int(iid), note="capacity lost"))
        return self

    def rolling_upgrade(self, topology: Dict[int, Dict], *,
                        start_s: float,
                        spacing_s: float) -> "CampaignSchedule":
        """Planned maintenance: every instance restarts once, one at a
        time, ``spacing_s`` apart — drain first, relaunch, rejoin."""
        for k, iid in enumerate(sorted(topology)):
            t = start_s + k * spacing_s
            if t >= self.horizon_s:
                break
            self.events.append(CampaignEvent(
                t, "upgrade", iid, note=f"rolling upgrade {k + 1}"))
        return self

    def instance_loss(self, topology: Dict[int, Dict], *,
                      rate_per_s: float) -> "CampaignSchedule":
        """Unplanned whole-host losses (kernel panic, fabric partition):
        rebuildable in place, but every in-flight request must re-home."""
        iids = sorted(topology)
        for t in self._poisson_times(rate_per_s):
            self.events.append(CampaignEvent(
                t, "instance_loss", self._pick(iids), note="host loss"))
        return self

    def build(self) -> List[CampaignEvent]:
        return sorted(self.events, key=lambda e: (e.at_s, e.iid, e.kind))


# -- SLO-burn scoring -------------------------------------------------------------


def _quantile(xs: List[float], q: float) -> float:
    """Nearest-rank-with-interpolation quantile (numpy-free of dtype
    surprises; deterministic)."""
    if not xs:
        return 0.0
    s = sorted(xs)
    pos = q * (len(s) - 1)
    lo = int(pos)
    hi = min(lo + 1, len(s) - 1)
    return s[lo] + (s[hi] - s[lo]) * (pos - lo)


def slo_burn(rows: List[Dict], *, ttft_target_s: float,
             tpot_target_s: Optional[float] = None,
             window_s: float = 10.0, q: float = 0.99,
             horizon_s: Optional[float] = None) -> Dict:
    """Integral of windowed p99 latency excess over target.

    ``rows`` come from :meth:`FleetRouter.slo_rows`.  Requests are
    bucketed by arrival into ``window_s`` windows; per window the p99
    TTFT (and TPOT, if targeted) is compared against target and the
    excess integrates as ``burn += max(0, p99 - target) * window_s``.
    A request that never produced a token (shed, or starved past the
    horizon) is censored at the horizon — it burns, maximally, instead
    of silently dropping out of the percentile."""
    if not rows:
        return {"ttft_burn_s": 0.0, "tpot_burn_s": 0.0,
                "total_burn_s": 0.0, "windows": [], "n_unserved": 0}
    end = horizon_s if horizon_s is not None else max(
        (r["finish_s"] or r["first_token_s"] or r["arrival_s"])
        for r in rows)
    end = max(end, max(r["arrival_s"] for r in rows) + 1e-9)
    n_win = max(1, int(np.ceil(end / window_s)))
    buckets: List[List[Dict]] = [[] for _ in range(n_win)]
    n_unserved = 0
    for r in rows:
        w = min(int(r["arrival_s"] / window_s), n_win - 1)
        buckets[w].append(r)
        if r["first_token_s"] is None:
            n_unserved += 1
    windows = []
    ttft_burn = tpot_burn = 0.0
    for w, bucket in enumerate(buckets):
        if not bucket:
            continue
        ttfts = [((r["first_token_s"] if r["first_token_s"] is not None
                   else end) - r["arrival_s"]) for r in bucket]
        p_ttft = _quantile(ttfts, q)
        w_ttft = max(0.0, p_ttft - ttft_target_s) * window_s
        ttft_burn += w_ttft
        row = {"window": w, "t0_s": round(w * window_s, 6),
               "n": len(bucket), "p99_ttft_s": round(p_ttft, 6),
               "ttft_burn_s": round(w_ttft, 6)}
        if tpot_target_s is not None:
            tpots = []
            for r in bucket:
                if (r["finish_s"] is not None
                        and r["first_token_s"] is not None
                        and r["n_out"] > 1):
                    tpots.append((r["finish_s"] - r["first_token_s"])
                                 / (r["n_out"] - 1))
            p_tpot = _quantile(tpots, q)
            w_tpot = max(0.0, p_tpot - tpot_target_s) * window_s
            tpot_burn += w_tpot
            row["p99_tpot_s"] = round(p_tpot, 6)
            row["tpot_burn_s"] = round(w_tpot, 6)
        windows.append(row)
    return {
        "ttft_burn_s": round(ttft_burn, 6),
        "tpot_burn_s": round(tpot_burn, 6),
        "total_burn_s": round(ttft_burn + tpot_burn, 6),
        "windows": windows,
        "n_unserved": n_unserved,
    }


# -- runner -----------------------------------------------------------------------


@dataclass
class CampaignResult:
    burn: Dict
    forensics: Dict
    events_applied: int = 0
    events_skipped: int = 0
    ticks: int = 0


_SEVERITIES = {s.value: s for s in Severity}
_ERROR_TYPES = {e.value: e for e in ErrorType}


class CampaignRunner:
    """Replays a built schedule against a live router on the virtual
    clock: each tick, every event whose time has come is applied, then
    the fleet steps.  When the fleet is idle but events remain, the
    clock fast-forwards to the next event (discrete-event semantics,
    same as the router's own idle fast-forward)."""

    def __init__(self, router: FleetRouter,
                 events: Sequence[CampaignEvent], *,
                 seed: Optional[int] = None,
                 profile: Optional[VirtualCostProfile] = None,
                 ttft_target_s: float = 1.0,
                 tpot_target_s: Optional[float] = None,
                 slo_window_s: float = 10.0,
                 max_ticks: int = 50000):
        self.router = router
        self.pending = sorted(events, key=lambda e: (e.at_s, e.iid,
                                                     e.kind))
        self.seed = seed
        self.profile = profile or router.cost_profile
        self.ttft_target_s = ttft_target_s
        self.tpot_target_s = tpot_target_s
        self.slo_window_s = slo_window_s
        self.max_ticks = max_ticks
        self.applied: List[Dict] = []
        self.skipped = 0

    # -- event application -------------------------------------------------------

    def _step_base_s(self) -> float:
        return self.profile.step_s if self.profile is not None else 0.05

    def _apply(self, ev: CampaignEvent) -> bool:
        r = self.router
        inst = r.instances.get(ev.iid)
        if inst is None or inst.state is InstanceState.DEAD:
            return False          # target already gone: the event is moot
        eng = inst.engine
        if ev.kind in ("device_fault", "rack_loss"):
            sev = _SEVERITIES.get(ev.severity, Severity.L6)
            err = _ERROR_TYPES.get(ev.error_type, ErrorType.HBM_ECC)
            for rank in ev.ranks:
                eng.injector.schedule(eng.step_no + 1, rank,
                                      severity=sev, error_type=err)
        elif ev.kind == "fault_clear":
            for rank in ev.ranks:
                eng.injector.clear(rank)
                eng.rejoin_device(rank)
        elif ev.kind == "straggler":
            extra = (ev.slowdown - 1.0) * self._step_base_s()
            for ex in eng.dp_executors:
                if ex.physical_id in ev.ranks and ex.alive:
                    ex.simulated_slowdown_s = extra
        elif ev.kind == "straggler_clear":
            for ex in eng.dp_executors:
                if ex.physical_id in ev.ranks:
                    ex.simulated_slowdown_s = 0.0
        elif ev.kind == "spot_notice":
            r.drain_instance(ev.iid, migrate=True,
                             reason="spot preemption notice")
        elif ev.kind == "spot_preempt":
            r.lose_instance(ev.iid, reason="spot preemption",
                            rebuild=False)
        elif ev.kind == "instance_loss":
            r.lose_instance(ev.iid, reason="host loss")
        elif ev.kind == "upgrade":
            r.planned_restart(ev.iid)
        else:
            raise ValueError(f"unknown campaign event kind {ev.kind!r}")
        return True

    # -- main loop ---------------------------------------------------------------

    def run(self) -> CampaignResult:
        r = self.router
        ticks = 0
        while ticks < self.max_ticks:
            while self.pending and self.pending[0].at_s <= r.now_s:
                ev = self.pending.pop(0)
                ok = self._apply(ev)
                if ok:
                    self.applied.append({
                        "at_s": round(ev.at_s, 6),
                        "fired_s": round(r.now_s, 6),
                        "kind": ev.kind, "iid": ev.iid,
                        "ranks": list(ev.ranks), "note": ev.note,
                    })
                else:
                    self.skipped += 1
            r.tick()
            ticks += 1
            drained = r.traffic is None or r.traffic.exhausted
            idle = drained and not r.unfinished and not r._frozen
            if idle:
                if not self.pending:
                    break
                # dead air before the next scheduled event: jump to it
                r.now_s = max(r.now_s, self.pending[0].at_s)
        burn = slo_burn(r.slo_rows(), ttft_target_s=self.ttft_target_s,
                        tpot_target_s=self.tpot_target_s,
                        window_s=self.slo_window_s)
        return CampaignResult(
            burn=burn, forensics=self.forensics(burn),
            events_applied=len(self.applied),
            events_skipped=self.skipped, ticks=ticks)

    # -- forensics ---------------------------------------------------------------

    def forensics(self, burn: Dict) -> Dict:
        """The failure-forensics document.  Every value is derived from
        the virtual clock / pinned cost profile, so with a profile the
        same campaign seed yields a byte-identical document."""
        r = self.router
        by_policy: Dict[str, int] = {}
        for e in r.forensics:
            by_policy[e["policy"]] = by_policy.get(e["policy"], 0) + 1
        health = r.fleet_health()
        return {
            "campaign": {
                "seed": self.seed,
                "profile": (dataclasses.asdict(self.profile)
                            if self.profile is not None else None),
                "ttft_target_s": self.ttft_target_s,
                "tpot_target_s": self.tpot_target_s,
                "slo_window_s": self.slo_window_s,
            },
            "events_applied": self.applied,
            "events_skipped": self.skipped,
            "recoveries": r.forensics,
            "recoveries_by_policy": dict(sorted(by_policy.items())),
            "slo": burn,
            "counters": {
                "requests": len(r.requests),
                "shed": r.shed_requests,
                "backlog_final": len(r.backlog),
                "cross_instance_migrations": sum(
                    req.cross_instance_migrations for req in r.requests),
                "spare_activations": (r.spares.activations
                                      if r.spares else 0),
            },
            "fleet_health_final": {
                "state": health.state,
                "serving": health.serving,
                "accepting": health.accepting,
                "backlog": health.backlog,
                "shed": health.shed,
                "spares_available": health.spares_available,
                "starved_models": health.starved_models,
            },
        }
