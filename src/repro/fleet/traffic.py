"""Open-loop traffic sources for the fleet router.

Open-loop means arrivals do not wait for the system: during a recovery
stall the arrival process keeps producing, the queue grows, and TTFT
degrades — which is exactly the client-visible cost the fleet benchmark
measures.  Closed-loop drivers (submit-on-completion) hide that cost.

Both sources yield :class:`Arrival` records against a caller-supplied
clock (wall seconds in benchmarks, synthetic seconds in tests), so runs
are reproducible given a seed.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np


@dataclass(frozen=True)
class Arrival:
    at_s: float                 # arrival time on the driver's clock
    prompt_tokens: Tuple[int, ...]
    max_new_tokens: int
    # multi-model fleets: which model config this request needs (None =
    # any instance may serve it)
    model_id: Optional[str] = None


class PoissonTraffic:
    """Memoryless open-loop arrivals at ``rate_per_s``, random prompts.

    ``prompt_len`` may be a single length or a sequence of choices (a
    mixed long/short workload — one is drawn per arrival).  With
    ``shared_prefix_len`` > 0, a fraction ``shared_fraction`` of
    arrivals start with one fixed random "system prompt" of that length
    — the prefix-cache-heavy production shape.

    ``length_dist="lognormal"`` replaces the fixed request shape with
    seeded heavy-tailed draws: the configured prompt length and
    ``max_new_tokens`` become the *medians* of lognormal distributions
    with log-space sigma ``length_sigma`` (prompt drawn first, then
    output, one pair per arrival), clamped to ``max_prompt_len`` /
    ``max_output_len`` when given.  Production traces are heavy-tailed
    — a few huge requests dominate queueing during recovery stalls —
    so campaigns should not score SLO burn against a uniform-shape
    fiction.  The default path (``length_dist=None``) makes exactly the
    same rng draws as before, so existing seeded traces replay
    unchanged."""

    def __init__(self, rate_per_s: float, vocab_size: int, *,
                 prompt_len=8, max_new_tokens: int = 16,
                 seed: int = 0, limit: Optional[int] = None,
                 shared_prefix_len: int = 0,
                 shared_fraction: float = 0.0,
                 length_dist: Optional[str] = None,
                 length_sigma: float = 0.75,
                 max_prompt_len: Optional[int] = None,
                 max_output_len: Optional[int] = None,
                 model_id: Optional[str] = None):
        if rate_per_s <= 0:
            raise ValueError(f"rate_per_s must be > 0, got {rate_per_s!r}")
        if length_dist not in (None, "lognormal"):
            raise ValueError(
                f"length_dist must be None or 'lognormal', got "
                f"{length_dist!r}")
        if length_sigma <= 0:
            raise ValueError(
                f"length_sigma must be > 0, got {length_sigma!r}")
        self.rate = rate_per_s
        self.rng = np.random.default_rng(seed)
        self.vocab_size = vocab_size
        self.prompt_lens = (tuple(prompt_len)
                            if isinstance(prompt_len, (tuple, list))
                            else (int(prompt_len),))
        self.max_new_tokens = max_new_tokens
        self.length_dist = length_dist
        self.length_sigma = length_sigma
        self.max_prompt_len = max_prompt_len
        self.max_output_len = max_output_len
        self.limit = limit
        self.model_id = model_id
        self.shared_fraction = shared_fraction
        self.shared_prefix = tuple(
            int(t) for t in self.rng.integers(0, vocab_size,
                                              shared_prefix_len))
        self._next_at = self._gap(0.0)
        self._emitted = 0

    def _gap(self, now_s: float) -> float:
        """Seconds until the next arrival after ``now_s`` (subclasses
        modulate the rate here)."""
        return now_s + float(self.rng.exponential(1.0 / self.rate))

    def _heavy_len(self, median: int, cap: Optional[int]) -> int:
        """One lognormal draw with the given median (exp(mu) = median),
        at least 1, clamped to ``cap`` when set."""
        n = int(round(median * float(
            np.exp(self.length_sigma * self.rng.standard_normal()))))
        n = max(1, n)
        return min(n, cap) if cap is not None else n

    def _prompt(self) -> Tuple[int, ...]:
        n = int(self.rng.choice(self.prompt_lens))
        if self.length_dist:
            n = self._heavy_len(n, self.max_prompt_len)
        if (self.shared_prefix
                and self.rng.random() < self.shared_fraction):
            # the drawn length is honored: short shared arrivals are a
            # truncation of the system prompt (the repeated-short-query
            # shape), long ones append a random user tail
            if n <= len(self.shared_prefix):
                return self.shared_prefix[:max(n, 1)]
            tail = n - len(self.shared_prefix)
            return self.shared_prefix + tuple(int(t) for t in
                                              self.rng.integers(
                                                  0, self.vocab_size, tail))
        return tuple(int(t) for t in self.rng.integers(
            0, self.vocab_size, n))

    def due(self, now_s: float) -> List[Arrival]:
        """All arrivals with at_s <= now_s that were not yet emitted."""
        out: List[Arrival] = []
        while self._next_at <= now_s and (
                self.limit is None or self._emitted < self.limit):
            prompt = self._prompt()        # drawn before the output len
            mnt = (self._heavy_len(self.max_new_tokens,
                                   self.max_output_len)
                   if self.length_dist else self.max_new_tokens)
            out.append(Arrival(self._next_at, prompt, mnt,
                               model_id=self.model_id))
            self._emitted += 1
            self._next_at = self._gap(self._next_at)
        return out

    @property
    def exhausted(self) -> bool:
        return self.limit is not None and self._emitted >= self.limit

    @property
    def next_at(self) -> Optional[float]:
        """Arrival time of the next pending request (None if exhausted)."""
        return None if self.exhausted else self._next_at


class DiurnalTraffic(PoissonTraffic):
    """Nonhomogeneous Poisson arrivals with a sinusoidal daily cycle.

    rate(t) = base · (1 + amplitude · sin(2πt / period_s + phase)) — the
    long diurnal trace chaos campaigns run against, so fault processes
    land on peaks and troughs rather than one constant load.  Sampled by
    thinning against the peak rate: candidate gaps are drawn at
    base·(1+amplitude) and accepted with probability rate(t)/peak, which
    keeps the arrival stream an exact seeded function of the clock.
    """

    def __init__(self, base_rate_per_s: float, vocab_size: int, *,
                 amplitude: float = 0.5, period_s: float = 60.0,
                 phase: float = 0.0, **kw):
        if not 0.0 <= amplitude < 1.0:
            raise ValueError(
                f"amplitude must be in [0, 1), got {amplitude!r}")
        self.base_rate = base_rate_per_s
        self.amplitude = amplitude
        self.period_s = period_s
        self.phase = phase
        super().__init__(base_rate_per_s, vocab_size, **kw)

    def rate_at(self, t_s: float) -> float:
        return self.base_rate * (1.0 + self.amplitude * float(
            np.sin(2.0 * np.pi * t_s / self.period_s + self.phase)))

    def _gap(self, now_s: float) -> float:
        peak = self.base_rate * (1.0 + self.amplitude)
        t = now_s
        while True:                      # Lewis–Shedler thinning
            t += float(self.rng.exponential(1.0 / peak))
            if self.rng.random() <= self.rate_at(t) / peak:
                return t


class MixedTraffic:
    """Merge several arrival sources into one stream (multi-model
    fleets: each model's traffic keeps its own seed/rate/shape, the
    router sees one time-ordered arrival sequence)."""

    def __init__(self, sources: Sequence):
        if not sources:
            raise ValueError("MixedTraffic needs at least one source")
        self.sources = list(sources)

    def due(self, now_s: float) -> List[Arrival]:
        out: List[Arrival] = []
        for src in self.sources:
            out.extend(src.due(now_s))
        return sorted(out, key=lambda a: a.at_s)

    @property
    def exhausted(self) -> bool:
        return all(s.exhausted for s in self.sources)

    @property
    def next_at(self) -> Optional[float]:
        nxt = [s.next_at for s in self.sources if s.next_at is not None]
        return min(nxt) if nxt else None


class TraceTraffic:
    """Replay an explicit arrival trace (deterministic tests/benchmarks)."""

    def __init__(self, arrivals: Sequence[Arrival]):
        self._pending = sorted(arrivals, key=lambda a: a.at_s)

    def due(self, now_s: float) -> List[Arrival]:
        out = []
        while self._pending and self._pending[0].at_s <= now_s:
            out.append(self._pending.pop(0))
        return out

    @property
    def exhausted(self) -> bool:
        return not self._pending

    @property
    def next_at(self):
        return self._pending[0].at_s if self._pending else None
