"""Open-loop traffic sources for the fleet router.

Open-loop means arrivals do not wait for the system: during a recovery
stall the arrival process keeps producing, the queue grows, and TTFT
degrades — which is exactly the client-visible cost the fleet benchmark
measures.  Closed-loop drivers (submit-on-completion) hide that cost.

Both sources yield :class:`Arrival` records against a caller-supplied
clock (wall seconds in benchmarks, synthetic seconds in tests), so runs
are reproducible given a seed.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np


@dataclass(frozen=True)
class Arrival:
    at_s: float                 # arrival time on the driver's clock
    prompt_tokens: Tuple[int, ...]
    max_new_tokens: int


class PoissonTraffic:
    """Memoryless open-loop arrivals at ``rate_per_s``, random prompts.

    ``prompt_len`` may be a single length or a sequence of choices (a
    mixed long/short workload — one is drawn per arrival).  With
    ``shared_prefix_len`` > 0, a fraction ``shared_fraction`` of
    arrivals start with one fixed random "system prompt" of that length
    — the prefix-cache-heavy production shape."""

    def __init__(self, rate_per_s: float, vocab_size: int, *,
                 prompt_len=8, max_new_tokens: int = 16,
                 seed: int = 0, limit: Optional[int] = None,
                 shared_prefix_len: int = 0,
                 shared_fraction: float = 0.0):
        if rate_per_s <= 0:
            raise ValueError(f"rate_per_s must be > 0, got {rate_per_s!r}")
        self.rate = rate_per_s
        self.rng = np.random.default_rng(seed)
        self.vocab_size = vocab_size
        self.prompt_lens = (tuple(prompt_len)
                            if isinstance(prompt_len, (tuple, list))
                            else (int(prompt_len),))
        self.max_new_tokens = max_new_tokens
        self.limit = limit
        self.shared_fraction = shared_fraction
        self.shared_prefix = tuple(
            int(t) for t in self.rng.integers(0, vocab_size,
                                              shared_prefix_len))
        self._next_at = float(self.rng.exponential(1.0 / self.rate))
        self._emitted = 0

    def _prompt(self) -> Tuple[int, ...]:
        n = int(self.rng.choice(self.prompt_lens))
        if (self.shared_prefix
                and self.rng.random() < self.shared_fraction):
            # the drawn length is honored: short shared arrivals are a
            # truncation of the system prompt (the repeated-short-query
            # shape), long ones append a random user tail
            if n <= len(self.shared_prefix):
                return self.shared_prefix[:max(n, 1)]
            tail = n - len(self.shared_prefix)
            return self.shared_prefix + tuple(int(t) for t in
                                              self.rng.integers(
                                                  0, self.vocab_size, tail))
        return tuple(int(t) for t in self.rng.integers(
            0, self.vocab_size, n))

    def due(self, now_s: float) -> List[Arrival]:
        """All arrivals with at_s <= now_s that were not yet emitted."""
        out: List[Arrival] = []
        while self._next_at <= now_s and (
                self.limit is None or self._emitted < self.limit):
            out.append(Arrival(self._next_at, self._prompt(),
                               self.max_new_tokens))
            self._emitted += 1
            self._next_at += float(self.rng.exponential(1.0 / self.rate))
        return out

    @property
    def exhausted(self) -> bool:
        return self.limit is not None and self._emitted >= self.limit

    @property
    def next_at(self) -> Optional[float]:
        """Arrival time of the next pending request (None if exhausted)."""
        return None if self.exhausted else self._next_at


class TraceTraffic:
    """Replay an explicit arrival trace (deterministic tests/benchmarks)."""

    def __init__(self, arrivals: Sequence[Arrival]):
        self._pending = sorted(arrivals, key=lambda a: a.at_s)

    def due(self, now_s: float) -> List[Arrival]:
        out = []
        while self._pending and self._pending[0].at_s <= now_s:
            out.append(self._pending.pop(0))
        return out

    @property
    def exhausted(self) -> bool:
        return not self._pending

    @property
    def next_at(self):
        return self._pending[0].at_s if self._pending else None
