"""Cluster router: N engine instances, continuous admission, arbitration.

The router is the gateway: it owns the authoritative record of every
request (prompt + tokens streamed back so far), routes new arrivals to
the least-loaded serving instance, and executes the
:class:`~repro.fleet.arbiter.RecoveryArbiter`'s per-fault decisions —
in-place revive, drain-and-restart, or spare substitution with live
request migration.

Virtual clock
=============
Everything runs in one process, so a naive wall clock would charge one
instance's restart stall to the whole fleet.  Instead the fleet advances
a *virtual clock*: each tick, all available instances step once
(lockstep, as a real fleet would concurrently) and the clock advances by
the longest measured step.  Recovery stalls are converted into
per-instance *freezes* — measured wall seconds during which only that
instance skips ticks — which is exactly the semantics of a real fleet
where the wounded instance is unavailable while its peers keep serving.
TTFT/goodput are therefore measured on a clock where revive, restart and
spare substitution penalize only the instance that pays them.

Virtual *costs* (chaos campaigns): with a ``cost_profile`` the clock
stops measuring wall time and instead charges pinned per-action costs
(step, revive, restart, spare swap + per-token/per-block migration
terms).  Recovery mechanics still really execute — revive revives,
spares substitute, requests migrate token-exactly — but every duration
fed to the clock, the cost model and the forensics log is a pure
function of the campaign seed, which is what makes campaign forensics
byte-reproducible.

Degradation: when a fault burst leaves a model with no serving instance
(spares dry, hosts gone), arrivals queue in a bounded router backlog
with backpressure instead of being routed to a dead instance, and
:meth:`fleet_health` surfaces a ``degraded``/``critical`` state until
capacity returns (spare joins, host rebuild, or evict-and-rebalance of
an instance serving another model).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

from repro.fleet.arbiter import ArbiterDecision, CostModel, RecoveryArbiter
from repro.fleet.instance import FleetInstance, InstanceState
from repro.fleet.spares import SparePool
from repro.serving.request import Request, RequestState

_MIN_TICK_S = 1e-4


@dataclass
class FleetHealth:
    """Fleet-level health surface (the per-instance analogue is
    :class:`~repro.serving.engine.InstanceHealth`)."""
    state: str                   # 'healthy' | 'degraded' | 'critical'
    serving: int                 # serving-or-draining instances
    accepting: int               # instances taking new admissions
    backlog: int                 # arrivals queued at the gateway
    shed: int                    # arrivals rejected by backpressure
    spares_available: int
    frozen: int                  # instances currently paying a stall
    starved_models: List[str] = field(default_factory=list)
    # models with zero accepting instances (requests for them backlog)


class FleetRouter:
    # prefix-affinity knobs: each arrival is keyed under a ladder of
    # leading-prefix lengths (longest match wins on lookup), so prompts
    # sharing a system prefix shorter than the longest key — but
    # diverging after it — still map to a common entry.  The matched
    # arrival sticks to the instance that served the prefix last (its
    # executors hold the shared blocks in their prefix caches), unless
    # that instance is more than AFFINITY_SLACK requests busier than the
    # least-loaded one — cache hits must not create hotspots
    AFFINITY_LENS = (32, 16, 8)
    AFFINITY_SLACK = 4
    _AFFINITY_MAP_MAX = 4096

    def __init__(self, instances: List[FleetInstance], *,
                 spares: Optional[SparePool] = None,
                 arbiter: Optional[RecoveryArbiter] = None,
                 traffic=None, kv_stream: bool = True,
                 prefix_affinity: bool = False,
                 cost_profile=None,
                 rebuilders: Optional[Dict[str, Callable[[int],
                                           FleetInstance]]] = None,
                 max_backlog: int = 256):
        """``kv_stream=False`` forces the token-replay re-prefill path on
        every migration (the verified fallback — used by the fleet_slo
        prefix sweep to measure what streaming saves).
        ``prefix_affinity=True`` routes arrivals with a recently seen
        prompt prefix back to the same instance, so shared-prefix cache
        hits land where the blocks live.
        ``cost_profile`` (a :class:`~repro.fleet.chaos.VirtualCostProfile`
        or anything with its attributes) switches the clock to pinned
        virtual costs — the campaign determinism mode.
        ``rebuilders`` maps model_id -> factory(iid) for evict-and-
        rebalance: when a model loses its last instance, the router may
        repurpose an instance of another model through its factory."""
        if not instances:
            raise ValueError("FleetRouter needs at least one instance")
        from collections import OrderedDict
        self.kv_stream = kv_stream
        self.prefix_affinity = prefix_affinity
        self.cost_profile = cost_profile
        self.rebuilders = rebuilders or {}
        self.max_backlog = max_backlog
        # prefix key -> iid, LRU-bounded: one-off random prefixes age
        # out individually without evicting the hot shared entries
        self._affinity: "OrderedDict" = OrderedDict()
        self.instances: Dict[int, FleetInstance] = {
            i.iid: i for i in instances}
        if len(self.instances) != len(instances):
            raise ValueError("duplicate instance ids")
        self.spares = spares
        self.arbiter = arbiter or RecoveryArbiter(
            CostModel(instances[0].engine.init_timings))
        self.traffic = traffic
        self.now_s = 0.0
        self.ticks = 0
        self.requests: List[Request] = []        # gateway record
        self.meta: Dict[int, Dict] = {}          # req_id -> virtual times
        self.log: List[str] = []
        # failure forensics: one structured entry per executed recovery /
        # planned action, with the decision's counterfactual cost table
        self.forensics: List[Dict] = []
        self.backlog: List[Request] = []         # no-capacity queue
        self.shed_requests = 0                   # backpressure rejections
        self._frozen: Dict[int, float] = {}      # iid -> stall seconds left
        self._cost_events: Dict[str, int] = {}   # policy -> events charged
        self._pending: Dict[int, List[ArbiterDecision]] = {}
        self._report_seen: Dict[int, int] = {}
        self._last_dec: Dict[int, ArbiterDecision] = {}
        self._next_rebuilt_iid = 2000            # evict-and-rebalance ids
        for inst in instances:
            self._enroll(inst)

    # -- membership --------------------------------------------------------------

    def _enroll(self, inst: FleetInstance) -> None:
        self.instances[inst.iid] = inst
        self._report_seen.setdefault(inst.iid, len(inst.engine.reports))
        inst.set_arbitration(self._arbitrate)
        if self.cost_profile is not None:
            inst.engine.virtual_step_s = self.cost_profile.step_s

    def _spare_available(self, model_id: Optional[str] = None) -> bool:
        return (self.spares is not None
                and self.spares.available_for(model_id) > 0)

    def serving(self) -> List[FleetInstance]:
        return [i for i in self.instances.values()
                if i.state in (InstanceState.SERVING,
                               InstanceState.DRAINING)]

    def available(self, inst: FleetInstance) -> bool:
        return (inst.state in (InstanceState.SERVING,
                               InstanceState.DRAINING)
                and self._frozen.get(inst.iid, 0.0) <= 0.0)

    # -- metrics helpers ---------------------------------------------------------

    def _charge_cost(self, policy: str, wall_s: float, *,
                     tokens: int = 0, blocks: int = 0) -> float:
        """Stall seconds to put on the virtual clock for one recovery
        action: the measured wall cost, or the pinned profile cost in
        campaign (deterministic) mode — per-event lognormal-jittered
        when the profile asks for dispersion (still a pure function of
        the profile seed and this action's per-kind sequence number)."""
        p = self.cost_profile
        if p is None:
            return wall_s
        if policy == "revive":
            base = p.revive_s
        elif policy == "restart":
            base = p.restart_s
        else:
            base = (p.spare_swap_s + tokens * p.per_token_prefill_s
                    + blocks * p.per_block_stream_s)
        idx = self._cost_events.get(policy, 0)
        self._cost_events[policy] = idx + 1
        event_cost = getattr(p, "event_cost", None)
        if event_cost is None:          # bare profile (tests use stubs)
            return base
        return event_cost(policy, idx, base)

    def _record(self, inst: FleetInstance, policy: str, charged_s: float,
                *, dec: Optional[ArbiterDecision] = None,
                planned: bool = False, detail: str = "") -> None:
        ev = {
            "seq": len(self.forensics),
            "tick": self.ticks,
            "now_s": round(self.now_s, 6),
            "iid": inst.iid,
            "model_id": inst.model_id,
            "policy": policy,
            "charged_s": round(charged_s, 6),
            "planned": planned,
        }
        if dec is not None:
            ev["decision"] = {
                "policy": dec.policy,
                "reason": dec.reason,
                "proactive": dec.proactive,
                "est_cost_s": {k: round(v, 6)
                               for k, v in sorted(dec.est_cost.items())},
            }
            # counterfactuals: what the untaken actions were priced at
            ev["counterfactual_s"] = {
                k: round(v, 6) for k, v in sorted(dec.est_cost.items())
                if k != policy}
        if detail:
            ev["detail"] = detail
        self.forensics.append(ev)

    # -- admission ----------------------------------------------------------------

    def submit(self, prompt_tokens, max_new_tokens: int = 16, *,
               eos_token=None, arrival_s: Optional[float] = None,
               model_id: Optional[str] = None) -> Request:
        at = self.now_s if arrival_s is None else arrival_s
        targets = [i for i in self.instances.values()
                   if i.accepting and i.serves(model_id)
                   and self._frozen.get(i.iid, 0.0) <= 0.0]
        if not targets:
            # every matching instance stalled/draining: park on the
            # least-loaded serving-or-draining one; it will catch up
            # when unfrozen
            targets = [i for i in self.serving() if i.serves(model_id)]
        if not targets:
            # no serving instance for this model at all: queue at the
            # gateway (degraded) instead of routing to a dead instance
            return self._backlog_submit(prompt_tokens, max_new_tokens,
                                        eos_token, at, model_id)
        inst = self._route(targets, prompt_tokens)
        req = inst.submit(prompt_tokens, max_new_tokens,
                          eos_token=eos_token)
        req.model_id = model_id
        self.requests.append(req)
        self.meta[req.req_id] = {
            "arrival_s": at,
            "first_token_s": None, "finish_s": None,
            "instances": [inst.iid],
        }
        return req

    def _backlog_submit(self, prompt_tokens, max_new_tokens, eos_token,
                        arrival_s: float,
                        model_id: Optional[str]) -> Request:
        req = Request(list(prompt_tokens), max_new_tokens,
                      eos_token=eos_token)
        req.model_id = model_id
        if len(self.backlog) >= self.max_backlog:
            # backpressure: beyond the bound we shed instead of growing
            # an unbounded queue (the client sees an admission error)
            req.state = RequestState.FAILED
            self.shed_requests += 1
            self.requests.append(req)
            self.meta[req.req_id] = {
                "arrival_s": arrival_s, "first_token_s": None,
                "finish_s": None, "instances": [], "shed": True,
            }
            return req
        self.backlog.append(req)
        self.requests.append(req)
        self.meta[req.req_id] = {
            "arrival_s": arrival_s, "first_token_s": None,
            "finish_s": None, "instances": [],
        }
        self.log.append(
            f"[router] no serving instance for "
            f"model={req.model_id or 'any'}: request {req.req_id} "
            f"queued at gateway ({len(self.backlog)} waiting)")
        return req

    def _admit_backlog(self) -> None:
        if not self.backlog:
            return
        still: List[Request] = []
        for req in self.backlog:
            targets = [i for i in self.instances.values()
                       if i.accepting and i.serves(req.model_id)
                       and self._frozen.get(i.iid, 0.0) <= 0.0]
            if not targets:
                still.append(req)
                continue
            inst = self._route(targets, req.prompt_tokens)
            inst.admit(req)
            self.meta[req.req_id]["instances"].append(inst.iid)
        self.backlog = still

    def _route(self, targets: List[FleetInstance],
               prompt_tokens) -> FleetInstance:
        """Least-loaded admission, biased toward prefix affinity: a
        prompt whose leading tokens were recently served by a still-
        available instance goes back there (its BlockManagers hold the
        shared-prefix blocks), unless that instance is overloaded."""
        least = min(targets, key=lambda i: i.load)
        if not self.prefix_affinity:
            return least
        keys = []
        for n in self.AFFINITY_LENS:
            k = tuple(prompt_tokens[:n])
            if k not in keys:                    # short prompts collapse
                keys.append(k)
        hit = None
        for k in keys:                           # longest match wins
            hit = self._affinity.get(k)
            if hit is not None:
                break
        chosen = least
        if hit is not None:
            for inst in targets:
                if (inst.iid == hit
                        and inst.load <= least.load + self.AFFINITY_SLACK):
                    chosen = inst
                    break
        for k in keys:
            while len(self._affinity) >= self._AFFINITY_MAP_MAX:
                self._affinity.popitem(last=False)   # evict LRU keys only
            self._affinity[k] = chosen.iid
            self._affinity.move_to_end(k)
        return chosen

    def _pump(self) -> None:
        if self.traffic is None:
            return
        if self.unfinished == 0 and not self._frozen:
            # fleet idle: discrete-event fast-forward to the next arrival
            # (idle ticks otherwise advance the clock by ~nothing)
            nxt = self.traffic.next_at
            if nxt is not None and nxt > self.now_s:
                self.now_s = nxt
        for a in self.traffic.due(self.now_s):
            self.submit(list(a.prompt_tokens), a.max_new_tokens,
                        arrival_s=a.at_s, model_id=a.model_id)

    # -- arbitration callbacks ------------------------------------------------------

    def _arbitrate(self, inst: FleetInstance, event) -> str:
        dec = self.arbiter.decide(
            inst, event,
            spare_available=self._spare_available(inst.model_id))
        self.log.append(dec.summary())
        self._last_dec[inst.iid] = dec
        if dec.policy == "revive":
            return "revive"
        self._pending.setdefault(inst.iid, []).append(dec)
        return dec.policy

    def lose_instance(self, iid: int, reason: str = "host loss", *,
                      rebuild: bool = True) -> None:
        """Full-instance loss: every device at once.  Revive is off the
        table; the arbiter picks spare substitution or rebuild — either
        way the gateway re-homes the in-flight requests immediately.
        ``rebuild=False`` models capacity that is *gone* (spot
        preemption): no in-place host rebuild — the fleet runs short
        until a spare joins or evict-and-rebalance repurposes another
        model's instance."""
        inst = self.instances[iid]
        if inst.state is InstanceState.DEAD:
            return                        # concurrent loss: already down
        inst.fail_instance(reason)
        dec = self.arbiter.decide(
            inst, None, instance_lost=True,
            spare_available=self._spare_available(inst.model_id))
        self.log.append(dec.summary())
        self._last_dec[inst.iid] = dec
        if dec.policy == "spare":
            self._substitute(inst, reason)
            return
        reqs = inst.export_requests()
        survivors = {i.iid: i for i in self.serving()
                     if i.iid != iid and i.serves(inst.model_id)}
        if survivors:
            from repro.core.migration import plan_migration
            loads = {i.iid: i.load for i in survivors.values()}
            for r, target_iid in plan_migration(reqs, loads):
                survivors[target_iid].admit(r)
                self.meta[r.req_id]["instances"].append(target_iid)
            self.log.append(
                f"[router] re-homed {len(reqs)} requests off lost "
                f"instance {iid}")
            if rebuild:
                elapsed = self._restart_and_charge(inst, dec=dec,
                                                   detail=reason)
                del elapsed
            else:
                inst.decommission(reason)
                self._record(inst, "abandon", 0.0, dec=dec,
                             detail=f"{reason}: capacity lost")
                self._rebalance(inst.model_id)
        elif rebuild:
            # last instance standing for this model: requests must wait
            # out the rebuild
            self._restart_and_charge(inst, dec=dec, detail=reason)
            for r in reqs:
                inst.admit(r)
                self.meta[r.req_id]["instances"].append(inst.iid)
        else:
            # capacity gone and nowhere to re-home: queue the refugees at
            # the gateway; health turns degraded until capacity returns
            inst.decommission(reason)
            for r in reqs:
                r.state = RequestState.WAITING
                self.backlog.append(r)
            self._record(inst, "abandon", 0.0, dec=dec,
                         detail=f"{reason}: {len(reqs)} requests queued")
            self.log.append(
                f"[router] instance {iid} gone ({reason}); "
                f"{len(reqs)} requests queued at gateway")
            self._rebalance(inst.model_id)

    def _restart_and_charge(self, inst: FleetInstance, *,
                            dec: Optional[ArbiterDecision],
                            detail: str = "",
                            planned: bool = False) -> float:
        wall = inst.restart()
        charged = self._charge_cost("restart", wall)
        self.arbiter.cost.observe_restart(charged)
        self._freeze(inst, charged)
        self._record(inst, "restart", charged, dec=dec, planned=planned,
                     detail=detail)
        return charged

    # -- planned faults (advance notice) ----------------------------------------------

    def drain_instance(self, iid: int, *, migrate: bool = True,
                       reason: str = "planned drain") -> int:
        """Advance-notice drain: stop routing new work here and (by
        default) migrate the residents to same-model peers NOW, KV
        blocks streamed — so a planned fault (spot preemption notice,
        rolling upgrade) hits an empty instance instead of aborting
        in-flight work.  Returns how many requests moved."""
        inst = self.instances[iid]
        if inst.state is InstanceState.SERVING:
            inst.state = InstanceState.DRAINING
        if not migrate:
            return 0
        peers = [i for i in self.serving()
                 if i.iid != iid and i.serves(inst.model_id)
                 and i.accepting]
        if not peers:
            self.log.append(
                f"[router] drain {iid}: no peers — residents finish "
                f"in place before the deadline")
            return 0
        exported = inst.export_requests(with_kv=self.kv_stream)
        if not self.kv_stream:
            exported = [(r, None) for r in exported]
        moved = 0
        for r, kv in exported:
            target = min(peers, key=lambda i: i.load)
            target.admit(r, kv=kv)
            self.meta[r.req_id]["instances"].append(target.iid)
            moved += 1
        self._record(inst, "drain", 0.0, planned=True,
                     detail=f"{reason}: {moved} requests migrated ahead "
                            f"of the fault")
        self.log.append(
            f"[router] drained instance {iid} ({reason}): {moved} "
            f"requests migrated with advance notice")
        return moved

    def planned_restart(self, iid: int,
                        reason: str = "rolling upgrade") -> None:
        """A rolling-upgrade step: drain with notice, relaunch, rejoin.
        The stall is paid by an (ideally empty) instance while peers
        absorb its traffic — the cheapest possible 'fault'."""
        self.drain_instance(iid, migrate=True, reason=reason)
        inst = self.instances[iid]
        self._restart_and_charge(inst, dec=None, detail=reason,
                                 planned=True)

    # -- capacity repair ---------------------------------------------------------------

    def _rebalance(self, model_id: str) -> bool:
        """Evict-and-rebalance: ``model_id`` has no serving instance
        left, so repurpose the least-loaded instance of an over-
        provisioned model (>= 2 serving) through the model's rebuilder
        factory.  The donor's residents re-home to its peers first."""
        if model_id not in self.rebuilders:
            return False
        if any(i.serves(model_id) for i in self.serving()):
            return False
        by_model: Dict[str, List[FleetInstance]] = {}
        for i in self.serving():
            if i.state is InstanceState.SERVING:
                by_model.setdefault(i.model_id, []).append(i)
        donors = [i for m, ins in by_model.items()
                  for i in ins if m != model_id and len(ins) >= 2]
        if not donors:
            return False
        donor = min(donors, key=lambda i: i.load)
        peers = [i for i in self.serving()
                 if i.iid != donor.iid and i.serves(donor.model_id)]
        exported = donor.export_requests(with_kv=self.kv_stream)
        if not self.kv_stream:
            exported = [(r, None) for r in exported]
        for r, kv in exported:
            target = min(peers, key=lambda i: i.load)
            target.admit(r, kv=kv)
            self.meta[r.req_id]["instances"].append(target.iid)
        donor.decommission(f"evicted: rebalanced to model {model_id}")
        t0 = time.perf_counter()
        fresh = self.rebuilders[model_id](self._next_rebuilt_iid)
        self._next_rebuilt_iid += 1
        wall = time.perf_counter() - t0
        fresh.state = InstanceState.SERVING
        self._enroll(fresh)
        charged = self._charge_cost("restart", wall)
        self._freeze(fresh, charged)
        self._record(fresh, "rebalance", charged, planned=True,
                     detail=f"evicted instance {donor.iid} "
                            f"(model {donor.model_id}) -> "
                            f"model {model_id}")
        self.log.append(
            f"[router] evict-and-rebalance: instance {donor.iid} "
            f"(model {donor.model_id}, {len(exported)} requests "
            f"re-homed) replaced by instance {fresh.iid} serving "
            f"model {model_id}")
        return True

    def _restore_capacity(self) -> None:
        """A model with queued work and zero accepting instances takes
        the next matching warm spare directly — capacity restoration,
        not fault substitution."""
        if self.spares is None or not self.backlog:
            return
        starved = {r.model_id for r in self.backlog
                   if not any(i.accepting and i.serves(r.model_id)
                              for i in self.instances.values())}
        for model_id in sorted(starved, key=lambda m: m or ""):
            spare = self.spares.acquire(model_id)
            if spare is None:
                continue
            self._enroll(spare)
            self._record(spare, "spare-join", 0.0,
                         detail=f"capacity restored for model "
                                f"{model_id or 'any'}")
            self.log.append(
                f"[router] spare {spare.iid} joined: restores capacity "
                f"for model {model_id or 'any'}")

    # -- policy execution -----------------------------------------------------------

    def _freeze(self, inst: FleetInstance, stall_s: float) -> None:
        self._frozen[inst.iid] = self._frozen.get(inst.iid, 0.0) + stall_s
        self.log.append(f"[router] instance {inst.iid} unavailable "
                        f"{stall_s * 1e3:.0f}ms (virtual)")

    def _substitute(self, inst: FleetInstance, reason: str) -> None:
        spare = (self.spares.acquire(inst.model_id)
                 if self.spares else None)
        if spare is None:                      # pool dry: degrade to restart
            self._restart_and_charge(inst, dec=self._last_dec.get(inst.iid),
                                     detail=f"{reason} (spare pool dry)")
            return
        t0 = time.perf_counter()
        # standby sync (FailSafe): every request whose executor is still
        # reachable streams its live KV blocks to the spare — takeover
        # cost is a block copy, flat in prefix length; the rest (on the
        # failed device, or still queued) re-prefill from token replay
        exported = inst.export_requests(with_kv=self.kv_stream)
        if not self.kv_stream:
            exported = [(r, None) for r in exported]
        streamed_tokens = replay_tokens = streamed_blocks = 0
        for r, kv in exported:
            spare.admit(r, kv=kv)
            self.meta[r.req_id]["instances"].append(spare.iid)
            # the install is all-or-nothing: a streamed request is RUNNING
            # on arrival, a fallback-to-replay one re-enters WAITING — so
            # the cost feedback reflects what actually happened
            if kv is not None and r.state is RequestState.RUNNING:
                streamed_tokens += kv.tokens_streamed
                streamed_blocks += kv.num_blocks
            else:
                replay_tokens += r.num_tokens
        wall = time.perf_counter() - t0
        charged = self._charge_cost("spare", wall, tokens=replay_tokens,
                                    blocks=streamed_blocks)
        self.arbiter.cost.observe_spare(charged, replay_tokens,
                                        streamed_blocks)
        self._freeze(spare, charged)
        inst.decommission(reason)
        self._enroll(spare)
        self._record(spare, "spare", charged,
                     dec=self._last_dec.get(inst.iid),
                     detail=f"substituted for {inst.iid}: "
                            f"{streamed_blocks} blocks streamed, "
                            f"{replay_tokens} tokens replayed")
        self.log.append(
            f"[router] spare {spare.iid} substituted for {inst.iid} "
            f"({len(exported)} requests: {streamed_tokens} tokens / "
            f"{streamed_blocks} blocks KV-streamed, {replay_tokens} "
            f"tokens to re-prefill, swap {charged * 1e3:.1f}ms)")

    def _execute(self, inst: FleetInstance, dec: ArbiterDecision) -> None:
        if dec.policy == "restart":
            self._restart_and_charge(inst, dec=dec)
        elif dec.policy == "spare":
            self._substitute(
                inst, dec.reason if dec.proactive else "fault: substituted")
        else:
            raise ValueError(f"unexpected deferred policy {dec.policy!r}")

    # -- main loop -------------------------------------------------------------------

    def tick(self) -> List[Request]:
        """One fleet step: admit due traffic, step every available
        instance in lockstep, execute deferred recovery decisions, and
        advance the virtual clock by the longest measured step."""
        self.ticks += 1
        self._pump()
        self._restore_capacity()
        self._admit_backlog()
        finished: List[Request] = []
        step_durs = [0.0]
        for inst in list(self.instances.values()):
            if not self.available(inst):
                continue
            pre = self._report_seen.get(inst.iid, 0)
            t0 = time.perf_counter()
            finished.extend(inst.step())
            dt = time.perf_counter() - t0
            # inline revive stalls charge the instance, not the fleet
            revive_s = 0.0
            reports = inst.engine.reports
            for rep in reports[pre:]:
                if rep.scenario == "benign":
                    continue
                charged = self._charge_cost("revive", rep.total_s)
                if self.cost_profile is None:
                    self.arbiter.cost.observe_revive(rep.cost_inputs())
                else:
                    self.arbiter.cost.observe_revive({"total_s": charged})
                revive_s += charged
                self._record(inst, "revive", charged,
                             dec=self._last_dec.get(inst.iid),
                             detail=rep.scenario)
            self._report_seen[inst.iid] = len(reports)
            if revive_s > 0.0:
                self._freeze(inst, revive_s)
                self.log.append(
                    f"[router] instance {inst.iid} revived in place "
                    f"({revive_s * 1e3:.0f}ms)")
            if self.cost_profile is not None:
                dt = (self.cost_profile.step_s
                      if inst.engine.unfinished else _MIN_TICK_S)
                step_durs.append(dt)
            else:
                step_durs.append(max(0.0, dt - revive_s))
            for dec in self._pending.pop(inst.iid, []):
                self._execute(inst, dec)
        for inst in self.serving():
            if not self.available(inst):
                continue
            dec = self.arbiter.consider_soft(
                inst, spare_available=self._spare_available(inst.model_id))
            if dec is not None:
                self.log.append(dec.summary())
                self._last_dec[inst.iid] = dec
                if dec.policy == "spare":
                    self._substitute(inst, "straggler: substituted")
        # background capacity repair: rebuild at most one consumed
        # standby per tick.  Provisioning happens on a fresh host, off
        # the serving path — it consumes wall time here (we are one
        # process) but no *virtual* time: serving instances are unfrozen
        # and the clock advances by their step durations only.
        if self.spares is not None:
            built = self.spares.maybe_replenish()
            if built is not None:
                self.log.append(
                    f"[router] spare pool replenished: instance "
                    f"{built.iid} warm "
                    f"({self.spares.available}/{self.spares.target_size})")
        inc = max(max(step_durs), _MIN_TICK_S)
        # discrete-event fast-forward: if every available instance is
        # idle but work is parked behind a freeze (e.g. a restarting
        # instance's queue), jump to the earliest unfreeze — wall time
        # passes while a host rebuilds, even when nothing else computes
        if self._frozen:
            idle = all(i.engine.unfinished == 0
                       for i in self.instances.values()
                       if self.available(i))
            if idle:
                jump = min(self._frozen.values())
                if self.traffic is not None \
                        and not self.traffic.exhausted:
                    jump = min(jump, max(
                        self.traffic.next_at - self.now_s, 0.0))
                inc = max(inc, jump)
        self.now_s += inc
        for iid in list(self._frozen):
            self._frozen[iid] -= inc
            if self._frozen[iid] <= 0.0:
                del self._frozen[iid]
        self._note_progress()
        return finished

    def _note_progress(self) -> None:
        for r in self.requests:
            m = self.meta[r.req_id]
            if m["first_token_s"] is None and r.output_tokens:
                m["first_token_s"] = self.now_s
            if m["finish_s"] is None and r.state is RequestState.FINISHED:
                m["finish_s"] = self.now_s

    @property
    def unfinished(self) -> int:
        return sum(1 for r in self.requests
                   if r.state not in (RequestState.FINISHED,
                                      RequestState.FAILED))

    def run(self, max_ticks: int = 2000) -> List[Request]:
        """Tick until the traffic source is exhausted and every request
        finished (or max_ticks)."""
        done: List[Request] = []
        for _ in range(max_ticks):
            done.extend(self.tick())
            drained = self.traffic is None or self.traffic.exhausted
            if drained and not self.unfinished:
                break
        return done

    # -- metrics ---------------------------------------------------------------------

    def ttfts(self) -> List[float]:
        return [m["first_token_s"] - m["arrival_s"]
                for m in self.meta.values()
                if m["first_token_s"] is not None]

    def slo_rows(self) -> List[Dict]:
        """Per-request rows for the SLO-burn scorer: arrival / first
        token / finish on the virtual clock, plus decoded-token count."""
        rows = []
        n_out = {r.req_id: len(r.output_tokens) for r in self.requests}
        for req_id, m in self.meta.items():
            rows.append({
                "arrival_s": m["arrival_s"],
                "first_token_s": m["first_token_s"],
                "finish_s": m["finish_s"],
                "n_out": n_out.get(req_id, 0),
            })
        return rows

    def fleet_health(self) -> FleetHealth:
        serving = self.serving()
        accepting = [i for i in self.instances.values() if i.accepting]
        models = {i.model_id for i in self.instances.values()}
        models |= {r.model_id for r in self.backlog
                   if r.model_id is not None}
        starved = sorted(
            m for m in models if m is not None
            and not any(i.accepting and i.serves(m)
                        for i in self.instances.values()))
        if not serving:
            state = "critical"
        elif (self.backlog or starved
              or any(self._frozen.get(i.iid, 0.0) > 0.0 for i in serving)
              # a revived instance serving with masked experts or a DP
              # rank down is degraded capacity, not healthy capacity —
              # the serving front end surfaces this distinction
              or any(i.health().degraded for i in serving)):
            state = "degraded"
        else:
            state = "healthy"
        return FleetHealth(
            state=state, serving=len(serving), accepting=len(accepting),
            backlog=len(self.backlog), shed=self.shed_requests,
            spares_available=(self.spares.available
                              if self.spares else 0),
            frozen=sum(1 for v in self._frozen.values() if v > 0.0),
            starved_models=starved)
