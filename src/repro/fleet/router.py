"""Cluster router: N engine instances, continuous admission, arbitration.

The router is the gateway: it owns the authoritative record of every
request (prompt + tokens streamed back so far), routes new arrivals to
the least-loaded serving instance, and executes the
:class:`~repro.fleet.arbiter.RecoveryArbiter`'s per-fault decisions —
in-place revive, drain-and-restart, or spare substitution with live
request migration.

Virtual clock
=============
Everything runs in one process, so a naive wall clock would charge one
instance's restart stall to the whole fleet.  Instead the fleet advances
a *virtual clock*: each tick, all available instances step once
(lockstep, as a real fleet would concurrently) and the clock advances by
the longest measured step.  Recovery stalls are converted into
per-instance *freezes* — measured wall seconds during which only that
instance skips ticks — which is exactly the semantics of a real fleet
where the wounded instance is unavailable while its peers keep serving.
TTFT/goodput are therefore measured on a clock where revive, restart and
spare substitution penalize only the instance that pays them.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional

from repro.fleet.arbiter import ArbiterDecision, CostModel, RecoveryArbiter
from repro.fleet.instance import FleetInstance, InstanceState
from repro.fleet.spares import SparePool
from repro.serving.request import Request, RequestState

_MIN_TICK_S = 1e-4


class FleetRouter:
    # prefix-affinity knobs: each arrival is keyed under a ladder of
    # leading-prefix lengths (longest match wins on lookup), so prompts
    # sharing a system prefix shorter than the longest key — but
    # diverging after it — still map to a common entry.  The matched
    # arrival sticks to the instance that served the prefix last (its
    # executors hold the shared blocks in their prefix caches), unless
    # that instance is more than AFFINITY_SLACK requests busier than the
    # least-loaded one — cache hits must not create hotspots
    AFFINITY_LENS = (32, 16, 8)
    AFFINITY_SLACK = 4
    _AFFINITY_MAP_MAX = 4096

    def __init__(self, instances: List[FleetInstance], *,
                 spares: Optional[SparePool] = None,
                 arbiter: Optional[RecoveryArbiter] = None,
                 traffic=None, kv_stream: bool = True,
                 prefix_affinity: bool = False):
        """``kv_stream=False`` forces the token-replay re-prefill path on
        every migration (the verified fallback — used by the fleet_slo
        prefix sweep to measure what streaming saves).
        ``prefix_affinity=True`` routes arrivals with a recently seen
        prompt prefix back to the same instance, so shared-prefix cache
        hits land where the blocks live."""
        if not instances:
            raise ValueError("FleetRouter needs at least one instance")
        from collections import OrderedDict
        self.kv_stream = kv_stream
        self.prefix_affinity = prefix_affinity
        # prefix key -> iid, LRU-bounded: one-off random prefixes age
        # out individually without evicting the hot shared entries
        self._affinity: "OrderedDict" = OrderedDict()
        self.instances: Dict[int, FleetInstance] = {
            i.iid: i for i in instances}
        if len(self.instances) != len(instances):
            raise ValueError("duplicate instance ids")
        self.spares = spares
        self.arbiter = arbiter or RecoveryArbiter(
            CostModel(instances[0].engine.init_timings))
        self.traffic = traffic
        self.now_s = 0.0
        self.ticks = 0
        self.requests: List[Request] = []        # gateway record
        self.meta: Dict[int, Dict] = {}          # req_id -> virtual times
        self.log: List[str] = []
        self._frozen: Dict[int, float] = {}      # iid -> stall seconds left
        self._pending: Dict[int, List[ArbiterDecision]] = {}
        self._report_seen: Dict[int, int] = {}
        for inst in instances:
            self._enroll(inst)

    # -- membership --------------------------------------------------------------

    def _enroll(self, inst: FleetInstance) -> None:
        self.instances[inst.iid] = inst
        self._report_seen.setdefault(inst.iid, len(inst.engine.reports))
        inst.set_arbitration(self._arbitrate)

    def _spare_available(self) -> bool:
        return self.spares is not None and self.spares.available > 0

    def serving(self) -> List[FleetInstance]:
        return [i for i in self.instances.values()
                if i.state in (InstanceState.SERVING,
                               InstanceState.DRAINING)]

    def available(self, inst: FleetInstance) -> bool:
        return (inst.state in (InstanceState.SERVING,
                               InstanceState.DRAINING)
                and self._frozen.get(inst.iid, 0.0) <= 0.0)

    # -- admission ----------------------------------------------------------------

    def submit(self, prompt_tokens, max_new_tokens: int = 16, *,
               eos_token=None, arrival_s: Optional[float] = None
               ) -> Request:
        targets = [i for i in self.instances.values()
                   if i.accepting and self._frozen.get(i.iid, 0.0) <= 0.0]
        if not targets:
            # every instance stalled/draining: park on the least-loaded
            # serving-or-draining one; it will catch up when unfrozen
            targets = self.serving()
        if not targets:
            raise RuntimeError("fleet has no serving instances left")
        inst = self._route(targets, prompt_tokens)
        req = inst.submit(prompt_tokens, max_new_tokens,
                          eos_token=eos_token)
        self.requests.append(req)
        self.meta[req.req_id] = {
            "arrival_s": self.now_s if arrival_s is None else arrival_s,
            "first_token_s": None, "finish_s": None,
            "instances": [inst.iid],
        }
        return req

    def _route(self, targets: List[FleetInstance],
               prompt_tokens) -> FleetInstance:
        """Least-loaded admission, biased toward prefix affinity: a
        prompt whose leading tokens were recently served by a still-
        available instance goes back there (its BlockManagers hold the
        shared-prefix blocks), unless that instance is overloaded."""
        least = min(targets, key=lambda i: i.load)
        if not self.prefix_affinity:
            return least
        keys = []
        for n in self.AFFINITY_LENS:
            k = tuple(prompt_tokens[:n])
            if k not in keys:                    # short prompts collapse
                keys.append(k)
        hit = None
        for k in keys:                           # longest match wins
            hit = self._affinity.get(k)
            if hit is not None:
                break
        chosen = least
        if hit is not None:
            for inst in targets:
                if (inst.iid == hit
                        and inst.load <= least.load + self.AFFINITY_SLACK):
                    chosen = inst
                    break
        for k in keys:
            while len(self._affinity) >= self._AFFINITY_MAP_MAX:
                self._affinity.popitem(last=False)   # evict LRU keys only
            self._affinity[k] = chosen.iid
            self._affinity.move_to_end(k)
        return chosen

    def _pump(self) -> None:
        if self.traffic is None:
            return
        if self.unfinished == 0 and not self._frozen:
            # fleet idle: discrete-event fast-forward to the next arrival
            # (idle ticks otherwise advance the clock by ~nothing)
            nxt = self.traffic.next_at
            if nxt is not None and nxt > self.now_s:
                self.now_s = nxt
        for a in self.traffic.due(self.now_s):
            self.submit(list(a.prompt_tokens), a.max_new_tokens,
                        arrival_s=a.at_s)

    # -- arbitration callbacks ------------------------------------------------------

    def _arbitrate(self, inst: FleetInstance, event) -> str:
        dec = self.arbiter.decide(inst, event,
                                  spare_available=self._spare_available())
        self.log.append(dec.summary())
        if dec.policy == "revive":
            return "revive"
        self._pending.setdefault(inst.iid, []).append(dec)
        return dec.policy

    def lose_instance(self, iid: int, reason: str = "host loss") -> None:
        """Full-instance loss: every device at once.  Revive is off the
        table; the arbiter picks spare substitution or rebuild — either
        way the gateway re-homes the in-flight requests immediately."""
        inst = self.instances[iid]
        inst.fail_instance(reason)
        dec = self.arbiter.decide(inst, None, instance_lost=True,
                                  spare_available=self._spare_available())
        self.log.append(dec.summary())
        if dec.policy == "spare":
            self._substitute(inst, reason)
            return
        # no spare (or forced restart): re-home requests onto survivors,
        # rebuild the host off the serving path, rejoin when done
        reqs = inst.export_requests()
        survivors = {i.iid: i for i in self.serving() if i.iid != iid}
        if survivors:
            from repro.core.migration import plan_migration
            loads = {i.iid: i.load for i in survivors.values()}
            for r, target_iid in plan_migration(reqs, loads):
                survivors[target_iid].admit(r)
                self.meta[r.req_id]["instances"].append(target_iid)
            self.log.append(
                f"[router] re-homed {len(reqs)} requests off lost "
                f"instance {iid}")
            elapsed = inst.restart()
            self.arbiter.cost.observe_restart(elapsed)
            self._freeze(inst, elapsed)
        else:
            # last instance standing: requests must wait out the rebuild
            elapsed = inst.restart()
            self.arbiter.cost.observe_restart(elapsed)
            self._freeze(inst, elapsed)
            for r in reqs:
                inst.admit(r)
                self.meta[r.req_id]["instances"].append(inst.iid)

    # -- policy execution -----------------------------------------------------------

    def _freeze(self, inst: FleetInstance, stall_s: float) -> None:
        self._frozen[inst.iid] = self._frozen.get(inst.iid, 0.0) + stall_s
        self.log.append(f"[router] instance {inst.iid} unavailable "
                        f"{stall_s * 1e3:.0f}ms (virtual)")

    def _substitute(self, inst: FleetInstance, reason: str) -> None:
        spare = self.spares.acquire() if self.spares else None
        if spare is None:                      # pool dry: degrade to restart
            elapsed = inst.restart()
            self.arbiter.cost.observe_restart(elapsed)
            self._freeze(inst, elapsed)
            return
        t0 = time.perf_counter()
        # standby sync (FailSafe): every request whose executor is still
        # reachable streams its live KV blocks to the spare — takeover
        # cost is a block copy, flat in prefix length; the rest (on the
        # failed device, or still queued) re-prefill from token replay
        exported = inst.export_requests(with_kv=self.kv_stream)
        if not self.kv_stream:
            exported = [(r, None) for r in exported]
        streamed_tokens = replay_tokens = streamed_blocks = 0
        for r, kv in exported:
            spare.admit(r, kv=kv)
            self.meta[r.req_id]["instances"].append(spare.iid)
            # the install is all-or-nothing: a streamed request is RUNNING
            # on arrival, a fallback-to-replay one re-enters WAITING — so
            # the cost feedback reflects what actually happened
            if kv is not None and r.state is RequestState.RUNNING:
                streamed_tokens += kv.tokens_streamed
                streamed_blocks += kv.num_blocks
            else:
                replay_tokens += r.num_tokens
        swap_s = time.perf_counter() - t0
        self.arbiter.cost.observe_spare(swap_s, replay_tokens,
                                        streamed_blocks)
        inst.decommission(reason)
        self._enroll(spare)
        self.log.append(
            f"[router] spare {spare.iid} substituted for {inst.iid} "
            f"({len(exported)} requests: {streamed_tokens} tokens / "
            f"{streamed_blocks} blocks KV-streamed, {replay_tokens} "
            f"tokens to re-prefill, swap {swap_s * 1e3:.1f}ms)")

    def _execute(self, inst: FleetInstance, dec: ArbiterDecision) -> None:
        if dec.policy == "restart":
            elapsed = inst.restart()
            self.arbiter.cost.observe_restart(elapsed)
            self._freeze(inst, elapsed)
        elif dec.policy == "spare":
            self._substitute(
                inst, dec.reason if dec.proactive else "fault: substituted")
        else:
            raise ValueError(f"unexpected deferred policy {dec.policy!r}")

    # -- main loop -------------------------------------------------------------------

    def tick(self) -> List[Request]:
        """One fleet step: admit due traffic, step every available
        instance in lockstep, execute deferred recovery decisions, and
        advance the virtual clock by the longest measured step."""
        self.ticks += 1
        self._pump()
        finished: List[Request] = []
        step_durs = [0.0]
        for inst in list(self.instances.values()):
            if not self.available(inst):
                continue
            pre = self._report_seen.get(inst.iid, 0)
            t0 = time.perf_counter()
            finished.extend(inst.step())
            dt = time.perf_counter() - t0
            # inline revive stalls charge the instance, not the fleet
            revive_s = 0.0
            reports = inst.engine.reports
            for rep in reports[pre:]:
                if rep.scenario == "benign":
                    continue
                self.arbiter.cost.observe_revive(rep.cost_inputs())
                revive_s += rep.total_s
            self._report_seen[inst.iid] = len(reports)
            if revive_s > 0.0:
                self._freeze(inst, revive_s)
                self.log.append(
                    f"[router] instance {inst.iid} revived in place "
                    f"({revive_s * 1e3:.0f}ms)")
            step_durs.append(max(0.0, dt - revive_s))
            for dec in self._pending.pop(inst.iid, []):
                self._execute(inst, dec)
        for inst in self.serving():
            if not self.available(inst):
                continue
            dec = self.arbiter.consider_soft(
                inst, spare_available=self._spare_available())
            if dec is not None:
                self.log.append(dec.summary())
                if dec.policy == "spare":
                    self._substitute(inst, "straggler: substituted")
        # background capacity repair: rebuild at most one consumed
        # standby per tick.  Provisioning happens on a fresh host, off
        # the serving path — it consumes wall time here (we are one
        # process) but no *virtual* time: serving instances are unfrozen
        # and the clock advances by their step durations only.
        if self.spares is not None:
            built = self.spares.maybe_replenish()
            if built is not None:
                self.log.append(
                    f"[router] spare pool replenished: instance "
                    f"{built.iid} warm "
                    f"({self.spares.available}/{self.spares.target_size})")
        inc = max(max(step_durs), _MIN_TICK_S)
        # discrete-event fast-forward: if every available instance is
        # idle but work is parked behind a freeze (e.g. a restarting
        # instance's queue), jump to the earliest unfreeze — wall time
        # passes while a host rebuilds, even when nothing else computes
        if self._frozen:
            idle = all(i.engine.unfinished == 0
                       for i in self.instances.values()
                       if self.available(i))
            if idle:
                jump = min(self._frozen.values())
                if self.traffic is not None \
                        and not self.traffic.exhausted:
                    jump = min(jump, max(
                        self.traffic.next_at - self.now_s, 0.0))
                inc = max(inc, jump)
        self.now_s += inc
        for iid in list(self._frozen):
            self._frozen[iid] -= inc
            if self._frozen[iid] <= 0.0:
                del self._frozen[iid]
        self._note_progress()
        return finished

    def _note_progress(self) -> None:
        for r in self.requests:
            m = self.meta[r.req_id]
            if m["first_token_s"] is None and r.output_tokens:
                m["first_token_s"] = self.now_s
            if m["finish_s"] is None and r.state is RequestState.FINISHED:
                m["finish_s"] = self.now_s

    @property
    def unfinished(self) -> int:
        return sum(1 for r in self.requests
                   if r.state not in (RequestState.FINISHED,
                                      RequestState.FAILED))

    def run(self, max_ticks: int = 2000) -> List[Request]:
        """Tick until the traffic source is exhausted and every request
        finished (or max_ticks)."""
        done: List[Request] = []
        for _ in range(max_ticks):
            done.extend(self.tick())
            drained = self.traffic is None or self.traffic.exhausted
            if drained and not self.unfinished:
                break
        return done

    # -- metrics ---------------------------------------------------------------------

    def ttfts(self) -> List[float]:
        return [m["first_token_s"] - m["arrival_s"]
                for m in self.meta.values()
                if m["first_token_s"] is not None]
