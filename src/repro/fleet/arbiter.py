"""Restart-vs-revive-vs-spare arbitration.

Per fault, the fleet has three ways out, each with a different
client-visible cost profile:

* **revive**  — ReviveMoE in-place recovery: the instance stalls for the
  (short, mostly precompiled) revive pipeline, then resumes with all its
  KV/scheduler state intact.
* **restart** — drain-and-restart: the instance stalls for a full
  relaunch (engine + executors + weights + groups + compile-from-cache);
  everything in flight waits out the stall, then re-prefills locally.
* **spare**   — substitution: in-flight requests migrate to a pre-warmed
  standby with prompt + generated-prefix re-prefill; the wounded
  instance leaves the serving set.  Costs a spare.

The :class:`CostModel` turns these into comparable numbers — expected
stall seconds × requests affected — and is *measurement-fed*: estimates
are seeded from the instance's own build timings, then replaced by the
running mean of what each policy actually cost when it ran (revive from
``RecoveryReport.cost_inputs()``, restart/spare from wall-clock).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.core.fault_codes import FaultEvent
from repro.fleet.instance import FleetInstance, InstanceState

POLICIES = ("revive", "restart", "spare")


class _RunningMean:
    def __init__(self, seed_value: float):
        self.value = seed_value
        self.n = 0          # observations (seed excluded)

    def observe(self, x: float) -> None:
        self.n += 1
        if self.n == 1:
            self.value = x          # first measurement replaces the seed
        else:
            self.value += (x - self.value) / self.n


class CostModel:
    """Per-policy stall estimates (seconds), measurement-fed.

    Spare substitution is priced on its *actual* migration mechanics:
    requests whose executors are still reachable stream their KV blocks
    (O(bytes) copy, per-block rate), the rest re-prefill from token
    replay (per-token rate) — so the estimate stays flat in prefix
    length exactly when the streamed path is available.

    Revive is priced on stall *and quality*: when the failed rank's
    experts have no surviving replica, revive serves with them masked
    until a (background) role switch restores the weights — degraded
    answers are a real client cost, converted to stall-equivalent
    seconds via ``degraded_quality_weight_s`` (the stall a client would
    trade for full-quality service of one request, scaled by the masked
    fraction).
    """

    def __init__(self, init_timings: Dict[str, float], *,
                 per_token_prefill_s: float = 2e-4,
                 per_block_stream_s: float = 2e-5,
                 degraded_quality_weight_s: float = 1.0,
                 spare_opportunity_cost_s: Optional[float] = None):
        restart_seed = sum(init_timings.values()) or 1.0
        # revive skips engine/executor/weight re-init; it pays rollback +
        # comm rebuild + a (pre)cached graph lookup.  Until measured, use
        # the build's comm + cache-read share as the seed.
        revive_seed = (init_timings.get("xccl", 0.0)
                       + init_timings.get("distributed_groups", 0.0)
                       + init_timings.get("read_cache", 0.0)) or \
            0.05 * restart_seed
        self.revive = _RunningMean(revive_seed)
        self.restart = _RunningMean(restart_seed)
        # spare substitution: the swap itself is a routing-table update;
        # migrated state arrives by KV-block stream (per block) or by
        # re-prefill of the replayed tokens (per token)
        self.per_token_prefill_s = per_token_prefill_s
        self.per_block_stream_s = per_block_stream_s
        self.degraded_quality_weight_s = degraded_quality_weight_s
        self.spare_swap = _RunningMean(0.0)
        # consuming a standby is not free even if the swap is fast: the
        # fleet loses a spare until a replacement is built.  Expressed in
        # stall-seconds so it competes in the same currency; defaults to
        # half the (measured) restart cost — the replenish build happens
        # off the serving path, hence the discount.
        self._spare_opportunity_cost_s = spare_opportunity_cost_s

    # -- estimates ---------------------------------------------------------------

    @property
    def spare_opportunity_cost_s(self) -> float:
        if self._spare_opportunity_cost_s is not None:
            return self._spare_opportunity_cost_s
        return 0.5 * self.restart.value

    def est_revive_s(self) -> float:
        return self.revive.value

    def est_restart_s(self) -> float:
        return self.restart.value

    def est_spare_s(self, tokens_to_reprefill: int,
                    blocks_to_stream: int = 0) -> float:
        return (self.spare_swap.value
                + tokens_to_reprefill * self.per_token_prefill_s
                + blocks_to_stream * self.per_block_stream_s)

    def quality_cost_s(self, masked_fraction: float) -> float:
        """Stall-equivalent price of serving one request with a fraction
        of the experts masked (0.0 when redundancy covers the fault)."""
        return masked_fraction * self.degraded_quality_weight_s

    # -- measurement feedback ----------------------------------------------------

    def observe_revive(self, cost_inputs: Dict[str, float]) -> None:
        self.revive.observe(cost_inputs["total_s"])

    def observe_restart(self, elapsed_s: float) -> None:
        self.restart.observe(elapsed_s)

    def observe_spare(self, swap_s: float, tokens: int,
                      streamed_blocks: int = 0) -> None:
        self.spare_swap.observe(max(0.0, swap_s
                                    - tokens * self.per_token_prefill_s
                                    - streamed_blocks
                                    * self.per_block_stream_s))


@dataclass
class ArbiterDecision:
    policy: str                       # 'revive' | 'restart' | 'spare'
    instance_id: int
    event: Optional[FaultEvent]
    est_cost: Dict[str, float] = field(default_factory=dict)
    reason: str = ""
    proactive: bool = False           # soft-signal (straggler) triggered

    def summary(self) -> str:
        costs = ", ".join(f"{k}={v * 1e3:.0f}ms"
                          for k, v in sorted(self.est_cost.items()))
        tag = "proactive " if self.proactive else ""
        return (f"[arbiter] {tag}instance {self.instance_id}: "
                f"{self.policy.upper()} ({self.reason}) :: {costs}")


class RecoveryArbiter:
    def __init__(self, cost_model: CostModel, *,
                 force_policy: Optional[str] = None,
                 soft_patience: int = 1):
        # soft_patience counts fleet ticks of sustained suspicion; it
        # must stay below the StragglerDetector's hard patience (2 engine
        # steps) or the hard L4 fault always wins the race and the
        # proactive path never fires
        if force_policy is not None and force_policy not in POLICIES:
            raise ValueError(
                f"force_policy must be one of {POLICIES} or None, "
                f"got {force_policy!r}")
        self.cost = cost_model
        self.force_policy = force_policy
        self.soft_patience = soft_patience
        self.decisions: List[ArbiterDecision] = []
        self._soft_streak: Dict[int, int] = {}

    # -- hard faults -------------------------------------------------------------

    def decide(self, inst: FleetInstance, event: Optional[FaultEvent], *,
               spare_available: bool,
               instance_lost: bool = False) -> ArbiterDecision:
        n_inflight = max(1, inst.load)
        tokens = sum(r.num_tokens for r in inst.engine.all_requests
                     if r.state.value not in ("finished", "failed"))
        # spare substitution streams KV blocks off still-reachable
        # executors and replays only the rest; a lost instance streams
        # nothing (device memory is gone with the host)
        split = getattr(inst.engine, "streamable_split", None)
        if split is not None and not instance_lost:
            stream_tokens, replay_tokens = split()
        else:
            stream_tokens, replay_tokens = 0, tokens
        block_size = getattr(getattr(inst.engine, "ecfg", None),
                             "block_size", 16)
        stream_blocks = -(-stream_tokens // block_size)
        # revive may have to serve with the fault's experts masked —
        # price that quality loss, not just the stall
        mask_frac = 0.0
        predict = getattr(inst.engine, "predict_masked_fraction", None)
        if predict is not None and event is not None and not instance_lost:
            mask_frac = predict(event.rank)
        est = {
            "revive": (self.cost.est_revive_s()
                       + self.cost.quality_cost_s(mask_frac)) * n_inflight,
            "restart": self.cost.est_restart_s() * n_inflight,
            "spare": (self.cost.est_spare_s(replay_tokens, stream_blocks)
                      * n_inflight
                      + self.cost.spare_opportunity_cost_s),
        }
        feasible = dict(est)
        reason = None
        if instance_lost:
            # nothing on the host can run the revive pipeline
            feasible.pop("revive", None)
            reason = "instance lost: in-place revive impossible"
        if not spare_available:
            feasible.pop("spare", None)
        if self.force_policy is not None:
            if self.force_policy in feasible:
                policy = self.force_policy
                reason = f"forced policy ({self.force_policy})"
            else:
                # deterministic fallback: a forced policy that cannot run
                # (revive on a lost host, spare with a dry pool) degrades
                # to drain-and-restart — always feasible — so "X-only"
                # baseline fleets are well-defined under every fault
                policy = "restart"
                reason = (f"forced policy ({self.force_policy}) "
                          f"infeasible: fell back to restart")
        else:
            policy = min(feasible, key=lambda k: feasible[k])
            if reason is None:
                reason = (f"min expected stall over {n_inflight} "
                          f"in-flight requests")
                if mask_frac > 0.0:
                    reason += (f"; revive priced with {mask_frac:.0%} "
                               f"experts masked")
        dec = ArbiterDecision(policy=policy, instance_id=inst.iid,
                              event=event, est_cost=est, reason=reason)
        self.decisions.append(dec)
        return dec

    # -- soft signals (stragglers) -----------------------------------------------

    def consider_soft(self, inst: FleetInstance,
                      spare_available: bool) -> Optional[ArbiterDecision]:
        """A straggling device throttles every collective step without
        ever raising a fault code.  Persistent suspicion (>= patience
        consecutive ticks) triggers a proactive decision: substitute a
        spare if one is warm, otherwise drain new traffic away."""
        signals = inst.health().soft_signals
        if not signals:
            self._soft_streak[inst.iid] = 0
            if inst.state is InstanceState.DRAINING:
                inst.state = InstanceState.SERVING   # suspicion cleared
            return None
        streak = self._soft_streak.get(inst.iid, 0) + 1
        self._soft_streak[inst.iid] = streak
        if streak < self.soft_patience:
            return None
        worst = max(signals.values())
        if spare_available:
            dec = ArbiterDecision(
                policy="spare", instance_id=inst.iid, event=None,
                est_cost={"slowdown_ratio": worst}, proactive=True,
                reason=f"straggler x{worst:.1f} for {streak} ticks")
            self.decisions.append(dec)
            self._soft_streak[inst.iid] = 0
            return dec
        if inst.state is InstanceState.SERVING:
            inst.state = InstanceState.DRAINING
            dec = ArbiterDecision(
                policy="restart", instance_id=inst.iid, event=None,
                est_cost={"slowdown_ratio": worst}, proactive=True,
                reason=f"straggler x{worst:.1f}, no spare: draining")
            self.decisions.append(dec)
            return dec
        return None
