"""One fleet member: an ``InferenceEngine`` plus control-plane lifecycle.

The wrapper owns what the engine cannot know about itself: its identity
in the fleet, whether the router may send it traffic, and the hook that
lets the :class:`~repro.fleet.arbiter.RecoveryArbiter` take a fault away
from the engine's in-place revive pipeline.

On a *full-instance loss* (host gone, every device inaccessible) the
engine cannot run at all — but request state survives: the router is the
gateway, and a gateway already holds every prompt plus the tokens it
streamed back.  Re-admitting those requests elsewhere with prompt +
generated-prefix re-prefill is therefore always possible; the in-process
``Request`` objects double as that gateway record.
"""
from __future__ import annotations

import enum
import time
from typing import Callable, List, Optional

from repro.serving.engine import InferenceEngine, InstanceHealth
from repro.serving.request import Request


class InstanceState(enum.Enum):
    SPARE = "spare"          # pre-warmed, not taking traffic
    SERVING = "serving"
    DRAINING = "draining"    # finishing residents, no new admissions
    RESTARTING = "restarting"
    DEAD = "dead"


class FleetInstance:
    def __init__(self, iid: int, engine: InferenceEngine,
                 state: InstanceState = InstanceState.SERVING,
                 model_id: str = "default"):
        self.iid = iid
        self.engine = engine
        self.state = state
        # multi-model fleets: which model config this instance serves;
        # the router only routes/migrates matching requests here
        self.model_id = model_id
        self.restarts = 0
        self.decommission_reason: Optional[str] = None

    def __repr__(self):
        return (f"FleetInstance(iid={self.iid}, {self.state.value}, "
                f"model={self.model_id}, "
                f"load={self.load if self.state != InstanceState.DEAD else '-'})")

    def serves(self, model_id: Optional[str]) -> bool:
        """Can this instance serve a request tagged ``model_id``?
        (None = untagged request, any instance will do.)"""
        return model_id is None or self.model_id == model_id

    # -- routing surface --------------------------------------------------------

    @property
    def accepting(self) -> bool:
        return self.state is InstanceState.SERVING

    @property
    def load(self) -> int:
        return self.engine.unfinished

    def health(self) -> InstanceHealth:
        return self.engine.health()

    def submit(self, prompt_tokens, max_new_tokens: int = 16,
               eos_token=None) -> Request:
        req = self.engine.submit(list(prompt_tokens), max_new_tokens,
                                 eos_token=eos_token)
        req.instance_id = self.iid
        return req

    def admit(self, req: Request, kv=None) -> Request:
        """Cross-instance admission of a migrated request; a KVBlocks
        payload streams the live prefix in (no re-prefill on arrival)."""
        if req.instance_id is not None and req.instance_id != self.iid:
            req.cross_instance_migrations += 1
        req.instance_id = self.iid
        return self.engine.admit(req, kv=kv)

    # -- arbitration hook --------------------------------------------------------

    def set_arbitration(self, decide: Callable) -> None:
        """``decide(instance, event) -> 'revive' | 'restart' | 'spare'``.
        Anything but 'revive' defers the fault to the fleet tick."""
        self.engine.fault_interceptor = lambda ev: decide(self, ev)

    # -- lifecycle ---------------------------------------------------------------

    def step(self) -> List[Request]:
        if self.state in (InstanceState.DEAD, InstanceState.SPARE,
                          InstanceState.RESTARTING):
            return []
        return self.engine.step()

    def export_requests(self, with_kv: bool = False):
        """Drain every unfinished request; ``with_kv`` returns
        ``[(req, KVBlocks|None)]`` with live blocks extracted from every
        still-reachable executor (streamed takeover)."""
        return self.engine.export_live_requests(with_kv=with_kv)

    def restart(self) -> float:
        """Drain-and-restart baseline: the whole instance relaunches
        (engine + executors + weights + groups + cached compile).  The
        instance serves nothing while this runs — that stall is the cost
        the arbiter weighs against revive/spare."""
        self.state = InstanceState.RESTARTING
        t0 = time.perf_counter()
        self.engine.full_reinit()
        dt = time.perf_counter() - t0
        self.restarts += 1
        self.state = InstanceState.SERVING
        return dt

    def fail_instance(self, reason: str = "host loss") -> None:
        """Full-instance loss: every device goes at once (host/kernel/
        fabric failure).  The engine is unusable until restarted; the
        router must re-home its requests."""
        for ex in self.engine.dp_executors:
            ex.fail_device()
            ex.terminate_process()
        for mex in self.engine.moe_executors:
            mex.fail_device()
        self.state = InstanceState.DEAD
        self.decommission_reason = reason

    def decommission(self, reason: str) -> None:
        self.state = InstanceState.DEAD
        self.decommission_reason = reason
