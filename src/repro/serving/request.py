"""Request / sequence state machine for the serving engine."""
from __future__ import annotations

import enum
import itertools
import time
from dataclasses import dataclass, field
from typing import List, Optional


class RequestState(enum.Enum):
    WAITING = "waiting"
    RUNNING = "running"
    FINISHED = "finished"
    MIGRATING = "migrating"   # in flight between executors after a failure
    FAILED = "failed"


_req_counter = itertools.count(1)


@dataclass
class Request:
    prompt_tokens: List[int]
    max_new_tokens: int
    req_id: int = field(default_factory=lambda: next(_req_counter))
    state: RequestState = RequestState.WAITING
    output_tokens: List[int] = field(default_factory=list)
    arrival_time: float = field(default_factory=time.monotonic)
    first_token_time: Optional[float] = None   # TTFT = this - arrival_time
    finish_time: Optional[float] = None
    dp_rank: Optional[int] = None        # executor currently responsible
    batch_slot: Optional[int] = None     # slot in the executor's decode batch
    instance_id: Optional[int] = None    # fleet instance currently serving us
    model_id: Optional[str] = None       # multi-model fleets: required config
    eos_token: Optional[int] = None
    migrations: int = 0                  # how many times recovery moved us
    cross_instance_migrations: int = 0   # moved to a different fleet instance
    recomputed_tokens: int = 0           # decode work redone due to recovery
    # chunked-prefill progress: prompt positions [0, prefill_pos) have
    # their KV installed (prefix-cache hits count — they skip compute).
    # A RUNNING request only joins the decode batch once prefill_pos
    # reaches its admission-time prefill target.
    prefill_pos: int = 0
    # overlap pipeline: the last `speculative_tokens` entries of
    # `output_tokens` are plan-ahead *guesses* for a step still in
    # flight on device.  They exist so the next step can be planned at
    # the predicted positions; the values are replaced by the
    # authoritative host-sampled tokens when the step drains (or popped
    # wholesale on reconcile/rollback).  Consumers that must only see
    # committed tokens (streaming, migration export) read
    # ``committed_output``.
    speculative_tokens: int = 0

    @property
    def committed_output(self) -> List[int]:
        """Output tokens confirmed by a drained step (never speculative)."""
        if self.speculative_tokens:
            return self.output_tokens[:len(self.output_tokens)
                                      - self.speculative_tokens]
        return self.output_tokens

    def apply_speculative(self, tokens: List[int]) -> None:
        self.output_tokens.extend(int(t) for t in tokens)
        self.speculative_tokens += len(tokens)

    def confirm_speculative(self, tokens: List[int]) -> None:
        """Replace this request's oldest in-flight guesses with the
        authoritative sampled values (counts already verified equal)."""
        n = len(tokens)
        base = len(self.output_tokens) - self.speculative_tokens
        self.output_tokens[base:base + n] = [int(t) for t in tokens]
        self.speculative_tokens -= n

    def unwind_speculative(self, n: int) -> None:
        if n:
            del self.output_tokens[len(self.output_tokens) - n:]
            self.speculative_tokens -= n

    @property
    def tokens_so_far(self) -> List[int]:
        return self.prompt_tokens + self.output_tokens

    @property
    def num_tokens(self) -> int:
        return len(self.prompt_tokens) + len(self.output_tokens)

    @property
    def done(self) -> bool:
        if len(self.output_tokens) >= self.max_new_tokens:
            return True
        return (self.eos_token is not None and self.output_tokens
                and self.output_tokens[-1] == self.eos_token)

    @property
    def ttft_s(self) -> Optional[float]:
        if self.first_token_time is None:
            return None
        return self.first_token_time - self.arrival_time

    def note_token(self, now: Optional[float] = None) -> None:
        """Record the first-token timestamp (idempotent)."""
        if self.first_token_time is None and self.output_tokens:
            self.first_token_time = (time.monotonic()
                                     if now is None else now)

    def rebuild_prompt_for_migration(self) -> "Request":
        """§3.2 partial recomputation: prompt + decoded tokens become the
        new prompt; the new executor re-prefills but skips completed
        decoding steps (they stay in ``output_tokens`` accounting)."""
        self.state = RequestState.MIGRATING
        self.migrations += 1
        self.dp_rank = None
        self.batch_slot = None
        self.prefill_pos = 0
        return self
