"""Streaming HTTP serving front end over the fleet router.

A deliberately dependency-free asyncio server (hand-rolled HTTP/1.1 +
server-sent events — the container has no web framework) that turns the
in-process :class:`~repro.fleet.router.FleetRouter` into something a
client can actually talk to:

* ``POST /v1/completions`` — OpenAI-style completions.  ``prompt`` is a
  list of token ids (or a string, byte-encoded mod vocab — the repro
  models have no tokenizer).  ``"stream": true`` switches the response
  to SSE chunks, one per committed token batch.
* ``GET  /health``     — fleet health + per-instance ``InstanceHealth``,
  including each instance's masked-expert fraction (degraded quality
  surface while a revive serves with experts masked).
* ``GET  /instances``  — instance detail + every arbiter decision so
  far (revive vs restart vs spare, with the counterfactual cost table).
* ``POST /control``    — fault-injection ops for drills and CI smoke:
  ``fail_device`` / ``lose_instance`` / ``drain_instance`` /
  ``planned_restart``.

Threading model: the fleet ticks on a dedicated driver thread (JAX
dispatch + host planning must not block the event loop); the asyncio
side talks to it through a command queue, and token progress flows back
through per-request ``asyncio.Queue`` handoffs scheduled with
``call_soon_threadsafe``.  Streams only ever see
``Request.committed_output`` — the overlap pipeline's speculative
guesses never reach a client.
"""
from __future__ import annotations

import asyncio
import json
import threading
import time
import uuid
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.serving.request import Request, RequestState

_MAX_BODY = 1 << 20          # 1 MiB request-body bound
_IDLE_SLEEP_S = 0.004        # driver poll period when the fleet is idle


def _encode_prompt(prompt, vocab_size: int) -> List[int]:
    """Token-id lists pass through; strings byte-encode mod vocab (the
    smoke models are tokenizer-free, determinism is what matters)."""
    if isinstance(prompt, str):
        if not prompt:
            raise ValueError("prompt must be non-empty")
        return [b % vocab_size for b in prompt.encode("utf-8")]
    if (isinstance(prompt, list) and prompt
            and all(isinstance(t, int) and not isinstance(t, bool)
                    for t in prompt)):
        bad = [t for t in prompt if not 0 <= t < vocab_size]
        if bad:
            raise ValueError(
                f"prompt token ids out of range [0, {vocab_size}): "
                f"{bad[:4]}")
        return list(prompt)
    raise ValueError("prompt must be a string or a non-empty list of "
                     "token ids")


class _Stream:
    """Bridge from the driver thread to one HTTP response: the driver
    pushes committed-token batches, the handler awaits them."""

    def __init__(self, req: Request, loop: asyncio.AbstractEventLoop):
        self.req = req
        self.loop = loop
        self.queue: "asyncio.Queue" = asyncio.Queue()
        self.sent = 0            # committed tokens already published

    def publish(self) -> bool:
        """Driver side: push any newly committed tokens; True when the
        request reached a terminal state (stream complete)."""
        committed = self.req.committed_output
        if len(committed) > self.sent:
            new = list(committed[self.sent:])
            self.sent = len(committed)
            self.loop.call_soon_threadsafe(self.queue.put_nowait, new)
        if self.req.state in (RequestState.FINISHED, RequestState.FAILED):
            self.loop.call_soon_threadsafe(self.queue.put_nowait, None)
            return True
        return False


class ServingFrontend:
    """Asyncio HTTP server + fleet driver thread over a FleetRouter."""

    def __init__(self, router, *, host: str = "127.0.0.1",
                 port: int = 8077):
        self.router = router
        self.host = host
        self.port = port
        # the router and every engine under it are single-threaded
        # structures: the driver owns them, HTTP handlers enqueue work /
        # read snapshots through this lock
        self._lock = threading.Lock()
        self._commands: List[Callable[[], Any]] = []
        self._streams: List[_Stream] = []
        self._stop = threading.Event()
        self._driver: Optional[threading.Thread] = None
        self._server: Optional[asyncio.AbstractServer] = None
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        eng = next(iter(router.instances.values())).engine
        self.vocab_size = eng.cfg.vocab_size
        self.default_eos = self.vocab_size - 1

    # -- driver thread (owns the fleet) ---------------------------------------

    def _drive(self) -> None:
        while not self._stop.is_set():
            with self._lock:
                cmds, self._commands = self._commands, []
                for fn in cmds:
                    fn()
                # freezes (restart/revive stall charges) only drain on
                # ticks, so an idle fleet must keep ticking until its
                # control-plane state settles or /health would report a
                # long-finished recovery forever
                busy = (self.router.unfinished > 0
                        or bool(self.router.backlog)
                        or any(v > 0.0
                               for v in self.router._frozen.values()))
                if busy:
                    self.router.tick()
                self._streams = [s for s in self._streams
                                 if not s.publish()]
            if not busy:
                time.sleep(_IDLE_SLEEP_S)

    def _call(self, fn: Callable[[], Any]) -> "asyncio.Future":
        """Schedule ``fn`` on the driver thread; resolve an asyncio
        future with its result (or exception)."""
        loop = asyncio.get_running_loop()
        fut: "asyncio.Future" = loop.create_future()

        def run():
            try:
                res = fn()
            except Exception as e:        # surfaced as HTTP 400
                loop.call_soon_threadsafe(
                    lambda: fut.cancelled() or fut.set_exception(e))
            else:
                loop.call_soon_threadsafe(
                    lambda: fut.cancelled() or fut.set_result(res))

        with self._lock:
            self._commands.append(run)
        return fut

    # -- lifecycle -------------------------------------------------------------

    async def start(self) -> None:
        self._loop = asyncio.get_running_loop()
        self._server = await asyncio.start_server(
            self._handle_client, self.host, self.port)
        if self.port == 0:
            self.port = self._server.sockets[0].getsockname()[1]
        self._driver = threading.Thread(target=self._drive,
                                        name="fleet-driver", daemon=True)
        self._driver.start()

    async def stop(self) -> None:
        self._stop.set()
        if self._driver is not None:
            self._driver.join(timeout=5.0)
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()

    async def serve_forever(self) -> None:
        await self.start()
        assert self._server is not None
        async with self._server:
            await self._server.serve_forever()

    # -- HTTP plumbing ----------------------------------------------------------

    async def _handle_client(self, reader: asyncio.StreamReader,
                             writer: asyncio.StreamWriter) -> None:
        try:
            while True:
                try:
                    head = await reader.readuntil(b"\r\n\r\n")
                except (asyncio.IncompleteReadError, ConnectionError):
                    return
                try:
                    method, path, headers = self._parse_head(head)
                except ValueError as e:
                    await self._respond_json(writer, 400,
                                             {"error": str(e)})
                    return
                length = int(headers.get("content-length", "0"))
                if length > _MAX_BODY:
                    await self._respond_json(
                        writer, 413, {"error": "body too large"})
                    return
                body = (await reader.readexactly(length)
                        if length else b"")
                keep = await self._dispatch(method, path, body, writer)
                if not keep:
                    return
        finally:
            try:
                writer.close()
                await writer.wait_closed()
            except (ConnectionError, RuntimeError):
                pass

    @staticmethod
    def _parse_head(head: bytes) -> Tuple[str, str, Dict[str, str]]:
        lines = head.decode("latin-1").split("\r\n")
        parts = lines[0].split(" ")
        if len(parts) != 3:
            raise ValueError(f"malformed request line: {lines[0]!r}")
        method, path, _version = parts
        headers: Dict[str, str] = {}
        for ln in lines[1:]:
            if ":" in ln:
                k, v = ln.split(":", 1)
                headers[k.strip().lower()] = v.strip()
        return method.upper(), path, headers

    @staticmethod
    async def _respond_json(writer: asyncio.StreamWriter, status: int,
                            obj: Any, *, keep_alive: bool = False) -> bool:
        reason = {200: "OK", 400: "Bad Request", 404: "Not Found",
                  405: "Method Not Allowed",
                  413: "Payload Too Large"}.get(status, "OK")
        payload = json.dumps(obj).encode("utf-8")
        conn = "keep-alive" if keep_alive else "close"
        writer.write(
            f"HTTP/1.1 {status} {reason}\r\n"
            f"Content-Type: application/json\r\n"
            f"Content-Length: {len(payload)}\r\n"
            f"Connection: {conn}\r\n\r\n".encode("latin-1") + payload)
        await writer.drain()
        return keep_alive

    # -- routing ---------------------------------------------------------------

    async def _dispatch(self, method: str, path: str, body: bytes,
                        writer: asyncio.StreamWriter) -> bool:
        path = path.split("?", 1)[0]
        if path == "/v1/completions":
            if method != "POST":
                return await self._respond_json(
                    writer, 405, {"error": "POST only"})
            return await self._completions(body, writer)
        if path == "/health":
            return await self._respond_json(writer, 200,
                                            await self._call(self._health),
                                            keep_alive=True)
        if path == "/instances":
            return await self._respond_json(
                writer, 200, await self._call(self._instances),
                keep_alive=True)
        if path == "/control":
            if method != "POST":
                return await self._respond_json(
                    writer, 405, {"error": "POST only"})
            return await self._control(body, writer)
        return await self._respond_json(
            writer, 404, {"error": f"no route for {path}"})

    # -- /v1/completions --------------------------------------------------------

    async def _completions(self, body: bytes,
                           writer: asyncio.StreamWriter) -> bool:
        try:
            spec = json.loads(body.decode("utf-8") or "{}")
            tokens = _encode_prompt(spec.get("prompt"), self.vocab_size)
            max_tokens = int(spec.get("max_tokens", 16))
            if not 1 <= max_tokens <= 4096:
                raise ValueError("max_tokens must be in [1, 4096]")
            stream = bool(spec.get("stream", False))
            model_id = spec.get("model")
            eos = spec.get("eos_token", self.default_eos)
            if eos is not None:
                eos = int(eos)
        except (ValueError, TypeError, json.JSONDecodeError) as e:
            return await self._respond_json(writer, 400,
                                            {"error": str(e)})
        loop = asyncio.get_running_loop()
        holder: Dict[str, _Stream] = {}

        def submit() -> Request:
            req = self.router.submit(tokens, max_tokens, eos_token=eos,
                                     model_id=model_id)
            s = _Stream(req, loop)
            holder["stream"] = s
            self._streams.append(s)
            return req

        req = await self._call(submit)
        s = holder["stream"]
        cid = f"cmpl-{uuid.uuid4().hex[:24]}"
        if stream:
            return await self._stream_response(writer, cid, req, s)
        chunks: List[List[int]] = []
        while True:
            batch = await s.queue.get()
            if batch is None:
                break
            chunks.append(batch)
        out = [t for c in chunks for t in c]
        return await self._respond_json(writer, 200, {
            "id": cid, "object": "text_completion",
            "model": req.model_id or "default",
            "choices": [{
                "index": 0, "tokens": out,
                "finish_reason": self._finish_reason(req),
            }],
            "usage": {"prompt_tokens": len(req.prompt_tokens),
                      "completion_tokens": len(out),
                      "total_tokens": len(req.prompt_tokens) + len(out)},
        })

    async def _stream_response(self, writer: asyncio.StreamWriter,
                               cid: str, req: Request,
                               s: _Stream) -> bool:
        writer.write(b"HTTP/1.1 200 OK\r\n"
                     b"Content-Type: text/event-stream\r\n"
                     b"Cache-Control: no-cache\r\n"
                     b"Connection: close\r\n\r\n")
        await writer.drain()
        while True:
            batch = await s.queue.get()
            if batch is None:
                break
            ev = {"id": cid, "object": "text_completion.chunk",
                  "choices": [{"index": 0, "tokens": batch,
                               "finish_reason": None}]}
            writer.write(b"data: " + json.dumps(ev).encode("utf-8")
                         + b"\n\n")
            try:
                await writer.drain()
            except ConnectionError:
                return False          # client went away: stop streaming
        ev = {"id": cid, "object": "text_completion.chunk",
              "choices": [{"index": 0, "tokens": [],
                           "finish_reason": self._finish_reason(req)}]}
        writer.write(b"data: " + json.dumps(ev).encode("utf-8") + b"\n\n")
        writer.write(b"data: [DONE]\n\n")
        try:
            await writer.drain()
        except ConnectionError:
            pass
        return False

    @staticmethod
    def _finish_reason(req: Request) -> str:
        if req.state is RequestState.FAILED:
            return "error"
        out = req.committed_output
        if (req.eos_token is not None and out
                and out[-1] == req.eos_token):
            return "stop"
        return "length"

    # -- /health / /instances ---------------------------------------------------

    def _masked_fraction(self, eng) -> float:
        if eng.expert_map is None:
            return 0.0
        return len(eng.expert_map.masked) / eng.expert_map.moe.num_experts

    def _health(self) -> Dict:
        # runs on the driver thread (via _call): between ticks, never
        # during one
        fh = self.router.fleet_health()
        per = {}
        for iid, inst in sorted(self.router.instances.items()):
            if inst.state.value == "dead":
                per[str(iid)] = {"state": "dead"}
                continue
            h = inst.health()
            per[str(iid)] = {
                "state": inst.state.value,
                "serving": h.serving,
                "degraded": h.degraded,
                "healthy_dp": h.healthy_dp, "total_dp": h.total_dp,
                "healthy_moe": h.healthy_moe,
                "total_moe": h.total_moe,
                "expert_coverage": h.expert_coverage,
                "masked_expert_fraction":
                    self._masked_fraction(inst.engine),
                "queue_depth": h.queue_depth,
                "unfinished": h.unfinished,
                "soft_signals": {str(k): v
                                 for k, v in h.soft_signals.items()},
            }
        return {
            "state": fh.state,
            "serving": fh.serving,
            "accepting": fh.accepting,
            "backlog": fh.backlog,
            "shed": fh.shed,
            "spares_available": fh.spares_available,
            "frozen": fh.frozen,
            "starved_models": fh.starved_models,
            "instances": per,
        }

    def _instances(self) -> Dict:
        rows = []
        for iid, inst in sorted(self.router.instances.items()):
            eng = inst.engine
            row = {
                "iid": iid,
                "state": inst.state.value,
                "model_id": inst.model_id,
                "restarts": inst.restarts,
                "decommission_reason": inst.decommission_reason,
            }
            if inst.state.value != "dead":
                row.update({
                    "load": inst.load,
                    "steps": eng.step_no,
                    "masked_expert_fraction":
                        self._masked_fraction(eng),
                    "host_gap_fraction":
                        round(eng.host_gap_fraction(), 6),
                    "overlap": eng.overlap_stats(),
                    "recoveries": [rep.summary()
                                   for rep in eng.reports],
                })
            rows.append(row)
        # every arbiter revive-vs-restart-vs-spare decision, with the
        # counterfactual cost table it priced
        decisions = [ev for ev in self.router.forensics
                     if "decision" in ev]
        return {"instances": rows, "decisions": decisions,
                "ticks": self.router.ticks,
                "now_s": round(self.router.now_s, 6)}

    # -- /control ---------------------------------------------------------------

    async def _control(self, body: bytes,
                       writer: asyncio.StreamWriter) -> bool:
        try:
            spec = json.loads(body.decode("utf-8") or "{}")
            op = spec["op"]
            iid = int(spec["iid"])
        except (KeyError, ValueError, TypeError,
                json.JSONDecodeError) as e:
            return await self._respond_json(
                writer, 400, {"error": f"bad control spec: {e}"})

        def run():
            if iid not in self.router.instances:
                raise ValueError(f"unknown instance {iid}")
            if op == "fail_device":
                # device-level fault on one rank next engine step: the
                # arbiter weighs revive vs restart vs spare, and with a
                # surviving DP rank revive keeps the instance serving —
                # the ReviveMoE path the CI smoke drills mid-stream
                from repro.core.fault_codes import ErrorType, Severity
                eng = self.router.instances[iid].engine
                pid = int(spec.get("pid", 1))
                eng.injector.schedule(
                    eng.step_no + 1, pid, severity=Severity.L6,
                    error_type=ErrorType.HBM_ECC,
                    component=spec.get("component", "attn"),
                    mid_step=True)
                return {"ok": True, "op": op, "iid": iid, "pid": pid}
            if op == "lose_instance":
                self.router.lose_instance(
                    iid, reason=spec.get("reason", "control: host loss"))
            elif op == "drain_instance":
                self.router.drain_instance(iid)
            elif op == "planned_restart":
                self.router.planned_restart(iid)
            else:
                raise ValueError(f"unknown op {op!r}")
            return {"ok": True, "op": op, "iid": iid}

        try:
            res = await self._call(run)
        except ValueError as e:
            return await self._respond_json(writer, 400,
                                            {"error": str(e)})
        return await self._respond_json(writer, 200, res,
                                        keep_alive=True)


def serve_http(router, *, host: str = "127.0.0.1",
               port: int = 8077) -> None:
    """Blocking entry point: run the front end until interrupted."""
    fe = ServingFrontend(router, host=host, port=port)

    async def _main():
        await fe.start()
        print(f"serving on http://{fe.host}:{fe.port} "
              f"(POST /v1/completions, GET /health, GET /instances, "
              f"POST /control)", flush=True)
        assert fe._server is not None
        async with fe._server:
            await fe._server.serve_forever()

    try:
        asyncio.run(_main())
    except KeyboardInterrupt:
        pass
