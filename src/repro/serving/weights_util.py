"""Expert-weight sharding utilities for the simulated multi-executor runtime.

The authoritative storage of routed-expert weights is per-EP-rank shards
(physically separate numpy arrays), so a rank failure genuinely destroys
its weights.  The engine assembles the full physical expert bank from the
alive shards (dead slices zeroed — the runtime never routes to them) for
the compiled forward.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

EXPERT_LEAF_NAMES = ("gate", "up", "down")
EXPERT_AXIS = 1  # stacked layer params: (L, E_phys, ...)


def _path_keys(path) -> Tuple[str, ...]:
    out = []
    for k in path:
        if hasattr(k, "key"):
            out.append(str(k.key))
        elif hasattr(k, "idx"):
            out.append(str(k.idx))
        else:
            out.append(str(k))
    return tuple(out)


def is_expert_leaf(path) -> bool:
    keys = _path_keys(path)
    return "moe" in keys and keys[-1] in EXPERT_LEAF_NAMES


def path_str(path) -> str:
    return "/".join(_path_keys(path))


def split_experts(params, ep_size: int):
    """Returns (base_params, shards).

    base_params: params with expert leaves zeroed (shape preserved).
    shards[r]: {path_str: np.ndarray slice} — rank r's physical slots.
    """
    shards: List[Dict[str, np.ndarray]] = [dict() for _ in range(ep_size)]

    def visit(path, leaf):
        if not is_expert_leaf(path):
            return leaf
        E = leaf.shape[EXPERT_AXIS]
        assert E % ep_size == 0, (path_str(path), E, ep_size)
        per = E // ep_size
        arr = np.asarray(leaf)
        for r in range(ep_size):
            shards[r][path_str(path)] = np.array(
                arr[:, r * per:(r + 1) * per])
        return jnp.zeros_like(leaf)

    base = jax.tree_util.tree_map_with_path(visit, params)
    return base, shards


def assemble(base, shards: List[Dict[str, np.ndarray]],
             alive: List[bool]):
    """Rebuild full params from base + alive shards (dead slices = 0)."""

    def visit(path, leaf):
        if not is_expert_leaf(path):
            return leaf
        key = path_str(path)
        parts = []
        for r, sh in enumerate(shards):
            if alive[r] and sh is not None and key in sh:
                parts.append(sh[key])
            else:
                ref = next(s[key] for s in shards if s is not None and key in s)
                parts.append(np.zeros_like(ref))
        return jnp.asarray(np.concatenate(parts, axis=EXPERT_AXIS))

    return jax.tree_util.tree_map_with_path(visit, base)


def expert_checksums(shards: List[Dict[str, np.ndarray]]) -> List[float]:
    """Per-rank weight checksums — recovery verifies integrity with these."""
    out = []
    for sh in shards:
        if sh is None:
            out.append(float("nan"))
        else:
            out.append(float(sum(np.abs(a).sum() for a in sh.values())))
    return out


def shard_ckpt_path(workdir: str, ep_rank: int) -> str:
    import os
    return os.path.join(workdir, f"expert_shard_{ep_rank}.npz")


def save_shard_checkpoints(workdir: str,
                           shards: List[Dict[str, np.ndarray]]) -> None:
    """Per-EP-rank shard files — production keeps each rank's expert
    weights addressable on disk, so a role switch reads exactly one
    rank's slice (§3.4), not the whole model."""
    import os
    for r, sh in enumerate(shards):
        path = shard_ckpt_path(workdir, r)
        if not os.path.exists(path):
            np.savez(path, **{k.replace("/", "|"): v for k, v in sh.items()})


def load_expert_shard_from_checkpoint(ckpt_path: str, template_shard: Dict,
                                      ep_rank: int, ep_size: int, *,
                                      workdir: str = None
                                      ) -> Dict[str, np.ndarray]:
    """Role-switch weight load (§3.4): read this rank's expert shard from
    disk — the per-rank shard file when present, else slice the full
    checkpoint."""
    import os
    wanted = set(template_shard.keys())
    if workdir is not None:
        spath = shard_ckpt_path(workdir, ep_rank)
        if os.path.exists(spath):
            with np.load(spath, allow_pickle=False) as z:
                loaded = {k.replace("|", "/"): z[k] for k in z.files}
            assert set(loaded) == wanted
            return loaded
    from repro.training.checkpoint import load_keys

    def slicer(key: str, arr: np.ndarray) -> np.ndarray:
        E = arr.shape[EXPERT_AXIS]
        per = E // ep_size
        return np.array(arr[:, ep_rank * per:(ep_rank + 1) * per])

    loaded = load_keys(ckpt_path, lambda k: k in wanted, slicer)
    assert set(loaded) == wanted, (sorted(wanted - set(loaded)))
    return loaded
