"""Structural operations on serving decode caches.

The serving cache is paged: attention layers hold block pools with *no*
batch axis (requests own physical blocks, addressed through block
tables), while non-attention mixers (Mamba state) hold fixed-size
per-slot state with a batch axis.  The helpers here tell the two apart
once, structurally — a leaf whose shape changes with the batch size is
per-slot state (its batch axis is recorded), one that doesn't is a pool
(axis ``None``) — and implement the slot/block scatter-gather the
executor and KV-block migration are built on.

``read_slot``/``write_slot`` are the *legacy ring-cache* per-slot ops:
they require an all-int axes tree (``infer_batch_axes``) and do not
accept the paged cache's ``None`` pool axes — the paged path uses
``install_prefill`` / ``gather_request_blocks`` /
``scatter_request_blocks``, which branch on ``None`` per leaf.
"""
from __future__ import annotations

from typing import Any, List, Optional

import jax
import jax.numpy as jnp
import numpy as np


def infer_batch_axes(model, max_seq: int):
    """Batch axis per leaf of the dense (ring) cache — legacy helper for
    the reference decode path and its tests."""
    s1 = jax.eval_shape(lambda: model.init_cache(1, max_seq))
    s2 = jax.eval_shape(lambda: model.init_cache(2, max_seq))
    return jax.tree_util.tree_map(_single_axis, s1, s2)


def infer_paged_axes(model, num_blocks: int, block_size: int):
    """Per-leaf batch axis of the paged cache; ``None`` marks pool leaves
    (shape independent of the batch size)."""
    s1 = jax.eval_shape(lambda: model.init_paged_cache(1, num_blocks,
                                                       block_size))
    s2 = jax.eval_shape(lambda: model.init_paged_cache(2, num_blocks,
                                                       block_size))

    def axis(a, b):
        diffs = [i for i, (x, y) in enumerate(zip(a.shape, b.shape))
                 if x != y]
        if not diffs:
            return None
        assert len(diffs) == 1, (a.shape, b.shape)
        return diffs[0]

    # tree_map would collapse None into structure; keep a flat list
    leaves1, treedef = jax.tree_util.tree_flatten(s1)
    leaves2 = jax.tree_util.tree_flatten(s2)[0]
    return treedef, [axis(a, b) for a, b in zip(leaves1, leaves2)]


def _single_axis(a, b):
    diffs = [i for i, (x, y) in enumerate(zip(a.shape, b.shape)) if x != y]
    assert len(diffs) == 1, (a.shape, b.shape)
    return diffs[0]


def write_slot(cache, sub, slot: int, axes):
    """Write a batch=1 sub-cache into slot ``slot`` of the batched cache."""
    def upd(c, s, ax):
        idx = [slice(None)] * c.ndim
        idx[ax] = slice(slot, slot + 1)
        return c.at[tuple(idx)].set(s.astype(c.dtype))
    return jax.tree_util.tree_map(upd, cache, sub, axes)


def read_slot(cache, slot: int, axes):
    """Extract slot ``slot`` as a batch=1 sub-cache."""
    def rd(c, ax):
        idx = [slice(None)] * c.ndim
        idx[ax] = slice(slot, slot + 1)
        return c[tuple(idx)]
    return jax.tree_util.tree_map(rd, cache, axes)


# -- paged-cache ops (pool leaves have axis None) ---------------------------


def install_prefill(cache, raw, axes_leaves: List[Optional[int]],
                    block_ids, slot):
    """Scatter one prefilled request into the paged cache.

    ``raw`` is ``Model.prefill_paged``'s output for a batch of 1: pool
    leaves carry (L, 1, S, *rest) raw K/V rows, written block-wise at
    ``block_ids`` (ids past the request's table point at the trash
    block); state leaves carry (L, 1, ...) final recurrent state, written
    into batch slot ``slot``.  ``block_ids`` (nblk,) and ``slot`` may be
    traced — the engine compiles this per prefill bucket.
    """
    nblk = block_ids.shape[0]
    c_leaves, treedef = jax.tree_util.tree_flatten(cache)
    r_leaves = jax.tree_util.tree_flatten(raw)[0]
    out = []
    for c, r, ax in zip(c_leaves, r_leaves, axes_leaves):
        if ax is None:
            L, _, bs = c.shape[0], c.shape[1], c.shape[2]
            S = r.shape[2]
            pad = nblk * bs - S
            assert pad >= 0, (nblk, bs, S)
            rb = jnp.pad(r[:, 0], [(0, 0), (0, pad)]
                         + [(0, 0)] * (r.ndim - 3))
            rb = rb.reshape((L, nblk, bs) + r.shape[3:])
            out.append(c.at[:, block_ids].set(rb.astype(c.dtype)))
        else:
            out.append(jax.lax.dynamic_update_slice_in_dim(
                c, r.astype(c.dtype), slot, ax))
    return jax.tree_util.tree_unflatten(treedef, out)


def gather_request_blocks(cache, axes_leaves: List[Optional[int]],
                          block_ids, slot: int):
    """Extract one request's device state for KV-block migration.

    Returns ``(pool_blocks, state)`` as flat leaf lists aligned with the
    cache's flatten order: pool leaves gathered block-wise →
    (L, nblk, bs, *rest); state leaves sliced at ``slot`` → (L, 1, ...);
    the other kind is ``None`` in each list.
    """
    bids = jnp.asarray(block_ids, jnp.int32)
    pool_blocks: List[Any] = []
    state: List[Any] = []
    for c, ax in zip(jax.tree_util.tree_flatten(cache)[0], axes_leaves):
        if ax is None:
            pool_blocks.append(c[:, bids])
            state.append(None)
        else:
            idx = [slice(None)] * c.ndim
            idx[ax] = slice(slot, slot + 1)
            pool_blocks.append(None)
            state.append(c[tuple(idx)])
    return pool_blocks, state


def copy_block_prefixes(cache, axes_leaves: List[Optional[int]], copies):
    """Copy the first ``n`` rows of source pool blocks into destination
    blocks — the device half of prefix-cache copy-on-write at the
    divergence block (each shared source keeps serving its owners; the
    new request gets a private block holding the common prefix rows).

    ``copies``: [(src_bid, dst_bid, n_tokens)].  All copies of a step
    are batched into ONE row-wise gather/scatter per pool leaf (the
    eager functional update rebuilds each leaf once regardless of how
    many admissions COW'd this step).  State leaves (per-slot recurrent
    state) are untouched: COW only exists on attention pools."""
    if not copies:
        return cache
    src = np.concatenate([np.full((n,), s, np.int32)
                          for s, _, n in copies])
    dst = np.concatenate([np.full((n,), d, np.int32)
                          for _, d, n in copies])
    off = np.concatenate([np.arange(n, dtype=np.int32)
                          for _, _, n in copies])
    src = jnp.asarray(src)
    dst = jnp.asarray(dst)
    off = jnp.asarray(off)
    c_leaves, treedef = jax.tree_util.tree_flatten(cache)
    out = []
    for c, ax in zip(c_leaves, axes_leaves):
        if ax is None:
            out.append(c.at[:, dst, off].set(c[:, src, off]))
        else:
            out.append(c)
    return jax.tree_util.tree_unflatten(treedef, out)


def capture_pool_rows(cache, axes_leaves: List[Optional[int]], bids, offs):
    """Gather the step's pool write set before it is overwritten.

    ``bids``/``offs`` (NR,) address every (block, offset) row the planned
    step will write (decode destinations, prefill-chunk rows, COW
    copies, trash rows).  Pool leaves are gathered row-wise —
    O(write set), not O(pool); per-slot state leaves are kept as O(1)
    references to the immutable pre-step arrays (they are small and do
    not block pool-buffer donation).  Returns the opaque undo payload
    for :func:`restore_pool_rows`.
    """
    bids = jnp.asarray(bids, jnp.int32)
    offs = jnp.asarray(offs, jnp.int32)
    rows: List[Any] = []
    state: List[Any] = []
    for c, ax in zip(jax.tree_util.tree_flatten(cache)[0], axes_leaves):
        if ax is None:
            rows.append(c[:, bids, offs])
            state.append(None)
        else:
            rows.append(None)
            state.append(c)
    return {"bids": bids, "offs": offs, "rows": rows, "state": state}


def restore_pool_rows(cache, axes_leaves: List[Optional[int]], undo):
    """Inverse of :func:`capture_pool_rows`: scatter the captured rows
    back and swap the state leaves to their pre-step values — the §3.3
    device-side rollback, touching only the step's write set."""
    bids, offs = undo["bids"], undo["offs"]
    c_leaves, treedef = jax.tree_util.tree_flatten(cache)
    out = []
    for c, ax, row, st in zip(c_leaves, axes_leaves, undo["rows"],
                              undo["state"]):
        if ax is None:
            out.append(c.at[:, bids, offs].set(row))
        else:
            out.append(st)
    return jax.tree_util.tree_unflatten(treedef, out)


def restore_pool_rows_subset(cache, axes_leaves: List[Optional[int]],
                             undo, idx):
    """Scatter back only the captured rows selected by ``idx`` (indices
    into the undo payload's row axis) — the rejected-draft half of a
    speculation window rolls back while the rest of the step's writes
    stand.  State leaves are untouched: speculative decode only runs on
    attention-only models, whose chunk steps write pools exclusively."""
    idxa = jnp.asarray(idx, jnp.int32)
    bids = undo["bids"][idxa]
    offs = undo["offs"][idxa]
    c_leaves, treedef = jax.tree_util.tree_flatten(cache)
    out = []
    for c, ax, row in zip(c_leaves, axes_leaves, undo["rows"]):
        if ax is None:
            out.append(c.at[:, bids, offs].set(row[:, idxa]))
        else:
            out.append(c)
    return jax.tree_util.tree_unflatten(treedef, out)


def scatter_request_blocks(cache, axes_leaves: List[Optional[int]],
                           pool_blocks, state, block_ids, slot: int):
    """Inverse of :func:`gather_request_blocks` on the *target* cache:
    install migrated pool blocks at freshly allocated ``block_ids`` and
    the request's recurrent state at batch slot ``slot``."""
    bids = jnp.asarray(block_ids, jnp.int32)
    c_leaves, treedef = jax.tree_util.tree_flatten(cache)
    out = []
    for c, ax, pb, st in zip(c_leaves, axes_leaves, pool_blocks, state):
        if ax is None:
            out.append(c.at[:, bids].set(jnp.asarray(pb, c.dtype)))
        else:
            idx = [slice(None)] * c.ndim
            idx[ax] = slice(slot, slot + 1)
            out.append(c.at[tuple(idx)].set(jnp.asarray(st, c.dtype)))
    return jax.tree_util.tree_unflatten(treedef, out)
