"""Slot-wise operations on decode caches.

The executor's decode cache is a fixed-max-batch pytree; requests occupy
slots.  Batch axes differ per leaf (stacked layer caches carry the batch
on axis 1, ``pos`` on axis 0, hybrid SSM states on axis 2), so we infer
the batch axis per leaf once by comparing eval_shapes at two batch sizes.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def infer_batch_axes(model, max_seq: int):
    """Returns a pytree (matching the cache) of int batch-axis per leaf."""
    s1 = jax.eval_shape(lambda: model.init_cache(1, max_seq))
    s2 = jax.eval_shape(lambda: model.init_cache(2, max_seq))

    def axis(a, b):
        diffs = [i for i, (x, y) in enumerate(zip(a.shape, b.shape)) if x != y]
        assert len(diffs) == 1, (a.shape, b.shape)
        return diffs[0]

    return jax.tree_util.tree_map(axis, s1, s2)


def write_slot(cache, sub, slot: int, axes):
    """Write a batch=1 sub-cache into slot ``slot`` of the batched cache."""
    def upd(c, s, ax):
        idx = [slice(None)] * c.ndim
        idx[ax] = slice(slot, slot + 1)
        return c.at[tuple(idx)].set(s.astype(c.dtype))
    return jax.tree_util.tree_map(upd, cache, sub, axes)


def read_slot(cache, slot: int, axes):
    """Extract slot ``slot`` as a batch=1 sub-cache."""
    def rd(c, ax):
        idx = [slice(None)] * c.ndim
        idx[ax] = slice(slot, slot + 1)
        return c[tuple(idx)]
    return jax.tree_util.tree_map(rd, cache, axes)
