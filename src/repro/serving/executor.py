"""Executors: DPExecutor (attention rank) and MoEExecutor (expert rank).

A DPExecutor owns a local scheduler, paged-KV block accounting (with the
§3.3 undo log), a fixed-max-batch decode cache, and heartbeats to the
engine.  A MoEExecutor owns one EP rank's physical expert slots; its
weights are destroyed if it fails.

Steps are two-phase to model collective lockstep: ``plan`` (host work —
admission, block allocation, all logged) then ``compute`` (the device
step).  A fault between the phases leaves an uncommitted log, which
recovery rolls back (§3.3).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.core.block_log import BlockLog, BlockManager
from repro.serving.cache_ops import infer_batch_axes, read_slot, write_slot
from repro.serving.request import Request, RequestState
from repro.serving.sampling import SamplingParams, sample
from repro.serving.scheduler import LocalScheduler, StepPlan


def next_bucket(n: int, max_seq: int, min_bucket: int = 16) -> int:
    b = min_bucket
    while b < n:
        b *= 2
    return min(b, max_seq)


class MoEExecutor:
    """Stateless expert host: one EP rank's slice of the physical slots."""

    def __init__(self, physical_id: int, ep_rank: int,
                 shard: Dict[str, np.ndarray]):
        self.physical_id = physical_id
        self.ep_rank = ep_rank
        self.shard: Optional[Dict[str, np.ndarray]] = shard
        self.device_alive = True
        self.process_alive = True

    def fail_device(self) -> None:
        """Hardware gone: the only copies of these weights are lost."""
        self.device_alive = False
        self.shard = None

    def install_shard(self, shard: Dict[str, np.ndarray]) -> None:
        self.shard = shard
        self.device_alive = True
        self.process_alive = True


class DPExecutor:
    def __init__(self, physical_id: int, dp_rank: int, model, *,
                 max_batch: int, max_seq: int, num_blocks: int,
                 block_size: int, sampling: SamplingParams,
                 ep_rank: Optional[int] = None,
                 shard: Optional[Dict[str, np.ndarray]] = None):
        self.physical_id = physical_id
        self.dp_rank = dp_rank
        self.model = model
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.sampling = sampling
        self.device_alive = True
        self.process_alive = True
        # collocated mode: this device also hosts an expert shard
        self.ep_rank = ep_rank
        self.shard = shard

        self.block_manager = BlockManager(num_blocks, block_size)
        self.block_log = BlockLog()
        self.scheduler = LocalScheduler(max_batch, max_seq,
                                        self.block_manager)
        self.cache = model.init_cache(max_batch, max_seq)
        self.batch_axes = infer_batch_axes(model, max_seq)
        self.last_token = np.zeros((max_batch,), np.int32)
        self.steps_done = 0
        self._plan: Optional[StepPlan] = None
        # injected extra per-step latency (straggler simulation)
        self.simulated_slowdown_s = 0.0

    # -- lifecycle --------------------------------------------------------------

    @property
    def alive(self) -> bool:
        return self.device_alive and self.process_alive

    def fail_device(self) -> None:
        self.device_alive = False
        if self.shard is not None:
            self.shard = None  # collocated: expert weights die too

    def terminate_process(self) -> None:
        """Engine-side isolation of the failed/hanging process."""
        self.process_alive = False
        self._plan = None

    def drop_attention_state(self) -> List[Request]:
        """Role switch (§3.4): shed KV caches, scheduler, attention duty.

        Returns the requests that must migrate elsewhere."""
        reqs = self.scheduler.drain()
        self.cache = None
        self.block_log = BlockLog()
        return reqs

    # -- two-phase step -----------------------------------------------------------

    def plan(self) -> StepPlan:
        self.block_log.begin_step()
        self._plan = self.scheduler.plan_step(self.block_log)
        return self._plan

    def compute(self, ctx, step_no: int) -> List[Request]:
        """Run the planned step on device; returns finished requests."""
        plan, self._plan = self._plan, None
        assert plan is not None, "compute without plan"
        finished: List[Request] = []
        params, runtime = ctx.params, ctx.runtime

        if plan.prefill is not None:
            req = plan.prefill
            toks = req.tokens_so_far
            bucket = next_bucket(len(toks), self.max_seq)
            padded = np.zeros((1, bucket), np.int32)
            padded[0, :len(toks)] = toks
            lengths = np.asarray([len(toks)], np.int32)
            prefill_fn = ctx.prefill_fn(bucket)
            last_logits, sub_cache = prefill_fn(
                params, padded, lengths, runtime)
            self.cache = write_slot(self.cache, sub_cache, req.batch_slot,
                                    self.batch_axes)
            # seed by sequence position, not engine step: the token is a
            # pure function of (seed, prefix, position) and survives
            # replay on any executor of any fleet instance
            tok = int(sample(np.asarray(last_logits), self.sampling,
                             step=req.num_tokens)[0])
            req.output_tokens.append(tok)
            req.note_token()
            req.state = RequestState.RUNNING
            self.last_token[req.batch_slot] = tok
            if req.done:
                self.scheduler.finish(req, self.block_log)
                req.finish_time = time.monotonic()
                finished.append(req)

        if plan.decode:
            tokens = np.asarray(self.last_token)
            logits, new_cache = ctx.decode_fn(
                params, self.cache, tokens, runtime)
            self.cache = new_cache
            logits = np.asarray(logits)
            # one batched sample over the whole decode batch (the
            # per-request loop serialized B host round trips per step)
            slots = np.fromiter((r.batch_slot for r in plan.decode),
                                np.intp, count=len(plan.decode))
            positions = np.fromiter((r.num_tokens for r in plan.decode),
                                    np.int64, count=len(plan.decode))
            toks = sample(logits[slots], self.sampling, step=positions)
            for req, tok in zip(plan.decode, toks):
                tok = int(tok)
                req.output_tokens.append(tok)
                req.note_token()
                self.last_token[req.batch_slot] = tok
                if req.done or req.num_tokens >= self.max_seq:
                    self.scheduler.finish(req, self.block_log)
                    req.finish_time = time.monotonic()
                    finished.append(req)
        self.steps_done += 1
        return finished

    def commit(self) -> None:
        """Step boundary reached: the undo log is no longer needed."""
        self.block_log.begin_step()  # clears; committed counter advances

    def rollback_inflight(self) -> int:
        """§3.3: undo all block ops of the in-flight (uncommitted) step."""
        n = self.block_log.undo_all(self.block_manager,
                                    self.scheduler.block_tables)
        # admissions from the aborted step (their allocs were all undone,
        # leaving an empty block table) return to the waiting queue
        aborted = [r for r in self.scheduler.running
                   if self.scheduler.block_tables[r.req_id].num_blocks() == 0]
        for r in aborted:
            self.scheduler.running.remove(r)
            del self.scheduler.block_tables[r.req_id]
            if r.batch_slot is not None:
                self.scheduler._free_slots.append(r.batch_slot)
                r.batch_slot = None
            self.scheduler.requeue_front(r)
        self._plan = None
        return n
