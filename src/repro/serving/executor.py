"""Executors: DPExecutor (attention rank) and MoEExecutor (expert rank).

A DPExecutor owns a local scheduler and the paged serving cache: block
pools (one trailing trash block for idle batch slots) addressed through
the ``BlockManager``/``BlockTable`` accounting, with the §3.3 undo log
covering both the host-side block ops and the device-side pool writes
(row-level write-set capture by default; the legacy O(1) functional
snapshot as fallback).  Prefill runs as batched multi-request *chunks*
— prompt tokens become virtual decode slots against the pools, ragged
across requests purely as paging data — on attention-only models;
recurrent-state models keep whole-prompt installs.  Decode attends
through per-step paging arrays (``kvcache.build_page_context``) that
ride into the compiled step as data, so continuous batching and
recovery never retrigger compilation.

Steps are two-phase to model collective lockstep: ``plan`` (host work —
admission, block allocation, prefix-cache sharing, all logged) then
``compute`` (the device step).  A fault between the phases leaves an
uncommitted log, which recovery rolls back (§3.3) — block tables from
the op log, pools by scattering the captured write-set rows back.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.block_log import BlockLog, BlockManager, BlockTable
from repro.core.migration import KVBlocks
from repro.serving.cache_ops import (capture_pool_rows,
                                     copy_block_prefixes,
                                     gather_request_blocks,
                                     infer_paged_axes, restore_pool_rows,
                                     restore_pool_rows_subset,
                                     scatter_request_blocks)
from repro.serving.kvcache import (build_chunk_context, build_page_context,
                                   max_blocks_per_seq, padded_block_ids)
from repro.serving.request import Request, RequestState
from repro.serving.sampling import (SamplingParams, device_predict, sample,
                                    seeded_uniforms, spec_verify)
from repro.serving.scheduler import ChunkPiece, LocalScheduler, StepPlan


def next_bucket(n: int, max_seq: int, min_bucket: int = 16) -> int:
    b = min_bucket
    while b < n:
        b *= 2
    return min(b, max_seq)


@dataclass
class _Point:
    """One sampling event of an in-flight step (overlap pipeline)."""
    req: Request
    kind: str                   # 'chunk_last' | 'spec' | 'decode'
    section: str                # which launch holds its logits
    row: int                    # logits row (chunk row0 / decode slot)
    win: Optional[ChunkPiece] = None
    guesses: List[int] = field(default_factory=list)
    positions: List[int] = field(default_factory=list)
    predicted_done: bool = False
    sidx: int = -1              # row in the device-predict arrays


@dataclass
class _Det:
    """Deterministic chunk bookkeeping applied at launch (plan-ahead):
    correct whatever the step's sampled outcome, undone only when the
    whole plan is rolled back (reconcile / fault abort)."""
    req: Request
    piece: ChunkPiece
    prev_prefill_pos: int
    prev_next_register: Optional[int]
    counted_was: bool


@dataclass
class _Actual:
    """Authoritative outcome of one sampling event, host-derived from
    the drained logits (pure — nothing mutated until the pipeline
    decides between confirm and reconcile)."""
    tokens: List[int]
    accepted: int
    finished: bool


class _Pending:
    """One launched-but-uncommitted step riding the readback ring:
    device references to its logits and predicted tokens (D2H copies
    enqueued at launch, forced one step late), plus the speculative
    host bookkeeping needed to confirm or unwind it."""
    __slots__ = ("plan", "step_no", "chunk_logits", "decode_logits",
                 "pred_chunk", "pred_decode", "points", "det",
                 "prefill_finished", "t_launch")

    def __init__(self, plan: StepPlan, step_no: int):
        self.plan = plan
        self.step_no = step_no
        self.chunk_logits = None
        self.decode_logits = None
        self.pred_chunk = None      # (targets, accepted) device arrays
        self.pred_decode = None
        self.points: List[_Point] = []
        self.det: List[_Det] = []
        self.prefill_finished: List[Request] = []
        self.t_launch = 0.0


def _host_async(*arrays) -> None:
    """Enqueue device→host copies without blocking (the readback ring)."""
    for a in arrays:
        if a is not None and hasattr(a, "copy_to_host_async"):
            a.copy_to_host_async()


class MoEExecutor:
    """Stateless expert host: one EP rank's slice of the physical slots."""

    def __init__(self, physical_id: int, ep_rank: int,
                 shard: Dict[str, np.ndarray]):
        self.physical_id = physical_id
        self.ep_rank = ep_rank
        self.shard: Optional[Dict[str, np.ndarray]] = shard
        self.device_alive = True
        self.process_alive = True

    def fail_device(self) -> None:
        """Hardware gone: the only copies of these weights are lost."""
        self.device_alive = False
        self.shard = None

    def install_shard(self, shard: Dict[str, np.ndarray]) -> None:
        self.shard = shard
        self.device_alive = True
        self.process_alive = True


class DPExecutor:
    def __init__(self, physical_id: int, dp_rank: int, model, *,
                 max_batch: int, max_seq: int, num_blocks: int,
                 block_size: int, sampling: SamplingParams,
                 ep_rank: Optional[int] = None,
                 shard: Optional[Dict[str, np.ndarray]] = None,
                 paged_axes: Optional[list] = None,
                 admission: str = "chunked",
                 prefill_chunk: int = 32,
                 token_budget: Optional[int] = None,
                 prefix_cache: bool = True,
                 pool_undo: str = "rows",
                 spec_window: int = 0):
        self.physical_id = physical_id
        self.dp_rank = dp_rank
        self.model = model
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.sampling = sampling
        self.device_alive = True
        self.process_alive = True
        # collocated mode: this device also hosts an expert shard
        self.ep_rank = ep_rank
        self.shard = shard

        self.block_size = block_size
        self.num_blocks = num_blocks
        self.max_blk = max_blocks_per_seq(max_seq, block_size)
        self.trash_block = num_blocks      # the extra pool row (see model)
        self.block_manager = BlockManager(num_blocks, block_size)
        self.block_log = BlockLog()
        self.admission = admission
        self.pool_undo = pool_undo
        # chunked prefill needs a batch-width-free cache (attention-only
        # pools); recurrent-state models fall back to whole-prompt installs
        chunk = (prefill_chunk if admission == "chunked"
                 and model.supports_chunked_prefill else 0)
        self.chunk_tokens = chunk
        self.scheduler = LocalScheduler(
            max_batch, max_seq, self.block_manager,
            token_budget=(token_budget if admission == "chunked" else None),
            chunk_tokens=chunk,
            prefix_cache=prefix_cache and chunk > 0,
            window=model.cfg.sliding_window or None,
            max_prefills=1 if admission == "serial" else None,
            spec_window=spec_window)
        self.cache = model.init_paged_cache(max_batch, num_blocks,
                                            block_size)
        if paged_axes is None:   # the engine passes its shared copy in
            _, paged_axes = infer_paged_axes(model, num_blocks, block_size)
        self.paged_axes = paged_axes
        self.last_token = np.zeros((max_batch,), np.int32)
        self.steps_done = 0
        self._plan: Optional[StepPlan] = None
        # injected extra per-step latency (straggler simulation)
        self.simulated_slowdown_s = 0.0
        # overlap pipeline state: the launched-but-undrained step, plus a
        # device-resident next-token vector so step N+1's inputs chain
        # from step N without a host round trip
        self._inflight: Optional[_Pending] = None
        self._dev_last = None
        self._dev_stale = True
        self.overlap_stats = {"steps": 0, "planned_ahead": 0,
                              "replans": 0, "drains": 0}
        self.perf = {"device_busy_s": 0.0}

    # -- lifecycle --------------------------------------------------------------

    @property
    def alive(self) -> bool:
        return self.device_alive and self.process_alive

    def fail_device(self) -> None:
        self.device_alive = False
        if self.shard is not None:
            self.shard = None  # collocated: expert weights die too

    def terminate_process(self) -> None:
        """Engine-side isolation of the failed/hanging process."""
        self.process_alive = False
        self._plan = None

    def drop_attention_state(self, collect_kv: bool = False):
        """Role switch (§3.4): shed KV caches, scheduler, attention duty.

        Returns the requests that must migrate elsewhere; with
        ``collect_kv`` their live blocks are extracted *first* (the donor
        device is healthy — §3.4's role switch, unlike a failure, can
        stream its residents' KV instead of forcing re-prefill) and the
        result is ``[(req, KVBlocks | None)]``."""
        payloads = {}
        if collect_kv:
            for req in list(self.scheduler.running):
                kv = self.export_kv_blocks(req)
                if kv is not None:
                    payloads[req.req_id] = kv
        reqs = self.scheduler.drain()
        self.cache = None
        self.block_log = BlockLog()
        if collect_kv:
            return [(r, payloads.get(r.req_id)) for r in reqs]
        return reqs

    def prefix_hit_blocks(self, digests, prompt_len: int) -> int:
        """How many *leading* full prompt blocks this executor's
        BlockManager can serve from its shared-prefix cache — the
        engine's in-instance affinity signal (``_assign``).  Mirrors the
        admission matcher: the prompt's final token is never cacheable
        (its logits must be computed), so the last block is skipped."""
        bs = self.block_size
        hits = 0
        for b, d in enumerate(digests):
            if (b + 1) * bs >= prompt_len:
                break
            if self.block_manager.lookup(d) is None:
                break
            hits += 1
        return hits

    # -- two-phase step -----------------------------------------------------------

    def plan(self) -> StepPlan:
        self.block_log.begin_step()
        plan = self.scheduler.plan_step(self.block_log)
        if self.cache is not None:
            # §3.3 device half: either the O(1) functional snapshot of
            # the whole cache (legacy; pins the pre-step pool buffers),
            # or — default — capture exactly the rows this step will
            # write, known at plan time, so rollback is O(write set) and
            # the pool buffers stay donation-friendly on TPU
            if self.pool_undo == "snapshot":
                self.block_log.snapshot_pools(self.cache)
            else:
                bids, offs = self._write_manifest(plan)
                self.block_log.record_pool_undo(capture_pool_rows(
                    self.cache, self.paged_axes, bids, offs))
            # prefix-cache COW: seed private divergence blocks from the
            # shared sources *after* the capture (the copies are part of
            # the step's write set and roll back with it); one batched
            # row scatter covers every COW admission of the step
            self.cache = copy_block_prefixes(self.cache, self.paged_axes,
                                             plan.cow_copies)
        self._plan = plan
        return plan

    def _write_manifest(self, plan: StepPlan
                        ) -> Tuple[np.ndarray, np.ndarray]:
        """Every (block, offset) pool row the planned step writes: decode
        destinations for all batch slots (idle slots hit the trash row),
        each chunk token's slot, whole-prefill installs (their padded
        block scatter covers every offset), and COW destination rows."""
        bs = self.block_size
        tables = self.scheduler.block_tables
        bids: List[int] = []
        offs: List[int] = []
        if plan.decode:
            row_bid = [self.trash_block] * self.max_batch
            row_off = [0] * self.max_batch
            for req in plan.decode:
                wp = req.num_tokens - 1
                blocks = tables[req.req_id].blocks
                row_bid[req.batch_slot] = blocks[wp // bs]
                row_off[req.batch_slot] = wp % bs
            bids += row_bid
            offs += row_off
        if plan.chunks or plan.spec:
            # speculation windows ride the same launch right after the
            # prefill pieces; their manifest rows are what the verify
            # phase partially restores for rejected drafts
            n = 0
            for piece in plan.chunks + plan.spec:
                blocks = tables[piece.req.req_id].blocks
                for j in range(piece.length):
                    pos = piece.start + j
                    bids.append(blocks[pos // bs])
                    offs.append(pos % bs)
                n += piece.length
            for _ in range(self.chunk_tokens - n):   # idle chunk rows
                bids.append(self.trash_block)
                offs.append(0)
        out_b = [np.asarray(bids, np.int32)]
        out_o = [np.asarray(offs, np.int32)]
        for req in plan.prefills:
            # the install scatter writes every offset of every padded
            # block id (bucket-sized, trash repeats included)
            bucket = next_bucket(len(req.tokens_so_far), self.max_seq)
            nblk = max_blocks_per_seq(bucket, bs)
            pb = padded_block_ids(tables[req.req_id].blocks, nblk,
                                  self.trash_block)
            out_b.append(np.repeat(pb, bs))
            out_o.append(np.tile(np.arange(bs, dtype=np.int32), nblk))
        for _, dst, n in plan.cow_copies:
            out_b.append(np.full((n,), dst, np.int32))
            out_o.append(np.arange(n, dtype=np.int32))
        return (np.concatenate(out_b).astype(np.int32),
                np.concatenate(out_o).astype(np.int32))

    def compute(self, ctx, step_no: int) -> List[Request]:
        """Run the planned step on device; returns finished requests.

        Lockstep path: dispatch then commit back to back.  The overlap
        pipeline calls the same two halves a step apart."""
        return self.finish_compute(self.begin_compute(ctx, step_no))

    def begin_compute(self, ctx, step_no: int,
                      predict: bool = False) -> _Pending:
        """Dispatch the planned step's device work without forcing any
        result.  With ``predict`` (overlap pipeline) the launch's token
        inputs come from the device-resident chain instead of the host
        vector, and a jitted epilogue samples the step's tokens
        on-device so the next step can launch before this one drains."""
        plan, self._plan = self._plan, None
        assert plan is not None, "compute without plan"
        pend = _Pending(plan, step_no)
        params, runtime = ctx.params, ctx.runtime

        if plan.chunks or plan.spec:
            tokens, page = build_chunk_context(
                plan.chunks + plan.spec, self.scheduler.block_tables,
                width=self.chunk_tokens, max_blk=self.max_blk,
                block_size=self.block_size, trash_block=self.trash_block)
            if predict:
                tokens = self._chain_chunk_tokens(tokens, plan)
            logits, self.cache = ctx.chunk_fn()(
                params, self.cache, tokens, page, runtime)
            pend.chunk_logits = logits

        assert not (predict and plan.prefills), \
            "overlap requires chunked admission (no whole-prompt installs)"
        for req in plan.prefills:
            toks = req.tokens_so_far
            bucket = next_bucket(len(toks), self.max_seq)
            padded = np.zeros((1, bucket), np.int32)
            padded[0, :len(toks)] = toks
            lengths = np.asarray([len(toks)], np.int32)
            prefill_fn = ctx.prefill_fn(bucket)
            last_logits, raw = prefill_fn(params, padded, lengths, runtime)
            nblk = max_blocks_per_seq(bucket, self.block_size)
            bids = padded_block_ids(
                self.scheduler.block_tables[req.req_id].blocks, nblk,
                self.trash_block)
            install_fn = ctx.install_fn(bucket)
            self.cache = install_fn(self.cache, raw, bids,
                                    np.int32(req.batch_slot))
            req.prefill_pos = len(toks)
            self.scheduler.note_prefill_done(len(toks))
            tok = int(sample(np.asarray(last_logits), self.sampling,
                             step=req.num_tokens)[0])
            req.output_tokens.append(tok)
            req.note_token()
            req.state = RequestState.RUNNING
            self.last_token[req.batch_slot] = tok
            if req.done:
                self.scheduler.finish(req, self.block_log)
                req.finish_time = time.monotonic()
                pend.prefill_finished.append(req)

        if plan.decode:
            page = build_page_context(
                plan.decode, self.scheduler.block_tables,
                max_batch=self.max_batch, max_blk=self.max_blk,
                block_size=self.block_size, trash_block=self.trash_block)
            tokens = (self._dev_chain() if predict
                      else np.asarray(self.last_token))
            logits, new_cache = ctx.decode_fn(
                params, self.cache, tokens, page, runtime)
            self.cache = new_cache
            pend.decode_logits = logits

        pend.t_launch = time.perf_counter()
        if predict:
            self._launch_predict(pend)
        return pend

    def finish_compute(self, pend: _Pending,
                       chunk_book: bool = True) -> List[Request]:
        """Force the step's logits and commit its outcome on the host
        (the authoritative sampler).  ``chunk_book=False`` skips the
        chunk-piece bookkeeping the overlap launch already applied."""
        plan = pend.plan
        finished: List[Request] = []
        t_done = None

        if pend.chunk_logits is not None:
            logits = np.asarray(pend.chunk_logits)
            t_done = time.perf_counter()
            row = 0
            for piece in plan.chunks:
                req = piece.req
                if chunk_book:
                    req.prefill_pos = piece.start + piece.length
                    self.scheduler.note_chunk_done(piece, self.block_log)
                if piece.last:
                    # seed by sequence position, not engine step: the
                    # token is a pure function of (seed, prefix,
                    # position) and survives replay on any executor of
                    # any fleet instance
                    tok = int(sample(logits[row + piece.length - 1][None],
                                     self.sampling,
                                     step=req.num_tokens)[0])
                    req.output_tokens.append(tok)
                    req.note_token()
                    req.state = RequestState.RUNNING
                    self.last_token[req.batch_slot] = tok
                    if req.done or req.num_tokens >= self.max_seq:
                        self.scheduler.finish(req, self.block_log)
                        req.finish_time = time.monotonic()
                        finished.append(req)
                row += piece.length
            if plan.spec:
                finished.extend(self._verify_spec(plan, logits, row))

        finished.extend(pend.prefill_finished)

        if pend.decode_logits is not None:
            logits = np.asarray(pend.decode_logits)
            t_done = time.perf_counter()
            # one batched sample over the whole decode batch (the
            # per-request loop serialized B host round trips per step)
            slots = np.fromiter((r.batch_slot for r in plan.decode),
                                np.intp, count=len(plan.decode))
            positions = np.fromiter((r.num_tokens for r in plan.decode),
                                    np.int64, count=len(plan.decode))
            toks = sample(logits[slots], self.sampling, step=positions)
            for req, tok in zip(plan.decode, toks):
                tok = int(tok)
                req.output_tokens.append(tok)
                req.note_token()
                self.last_token[req.batch_slot] = tok
                # decode-grown blocks publish in the prefix cache as
                # they fill (carry-over (f)) — register before a
                # possible finish so the blocks park cache-addressable
                self.scheduler.note_decode_progress(req, self.block_log)
                if req.done or req.num_tokens >= self.max_seq:
                    self.scheduler.finish(req, self.block_log)
                    req.finish_time = time.monotonic()
                    finished.append(req)
        if t_done is not None:
            self.perf["device_busy_s"] += t_done - pend.t_launch
        self._dev_stale = True
        self.steps_done += 1
        return finished

    def _verify_spec(self, plan: StepPlan, logits: np.ndarray,
                     row0: int) -> List[Request]:
        """Commit each speculation window against the verifier logits.

        Window rows sit after the prefill-chunk rows in both the launch
        (logits rows) and the plan-time write manifest, in the same
        order — so a window's manifest indices are its logits rows
        shifted by the decode section.  Every emitted token is the
        seeded sampler's output at its own sequence position
        (``spec_verify``), keeping the stream token-identical to plain
        decode; pool rows written by rejected drafts are restored
        bit-exact from the §3.3 write-set capture (under the legacy
        snapshot strategy they are left stale, which is safe: a stale
        row's position is only ever attended after its true token's
        decode step rewrites it)."""
        finished: List[Request] = []
        undo = self.block_log.peek_pool_undo()
        base_manifest = self.max_batch if plan.decode else 0
        row = row0
        for win in plan.spec:
            req = win.req
            g = win.length
            base = req.num_tokens          # next position to commit
            drafts = win.tokens[base:]     # the g - 1 proposals
            toks, accepted = spec_verify(
                logits[row:row + g], drafts, self.sampling,
                start_step=base)
            emitted = 0
            for tok in toks:
                req.output_tokens.append(int(tok))
                req.note_token()
                self.last_token[req.batch_slot] = int(tok)
                emitted += 1
                if req.done or req.num_tokens >= self.max_seq:
                    break
            # window row r wrote the KV row of position base - 1 + r;
            # rows [emitted, g) hold drafts that were rejected (or never
            # reached) — scatter their pre-step rows back
            if emitted < g and undo is not None:
                idx = np.arange(base_manifest + row + emitted,
                                base_manifest + row + g, dtype=np.int32)
                self.cache = restore_pool_rows_subset(
                    self.cache, self.paged_axes, undo, idx)
            self.scheduler.note_spec_done(win, emitted, accepted)
            self.scheduler.note_decode_progress(req, self.block_log)
            if req.done or req.num_tokens >= self.max_seq:
                self.scheduler.finish(req, self.block_log)
                req.finish_time = time.monotonic()
                finished.append(req)
            row += g
        return finished

    def commit(self) -> None:
        """Step boundary reached: the undo log is no longer needed."""
        self.block_log.begin_step()  # clears; committed counter advances

    def rollback_inflight(self) -> int:
        """§3.3: undo every uncommitted step — host block tables from
        the op log, device pools by restoring each frame's captured
        write-set rows (or the legacy step-boundary snapshot), newest
        frame first, so table and pool agree exactly on which rows are
        live.  Under the overlap pipeline this is *total*: the in-flight
        step's speculative token guesses and launch-time chunk
        bookkeeping unwind first, then both stacked frames — recovery
        then sees exactly the last committed state, and replay
        regenerates the lost step's tokens bit-identically (they are
        pure functions of seed/prefix/position)."""
        if self._inflight is not None:
            pend, self._inflight = self._inflight, None
            self._unwind_overlay(pend)
            self._unwind_det(pend)
            self.scheduler.unwind_plan_stats(pend.plan)
        n = 0
        for _ in range(self.block_log.num_frames):
            undo = self.block_log.take_pool_undo()
            snap = self.block_log.take_pool_snapshot()
            if self.cache is not None:
                if undo is not None:
                    self.cache = restore_pool_rows(
                        self.cache, self.paged_axes, undo)
                elif snap is not None:
                    self.cache = snap
            n += self.block_log.undo_newest(self.block_manager,
                                            self.scheduler.block_tables)
        # admissions from the aborted step(s) return to the waiting queue
        self.scheduler.rollback_aborted()
        self._plan = None
        self._dev_stale = True
        return n

    def has_uncommitted(self) -> bool:
        """Anything between this executor and its last step boundary —
        logged block ops, an armed pool capture, a stacked plan-ahead
        frame, or an undrained launch.  (The overlap pipeline can hold
        speculative state with *zero* block ops — a pure-decode frame —
        so ``len(block_log) > 0`` alone is not a safe export guard.)"""
        return (self._inflight is not None
                or len(self.block_log) > 0
                or self.block_log.num_frames > 1
                or self.block_log.has_pool_state())

    # -- overlap pipeline (host/device overlap, async readback) -------------------
    #
    # Lifecycle per engine step k (one call to ``overlap_step``):
    #   1. plan step k against the *predicted* post-(k-1) state (the
    #      k-1 launch applied its guessed tokens as a speculative
    #      overlay, so the scheduler simply plans at the right
    #      positions), in a fresh undo frame stacked on k-1's;
    #   2. launch step k: token inputs chain from the device-resident
    #      next-token vector (never the host guesses), a jitted
    #      epilogue samples k's tokens on-device, and only token-id
    #      sized D2H copies join the readback ring;
    #   3. drain step k-1: force its logits (one step late), re-derive
    #      the authoritative outcome with the host sampler, and either
    #      confirm (replace guessed values, commit the oldest frame) or
    #      reconcile (roll back k's frame + overlay, commit k-1's true
    #      outcome via the lockstep commit code, replan k).
    # A plan stays valid whenever the *shape* of the outcome matched —
    # per-event token counts, finishes, and the device-chain inputs the
    # next step consumed — so guessed token values never force replans
    # on their own.

    def overlap_step(self, ctx, step_no: int) -> List[Request]:
        prev = self._inflight
        nxt = None
        if self.scheduler.num_requests:
            nxt = self._plan_and_launch(ctx, step_no,
                                        stacked=prev is not None)
            if nxt is not None and prev is not None:
                self.overlap_stats["planned_ahead"] += 1
        finished: List[Request] = []
        if prev is not None:
            finished, diverged = self._drain(prev, nxt)
            if diverged:
                self.overlap_stats["replans"] += 1
                nxt = (self._plan_and_launch(ctx, step_no, stacked=False)
                       if self.scheduler.num_requests else None)
        self._inflight = nxt
        self.overlap_stats["steps"] += 1
        return finished

    def flush(self, ctx) -> List[Request]:
        """Drain the in-flight step without launching another (pipeline
        tail / engine quiesce)."""
        prev, self._inflight = self._inflight, None
        if prev is None:
            return []
        finished, _ = self._drain(prev, None)
        return finished

    def _plan_and_launch(self, ctx, step_no: int, *,
                         stacked: bool) -> Optional[_Pending]:
        """Plan-ahead half: plan in a (possibly stacked) undo frame,
        capture the write set, and dispatch.  Returns None when the
        scheduler has nothing plannable (pool/budget pressure)."""
        if stacked:
            self.block_log.push_frame()
        plan = self.scheduler.plan_step(self.block_log)
        if plan.empty:
            if stacked:
                self.block_log.undo_newest(self.block_manager,
                                           self.scheduler.block_tables)
            return None
        # the capture gathers post-(k-1) row values: it dispatches after
        # k-1's compute in device program order, which is exactly what a
        # rollback of step k alone must restore
        bids, offs = self._write_manifest(plan)
        self.block_log.record_pool_undo(capture_pool_rows(
            self.cache, self.paged_axes, bids, offs))
        self.cache = copy_block_prefixes(self.cache, self.paged_axes,
                                         plan.cow_copies)
        self._plan = plan
        return self.begin_compute(ctx, step_no, predict=True)

    def _dev_chain(self):
        """Device-resident last-token vector (refreshed from the host
        copy whenever the pipeline broke the chain)."""
        if self._dev_last is None or self._dev_stale:
            import jax.numpy as jnp
            self._dev_last = jnp.asarray(self.last_token)
            self._dev_stale = False
        return self._dev_last

    def _chain_chunk_tokens(self, tokens: np.ndarray, plan: StepPlan):
        """Chunk-launch inputs with every speculative-window row 0 (the
        re-forwarded last committed token — a host-side *guess* under
        plan-ahead) overridden from the device chain."""
        import jax.numpy as jnp
        dev = jnp.asarray(tokens)
        if not plan.spec:
            return dev
        row = sum(p.length for p in plan.chunks)
        idx, slots = [], []
        for win in plan.spec:
            idx.append(row)
            slots.append(win.req.batch_slot)
            row += win.length
        chain = self._dev_chain()
        return dev.at[jnp.asarray(idx, jnp.int32)].set(
            chain[jnp.asarray(slots, jnp.int32)])

    def _launch_predict(self, pend: _Pending) -> None:
        """Device-side sampling epilogue: enumerate the step's sampling
        events, guess their outcomes for the overlay, sample their
        tokens on-device (position-seeded uniforms computed host-side),
        scatter the emitted last tokens into the device chain, and
        enqueue the token-id D2H copies."""
        plan = pend.plan
        sched = self.scheduler
        points: List[_Point] = []
        row = 0
        for piece in plan.chunks:
            if piece.last:
                points.append(_Point(piece.req, "chunk_last", "chunk",
                                     row + piece.length - 1))
            row += piece.length
        for win in plan.spec:
            points.append(_Point(win.req, "spec", "chunk", row, win=win))
            row += win.length
        for req in plan.decode:
            points.append(_Point(req, "decode", "decode", req.batch_slot))

        # guesses + sample positions (pre-overlay state = the state the
        # in-flight inputs were built from)
        for pt in points:
            req = pt.req
            base = req.num_tokens
            if pt.kind == "spec":
                drafts = [int(t) for t in pt.win.tokens[base:]]
                bonus = sched.predict_next_token(req,
                                                 context=pt.win.tokens)
                pt.guesses = drafts + [bonus]
            else:
                pt.guesses = [sched.predict_next_token(req)]
            pt.positions = list(range(base, base + len(pt.guesses)))
        pend.points = points

        G = max(sched.spec_window, 1)
        S = self.max_batch

        def run_section(section: str, logits):
            sec = [pt for pt in points if pt.section == section]
            if not sec or logits is None:
                return None
            row0 = np.zeros(S, np.int32)
            lens = np.zeros(S, np.int32)
            drafts = np.zeros((S, G), np.int32)
            u = np.zeros((S, G), np.float32)
            slots = np.full(S, S, np.int32)   # out of range -> dropped
            for i, pt in enumerate(sec):
                pt.sidx = i
                row0[i] = pt.row
                lens[i] = len(pt.positions)
                slots[i] = pt.req.batch_slot
                if pt.kind == "spec":
                    dr = pt.guesses[:-1]      # the forwarded drafts
                    drafts[i, 1:1 + len(dr)] = dr
                if self.sampling.temperature > 0.0:
                    u[i, :len(pt.positions)] = seeded_uniforms(
                        self.sampling.seed,
                        np.asarray(pt.positions, np.int64))
            targets, accepted, new_last = device_predict(
                logits, row0, lens, drafts, u, self._dev_chain(), slots,
                temperature=self.sampling.temperature,
                top_p=self.sampling.top_p)
            self._dev_last = new_last
            self._dev_stale = False
            _host_async(targets, accepted)
            return targets, accepted

        pend.pred_chunk = run_section("chunk", pend.chunk_logits)
        pend.pred_decode = run_section("decode", pend.decode_logits)
        _host_async(pend.chunk_logits, pend.decode_logits)

        # deterministic chunk bookkeeping applies at launch (correct for
        # any sampled outcome; undone only with the whole frame)
        for piece in plan.chunks:
            req = piece.req
            info = sched._seq.get(req.req_id)
            pend.det.append(_Det(
                req, piece, req.prefill_pos,
                None if info is None else info.next_register,
                True if info is None else info.counted))
            req.prefill_pos = piece.start + piece.length
            sched.note_chunk_done(piece, self.block_log)

        # the speculative overlay: guessed tokens advance each request's
        # host-visible position so the next plan sees post-step state
        for pt in points:
            pt.req.apply_speculative(pt.guesses)
            pt.predicted_done = (pt.req.done
                                 or pt.req.num_tokens >= self.max_seq)

    def _unwind_overlay(self, pend: _Pending) -> None:
        for pt in reversed(pend.points):
            pt.req.unwind_speculative(len(pt.guesses))

    def _unwind_det(self, pend: _Pending) -> None:
        sched = self.scheduler
        for d in reversed(pend.det):
            d.req.prefill_pos = d.prev_prefill_pos
            info = sched._seq.get(d.req.req_id)
            if info is None:
                continue
            if d.prev_next_register is not None:
                info.next_register = d.prev_next_register
            if info.counted and not d.counted_was:
                info.counted = False
                sched.stats["prefill_tokens_cached"] -= info.cached_tokens

    def _unwind_pending(self, pend: _Pending) -> None:
        """Roll back a launched plan-ahead step completely: speculative
        overlay, launch-time bookkeeping, pool rows (restoring the
        post-(k-1) values its capture gathered), block ops, and any
        admissions of its frame."""
        self._unwind_overlay(pend)
        self._unwind_det(pend)
        self.scheduler.unwind_plan_stats(pend.plan)
        undo = self.block_log.take_pool_undo()
        snap = self.block_log.take_pool_snapshot()
        if self.cache is not None:
            if undo is not None:
                self.cache = restore_pool_rows(self.cache,
                                               self.paged_axes, undo)
            elif snap is not None:
                self.cache = snap
        self.block_log.undo_newest(self.block_manager,
                                   self.scheduler.block_tables)
        self.scheduler.rollback_aborted()

    def _actual_outcome(self, pend: _Pending, ch: Optional[np.ndarray],
                        de: Optional[np.ndarray]) -> List[_Actual]:
        """The authoritative outcome of each sampling event, re-derived
        from the drained logits with the host sampler — pure (no state
        mutated), replicating the lockstep commit's emit/finish logic
        against the *committed* (pre-overlay) positions."""
        out: List[_Actual] = []
        for pt in pend.points:
            req = pt.req
            committed = req.num_tokens - req.speculative_tokens
            committed_out = len(req.output_tokens) - req.speculative_tokens
            logits = ch if pt.section == "chunk" else de
            if pt.kind == "spec":
                g = pt.win.length
                toks, accepted = spec_verify(
                    logits[pt.row:pt.row + g], pt.win.tokens[committed:],
                    self.sampling, start_step=committed)
            else:
                toks = sample(logits[pt.row][None], self.sampling,
                              step=committed)
                accepted = 0
            tokens: List[int] = []
            fin = False
            n_out = committed_out
            for t in toks:
                t = int(t)
                tokens.append(t)
                n_out += 1
                done = (n_out >= req.max_new_tokens
                        or (req.eos_token is not None
                            and t == req.eos_token))
                fin = done or committed + len(tokens) >= self.max_seq
                if fin:
                    break
            out.append(_Actual(tokens, accepted, fin))
        return out

    def _diverged(self, pend: _Pending, actual: List[_Actual]) -> bool:
        """Did the in-flight step's real outcome invalidate the stacked
        plan-ahead step?  Token *values* never do on their own — only
        the outcome's shape: emitted counts (spec accepts), finishes,
        and the device-chain tokens the next launch actually consumed
        as inputs (greedy prediction is exact; temperature>0 can
        diverge in the last ULP, costing a replan, never a token)."""
        pred = {}
        for arrs, sec in ((pend.pred_chunk, "chunk"),
                          (pend.pred_decode, "decode")):
            if arrs is not None:
                pred[sec] = (np.asarray(arrs[0]), np.asarray(arrs[1]))
        for pt, act in zip(pend.points, actual):
            if len(act.tokens) != len(pt.guesses):
                return True
            if act.finished != pt.predicted_done:
                return True
            if not act.finished:
                targets, accepted = pred[pt.section]
                dev_tok = int(targets[pt.sidx, int(accepted[pt.sidx])])
                if dev_tok != act.tokens[-1]:
                    return True
        return False

    def _confirm(self, pend: _Pending,
                 actual: List[_Actual]) -> List[Request]:
        """Matched outcome: swap the authoritative token values in for
        the guesses and run the commit-side bookkeeping, targeting the
        *oldest* frame (this step's own — a stacked plan-ahead frame
        may sit on top)."""
        finished: List[Request] = []
        oldest = self.block_log.oldest()
        for pt, act in zip(pend.points, actual):
            req = pt.req
            req.confirm_speculative(act.tokens)
            req.note_token()
            self.last_token[req.batch_slot] = act.tokens[-1]
            if pt.kind == "spec":
                self.scheduler.note_spec_done(pt.win, len(act.tokens),
                                              act.accepted)
            if pt.kind in ("spec", "decode"):
                self.scheduler.note_decode_progress(req, oldest)
            if act.finished:
                self.scheduler.finish(req, oldest)
                req.finish_time = time.monotonic()
                finished.append(req)
        return finished

    def _drain(self, prev: _Pending,
               nxt: Optional[_Pending]) -> Tuple[List[Request], bool]:
        """Retire the in-flight step one launch late.  Returns
        ``(finished, diverged)``; on divergence the stacked plan-ahead
        step ``nxt`` has been fully unwound (newest-first, so pool rows
        restore in exact reverse temporal order) and the true outcome
        committed via the lockstep commit path."""
        self.overlap_stats["drains"] += 1
        ch = de = None
        if prev.chunk_logits is not None:
            ch = np.asarray(prev.chunk_logits)
            prev.chunk_logits = ch
        if prev.decode_logits is not None:
            de = np.asarray(prev.decode_logits)
            prev.decode_logits = de
        self.perf["device_busy_s"] += time.perf_counter() - prev.t_launch
        actual = self._actual_outcome(prev, ch, de)
        if not self._diverged(prev, actual):
            finished = self._confirm(prev, actual)
            self.block_log.commit_oldest()
            self.steps_done += 1
            return finished, False
        # reconcile: unwind the mispredicted plan-ahead step first (its
        # pool capture holds post-prev values, so it must restore before
        # prev's own spec-reject restores), then pop prev's guesses and
        # replay its true outcome through the lockstep commit code
        if nxt is not None:
            self._unwind_pending(nxt)
        self._unwind_overlay(prev)
        prev.t_launch = time.perf_counter()   # busy already accounted
        finished = self.finish_compute(prev, chunk_book=False)
        self.block_log.commit_oldest()
        self._dev_stale = True
        return finished, True

    # -- KV-block migration (§3.2, streaming path) --------------------------------

    def export_kv_blocks(self, req: Request) -> Optional[KVBlocks]:
        """Extract a RUNNING request's live blocks + recurrent state.

        None when this device's state is unreachable or the request has
        no installed KV yet (still WAITING, mid-chunked-prefill, or
        mid-migration) — callers fall back to token-replay re-prefill.
        Prefix-shared blocks are read in place (sharing is refcounted;
        a gather never mutates), and window-released table entries ship
        trash rows the target's attention window masks identically."""
        if self.cache is None or not self.alive:
            return None
        if req.state is not RequestState.RUNNING or req.batch_slot is None:
            return None
        if self.scheduler.prefilling(req):
            return None
        table = self.scheduler.block_tables.get(req.req_id)
        if table is None or not req.output_tokens:
            return None
        valid_len = req.num_tokens - 1   # last sampled token's KV is not
        if valid_len <= 0:               # written until its decode step
            return None
        nblk = (valid_len + self.block_size - 1) // self.block_size
        bids = table.blocks[:nblk]
        # window-released entries are trash sentinels: ship no rows for
        # them (their positions are below the attention window forever)
        live_mask = [b < self.num_blocks for b in bids]
        live_bids = [b for b in bids if b < self.num_blocks]
        pools, state = gather_request_blocks(self.cache, self.paged_axes,
                                             live_bids, req.batch_slot)
        return KVBlocks(
            block_size=self.block_size, num_blocks=nblk,
            valid_len=valid_len,
            pool_blocks=[None if p is None else np.asarray(p)
                         for p in pools],
            state=[None if s is None else np.asarray(s) for s in state],
            last_token=int(req.output_tokens[-1]),
            live_mask=live_mask)

    def import_kv_blocks(self, req: Request, kv: KVBlocks) -> bool:
        """Install streamed blocks: allocate fresh physical blocks here,
        scatter the payload, and adopt the request as RUNNING — it skips
        re-prefill entirely and decodes on the next step.  False when
        this executor lacks a batch slot or enough free blocks."""
        if self.cache is None or not self.alive:
            return False
        if kv.block_size != self.block_size:
            return False
        if not self.scheduler._free_slots:
            return False
        span = max(kv.num_blocks, self.scheduler._blocks_needed(
            min(req.num_tokens + 1, self.max_seq)))
        live = (kv.live_mask if kv.live_mask is not None
                else [True] * kv.num_blocks)
        # dead (window-released) table entries install as trash
        # sentinels here too — only live payload blocks and the growth
        # region past the payload need real allocations
        need = sum(live) + (span - kv.num_blocks)
        if self.block_manager.num_allocatable < need:
            return False
        # host accounting mirrors admission; import runs at a step
        # boundary, so the ops commit immediately (log=None)
        table = BlockTable(req.req_id)
        for j in range(span):
            if j < kv.num_blocks and not live[j]:
                table.append_block(self.trash_block)
            else:
                table.append_block(self.block_manager.allocate())
        self.scheduler.block_tables[req.req_id] = table
        req.batch_slot = self.scheduler._free_slots.pop()
        req.dp_rank = self.dp_rank
        req.state = RequestState.RUNNING
        self.scheduler.running.append(req)
        self.scheduler.register_imported(req)
        live_ids = [table.blocks[j] for j in range(kv.num_blocks)
                    if live[j]]
        self.cache = scatter_request_blocks(
            self.cache, self.paged_axes, kv.pool_blocks, kv.state,
            np.asarray(live_ids, np.int32), req.batch_slot)
        self.last_token[req.batch_slot] = kv.last_token
        self._dev_stale = True   # device token chain must re-sync
        return True
