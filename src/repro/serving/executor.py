"""Executors: DPExecutor (attention rank) and MoEExecutor (expert rank).

A DPExecutor owns a local scheduler and the paged serving cache: block
pools (one trailing trash block for idle batch slots) addressed through
the ``BlockManager``/``BlockTable`` accounting, with the §3.3 undo log
covering both the host-side block ops and the device-side pool writes
(row-level write-set capture by default; the legacy O(1) functional
snapshot as fallback).  Prefill runs as batched multi-request *chunks*
— prompt tokens become virtual decode slots against the pools, ragged
across requests purely as paging data — on attention-only models;
recurrent-state models keep whole-prompt installs.  Decode attends
through per-step paging arrays (``kvcache.build_page_context``) that
ride into the compiled step as data, so continuous batching and
recovery never retrigger compilation.

Steps are two-phase to model collective lockstep: ``plan`` (host work —
admission, block allocation, prefix-cache sharing, all logged) then
``compute`` (the device step).  A fault between the phases leaves an
uncommitted log, which recovery rolls back (§3.3) — block tables from
the op log, pools by scattering the captured write-set rows back.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.block_log import BlockLog, BlockManager, BlockTable
from repro.core.migration import KVBlocks
from repro.serving.cache_ops import (capture_pool_rows,
                                     copy_block_prefixes,
                                     gather_request_blocks,
                                     infer_paged_axes, restore_pool_rows,
                                     restore_pool_rows_subset,
                                     scatter_request_blocks)
from repro.serving.kvcache import (build_chunk_context, build_page_context,
                                   max_blocks_per_seq, padded_block_ids)
from repro.serving.request import Request, RequestState
from repro.serving.sampling import SamplingParams, sample, spec_verify
from repro.serving.scheduler import LocalScheduler, StepPlan


def next_bucket(n: int, max_seq: int, min_bucket: int = 16) -> int:
    b = min_bucket
    while b < n:
        b *= 2
    return min(b, max_seq)


class MoEExecutor:
    """Stateless expert host: one EP rank's slice of the physical slots."""

    def __init__(self, physical_id: int, ep_rank: int,
                 shard: Dict[str, np.ndarray]):
        self.physical_id = physical_id
        self.ep_rank = ep_rank
        self.shard: Optional[Dict[str, np.ndarray]] = shard
        self.device_alive = True
        self.process_alive = True

    def fail_device(self) -> None:
        """Hardware gone: the only copies of these weights are lost."""
        self.device_alive = False
        self.shard = None

    def install_shard(self, shard: Dict[str, np.ndarray]) -> None:
        self.shard = shard
        self.device_alive = True
        self.process_alive = True


class DPExecutor:
    def __init__(self, physical_id: int, dp_rank: int, model, *,
                 max_batch: int, max_seq: int, num_blocks: int,
                 block_size: int, sampling: SamplingParams,
                 ep_rank: Optional[int] = None,
                 shard: Optional[Dict[str, np.ndarray]] = None,
                 paged_axes: Optional[list] = None,
                 admission: str = "chunked",
                 prefill_chunk: int = 32,
                 token_budget: Optional[int] = None,
                 prefix_cache: bool = True,
                 pool_undo: str = "rows",
                 spec_window: int = 0):
        self.physical_id = physical_id
        self.dp_rank = dp_rank
        self.model = model
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.sampling = sampling
        self.device_alive = True
        self.process_alive = True
        # collocated mode: this device also hosts an expert shard
        self.ep_rank = ep_rank
        self.shard = shard

        self.block_size = block_size
        self.num_blocks = num_blocks
        self.max_blk = max_blocks_per_seq(max_seq, block_size)
        self.trash_block = num_blocks      # the extra pool row (see model)
        self.block_manager = BlockManager(num_blocks, block_size)
        self.block_log = BlockLog()
        self.admission = admission
        self.pool_undo = pool_undo
        # chunked prefill needs a batch-width-free cache (attention-only
        # pools); recurrent-state models fall back to whole-prompt installs
        chunk = (prefill_chunk if admission == "chunked"
                 and model.supports_chunked_prefill else 0)
        self.chunk_tokens = chunk
        self.scheduler = LocalScheduler(
            max_batch, max_seq, self.block_manager,
            token_budget=(token_budget if admission == "chunked" else None),
            chunk_tokens=chunk,
            prefix_cache=prefix_cache and chunk > 0,
            window=model.cfg.sliding_window or None,
            max_prefills=1 if admission == "serial" else None,
            spec_window=spec_window)
        self.cache = model.init_paged_cache(max_batch, num_blocks,
                                            block_size)
        if paged_axes is None:   # the engine passes its shared copy in
            _, paged_axes = infer_paged_axes(model, num_blocks, block_size)
        self.paged_axes = paged_axes
        self.last_token = np.zeros((max_batch,), np.int32)
        self.steps_done = 0
        self._plan: Optional[StepPlan] = None
        # injected extra per-step latency (straggler simulation)
        self.simulated_slowdown_s = 0.0

    # -- lifecycle --------------------------------------------------------------

    @property
    def alive(self) -> bool:
        return self.device_alive and self.process_alive

    def fail_device(self) -> None:
        self.device_alive = False
        if self.shard is not None:
            self.shard = None  # collocated: expert weights die too

    def terminate_process(self) -> None:
        """Engine-side isolation of the failed/hanging process."""
        self.process_alive = False
        self._plan = None

    def drop_attention_state(self, collect_kv: bool = False):
        """Role switch (§3.4): shed KV caches, scheduler, attention duty.

        Returns the requests that must migrate elsewhere; with
        ``collect_kv`` their live blocks are extracted *first* (the donor
        device is healthy — §3.4's role switch, unlike a failure, can
        stream its residents' KV instead of forcing re-prefill) and the
        result is ``[(req, KVBlocks | None)]``."""
        payloads = {}
        if collect_kv:
            for req in list(self.scheduler.running):
                kv = self.export_kv_blocks(req)
                if kv is not None:
                    payloads[req.req_id] = kv
        reqs = self.scheduler.drain()
        self.cache = None
        self.block_log = BlockLog()
        if collect_kv:
            return [(r, payloads.get(r.req_id)) for r in reqs]
        return reqs

    def prefix_hit_blocks(self, digests, prompt_len: int) -> int:
        """How many *leading* full prompt blocks this executor's
        BlockManager can serve from its shared-prefix cache — the
        engine's in-instance affinity signal (``_assign``).  Mirrors the
        admission matcher: the prompt's final token is never cacheable
        (its logits must be computed), so the last block is skipped."""
        bs = self.block_size
        hits = 0
        for b, d in enumerate(digests):
            if (b + 1) * bs >= prompt_len:
                break
            if self.block_manager.lookup(d) is None:
                break
            hits += 1
        return hits

    # -- two-phase step -----------------------------------------------------------

    def plan(self) -> StepPlan:
        self.block_log.begin_step()
        plan = self.scheduler.plan_step(self.block_log)
        if self.cache is not None:
            # §3.3 device half: either the O(1) functional snapshot of
            # the whole cache (legacy; pins the pre-step pool buffers),
            # or — default — capture exactly the rows this step will
            # write, known at plan time, so rollback is O(write set) and
            # the pool buffers stay donation-friendly on TPU
            if self.pool_undo == "snapshot":
                self.block_log.snapshot_pools(self.cache)
            else:
                bids, offs = self._write_manifest(plan)
                self.block_log.record_pool_undo(capture_pool_rows(
                    self.cache, self.paged_axes, bids, offs))
            # prefix-cache COW: seed private divergence blocks from the
            # shared sources *after* the capture (the copies are part of
            # the step's write set and roll back with it); one batched
            # row scatter covers every COW admission of the step
            self.cache = copy_block_prefixes(self.cache, self.paged_axes,
                                             plan.cow_copies)
        self._plan = plan
        return plan

    def _write_manifest(self, plan: StepPlan
                        ) -> Tuple[np.ndarray, np.ndarray]:
        """Every (block, offset) pool row the planned step writes: decode
        destinations for all batch slots (idle slots hit the trash row),
        each chunk token's slot, whole-prefill installs (their padded
        block scatter covers every offset), and COW destination rows."""
        bs = self.block_size
        tables = self.scheduler.block_tables
        bids: List[int] = []
        offs: List[int] = []
        if plan.decode:
            row_bid = [self.trash_block] * self.max_batch
            row_off = [0] * self.max_batch
            for req in plan.decode:
                wp = req.num_tokens - 1
                blocks = tables[req.req_id].blocks
                row_bid[req.batch_slot] = blocks[wp // bs]
                row_off[req.batch_slot] = wp % bs
            bids += row_bid
            offs += row_off
        if plan.chunks or plan.spec:
            # speculation windows ride the same launch right after the
            # prefill pieces; their manifest rows are what the verify
            # phase partially restores for rejected drafts
            n = 0
            for piece in plan.chunks + plan.spec:
                blocks = tables[piece.req.req_id].blocks
                for j in range(piece.length):
                    pos = piece.start + j
                    bids.append(blocks[pos // bs])
                    offs.append(pos % bs)
                n += piece.length
            for _ in range(self.chunk_tokens - n):   # idle chunk rows
                bids.append(self.trash_block)
                offs.append(0)
        out_b = [np.asarray(bids, np.int32)]
        out_o = [np.asarray(offs, np.int32)]
        for req in plan.prefills:
            # the install scatter writes every offset of every padded
            # block id (bucket-sized, trash repeats included)
            bucket = next_bucket(len(req.tokens_so_far), self.max_seq)
            nblk = max_blocks_per_seq(bucket, bs)
            pb = padded_block_ids(tables[req.req_id].blocks, nblk,
                                  self.trash_block)
            out_b.append(np.repeat(pb, bs))
            out_o.append(np.tile(np.arange(bs, dtype=np.int32), nblk))
        for _, dst, n in plan.cow_copies:
            out_b.append(np.full((n,), dst, np.int32))
            out_o.append(np.arange(n, dtype=np.int32))
        return (np.concatenate(out_b).astype(np.int32),
                np.concatenate(out_o).astype(np.int32))

    def compute(self, ctx, step_no: int) -> List[Request]:
        """Run the planned step on device; returns finished requests."""
        plan, self._plan = self._plan, None
        assert plan is not None, "compute without plan"
        finished: List[Request] = []
        params, runtime = ctx.params, ctx.runtime

        if plan.chunks or plan.spec:
            tokens, page = build_chunk_context(
                plan.chunks + plan.spec, self.scheduler.block_tables,
                width=self.chunk_tokens, max_blk=self.max_blk,
                block_size=self.block_size, trash_block=self.trash_block)
            logits, self.cache = ctx.chunk_fn()(
                params, self.cache, tokens, page, runtime)
            logits = np.asarray(logits)
            row = 0
            for piece in plan.chunks:
                req = piece.req
                req.prefill_pos = piece.start + piece.length
                self.scheduler.note_chunk_done(piece, self.block_log)
                if piece.last:
                    # seed by sequence position, not engine step: the
                    # token is a pure function of (seed, prefix,
                    # position) and survives replay on any executor of
                    # any fleet instance
                    tok = int(sample(logits[row + piece.length - 1][None],
                                     self.sampling,
                                     step=req.num_tokens)[0])
                    req.output_tokens.append(tok)
                    req.note_token()
                    req.state = RequestState.RUNNING
                    self.last_token[req.batch_slot] = tok
                    if req.done or req.num_tokens >= self.max_seq:
                        self.scheduler.finish(req, self.block_log)
                        req.finish_time = time.monotonic()
                        finished.append(req)
                row += piece.length
            if plan.spec:
                finished.extend(self._verify_spec(plan, logits, row))

        for req in plan.prefills:
            toks = req.tokens_so_far
            bucket = next_bucket(len(toks), self.max_seq)
            padded = np.zeros((1, bucket), np.int32)
            padded[0, :len(toks)] = toks
            lengths = np.asarray([len(toks)], np.int32)
            prefill_fn = ctx.prefill_fn(bucket)
            last_logits, raw = prefill_fn(params, padded, lengths, runtime)
            nblk = max_blocks_per_seq(bucket, self.block_size)
            bids = padded_block_ids(
                self.scheduler.block_tables[req.req_id].blocks, nblk,
                self.trash_block)
            install_fn = ctx.install_fn(bucket)
            self.cache = install_fn(self.cache, raw, bids,
                                    np.int32(req.batch_slot))
            req.prefill_pos = len(toks)
            self.scheduler.note_prefill_done(len(toks))
            tok = int(sample(np.asarray(last_logits), self.sampling,
                             step=req.num_tokens)[0])
            req.output_tokens.append(tok)
            req.note_token()
            req.state = RequestState.RUNNING
            self.last_token[req.batch_slot] = tok
            if req.done:
                self.scheduler.finish(req, self.block_log)
                req.finish_time = time.monotonic()
                finished.append(req)

        if plan.decode:
            page = build_page_context(
                plan.decode, self.scheduler.block_tables,
                max_batch=self.max_batch, max_blk=self.max_blk,
                block_size=self.block_size, trash_block=self.trash_block)
            tokens = np.asarray(self.last_token)
            logits, new_cache = ctx.decode_fn(
                params, self.cache, tokens, page, runtime)
            self.cache = new_cache
            logits = np.asarray(logits)
            # one batched sample over the whole decode batch (the
            # per-request loop serialized B host round trips per step)
            slots = np.fromiter((r.batch_slot for r in plan.decode),
                                np.intp, count=len(plan.decode))
            positions = np.fromiter((r.num_tokens for r in plan.decode),
                                    np.int64, count=len(plan.decode))
            toks = sample(logits[slots], self.sampling, step=positions)
            for req, tok in zip(plan.decode, toks):
                tok = int(tok)
                req.output_tokens.append(tok)
                req.note_token()
                self.last_token[req.batch_slot] = tok
                # decode-grown blocks publish in the prefix cache as
                # they fill (carry-over (f)) — register before a
                # possible finish so the blocks park cache-addressable
                self.scheduler.note_decode_progress(req, self.block_log)
                if req.done or req.num_tokens >= self.max_seq:
                    self.scheduler.finish(req, self.block_log)
                    req.finish_time = time.monotonic()
                    finished.append(req)
        self.steps_done += 1
        return finished

    def _verify_spec(self, plan: StepPlan, logits: np.ndarray,
                     row0: int) -> List[Request]:
        """Commit each speculation window against the verifier logits.

        Window rows sit after the prefill-chunk rows in both the launch
        (logits rows) and the plan-time write manifest, in the same
        order — so a window's manifest indices are its logits rows
        shifted by the decode section.  Every emitted token is the
        seeded sampler's output at its own sequence position
        (``spec_verify``), keeping the stream token-identical to plain
        decode; pool rows written by rejected drafts are restored
        bit-exact from the §3.3 write-set capture (under the legacy
        snapshot strategy they are left stale, which is safe: a stale
        row's position is only ever attended after its true token's
        decode step rewrites it)."""
        finished: List[Request] = []
        undo = self.block_log.peek_pool_undo()
        base_manifest = self.max_batch if plan.decode else 0
        row = row0
        for win in plan.spec:
            req = win.req
            g = win.length
            base = req.num_tokens          # next position to commit
            drafts = win.tokens[base:]     # the g - 1 proposals
            toks, accepted = spec_verify(
                logits[row:row + g], drafts, self.sampling,
                start_step=base)
            emitted = 0
            for tok in toks:
                req.output_tokens.append(int(tok))
                req.note_token()
                self.last_token[req.batch_slot] = int(tok)
                emitted += 1
                if req.done or req.num_tokens >= self.max_seq:
                    break
            # window row r wrote the KV row of position base - 1 + r;
            # rows [emitted, g) hold drafts that were rejected (or never
            # reached) — scatter their pre-step rows back
            if emitted < g and undo is not None:
                idx = np.arange(base_manifest + row + emitted,
                                base_manifest + row + g, dtype=np.int32)
                self.cache = restore_pool_rows_subset(
                    self.cache, self.paged_axes, undo, idx)
            self.scheduler.note_spec_done(win, emitted, accepted)
            self.scheduler.note_decode_progress(req, self.block_log)
            if req.done or req.num_tokens >= self.max_seq:
                self.scheduler.finish(req, self.block_log)
                req.finish_time = time.monotonic()
                finished.append(req)
            row += g
        return finished

    def commit(self) -> None:
        """Step boundary reached: the undo log is no longer needed."""
        self.block_log.begin_step()  # clears; committed counter advances

    def rollback_inflight(self) -> int:
        """§3.3: undo all block ops of the in-flight (uncommitted) step —
        host block tables from the op log, device pools by restoring the
        step's captured write-set rows (or the legacy step-boundary
        snapshot), so table and pool agree exactly on which rows are
        live."""
        undo = self.block_log.take_pool_undo()
        snap = self.block_log.take_pool_snapshot()
        if self.cache is not None:
            if undo is not None:
                self.cache = restore_pool_rows(self.cache, self.paged_axes,
                                               undo)
            elif snap is not None:
                self.cache = snap
        n = self.block_log.undo_all(self.block_manager,
                                    self.scheduler.block_tables)
        # admissions from the aborted step return to the waiting queue
        self.scheduler.rollback_aborted()
        self._plan = None
        return n

    # -- KV-block migration (§3.2, streaming path) --------------------------------

    def export_kv_blocks(self, req: Request) -> Optional[KVBlocks]:
        """Extract a RUNNING request's live blocks + recurrent state.

        None when this device's state is unreachable or the request has
        no installed KV yet (still WAITING, mid-chunked-prefill, or
        mid-migration) — callers fall back to token-replay re-prefill.
        Prefix-shared blocks are read in place (sharing is refcounted;
        a gather never mutates), and window-released table entries ship
        trash rows the target's attention window masks identically."""
        if self.cache is None or not self.alive:
            return None
        if req.state is not RequestState.RUNNING or req.batch_slot is None:
            return None
        if self.scheduler.prefilling(req):
            return None
        table = self.scheduler.block_tables.get(req.req_id)
        if table is None or not req.output_tokens:
            return None
        valid_len = req.num_tokens - 1   # last sampled token's KV is not
        if valid_len <= 0:               # written until its decode step
            return None
        nblk = (valid_len + self.block_size - 1) // self.block_size
        bids = table.blocks[:nblk]
        # window-released entries are trash sentinels: ship no rows for
        # them (their positions are below the attention window forever)
        live_mask = [b < self.num_blocks for b in bids]
        live_bids = [b for b in bids if b < self.num_blocks]
        pools, state = gather_request_blocks(self.cache, self.paged_axes,
                                             live_bids, req.batch_slot)
        return KVBlocks(
            block_size=self.block_size, num_blocks=nblk,
            valid_len=valid_len,
            pool_blocks=[None if p is None else np.asarray(p)
                         for p in pools],
            state=[None if s is None else np.asarray(s) for s in state],
            last_token=int(req.output_tokens[-1]),
            live_mask=live_mask)

    def import_kv_blocks(self, req: Request, kv: KVBlocks) -> bool:
        """Install streamed blocks: allocate fresh physical blocks here,
        scatter the payload, and adopt the request as RUNNING — it skips
        re-prefill entirely and decodes on the next step.  False when
        this executor lacks a batch slot or enough free blocks."""
        if self.cache is None or not self.alive:
            return False
        if kv.block_size != self.block_size:
            return False
        if not self.scheduler._free_slots:
            return False
        span = max(kv.num_blocks, self.scheduler._blocks_needed(
            min(req.num_tokens + 1, self.max_seq)))
        live = (kv.live_mask if kv.live_mask is not None
                else [True] * kv.num_blocks)
        # dead (window-released) table entries install as trash
        # sentinels here too — only live payload blocks and the growth
        # region past the payload need real allocations
        need = sum(live) + (span - kv.num_blocks)
        if self.block_manager.num_allocatable < need:
            return False
        # host accounting mirrors admission; import runs at a step
        # boundary, so the ops commit immediately (log=None)
        table = BlockTable(req.req_id)
        for j in range(span):
            if j < kv.num_blocks and not live[j]:
                table.append_block(self.trash_block)
            else:
                table.append_block(self.block_manager.allocate())
        self.scheduler.block_tables[req.req_id] = table
        req.batch_slot = self.scheduler._free_slots.pop()
        req.dp_rank = self.dp_rank
        req.state = RequestState.RUNNING
        self.scheduler.running.append(req)
        self.scheduler.register_imported(req)
        live_ids = [table.blocks[j] for j in range(kv.num_blocks)
                    if live[j]]
        self.cache = scatter_request_blocks(
            self.cache, self.paged_axes, kv.pool_blocks, kv.state,
            np.asarray(live_ids, np.int32), req.batch_slot)
        self.last_token[req.batch_slot] = kv.last_token
        return True
