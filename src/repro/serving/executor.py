"""Executors: DPExecutor (attention rank) and MoEExecutor (expert rank).

A DPExecutor owns a local scheduler and the paged serving cache: block
pools (one trailing trash block for idle batch slots) addressed through
the ``BlockManager``/``BlockTable`` accounting, with the §3.3 undo log
covering both the host-side block ops and (via a functional snapshot)
the device-side pool writes.  Prefill scatters raw K/V into a request's
blocks; decode attends through per-step paging arrays
(``kvcache.build_page_context``) that ride into the compiled step as
data, so continuous batching and recovery never retrigger compilation.

Steps are two-phase to model collective lockstep: ``plan`` (host work —
admission, block allocation, all logged) then ``compute`` (the device
step).  A fault between the phases leaves an uncommitted log, which
recovery rolls back (§3.3) — block tables from the op log, pools from
the snapshot.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.core.block_log import BlockLog, BlockManager, BlockTable
from repro.core.migration import KVBlocks
from repro.serving.cache_ops import (gather_request_blocks,
                                     infer_paged_axes,
                                     scatter_request_blocks)
from repro.serving.kvcache import (build_page_context, max_blocks_per_seq,
                                   padded_block_ids)
from repro.serving.request import Request, RequestState
from repro.serving.sampling import SamplingParams, sample
from repro.serving.scheduler import LocalScheduler, StepPlan


def next_bucket(n: int, max_seq: int, min_bucket: int = 16) -> int:
    b = min_bucket
    while b < n:
        b *= 2
    return min(b, max_seq)


class MoEExecutor:
    """Stateless expert host: one EP rank's slice of the physical slots."""

    def __init__(self, physical_id: int, ep_rank: int,
                 shard: Dict[str, np.ndarray]):
        self.physical_id = physical_id
        self.ep_rank = ep_rank
        self.shard: Optional[Dict[str, np.ndarray]] = shard
        self.device_alive = True
        self.process_alive = True

    def fail_device(self) -> None:
        """Hardware gone: the only copies of these weights are lost."""
        self.device_alive = False
        self.shard = None

    def install_shard(self, shard: Dict[str, np.ndarray]) -> None:
        self.shard = shard
        self.device_alive = True
        self.process_alive = True


class DPExecutor:
    def __init__(self, physical_id: int, dp_rank: int, model, *,
                 max_batch: int, max_seq: int, num_blocks: int,
                 block_size: int, sampling: SamplingParams,
                 ep_rank: Optional[int] = None,
                 shard: Optional[Dict[str, np.ndarray]] = None,
                 paged_axes: Optional[list] = None):
        self.physical_id = physical_id
        self.dp_rank = dp_rank
        self.model = model
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.sampling = sampling
        self.device_alive = True
        self.process_alive = True
        # collocated mode: this device also hosts an expert shard
        self.ep_rank = ep_rank
        self.shard = shard

        self.block_size = block_size
        self.num_blocks = num_blocks
        self.max_blk = max_blocks_per_seq(max_seq, block_size)
        self.trash_block = num_blocks      # the extra pool row (see model)
        self.block_manager = BlockManager(num_blocks, block_size)
        self.block_log = BlockLog()
        self.scheduler = LocalScheduler(max_batch, max_seq,
                                        self.block_manager)
        self.cache = model.init_paged_cache(max_batch, num_blocks,
                                            block_size)
        if paged_axes is None:   # the engine passes its shared copy in
            _, paged_axes = infer_paged_axes(model, num_blocks, block_size)
        self.paged_axes = paged_axes
        self.last_token = np.zeros((max_batch,), np.int32)
        self.steps_done = 0
        self._plan: Optional[StepPlan] = None
        # injected extra per-step latency (straggler simulation)
        self.simulated_slowdown_s = 0.0

    # -- lifecycle --------------------------------------------------------------

    @property
    def alive(self) -> bool:
        return self.device_alive and self.process_alive

    def fail_device(self) -> None:
        self.device_alive = False
        if self.shard is not None:
            self.shard = None  # collocated: expert weights die too

    def terminate_process(self) -> None:
        """Engine-side isolation of the failed/hanging process."""
        self.process_alive = False
        self._plan = None

    def drop_attention_state(self, collect_kv: bool = False):
        """Role switch (§3.4): shed KV caches, scheduler, attention duty.

        Returns the requests that must migrate elsewhere; with
        ``collect_kv`` their live blocks are extracted *first* (the donor
        device is healthy — §3.4's role switch, unlike a failure, can
        stream its residents' KV instead of forcing re-prefill) and the
        result is ``[(req, KVBlocks | None)]``."""
        payloads = {}
        if collect_kv:
            for req in list(self.scheduler.running):
                kv = self.export_kv_blocks(req)
                if kv is not None:
                    payloads[req.req_id] = kv
        reqs = self.scheduler.drain()
        self.cache = None
        self.block_log = BlockLog()
        if collect_kv:
            return [(r, payloads.get(r.req_id)) for r in reqs]
        return reqs

    # -- two-phase step -----------------------------------------------------------

    def plan(self) -> StepPlan:
        self.block_log.begin_step()
        # §3.3 device half: the pool value at the step boundary
        self.block_log.snapshot_pools(self.cache)
        self._plan = self.scheduler.plan_step(self.block_log)
        return self._plan

    def compute(self, ctx, step_no: int) -> List[Request]:
        """Run the planned step on device; returns finished requests."""
        plan, self._plan = self._plan, None
        assert plan is not None, "compute without plan"
        finished: List[Request] = []
        params, runtime = ctx.params, ctx.runtime

        if plan.prefill is not None:
            req = plan.prefill
            toks = req.tokens_so_far
            bucket = next_bucket(len(toks), self.max_seq)
            padded = np.zeros((1, bucket), np.int32)
            padded[0, :len(toks)] = toks
            lengths = np.asarray([len(toks)], np.int32)
            prefill_fn = ctx.prefill_fn(bucket)
            last_logits, raw = prefill_fn(params, padded, lengths, runtime)
            nblk = max_blocks_per_seq(bucket, self.block_size)
            bids = padded_block_ids(
                self.scheduler.block_tables[req.req_id].blocks, nblk,
                self.trash_block)
            install_fn = ctx.install_fn(bucket)
            self.cache = install_fn(self.cache, raw, bids,
                                    np.int32(req.batch_slot))
            # seed by sequence position, not engine step: the token is a
            # pure function of (seed, prefix, position) and survives
            # replay on any executor of any fleet instance
            tok = int(sample(np.asarray(last_logits), self.sampling,
                             step=req.num_tokens)[0])
            req.output_tokens.append(tok)
            req.note_token()
            req.state = RequestState.RUNNING
            self.last_token[req.batch_slot] = tok
            if req.done:
                self.scheduler.finish(req, self.block_log)
                req.finish_time = time.monotonic()
                finished.append(req)

        if plan.decode:
            page = build_page_context(
                plan.decode, self.scheduler.block_tables,
                max_batch=self.max_batch, max_blk=self.max_blk,
                block_size=self.block_size, trash_block=self.trash_block)
            tokens = np.asarray(self.last_token)
            logits, new_cache = ctx.decode_fn(
                params, self.cache, tokens, page, runtime)
            self.cache = new_cache
            logits = np.asarray(logits)
            # one batched sample over the whole decode batch (the
            # per-request loop serialized B host round trips per step)
            slots = np.fromiter((r.batch_slot for r in plan.decode),
                                np.intp, count=len(plan.decode))
            positions = np.fromiter((r.num_tokens for r in plan.decode),
                                    np.int64, count=len(plan.decode))
            toks = sample(logits[slots], self.sampling, step=positions)
            for req, tok in zip(plan.decode, toks):
                tok = int(tok)
                req.output_tokens.append(tok)
                req.note_token()
                self.last_token[req.batch_slot] = tok
                if req.done or req.num_tokens >= self.max_seq:
                    self.scheduler.finish(req, self.block_log)
                    req.finish_time = time.monotonic()
                    finished.append(req)
        self.steps_done += 1
        return finished

    def commit(self) -> None:
        """Step boundary reached: the undo log is no longer needed."""
        self.block_log.begin_step()  # clears; committed counter advances

    def rollback_inflight(self) -> int:
        """§3.3: undo all block ops of the in-flight (uncommitted) step —
        host block tables from the op log, device pools from the step-
        boundary snapshot (any in-flight pool write is discarded with it,
        so table and pool agree exactly on which rows are live)."""
        snap = self.block_log.take_pool_snapshot()
        if snap is not None and self.cache is not None:
            self.cache = snap
        n = self.block_log.undo_all(self.block_manager,
                                    self.scheduler.block_tables)
        # admissions from the aborted step (their allocs were all undone,
        # leaving an empty block table) return to the waiting queue
        aborted = [r for r in self.scheduler.running
                   if self.scheduler.block_tables[r.req_id].num_blocks() == 0]
        for r in aborted:
            self.scheduler.running.remove(r)
            del self.scheduler.block_tables[r.req_id]
            if r.batch_slot is not None:
                self.scheduler._free_slots.append(r.batch_slot)
                r.batch_slot = None
            self.scheduler.requeue_front(r)
        self._plan = None
        return n

    # -- KV-block migration (§3.2, streaming path) --------------------------------

    def export_kv_blocks(self, req: Request) -> Optional[KVBlocks]:
        """Extract a RUNNING request's live blocks + recurrent state.

        None when this device's state is unreachable or the request has
        no installed KV yet (still WAITING, or mid-migration) — callers
        fall back to token-replay re-prefill."""
        if self.cache is None or not self.alive:
            return None
        if req.state is not RequestState.RUNNING or req.batch_slot is None:
            return None
        table = self.scheduler.block_tables.get(req.req_id)
        if table is None or not req.output_tokens:
            return None
        valid_len = req.num_tokens - 1   # last sampled token's KV is not
        if valid_len <= 0:               # written until its decode step
            return None
        nblk = (valid_len + self.block_size - 1) // self.block_size
        bids = table.blocks[:nblk]
        pools, state = gather_request_blocks(self.cache, self.paged_axes,
                                             bids, req.batch_slot)
        return KVBlocks(
            block_size=self.block_size, num_blocks=nblk,
            valid_len=valid_len,
            pool_blocks=[None if p is None else np.asarray(p)
                         for p in pools],
            state=[None if s is None else np.asarray(s) for s in state],
            last_token=int(req.output_tokens[-1]))

    def import_kv_blocks(self, req: Request, kv: KVBlocks) -> bool:
        """Install streamed blocks: allocate fresh physical blocks here,
        scatter the payload, and adopt the request as RUNNING — it skips
        re-prefill entirely and decodes on the next step.  False when
        this executor lacks a batch slot or enough free blocks."""
        if self.cache is None or not self.alive:
            return False
        if kv.block_size != self.block_size:
            return False
        if not self.scheduler._free_slots:
            return False
        need = max(kv.num_blocks, self.scheduler._blocks_needed(
            min(req.num_tokens + 1, self.max_seq)))
        if self.block_manager.num_free < need:
            return False
        # host accounting mirrors admission; import runs at a step
        # boundary, so the ops commit immediately (log=None)
        table = BlockTable(req.req_id)
        for _ in range(need):
            table.append_block(self.block_manager.allocate())
        self.scheduler.block_tables[req.req_id] = table
        req.batch_slot = self.scheduler._free_slots.pop()
        req.dp_rank = self.dp_rank
        req.state = RequestState.RUNNING
        self.scheduler.running.append(req)
        self.cache = scatter_request_blocks(
            self.cache, self.paged_axes, kv.pool_blocks, kv.state,
            np.asarray(table.blocks[:kv.num_blocks], np.int32),
            req.batch_slot)
        self.last_token[req.batch_slot] = kv.last_token
        return True
