"""Continuous-batching local scheduler (one per DPExecutor).

Plans a **token budget per step** (vLLM-style): every ongoing decode
costs one token, and the remaining budget admits prefill work — many
requests per step, each *chunked* so a long prompt interleaves with
ongoing decodes instead of stalling them.  Models whose prefill cannot
be chunked (recurrent state: SSM / hybrid) fall back to whole-prompt
prefills, still admitted under the same budget.

The scheduler also drives the content-hash **shared-prefix cache**:
admission matches the prompt's full blocks against the BlockManager's
digest index (ref-counted reuse, those tokens skip prefill compute
entirely) and plans a copy-on-write of the divergence block when a
cached block shares only the first few tokens.

All block accounting flows through the (logged) BlockManager so that a
mid-step failure can be rolled back exactly (§3.3).
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.core.block_log import (ROOT_DIGEST, BlockLog, BlockManager,
                                  BlockTable, block_digest, prompt_digests)
from repro.serving.request import Request, RequestState


def ngram_propose(tokens, max_draft: int, n: int = 2) -> Tuple[int, ...]:
    """Self-draft proposer (prompt-lookup decoding): find the most recent
    *earlier* occurrence of the sequence's final ``n``-gram and propose
    the tokens that followed it.  Free (no model call, no extra state)
    and strong exactly where speculation pays — repetitive continuations
    (code, templated text, multi-turn echoes).  Returns () when the
    sequence is too short or the n-gram never recurred; the request then
    decodes one token as usual."""
    t = list(tokens)
    if max_draft < 1 or len(t) < n + 1:
        return ()
    key = t[-n:]
    for i in range(len(t) - n - 1, -1, -1):
        if t[i:i + n] == key:
            return tuple(t[i + n:i + n + max_draft])
    return ()


@dataclass
class ChunkPiece:
    """One request's slice of this step's batched prefill chunk.

    Speculation windows (``StepPlan.spec``) reuse this shape: ``start``
    is the last committed token's position (its KV row is unwritten —
    row 0 of the window re-forwards it), ``tokens`` is the committed
    sequence plus the proposed drafts, and ``length`` is the full
    verify width (1 + drafts)."""
    req: Request
    start: int                 # first position computed this step
    length: int                # tokens computed this step
    tokens: Tuple[int, ...]    # the full sequence being prefilled
    last: bool                 # completes the prefill -> sample a token


@dataclass
class StepPlan:
    chunks: List[ChunkPiece] = field(default_factory=list)
    prefills: List[Request] = field(default_factory=list)  # whole-prompt
    decode: List[Request] = field(default_factory=list)
    # self-speculative verify windows: decode-ready requests whose next
    # few tokens ride the chunk graph as virtual decode slots
    spec: List[ChunkPiece] = field(default_factory=list)
    # (src_bid, dst_bid, n_tokens) device copies for prefix-cache COW
    cow_copies: List[Tuple[int, int, int]] = field(default_factory=list)

    @property
    def prefill(self) -> Optional[Request]:
        """Legacy convenience: the first whole-prompt admission."""
        return self.prefills[0] if self.prefills else None

    @property
    def empty(self) -> bool:
        return not (self.chunks or self.prefills or self.decode
                    or self.spec)


@dataclass
class _SeqInfo:
    """Host-side prefill bookkeeping for one admitted request."""
    tokens: Tuple[int, ...]
    target: int                # tokens [0, target) must be installed
    digests: List[bytes] = field(default_factory=list)
    next_register: int = 0     # first block index not yet hash-published
    cached_tokens: int = 0     # prefix-cache hit length (skipped compute)
    counted: bool = False      # cached_tokens folded into stats yet?
    released_upto: int = 0     # blocks [0, released_upto) window-freed


class LocalScheduler:
    def __init__(self, max_batch: int, max_seq: int,
                 block_manager: BlockManager, *,
                 token_budget: Optional[int] = None,
                 chunk_tokens: int = 0,
                 prefix_cache: bool = False,
                 window: Optional[int] = None,
                 max_prefills: Optional[int] = None,
                 spec_window: int = 0):
        """``token_budget``: per-step decode+prefill token target (None =
        unbounded).  ``chunk_tokens`` > 0 enables chunked prefill with
        that batched-chunk width; 0 selects whole-prompt prefills.
        ``prefix_cache`` turns on content-hash block reuse (chunked path
        only).  ``window`` frees blocks the sliding attention window has
        passed.  ``max_prefills`` caps whole-prompt admissions per step
        (1 = the legacy one-prefill-per-step engine).  ``spec_window``
        > 1 plans self-speculative verify windows of up to that many
        tokens for decode-ready requests (needs the chunked path — the
        windows ride the compiled chunk graph)."""
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.block_manager = block_manager
        self.token_budget = token_budget
        self.chunk_tokens = chunk_tokens
        self.prefix_cache = prefix_cache and chunk_tokens > 0
        self.window = window
        self.max_prefills = max_prefills
        self.spec_window = spec_window if chunk_tokens > 0 else 0
        # speculation-window width histogram {planned rows: count}
        self.spec_hist: Dict[int, int] = {}
        self.waiting: deque[Request] = deque()
        self.running: List[Request] = []
        self.block_tables: Dict[int, BlockTable] = {}
        self._free_slots = list(range(max_batch - 1, -1, -1))
        self._seq: Dict[int, _SeqInfo] = {}
        self._digest_cache: Dict[int, List[bytes]] = {}
        self.stats = {"prefill_tokens_computed": 0,
                      "prefill_tokens_cached": 0,
                      "prefill_chunks": 0,
                      "blocks_window_freed": 0,
                      "spec_windows": 0,
                      "spec_drafts": 0,
                      "spec_accepted": 0,
                      "spec_emitted": 0}

    # -- queue management -----------------------------------------------------

    def add_request(self, req: Request) -> None:
        req.state = RequestState.WAITING
        req.prefill_pos = 0
        self._seq.pop(req.req_id, None)
        self._digest_cache.pop(req.req_id, None)
        self.waiting.append(req)

    def memo_digests(self, req_id: int, digests: List[bytes]) -> None:
        """Seed the per-request chain-digest memo (a caller — e.g. the
        engine's prefix-affine ``_assign`` — already hashed the prompt;
        admission must not rehash it).  Only valid after
        ``add_request``, which clears any stale entry first."""
        self._digest_cache[req_id] = digests

    def drain(self) -> List[Request]:
        """Remove and return every request (used for migration §3.2)."""
        reqs = list(self.waiting) + list(self.running)
        self.waiting.clear()
        for r in list(self.running):
            self._release(r, log=None)
        self.running.clear()
        self._digest_cache.clear()   # waiting heads memoized here too
        return reqs

    def requeue_front(self, req: Request) -> None:
        """Requeue-after-export: a request whose step was rolled back (or
        that came back from a failed export) re-enters at the queue front
        so its completed decode prefix is re-prefilled before new work.
        Re-admission runs through the normal budgeted path, so the
        requeued prefill is charged against the step token budget like
        any other arrival."""
        req.state = RequestState.WAITING
        req.prefill_pos = 0
        self._seq.pop(req.req_id, None)
        self._digest_cache.pop(req.req_id, None)
        self.waiting.appendleft(req)

    def rollback_aborted(self) -> List[Request]:
        """After ``BlockLog.undo_all``: admissions from the aborted step
        (their allocs were all undone, leaving an empty block table)
        return to the waiting queue front.  Requeued in *reverse*
        admission order — each ``requeue_front`` prepends, so walking
        the aborted list backwards restores the original FIFO order
        when one step admitted several requests."""
        aborted = [r for r in self.running
                   if self.block_tables[r.req_id].num_blocks() == 0]
        for r in reversed(aborted):
            self.running.remove(r)
            del self.block_tables[r.req_id]
            if r.batch_slot is not None:
                self._free_slots.append(r.batch_slot)
                r.batch_slot = None
            self.requeue_front(r)
        return aborted

    def register_imported(self, req: Request) -> None:
        """Adopt a KV-block-streamed request (import path): its prefix is
        fully installed, so it decodes on the next step.  Its installed
        blocks register in the prefix cache immediately (carry-over (f))
        — a migrated conversation's prefix is shareable on the target
        from the moment it lands.  Import runs at a step boundary, so
        the registrations commit unlogged."""
        toks = tuple(req.tokens_so_far)
        req.prefill_pos = len(toks)
        info = _SeqInfo(tokens=toks, target=len(toks))
        self._seq[req.req_id] = info
        if self.prefix_cache:
            info.digests = prompt_digests(
                toks, self.block_manager.block_size)
            # KV rows exist for positions [0, num_tokens - 1): exactly
            # the full blocks below that bound are publishable
            self._register_upto(req, info, req.num_tokens - 1, None)

    def check_consistent(self) -> None:
        """Invariant check used by tests and cross-instance migration:
        slots + block tables exactly mirror the running set."""
        slots = [r.batch_slot for r in self.running]
        if None in slots or len(set(slots)) != len(slots):
            raise AssertionError(f"running slots corrupt: {slots}")
        if set(self._free_slots) & set(slots):
            raise AssertionError(
                f"slot both free and in use: {self._free_slots} vs {slots}")
        if len(self._free_slots) + len(slots) != self.max_batch:
            raise AssertionError(
                f"slot accounting leak: {len(self._free_slots)} free + "
                f"{len(slots)} running != {self.max_batch}")
        table_ids = set(self.block_tables)
        running_ids = {r.req_id for r in self.running}
        if table_ids != running_ids:
            raise AssertionError(
                f"block tables {table_ids} != running {running_ids}")

    @property
    def num_requests(self) -> int:
        return len(self.waiting) + len(self.running)

    def prefilling(self, req: Request) -> bool:
        info = self._seq.get(req.req_id)
        return info is not None and req.prefill_pos < info.target

    def prefill_target(self, req: Request) -> int:
        return self._seq[req.req_id].target

    # -- step planning ----------------------------------------------------------

    def _blocks_needed(self, n_tokens: int) -> int:
        bs = self.block_manager.block_size
        return (n_tokens + bs - 1) // bs

    @property
    def _trash(self) -> int:
        """Released table entries point at the pool's trash row (always
        masked by the window lower bound — readers never see it)."""
        return self.block_manager.num_blocks

    def plan_step(self, log: BlockLog) -> StepPlan:
        """Plan one generation step under the token budget.

        All block allocations / releases / cache acquisitions are
        recorded in ``log`` so a mid-step fault rolls back exactly.
        """
        plan = StepPlan()
        budget = (self.token_budget if self.token_budget is not None
                  else float("inf"))
        # 1. ongoing decodes first: a growing sequence may need a new
        #    block; sequences the window moved past release old ones.
        #    With speculation on, a decode-ready request whose n-gram
        #    proposer has drafts becomes a verify window on the chunk
        #    graph instead (it shares the chunk width with prefills)
        spec_room = self.chunk_tokens
        for req in self.running:
            if req.done or self.prefilling(req):
                continue
            pos = req.num_tokens  # position the next token will occupy
            # this step writes position pos - 1 and attends seq_len = pos
            # (build_page_context): release strictly below pos - window
            # BEFORE growing — at pool exhaustion the request's own dead
            # blocks must be able to feed its next allocation
            self._release_out_of_window(req, pos, log)
            g = self._plan_spec(plan, req, pos, spec_room, log)
            if g:
                spec_room -= g
                budget -= g
                continue
            table = self.block_tables[req.req_id]
            if self._blocks_needed(pos + 1) > table.num_blocks():
                bid = self.block_manager.allocate(log)
                table.append_block(bid, log)
            plan.decode.append(req)
        budget -= len(plan.decode)

        # 2. continue in-flight chunked prefills (admission order)
        room = spec_room
        for req in self.running:
            if room <= 0 or budget <= 0:
                break
            info = self._seq.get(req.req_id)
            if info is None or req.prefill_pos >= info.target:
                continue
            take = int(min(info.target - req.prefill_pos, room, budget))
            # windowed prompts: blocks every remaining chunk token has
            # already slid past are dead — free them BEFORE growing the
            # table, so an exhausted pool refills from the request's own
            # dead blocks instead of livelocking with take clamped to 0
            self._release_out_of_window(req, req.prefill_pos + 1, log)
            take = self._ensure_coverage(req, take, log)
            if take < 1:
                continue
            self._plan_piece(plan, req, info, take, log)
            room -= take
            budget -= take

        # 3. admissions
        while self.waiting and self._free_slots:
            if self.chunk_tokens > 0:
                if room <= 0 or budget <= 0:
                    break
                cap = room if budget == float("inf") else min(
                    room, int(budget))
                take = self._admit_chunked(plan, self.waiting[0], cap, log)
                if take is None:
                    break       # FIFO: blocked head defers the rest
                room -= take
                budget -= take
            else:
                if (self.max_prefills is not None
                        and len(plan.prefills) >= self.max_prefills):
                    break
                # the first whole-prompt prefill may overflow the budget
                # (a prompt longer than the budget must still admit);
                # later ones need headroom
                req = self.waiting[0]
                cost = len(req.tokens_so_far)
                if plan.prefills and budget < cost:
                    break
                if not self._admit_whole(req, log):
                    break
                plan.prefills.append(req)
                budget -= cost
        return plan

    def _plan_spec(self, plan: StepPlan, req: Request, pos: int,
                   room: int, log: BlockLog) -> int:
        """Plan a self-speculative verify window for a decode-ready
        request.  The window is a chunk piece over ``g`` virtual decode
        slots — row 0 re-forwards the last committed token (position
        ``pos - 1``, whose KV row this step writes anyway), rows 1..g-1
        forward the n-gram drafts — so it reuses the compiled chunk
        graph verbatim.  The block table grows to cover every window
        write position; pool pressure shrinks the window (a width-1
        window is just a decode and falls back to the decode batch).
        Returns the verify rows planned (0 = plain decode)."""
        if self.spec_window <= 1 or room <= 1:
            return 0
        limit = min(self.spec_window, room,
                    req.max_new_tokens - len(req.output_tokens),
                    self.max_seq - pos + 1)
        if limit <= 1:
            return 0
        drafts = ngram_propose(req.tokens_so_far, limit - 1)
        if not drafts:
            return 0
        g = 1 + len(drafts)
        # cover write positions pos - 1 .. pos + g - 2
        table = self.block_tables[req.req_id]
        bs = self.block_manager.block_size
        grow = self._blocks_needed(pos + g - 1) - table.num_blocks()
        if grow > 0:
            grow = min(grow, self.block_manager.num_allocatable)
            for _ in range(grow):
                table.append_block(self.block_manager.allocate(log), log)
            g = min(g, table.num_blocks() * bs - pos + 1)
        if g <= 1:
            return 0
        toks = tuple(req.tokens_so_far) + drafts[:g - 1]
        plan.spec.append(ChunkPiece(req, pos - 1, g, toks, last=False))
        self.stats["spec_windows"] += 1
        self.stats["spec_drafts"] += g - 1
        self.spec_hist[g] = self.spec_hist.get(g, 0) + 1
        return g

    # -- plan-ahead (overlap pipeline) --------------------------------------------

    def predict_next_token(self, req: Request, context=None) -> int:
        """Value guess for the token an in-flight device step will emit
        for ``req``, so the *next* step can be planned before this one
        drains.  Uses the n-gram proposer (free, and right exactly where
        drafts are right); falls back to repeating the last token.  The
        guess only shapes plan quality — drafts proposed from it, the
        planner's done-check — never the emitted stream: the host
        sampler re-derives every token from the drained logits and is
        authoritative.  It is sanitized away from EOS so plan-ahead
        never skips a request on a guessed finish."""
        toks = list(context) if context is not None else req.tokens_so_far
        prop = ngram_propose(toks, 1)
        guess = int(prop[0]) if prop else (int(toks[-1]) if toks else 0)
        if req.eos_token is not None and guess == int(req.eos_token):
            guess = 0 if guess != 0 else 1
        return guess

    def unwind_plan_stats(self, plan: "StepPlan") -> None:
        """Reconcile path: a plan-ahead step was rolled back before it
        committed — back out the advisory counters its plan/launch
        bumped so the relaunched step doesn't double-count."""
        for piece in plan.chunks:
            self.stats["prefill_tokens_computed"] -= piece.length
            self.stats["prefill_chunks"] -= 1
        for win in plan.spec:
            self.stats["spec_windows"] -= 1
            self.stats["spec_drafts"] -= win.length - 1
            self.spec_hist[win.length] -= 1

    # -- admission internals -----------------------------------------------------

    def _ensure_coverage(self, req: Request, take: int,
                         log: BlockLog) -> int:
        """Grow the block table to cover the next chunk piece.

        Windowed prompts allocate lazily (admission only covered the
        first piece), so a long prompt never holds O(prompt) blocks —
        paired with the in-prefill window release, occupancy stays
        O(window + chunk).  When the pool cannot cover the whole piece,
        the piece shrinks to what fits (the request resumes next step)."""
        table = self.block_tables[req.req_id]
        bs = self.block_manager.block_size
        need = self._blocks_needed(req.prefill_pos + take)
        grow = need - table.num_blocks()
        if grow > 0:
            grow = min(grow, self.block_manager.num_allocatable)
            for _ in range(grow):
                table.append_block(self.block_manager.allocate(log), log)
            take = min(take, table.num_blocks() * bs - req.prefill_pos)
        return take

    def _plan_piece(self, plan: StepPlan, req: Request, info: _SeqInfo,
                    take: int, log: BlockLog) -> None:
        start = req.prefill_pos
        last = start + take >= info.target
        plan.chunks.append(ChunkPiece(req, start, take, info.tokens, last))

    def _register_upto(self, req: Request, info: _SeqInfo, upto: int,
                       log: Optional[BlockLog]) -> None:
        """Publish prompt blocks whose content is now installed under
        their chain digests.  Called from the *compute* phase, after the
        chunk scatter ran — a digest must never be matchable before its
        rows exist, or a same-step admission would share garbage."""
        bs = self.block_manager.block_size
        table = self.block_tables[req.req_id]
        while (info.next_register < len(info.digests)
               and (info.next_register + 1) * bs <= upto):
            b = info.next_register
            bid = table.blocks[b]
            if bid < self.block_manager.num_blocks:  # not released
                parent = info.digests[b - 1] if b else ROOT_DIGEST
                self.block_manager.register(
                    bid, info.digests[b], parent,
                    info.tokens[b * bs:(b + 1) * bs], log)
            info.next_register += 1

    def _admit_chunked(self, plan: StepPlan, req: Request, take_cap: int,
                       log: BlockLog) -> Optional[int]:
        """Admit the queue head onto the chunked path; returns the token
        cost of its first piece (None = cannot admit this step)."""
        bm = self.block_manager
        bs = bm.block_size
        toks = tuple(req.tokens_so_far)
        target = len(toks)
        # memoized per request: a head-of-line prompt that cannot admit
        # for many steps (pool pressure) must not rehash every plan
        digests: List[bytes] = []
        if self.prefix_cache:
            digests = self._digest_cache.get(req.req_id)
            if digests is None:
                digests = prompt_digests(toks, bs)
                self._digest_cache[req.req_id] = digests

        # full-block prefix hits — never the entire prompt: the final
        # token must be computed to produce the first-sample logits
        matched: List[bytes] = []
        parked = 0
        for b, d in enumerate(digests):
            if (b + 1) * bs >= target:
                break
            bid = bm.lookup(d)
            if bid is None:
                break
            matched.append(d)
            if bm.ref_count(bid) == 0:
                parked += 1
        # copy-on-write at the divergence block: a cached block sharing
        # the first q tokens after the matched prefix seeds the
        # request's private block via a device row copy
        cow_src, cow_q = None, 0
        if self.prefix_cache:
            parent = matched[-1] if matched else ROOT_DIGEST
            rem = toks[len(matched) * bs: target - 1][:bs]
            if rem:
                for bid, cand in bm.children_of(parent):
                    q = 0
                    for a, c in zip(rem, cand):
                        if a != c:
                            break
                        q += 1
                    if q > cow_q:
                        cow_src, cow_q = bid, q

        cached_tokens = len(matched) * bs + cow_q
        take = int(min(target - cached_tokens, take_cap))
        if take < 1:
            return None
        if self.window:
            # lazy allocation: cover only the first piece; continuations
            # grow (and window-release) the table chunk by chunk, so a
            # long prompt never pins O(prompt) blocks
            cover = cached_tokens + take
        else:
            cover = min(target + 1, self.max_seq)
        fresh = self._blocks_needed(cover) - len(matched)
        if bm.num_allocatable - parked < fresh:
            if self.window and bm.num_allocatable - parked > 0:
                fresh = bm.num_allocatable - parked
                take = min(take,
                           (len(matched) + fresh) * bs - cached_tokens)
                if take < 1:
                    return None
            else:
                return None

        self.waiting.popleft()
        table = BlockTable(req.req_id)
        for d in matched:
            table.append_block(bm.acquire_cached(d, log), log)
        for _ in range(fresh):
            table.append_block(bm.allocate(log), log)
        self.block_tables[req.req_id] = table
        req.state = RequestState.RUNNING
        req.batch_slot = self._free_slots.pop()
        req.prefill_pos = cached_tokens
        self.running.append(req)
        if cow_src is not None:
            plan.cow_copies.append(
                (cow_src, table.blocks[len(matched)], cow_q))
        self._digest_cache.pop(req.req_id, None)
        info = _SeqInfo(tokens=toks, target=target, digests=digests,
                        next_register=len(matched),
                        cached_tokens=cached_tokens)
        self._seq[req.req_id] = info
        self._plan_piece(plan, req, info, take, log)
        return take

    def _admit_whole(self, req: Request, log: BlockLog) -> bool:
        """Legacy whole-prompt admission (models with recurrent prefill
        state; also the one-prefill-per-step baseline)."""
        bm = self.block_manager
        toks = tuple(req.tokens_so_far)
        need = self._blocks_needed(min(len(toks) + 1, self.max_seq))
        if bm.num_allocatable < need:
            return False
        self.waiting.popleft()
        table = BlockTable(req.req_id)
        for _ in range(need):
            table.append_block(bm.allocate(log), log)
        self.block_tables[req.req_id] = table
        req.state = RequestState.RUNNING
        req.batch_slot = self._free_slots.pop()
        req.prefill_pos = 0
        self.running.append(req)
        self._seq[req.req_id] = _SeqInfo(tokens=toks, target=len(toks))
        return True

    # -- sliding-window block release ---------------------------------------------

    def _release_out_of_window(self, req: Request, seq_len: int,
                               log: Optional[BlockLog]) -> None:
        """Free blocks entirely below the attention window's lower bound
        (ROADMAP paged-KV follow-up (b)): the smallest attention this
        step runs covers ``[seq_len - window, seq_len)``, so everything
        strictly below that bound is never attended again.  The table
        entry keeps its index but points at the trash row; pool
        occupancy stays O(window) per sequence."""
        if not self.window:
            return
        info = self._seq.get(req.req_id)
        if info is None:
            return
        start = max(seq_len - self.window, 0)
        bs = self.block_manager.block_size
        table = self.block_tables[req.req_id]
        # self-heal after a §3.3 rollback: undone releases restored real
        # block ids below the watermark — walk it back so they free again
        while (info.released_upto > 0
               and table.blocks[info.released_upto - 1]
               < self.block_manager.num_blocks):
            info.released_upto -= 1
        while (info.released_upto + 1) * bs <= start:
            idx = info.released_upto
            bid = table.blocks[idx]
            if bid < self.block_manager.num_blocks:
                table.set_block(idx, self._trash, log)
                self.block_manager.free(bid, log)
                self.stats["blocks_window_freed"] += 1
            info.released_upto += 1

    # -- stats (advisory; committed-step granularity) --------------------------------

    def note_chunk_done(self, piece: ChunkPiece,
                        log: Optional[BlockLog] = None) -> None:
        """Compute-phase bookkeeping for one executed chunk piece: stats,
        plus hash-publishing the prompt blocks the piece completed (their
        rows are in the pool now)."""
        self.stats["prefill_tokens_computed"] += piece.length
        self.stats["prefill_chunks"] += 1
        info = self._seq.get(piece.req.req_id)
        if info is None:
            return
        if not info.counted:
            self.stats["prefill_tokens_cached"] += info.cached_tokens
            info.counted = True
        if self.prefix_cache and info.digests:
            self._register_upto(piece.req, info,
                                piece.start + piece.length, log)

    def note_prefill_done(self, n_tokens: int) -> None:
        self.stats["prefill_tokens_computed"] += n_tokens

    def note_decode_progress(self, req: Request,
                             log: Optional[BlockLog] = None) -> None:
        """Carry-over (f): publish *decode-grown* blocks in the prefix
        cache.  Called after decode/speculation tokens commit: KV rows
        exist for positions [0, num_tokens - 1) (the newest token's row
        is written by its next forward), so every full block below that
        bound is registrable — a multi-turn follow-up whose prompt
        embeds this conversation then hits the cache past the original
        prompt, not just up to it."""
        if not self.prefix_cache:
            return
        info = self._seq.get(req.req_id)
        if info is None:
            return
        bs = self.block_manager.block_size
        # overlap pipeline: only *committed* tokens are registrable —
        # the speculative tail holds plan-ahead guesses whose values
        # (and KV rows) are still in flight
        committed = req.num_tokens - req.speculative_tokens
        kv_complete = committed - 1
        full = kv_complete // bs
        if len(info.digests) < full:
            toks = tuple((req.prompt_tokens + req.committed_output)
                         if req.speculative_tokens else req.tokens_so_far)
            info.tokens = toks   # registration reads block token slices
            while len(info.digests) < full:
                b = len(info.digests)
                parent = info.digests[b - 1] if b else ROOT_DIGEST
                info.digests.append(
                    block_digest(parent, toks[b * bs:(b + 1) * bs]))
        self._register_upto(req, info, kv_complete, log)

    def note_spec_done(self, piece: ChunkPiece, emitted: int,
                       accepted: int) -> None:
        """Compute-phase bookkeeping for one verified speculation
        window: ``emitted`` tokens committed (>= 1), ``accepted`` of the
        window's drafts matched the verifier."""
        self.stats["spec_accepted"] += accepted
        self.stats["spec_emitted"] += emitted

    # -- completion -------------------------------------------------------------------

    def finish(self, req: Request, log: Optional[BlockLog]) -> None:
        req.state = RequestState.FINISHED
        self._release(req, log)
        self.running.remove(req)

    def _release(self, req: Request, log: Optional[BlockLog]) -> None:
        table = self.block_tables.pop(req.req_id, None)
        if table is not None:
            for bid in reversed(table.blocks):
                if bid < self.block_manager.num_blocks:  # skip released
                    self.block_manager.free(bid, log)
        self._seq.pop(req.req_id, None)
        self._digest_cache.pop(req.req_id, None)
        if req.batch_slot is not None:
            self._free_slots.append(req.batch_slot)
            req.batch_slot = None
