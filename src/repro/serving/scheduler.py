"""Continuous-batching local scheduler (one per DPExecutor).

Decides, each generation step, which sequences prefill/decode, and drives
all paged-KV block accounting through the (logged) BlockManager so that a
mid-step failure can be rolled back exactly (§3.3).
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.core.block_log import BlockLog, BlockManager, BlockTable
from repro.serving.request import Request, RequestState


@dataclass
class StepPlan:
    prefill: Optional[Request] = None
    decode: List[Request] = field(default_factory=list)

    @property
    def empty(self) -> bool:
        return self.prefill is None and not self.decode


class LocalScheduler:
    def __init__(self, max_batch: int, max_seq: int,
                 block_manager: BlockManager):
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.block_manager = block_manager
        self.waiting: deque[Request] = deque()
        self.running: List[Request] = []
        self.block_tables: Dict[int, BlockTable] = {}
        self._free_slots = list(range(max_batch - 1, -1, -1))

    # -- queue management -----------------------------------------------------

    def add_request(self, req: Request) -> None:
        req.state = RequestState.WAITING
        self.waiting.append(req)

    def drain(self) -> List[Request]:
        """Remove and return every request (used for migration §3.2)."""
        reqs = list(self.waiting) + list(self.running)
        self.waiting.clear()
        for r in list(self.running):
            self._release(r, log=None)
        self.running.clear()
        return reqs

    def requeue_front(self, req: Request) -> None:
        """Requeue-after-export: a request whose step was rolled back (or
        that came back from a failed export) re-enters at the queue front
        so its completed decode prefix is re-prefilled before new work."""
        req.state = RequestState.WAITING
        self.waiting.appendleft(req)

    def check_consistent(self) -> None:
        """Invariant check used by tests and cross-instance migration:
        slots + block tables exactly mirror the running set."""
        slots = [r.batch_slot for r in self.running]
        if None in slots or len(set(slots)) != len(slots):
            raise AssertionError(f"running slots corrupt: {slots}")
        if set(self._free_slots) & set(slots):
            raise AssertionError(
                f"slot both free and in use: {self._free_slots} vs {slots}")
        if len(self._free_slots) + len(slots) != self.max_batch:
            raise AssertionError(
                f"slot accounting leak: {len(self._free_slots)} free + "
                f"{len(slots)} running != {self.max_batch}")
        table_ids = set(self.block_tables)
        running_ids = {r.req_id for r in self.running}
        if table_ids != running_ids:
            raise AssertionError(
                f"block tables {table_ids} != running {running_ids}")

    @property
    def num_requests(self) -> int:
        return len(self.waiting) + len(self.running)

    # -- step planning ----------------------------------------------------------

    def _blocks_needed(self, n_tokens: int) -> int:
        bs = self.block_manager.block_size
        return (n_tokens + bs - 1) // bs

    def plan_step(self, log: BlockLog) -> StepPlan:
        """Admit at most one prefill per step (vLLM-style), decode the rest.

        All block allocations are recorded in ``log``.
        """
        plan = StepPlan()
        # decode bookkeeping first: growing sequences may need a new block
        for req in self.running:
            if req.done:
                continue
            pos = req.num_tokens  # position the next token will occupy
            table = self.block_tables[req.req_id]
            if self._blocks_needed(pos + 1) > table.num_blocks():
                bid = self.block_manager.allocate(log)
                table.append_block(bid, log)
            plan.decode.append(req)
        # admission
        if self.waiting and self._free_slots:
            req = self.waiting[0]
            need = self._blocks_needed(
                min(req.num_tokens + 1, self.max_seq))
            if self.block_manager.num_free >= need:
                self.waiting.popleft()
                table = BlockTable(req.req_id)
                for _ in range(need):
                    table.append_block(self.block_manager.allocate(log), log)
                self.block_tables[req.req_id] = table
                req.state = RequestState.RUNNING
                req.batch_slot = self._free_slots.pop()
                self.running.append(req)
                plan.prefill = req
        return plan

    def finish(self, req: Request, log: Optional[BlockLog]) -> None:
        req.state = RequestState.FINISHED
        self._release(req, log)
        self.running.remove(req)

    def _release(self, req: Request, log: Optional[BlockLog]) -> None:
        table = self.block_tables.pop(req.req_id, None)
        if table is not None:
            for bid in reversed(table.blocks):
                self.block_manager.free(bid, log)
        if req.batch_slot is not None:
            self._free_slots.append(req.batch_slot)
            req.batch_slot = None
