"""Paged KV cache: physical block pools addressed through block tables.

This is the engine's **only** compiled serving-cache representation (the
dense per-slot ring caches in ``repro.models.attention`` remain as the
reference decode semantics, proven equivalent in
tests/test_paged_serving.py).  The host-side twin is
``repro.core.block_log``: the BlockManager/BlockTable decide *which*
physical block a token lands in (all logged/undoable); the device-side
pools live inside the model's paged cache pytree
(``Model.init_paged_cache``) and are attended through
``ops.paged_attention`` — the Pallas kernel on TPU, its jnp oracle on
CPU.

This module owns the host-side glue: packing the per-step paging arrays
(block tables, valid lengths, write destinations) that ride into the
compiled decode step as data, so continuous batching, migration, and
recovery never retrigger compilation.
"""
from __future__ import annotations

from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.kernels import ops


def max_blocks_per_seq(max_seq: int, block_size: int) -> int:
    return (max_seq + block_size - 1) // block_size


def table_array(tables: Dict[int, "BlockTable"], order: List[int],
                max_blk: int) -> np.ndarray:
    """Pack host-side block tables into the (B, max_blk) device array."""
    out = np.zeros((len(order), max_blk), np.int32)
    for i, seq_id in enumerate(order):
        blocks = tables[seq_id].blocks
        out[i, : len(blocks)] = blocks[:max_blk]
    return out


def build_page_context(decode_reqs, block_tables, *, max_batch: int,
                       max_blk: int, block_size: int,
                       trash_block: int) -> Dict[str, np.ndarray]:
    """The per-step paging arrays for one decode batch.

    For each request in ``decode_reqs`` (occupying ``req.batch_slot``),
    position ``num_tokens - 1`` is where this step's incoming token
    lands; ``seq_lens`` is the valid length including it.  Idle batch
    slots keep seq_len 0 and write into the trash block, so a full-width
    decode step never touches live blocks.
    """
    tables = np.zeros((max_batch, max_blk), np.int32)
    seq_lens = np.zeros((max_batch,), np.int32)
    write_bid = np.full((max_batch,), trash_block, np.int32)
    write_off = np.zeros((max_batch,), np.int32)
    for req in decode_reqs:
        slot = req.batch_slot
        blocks = block_tables[req.req_id].blocks
        tables[slot, : len(blocks)] = blocks[:max_blk]
        wp = req.num_tokens - 1              # position of the new token
        seq_lens[slot] = wp + 1
        write_bid[slot] = blocks[wp // block_size]
        write_off[slot] = wp % block_size
    return {"tables": tables, "seq_lens": seq_lens,
            "write_bid": write_bid, "write_off": write_off}


def build_chunk_context(pieces, block_tables, *, width: int, max_blk: int,
                        block_size: int, trash_block: int):
    """Pack a batched multi-request prefill chunk into paging arrays.

    A chunked prefill step is a decode step over ``width`` *virtual
    slots*: row ``r`` carries one prompt token, its owner's block table,
    ``seq_lens`` = its absolute position + 1 (so causal attention over
    the pool covers the already-installed prefix AND earlier rows of the
    same chunk, whose K/V land in the pool before attention runs), and
    the (block, offset) its own K/V is written to.  Requests of any
    length mix freely in one chunk — raggedness is pure data, so the
    compiled graph never re-specializes.  Rows past the planned tokens
    are idle: seq_len 0, writes into the trash block.

    ``pieces``: objects with ``.req`` (owning Request), ``.start``
    (first position this step), ``.length`` and ``.tokens`` (the full
    token sequence being prefilled).  Returns ``(tokens, page)``.
    """
    tokens = np.zeros((width,), np.int32)
    tables = np.zeros((width, max_blk), np.int32)
    seq_lens = np.zeros((width,), np.int32)
    write_bid = np.full((width,), trash_block, np.int32)
    write_off = np.zeros((width,), np.int32)
    row = 0
    for piece in pieces:
        blocks = block_tables[piece.req.req_id].blocks
        packed = np.asarray(blocks[:max_blk], np.int32)
        for j in range(piece.length):
            pos = piece.start + j
            tokens[row] = piece.tokens[pos]
            tables[row, : len(packed)] = packed
            seq_lens[row] = pos + 1
            write_bid[row] = blocks[pos // block_size]
            write_off[row] = pos % block_size
            row += 1
    assert row <= width, (row, width)
    page = {"tables": tables, "seq_lens": seq_lens,
            "write_bid": write_bid, "write_off": write_off}
    return tokens, page


def page_context_specs(max_batch: int, max_blk: int):
    i32 = jnp.int32
    return {
        "tables": jax.ShapeDtypeStruct((max_batch, max_blk), i32),
        "seq_lens": jax.ShapeDtypeStruct((max_batch,), i32),
        "write_bid": jax.ShapeDtypeStruct((max_batch,), i32),
        "write_off": jax.ShapeDtypeStruct((max_batch,), i32),
    }


def padded_block_ids(blocks: List[int], nblk: int,
                     trash_block: int) -> np.ndarray:
    """A request's block ids padded to the prefill bucket's block count;
    ids past the table point at the trash block (their rows are dead)."""
    out = np.full((nblk,), trash_block, np.int32)
    out[: min(len(blocks), nblk)] = blocks[:nblk]
    return out


class PagedKVCache:
    """Standalone per-layer K/V pools — the unit-test twin of the pools
    inside the engine's paged cache pytree (kept for kernel-level tests
    and ad-hoc experiments; the engine uses ``Model.init_paged_cache``)."""

    def __init__(self, cfg: ModelConfig, num_layers: int, num_blocks: int,
                 block_size: int, dtype=jnp.float32):
        Dh = cfg.resolved_head_dim()
        self.cfg = cfg
        self.block_size = block_size
        self.num_blocks = num_blocks
        shape = (num_layers, num_blocks, block_size, cfg.num_kv_heads, Dh)
        self.k_pool = jnp.zeros(shape, dtype)
        self.v_pool = jnp.zeros(shape, dtype)

    def write_token(self, layer: int, block_id: int, offset: int,
                    k: jnp.ndarray, v: jnp.ndarray) -> None:
        """Write one token's K/V (Hkv, Dh) into (block, offset)."""
        self.k_pool = self.k_pool.at[layer, block_id, offset].set(
            k.astype(self.k_pool.dtype))
        self.v_pool = self.v_pool.at[layer, block_id, offset].set(
            v.astype(self.v_pool.dtype))

    def write_prefill(self, layer: int, block_ids: List[int],
                      k: jnp.ndarray, v: jnp.ndarray) -> None:
        """Write a whole prompt's K/V (S, Hkv, Dh) into its blocks."""
        S = k.shape[0]
        bs = self.block_size
        for j, bid in enumerate(block_ids):
            lo = j * bs
            if lo >= S:
                break
            hi = min(lo + bs, S)
            self.k_pool = self.k_pool.at[layer, bid, : hi - lo].set(
                k[lo:hi].astype(self.k_pool.dtype))
            self.v_pool = self.v_pool.at[layer, bid, : hi - lo].set(
                v[lo:hi].astype(self.v_pool.dtype))

    def attend(self, layer: int, q: jnp.ndarray,
               block_table: jnp.ndarray, seq_lens: jnp.ndarray,
               use_pallas: bool = False) -> jnp.ndarray:
        """Decode attention for one layer.

        q: (B, H, Dh); block_table: (B, max_blk) int32; seq_lens: (B,).
        use_pallas: run the Pallas kernel (interpret mode on CPU).
        """
        return ops.paged_attention(q, self.k_pool[layer],
                                   self.v_pool[layer], block_table,
                                   seq_lens, use_pallas=use_pallas)
