"""Paged KV cache: physical block pools addressed through block tables.

This is the device-side twin of the host-side block accounting in
``repro.core.block_log``: the BlockManager/BlockTable decide *which*
physical block a token lands in (all logged/undoable); this module owns
the tensor pools and the attention over them.  The attention hot path is
the Pallas ``paged_attention`` kernel (TPU) / its jnp oracle (CPU).

Used by the TPU-native decode path and the paged-serving integration
tests; the CPU engine's compiled path uses ring caches (DESIGN.md §2),
with equivalence between the two proven in tests/test_paged_serving.py.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.kernels import ops


class PagedKVCache:
    """Per-layer K/V pools of shape (num_blocks, block_size, Hkv, Dh)."""

    def __init__(self, cfg: ModelConfig, num_layers: int, num_blocks: int,
                 block_size: int, dtype=jnp.float32):
        Dh = cfg.resolved_head_dim()
        self.cfg = cfg
        self.block_size = block_size
        self.num_blocks = num_blocks
        shape = (num_layers, num_blocks, block_size, cfg.num_kv_heads, Dh)
        self.k_pool = jnp.zeros(shape, dtype)
        self.v_pool = jnp.zeros(shape, dtype)

    def write_token(self, layer: int, block_id: int, offset: int,
                    k: jnp.ndarray, v: jnp.ndarray) -> None:
        """Write one token's K/V (Hkv, Dh) into (block, offset)."""
        self.k_pool = self.k_pool.at[layer, block_id, offset].set(
            k.astype(self.k_pool.dtype))
        self.v_pool = self.v_pool.at[layer, block_id, offset].set(
            v.astype(self.v_pool.dtype))

    def write_prefill(self, layer: int, block_ids: List[int],
                      k: jnp.ndarray, v: jnp.ndarray) -> None:
        """Write a whole prompt's K/V (S, Hkv, Dh) into its blocks."""
        S = k.shape[0]
        bs = self.block_size
        for j, bid in enumerate(block_ids):
            lo = j * bs
            if lo >= S:
                break
            hi = min(lo + bs, S)
            self.k_pool = self.k_pool.at[layer, bid, : hi - lo].set(
                k[lo:hi].astype(self.k_pool.dtype))
            self.v_pool = self.v_pool.at[layer, bid, : hi - lo].set(
                v[lo:hi].astype(self.v_pool.dtype))

    def attend(self, layer: int, q: jnp.ndarray,
               block_table: jnp.ndarray, seq_lens: jnp.ndarray,
               use_pallas: bool = False) -> jnp.ndarray:
        """Decode attention for one layer.

        q: (B, H, Dh); block_table: (B, max_blk) int32; seq_lens: (B,).
        use_pallas: run the Pallas kernel (interpret mode on CPU).
        """
        return ops.paged_attention(q, self.k_pool[layer],
                                   self.v_pool[layer], block_table,
                                   seq_lens, use_pallas=use_pallas)


def table_array(tables: Dict[int, "BlockTable"], order: List[int],
                max_blk: int) -> np.ndarray:
    """Pack host-side block tables into the (B, max_blk) device array."""
    out = np.zeros((len(order), max_blk), np.int32)
    for i, seq_id in enumerate(order):
        blocks = tables[seq_id].blocks
        out[i, : len(blocks)] = blocks[:max_blk]
    return out
