"""FlowServe-style inference engine with ReviveMoE recovery wired in.

One process simulates the whole deployment: executors are logical ranks
owning physically separate state (expert shards, KV caches, block
tables), so injected hardware failures destroy real state and recovery
manipulates real data structures, real compiled executables, and real
weight files.

Two deployment modes (§2.2):
* ``collocated``   — every device hosts attention + an EP expert shard.
* ``disaggregated`` — DPExecutors (attention) and MoEExecutors (experts)
  on separate devices; MoE failures can role-switch a DP rank (§3.4).
"""
from __future__ import annotations

import os
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.comm_domain import CommDomain
from repro.core.fault_codes import Action
from repro.core.detection import (AnnotationPoller, HeartbeatMonitor,
                                  StragglerDetector)
from repro.core.expert_map import ExpertMap
from repro.core.faults import FaultInjector, SimulatedDeviceFailure
from repro.core.graph_cache import GraphCache
from repro.core.weights import DenseFFNGroups, RecoveryPolicy
from repro.models.model import Model
from repro.serving.executor import DPExecutor, MoEExecutor, next_bucket
from repro.serving.request import Request, RequestState
from repro.serving.sampling import SamplingParams
from repro.serving.weights_util import (assemble, expert_checksums,
                                        split_experts)
from repro.training.checkpoint import restore_like, save_checkpoint


class _Timer:
    def __init__(self, sink: Dict[str, float], key: str):
        self.sink, self.key = sink, key

    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.sink[self.key] = self.sink.get(self.key, 0.0) + (
            time.perf_counter() - self.t0)


def _specs(tree):
    return jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(jnp.shape(x), jnp.result_type(x)), tree)


def _decode_closure(model: Model, version: int):
    def fn(params, cache, tokens, page, runtime):
        return model.decode_step_paged(params, cache, tokens, page, runtime)
    fn.__name__ = f"decode_v{version}"
    fn.__qualname__ = fn.__name__
    return fn


def _chunk_closure(model: Model, version: int):
    # a chunked-prefill step IS a decode step over chunk-width virtual
    # slots (per-token page context); only the compiled width differs
    def fn(params, cache, tokens, page, runtime):
        return model.decode_step_paged(params, cache, tokens, page, runtime)
    fn.__name__ = f"chunk_v{version}"
    fn.__qualname__ = fn.__name__
    return fn


def _prefill_closure(model: Model, version: int, max_seq: int):
    def fn(params, tokens, lengths, runtime):
        batch = {"tokens": tokens, "lengths": lengths}
        return model.prefill_paged(params, batch, runtime)
    fn.__name__ = f"prefill_v{version}"
    fn.__qualname__ = fn.__name__
    return fn


def _install_closure(axes_leaves, bucket: int):
    from repro.serving.cache_ops import install_prefill

    def fn(cache, raw, block_ids, slot):
        return install_prefill(cache, raw, axes_leaves, block_ids, slot)
    fn.__name__ = f"install_b{bucket}"
    fn.__qualname__ = fn.__name__
    return fn


class _Ctx:
    """What an executor sees during compute: weights + compiled fns."""

    def __init__(self, engine: "InferenceEngine"):
        self.engine = engine
        self.params = engine.params
        self.runtime = engine.runtime

    def decode_fn(self, *args):
        return self.engine.get_compiled("decode")( *args)

    def chunk_fn(self):
        return self.engine.get_compiled("chunk")

    def prefill_fn(self, bucket: int):
        return self.engine.get_compiled("prefill", bucket)

    def install_fn(self, bucket: int):
        return self.engine.get_compiled("install", bucket)


@dataclass
class EngineConfig:
    mode: str = "collocated"            # 'collocated' | 'disaggregated'
    num_dp: int = 2
    num_moe: int = 2                    # disaggregated only
    max_batch: int = 4
    max_seq: int = 128
    block_size: int = 16
    num_blocks: int = 128
    sampling: SamplingParams = field(default_factory=SamplingParams)
    seed: int = 0
    workdir: str = "/tmp/repro_engine"
    policy: RecoveryPolicy = field(default_factory=RecoveryPolicy)
    precompile_failure_scenarios: bool = True
    persist_cache_dir: Optional[str] = None
    heartbeat_timeout_steps: int = 2
    # override ModelConfig.moe_impl (e.g. 'fused' routes the MoE layer
    # through the fused Pallas dispatch->FFN->combine pipeline); None
    # keeps the model config's choice
    moe_impl: Optional[str] = None
    # override ModelConfig.decode_impl: 'megakernel' fuses each
    # attention+MoE block's decode/chunk step into one kernel launch
    # (ops.decode_megastep); None keeps the model config's choice, whose
    # default — 'composed' — is the kernel-chain oracle path
    decode_impl: Optional[str] = None
    # -- admission pipeline ---------------------------------------------------
    # 'chunked': token-budget continuous batching — many prefills per
    #   step, each chunked so long prompts interleave with decodes
    #   (attention-only models; recurrent-state models whole-prefill
    #   under the same budget).
    # 'serial': the legacy one-whole-prefill-per-step baseline.
    admission: str = "chunked"
    prefill_chunk: int = 32             # batched chunk width (tokens)
    # per-step decode+prefill token target; None -> max_batch + chunk
    token_budget: Optional[int] = None
    # content-hash shared-prefix block reuse across requests (COW at the
    # divergence block); chunked admission only
    prefix_cache: bool = True
    # §3.3 device-pool rollback strategy: 'rows' restores only the
    # step's captured write set (donation-friendly); 'snapshot' keeps
    # the legacy O(1) functional reference to the whole cache
    pool_undo: str = "rows"
    # multi-token self-speculative decode: > 1 lets decode-ready
    # requests verify up to this many tokens per step through the
    # compiled chunk graph (n-gram self-drafts, deterministic
    # accept/reject — output stays token-identical to plain decode).
    # 0/1 disables; chunked admission only (recurrent-prefill models
    # fall back to plain decode automatically)
    spec_window: int = 0
    # async pipelined engine: while step N runs on device, plan step N+1
    # against the predicted post-N state (speculative host bookkeeping
    # only — token streams stay bit-identical to lockstep, and §3.3
    # rollback/replay is unchanged because every plan-ahead frame
    # unwinds before recovery looks at the tables).  Tokens are sampled
    # on-device and drained one step late through a small ring of
    # in-flight D2H copies.  Requires chunked admission + row-level
    # pool undo; models without chunked-prefill support fall back to
    # lockstep automatically.
    overlap: bool = False

    def __post_init__(self):
        # ValueError (not assert) so misconfiguration still fails loudly
        # under `python -O`
        if self.mode not in ("collocated", "disaggregated"):
            raise ValueError(
                f"EngineConfig.mode must be 'collocated' or "
                f"'disaggregated', got {self.mode!r}")
        for name in ("num_dp", "max_batch", "max_seq", "block_size",
                     "num_blocks"):
            v = getattr(self, name)
            if not isinstance(v, int) or v < 1:
                raise ValueError(
                    f"EngineConfig.{name} must be a positive int, "
                    f"got {v!r}")
        if not isinstance(self.num_moe, int) or self.num_moe < 0:
            raise ValueError(
                f"EngineConfig.num_moe must be a non-negative int, "
                f"got {self.num_moe!r}")
        if self.heartbeat_timeout_steps < 1:
            raise ValueError(
                f"EngineConfig.heartbeat_timeout_steps must be >= 1, "
                f"got {self.heartbeat_timeout_steps!r}")
        if (self.moe_impl is not None
                and self.moe_impl not in ModelConfig.MOE_IMPLS):
            raise ValueError(
                f"EngineConfig.moe_impl must be one of "
                f"{ModelConfig.MOE_IMPLS} or None, got {self.moe_impl!r}")
        if (self.decode_impl is not None
                and self.decode_impl not in ModelConfig.DECODE_IMPLS):
            raise ValueError(
                f"EngineConfig.decode_impl must be one of "
                f"{ModelConfig.DECODE_IMPLS} or None, "
                f"got {self.decode_impl!r}")
        if self.admission not in ("chunked", "serial"):
            raise ValueError(
                f"EngineConfig.admission must be 'chunked' or 'serial', "
                f"got {self.admission!r}")
        if not isinstance(self.prefill_chunk, int) or self.prefill_chunk < 1:
            raise ValueError(
                f"EngineConfig.prefill_chunk must be a positive int, "
                f"got {self.prefill_chunk!r}")
        # None stays None here (resolved at executor construction from
        # the *final* max_batch/prefill_chunk, so dataclasses.replace
        # after construction cannot freeze a stale default)
        if self.token_budget is not None and (
                not isinstance(self.token_budget, int)
                or self.token_budget < 1):
            raise ValueError(
                f"EngineConfig.token_budget must be a positive int or "
                f"None, got {self.token_budget!r}")
        if self.pool_undo not in ("rows", "snapshot"):
            raise ValueError(
                f"EngineConfig.pool_undo must be 'rows' or 'snapshot', "
                f"got {self.pool_undo!r}")
        if not isinstance(self.spec_window, int) or self.spec_window < 0:
            raise ValueError(
                f"EngineConfig.spec_window must be a non-negative int, "
                f"got {self.spec_window!r}")
        if self.spec_window > self.prefill_chunk:
            raise ValueError(
                f"EngineConfig.spec_window ({self.spec_window}) cannot "
                f"exceed prefill_chunk ({self.prefill_chunk}) — verify "
                f"windows ride the chunk graph")
        if self.overlap and self.pool_undo != "rows":
            raise ValueError(
                "EngineConfig.overlap requires pool_undo='rows' — "
                "stacked plan-ahead frames restore per-frame write "
                "sets; the whole-pool snapshot cannot unwind one frame "
                "at a time")
        if self.overlap and self.admission != "chunked":
            raise ValueError(
                "EngineConfig.overlap requires admission='chunked' — "
                "whole-prefill installs synchronize with the device "
                "and cannot be planned ahead")


@dataclass
class InstanceHealth:
    """Engine health surface consumed by the fleet control plane."""
    serving: bool                # >=1 healthy attention rank
    healthy_dp: int
    total_dp: int
    healthy_moe: int
    total_moe: int
    expert_coverage: float       # 1.0 = every logical expert has a live slot
    queue_depth: int             # waiting + running on healthy ranks
    unfinished: int
    soft_signals: Dict[int, float] = field(default_factory=dict)
    # physical_id -> slowdown ratio vs fleet median (straggler suspicion)

    @property
    def degraded(self) -> bool:
        return (self.healthy_dp < self.total_dp
                or self.healthy_moe < self.total_moe
                or self.expert_coverage < 1.0
                or bool(self.soft_signals))


class InferenceEngine:
    def __init__(self, cfg: ModelConfig, engine_cfg: EngineConfig = None):
        import dataclasses
        self.ecfg = engine_cfg or EngineConfig()
        if self.ecfg.moe_impl is not None and cfg.moe is not None:
            cfg = dataclasses.replace(cfg, moe_impl=self.ecfg.moe_impl)
        if self.ecfg.decode_impl is not None:
            cfg = dataclasses.replace(cfg, decode_impl=self.ecfg.decode_impl)
        self.cfg = cfg
        if cfg.moe is None:
            # dense model: no expert ranks; disaggregated degenerates
            self.ecfg.mode = "collocated"
        self.init_timings: Dict[str, float] = {}
        self.step_no = 0
        self.reports: List[Any] = []
        self.all_requests: List[Request] = []
        self._handled_faults: set = set()
        # §4.3: role switches deferred by the background policy; executed
        # between steps while service continues
        self.pending_switches: List[Any] = []
        self.background_reports: List[Dict] = []
        # fleet hook: called with each actionable FaultEvent BEFORE the
        # in-place revive pipeline runs; returning anything other than
        # "revive" defers handling to the fleet control plane (the engine
        # only isolates the failed device; the router tracks the rest)
        self.fault_interceptor = None
        # latest straggler suspicion {physical_id: slowdown ratio}
        self.soft_signals: Dict[int, float] = {}
        # campaign determinism hook: when set, straggler detection
        # samples this fixed virtual step duration (+ any simulated
        # slowdown) instead of the wall clock, so chaos campaigns are a
        # pure function of their seed
        self.virtual_step_s: Optional[float] = None
        # wall-clock spent inside executor step calls (summed across
        # ranks, both lockstep and overlap paths) — the denominator of
        # ``host_gap_fraction``; the numerator lives on the executors
        self.perf: Dict[str, float] = {"wall_s": 0.0}
        self._build(first_time=True)

    # -- construction / reinitialization ---------------------------------------

    def _build(self, first_time: bool) -> Dict[str, float]:
        ec = self.ecfg
        t: Dict[str, float] = {}
        with _Timer(t, "engine"):
            # paper baseline is a *cached* reinit: the compile cache lives
            # on disk (Dynamo/IR cache analogue = XLA persistent cache)
            if ec.persist_cache_dir is None:
                ec.persist_cache_dir = os.path.join(ec.workdir, "xla_cache")
            self.graph_cache = getattr(self, "graph_cache", None) or \
                GraphCache(ec.persist_cache_dir)
            self.injector = getattr(self, "injector", None) or FaultInjector()
            self.poller = AnnotationPoller(self.injector)
            self.monitor = HeartbeatMonitor(ec.heartbeat_timeout_steps)
            self.straggler = StragglerDetector()
            self.model = Model(self.cfg)
            from repro.serving.cache_ops import infer_paged_axes
            _, self.paged_axes = infer_paged_axes(
                self.model, ec.num_blocks, ec.block_size)
            os.makedirs(ec.workdir, exist_ok=True)
            self.ckpt_path = os.path.join(ec.workdir, "weights.npz")

        with _Timer(t, "generator"):
            # model instantiation + weight loading + KV warmup
            if os.path.exists(self.ckpt_path):
                template = self.model.param_specs()
                full_params = restore_like(self.ckpt_path, template)
                full_params = jax.tree_util.tree_map(jnp.asarray, full_params)
            else:
                full_params = self.model.init(
                    jax.random.PRNGKey(ec.seed))
                save_checkpoint(self.ckpt_path, full_params)
            self.ep_size = (ec.num_moe if ec.mode == "disaggregated"
                            else ec.num_dp) if self.cfg.moe else 0
            if self.cfg.moe is not None:
                self.base_params, self.shards = split_experts(
                    full_params, self.ep_size)
                from repro.serving.weights_util import save_shard_checkpoints
                save_shard_checkpoints(ec.workdir, self.shards)
                self.expert_map = ExpertMap(self.cfg.moe, self.ep_size)
                self.runtime = self.expert_map.runtime()
                self.shard_alive = [True] * self.ep_size
                self.params = assemble(self.base_params, self.shards,
                                       self.shard_alive)
                self.dense_groups = (
                    DenseFFNGroups(max(2, self.ep_size // 2))
                    if self.cfg.moe.first_k_dense else None)
            else:
                self.base_params, self.shards = full_params, []
                self.expert_map = None
                self.runtime = None
                self.shard_alive = []
                self.params = full_params
                self.dense_groups = None
            del full_params

        with _Timer(t, "executor_processes"):
            self.dp_executors: List[DPExecutor] = []
            for i in range(ec.num_dp):
                shard = None
                ep_rank = None
                if self.cfg.moe is not None and ec.mode == "collocated":
                    shard, ep_rank = self.shards[i], i
                self.dp_executors.append(
                    self._make_dp_executor(i, i, shard=shard,
                                           ep_rank=ep_rank))
            self.moe_executors: List[MoEExecutor] = []
            if self.cfg.moe is not None and ec.mode == "disaggregated":
                for j in range(ec.num_moe):
                    self.moe_executors.append(MoEExecutor(
                        physical_id=ec.num_dp + j, ep_rank=j,
                        shard=self.shards[j]))
            for ex in self.dp_executors + self.moe_executors:
                self.monitor.register(ex.physical_id, self.step_no)

        with _Timer(t, "distributed_groups"):
            # torch.distributed analogue: default world group + subgroups
            self.world_group = [ex.physical_id for ex in
                                self.dp_executors + self.moe_executors]

        with _Timer(t, "xccl"):
            self.domain = CommDomain(
                ec.num_dp,
                ec.num_moe if ec.mode == "disaggregated" else 0,
                collocated=(ec.mode == "collocated"))
            if not first_time:
                self.domain.version = self._next_version
            self.domain.rebuild()

        # initial graph compilation (Fig. 1 "Read Cache"/"Compile")
        self._compile_initial(t)

        if first_time and ec.precompile_failure_scenarios:
            with _Timer(t, "precompile_failure_scenarios"):
                self._precompile_failure_graphs()

        with _Timer(t, "other"):
            from repro.core.revive import RecoveryManager
            self.recovery = RecoveryManager(self)
        self.init_timings = t
        return t

    def _make_dp_executor(self, physical_id: int, dp_rank: int, *,
                          shard=None, ep_rank: Optional[int] = None
                          ) -> DPExecutor:
        ec = self.ecfg
        return DPExecutor(
            physical_id=physical_id, dp_rank=dp_rank, model=self.model,
            max_batch=ec.max_batch, max_seq=ec.max_seq,
            num_blocks=ec.num_blocks, block_size=ec.block_size,
            sampling=ec.sampling, ep_rank=ep_rank, shard=shard,
            paged_axes=self.paged_axes,
            admission=ec.admission,
            prefill_chunk=ec.prefill_chunk,
            token_budget=(ec.token_budget
                          if ec.token_budget is not None
                          else ec.max_batch + ec.prefill_chunk),
            prefix_cache=ec.prefix_cache,
            pool_undo=ec.pool_undo,
            spec_window=ec.spec_window)

    @property
    def _next_version(self) -> int:
        return self.domain.version + 1 if hasattr(self, "domain") else 0

    def _cache_specs(self):
        return jax.eval_shape(
            lambda: self.model.init_paged_cache(
                self.ecfg.max_batch, self.ecfg.num_blocks,
                self.ecfg.block_size))

    def _arg_specs(self, phase: str, bucket: Optional[int] = None):
        from repro.serving.kvcache import (max_blocks_per_seq,
                                           page_context_specs)
        p_specs = _specs(self.params)
        r_specs = _specs(self.runtime)
        if phase in ("decode", "chunk"):
            width = (self.ecfg.max_batch if phase == "decode"
                     else self.ecfg.prefill_chunk)
            c_specs = self._cache_specs()
            tok = jax.ShapeDtypeStruct((width,), jnp.int32)
            page = page_context_specs(
                width,
                max_blocks_per_seq(self.ecfg.max_seq, self.ecfg.block_size))
            return (p_specs, c_specs, tok, page, r_specs)
        toks = jax.ShapeDtypeStruct((1, bucket), jnp.int32)
        lens = jax.ShapeDtypeStruct((1,), jnp.int32)
        if phase == "install":
            raw_specs = jax.eval_shape(self.model.prefill_paged, p_specs,
                                       {"tokens": toks, "lengths": lens},
                                       r_specs)[1]
            nblk = max_blocks_per_seq(bucket, self.ecfg.block_size)
            bids = jax.ShapeDtypeStruct((nblk,), jnp.int32)
            slot = jax.ShapeDtypeStruct((), jnp.int32)
            return (self._cache_specs(), raw_specs, bids, slot)
        return (p_specs, toks, lens, r_specs)

    def _donate(self, phase: str) -> tuple:
        """Donate the KV pool (cache, arg 1) into the compiled decode and
        chunk steps so the token writes happen in place (carry-over (h)).
        Safe only under row-level undo: ``plan()`` captures the step's
        write-set rows *before* compute, so rollback never needs the
        pre-step buffers.  The legacy 'snapshot' strategy keeps a live
        reference to them and must not donate."""
        if phase in ("decode", "chunk") and self.ecfg.pool_undo == "rows":
            return (1,)
        return ()

    def _compile_initial(self, t: Dict[str, float]) -> None:
        v = self.domain.version
        phases = [("decode", _decode_closure(self.model, v))]
        if self._chunking:
            phases.append(("chunk", _chunk_closure(self.model, v)))
        for phase, fn in phases:
            key = (phase, v, None)
            if key not in self.graph_cache:
                _, tm = self.graph_cache.get_or_compile(
                    key, fn, self._arg_specs(phase),
                    donate_argnums=self._donate(phase))
                t["read_cache"] = t.get("read_cache", 0.0) + tm.read_cache_s
                t["compile"] = t.get("compile", 0.0) + tm.compile_s
            else:
                self.graph_cache.get_or_compile(key, fn,
                                                self._arg_specs(phase))

    def _precompile_failure_graphs(self) -> None:
        """§3.6: precompile graphs for the anticipated failure scenario
        (post-failure domain version), so recovery does a cached compile."""
        v = self.domain.version + 1
        self.graph_cache.precompile(
            ("decode", v, None), _decode_closure(self.model, v),
            self._arg_specs("decode"),
            donate_argnums=self._donate("decode"))
        if self._chunking:
            # chunked admission re-prefills migrated/rolled-back requests
            # through the chunk graph — it must be ready post-failure
            self.graph_cache.precompile(
                ("chunk", v, None), _chunk_closure(self.model, v),
                self._arg_specs("chunk"),
                donate_argnums=self._donate("chunk"))
            return
        # whole-prefill path: the most common prefill bucket is needed
        # right after migration
        b = next_bucket(16, self.ecfg.max_seq)
        self.graph_cache.precompile(
            ("prefill", v, b),
            _prefill_closure(self.model, v, self.ecfg.max_seq),
            self._arg_specs("prefill", b))
        if ("install", 0, b) not in self.graph_cache:
            self.graph_cache.precompile(
                ("install", 0, b), _install_closure(self.paged_axes, b),
                self._arg_specs("install", b))

    @property
    def _chunking(self) -> bool:
        return (self.ecfg.admission == "chunked"
                and self.model.supports_chunked_prefill)

    @property
    def _overlap_active(self) -> bool:
        # recurrent-prefill models fall back to lockstep (they cannot
        # chunk, so plan-ahead would have to predict whole prefills)
        return self.ecfg.overlap and self._chunking

    # -- compiled-fn access ------------------------------------------------------

    def get_compiled(self, phase: str, bucket: Optional[int] = None):
        # the install scatter has no collectives: its graph is domain-
        # version independent and survives every comm rebuild
        v = 0 if phase == "install" else self.domain.version
        key = (phase, v, bucket if phase in ("prefill", "install") else None)
        if key in self.graph_cache:
            fn, _ = self.graph_cache.get_or_compile(key, None, None)
            return fn
        if phase == "decode":
            fn = _decode_closure(self.model, v)
        elif phase == "chunk":
            fn = _chunk_closure(self.model, v)
        elif phase == "install":
            fn = _install_closure(self.paged_axes, bucket)
        else:
            fn = _prefill_closure(self.model, v, self.ecfg.max_seq)
        compiled, _ = self.graph_cache.get_or_compile(
            key, fn, self._arg_specs(phase, bucket),
            donate_argnums=self._donate(phase))
        return compiled

    # -- request API ----------------------------------------------------------------

    def submit(self, prompt_tokens: List[int], max_new_tokens: int = 16,
               eos_token: Optional[int] = None) -> Request:
        req = Request(list(prompt_tokens), max_new_tokens,
                      eos_token=eos_token)
        self._assign(req)
        self.all_requests.append(req)
        return req

    # a prefix-affine executor may be at most this many requests busier
    # than the least-loaded one (mirrors FleetRouter.AFFINITY_SLACK —
    # cache hits must not create hotspots within the instance either)
    ASSIGN_AFFINITY_SLACK = 4

    def _assign(self, req: Request) -> None:
        """Pick an attention rank for a request: least-loaded, biased
        toward in-instance prefix affinity (ROADMAP paged-KV (i)) — the
        DP executor whose BlockManager already holds the prompt's
        leading full-block digests serves the shared prefix from its
        cache instead of recomputing it on a cold rank, unless it is
        more than ``ASSIGN_AFFINITY_SLACK`` requests busier than the
        least-loaded executor."""
        healthy = [ex for ex in self.dp_executors
                   if ex.alive and ex.cache is not None]
        if not healthy:
            raise RuntimeError(
                "no healthy attention ranks left on this instance")
        least = min(healthy, key=lambda e: e.scheduler.num_requests)
        ex = least
        digests = None
        if (len(healthy) > 1 and self._chunking and self.ecfg.prefix_cache
                and len(req.tokens_so_far) > self.ecfg.block_size):
            from repro.core.block_log import prompt_digests
            digests = prompt_digests(tuple(req.tokens_so_far),
                                     self.ecfg.block_size)
            best, best_hits = None, 0
            for cand in healthy:
                hits = cand.prefix_hit_blocks(digests,
                                              len(req.tokens_so_far))
                if hits > best_hits:
                    best, best_hits = cand, hits
            if (best is not None
                    and best.scheduler.num_requests
                    <= least.scheduler.num_requests
                    + self.ASSIGN_AFFINITY_SLACK):
                ex = best
        req.dp_rank = ex.dp_rank
        ex.scheduler.add_request(req)
        if digests is not None:
            # hand the chain digests to the scheduler's per-request memo
            # so admission doesn't rehash the prompt _assign just hashed
            ex.scheduler.memo_digests(req.req_id, digests)

    def admit(self, req: Request, kv=None) -> Request:
        """Admit a request created elsewhere (cross-instance migration).

        With a :class:`~repro.core.migration.KVBlocks` payload the least-
        loaded healthy executor installs the streamed blocks directly —
        the request skips re-prefill and decodes on the next step.
        Without one (or if no executor can take the blocks) it re-enters
        with prompt + decoded prefix intact, so the next prefill resumes
        generation without redoing completed tokens."""
        if kv is not None:
            healthy = sorted(
                (ex for ex in self.dp_executors
                 if ex.alive and ex.cache is not None),
                key=lambda e: e.scheduler.num_requests)
            for ex in healthy:
                if ex.import_kv_blocks(req, kv):
                    if all(r is not req for r in self.all_requests):
                        self.all_requests.append(req)
                    return req
            # stream install failed (no slot/blocks): the prefix must be
            # re-prefilled after all — charge the replay now
            from repro.core.migration import charge_replay
            charge_replay(req)
        self._assign(req)
        if all(r is not req for r in self.all_requests):
            self.all_requests.append(req)
        return req

    def export_live_requests(self, with_kv: bool = False):
        """Fleet drain/export hook: strip every unfinished request off
        this instance — dead executors included, their token ids live in
        host memory.  With ``with_kv``, each RUNNING request's live
        blocks are extracted first from executors whose device state is
        still reachable (rollback-then-migrate: any uncommitted step is
        rolled back before the read, so tables and pools agree) and the
        result is ``[(req, KVBlocks | None)]``; a None payload means
        token-replay re-prefill on the target."""
        from repro.core.migration import prepare_for_migration
        out = []
        for ex in self.dp_executors:
            payloads = {}
            # pipeline quiesce before the export: the in-flight step's
            # readback already landed, so its outcome commits; leftover
            # speculative overlays must not leak into migration prompts,
            # even from dead executors (rollback is cache-None-safe)
            if ex._inflight is not None:
                ex.flush(None)
            if ex.has_uncommitted():
                ex.rollback_inflight()
            if with_kv and ex.alive and ex.cache is not None:
                for req in list(ex.scheduler.running):
                    blocks_kv = ex.export_kv_blocks(req)
                    if blocks_kv is not None:
                        payloads[req.req_id] = blocks_kv
            for req in ex.scheduler.drain():
                if req.state in (RequestState.FINISHED,
                                 RequestState.FAILED):
                    continue
                blocks_kv = payloads.get(req.req_id)
                prepare_for_migration(req, streamed=blocks_kv is not None)
                out.append((req, blocks_kv) if with_kv else req)
        gone = {(r[0] if with_kv else r).req_id for r in out}
        self.all_requests = [r for r in self.all_requests
                             if r.req_id not in gone]
        return out

    def streamable_split(self) -> Tuple[int, int]:
        """(streamable, replay-only) token counts over this instance's
        unfinished requests — the spare-substitution cost split: RUNNING
        requests on reachable executors can stream their KV blocks;
        everything else re-prefills on the target."""
        stream = replay = 0
        for ex in self.dp_executors:
            reachable = ex.alive and ex.cache is not None
            for r in list(ex.scheduler.waiting) + list(ex.scheduler.running):
                if r.state in (RequestState.FINISHED, RequestState.FAILED):
                    continue
                if (reachable and r.state is RequestState.RUNNING
                        and r.batch_slot is not None and r.output_tokens):
                    stream += r.num_tokens
                else:
                    replay += r.num_tokens
        return stream, replay

    def predict_masked_fraction(self, rank: int) -> float:
        """Fraction of logical experts that would lose every live replica
        if physical ``rank``'s expert slots died — the degraded-quality
        input to the fleet cost model (revive may serve with those
        experts masked until a role switch restores them)."""
        if self.expert_map is None:
            return 0.0
        ep_rank = None
        for ex in self.dp_executors:
            if ex.physical_id == rank:
                ep_rank = ex.ep_rank
        for mex in self.moe_executors:
            if mex.physical_id == rank:
                ep_rank = mex.ep_rank
        if ep_rank is None:
            return 0.0
        emap = self.expert_map
        dead = set(emap.rank_slots(ep_rank))
        lost = sum(
            1 for e in range(emap.moe.num_experts)
            if e not in emap.masked
            and not [s for s in emap.replicas_of(e) if s not in dead])
        return lost / emap.moe.num_experts

    def health(self) -> InstanceHealth:
        healthy_dp = [ex for ex in self.dp_executors
                      if ex.alive and ex.cache is not None]
        healthy_moe = [m for m in self.moe_executors if m.device_alive]
        cov = (self.expert_map.coverage()
               if self.expert_map is not None else 1.0)
        return InstanceHealth(
            serving=bool(healthy_dp),
            healthy_dp=len(healthy_dp), total_dp=len(self.dp_executors),
            healthy_moe=len(healthy_moe),
            total_moe=len(self.moe_executors),
            expert_coverage=cov,
            queue_depth=sum(ex.scheduler.num_requests
                            for ex in healthy_dp),
            unfinished=self.unfinished,
            soft_signals=dict(self.soft_signals))

    @property
    def unfinished(self) -> int:
        return sum(1 for r in self.all_requests
                   if r.state not in (RequestState.FINISHED,
                                      RequestState.FAILED))

    def prefill_stats(self) -> Dict[str, int]:
        """Aggregated admission-pipeline counters across attention ranks:
        prefill tokens actually computed vs skipped via the shared-prefix
        cache, chunk count, window-freed blocks, and the BlockManagers'
        cache acquire/eviction counters."""
        out: Dict[str, int] = {}
        for ex in self.dp_executors:
            for k, val in ex.scheduler.stats.items():
                out[k] = out.get(k, 0) + val
            out["prefix_cache_hits"] = (out.get("prefix_cache_hits", 0)
                                        + ex.block_manager.cache_hits)
            out["prefix_cache_evictions"] = (
                out.get("prefix_cache_evictions", 0)
                + ex.block_manager.cache_evictions)
        return out

    def spec_histogram(self) -> Dict[int, int]:
        """Speculation-window width histogram ({planned rows: count})
        aggregated across attention ranks — the spec-efficiency surface
        the benchmarks record next to accepted tokens/step."""
        out: Dict[int, int] = {}
        for ex in self.dp_executors:
            for g, n in ex.scheduler.spec_hist.items():
                out[g] = out.get(g, 0) + n
        return out

    # -- main loop --------------------------------------------------------------------

    def step(self) -> List[Request]:
        if self._overlap_active:
            return self._step_overlap()
        self.step_no += 1
        # finish deferred role switches in the background (§4.3): service
        # already resumed; these timings are not downtime
        while self.pending_switches:
            plan = self.pending_switches.pop(0)
            self.background_reports.append(
                self.recovery.complete_background_switch(plan))
        self.injector.pre_step_faults(self.step_no)
        for ev in self.poller.poll():
            self._handle(ev)
        for ev in self.monitor.check(self.step_no):
            self._handle(ev)

        active = [ex for ex in self.dp_executors
                  if ex.alive and ex.cache is not None
                  and ex.scheduler.num_requests]
        for ex in active:
            ex.plan()

        # mid-step faults fire while the collective step is in flight
        hit = False
        for ex in active + [m for m in self.moe_executors if m.device_alive]:
            try:
                self.injector.maybe_fail_mid_step(self.step_no,
                                                  ex.physical_id)
            except SimulatedDeviceFailure:
                ex.fail_device()
                if ex.ep_rank is not None and self.expert_map is not None:
                    pass  # handled by recovery via the annotation
                hit = True
        if hit:
            # global stop: the step aborts with uncommitted logs everywhere;
            # detection fires on the annotation we just recorded
            for ev in self.poller.poll():
                self._handle(ev)
            return []

        finished: List[Request] = []
        ctx = _Ctx(self)
        def real_compiles():
            return sum(1 for t in self.graph_cache.timings
                       if t.compile_s > 0.01)

        for ex in active:
            t0 = time.perf_counter()
            n_compiles = real_compiles()
            finished.extend(ex.compute(ctx, self.step_no))
            ex.commit()
            self.perf["wall_s"] += time.perf_counter() - t0
            # slowdown detection (§6 future work): per-device step time;
            # steps that triggered a fresh compile are not samples
            if real_compiles() == n_compiles:
                base = (self.virtual_step_s
                        if self.virtual_step_s is not None
                        else time.perf_counter() - t0)
                self.straggler.record(
                    ex.physical_id, base + ex.simulated_slowdown_s)
        # soft signal: suspicion that has not yet hardened into an L4
        # fault, surfaced via health() for the fleet arbiter to act on
        self.soft_signals = self.straggler.suspects()
        for ev in self.straggler.check():
            self._handle(ev)
        for ex in self.dp_executors + self.moe_executors:
            alive = (ex.device_alive if isinstance(ex, MoEExecutor)
                     else ex.alive)
            if alive:
                self.monitor.beat(ex.physical_id, self.step_no)
        return finished

    def _step_overlap(self) -> List[Request]:
        """Pipelined step: each executor plans+launches step N against
        the predicted post-(N-1) state, then drains step N-1 (whose
        logits forced while N's plan was being built on the host).
        Fault handling is strictly *before* any executor work and always
        quiesces the pipeline first — flush the in-flight step (its
        readback predates the fault), roll back anything else — so
        recovery, and the migration/replay machinery behind it, sees
        exactly the state lockstep would have committed."""
        self.step_no += 1
        while self.pending_switches:
            plan = self.pending_switches.pop(0)
            self.background_reports.append(
                self.recovery.complete_background_switch(plan))
        self.injector.pre_step_faults(self.step_no)
        events = list(self.poller.poll()) + list(
            self.monitor.check(self.step_no))
        finished: List[Request] = []
        if events:
            finished.extend(self._quiesce_inflight())
            for ev in events:
                self._handle(ev)

        # mid-step faults fire while the previous step's collective is
        # still in flight — the canonical §3.3 scenario the pipeline
        # must survive: the already-drained-readback step commits, the
        # faulted step's partial work rolls back, and replay regenerates
        # everything after the commit point bit-identically
        hit = False
        alive_dp = [ex for ex in self.dp_executors
                    if ex.alive and ex.cache is not None]
        for ex in alive_dp + [m for m in self.moe_executors
                              if m.device_alive]:
            try:
                self.injector.maybe_fail_mid_step(self.step_no,
                                                  ex.physical_id)
            except SimulatedDeviceFailure:
                ex.fail_device()
                hit = True
        if hit:
            finished.extend(self._quiesce_inflight())
            for ev in self.poller.poll():
                self._handle(ev)
            return finished

        ctx = _Ctx(self)
        def real_compiles():
            return sum(1 for t in self.graph_cache.timings
                       if t.compile_s > 0.01)

        for ex in self.dp_executors:
            if not (ex.alive and ex.cache is not None):
                continue
            if not (ex.scheduler.num_requests or ex._inflight is not None):
                continue
            t0 = time.perf_counter()
            n_compiles = real_compiles()
            finished.extend(ex.overlap_step(ctx, self.step_no))
            self.perf["wall_s"] += time.perf_counter() - t0
            if real_compiles() == n_compiles:
                base = (self.virtual_step_s
                        if self.virtual_step_s is not None
                        else time.perf_counter() - t0)
                self.straggler.record(
                    ex.physical_id, base + ex.simulated_slowdown_s)
        self.soft_signals = self.straggler.suspects()
        events = list(self.straggler.check())
        if events:
            finished.extend(self._quiesce_inflight())
            for ev in events:
                self._handle(ev)
        for ex in self.dp_executors + self.moe_executors:
            alive = (ex.device_alive if isinstance(ex, MoEExecutor)
                     else ex.alive)
            if alive:
                self.monitor.beat(ex.physical_id, self.step_no)
        return finished

    def _quiesce_inflight(self) -> List[Request]:
        """Retire the pipeline before recovery or migration reads
        request/table state.  The in-flight step launched a full engine
        step before the fault fired, so its token-id readback was
        already on the wire — flush commits its authoritative outcome
        through the normal drain path, exactly the step lockstep had
        already committed synchronously (this is what keeps fault-path
        token streams bit-identical to lockstep).  Anything still
        uncommitted afterwards rolls back via §3.3.  Runs on *all* DP
        executors — a FAILED executor's pending outcome still commits
        (its readback preceded the fault), and its overlays must never
        leak into the migration replay prompt (rollback is
        cache-None-safe)."""
        finished: List[Request] = []
        for ex in self.dp_executors:
            if ex._inflight is not None:
                finished.extend(ex.flush(None))
            if ex.has_uncommitted():
                ex.rollback_inflight()
        return finished

    def host_gap_fraction(self) -> float:
        """Fraction of executor-step wall time the device spent idle
        waiting on host work (planning, sampling, readback).  The
        overlap pipeline exists to drive this toward zero."""
        wall = self.perf["wall_s"]
        if wall <= 0.0:
            return 0.0
        busy = sum(ex.perf["device_busy_s"] for ex in self.dp_executors)
        return max(0.0, 1.0 - busy / wall)

    def overlap_stats(self) -> Dict[str, int]:
        """Aggregated pipeline counters across attention ranks."""
        out = {"steps": 0, "planned_ahead": 0, "replans": 0, "drains": 0}
        for ex in self.dp_executors:
            for k, v in ex.overlap_stats.items():
                out[k] = out.get(k, 0) + v
        return out

    def run(self, max_steps: int = 1000) -> List[Request]:
        done: List[Request] = []
        for _ in range(max_steps):
            if not self.unfinished:
                break
            done.extend(self.step())
        return done

    # -- failure handling ------------------------------------------------------------

    def _handle(self, ev) -> None:
        if ev.rank in self._handled_faults:
            return
        self._handled_faults.add(ev.rank)
        if (self.fault_interceptor is not None
                and ev.action is not Action.IGNORE):
            verdict = self.fault_interceptor(ev)
            if verdict != "revive":
                # the fleet owns this fault: isolate the device so the
                # step loop skips it, then defer (restart / spare /
                # redistribution happen at the fleet tick)
                self._isolate_only(ev)
                return
        report = self.recovery.recover(ev)
        self.reports.append(report)
        # inference was paused during recovery: reset the heartbeat clock
        # for every surviving executor so the pause is not mistaken for a
        # hang (the monitor resumes with inference)
        for ex in self.dp_executors:
            if ex.alive:
                self.monitor.beat(ex.physical_id, self.step_no)
        for mex in self.moe_executors:
            if mex.device_alive:
                self.monitor.beat(mex.physical_id, self.step_no)

    def _isolate_only(self, ev) -> None:
        """Minimal isolation for a fleet-deferred fault: terminate the
        failed executor and stop expecting its heartbeats, nothing else."""
        try:
            self.domain.device(ev.rank).alive = False
        except KeyError:
            pass
        for ex in self.dp_executors:
            if ex.physical_id == ev.rank:
                ex.fail_device()
                ex.terminate_process()
        for mex in self.moe_executors:
            if mex.physical_id == ev.rank:
                mex.fail_device()
        self.monitor.unregister(ev.rank)

    # -- device rejoin (cleared transient faults) --------------------------------

    def rejoin_device(self, physical_id: int) -> bool:
        """A cleared transient fault (flapping link restored, thermals
        back in range) returns the device to service: rebuild its
        executor, restore its expert shard from the checkpoint when its
        EP rank is uncovered, re-admit it to the comm domain (version
        bump -> cached graph for the new domain), and reset the
        detection state so the rank is faultable again.

        Returns True if a device actually rejoined; False when there is
        nothing to rejoin (rank alive, unknown, or its expert duty has
        been taken over by a role-switched donor)."""
        from repro.serving.weights_util import (
            load_expert_shard_from_checkpoint)
        dp = next((ex for ex in self.dp_executors
                   if ex.physical_id == physical_id), None)
        mex = next((m for m in self.moe_executors
                    if m.physical_id == physical_id), None)
        if dp is not None:
            if dp.alive:
                return False
            shard, ep_rank = None, dp.ep_rank
            if ep_rank is not None and self.expert_map is not None:
                if self._shard_owner(ep_rank) is not None:
                    ep_rank = None      # duty covered elsewhere
                else:
                    shard = load_expert_shard_from_checkpoint(
                        self.ckpt_path, self.shards[ep_rank], ep_rank,
                        self.ep_size, workdir=self.ecfg.workdir)
            fresh = self._make_dp_executor(physical_id, dp.dp_rank,
                                           shard=shard, ep_rank=ep_rank)
            self.dp_executors[self.dp_executors.index(dp)] = fresh
            if shard is not None:
                self.expert_map.install_rank(ep_rank)
        elif mex is not None:
            if mex.device_alive:
                return False
            if self._shard_owner(mex.ep_rank) is not None:
                return False            # a role-switched donor owns it
            shard = load_expert_shard_from_checkpoint(
                self.ckpt_path, self.shards[mex.ep_rank], mex.ep_rank,
                self.ep_size, workdir=self.ecfg.workdir)
            mex.install_shard(shard)
            self.expert_map.install_rank(mex.ep_rank)
        else:
            return False
        if self.expert_map is not None:
            self.runtime = self.expert_map.runtime()
            self.reassemble_params()
        # comm domain: back in with a fresh logical rank at the end of
        # its role group; rebuild compacts any remaining gaps and bumps
        # the version (cached compile on the next step)
        dev = self.domain.device(physical_id)
        if not dev.alive:
            peers = [r.logical_rank for r in self.domain.group(
                "moe" if (mex is not None and not self.domain.collocated)
                else "attn")]
            dev.logical_rank = (max(peers) + 1) if peers else 0
            dev.alive = True
        self.domain.rebuild()
        self.world_group = [ex.physical_id for ex in self.dp_executors
                            if ex.alive] + \
                           [m.physical_id for m in self.moe_executors
                            if m.device_alive]
        self.monitor.register(physical_id, self.step_no)
        self.straggler.forgive(physical_id)
        self._handled_faults.discard(physical_id)
        self.injector.clear(physical_id)
        return True

    # -- weight assembly -----------------------------------------------------------------

    def reassemble_params(self) -> None:
        if self.cfg.moe is None:
            return
        shard_arrays = []
        for r in range(self.ep_size):
            owner = self._shard_owner(r)
            shard_arrays.append(owner.shard if owner is not None else None)
        self.shard_alive = [s is not None for s in shard_arrays]
        self.params = assemble(self.base_params,
                               [s if s is not None else self.shards[r]
                                for r, s in enumerate(shard_arrays)],
                               self.shard_alive)

    def _shard_owner(self, ep_rank: int):
        """The executor currently hosting this EP rank's shard (or None)."""
        if self.ecfg.mode == "collocated":
            for ex in self.dp_executors:
                if ex.ep_rank == ep_rank and ex.device_alive \
                        and ex.shard is not None:
                    return ex
            return None
        for mex in self.moe_executors:
            if mex.ep_rank == ep_rank and mex.device_alive \
                    and mex.shard is not None:
                return mex
        return None

    def rebalance_experts(self, usage_counts) -> Dict[int, int]:
        """Maintenance op: re-point redundant replica slots at the hottest
        experts (paper §3.4/§4.3 — replicas follow usage frequency) and
        physically copy the weights into the replica slots' shards."""
        if self.expert_map is None:
            return {}
        emap = self.expert_map
        moves = emap.rebalance_replicas(usage_counts)
        for slot, logical in moves.items():
            # copy weights from an alive source slot of `logical`
            sources = [s for s in emap.replicas_of(logical) if s != slot]
            if not sources:
                continue
            src = sources[0]
            dst_owner = self._shard_owner(emap.rank_of_slot(slot))
            src_owner = self._shard_owner(emap.rank_of_slot(src))
            if dst_owner is None or src_owner is None:
                continue
            per = emap.slots_per_rank
            s_loc, d_loc = src % per, slot % per
            for key, arr in dst_owner.shard.items():
                arr[:, d_loc] = src_owner.shard[key][:, s_loc]
        self.runtime = emap.runtime()
        self.reassemble_params()
        return moves

    def expert_integrity(self) -> Tuple[List[float], List[bool]]:
        shard_arrays = [self._shard_owner(r).shard
                        if self._shard_owner(r) else None
                        for r in range(self.ep_size)]
        return expert_checksums(shard_arrays), self.shard_alive

    # -- baseline: full instance reinitialization (Fig. 1) ------------------------------

    def full_reinit(self) -> Dict[str, float]:
        """The baseline recovery: relaunch engine + executors, reload
        weights, rebuild groups, cached-compile — everything, timed."""
        in_flight = []
        for ex in self.dp_executors:
            # dead executors included: their requests' token ids survive
            # in host memory and must be requeued after the rebuild —
            # the in-flight step's readback landed (commit it), minus
            # any speculative overlay still riding on the requests
            if ex._inflight is not None:
                ex.flush(None)
            if ex.has_uncommitted():
                ex.rollback_inflight()
            in_flight.extend(ex.scheduler.drain())
        self.monitor = HeartbeatMonitor(self.ecfg.heartbeat_timeout_steps)
        # process death: in-memory executables are gone (the on-disk
        # persistent compile cache survives — that's the "cached" part)
        self.graph_cache.invalidate(lambda k: True)
        t = self._build(first_time=False)
        # restore shard state for ranks that had died (weights came from
        # disk in _build's generator stage — that's the point of reinit)
        for req in in_flight:
            if req.state not in (RequestState.FINISHED,):
                req.state = RequestState.WAITING
                self._assign(req)
        self._handled_faults.clear()
        self.soft_signals = {}
        return t
