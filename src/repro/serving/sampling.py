"""Token sampling: greedy / temperature / top-p, host-side."""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class SamplingParams:
    temperature: float = 0.0   # 0 = greedy
    top_p: float = 1.0
    seed: int = 0


def sample(logits: np.ndarray, params: SamplingParams,
           step: int = 0) -> np.ndarray:
    """logits: (B, V) -> (B,) int32 token ids. Deterministic given seed+step."""
    logits = np.asarray(logits, dtype=np.float64)
    if params.temperature <= 0.0:
        return np.argmax(logits, axis=-1).astype(np.int32)
    rng = np.random.default_rng(params.seed * 1_000_003 + step)
    z = logits / params.temperature
    z = z - z.max(axis=-1, keepdims=True)
    p = np.exp(z)
    p /= p.sum(axis=-1, keepdims=True)
    if params.top_p < 1.0:
        order = np.argsort(-p, axis=-1)
        sorted_p = np.take_along_axis(p, order, axis=-1)
        csum = np.cumsum(sorted_p, axis=-1)
        cut = csum - sorted_p > params.top_p
        sorted_p[cut] = 0.0
        sorted_p /= sorted_p.sum(axis=-1, keepdims=True)
        out = np.empty(p.shape[0], np.int32)
        for b in range(p.shape[0]):
            out[b] = order[b, rng.choice(p.shape[1], p=sorted_p[b])]
        return out
    out = np.empty(p.shape[0], np.int32)
    for b in range(p.shape[0]):
        out[b] = rng.choice(p.shape[1], p=p[b])
    return out.astype(np.int32)
