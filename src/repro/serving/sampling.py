"""Token sampling: greedy / temperature / top-p, host-side."""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class SamplingParams:
    temperature: float = 0.0   # 0 = greedy
    top_p: float = 1.0
    seed: int = 0


def sample(logits: np.ndarray, params: SamplingParams,
           step=0) -> np.ndarray:
    """logits: (B, V) -> (B,) int32 token ids.

    Deterministic given (seed, step) *per row*: each row's uniform is
    drawn from (seed, its step value) alone, so a request's token
    depends only on its own logits and step — not on its batch slot or
    on which other requests happen to be decoding alongside it.

    ``step`` may be a scalar (all rows share one draw, the pre-fleet
    behaviour) or a per-row array.  The serving executors pass each
    request's *sequence position* as its step, which makes the sampled
    token a pure function of (seed, prompt, position): a request
    replayed after migration — to another executor or to another fleet
    instance entirely — reproduces its original tokens exactly.
    """
    logits = np.asarray(logits, dtype=np.float64)
    if params.temperature <= 0.0:
        return np.argmax(logits, axis=-1).astype(np.int32)
    steps = np.broadcast_to(np.asarray(step, np.int64), (logits.shape[0],))
    u = np.asarray([np.random.default_rng(
        params.seed * 1_000_003 + int(s)).random() for s in steps])
    z = logits / params.temperature
    z = z - z.max(axis=-1, keepdims=True)
    p = np.exp(z)
    p /= p.sum(axis=-1, keepdims=True)
    order = np.argsort(-p, axis=-1)
    sorted_p = np.take_along_axis(p, order, axis=-1)
    if params.top_p < 1.0:
        csum = np.cumsum(sorted_p, axis=-1)
        cut = csum - sorted_p > params.top_p
        sorted_p[cut] = 0.0
        sorted_p /= sorted_p.sum(axis=-1, keepdims=True)
    # per-row-u inverse CDF over the sorted distribution, vectorized
    cdf = np.cumsum(sorted_p, axis=-1)
    idx = np.minimum((cdf < u[:, None]).sum(axis=-1), logits.shape[-1] - 1)
    return np.take_along_axis(order, idx[:, None], axis=-1)[:, 0].astype(
        np.int32)


def spec_verify(logits: np.ndarray, drafts, params: SamplingParams, *,
                start_step: int):
    """Deterministic accept/reject for self-speculative decode.

    ``logits`` (g, V) are the verifier's outputs for one speculation
    window: row r holds the logits for sequence position
    ``start_step + r`` (row 0 re-forwarded the last committed token;
    rows 1..g-1 forwarded ``drafts``).  Each row is sampled with the
    *same* seeded sampler a non-speculative decode step would use at
    that position, and a draft is accepted iff it equals the sampled
    target exactly — so the emitted stream is token-identical to plain
    decode, whatever the temperature.  Rows past the first mismatch
    conditioned on rejected drafts and are discarded.

    Returns ``(tokens, accepted)``: the emitted token ids (1 + accepted
    drafts; the final entry is the verifier's "bonus" token, fresh for
    the first rejected position or appended after a fully-accepted
    window) and the number of drafts accepted.
    """
    g = logits.shape[0]
    steps = start_step + np.arange(g, dtype=np.int64)
    targets = sample(logits, params, step=steps)
    accepted = 0
    for d in drafts:
        if accepted >= g - 1 or int(targets[accepted]) != int(d):
            break
        accepted += 1
    return targets[:accepted + 1], accepted
