"""Token sampling: greedy / temperature / top-p, host-side."""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class SamplingParams:
    temperature: float = 0.0   # 0 = greedy
    top_p: float = 1.0
    seed: int = 0


def sample(logits: np.ndarray, params: SamplingParams,
           step: int = 0) -> np.ndarray:
    """logits: (B, V) -> (B,) int32 token ids.

    Deterministic given (seed, step) *per row*: every row shares the one
    uniform drawn for this step, so a request's token depends only on
    its own logits — not on its batch slot or on which other requests
    happen to be decoding this step.  Recovery replays (a surviving
    request re-stepping after a migration changed the batch) therefore
    reproduce the originally emitted tokens.
    """
    logits = np.asarray(logits, dtype=np.float64)
    if params.temperature <= 0.0:
        return np.argmax(logits, axis=-1).astype(np.int32)
    rng = np.random.default_rng(params.seed * 1_000_003 + step)
    u = rng.random()
    z = logits / params.temperature
    z = z - z.max(axis=-1, keepdims=True)
    p = np.exp(z)
    p /= p.sum(axis=-1, keepdims=True)
    order = np.argsort(-p, axis=-1)
    sorted_p = np.take_along_axis(p, order, axis=-1)
    if params.top_p < 1.0:
        csum = np.cumsum(sorted_p, axis=-1)
        cut = csum - sorted_p > params.top_p
        sorted_p[cut] = 0.0
        sorted_p /= sorted_p.sum(axis=-1, keepdims=True)
    # shared-u inverse CDF over the sorted distribution, vectorized
    cdf = np.cumsum(sorted_p, axis=-1)
    idx = np.minimum((cdf < u).sum(axis=-1), logits.shape[-1] - 1)
    return np.take_along_axis(order, idx[:, None], axis=-1)[:, 0].astype(
        np.int32)
