"""Token sampling: greedy / temperature / top-p, host-side."""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class SamplingParams:
    temperature: float = 0.0   # 0 = greedy
    top_p: float = 1.0
    seed: int = 0


# ---------------------------------------------------------------------------
# Batched seeded uniforms.
#
# The sampler draws one uniform per row from
# ``np.random.default_rng(seed).random()`` where ``seed`` encodes the row's
# sequence position.  Constructing a Generator per row per step is the
# engine's host-side hot spot, so ``_seeded_uniforms`` replicates numpy's
# SeedSequence pool mixing + PCG64 seeding + first draw exactly — same bits
# out — as a handful of vectorized uint32/uint64 passes over all rows at
# once.  The hash-constant chains below are data-independent, so they are
# precomputed once at import (as Python ints, then narrowed to uint32).
# ---------------------------------------------------------------------------

_XSHIFT = np.uint32(16)
_MIX_L = np.uint32(0xCA01F9DD)           # SeedSequence MIX_MULT_L
_MIX_R = np.uint32(0x4973F715)           # SeedSequence MIX_MULT_R
_U32MASK = np.uint64(0xFFFFFFFF)
_PCG_MULT_HI = np.uint64(2549297995355413924)   # PCG64 128-bit multiplier
_PCG_MULT_LO = np.uint64(4865540595714422341)


def _hash_consts(init: int, mult: int, n: int):
    """(xor, mul) uint32 pairs for n chained SeedSequence hashmix calls."""
    out, hc = [], init
    for _ in range(n):
        nxt = (hc * mult) & 0xFFFFFFFF
        out.append((np.uint32(hc), np.uint32(nxt)))
        hc = nxt
    return out


# pool fill (4 calls) + pool mixing (12 calls) share one INIT_A chain;
# generate_state uses its own INIT_B chain (8 output words).
_HASH_A = _hash_consts(0x43B0D7E5, 0x931E8875, 16)
_HASH_B = _hash_consts(0x8B51F9DD, 0x58F38DED, 8)


def _seeded_uniforms(seeds: np.ndarray) -> np.ndarray:
    """One ``np.random.default_rng(int(s)).random()`` per entry, batched.

    Bit-identical to the per-row Generator construction for any seed that
    fits in uint64 (callers guard the range and fall back otherwise).
    """
    seeds = np.asarray(seeds, dtype=np.uint64)
    # -- SeedSequence: fill + mix the 4-word entropy pool.  Entropy is the
    # seed as [lo32, hi32]; absent words hash like explicit zeros, so every
    # seed < 2**64 takes this one code path.
    consts = iter(_HASH_A)

    def hashmix(v):
        xor_c, mul_c = next(consts)
        v = (v ^ xor_c) * mul_c
        return v ^ (v >> _XSHIFT)

    zero = np.zeros(seeds.shape, np.uint32)
    pool = [hashmix((seeds & _U32MASK).astype(np.uint32)),
            hashmix((seeds >> np.uint64(32)).astype(np.uint32)),
            hashmix(zero), hashmix(zero)]
    for i_src in range(4):
        for i_dst in range(4):
            if i_src == i_dst:
                continue
            r = pool[i_dst] * _MIX_L - hashmix(pool[i_src]) * _MIX_R
            pool[i_dst] = r ^ (r >> _XSHIFT)
    # -- SeedSequence.generate_state(4, uint64): 8 uint32 words, paired
    # little-endian into (initstate, initseq) 64-bit halves.
    w = []
    for i, (xor_c, mul_c) in enumerate(_HASH_B):
        v = (pool[i % 4] ^ xor_c) * mul_c
        w.append((v ^ (v >> _XSHIFT)).astype(np.uint64))
    sh = np.uint64(32)
    st_hi, st_lo = w[0] | (w[1] << sh), w[2] | (w[3] << sh)
    iq_hi, iq_lo = w[4] | (w[5] << sh), w[6] | (w[7] << sh)
    inc_hi = (iq_hi << np.uint64(1)) | (iq_lo >> np.uint64(63))
    inc_lo = (iq_lo << np.uint64(1)) | np.uint64(1)

    def mul_hilo(a, b):
        # full 64x64 -> 128-bit product via 32-bit limbs
        al, ah = a & _U32MASK, a >> sh
        bl, bh = b & _U32MASK, b >> sh
        ll, lh, hl, hh = al * bl, al * bh, ah * bl, ah * bh
        mid = (ll >> sh) + (lh & _U32MASK) + (hl & _U32MASK)
        lo = (ll & _U32MASK) | ((mid & _U32MASK) << sh)
        return hh + (lh >> sh) + (hl >> sh) + (mid >> sh), lo

    def pcg_step(hi, lo):
        # state = state * MULT + inc  (mod 2**128)
        phi, plo = mul_hilo(lo, _PCG_MULT_LO)
        phi = phi + lo * _PCG_MULT_HI + hi * _PCG_MULT_LO
        lo2 = plo + inc_lo
        return phi + inc_hi + (lo2 < plo).astype(np.uint64), lo2

    hi = np.zeros(seeds.shape, np.uint64)
    lo = np.zeros(seeds.shape, np.uint64)
    hi, lo = pcg_step(hi, lo)                 # srandom: advance zero state
    lo2 = lo + st_lo
    hi, lo = hi + st_hi + (lo2 < lo).astype(np.uint64), lo2
    hi, lo = pcg_step(hi, lo)                 # srandom: second advance
    hi, lo = pcg_step(hi, lo)                 # the single .random() draw
    out = hi ^ lo                             # PCG64 XSL-RR output
    rot = hi >> np.uint64(58)
    out = (out >> rot) | (out << ((np.uint64(64) - rot) & np.uint64(63)))
    return (out >> np.uint64(11)) * (1.0 / 9007199254740992.0)


def seeded_uniforms(seed: int, steps: np.ndarray) -> np.ndarray:
    """Per-row uniforms for ``sample``: rng(seed*1_000_003 + step).random().

    Vectorized over rows when every derived seed fits in uint64; falls back
    to the reference per-row Generator path for exotic seeds.
    """
    steps = np.asarray(steps, np.int64)
    if steps.size == 0:
        return np.empty(0, np.float64)
    base = seed * 1_000_003
    lo_v, hi_v = base + int(steps.min()), base + int(steps.max())
    if 0 <= lo_v and hi_v < 2 ** 64:
        # wraparound addition is exact here: the true values are in range
        return _seeded_uniforms(np.uint64(base & 0xFFFFFFFFFFFFFFFF)
                                + steps.astype(np.uint64))
    return np.asarray([np.random.default_rng(base + int(s)).random()
                       for s in steps])


def sample(logits: np.ndarray, params: SamplingParams,
           step=0) -> np.ndarray:
    """logits: (B, V) -> (B,) int32 token ids.

    Deterministic given (seed, step) *per row*: each row's uniform is
    drawn from (seed, its step value) alone, so a request's token
    depends only on its own logits and step — not on its batch slot or
    on which other requests happen to be decoding alongside it.

    ``step`` may be a scalar (all rows share one draw, the pre-fleet
    behaviour) or a per-row array.  The serving executors pass each
    request's *sequence position* as its step, which makes the sampled
    token a pure function of (seed, prompt, position): a request
    replayed after migration — to another executor or to another fleet
    instance entirely — reproduces its original tokens exactly.
    """
    logits = np.asarray(logits, dtype=np.float64)
    if params.temperature <= 0.0:
        return np.argmax(logits, axis=-1).astype(np.int32)
    steps = np.broadcast_to(np.asarray(step, np.int64), (logits.shape[0],))
    u = seeded_uniforms(params.seed, steps)
    z = logits / params.temperature
    z = z - z.max(axis=-1, keepdims=True)
    p = np.exp(z)
    p /= p.sum(axis=-1, keepdims=True)
    order = np.argsort(-p, axis=-1)
    sorted_p = np.take_along_axis(p, order, axis=-1)
    if params.top_p < 1.0:
        csum = np.cumsum(sorted_p, axis=-1)
        cut = csum - sorted_p > params.top_p
        sorted_p[cut] = 0.0
        sorted_p /= sorted_p.sum(axis=-1, keepdims=True)
    # per-row-u inverse CDF over the sorted distribution, vectorized
    cdf = np.cumsum(sorted_p, axis=-1)
    idx = np.minimum((cdf < u[:, None]).sum(axis=-1), logits.shape[-1] - 1)
    return np.take_along_axis(order, idx[:, None], axis=-1)[:, 0].astype(
        np.int32)


# ---------------------------------------------------------------------------
# Device-side predictive sampling (overlap pipeline's async readback).
#
# The overlapped executor never materializes full logits on the host
# mid-pipeline: a tiny jitted epilogue samples every window's tokens
# on-device with the same math as `sample` (greedy argmax / seeded
# top-p inverse-CDF — the per-position uniforms are computed on host by
# `seeded_uniforms` and passed in), chains the chosen last tokens into a
# device-resident next-token vector, and only token-id-sized arrays ride
# the device→host readback ring.  Greedy prediction is exact (argmax
# order survives the f32↔f64 cast, both sides take the first index);
# temperature>0 may rarely differ in the last ULP of the CDF — the host
# sampler re-derives every token from the drained logits at commit time
# and remains authoritative, so a disagreement costs a replan, never a
# wrong token.
# ---------------------------------------------------------------------------

_DEVICE_PREDICT_CACHE: dict = {}


def device_predict(logits, row0, lens, drafts, u, dev_last, slots, *,
                   temperature: float, top_p: float):
    """Sample all windows of one compiled step's logits on-device.

    logits: (R, V) device array.  Per window i (of S, padded):
    ``row0[i]`` first logits row, ``lens[i]`` rows used (0 = padding),
    ``drafts[i]`` the g tokens forwarded (row 0's entry unused),
    ``u[i]`` per-row uniforms, ``slots[i]`` decode batch slot (out of
    range = dropped).  Returns ``(targets (S,G), accepted (S,),
    new_dev_last)`` — targets row-wise sampled tokens, accepted the
    number of drafts matched, and ``dev_last`` updated with each
    window's emitted last token."""
    key = (round(float(temperature), 9), round(float(top_p), 9))
    fn = _DEVICE_PREDICT_CACHE.get(key)
    if fn is None:
        fn = _build_device_predict(*key)
        _DEVICE_PREDICT_CACHE[key] = fn
    return fn(logits, row0, lens, drafts, u, dev_last, slots)


def _build_device_predict(temperature: float, top_p: float):
    import jax
    import jax.numpy as jnp

    def predict(logits, row0, lens, drafts, u, dev_last, slots):
        G = drafts.shape[1]
        idx = jnp.clip(row0[:, None] + jnp.arange(G, dtype=row0.dtype),
                       0, logits.shape[0] - 1)
        rows = logits[idx].astype(jnp.float32)          # (S, G, V)
        if temperature <= 0.0:
            targets = jnp.argmax(rows, axis=-1).astype(jnp.int32)
        else:
            z = rows / temperature
            z = z - z.max(axis=-1, keepdims=True)
            p = jnp.exp(z)
            p = p / p.sum(axis=-1, keepdims=True)
            order = jnp.argsort(-p, axis=-1)
            sp = jnp.take_along_axis(p, order, axis=-1)
            if top_p < 1.0:
                csum = jnp.cumsum(sp, axis=-1)
                sp = jnp.where(csum - sp > top_p, 0.0, sp)
                sp = sp / sp.sum(axis=-1, keepdims=True)
            cdf = jnp.cumsum(sp, axis=-1)
            k = jnp.minimum((cdf < u[..., None]).sum(axis=-1),
                            rows.shape[-1] - 1)
            targets = jnp.take_along_axis(
                order, k[..., None], axis=-1)[..., 0].astype(jnp.int32)
        if G > 1:
            ok = (targets[:, :-1] == drafts[:, 1:])
            live = jnp.arange(1, G)[None, :] < lens[:, None]
            accepted = jnp.cumprod(
                (ok & live).astype(jnp.int32), axis=1).sum(axis=1)
        else:
            accepted = jnp.zeros(row0.shape, jnp.int32)
        accepted = jnp.minimum(accepted, jnp.maximum(lens - 1, 0))
        last = jnp.take_along_axis(
            targets, accepted[:, None].astype(jnp.int32), axis=1)[:, 0]
        safe = jnp.where(lens > 0, slots, dev_last.shape[0])
        new_last = dev_last.at[safe].set(last, mode="drop")
        return targets, accepted, new_last

    return jax.jit(predict)


def spec_verify(logits: np.ndarray, drafts, params: SamplingParams, *,
                start_step: int):
    """Deterministic accept/reject for self-speculative decode.

    ``logits`` (g, V) are the verifier's outputs for one speculation
    window: row r holds the logits for sequence position
    ``start_step + r`` (row 0 re-forwarded the last committed token;
    rows 1..g-1 forwarded ``drafts``).  Each row is sampled with the
    *same* seeded sampler a non-speculative decode step would use at
    that position, and a draft is accepted iff it equals the sampled
    target exactly — so the emitted stream is token-identical to plain
    decode, whatever the temperature.  Rows past the first mismatch
    conditioned on rejected drafts and are discarded.

    Returns ``(tokens, accepted)``: the emitted token ids (1 + accepted
    drafts; the final entry is the verifier's "bonus" token, fresh for
    the first rejected position or appended after a fully-accepted
    window) and the number of drafts accepted.
    """
    g = logits.shape[0]
    steps = start_step + np.arange(g, dtype=np.int64)
    targets = sample(logits, params, step=steps)
    accepted = 0
    for d in drafts:
        if accepted >= g - 1 or int(targets[accepted]) != int(d):
            break
        accepted += 1
    return targets[:accepted + 1], accepted
