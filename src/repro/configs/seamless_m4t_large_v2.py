"""seamless-m4t-large-v2 [audio] — enc-dec multimodal [arXiv:2308.11596].

Assignment specifies the transformer backbone: 24L d_model=1024 16H
(kv=16) d_ff=8192 vocab=256206.  The mel-spectrogram + conv feature
extractor frontend is a stub — ``input_specs()`` supplies precomputed
frame embeddings for the encoder (the assignment carve-out).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    source="[arXiv:2308.11596]",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=8192,
    vocab_size=256206,
    head_dim=64,
    encoder_layers=24,
    encoder_seq=1536,  # precomputed audio frame embeddings per utterance
)
