"""falcon-mamba-7b [ssm] — attention-free Mamba-1 [arXiv:2410.05355]."""
from repro.configs.base import MambaConfig, ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    family="ssm",
    source="[arXiv:2410.05355]",
    num_layers=64,
    d_model=4096,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=65024,
    attention_type="none",
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2),
)
