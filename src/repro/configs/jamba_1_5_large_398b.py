"""jamba-1.5-large-398b [hybrid] — Mamba+attention 1:7, MoE [arXiv:2403.19887].

72 layers in periods of 8: one attention sublayer per period, 7 Mamba.
MoE (16 experts, top-2) on every other sublayer.
"""
from repro.configs.base import MambaConfig, MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    source="[arXiv:2403.19887]",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=24576,
    vocab_size=65536,
    head_dim=128,
    moe=MoEConfig(
        num_experts=16,
        top_k=2,
        expert_d_ff=24576,
        moe_layer_period=2,
        # one replica per expert: 16+16=32 physical slots shard 16-way
        num_redundant_experts=16,
    ),
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2),
    hybrid_period=8,
    hybrid_attn_index=4,
)
