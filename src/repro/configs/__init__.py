"""Architecture registry: the 10 assigned architectures + the paper's own.

``get_config(name)`` returns the full-size config; ``get_smoke_config``
returns the reduced same-family variant used by CPU smoke tests.
"""
from __future__ import annotations

from repro.configs.base import (
    INPUT_SHAPES,
    LONG_CONTEXT_WINDOW,
    InputShape,
    MLAConfig,
    MambaConfig,
    MoEConfig,
    ModelConfig,
    reduced,
)

from repro.configs.minicpm3_4b import CONFIG as _minicpm3
from repro.configs.kimi_k2_1t_a32b import CONFIG as _kimi
from repro.configs.jamba_1_5_large_398b import CONFIG as _jamba
from repro.configs.falcon_mamba_7b import CONFIG as _falcon
from repro.configs.mistral_large_123b import CONFIG as _mistral
from repro.configs.seamless_m4t_large_v2 import CONFIG as _seamless
from repro.configs.internvl2_26b import CONFIG as _internvl
from repro.configs.nemotron_4_340b import CONFIG as _nemotron
from repro.configs.qwen2_moe_a2_7b import CONFIG as _qwen_moe
from repro.configs.internlm2_20b import CONFIG as _internlm
from repro.configs.deepseek_v3 import CONFIG as _deepseek

ASSIGNED_ARCHS = (
    "minicpm3-4b",
    "kimi-k2-1t-a32b",
    "jamba-1.5-large-398b",
    "falcon-mamba-7b",
    "mistral-large-123b",
    "seamless-m4t-large-v2",
    "internvl2-26b",
    "nemotron-4-340b",
    "qwen2-moe-a2.7b",
    "internlm2-20b",
)

_REGISTRY = {
    c.name: c
    for c in (
        _minicpm3, _kimi, _jamba, _falcon, _mistral, _seamless,
        _internvl, _nemotron, _qwen_moe, _internlm, _deepseek,
    )
}

ALL_ARCHS = tuple(_REGISTRY)


def get_config(name: str, shape: str | None = None) -> ModelConfig:
    """Return the registered config, adapted to an input shape if given.

    For ``long_500k`` on full-attention architectures, a sliding-window
    variant (window=LONG_CONTEXT_WINDOW) is selected so decode stays
    sub-quadratic (DESIGN.md §5).  Sub-quadratic families (ssm/hybrid) are
    returned unchanged.
    """
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    cfg = _REGISTRY[name]
    cfg.validate()
    if shape == "long_500k" and not cfg.supports_long_context_natively:
        cfg = cfg.with_sliding_window(LONG_CONTEXT_WINDOW)
    if shape == "long_500k" and cfg.hybrid_period:
        # Hybrid: Mamba handles length natively; the sparse attention
        # sublayers use a windowed KV so their ring cache stays bounded.
        cfg = cfg.with_sliding_window(LONG_CONTEXT_WINDOW)
    return cfg


def get_smoke_config(name: str) -> ModelConfig:
    return reduced(get_config(name))


__all__ = [
    "ALL_ARCHS",
    "ASSIGNED_ARCHS",
    "INPUT_SHAPES",
    "LONG_CONTEXT_WINDOW",
    "InputShape",
    "MLAConfig",
    "MambaConfig",
    "MoEConfig",
    "ModelConfig",
    "get_config",
    "get_smoke_config",
    "reduced",
]
