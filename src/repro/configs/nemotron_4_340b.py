"""nemotron-4-340b [dense] — GQA, squared-ReLU [arXiv:2402.16819]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-340b",
    family="dense",
    source="[arXiv:2402.16819]",
    num_layers=96,
    d_model=18432,
    num_heads=96,
    num_kv_heads=8,
    d_ff=73728,
    vocab_size=256000,
    head_dim=192,
    activation="relu2",
)
