"""deepseek-v3 [moe] — the paper's own evaluation model [arXiv:2412.19437].

Not part of the assigned 10; included because every ReviveMoE experiment
(Fig. 1, Fig. 5, Table 2) is run on DeepSeek V3, so the benchmark
analogues use (a reduced variant of) this config.
"""
from repro.configs.base import MLAConfig, MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3",
    family="moe",
    source="[arXiv:2412.19437]",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,
    d_ff=18432,
    vocab_size=129280,
    head_dim=128,
    attention_type="mla",
    mla=MLAConfig(
        q_lora_rank=1536,
        kv_lora_rank=512,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
    ),
    moe=MoEConfig(
        num_experts=256,
        top_k=8,
        expert_d_ff=2048,
        num_shared_experts=1,
        first_k_dense=3,
        dense_d_ff=18432,
        num_redundant_experts=32,
    ),
)
