"""internvl2-26b [vlm] — InternViT + InternLM2 [arXiv:2404.16821].

Backbone only (InternLM2-20B-style LM): 48L d_model=6144 48H (kv=8)
d_ff=16384 vocab=92553.  The InternViT vision encoder + projector is a
stub — ``input_specs()`` supplies precomputed patch embeddings that are
prefixed to the token sequence (the assignment carve-out).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b",
    family="vlm",
    source="[arXiv:2404.16821]",
    num_layers=48,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=16384,
    vocab_size=92553,
    head_dim=128,
    num_patches=256,  # one tile of InternViT patches after pixel-shuffle
)
