"""kimi-k2-1t-a32b [moe] — Kimi K2, trillion-param MoE [arXiv:2501.kimi2].

Paper-table assignment: 61L d_model=7168 64H (GQA kv=8) expert d_ff=2048,
vocab 163840, 384 experts top-8.  Kimi K2 is one of the models the paper
reports serving on xDeepServe, making this the closest production analogue
for ReviveMoE's expert-recovery paths.
"""
from repro.configs.base import MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    source="[arXiv:2501.kimi2]",
    num_layers=61,
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,
    d_ff=2048,
    vocab_size=163840,
    head_dim=128,
    moe=MoEConfig(
        num_experts=384,
        top_k=8,
        expert_d_ff=2048,
        num_shared_experts=1,
        first_k_dense=1,
        dense_d_ff=18432,
        num_redundant_experts=32,
    ),
)
