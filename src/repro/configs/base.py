"""Configuration dataclasses for the repro framework.

Every assigned architecture is expressed as a ``ModelConfig``. The config is
deliberately explicit (no HF-style kwargs soup): each field is consumed by
exactly one place in ``repro.models``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, replace
from typing import Optional


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts settings (GShard/DeepSeek-style routed experts)."""

    num_experts: int
    top_k: int
    expert_d_ff: int
    num_shared_experts: int = 0
    # Layers [0, first_k_dense) use a dense FFN instead of MoE (DeepSeek V3
    # uses 3, Kimi K2 uses 1).  These dense FFNs are what §3.4's
    # "compromised FFN TP group" handling applies to.
    first_k_dense: int = 0
    dense_d_ff: int = 0           # d_ff of those first dense layers
    moe_layer_period: int = 1     # MoE every Nth layer (Jamba: 2)
    capacity_factor: float = 1.25
    # smallest per-expert dispatch capacity; 1 = exact-fit (decode perf)
    min_capacity: int = 8
    # Redundant experts (paper §3.4): number of extra physical replicas
    # provisioned for the hottest experts, used for load balance *and*
    # fault tolerance.
    num_redundant_experts: int = 0
    router_noise: float = 0.0
    aux_loss_coef: float = 0.01


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head latent attention (DeepSeek/MiniCPM3 style)."""

    q_lora_rank: int
    kv_lora_rank: int
    qk_nope_head_dim: int
    qk_rope_head_dim: int
    v_head_dim: int


@dataclass(frozen=True)
class MambaConfig:
    """Mamba-1 selective SSM settings."""

    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0  # 0 -> ceil(d_model / 16)

    def resolved_dt_rank(self, d_model: int) -> int:
        return self.dt_rank if self.dt_rank > 0 else max(1, d_model // 16)


@dataclass(frozen=True)
class ModelConfig:
    # identity
    name: str
    family: str          # dense | moe | hybrid | ssm | audio | vlm
    source: str          # citation from the assignment table

    # trunk dims
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0            # 0 -> d_model // num_heads

    # mixer selection
    attention_type: str = "gqa"  # gqa | mla | none
    activation: str = "swiglu"   # swiglu | relu2
    mla: Optional[MLAConfig] = None
    moe: Optional[MoEConfig] = None
    mamba: Optional[MambaConfig] = None
    # hybrid (Jamba): layers come in periods of ``hybrid_period``; the
    # sublayer at index ``hybrid_attn_index`` is attention, the rest Mamba.
    hybrid_period: int = 0
    hybrid_attn_index: int = 0

    # encoder-decoder (audio): number of encoder layers; 0 = decoder-only.
    encoder_layers: int = 0
    encoder_seq: int = 0         # stub frame count fed by input_specs()
    # vlm: number of stub patch embeddings prefixed to the token sequence.
    num_patches: int = 0

    # attention extras
    rope_theta: float = 10000.0
    sliding_window: int = 0      # 0 = full causal attention
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    # runtime knobs (overridden per input-shape / perf experiment)
    # MoE execution: 'gather_psum' | 'a2a' pick the distributed
    # dispatch/combine (DESIGN.md §6); a '_fused' suffix (or plain
    # 'fused' for single-rank) additionally routes the local expert
    # compute through the fused Pallas dispatch->FFN->combine kernel
    # instead of the dense-scatter capacity buffer.
    moe_impl: str = "gather_psum"
    # Paged decode/chunk step execution: 'composed' runs the
    # attention -> router -> MoE op chain (each op jnp oracle on CPU,
    # Pallas kernel on TPU); 'megakernel' fuses one attention+MoE
    # block's paged attention, output projection, residuals, norm,
    # router top-k, replica selection and expert FFN+combine into a
    # single decode-shaped kernel launch (``ops.decode_megastep``).
    # Blocks the megakernel cannot express (dense FFN, recurrent
    # mixers, distributed MoE) fall back to the composed chain.
    decode_impl: str = "composed"
    remat: bool = False
    scan_layers: bool = True
    # decode-cache update strategy: False = cache flows as scan xs/ys
    # (copies the whole cache each step); True = cache is a scan carry
    # updated with in-place dynamic_update_slice (aliasable — §Perf A4)
    decode_cache_carry: bool = False

    MOE_IMPLS = ("gather_psum", "a2a", "fused", "gather_psum_fused",
                 "a2a_fused")
    DECODE_IMPLS = ("composed", "megakernel")

    @property
    def moe_fused(self) -> bool:
        """True when local expert compute uses the fused Pallas pipeline."""
        return self.moe_impl == "fused" or self.moe_impl.endswith("_fused")

    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        assert self.num_heads > 0
        return self.d_model // self.num_heads

    @property
    def is_attention_free(self) -> bool:
        return self.attention_type == "none" and self.hybrid_period == 0

    @property
    def supports_long_context_natively(self) -> bool:
        """True when decode cost is sub-quadratic without modification."""
        return self.family in ("ssm",) or self.hybrid_period > 0

    def with_sliding_window(self, window: int) -> "ModelConfig":
        return replace(self, sliding_window=window)

    def validate(self) -> None:
        assert self.family in ("dense", "moe", "hybrid", "ssm", "audio", "vlm")
        assert self.moe_impl in self.MOE_IMPLS, self.moe_impl
        assert self.decode_impl in self.DECODE_IMPLS, self.decode_impl
        assert self.attention_type in ("gqa", "mla", "none")
        if self.attention_type == "mla":
            assert self.mla is not None
        if self.attention_type == "gqa" and self.num_heads:
            assert self.num_heads % max(1, self.num_kv_heads) == 0
        if self.family in ("moe",):
            assert self.moe is not None
        if self.family == "ssm":
            assert self.mamba is not None and self.attention_type == "none"
        if self.hybrid_period:
            assert self.mamba is not None
            assert self.num_layers % self.hybrid_period == 0
        if self.family == "audio":
            assert self.encoder_layers > 0 and self.encoder_seq > 0
        if self.family == "vlm":
            assert self.num_patches > 0


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}

# Window applied to full-attention architectures for the long_500k shape
# (see DESIGN.md §5): keeps decode sub-quadratic and the ring cache small.
LONG_CONTEXT_WINDOW = 8192


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Reduced variant of the same family for CPU smoke tests.

    2 layers (one hybrid period for hybrids), d_model<=256, <=4 experts.
    """
    d_model = 256
    num_heads = 4
    num_kv = min(cfg.num_kv_heads, 2) if cfg.num_kv_heads else 0
    kw = dict(
        name=cfg.name + "-smoke",
        num_layers=2,
        d_model=d_model,
        num_heads=num_heads if cfg.num_heads else 0,
        num_kv_heads=num_kv,
        d_ff=512,
        vocab_size=512,
        head_dim=64 if cfg.num_heads else 0,
        encoder_layers=2 if cfg.encoder_layers else 0,
        encoder_seq=16 if cfg.encoder_seq else 0,
        num_patches=8 if cfg.num_patches else 0,
        scan_layers=False,
    )
    if cfg.moe is not None:
        kw["moe"] = replace(
            cfg.moe,
            num_experts=4,
            top_k=min(cfg.moe.top_k, 2),
            expert_d_ff=128,
            num_shared_experts=min(cfg.moe.num_shared_experts, 1),
            first_k_dense=min(cfg.moe.first_k_dense, 1),
            dense_d_ff=256 if cfg.moe.first_k_dense else 0,
            num_redundant_experts=min(cfg.moe.num_redundant_experts, 2),
        )
    if cfg.mla is not None:
        kw["mla"] = MLAConfig(
            q_lora_rank=64, kv_lora_rank=32,
            qk_nope_head_dim=32, qk_rope_head_dim=16, v_head_dim=32,
        )
    if cfg.mamba is not None:
        kw["mamba"] = replace(cfg.mamba, dt_rank=16)
    if cfg.hybrid_period:
        kw["num_layers"] = cfg.hybrid_period  # a single period
        kw["hybrid_period"] = cfg.hybrid_period
        kw["hybrid_attn_index"] = cfg.hybrid_attn_index
    out = replace(cfg, **kw)
    out.validate()
    return out
