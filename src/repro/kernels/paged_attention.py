"""Paged GQA decode-attention kernel (the per-step serving hot spot).

One query token per sequence attends over a paged KV pool through a block
table — the TPU-native analogue of the serving engine's paged cache.  The
block table and sequence lengths ride in as *scalar-prefetch* operands so
each grid step can DMA exactly the page it needs from HBM:

  grid = (B, max_blk); page j of sequence b is resolved to a physical
  pool page via block_table[b, j] inside the k/v BlockSpec index_map.

Online softmax (running max / denominator / accumulator in VMEM scratch,
carried across the sequential page axis) keeps the score matrix
unmaterialized; the output tile is written once on the final page.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import tpu_compiler_params

NEG_INF = -1e30


def _paged_attn_kernel(bt_ref, sl_ref, st_ref, q_ref, k_ref, v_ref, o_ref,
                       acc_ref, m_ref, l_ref, *, bs: int, scale: float):
    b = pl.program_id(0)
    j = pl.program_id(1)
    nblk = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)                  # (H, Dh)
    k = k_ref[0].astype(jnp.float32)                  # (bs, Hkv, Dh)
    v = v_ref[0].astype(jnp.float32)
    H, Dh = q.shape
    Hkv = k.shape[1]
    G = H // Hkv

    pos = j * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)
    # valid window: [start, len) — start > 0 models a sliding window
    valid = (pos < sl_ref[b]) & (pos >= st_ref[b])    # (1, bs)

    # per-kv-head matmuls: (G, Dh) x (Dh, bs) -> (G, bs)
    qg = q.reshape(Hkv, G, Dh)
    s_rows = []
    for h in range(Hkv):
        s_rows.append(jnp.dot(qg[h], k[:, h, :].T,
                              preferred_element_type=jnp.float32))
    s = jnp.stack(s_rows).reshape(H, bs) * scale      # (H, bs)
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]                               # (H, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    p = jnp.where(valid, p, 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    pv_rows = []
    pg = p.reshape(Hkv, G, bs)
    for h in range(Hkv):
        pv_rows.append(jnp.dot(pg[h], v[:, h, :],
                               preferred_element_type=jnp.float32))
    pv = jnp.stack(pv_rows).reshape(H, Dh)
    acc_ref[...] = acc_ref[...] * corr + pv
    m_ref[...] = m_new

    @pl.when(j == nblk - 1)
    def _finish():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def paged_attention_pallas(q, k_pool, v_pool, block_table, seq_lens,
                           start_lens=None, *, interpret: bool = False):
    """q: (B,H,Dh); pools: (nb, bs, Hkv, Dh); block_table: (B, max_blk);
    seq_lens: (B,); start_lens: optional (B,) first valid position
    (sliding window) -> (B, H, Dh)."""
    B, H, Dh = q.shape
    nb, bs, Hkv, _ = k_pool.shape
    max_blk = block_table.shape[1]
    scale = 1.0 / (Dh ** 0.5)
    if start_lens is None:
        start_lens = jnp.zeros_like(seq_lens)

    kernel = functools.partial(_paged_attn_kernel, bs=bs, scale=scale)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B, max_blk),
        in_specs=[
            pl.BlockSpec((1, H, Dh), lambda b, j, bt, sl, st: (b, 0, 0)),
            pl.BlockSpec((1, bs, Hkv, Dh),
                         lambda b, j, bt, sl, st: (bt[b, j], 0, 0, 0)),
            pl.BlockSpec((1, bs, Hkv, Dh),
                         lambda b, j, bt, sl, st: (bt[b, j], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, H, Dh),
                               lambda b, j, bt, sl, st: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((H, Dh), jnp.float32),
            pltpu.VMEM((H, 1), jnp.float32),
            pltpu.VMEM((H, 1), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, Dh), q.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(block_table.astype(jnp.int32), seq_lens.astype(jnp.int32),
      start_lens.astype(jnp.int32), q, k_pool, v_pool)
