"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute in interpret mode; on TPU they
compile natively.  ``use_pallas=False`` falls back to the jnp oracle —
the serving engine uses the oracle on CPU for speed, the kernels are the
TPU deployment path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.decode_megakernel import decode_megastep_pallas
from repro.kernels.expert_ffn import expert_ffn_pallas
from repro.kernels.moe_fused import moe_fused_pallas
from repro.kernels.paged_attention import paged_attention_pallas
from repro.kernels.router_topk import router_topk_pallas
from repro.kernels.ssm_scan import ssm_scan_pallas


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


@functools.partial(jax.jit, static_argnames=("k", "use_pallas"))
def router_topk(logits, expert_mask, k: int, use_pallas: bool = True):
    if not use_pallas:
        return ref.router_topk_ref(logits, expert_mask, k)
    return router_topk_pallas(logits, expert_mask, k, interpret=_on_cpu())


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def expert_ffn(x, gate_w, up_w, down_w, use_pallas: bool = True):
    if not use_pallas:
        return ref.expert_ffn_ref(x, gate_w, up_w, down_w)
    return expert_ffn_pallas(x, gate_w, up_w, down_w, interpret=_on_cpu())


@functools.partial(jax.jit,
                   static_argnames=("cap", "e_local", "use_pallas"))
def moe_dispatch_ffn_combine(x, gate_w, up_w, down_w, weights, phys, alive,
                             expert_offset, *, cap: int, e_local: int,
                             use_pallas: bool = True):
    """Fused MoE dispatch -> grouped SwiGLU FFN -> weighted combine.

    ``expert_offset`` is a *traced* operand (EP rank × e_local inside
    shard_map) and the MoERuntime-derived phys/alive/weights are data, so
    recovery mutations never retrigger compilation.  ``use_pallas=False``
    selects the jnp fallback (the serving engine's CPU path).
    """
    if not use_pallas:
        return ref.moe_fused_ref(x, gate_w, up_w, down_w, weights, phys,
                                 alive, cap=cap,
                                 expert_offset=expert_offset,
                                 e_local=e_local)
    return moe_fused_pallas(x, gate_w, up_w, down_w, weights, phys, alive,
                            cap=cap, expert_offset=expert_offset,
                            e_local=e_local, interpret=_on_cpu())


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def paged_attention(q, k_pool, v_pool, block_table, seq_lens,
                    start_lens=None, use_pallas: bool = True):
    """Decode attention over a paged pool.  ``start_lens`` (optional,
    (B,)) is the first valid position per sequence — the sliding-window
    lower bound; None means attend from position 0."""
    if not use_pallas:
        return ref.paged_attention_ref(q, k_pool, v_pool, block_table,
                                       seq_lens, start_lens)
    return paged_attention_pallas(q, k_pool, v_pool, block_table, seq_lens,
                                  start_lens, interpret=_on_cpu())


@functools.partial(jax.jit,
                   static_argnames=("top_k", "cap", "e_local", "eps",
                                    "use_pallas"))
def decode_megastep(q, k_pool, v_pool, block_table, seq_lens, start_lens,
                    x, w_post, ln2_w, router_w, l2p, replica_count,
                    expert_mask, gate_w, up_w, down_w, expert_offset,
                    shared_gate=None, shared_up=None, shared_down=None, *,
                    top_k: int, cap: int, e_local: int, eps: float = 1e-5,
                    use_pallas: bool = True):
    """One fused attention+MoE decode block step (ISSUE 5 tentpole,
    D-blocked + shared experts in ISSUE 8).

    Paged attention -> output projection -> residual -> norm -> router
    top-k -> replica select -> grouped expert FFN (+ shared-expert FFN)
    -> combine -> residual in one kernel launch (Pallas on TPU; jnp
    oracle on CPU).  Weight matrices stream through VMEM in ``d_model``
    pages, so deployment hidden sizes fit.  The block table / seq_lens /
    start_lens paging arrays, ``expert_offset`` and the MoERuntime
    arrays are all *traced data*, so continuous batching, revive,
    migration and expert masking never retrigger compilation.
    shared_gate/shared_up/shared_down are the shared-expert SwiGLU
    weights or None (no shared experts — the phase is statically
    elided).  Returns ``(y, h2)``.
    """
    if not use_pallas:
        return ref.decode_megastep_ref(
            q, k_pool, v_pool, block_table, seq_lens, start_lens, x,
            w_post, ln2_w, router_w, l2p, replica_count, expert_mask,
            gate_w, up_w, down_w, expert_offset, shared_gate, shared_up,
            shared_down, top_k=top_k, cap=cap, e_local=e_local, eps=eps)
    return decode_megastep_pallas(
        q, k_pool, v_pool, block_table, seq_lens, start_lens, x, w_post,
        ln2_w, router_w, l2p, replica_count, expert_mask, gate_w, up_w,
        down_w, expert_offset, shared_gate, shared_up, shared_down,
        top_k=top_k, cap=cap, e_local=e_local, eps=eps,
        interpret=_on_cpu())


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def ssm_scan(u, dt, A, B_ssm, C_ssm, use_pallas: bool = True):
    if not use_pallas:
        return ref.ssm_scan_ref(u, dt, A, B_ssm, C_ssm)
    return ssm_scan_pallas(u, dt, A, B_ssm, C_ssm, interpret=_on_cpu())


@functools.partial(jax.jit, static_argnames=("causal", "use_pallas"))
def flash_prefill(q, k, v, causal: bool = True, use_pallas: bool = True):
    from repro.kernels.flash_prefill import flash_prefill_pallas
    if not use_pallas:
        return ref.flash_prefill_ref(q, k, v, causal)
    return flash_prefill_pallas(q, k, v, causal=causal,
                                interpret=_on_cpu())
