"""Flash-attention prefill kernel (causal GQA, online softmax in VMEM).

The §Perf analysis showed pure-XLA chunked attention materializes the
(q_chunk, kv_chunk) probability tile to HBM on every inner step — at 32 k
context that is ~10 TB/step of avoidable traffic (the dominant roofline
term for every prefill shape).  This kernel keeps the score/probability
tile and the online-softmax state (m, l, acc) in VMEM scratch for the
whole kv sweep, so HBM sees only Q/K/V once and O once — the
memory-optimal schedule.

Tiling: grid = (B, Hkv, Sq/Bq, Skv/Bk), kv innermost (sequential);
q/o tiles are (G·Bq, Dh) with G = H/Hkv query heads per kv head —
MXU-aligned when G·Bq and Dh are multiples of 128.  Causal masking is
positional; fully-masked kv tiles are skipped via the index map (the
grid is still issued but the kernel exits early on the mask check).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import tpu_compiler_params

NEG_INF = -1e30


def _flash_prefill_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref,
                          l_ref, *, bq: int, bk: int, scale: float,
                          causal: bool):
    qi = pl.program_id(2)
    kj = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(kj == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # token positions of this tile pair
    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = kj * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    live = (not causal) or (qi * bq + bq - 1 >= kj * bk)

    @pl.when(live)
    def _tile():
        q = q_ref[0, 0].astype(jnp.float32)          # (G, bq, Dh)
        k = k_ref[0, 0].astype(jnp.float32)          # (bk, Dh)
        v = v_ref[0, 0].astype(jnp.float32)          # (bk, Dh)
        G = q.shape[0]
        s = jax.lax.dot_general(
            q.reshape(G * q.shape[1], -1), k,
            (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (G*bq, bk)
        s = s.reshape(G, -1, k.shape[0])
        if causal:
            mask = q_pos >= k_pos
            s = jnp.where(mask[None], s, NEG_INF)
        m_prev = m_ref[...]                           # (G, bq, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        if causal:
            p = jnp.where(mask[None], p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=-1, keepdims=True)
        pv = jax.lax.dot_general(
            p.reshape(-1, p.shape[-1]), v,
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * corr + pv.reshape(acc_ref.shape)
        m_ref[...] = m_new

    @pl.when(kj == nk - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_prefill_pallas(q, k, v, *, causal: bool = True, block_q: int = 256,
                         block_k: int = 256, interpret: bool = False):
    """q: (B, S, H, Dh); k/v: (B, S, Hkv, Dh) -> (B, S, H, Dh)."""
    B, S, H, Dh = q.shape
    Hkv = k.shape[2]
    assert H % Hkv == 0
    G = H // Hkv
    bq = min(block_q, S)
    bk = min(block_k, S)
    assert S % bq == 0 and S % bk == 0, (S, bq, bk)
    scale = 1.0 / (Dh ** 0.5)

    # layout: (B, Hkv, G, S, Dh) so one grid step owns a (G, bq, Dh) tile
    qg = q.reshape(B, S, Hkv, G, Dh).transpose(0, 2, 3, 1, 4)
    kg = k.transpose(0, 2, 1, 3)                     # (B, Hkv, S, Dh)
    vg = v.transpose(0, 2, 1, 3)

    kernel = functools.partial(_flash_prefill_kernel, bq=bq, bk=bk,
                               scale=scale, causal=causal)
    out = pl.pallas_call(
        kernel,
        grid=(B, Hkv, S // bq, S // bk),
        in_specs=[
            pl.BlockSpec((1, 1, G, bq, Dh),
                         lambda b, h, i, j: (b, h, 0, i, 0)),
            pl.BlockSpec((1, 1, bk, Dh), lambda b, h, i, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, bk, Dh), lambda b, h, i, j: (b, h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, bq, Dh),
                               lambda b, h, i, j: (b, h, 0, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, S, Dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, bq, Dh), jnp.float32),
            pltpu.VMEM((G, bq, 1), jnp.float32),
            pltpu.VMEM((G, bq, 1), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(qg, kg, vg)
    return out.transpose(0, 3, 1, 2, 4).reshape(B, S, H, Dh)
