"""Chunked Mamba selective-scan kernel.

The recurrence h_t = exp(dt_t·A)·h_{t-1} + dt_t·B_t·u_t is sequential in
time but embarrassingly parallel over (batch, channel).  TPU-native
layout: grid = (B, d_inner/db, S/Sc) with the chunk axis innermost and
*sequential*; the (db, N) state lives in VMEM scratch and is carried
across chunks, so HBM traffic is exactly one read of u/dt/B/C and one
write of y — the memory-bound optimum (N=16 keeps the state tiny).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import tpu_compiler_params


def _ssm_scan_kernel(u_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, hout_ref,
                     h_ref, *, chunk: int):
    cblk = pl.program_id(2)
    nchunk = pl.num_programs(2)

    @pl.when(cblk == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    A = a_ref[...].astype(jnp.float32)                # (db, N)

    def body(t, _):
        u_t = u_ref[0, t, :].astype(jnp.float32)      # (db,)
        dt_t = dt_ref[0, t, :].astype(jnp.float32)    # (db,)
        b_t = b_ref[0, t, :].astype(jnp.float32)      # (N,)
        c_t = c_ref[0, t, :].astype(jnp.float32)      # (N,)
        dA = jnp.exp(dt_t[:, None] * A)               # (db, N)
        h = h_ref[...] * dA + (dt_t * u_t)[:, None] * b_t[None, :]
        h_ref[...] = h
        y_ref[0, t, :] = jnp.sum(h * c_t[None, :], axis=1).astype(
            y_ref.dtype)
        return 0

    jax.lax.fori_loop(0, chunk, body, 0)

    @pl.when(cblk == nchunk - 1)
    def _emit_state():
        hout_ref[0] = h_ref[...].astype(hout_ref.dtype)


def ssm_scan_pallas(u, dt, A, B_ssm, C_ssm, *, block_d: int = 256,
                    chunk: int = 64, interpret: bool = False):
    """u/dt: (B,S,d); A: (d,N); B/C: (B,S,N) -> (y (B,S,d), h (B,d,N))."""
    Bsz, S, d = u.shape
    N = A.shape[1]
    db = min(block_d, d)
    Sc = min(chunk, S)
    assert d % db == 0 and S % Sc == 0, (d, db, S, Sc)

    kernel = functools.partial(_ssm_scan_kernel, chunk=Sc)
    y, h = pl.pallas_call(
        kernel,
        grid=(Bsz, d // db, S // Sc),
        in_specs=[
            pl.BlockSpec((1, Sc, db), lambda b, dd, c: (b, c, dd)),
            pl.BlockSpec((1, Sc, db), lambda b, dd, c: (b, c, dd)),
            pl.BlockSpec((db, N), lambda b, dd, c: (dd, 0)),
            pl.BlockSpec((1, Sc, N), lambda b, dd, c: (b, c, 0)),
            pl.BlockSpec((1, Sc, N), lambda b, dd, c: (b, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, Sc, db), lambda b, dd, c: (b, c, dd)),
            pl.BlockSpec((1, db, N), lambda b, dd, c: (b, dd, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bsz, S, d), jnp.float32),
            jax.ShapeDtypeStruct((Bsz, d, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((db, N), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(u, dt, A, B_ssm, C_ssm)
    return y, h
