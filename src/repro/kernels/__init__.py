"""Pallas TPU kernels for the serving hot spots.

Every kernel has a pure-jnp oracle in :mod:`repro.kernels.ref` (the
semantic ground truth for tests) and a jit'd public wrapper in
:mod:`repro.kernels.ops` with a ``use_pallas`` fallback switch — on CPU
the wrappers default to the oracle, on TPU they compile natively.

Kernels:

* ``flash_prefill``   — causal GQA prefill, online softmax in VMEM.
* ``paged_attention`` — one-token decode over a paged KV pool.
* ``router_topk``     — mask -> softmax -> top-k -> renormalize; the
  §3.4 failure mask is a kernel *input* (recovery = data write).
* ``ssm_scan``        — Mamba selective scan.
* ``expert_ffn``      — grouped SwiGLU FFN over a pre-built capacity
  buffer (building block, kept for the dense-scatter path).
* ``moe_fused``       — the fused MoE pipeline: token dispatch ->
  grouped SwiGLU FFN -> weighted combine in one kernel, fed by a single
  jnp sort pass (``moe_group_tokens``).  Selected end-to-end via
  ``ModelConfig.moe_impl`` ('fused', 'gather_psum_fused', 'a2a_fused')
  or ``EngineConfig.moe_impl``; the routing tables it consumes come from
  ``MoERuntime``, so ReviveMoE recovery (replica drop / expert mask)
  stays a data mutation with zero recompiles.

``compat.py`` shims Pallas API renames across JAX versions
(``TPUCompilerParams`` vs ``CompilerParams``).
"""
