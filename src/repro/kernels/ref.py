"""Pure-jnp oracles for every Pallas kernel.

These are the semantic ground truth: kernel tests sweep shapes/dtypes and
assert_allclose against these functions (interpret=True on CPU, compiled
on TPU).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def router_topk_ref(logits, expert_mask, k: int):
    """Fused routing oracle (§3.4 failure mask included).

    logits: (T, E) f32; expert_mask: (E,) bool.
    Returns (weights (T,k) f32 renormalized, indices (T,k) int32).
    """
    masked = jnp.where(expert_mask[None, :], logits.astype(jnp.float32),
                       -jnp.inf)
    gates = jax.nn.softmax(masked, axis=-1)
    w, idx = jax.lax.top_k(gates, k)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
    return w, idx.astype(jnp.int32)


def expert_ffn_ref(x, gate_w, up_w, down_w):
    """Grouped expert SwiGLU FFN oracle.

    x: (E, C, D); gate_w/up_w: (E, D, F); down_w: (E, F, D) -> (E, C, D).
    """
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", x, gate_w,
                               preferred_element_type=jnp.float32))
    h = h * jnp.einsum("ecd,edf->ecf", x, up_w,
                       preferred_element_type=jnp.float32)
    y = jnp.einsum("ecf,efd->ecd", h.astype(x.dtype), down_w,
                   preferred_element_type=jnp.float32)
    return y.astype(x.dtype)


def moe_fused_ref(x, gate_w, up_w, down_w, weights, phys, alive, *,
                  cap: int, expert_offset=0, e_local: int):
    """Fused MoE dispatch->grouped FFN->combine oracle.

    Same routing/drop semantics as ``moe.dispatch_compute_combine`` (the
    dense-scatter path), expressed gather-first: one sort pass builds
    (E_local, cap) slot tables, tokens are *gathered* into the capacity
    layout, and expert outputs *scatter-add* straight into y — no (N, D)
    unsort pass.  This is also the CPU fallback of the fused pipeline.
    x: (T, D) -> y (T, D).
    """
    from repro.kernels.moe_fused import moe_group_tokens
    T, D = x.shape
    tok_idx, wgt = moe_group_tokens(
        phys, alive, weights, expert_offset=expert_offset,
        e_local=e_local, cap=cap)
    xe = x[tok_idx] * (wgt != 0.0)[..., None].astype(x.dtype)  # (E, cap, D)
    out = expert_ffn_ref(xe, gate_w, up_w, down_w)
    y = jnp.zeros((T, D), x.dtype).at[tok_idx.reshape(-1)].add(
        (wgt[..., None].astype(jnp.float32) * out).reshape(-1, D).astype(
            x.dtype))
    return y


def paged_attention_ref(q, k_pool, v_pool, block_table, seq_lens,
                        start_lens=None):
    """Paged GQA decode attention oracle.

    q: (B, H, Dh); pools: (num_blocks, bs, Hkv, Dh);
    block_table: (B, max_blk) int32; seq_lens: (B,) int32 — number of valid
    tokens (cache positions 0..len-1); start_lens: optional (B,) int32 —
    first valid position (sliding window: len - window).  Returns (B, H, Dh).
    """
    B, H, Dh = q.shape
    nb, bs, Hkv, _ = k_pool.shape
    max_blk = block_table.shape[1]
    G = H // Hkv
    k = k_pool[block_table]            # (B, max_blk, bs, Hkv, Dh)
    v = v_pool[block_table]
    k = k.reshape(B, max_blk * bs, Hkv, Dh)
    v = v.reshape(B, max_blk * bs, Hkv, Dh)
    qg = q.reshape(B, Hkv, G, Dh)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k,
                   preferred_element_type=jnp.float32)
    s = s / jnp.sqrt(jnp.float32(Dh))
    pos = jnp.arange(max_blk * bs)[None, :]
    valid = pos < seq_lens[:, None]
    if start_lens is not None:
        valid &= pos >= start_lens[:, None]
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    # fully-masked rows (seq_len == 0, e.g. an idle batch slot): the
    # uniform softmax over -inf rows would average garbage; zero them
    p = jnp.where(valid[:, None, None, :], p, 0.0)
    o = jnp.einsum("bkgs,bskd->bkgd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, H, Dh).astype(q.dtype)


def decode_megastep_ref(q, k_pool, v_pool, block_table, seq_lens,
                        start_lens, x, w_post, ln2_w, router_w, l2p,
                        replica_count, expert_mask, gate_w, up_w, down_w,
                        expert_offset, shared_gate=None, shared_up=None,
                        shared_down=None, *, top_k: int, cap: int,
                        e_local: int, eps: float = 1e-5):
    """Fused decode-step oracle: paged attention -> output projection ->
    residual -> RMS norm -> router top-k -> replica select -> fused MoE
    dispatch/FFN/combine (+ shared-expert SwiGLU) -> residual, for one
    attention+MoE block.

    q: (B, H, Da) roped/pre-scaled query (for MLA, Da = R + dr and q is
    the latent query the composed path feeds ``paged_attention``);
    pools/block_table/seq_lens/start_lens as in
    :func:`paged_attention_ref` (the incoming token's K/V is already
    written); x: (B, D) the block input (residual stream); w_post:
    (H*Da, D) post-attention projection (GQA: wo; MLA: the absorbed
    wuv·wo with zero rows for the rope columns); l2p (E_log,
    MAX_REPLICAS) / replica_count (E_log,) / expert_mask (E_log,) are
    the MoERuntime arrays — pure data, so recovery mutations never
    recompile.  shared_gate/shared_up (D, Fs) and shared_down (Fs, D)
    are the shared-expert SwiGLU weights; None means the config has no
    shared experts.  Returns ``(y, h2)``: the block output (shared
    experts applied over ``h2``, the normed post-attention activations,
    exactly as the composed path does).
    """
    B = q.shape[0]
    o = paged_attention_ref(q, k_pool, v_pool, block_table, seq_lens,
                            start_lens)
    x2 = x + o.reshape(B, -1).astype(x.dtype) @ w_post
    xf = x2.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    h2 = (xf * jax.lax.rsqrt(var + eps)).astype(x2.dtype) * ln2_w
    # routing — same math as moe.route (§3.4 failure mask included)
    logits = (h2 @ router_w).astype(jnp.float32)
    logits = jnp.where(expert_mask[None, :], logits, -jnp.inf)
    gates = jax.nn.softmax(logits, axis=-1)
    w, sel = jax.lax.top_k(gates, top_k)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
    # replica selection — same math as moe.select_replicas
    count = jnp.maximum(replica_count[sel], 1)
    replica = (jnp.arange(B)[:, None] + jnp.arange(top_k)[None, :]) % count
    phys = jnp.take_along_axis(l2p[sel], replica[..., None], axis=-1)[..., 0]
    alive = replica_count[sel] > 0
    y_moe = moe_fused_ref(h2, gate_w, up_w, down_w, w,
                          phys.astype(jnp.int32), alive, cap=cap,
                          expert_offset=expert_offset, e_local=e_local)
    y = x2 + y_moe
    if shared_gate is not None:
        # same expression as ffn.ffn_apply("swiglu") over h2
        hs = jax.nn.silu(h2 @ shared_gate) * (h2 @ shared_up)
        y = y + hs @ shared_down
    return y, h2


def ssm_scan_ref(u, dt, A, B_ssm, C_ssm, h0=None):
    """Selective-scan oracle.

    u/dt: (B, S, d); A: (d, N); B_ssm/C_ssm: (B, S, N).
    Returns (y (B, S, d) f32, h_final (B, d, N) f32).
    """
    Bsz, S, d = u.shape
    N = A.shape[1]
    if h0 is None:
        h0 = jnp.zeros((Bsz, d, N), jnp.float32)

    def step(h, xs):
        u_t, dt_t, b_t, c_t = xs
        dA = jnp.exp(dt_t[..., None].astype(jnp.float32) * A[None])
        dBu = (dt_t * u_t)[..., None].astype(jnp.float32) * \
            b_t[:, None, :].astype(jnp.float32)
        h = dA * h + dBu
        y = jnp.einsum("bdn,bn->bd", h, c_t.astype(jnp.float32))
        return h, y

    h, ys = jax.lax.scan(
        step, h0, (u.swapaxes(0, 1), dt.swapaxes(0, 1),
                   B_ssm.swapaxes(0, 1), C_ssm.swapaxes(0, 1)))
    return ys.swapaxes(0, 1), h


def flash_prefill_ref(q, k, v, causal: bool = True):
    """Full-sequence attention oracle for the flash prefill kernel.

    q: (B, S, H, Dh); k/v: (B, S, Hkv, Dh) -> (B, S, H, Dh).
    """
    B, S, H, Dh = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    qg = q.reshape(B, S, Hkv, G, Dh)
    s = jnp.einsum("bskgd,btkd->bkgst", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) / jnp.sqrt(jnp.float32(Dh))
    if causal:
        mask = jnp.arange(S)[:, None] >= jnp.arange(S)[None, :]
        s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgst,btkd->bskgd", p, v.astype(jnp.float32))
    return o.reshape(B, S, H, Dh).astype(q.dtype)
