"""Grouped expert SwiGLU FFN kernel (the MoE compute hot spot).

Tiling strategy (TPU-native, MXU-aligned):
  grid = (E, C/Cb, F/Fb); each program computes the contribution of one
  (expert, token-block, ff-block) tile:

      h_f = silu(x @ gate[:, f]) * (x @ up[:, f])      (Cb, Fb)
      out += h_f @ down[f, :]                           (Cb, D)

  The f axis is innermost → the (Cb, D) output accumulator tile stays
  resident in VMEM across the F sweep (initialized at f==0).  All matmul
  dims are multiples of 128 so the MXU runs dense.  VMEM working set per
  program ≈ x(Cb·D) + gate/up/down(D·Fb·3) + out(Cb·D) — e.g.
  Cb=128, Fb=256, D=4096, bf16: ~8.5 MiB.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _expert_ffn_kernel(x_ref, g_ref, u_ref, d_ref, o_ref):
    f = pl.program_id(2)

    @pl.when(f == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[0]                                      # (Cb, D)
    g = g_ref[0]                                      # (D, Fb)
    u = u_ref[0]
    d = d_ref[0]                                      # (Fb, D)
    h = jax.nn.silu(jnp.dot(x, g, preferred_element_type=jnp.float32))
    h = h * jnp.dot(x, u, preferred_element_type=jnp.float32)
    o_ref[...] += jnp.dot(h.astype(x.dtype), d,
                          preferred_element_type=jnp.float32)[None]


def expert_ffn_pallas(x, gate_w, up_w, down_w, *, block_c: int = 128,
                      block_f: int = 256, interpret: bool = False):
    """x: (E, C, D); gate/up: (E, D, F); down: (E, F, D) -> (E, C, D)."""
    E, C, D = x.shape
    F = gate_w.shape[-1]
    Cb = min(block_c, C)
    Fb = min(block_f, F)
    Cp = ((C + Cb - 1) // Cb) * Cb
    Fp = ((F + Fb - 1) // Fb) * Fb
    if Cp != C:
        x = jnp.pad(x, ((0, 0), (0, Cp - C), (0, 0)))
    if Fp != F:
        gate_w = jnp.pad(gate_w, ((0, 0), (0, 0), (0, Fp - F)))
        up_w = jnp.pad(up_w, ((0, 0), (0, 0), (0, Fp - F)))
        down_w = jnp.pad(down_w, ((0, 0), (0, Fp - F), (0, 0)))

    out = pl.pallas_call(
        _expert_ffn_kernel,
        grid=(E, Cp // Cb, Fp // Fb),
        in_specs=[
            pl.BlockSpec((1, Cb, D), lambda e, c, f: (e, c, 0)),
            pl.BlockSpec((1, D, Fb), lambda e, c, f: (e, 0, f)),
            pl.BlockSpec((1, D, Fb), lambda e, c, f: (e, 0, f)),
            pl.BlockSpec((1, Fb, D), lambda e, c, f: (e, f, 0)),
        ],
        out_specs=pl.BlockSpec((1, Cb, D), lambda e, c, f: (e, c, 0)),
        out_shape=jax.ShapeDtypeStruct((E, Cp, D), jnp.float32),
        interpret=interpret,
    )(x, gate_w, up_w, down_w)
    return out[:, :C].astype(x.dtype)
