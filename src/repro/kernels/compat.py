"""Version-compat shims for the Pallas TPU API.

``pltpu.TPUCompilerParams`` was renamed to ``pltpu.CompilerParams`` in
newer JAX releases; every kernel goes through :func:`tpu_compiler_params`
so the repo compiles against either spelling.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

_CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    getattr(pltpu, "TPUCompilerParams")


def tpu_compiler_params(**kwargs):
    """Build compiler params portably (e.g. ``dimension_semantics=...``)."""
    return _CompilerParams(**kwargs)
