"""Decode megakernel: paged attention + router + MoE in one launch.

The steady-state decode step is the hot path every ReviveMoE recovery
event returns to.  The composed step runs, per attention+MoE block, a
chain of kernels with HBM round-trips between them:

  paged_attention -> (B, H*Dh) out -> wo matmul -> residual -> rms_norm
  -> router matmul -> top_k -> replica select -> sort pre-pass ->
  fused MoE dispatch/FFN/combine -> shared-expert FFN -> residual

This kernel fuses the whole chain into **one** ``pallas_call`` per
block.  A single flat sequential grid runs five phases (TPU grids with
``arbitrary`` semantics execute in order, so cross-phase scratch carries
are race-free).  Only the *activations* — (B, D) residual/``h2`` tiles
and the (B, H*Dh) attention output — stay whole in VMEM (decode B is
small); every weight matrix with a ``d_model`` axis streams through the
kernel one D-page at a time, so deployment hidden sizes
(deepseek_v3/kimi_k2-class D = 7168) never have to fit a weight's full
D extent on chip:

  * **attention** (steps ``[0, B*max_blk)``): the paged-attention online
    softmax of ``kernels.paged_attention`` — page ``j`` of row ``b`` is
    gathered per row via the scalar-prefetched block table (the grid
    pipeline revolves these KV page buffers, i.e. the DMA for row
    ``b``'s next page overlaps the current page's compute); each row's
    normalized (H, Dh) output lands in a VMEM scratch tile.
  * **project** (``nd = D/block_d`` steps): the post-attention
    projection, one D-page per step — ``y[:, dp] = x[:, dp] +
    o @ w_post[:, dp]`` with the (H*Dh, block_d) weight page streamed
    (and double-buffered) by the pipeline; a running sum of squares
    accumulates for the norm.
  * **route** (``nd`` steps): RMS norm one D-page at a time (the sum of
    squares is already complete), router logits accumulated over
    (block_d, E) router pages; the last page finishes with the masked
    softmax, iterative top-k (k argmax passes — decode-shaped, k <= 8),
    replica selection from the MoERuntime arrays, and the per-expert
    slot tables built by a sequential scan (decode batches are small
    enough that the sort pre-pass of ``moe_fused`` degenerates to this
    O(B*k) scan).
  * **shared experts** (``ns * 2*nd`` steps, skipped when the config has
    none): the dense shared-expert SwiGLU folded into the launch — for
    each shared F-block, ``nd`` contraction steps accumulate the hidden
    over streamed (block_d, Fs_b) weight pages, then ``nd`` output
    steps scatter ``act @ w_down[f, dp]`` pages back into the resident
    ``y`` tile.
  * **MoE** (``E * nf * 2*nd`` steps): the grouped-SwiGLU expert
    pipeline of ``kernels.moe_fused`` with the same D-paging — gather
    rows from the resident ``h2`` tile at each expert's first step,
    ``nd`` contraction steps per F-block over streamed (1, block_d, Fb)
    gate/up pages, ``nd`` output steps over (1, Fb, block_d) down
    pages, and a weighted scatter-combine into ``y`` on the expert's
    last step.

Everything mutable by recovery — block tables, seq lens, window starts,
``expert_offset`` and the MoERuntime ``l2p``/``replica_count``/
``expert_mask`` — rides in as scalar-prefetch or tensor *data*, so
``fail_rank``/``mask_experts``/migration/chunked prefill never retrigger
compilation.

Remaining limitation: the capacity axis is a single block (decode caps
are small) and VMEM still scales with B * H * Dh for the attention
scratch, so prefill-shaped batches belong to the flash kernel, not this
one.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import tpu_compiler_params

NEG_INF = -1e30


def _megastep_kernel(bt_ref, sl_ref, st_ref, off_ref,
                     q_ref, k_ref, v_ref, x_ref, wpost_ref, ln2_ref,
                     router_ref, l2p_ref, rcnt_ref, mask_ref,
                     sgate_ref, sup_ref, sdown_ref,
                     gate_ref, up_ref, down_ref,
                     y_ref, h2_ref,
                     acc_ref, m_ref, l_ref, o_ref, ssq_ref, lg_ref,
                     xs_ref, accm_ref, hg_ref, hu_ref, hgs_ref, hus_ref,
                     sel_ref, wsel_ref, tok_ref, wgt_ref, cnt_ref, *,
                     bs: int, n_attn: int, nd: int, nf: int, ns: int,
                     cap: int, top_k: int, e_local: int, e_log: int,
                     scale: float, eps: float, d_model: int, block_d: int):
    t = pl.program_id(0)
    B = y_ref.shape[0]
    attn_steps = B * n_attn
    p0 = attn_steps            # projection phase start
    r0 = p0 + nd               # norm/route phase start
    s0 = r0 + nd               # shared-expert phase start
    m0 = s0 + ns * 2 * nd      # routed-expert phase start

    # ---- phase A: paged-attention online softmax ----------------------
    @pl.when(t < attn_steps)
    def _attention():
        b = t // n_attn
        j = t % n_attn

        @pl.when(j == 0)
        def _init():
            acc_ref[...] = jnp.zeros_like(acc_ref)
            m_ref[...] = jnp.full_like(m_ref, NEG_INF)
            l_ref[...] = jnp.zeros_like(l_ref)

        q = q_ref[0].astype(jnp.float32)                  # (H, Da)
        k = k_ref[0].astype(jnp.float32)                  # (bs, Hkv, Da)
        v = v_ref[0].astype(jnp.float32)
        H, Da = q.shape
        Hkv = k.shape[1]
        G = H // Hkv

        pos = j * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)
        valid = (pos < sl_ref[b]) & (pos >= st_ref[b])    # (1, bs)

        qg = q.reshape(Hkv, G, Da)
        s_rows = []
        for h in range(Hkv):
            s_rows.append(jnp.dot(qg[h], k[:, h, :].T,
                                  preferred_element_type=jnp.float32))
        s = jnp.stack(s_rows).reshape(H, bs) * scale      # (H, bs)
        s = jnp.where(valid, s, NEG_INF)

        m_prev = m_ref[...]                               # (H, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        p = jnp.where(valid, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        pv_rows = []
        pg = p.reshape(Hkv, G, bs)
        for h in range(Hkv):
            pv_rows.append(jnp.dot(pg[h], v[:, h, :],
                                   preferred_element_type=jnp.float32))
        pv = jnp.stack(pv_rows).reshape(H, Da)
        acc_ref[...] = acc_ref[...] * corr + pv
        m_ref[...] = m_new

        @pl.when(j == n_attn - 1)
        def _finish_row():
            o = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)   # (H, Da)
            o_ref[b, :] = o.reshape(H * Da)

    # ---- phase P: post-projection + residual, one D-page per step -----
    @pl.when((t >= p0) & (t < r0))
    def _project():
        dp = t - p0
        o_flat = o_ref[...].astype(x_ref.dtype)           # (B, H*Da)
        proj = jnp.dot(o_flat, wpost_ref[...],
                       preferred_element_type=jnp.float32)  # (B, Db)
        yb = x_ref[...] + proj.astype(y_ref.dtype)
        y_ref[:, pl.ds(dp * block_d, block_d)] = yb
        sq = jnp.sum(jnp.square(yb.astype(jnp.float32)), axis=-1,
                     keepdims=True)

        @pl.when(dp == 0)
        def _():
            ssq_ref[...] = sq

        @pl.when(dp != 0)
        def _():
            ssq_ref[...] += sq

    # ---- phase R: norm + router (paged), then top-k + grouping --------
    @pl.when((t >= r0) & (t < s0))
    def _norm_route():
        dr = t - r0
        yb = y_ref[:, pl.ds(dr * block_d, block_d)].astype(jnp.float32)
        rs = jax.lax.rsqrt(ssq_ref[...] / d_model + eps)  # (B, 1)
        h2b = (yb * rs).astype(h2_ref.dtype) * ln2_ref[...]
        h2_ref[:, pl.ds(dr * block_d, block_d)] = h2b
        contrib = jnp.dot(h2b, router_ref[...],
                          preferred_element_type=jnp.float32)  # (B, E_log)

        @pl.when(dr == 0)
        def _():
            lg_ref[...] = contrib

        @pl.when(dr != 0)
        def _():
            lg_ref[...] += contrib

        @pl.when(dr == nd - 1)
        def _route():
            logits = jnp.where(mask_ref[...] != 0, lg_ref[...], NEG_INF)
            mx = jnp.max(logits, axis=-1, keepdims=True)
            g = jnp.exp(logits - mx)
            gates = g / jnp.sum(g, axis=-1, keepdims=True)
            iota_e = jax.lax.broadcasted_iota(jnp.int32, (B, e_log), 1)
            remaining = gates
            wsum = jnp.zeros((B, 1), jnp.float32)
            for kk in range(top_k):  # k argmax passes; ties -> lowest id,
                mv = jnp.max(remaining, axis=-1, keepdims=True)  # as top_k
                sk = jnp.min(jnp.where(remaining >= mv, iota_e, e_log),
                             axis=-1, keepdims=True)
                sel_ref[:, kk] = sk[:, 0]
                wsel_ref[:, kk] = mv[:, 0]
                wsum = wsum + mv
                remaining = jnp.where(iota_e == sk, -1.0, remaining)
            wsel_ref[...] = wsel_ref[...] / jnp.maximum(wsum, 1e-9)

            # per-expert slot tables: the sequential scan is the decode-
            # shaped sort pre-pass (token order == stable-sort order, so
            # drop semantics match moe_group_tokens exactly)
            tok_ref[...] = jnp.zeros_like(tok_ref)
            wgt_ref[...] = jnp.zeros_like(wgt_ref)

            def _zero(i, _):
                cnt_ref[i] = 0
                return 0
            jax.lax.fori_loop(0, e_local, _zero, 0)

            off = off_ref[0]

            def _group(n, _):
                b = n // top_k
                kk = n % top_k
                s = sel_ref[b, kk]
                w = wsel_ref[b, kk]
                rc = rcnt_ref[0, s]
                rep = jax.lax.rem(b + kk, jnp.maximum(rc, 1))
                ph = l2p_ref[s, rep]
                e = ph - off
                ok = (e >= 0) & (e < e_local) & (rc > 0)
                ec = jnp.clip(e, 0, e_local - 1)
                c = cnt_ref[ec]
                ok = ok & (c < cap)

                @pl.when(ok)
                def _():
                    tok_ref[ec, c] = b
                    wgt_ref[ec, c] = w
                    cnt_ref[ec] = c + 1

                return 0
            jax.lax.fori_loop(0, B * top_k, _group, 0)

    # ---- phase S: shared-expert SwiGLU over h2 (paged weights) --------
    @pl.when((t >= s0) & (t < m0))
    def _shared():
        u = t - s0
        r = jax.lax.rem(u, 2 * nd)
        d = jax.lax.rem(r, nd)
        is_in = r < nd

        @pl.when(is_in)
        def _contract():
            h2b = h2_ref[:, pl.ds(d * block_d, block_d)]
            cg = jnp.dot(h2b, sgate_ref[...],
                         preferred_element_type=jnp.float32)
            cu = jnp.dot(h2b, sup_ref[...],
                         preferred_element_type=jnp.float32)

            @pl.when(d == 0)
            def _():
                hgs_ref[...] = cg
                hus_ref[...] = cu

            @pl.when(d != 0)
            def _():
                hgs_ref[...] += cg
                hus_ref[...] += cu

        @pl.when(jnp.logical_not(is_in))
        def _emit():
            @pl.when(d == 0)
            def _():
                hgs_ref[...] = jax.nn.silu(hgs_ref[...]) * hus_ref[...]

            contrib = jnp.dot(hgs_ref[...].astype(h2_ref.dtype),
                              sdown_ref[...],
                              preferred_element_type=jnp.float32)
            y_ref[:, pl.ds(d * block_d, block_d)] += contrib.astype(
                y_ref.dtype)

    # ---- phase M: grouped SwiGLU FFN + weighted scatter-combine -------
    @pl.when(t >= m0)
    def _moe():
        u = t - m0
        per_e = nf * 2 * nd
        e = u // per_e
        u2 = jax.lax.rem(u, per_e)
        r = jax.lax.rem(u2, 2 * nd)
        d = jax.lax.rem(r, nd)
        is_in = r < nd

        @pl.when(u2 == 0)
        def _gather():
            accm_ref[...] = jnp.zeros_like(accm_ref)

            def body(i, _):
                tkn = tok_ref[e, i]
                live = wgt_ref[e, i] != 0.0
                row = h2_ref[tkn, :]
                xs_ref[i, :] = jnp.where(live, row, 0.0).astype(
                    xs_ref.dtype)
                return 0
            jax.lax.fori_loop(0, cap, body, 0)

        @pl.when(is_in)
        def _contract():
            xg = xs_ref[:, pl.ds(d * block_d, block_d)]   # (cap, Db)
            cg = jnp.dot(xg, gate_ref[0],
                         preferred_element_type=jnp.float32)
            cu = jnp.dot(xg, up_ref[0],
                         preferred_element_type=jnp.float32)

            @pl.when(d == 0)
            def _():
                hg_ref[...] = cg
                hu_ref[...] = cu

            @pl.when(d != 0)
            def _():
                hg_ref[...] += cg
                hu_ref[...] += cu

        @pl.when(jnp.logical_not(is_in))
        def _emit():
            @pl.when(d == 0)
            def _():
                hg_ref[...] = jax.nn.silu(hg_ref[...]) * hu_ref[...]

            contrib = jnp.dot(hg_ref[...].astype(xs_ref.dtype),
                              down_ref[0],
                              preferred_element_type=jnp.float32)
            accm_ref[:, pl.ds(d * block_d, block_d)] += contrib

        @pl.when(u2 == per_e - 1)
        def _combine():
            def body(i, _):
                w = wgt_ref[e, i]

                @pl.when(w != 0.0)
                def _():
                    tkn = tok_ref[e, i]
                    y_ref[tkn, :] += (w * accm_ref[i, :]).astype(
                        y_ref.dtype)

                return 0
            jax.lax.fori_loop(0, cap, body, 0)


def decode_megastep_pallas(q, k_pool, v_pool, block_table, seq_lens,
                           start_lens, x, w_post, ln2_w, router_w, l2p,
                           replica_count, expert_mask, gate_w, up_w,
                           down_w, expert_offset, shared_gate=None,
                           shared_up=None, shared_down=None, *,
                           top_k: int, cap: int, e_local: int,
                           eps: float = 1e-5, block_f: int = 256,
                           block_d: int = 512, interpret: bool = False):
    """One fused attention+MoE decode block step (see module docstring).

    Shapes as :func:`repro.kernels.ref.decode_megastep_ref`; returns
    ``(y (B, D), h2 (B, D))``.  ``shared_gate``/``shared_up`` (D, Fs)
    and ``shared_down`` (Fs, D) are the shared-expert SwiGLU weights
    (None = no shared experts; the phase is statically skipped).  The
    D axis is tiled into ``block_d`` pages: activations stay VMEM-
    resident whole, weights stream one (double-buffered) page per grid
    step.
    """
    B, H, Da = q.shape
    nb, bs, Hkv, _ = k_pool.shape
    n_attn = block_table.shape[1]
    D = x.shape[1]
    E = gate_w.shape[0]
    assert E == e_local, (E, e_local)
    e_log = router_w.shape[1]
    F = gate_w.shape[-1]
    scale = 1.0 / (Da ** 0.5)

    Fb = min(block_f, F)
    Fp = ((F + Fb - 1) // Fb) * Fb
    if Fp != F:
        gate_w = jnp.pad(gate_w, ((0, 0), (0, 0), (0, Fp - F)))
        up_w = jnp.pad(up_w, ((0, 0), (0, 0), (0, Fp - F)))
        down_w = jnp.pad(down_w, ((0, 0), (0, Fp - F), (0, 0)))
    nf = Fp // Fb

    Db = min(block_d, D)
    Dp = ((D + Db - 1) // Db) * Db
    nd = Dp // Db
    if Dp != D:
        # zero D-padding is norm-/router-/FFN-neutral: padded x/w_post
        # columns keep y's pad zero (the norm divides by the true D),
        # padded router/gate/up rows contribute nothing, padded down
        # columns write nothing
        pad = Dp - D
        x = jnp.pad(x, ((0, 0), (0, pad)))
        w_post = jnp.pad(w_post, ((0, 0), (0, pad)))
        ln2_w = jnp.pad(ln2_w, ((0, pad),))
        router_w = jnp.pad(router_w, ((0, pad), (0, 0)))
        gate_w = jnp.pad(gate_w, ((0, 0), (0, pad), (0, 0)))
        up_w = jnp.pad(up_w, ((0, 0), (0, pad), (0, 0)))
        down_w = jnp.pad(down_w, ((0, 0), (0, 0), (0, pad)))

    if shared_gate is None:
        ns, Fsb = 0, 8
        shared_gate = jnp.zeros((Db, Fsb), x.dtype)
        shared_up = jnp.zeros((Db, Fsb), x.dtype)
        shared_down = jnp.zeros((Fsb, Db), x.dtype)
    else:
        Fs = shared_gate.shape[1]
        Fsb = min(block_f, Fs)
        Fsp = ((Fs + Fsb - 1) // Fsb) * Fsb
        ns = Fsp // Fsb
        shared_gate = jnp.pad(shared_gate,
                              ((0, Dp - D), (0, Fsp - Fs)))
        shared_up = jnp.pad(shared_up, ((0, Dp - D), (0, Fsp - Fs)))
        shared_down = jnp.pad(shared_down,
                              ((0, Fsp - Fs), (0, Dp - D)))

    attn_steps = B * n_attn
    p0 = attn_steps
    r0 = p0 + nd
    s0 = r0 + nd
    m0 = s0 + ns * 2 * nd
    grid = (m0 + E * nf * 2 * nd,)

    def _ab(t):
        ta = jnp.minimum(t, attn_steps - 1)
        return ta // n_attn, ta % n_attn

    def _dp(t):
        return jnp.clip(t - p0, 0, nd - 1)

    def _dr(t):
        return jnp.clip(t - r0, 0, nd - 1)

    def _sfd(t):
        u = jnp.clip(t - s0, 0, max(ns * 2 * nd - 1, 0))
        f = u // (2 * nd)
        r = jax.lax.rem(u, 2 * nd)
        return f, jax.lax.rem(r, nd)

    def _efd(t):
        u = jnp.clip(t - m0, 0, E * nf * 2 * nd - 1)
        per_e = nf * 2 * nd
        e = u // per_e
        u2 = jax.lax.rem(u, per_e)
        f = u2 // (2 * nd)
        r = jax.lax.rem(u2, 2 * nd)
        return e, f, jax.lax.rem(r, nd)

    kernel = functools.partial(
        _megastep_kernel, bs=bs, n_attn=n_attn, nd=nd, nf=nf, ns=ns,
        cap=cap, top_k=top_k, e_local=E, e_log=e_log, scale=scale,
        eps=eps, d_model=D, block_d=Db)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, H, Da),
                         lambda t, bt, sl, st, off: (_ab(t)[0], 0, 0)),
            pl.BlockSpec((1, bs, Hkv, Da),
                         lambda t, bt, sl, st, off:
                         (bt[_ab(t)[0], _ab(t)[1]], 0, 0, 0)),
            pl.BlockSpec((1, bs, Hkv, Da),
                         lambda t, bt, sl, st, off:
                         (bt[_ab(t)[0], _ab(t)[1]], 0, 0, 0)),
            pl.BlockSpec((B, Db), lambda t, bt, sl, st, off: (0, _dp(t))),
            pl.BlockSpec((H * Da, Db),
                         lambda t, bt, sl, st, off: (0, _dp(t))),
            pl.BlockSpec((1, Db), lambda t, bt, sl, st, off: (0, _dr(t))),
            pl.BlockSpec((Db, e_log),
                         lambda t, bt, sl, st, off: (_dr(t), 0)),
            pl.BlockSpec(l2p.shape, lambda t, bt, sl, st, off: (0, 0)),
            pl.BlockSpec((1, e_log), lambda t, bt, sl, st, off: (0, 0)),
            pl.BlockSpec((1, e_log), lambda t, bt, sl, st, off: (0, 0)),
            pl.BlockSpec((Db, Fsb),
                         lambda t, bt, sl, st, off:
                         (_sfd(t)[1], _sfd(t)[0])),
            pl.BlockSpec((Db, Fsb),
                         lambda t, bt, sl, st, off:
                         (_sfd(t)[1], _sfd(t)[0])),
            pl.BlockSpec((Fsb, Db),
                         lambda t, bt, sl, st, off:
                         (_sfd(t)[0], _sfd(t)[1])),
            pl.BlockSpec((1, Db, Fb),
                         lambda t, bt, sl, st, off:
                         (_efd(t)[0], _efd(t)[2], _efd(t)[1])),
            pl.BlockSpec((1, Db, Fb),
                         lambda t, bt, sl, st, off:
                         (_efd(t)[0], _efd(t)[2], _efd(t)[1])),
            pl.BlockSpec((1, Fb, Db),
                         lambda t, bt, sl, st, off:
                         (_efd(t)[0], _efd(t)[1], _efd(t)[2])),
        ],
        out_specs=[
            pl.BlockSpec((B, Dp), lambda t, bt, sl, st, off: (0, 0)),
            pl.BlockSpec((B, Dp), lambda t, bt, sl, st, off: (0, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((H, Da), jnp.float32),    # attention accumulator
            pltpu.VMEM((H, 1), jnp.float32),     # running max
            pltpu.VMEM((H, 1), jnp.float32),     # running denominator
            pltpu.VMEM((B, H * Da), jnp.float32),  # attention outputs
            pltpu.VMEM((B, 1), jnp.float32),     # norm sum of squares
            pltpu.VMEM((B, e_log), jnp.float32),  # router logit accum
            pltpu.VMEM((cap, Dp), x.dtype),      # gathered expert rows
            pltpu.VMEM((cap, Dp), jnp.float32),  # FFN accumulator
            pltpu.VMEM((cap, Fb), jnp.float32),  # expert gate hidden
            pltpu.VMEM((cap, Fb), jnp.float32),  # expert up hidden
            pltpu.VMEM((B, Fsb), jnp.float32),   # shared gate hidden
            pltpu.VMEM((B, Fsb), jnp.float32),   # shared up hidden
            pltpu.VMEM((B, top_k), jnp.int32),   # selected logical ids
            pltpu.VMEM((B, top_k), jnp.float32),  # renormalized weights
            pltpu.VMEM((E, cap), jnp.int32),     # slot -> token row
            pltpu.VMEM((E, cap), jnp.float32),   # slot combine weight
            pltpu.SMEM((E,), jnp.int32),         # per-expert fill count
        ],
    )
    y, h2 = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((B, Dp), x.dtype),
                   jax.ShapeDtypeStruct((B, Dp), x.dtype)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(block_table.astype(jnp.int32), seq_lens.astype(jnp.int32),
      start_lens.astype(jnp.int32),
      jnp.asarray(expert_offset, jnp.int32).reshape(1),
      q, k_pool, v_pool, x, w_post, ln2_w.reshape(1, Dp), router_w,
      l2p.astype(jnp.int32), replica_count.astype(jnp.int32).reshape(
          1, e_log), expert_mask.astype(jnp.int32).reshape(1, e_log),
      shared_gate, shared_up, shared_down, gate_w, up_w, down_w)
    if Dp != D:
        y, h2 = y[:, :D], h2[:, :D]
    return y, h2
