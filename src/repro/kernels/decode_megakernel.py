"""Decode megakernel: paged attention + router + MoE in one launch.

The steady-state decode step is the hot path every ReviveMoE recovery
event returns to.  The composed step runs, per attention+MoE block, a
chain of kernels with HBM round-trips between them:

  paged_attention -> (B, H*Dh) out -> wo matmul -> residual -> rms_norm
  -> router matmul -> top_k -> replica select -> sort pre-pass ->
  fused MoE dispatch/FFN/combine -> residual

This kernel fuses the whole chain into **one** ``pallas_call`` per
block.  A single flat sequential grid runs three phases (TPU grids with
``arbitrary`` semantics execute in order, so cross-phase scratch carries
are race-free):

  * **attention** (steps ``[0, B*max_blk)``): the paged-attention online
    softmax of ``kernels.paged_attention`` — page ``j`` of row ``b`` is
    DMA'd via the scalar-prefetched block table; on each row's last page
    the output is projected through ``w_post`` and added to the residual
    stream, writing ``x2`` into the output tile (which stays VMEM-
    resident across all phases — the (B, H*Dh) attention output and the
    (B, D) residual never round-trip HBM).
  * **route** (step ``B*max_blk``): RMS norm, router matmul, iterative
    top-k (k argmax passes — decode-shaped, k <= 8), replica selection
    from the MoERuntime arrays, and the per-expert slot tables built by
    a sequential scan (decode batches are small enough that the sort
    pre-pass of ``moe_fused`` degenerates to this O(B*k) scan).  This
    subsumes kernel target (b): router top-k + replica select live in
    the megakernel's grouping pre-pass.
  * **MoE** (steps after): the grouped-SwiGLU expert pipeline of
    ``kernels.moe_fused`` — gather rows from the resident ``h2`` tile at
    the first F-block, accumulate the FFN, scatter-combine ``wgt * acc``
    into the resident output tile on the last.

Everything mutable by recovery — block tables, seq lens, window starts,
``expert_offset`` and the MoERuntime ``l2p``/``replica_count``/
``expert_mask`` — rides in as scalar-prefetch or tensor *data*, so
``fail_rank``/``mask_experts``/migration/chunked prefill never retrigger
compilation.

Current limitation (documented, matching ``moe_fused``): ``x``/``y``/
``h2``/``w_post``/``router_w`` use whole-array VMEM block specs, so the
kernel is decode/chunk-shaped (B = decode batch or chunk width); the
capacity axis is a single block (decode caps are small).  Shared
experts are a dense FFN over ``h2`` and stay outside (they are
compute-bound GEMMs, not paged-memory-bound; the ``h2`` output exists
so callers apply them without recomputing the norm).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import tpu_compiler_params

NEG_INF = -1e30


def _megastep_kernel(bt_ref, sl_ref, st_ref, off_ref,
                     q_ref, k_ref, v_ref, x_ref, wpost_ref, ln2_ref,
                     router_ref, l2p_ref, rcnt_ref, mask_ref,
                     gate_ref, up_ref, down_ref,
                     y_ref, h2_ref,
                     acc_ref, m_ref, l_ref, xs_ref, accm_ref,
                     sel_ref, wsel_ref, tok_ref, wgt_ref, cnt_ref, *,
                     bs: int, n_attn: int, nf: int, cap: int, top_k: int,
                     e_local: int, e_log: int, scale: float, eps: float):
    t = pl.program_id(0)
    attn_steps = pl.num_programs(0) - 1 - e_local * nf  # == B * n_attn

    # ---- phase A: paged-attention online softmax + post-projection ----
    @pl.when(t < attn_steps)
    def _attention():
        b = t // n_attn
        j = t % n_attn

        @pl.when(j == 0)
        def _init():
            acc_ref[...] = jnp.zeros_like(acc_ref)
            m_ref[...] = jnp.full_like(m_ref, NEG_INF)
            l_ref[...] = jnp.zeros_like(l_ref)

        q = q_ref[0].astype(jnp.float32)                  # (H, Da)
        k = k_ref[0].astype(jnp.float32)                  # (bs, Hkv, Da)
        v = v_ref[0].astype(jnp.float32)
        H, Da = q.shape
        Hkv = k.shape[1]
        G = H // Hkv

        pos = j * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)
        valid = (pos < sl_ref[b]) & (pos >= st_ref[b])    # (1, bs)

        qg = q.reshape(Hkv, G, Da)
        s_rows = []
        for h in range(Hkv):
            s_rows.append(jnp.dot(qg[h], k[:, h, :].T,
                                  preferred_element_type=jnp.float32))
        s = jnp.stack(s_rows).reshape(H, bs) * scale      # (H, bs)
        s = jnp.where(valid, s, NEG_INF)

        m_prev = m_ref[...]                               # (H, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        p = jnp.where(valid, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        pv_rows = []
        pg = p.reshape(Hkv, G, bs)
        for h in range(Hkv):
            pv_rows.append(jnp.dot(pg[h], v[:, h, :],
                                   preferred_element_type=jnp.float32))
        pv = jnp.stack(pv_rows).reshape(H, Da)
        acc_ref[...] = acc_ref[...] * corr + pv
        m_ref[...] = m_new

        @pl.when(j == n_attn - 1)
        def _project():
            o = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)   # (H, Da)
            o_flat = o.reshape(1, H * Da).astype(x_ref.dtype)
            proj = jnp.dot(o_flat, wpost_ref[...],
                           preferred_element_type=jnp.float32)  # (1, D)
            y_ref[b, :] = x_ref[b, :] + proj[0].astype(y_ref.dtype)

    # ---- phase R: norm + router top-k + replica select + grouping ----
    @pl.when(t == attn_steps)
    def _route():
        x2 = y_ref[...]                                   # (B, D) == x+attn
        B = x2.shape[0]
        xf = x2.astype(jnp.float32)
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        h2 = (xf * jax.lax.rsqrt(var + eps)).astype(x2.dtype) * ln2_ref[...]
        h2_ref[...] = h2
        logits = jnp.dot(h2, router_ref[...],
                         preferred_element_type=jnp.float32)  # (B, E_log)
        logits = jnp.where(mask_ref[...] != 0, logits, NEG_INF)
        mx = jnp.max(logits, axis=-1, keepdims=True)
        g = jnp.exp(logits - mx)
        gates = g / jnp.sum(g, axis=-1, keepdims=True)
        iota_e = jax.lax.broadcasted_iota(jnp.int32, (B, e_log), 1)
        remaining = gates
        wsum = jnp.zeros((B, 1), jnp.float32)
        for kk in range(top_k):     # k argmax passes; ties -> lowest id,
            mv = jnp.max(remaining, axis=-1, keepdims=True)  # as lax.top_k
            sk = jnp.min(jnp.where(remaining >= mv, iota_e, e_log),
                         axis=-1, keepdims=True)
            sel_ref[:, kk] = sk[:, 0]
            wsel_ref[:, kk] = mv[:, 0]
            wsum = wsum + mv
            remaining = jnp.where(iota_e == sk, -1.0, remaining)
        wsel_ref[...] = wsel_ref[...] / jnp.maximum(wsum, 1e-9)

        # per-expert slot tables: the sequential scan is the decode-shaped
        # sort pre-pass (token order == stable-sort order, so drop
        # semantics match moe_group_tokens exactly)
        tok_ref[...] = jnp.zeros_like(tok_ref)
        wgt_ref[...] = jnp.zeros_like(wgt_ref)

        def _zero(i, _):
            cnt_ref[i] = 0
            return 0
        jax.lax.fori_loop(0, e_local, _zero, 0)

        off = off_ref[0]

        def _group(n, _):
            b = n // top_k
            kk = n % top_k
            s = sel_ref[b, kk]
            w = wsel_ref[b, kk]
            rc = rcnt_ref[0, s]
            rep = jax.lax.rem(b + kk, jnp.maximum(rc, 1))
            ph = l2p_ref[s, rep]
            e = ph - off
            ok = (e >= 0) & (e < e_local) & (rc > 0)
            ec = jnp.clip(e, 0, e_local - 1)
            c = cnt_ref[ec]
            ok = ok & (c < cap)

            @pl.when(ok)
            def _():
                tok_ref[ec, c] = b
                wgt_ref[ec, c] = w
                cnt_ref[ec] = c + 1

            return 0
        jax.lax.fori_loop(0, sel_ref.shape[0] * top_k, _group, 0)

    # ---- phase M: grouped SwiGLU FFN + weighted scatter-combine ----
    @pl.when(t > attn_steps)
    def _moe():
        u = t - attn_steps - 1
        e = u // nf
        f = u % nf

        @pl.when(f == 0)
        def _gather():
            accm_ref[...] = jnp.zeros_like(accm_ref)

            def body(i, _):
                tkn = tok_ref[e, i]
                live = wgt_ref[e, i] != 0.0
                row = h2_ref[tkn, :]
                xs_ref[i, :] = jnp.where(live, row, 0.0).astype(
                    xs_ref.dtype)
                return 0
            jax.lax.fori_loop(0, cap, body, 0)

        xg = xs_ref[...]                                  # (cap, D)
        gw = gate_ref[0]                                  # (D, Fb)
        uw = up_ref[0]
        dw = down_ref[0]                                  # (Fb, D)
        h = jax.nn.silu(jnp.dot(xg, gw, preferred_element_type=jnp.float32))
        h = h * jnp.dot(xg, uw, preferred_element_type=jnp.float32)
        accm_ref[...] += jnp.dot(h.astype(xg.dtype), dw,
                                 preferred_element_type=jnp.float32)

        @pl.when(f == nf - 1)
        def _combine():
            def body(i, _):
                w = wgt_ref[e, i]

                @pl.when(w != 0.0)
                def _():
                    tkn = tok_ref[e, i]
                    y_ref[tkn, :] += (w * accm_ref[i, :]).astype(
                        y_ref.dtype)

                return 0
            jax.lax.fori_loop(0, cap, body, 0)


def decode_megastep_pallas(q, k_pool, v_pool, block_table, seq_lens,
                           start_lens, x, w_post, ln2_w, router_w, l2p,
                           replica_count, expert_mask, gate_w, up_w,
                           down_w, expert_offset, *, top_k: int, cap: int,
                           e_local: int, eps: float = 1e-5,
                           block_f: int = 256, interpret: bool = False):
    """One fused attention+MoE decode block step (see module docstring).

    Shapes as :func:`repro.kernels.ref.decode_megastep_ref`; returns
    ``(y (B, D), h2 (B, D))``.
    """
    B, H, Da = q.shape
    nb, bs, Hkv, _ = k_pool.shape
    n_attn = block_table.shape[1]
    D = x.shape[1]
    E = gate_w.shape[0]
    assert E == e_local, (E, e_local)
    e_log = router_w.shape[1]
    F = gate_w.shape[-1]
    scale = 1.0 / (Da ** 0.5)

    Fb = min(block_f, F)
    Fp = ((F + Fb - 1) // Fb) * Fb
    if Fp != F:
        gate_w = jnp.pad(gate_w, ((0, 0), (0, 0), (0, Fp - F)))
        up_w = jnp.pad(up_w, ((0, 0), (0, 0), (0, Fp - F)))
        down_w = jnp.pad(down_w, ((0, 0), (0, Fp - F), (0, 0)))
    nf = Fp // Fb

    attn_steps = B * n_attn
    grid = (attn_steps + 1 + E * nf,)

    def _ab(t):
        ta = jnp.minimum(t, attn_steps - 1)
        return ta // n_attn, ta % n_attn

    def _ef(t):
        u = jnp.clip(t - attn_steps - 1, 0, E * nf - 1)
        return u // nf, u % nf

    kernel = functools.partial(
        _megastep_kernel, bs=bs, n_attn=n_attn, nf=nf, cap=cap,
        top_k=top_k, e_local=E, e_log=e_log, scale=scale, eps=eps)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, H, Da),
                         lambda t, bt, sl, st, off: (_ab(t)[0], 0, 0)),
            pl.BlockSpec((1, bs, Hkv, Da),
                         lambda t, bt, sl, st, off:
                         (bt[_ab(t)[0], _ab(t)[1]], 0, 0, 0)),
            pl.BlockSpec((1, bs, Hkv, Da),
                         lambda t, bt, sl, st, off:
                         (bt[_ab(t)[0], _ab(t)[1]], 0, 0, 0)),
            pl.BlockSpec((B, D), lambda t, bt, sl, st, off: (0, 0)),
            pl.BlockSpec((H * Da, D), lambda t, bt, sl, st, off: (0, 0)),
            pl.BlockSpec((1, D), lambda t, bt, sl, st, off: (0, 0)),
            pl.BlockSpec((D, e_log), lambda t, bt, sl, st, off: (0, 0)),
            pl.BlockSpec(l2p.shape, lambda t, bt, sl, st, off: (0, 0)),
            pl.BlockSpec((1, e_log), lambda t, bt, sl, st, off: (0, 0)),
            pl.BlockSpec((1, e_log), lambda t, bt, sl, st, off: (0, 0)),
            pl.BlockSpec((1, D, Fb),
                         lambda t, bt, sl, st, off: (*_ef(t)[:1], 0,
                                                     _ef(t)[1])),
            pl.BlockSpec((1, D, Fb),
                         lambda t, bt, sl, st, off: (*_ef(t)[:1], 0,
                                                     _ef(t)[1])),
            pl.BlockSpec((1, Fb, D),
                         lambda t, bt, sl, st, off: (*_ef(t)[:1],
                                                     _ef(t)[1], 0)),
        ],
        out_specs=[
            pl.BlockSpec((B, D), lambda t, bt, sl, st, off: (0, 0)),
            pl.BlockSpec((B, D), lambda t, bt, sl, st, off: (0, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((H, Da), jnp.float32),    # attention accumulator
            pltpu.VMEM((H, 1), jnp.float32),     # running max
            pltpu.VMEM((H, 1), jnp.float32),     # running denominator
            pltpu.VMEM((cap, D), x.dtype),       # gathered expert rows
            pltpu.VMEM((cap, D), jnp.float32),   # FFN accumulator
            pltpu.VMEM((B, top_k), jnp.int32),   # selected logical ids
            pltpu.VMEM((B, top_k), jnp.float32),  # renormalized weights
            pltpu.VMEM((E, cap), jnp.int32),     # slot -> token row
            pltpu.VMEM((E, cap), jnp.float32),   # slot combine weight
            pltpu.SMEM((E,), jnp.int32),         # per-expert fill count
        ],
    )
    y, h2 = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((B, D), x.dtype),
                   jax.ShapeDtypeStruct((B, D), x.dtype)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(block_table.astype(jnp.int32), seq_lens.astype(jnp.int32),
      start_lens.astype(jnp.int32),
      jnp.asarray(expert_offset, jnp.int32).reshape(1),
      q, k_pool, v_pool, x, w_post, ln2_w.reshape(1, D), router_w,
      l2p.astype(jnp.int32), replica_count.astype(jnp.int32).reshape(
          1, e_log), expert_mask.astype(jnp.int32).reshape(1, e_log),
      gate_w, up_w, down_w)
    return y, h2
