"""Fused router kernel: mask → softmax → top-k → renormalize, one VMEM pass.

The §3.4 missing-expert mask is a kernel *input*, so recovery changes
routing by writing one small array — no recompilation, no weight touch.

Tiling: grid over token blocks; each program holds a (Tb, E) logit tile in
VMEM (E up to 512 comfortably: 256×512×4 B = 512 KiB) and runs k
iterative argmax extractions on it.  E is padded to the 128-lane boundary
by the wrapper.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _router_topk_kernel(logits_ref, mask_ref, w_ref, idx_ref, *, k: int,
                        e_valid: int):
    x = logits_ref[...].astype(jnp.float32)          # (Tb, Ep)
    Tb, Ep = x.shape
    col = jax.lax.broadcasted_iota(jnp.int32, (Tb, Ep), 1)
    valid = (col < e_valid) & (mask_ref[...] != 0)[None, :]
    x = jnp.where(valid, x, NEG_INF)

    # numerically-stable softmax over the masked row
    row_max = jnp.max(x, axis=1, keepdims=True)
    ex = jnp.where(valid, jnp.exp(x - row_max), 0.0)
    denom = jnp.maximum(jnp.sum(ex, axis=1, keepdims=True), 1e-30)
    gates = ex / denom                                # (Tb, Ep)

    work = gates
    wsum = jnp.zeros((Tb, 1), jnp.float32)
    ws, ids = [], []
    for _ in range(k):
        m = jnp.max(work, axis=1, keepdims=True)      # (Tb, 1)
        # first column achieving the max
        hit = work >= m
        first = jnp.min(jnp.where(hit, col, Ep), axis=1, keepdims=True)
        ws.append(m)
        ids.append(first)
        wsum = wsum + m
        work = jnp.where(col == first, NEG_INF, work)
    w = jnp.concatenate(ws, axis=1) / jnp.maximum(wsum, 1e-9)
    w_ref[...] = w
    idx_ref[...] = jnp.concatenate(ids, axis=1).astype(jnp.int32)


def router_topk_pallas(logits, expert_mask, k: int, *, block_t: int = 256,
                       interpret: bool = False):
    """logits: (T, E) -> (weights (T,k) f32, indices (T,k) i32)."""
    T, E = logits.shape
    Ep = max(128, ((E + 127) // 128) * 128)
    Tb = min(block_t, T)
    Tpad = ((T + Tb - 1) // Tb) * Tb
    lg = jnp.pad(logits, ((0, Tpad - T), (0, Ep - E)))
    mask = jnp.pad(expert_mask.astype(jnp.int32), (0, Ep - E))

    kernel = functools.partial(_router_topk_kernel, k=k, e_valid=E)
    w, idx = pl.pallas_call(
        kernel,
        grid=(Tpad // Tb,),
        in_specs=[
            pl.BlockSpec((Tb, Ep), lambda i: (i, 0)),
            pl.BlockSpec((Ep,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((Tb, k), lambda i: (i, 0)),
            pl.BlockSpec((Tb, k), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Tpad, k), jnp.float32),
            jax.ShapeDtypeStruct((Tpad, k), jnp.int32),
        ],
        interpret=interpret,
    )(lg, mask)
    return w[:T], idx[:T]
