"""Fused MoE dispatch → grouped SwiGLU FFN → weighted combine kernel.

The dense-scatter MoE path (``moe.dispatch_compute_combine``) round-trips
an ``(E_local, cap, D)`` capacity buffer through HBM four times: scatter
tokens in, read for the expert FFN, write the FFN output, gather back out
— plus an ``(N, D)`` unsort scatter.  At prefill shapes that buffer is
``capacity_factor`` × the token payload and dominates the MoE roofline.

This kernel keeps the whole pipeline on-chip.  A single ``argsort`` over
expert ids (done in jnp by :func:`moe_group_tokens` — sorting is cheap,
it is the D-wide data movement that hurts) produces, per capacity slot:

  * ``tok_idx (E_local, cap) int32`` — which token row fills the slot
  * ``wgt     (E_local, cap) f32``   — its combine weight (0 = empty slot)

The kernel then runs the ``expert_ffn`` tiling (grid ``(E, cap/Cb,
F/Fb)``, f innermost, (Cb, D) accumulator resident in VMEM, 128-aligned
MXU tiles) but instead of reading a pre-scattered capacity buffer it

  1. **gathers** the x rows for its (expert, slot-block) tile straight
     from the token array at ``f == 0`` (rows stay in VMEM scratch for
     the whole F sweep),
  2. computes ``silu(x@gate) * (x@up) @ down`` tile by tile, and
  3. **scatter-combines** ``wgt * acc`` into the output token rows at
     the last f step.

TPU grids execute sequentially over non-parallel dimensions, so the
read-modify-write combine into ``y`` is race-free; a token selected by k
experts receives its k partial sums across k distinct grid steps.  HBM
sees x once, y once, and two (E·cap) int32/f32 tables — no (E, cap, D)
buffer, no unsort pass.

Current limitation (documented, not enforced): x and y ride in whole-
array VMEM block specs, so very large prefill chunks should be split by
the caller (the distributed path already chunks at
``MAX_GATHERED_TOKENS``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import tpu_compiler_params
from repro.models.moe import group_by_expert


def moe_group_tokens(phys, alive, weights, *, expert_offset, e_local: int,
                     cap: int):
    """Single sort pass: routing outputs -> per-expert slot tables.

    phys/alive/weights: (T, k); expert_offset may be traced (EP rank *
    e_local inside shard_map).  Returns (tok_idx (E,cap) i32 row into the
    flat token array, wgt (E,cap) f32; empty slots have wgt == 0 and
    tok_idx == 0).
    """
    T, k = phys.shape
    N = T * k
    e_id = phys.reshape(N) - expert_offset
    ok = (e_id >= 0) & (e_id < e_local) & alive.reshape(N)
    order, scatter_e, scatter_p = group_by_expert(e_id, ok, e_local, cap)
    tok = (jnp.arange(N, dtype=jnp.int32) // k)[order]
    w = weights.reshape(N).astype(jnp.float32)[order]
    tok_idx = jnp.zeros((e_local, cap), jnp.int32).at[
        scatter_e, scatter_p].set(tok, mode="drop")
    wgt = jnp.zeros((e_local, cap), jnp.float32).at[
        scatter_e, scatter_p].set(w, mode="drop")
    return tok_idx, wgt


def _moe_fused_kernel(tok_ref, wgt_ref, x_ref, g_ref, u_ref, d_ref, y_ref,
                      xs_ref, acc_ref, *, cb: int):
    e = pl.program_id(0)
    c = pl.program_id(1)
    f = pl.program_id(2)
    nf = pl.num_programs(2)

    @pl.when((e == 0) & (c == 0) & (f == 0))
    def _zero_out():
        y_ref[...] = jnp.zeros_like(y_ref)

    @pl.when(f == 0)
    def _gather():
        acc_ref[...] = jnp.zeros_like(acc_ref)

        def body(i, _):
            t = tok_ref[0, i]
            live = wgt_ref[0, i] != 0.0
            row = x_ref[t, :]
            xs_ref[i, :] = jnp.where(live, row, 0.0).astype(xs_ref.dtype)
            return 0

        jax.lax.fori_loop(0, cb, body, 0)

    x = xs_ref[...]                                   # (Cb, D)
    g = g_ref[0]                                      # (D, Fb)
    u = u_ref[0]
    d = d_ref[0]                                      # (Fb, D)
    h = jax.nn.silu(jnp.dot(x, g, preferred_element_type=jnp.float32))
    h = h * jnp.dot(x, u, preferred_element_type=jnp.float32)
    acc_ref[...] += jnp.dot(h.astype(x.dtype), d,
                            preferred_element_type=jnp.float32)

    @pl.when(f == nf - 1)
    def _combine():
        def body(i, _):
            w = wgt_ref[0, i]

            @pl.when(w != 0.0)
            def _():
                t = tok_ref[0, i]
                y_ref[t, :] += (w * acc_ref[i, :]).astype(y_ref.dtype)

            return 0

        jax.lax.fori_loop(0, cb, body, 0)


def moe_fused_pallas(x, gate_w, up_w, down_w, weights, phys, alive, *,
                     cap: int, expert_offset=0, e_local: int,
                     block_c: int = 128, block_f: int = 256,
                     interpret: bool = False):
    """Fused dispatch->FFN->combine over local expert slots.

    x: (T, D); gate/up: (E_local, D, F); down: (E_local, F, D);
    weights (T,k) f32, phys (T,k) i32 physical slot ids, alive (T,k) bool.
    Returns y (T, D) = sum_k w * expert_{phys}(x) restricted to slots in
    [expert_offset, expert_offset + e_local); out-of-capacity / foreign /
    lost-expert copies contribute zero (same semantics as the dense path).
    """
    T, D = x.shape
    E = gate_w.shape[0]
    assert E == e_local, (E, e_local)
    F = gate_w.shape[-1]
    tok_idx, wgt = moe_group_tokens(
        phys, alive, weights, expert_offset=expert_offset,
        e_local=e_local, cap=cap)

    Cb = min(block_c, cap)
    Fb = min(block_f, F)
    Cp = ((cap + Cb - 1) // Cb) * Cb
    Fp = ((F + Fb - 1) // Fb) * Fb
    if Cp != cap:
        tok_idx = jnp.pad(tok_idx, ((0, 0), (0, Cp - cap)))
        wgt = jnp.pad(wgt, ((0, 0), (0, Cp - cap)))
    if Fp != F:
        gate_w = jnp.pad(gate_w, ((0, 0), (0, 0), (0, Fp - F)))
        up_w = jnp.pad(up_w, ((0, 0), (0, 0), (0, Fp - F)))
        down_w = jnp.pad(down_w, ((0, 0), (0, Fp - F), (0, 0)))

    kernel = functools.partial(_moe_fused_kernel, cb=Cb)
    y = pl.pallas_call(
        kernel,
        grid=(E, Cp // Cb, Fp // Fb),
        in_specs=[
            pl.BlockSpec((1, Cb), lambda e, c, f: (e, c)),      # tok_idx
            pl.BlockSpec((1, Cb), lambda e, c, f: (e, c)),      # wgt
            pl.BlockSpec((T, D), lambda e, c, f: (0, 0)),       # x (whole)
            pl.BlockSpec((1, D, Fb), lambda e, c, f: (e, 0, f)),
            pl.BlockSpec((1, D, Fb), lambda e, c, f: (e, 0, f)),
            pl.BlockSpec((1, Fb, D), lambda e, c, f: (e, f, 0)),
        ],
        out_specs=pl.BlockSpec((T, D), lambda e, c, f: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((T, D), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((Cb, D), x.dtype),
            pltpu.VMEM((Cb, D), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary", "arbitrary", "arbitrary")),
        interpret=interpret,
    )(tok_idx, wgt, x, gate_w, up_w, down_w)
    return y.astype(x.dtype)
