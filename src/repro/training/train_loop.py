"""Training loop: jitted train_step factory + a simple driver."""
from __future__ import annotations

import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.models.model import Model
from repro.training.optimizer import (OptimizerConfig,
                                      adamw_update, init_adamw)


def make_train_step(model: Model, opt_cfg: OptimizerConfig,
                    runtime=None) -> Callable:
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    The MoE runtime is threaded through so a degraded system (masked
    experts after a recovery) can keep *serving-consistent* fine-tuning —
    and so the dry-run sees the same routing data flow as serving.
    """

    def train_step(params, opt_state, batch):
        def loss_fn(p):
            loss, metrics = model.loss(p, batch, runtime)
            return loss, metrics
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        params, opt_state, opt_metrics = adamw_update(
            opt_cfg, grads, opt_state, params)
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step


def train(model: Model, batches, steps: int,
          opt_cfg: Optional[OptimizerConfig] = None, seed: int = 0,
          log_every: int = 50, params=None):
    """Simple single-host training driver. Returns (params, history)."""
    opt_cfg = opt_cfg or OptimizerConfig(total_steps=steps)
    if params is None:
        params = model.init(jax.random.PRNGKey(seed))
    opt_state = init_adamw(params)
    step_fn = jax.jit(make_train_step(model, opt_cfg))
    history = []
    t0 = time.perf_counter()
    for i, batch in zip(range(steps), batches):
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if i % log_every == 0 or i == steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            m["step"] = i
            m["elapsed_s"] = time.perf_counter() - t0
            history.append(m)
    return params, history
