"""Synthetic data pipeline: deterministic, learnable token streams.

Two generators:
* ``lm_batches`` — a mixture of structured patterns (arithmetic mod-V
  sequences, copy spans, periodic motifs).  A ~100M model reaches well
  below uniform entropy in a few hundred steps, which is all the §4.2
  lost-expert benchmark needs: a trained model whose quality we can
  measure as experts are masked.
* ``eval_batch`` — held-out split with the same distribution.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    batch_size: int
    seed: int = 0


def _pattern_seq(rng: np.random.Generator, V: int, S: int) -> np.ndarray:
    kind = rng.integers(0, 3)
    if kind == 0:       # arithmetic: x_{t+1} = (x_t + d) % V
        start, d = rng.integers(0, V), rng.integers(1, min(7, V))
        return (start + d * np.arange(S)) % V
    if kind == 1:       # copy: motif of length m repeated
        m = int(rng.integers(2, 9))
        motif = rng.integers(0, V, m)
        return np.tile(motif, S // m + 1)[:S]
    # interleave two arithmetic streams
    a0, a1 = rng.integers(0, V, 2)
    d0, d1 = rng.integers(1, 5, 2)
    out = np.empty(S, np.int64)
    out[0::2] = (a0 + d0 * np.arange((S + 1) // 2)) % V
    out[1::2] = (a1 + d1 * np.arange(S // 2)) % V
    return out


def make_batch(cfg: DataConfig, step: int, split: str = "train"
               ) -> Dict[str, np.ndarray]:
    salt = 0 if split == "train" else 777_777
    rng = np.random.default_rng(cfg.seed * 1_000_003 + step + salt)
    toks = np.stack([_pattern_seq(rng, cfg.vocab_size, cfg.seq_len)
                     for _ in range(cfg.batch_size)])
    return {"tokens": toks.astype(np.int32),
            "loss_mask": np.ones_like(toks, np.int32)}


def lm_batches(cfg: DataConfig, start_step: int = 0) -> Iterator[Dict]:
    step = start_step
    while True:
        yield make_batch(cfg, step)
        step += 1
