"""AdamW + cosine schedule with linear warmup (pure pytree, no optax)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    betas: tuple = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 1000
    min_lr_ratio: float = 0.1
    grad_clip: float = 1.0


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


def init_adamw(params) -> AdamWState:
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return AdamWState(jnp.zeros((), jnp.int32), zeros,
                      jax.tree_util.tree_map(jnp.zeros_like, params))


def schedule(cfg: OptimizerConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, (step + 1) / max(cfg.warmup_steps, 1))
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree_util.tree_leaves(tree)))


def adamw_update(cfg: OptimizerConfig, grads, state: AdamWState, params):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    grads = jax.tree_util.tree_map(lambda g: g * clip, grads)
    step = state.step + 1
    b1, b2 = cfg.betas
    lr = schedule(cfg, state.step)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mh = m / bc1
        vh = v / bc2
        new_p = p - lr * (mh / (jnp.sqrt(vh) + cfg.eps)
                          + cfg.weight_decay * p)
        return new_p, m, v

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(state.m)
    flat_v = jax.tree_util.tree_leaves(state.v)
    out = [upd(g, m, v, p) for g, m, v, p
           in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step, new_m, new_v), {
        "lr": lr, "grad_norm": gnorm}
