"""Checkpointing: flat-key .npz save/restore with partial (sliced) reads.

Inference weights are static (no periodic checkpointing needed — the
paper's point about training vs inference recovery), but the checkpoint
is the *disk source* for the role-switch path: a switched MoEExecutor
re-loads only its expert slice from here (§3.4).
"""
from __future__ import annotations

import os
import time
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np


def _flatten(params) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
        key = "/".join(_key_str(k) for k in path)
        flat[key] = np.asarray(leaf)
    return flat


def _key_str(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return str(k.idx)
    if hasattr(k, "name"):
        return str(k.name)
    return str(k)


def save_checkpoint(path: str, params, extra: Optional[Dict] = None) -> float:
    """Returns elapsed seconds."""
    t0 = time.perf_counter()
    flat = _flatten(params)
    if extra:
        for k, v in extra.items():
            flat[f"__extra__/{k}"] = np.asarray(v)
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    np.savez(path, **flat)
    return time.perf_counter() - t0


def load_flat(path: str) -> Dict[str, np.ndarray]:
    with np.load(path, allow_pickle=False) as z:
        return {k: z[k] for k in z.files if not k.startswith("__extra__/")}


def restore_like(path: str, template) -> Any:
    """Restore a pytree shaped like ``template`` from the checkpoint."""
    flat = load_flat(path)
    paths, tdef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for p, leaf in paths:
        key = "/".join(_key_str(k) for k in p)
        arr = flat[key]
        assert arr.shape == leaf.shape, (key, arr.shape, leaf.shape)
        leaves.append(arr.astype(leaf.dtype))
    return tdef.unflatten(leaves)


def load_keys(path: str, predicate: Callable[[str], bool],
              slicer: Optional[Callable[[str, np.ndarray], np.ndarray]] = None
              ) -> Dict[str, np.ndarray]:
    """Partial read: only keys matching ``predicate`` (e.g. one EP rank's
    expert slice) — the role-switch weight load."""
    out = {}
    with np.load(path, allow_pickle=False) as z:
        for k in z.files:
            if predicate(k):
                arr = z[k]
                out[k] = slicer(k, arr) if slicer else arr
    return out
