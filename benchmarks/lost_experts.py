"""Table 2 / Figure 6 analogue: model quality as experts are lost (§4.2).

We cannot run DeepSeek V3 + lm-eval-harness on CPU; instead we train a
small 64-expert MoE on the synthetic pattern task until it clearly beats
chance, then mask a fraction r ∈ {1/64..1/2} of experts under the paper's
two selection schemes and measure quality (CE loss + next-token accuracy):

  task-based  worst case — fail the most-activated experts first
              (activation counts from a calibration pass)
  every_nth   uniform — fail every ⌈1/r⌉-th expert

The paper's claim to validate: degradation is negligible for small r
(≤ 1/32) and grows sharply past 1/8, with task-based strictly worse.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.models import moe as moe_mod
from repro.models.model import Model
from repro.training.data import DataConfig, make_batch
from repro.training.train_loop import train

FRACTIONS = [1 / 64, 1 / 32, 1 / 16, 1 / 8, 1 / 4, 1 / 2]


def build_model():
    cfg = get_smoke_config("qwen2-moe-a2.7b")
    cfg = dataclasses.replace(
        cfg,
        d_model=128, num_heads=4, num_kv_heads=2, head_dim=32,
        vocab_size=64, num_layers=2,
        moe=dataclasses.replace(cfg.moe, num_experts=64, top_k=4,
                                expert_d_ff=64, num_shared_experts=1,
                                num_redundant_experts=0,
                                capacity_factor=4.0),
    )
    return Model(cfg), cfg


def eval_quality(model, params, cfg, runtime, dc, n_batches=4) -> Dict:
    ce_sum, acc_sum, n = 0.0, 0.0, 0
    for i in range(n_batches):
        b = make_batch(dc, 10_000 + i, split="eval")
        batch = {k: jnp.asarray(v) for k, v in b.items()}
        logits, _, _ = model.logits_full(params, batch, runtime)
        labels = batch["tokens"][:, 1:]
        lg = logits[:, :-1, : cfg.vocab_size].astype(jnp.float32)
        logz = jax.nn.logsumexp(lg, axis=-1)
        gold = jnp.take_along_axis(lg, labels[..., None], axis=-1)[..., 0]
        ce_sum += float((logz - gold).mean())
        acc_sum += float((jnp.argmax(lg, -1) == labels).mean())
        n += 1
    return {"ce": ce_sum / n, "acc": acc_sum / n}


def calibrate_activation_counts(model, params, cfg, dc) -> np.ndarray:
    """Per-expert activation counts over calibration data (the paper's
    task-based ranking), collected by intercepting the router."""
    counts = np.zeros(cfg.moe.num_experts, np.int64)
    orig_route = moe_mod.route

    def counting_route(router_w, x_flat, runtime, moe):
        w, sel, aux = orig_route(router_w, x_flat, runtime, moe)
        sel_np = np.asarray(sel)           # eager mode: concrete
        np.add.at(counts, sel_np.reshape(-1), 1)
        return w, sel, aux

    moe_mod.route = counting_route
    try:
        for i in range(2):
            b = make_batch(dc, 20_000 + i, split="eval")
            batch = {k: jnp.asarray(v) for k, v in b.items()}
            model.logits_full(params, batch)  # eager (un-jitted) on purpose
    finally:
        moe_mod.route = orig_route
    return counts


def mask_for(cfg, scheme: str, r: float, counts: np.ndarray):
    E = cfg.moe.num_experts
    k = max(1, round(E * r))
    if scheme == "task_based":
        dead = np.argsort(-counts)[:k]
    else:  # every_nth
        step = max(1, round(1 / r))
        dead = np.arange(0, E, step)[:k]
    mask = np.ones(E, bool)
    mask[dead] = False
    return mask, dead


def run(train_steps: int = 400) -> List[Dict]:
    from repro.training.optimizer import OptimizerConfig
    model, cfg = build_model()
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=64, batch_size=16)

    def batches():
        i = 0
        while True:
            yield make_batch(dc, i)
            i += 1

    opt_cfg = OptimizerConfig(lr=2e-3, warmup_steps=30,
                              total_steps=train_steps)
    params, history = train(model, batches(), train_steps, opt_cfg=opt_cfg,
                            log_every=50)
    base_rt = model.default_runtime()
    base = eval_quality(model, params, cfg, base_rt, dc)
    counts = calibrate_activation_counts(model, params, cfg, dc)

    rows = [{"scheme": "base", "fraction": 0.0, **base,
             "train_loss": history[-1]["loss"]}]
    for scheme in ("task_based", "every_nth"):
        for r in FRACTIONS:
            mask, dead = mask_for(cfg, scheme, r, counts)
            rt = base_rt._replace(expert_mask=jnp.asarray(mask))
            q = eval_quality(model, params, cfg, rt, dc)
            rows.append({"scheme": scheme, "fraction": r, **q,
                         "n_dead": int((~mask).sum())})
    return rows


def print_table(rows: List[Dict]) -> None:
    print("\n# Table-2/Fig-6 analogue: quality vs fraction of lost experts")
    print(f"{'scheme':12s} {'r':>7s} {'CE loss':>9s} {'accuracy':>9s}")
    for r in rows:
        print(f"{r['scheme']:12s} {r['fraction']:7.4f} {r['ce']:9.4f} "
              f"{r['acc']:9.4f}")
    base = rows[0]
    small = [r for r in rows if 0 < r["fraction"] <= 1 / 32]
    big = [r for r in rows if r["fraction"] >= 1 / 4]
    if small and big:
        d_small = max(r["ce"] - base["ce"] for r in small)
        d_big = max(r["ce"] - base["ce"] for r in big)
        print(f"\nΔCE at r<=1/32: {d_small:+.4f}   ΔCE at r>=1/4: "
              f"{d_big:+.4f}   (paper: small-r loss negligible)")


if __name__ == "__main__":
    print_table(run())
