"""Benchmark entrypoint: one benchmark per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--quick] [--only NAME]
                                          [--json PATH]

Prints human tables plus a machine-readable ``name,us_per_call,derived``
CSV summary at the end.  ``--json PATH`` additionally appends the same
summary rows to PATH (a JSON list of run records), so every benchmark —
not just moe_hotpath — feeds the BENCH_* perf trajectory.
"""
from __future__ import annotations

import argparse
import sys
import time


def append_json(path: str, rows) -> None:
    """Append one run record to a BENCH-style JSON trajectory file."""
    from benchmarks.trajectory import append_record
    append_record(path, {
        "unix_time": time.time(),
        "rows": [{"name": n, "us_per_call": us, "derived": d}
                 for n, us, d in rows],
    })


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="shrink the lost-experts training run")
    ap.add_argument("--only", default=None,
                    choices=[None, "recovery", "lost_experts",
                             "compile_cache", "reinit", "roofline",
                             "slo", "moe_hotpath", "fleet_slo",
                             "fleet_campaign"])
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="append the CSV-summary rows to PATH as JSON")
    args = ap.parse_args(argv)
    csv_rows = [("name", "us_per_call", "derived")]

    def want(name):
        return args.only in (None, name)

    if want("moe_hotpath"):
        from benchmarks import moe_hotpath
        rows = moe_hotpath.run(quick=args.quick)
        moe_hotpath.print_table(rows)
        moe_hotpath.save_json(rows, quick=args.quick)
        for r in rows:
            if "accepted_per_step" in r:
                # speculation efficiency, not just latency: accepted
                # tokens per speculative step + window-width histogram
                hist = "|".join(f"{g}:{n}" for g, n in
                                sorted(r["window_hist"].items()))
                csv_rows.append((f"moe_hotpath_{r['name']}",
                                 f"{r['metric_us']:.0f}",
                                 f"accepted_per_step="
                                 f"{r['accepted_per_step']:.2f},"
                                 f"windows={r['spec_windows']},"
                                 f"hist={hist}"))
            elif r.get("kind") == "engine":
                # host/device overlap surface: wall-clock TPOT plus the
                # fraction of wall time the device idled on the host
                ov = r.get("overlap", {})
                extra = (f",planned_ahead={ov['planned_ahead']}"
                         f",replans={ov['replans']}" if ov else "")
                csv_rows.append((f"moe_hotpath_{r['name']}",
                                 f"{r['metric_us']:.0f}",
                                 f"host_gap_fraction="
                                 f"{r['host_gap_fraction']:.4f}{extra}"))
            elif "mega_us" in r:
                csv_rows.append((f"moe_hotpath_{r['name']}_mega",
                                 f"{r['mega_us']:.0f}",
                                 f"composed_us={r['composed_us']:.0f},"
                                 f"speedup={r['speedup']:.2f}x"))
            else:
                csv_rows.append((f"moe_hotpath_{r['name']}_fused",
                                 f"{r['fused_us']:.0f}",
                                 f"dense_us={r['dense_us']:.0f},"
                                 f"speedup={r['speedup']:.2f}x"))

    if want("reinit"):
        from benchmarks import reinit_breakdown
        rows = reinit_breakdown.run()
        reinit_breakdown.print_table(rows)
        total = next(r["seconds"] for r in rows if r["category"] == "TOTAL")
        gen = next(r["share"] for r in rows if r["category"] == "generator")
        csv_rows.append(("reinit_breakdown", f"{total * 1e6:.0f}",
                         f"generator_share={gen:.2f}"))

    if want("recovery"):
        from benchmarks import recovery_time
        rows = recovery_time.run()
        recovery_time.print_table(rows)
        base = next(r for r in rows
                    if r["scenario"] == "baseline_cached_reinit")
        others = [r for r in rows if r is not base]
        best = min(others, key=lambda r: r["total_s"])
        worst = max(others, key=lambda r: r["total_s"])
        csv_rows.append(("recovery_best_case",
                         f"{best['total_s'] * 1e6:.0f}",
                         f"reduction_vs_baseline="
                         f"{100 * (1 - best['total_s'] / base['total_s']):.1f}%"))
        csv_rows.append(("recovery_worst_case",
                         f"{worst['total_s'] * 1e6:.0f}",
                         f"reduction_vs_baseline="
                         f"{100 * (1 - worst['total_s'] / base['total_s']):.1f}%"))

    if want("compile_cache"):
        from benchmarks import compile_cache
        rows = compile_cache.run()
        compile_cache.print_table(rows)
        cold = rows[0]["read_cache_s"] + rows[0]["compile_s"]
        pre = rows[2]["read_cache_s"] + rows[2]["compile_s"]
        csv_rows.append(("compile_cold", f"{cold * 1e6:.0f}", ""))
        csv_rows.append(("compile_precompiled", f"{pre * 1e6:.0f}",
                         f"speedup={cold / max(pre, 1e-9):.0f}x"))

    if want("lost_experts"):
        from benchmarks import lost_experts
        rows = lost_experts.run(train_steps=150 if args.quick else 400)
        lost_experts.print_table(rows)
        base = rows[0]
        r32 = next((r for r in rows if r["scheme"] == "every_nth"
                    and abs(r["fraction"] - 1 / 32) < 1e-9), None)
        if r32:
            csv_rows.append(("lost_experts_r32_dCE", "0",
                             f"delta_ce={r32['ce'] - base['ce']:+.4f}"))

    if want("fleet_slo"):
        from benchmarks import fleet_slo
        out = fleet_slo.run(quick=args.quick)
        fleet_slo.print_table(out)
        fleet_slo.save_json(out)
        for name, res in out["policies"].items():
            csv_rows.append((f"fleet_slo_{name}_p99_ttft",
                             f"{res['p99_ttft_s'] * 1e6:.0f}",
                             f"p99_degradation_ms="
                             f"{res['p99_degradation_s'] * 1e3:.0f}"))
        csv_rows.append(("fleet_slo_revive_beats_restart",
                         "1" if out["revive_beats_restart"] else "0", ""))
        if "frontend" in out:
            fr = out["frontend"]
            csv_rows.append((
                "fleet_slo_frontend_req_per_s",
                f"{1e6 / max(fr['req_per_s'], 1e-9):.0f}",
                f"req_per_s={fr['req_per_s']:.3f},"
                f"p99_s={fr['p99_latency_s']:.3f},"
                f"host_gap_fraction={fr['host_gap_fraction']:.4f}"))

    if want("fleet_campaign"):
        from benchmarks import fleet_campaign
        out = fleet_campaign.run(quick=args.quick)
        fleet_campaign.print_table(out)
        fleet_campaign.save_json(out)
        fleet_campaign.write_forensics(out)
        for name, res in out["policies"].items():
            csv_rows.append((f"fleet_campaign_{name}_slo_burn",
                             f"{res['slo_burn_s'] * 1e6:.0f}",
                             f"finished={res['finished']}/{res['n']}"))
        csv_rows.append(("fleet_campaign_arbiter_beats_forced",
                         "1" if out["arbiter_beats_best_forced"] else "0",
                         f"best_forced={out['best_forced_policy']}"))

    if want("slo"):
        from benchmarks import slo_timeline
        res = slo_timeline.run()
        slo_timeline.print_table(res)
        csv_rows.append(("slo_worst_stall", f"{res['stall_s'] * 1e6:.0f}",
                         f"recovery_total_ms="
                         f"{res['recovery_total_s'] * 1e3:.0f}"))

    if want("roofline"):
        from benchmarks import roofline
        rows = roofline.run()
        if rows:
            roofline.print_table(rows)
            csv_rows.append(("roofline_combos", "0", f"n={len(rows)}"))
        else:
            print("\n(no dry-run records yet: run "
                  "`python -m repro.launch.dryrun_all` first)")

    print("\n# CSV summary")
    for row in csv_rows:
        print(",".join(str(x) for x in row))
    if args.json:
        append_json(args.json, csv_rows[1:])
        print(f"\nappended {len(csv_rows) - 1} rows to {args.json}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
