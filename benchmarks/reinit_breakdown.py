"""Figure 1 analogue: category breakdown of a cached instance
reinitialization (the baseline ReviveMoE avoids).

Paper (DeepSeek V3, 80 NPUs): 83.1 s total, dominated by the generator
(model instantiation + weight loading).  Our laptop-scale breakdown
reproduces the *shape*: generator ≫ executor processes > compile >
groups/other.
"""
from __future__ import annotations

import tempfile
from typing import Dict, List

from repro.configs import get_smoke_config
from repro.serving.engine import EngineConfig, InferenceEngine


def run() -> List[Dict]:
    cfg = get_smoke_config("qwen2-moe-a2.7b")
    ec = EngineConfig(mode="disaggregated", num_dp=3, num_moe=2,
                      max_batch=2, max_seq=64, block_size=8, num_blocks=64,
                      workdir=tempfile.mkdtemp(prefix="bench_reinit_"))
    eng = InferenceEngine(cfg, ec)     # first build writes the checkpoint
    t = eng.full_reinit()              # cached reinit: weights from disk
    skip = {"precompile_failure_scenarios"}
    total = sum(v for k, v in t.items() if k not in skip)
    return [{"category": k, "seconds": v,
             "share": v / total if total else 0.0}
            for k, v in sorted(t.items(), key=lambda kv: -kv[1])
            if k not in skip] + [{"category": "TOTAL", "seconds": total,
                                  "share": 1.0}]


def print_table(rows: List[Dict]) -> None:
    print("\n# Figure-1 analogue: cached reinitialization breakdown")
    for r in rows:
        print(f"  {r['category']:22s} {r['seconds']:8.3f}s "
              f"{100 * r['share']:5.1f}%")


if __name__ == "__main__":
    print_table(run())
