"""Shared fleet benchmark harness.

The pieces every fleet-level benchmark needs — the smoke model config,
the engine config, percentile helper, and the run-one-fleet driver —
extracted from ``fleet_slo`` so ``fleet_campaign`` (and future fleet
benchmarks) reuse one implementation instead of drifting copies.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import numpy as np

from repro.configs import get_smoke_config
from repro.core.fault_codes import ErrorType, Severity
from repro.fleet import PoissonTraffic, build_fleet
from repro.serving.engine import EngineConfig

FAULT_STEP = 10         # engine step on instance 0 (mid-step MoE loss)
FAULT_PID = 3           # second MoE executor (pid = num_dp + 1)


def fleet_cfg():
    cfg = get_smoke_config("qwen2-moe-a2.7b")
    # fully provisioned redundancy (§3.4's common case): the injected
    # fault is covered by replica slots, so revive is the pure
    # map-update + precompiled-graph path — no role switch, no capacity
    # loss.  Restart/spare handle the *same* covered fault, so the
    # comparison isolates the recovery mechanism itself.
    return dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, num_experts=4,
                                     num_redundant_experts=4, top_k=2))


def fleet_ecfg(workdir: str) -> EngineConfig:
    return EngineConfig(mode="disaggregated", num_dp=2, num_moe=2,
                        max_batch=2, max_seq=64, block_size=8,
                        num_blocks=96, workdir=workdir)


def percentile(xs: List[float], q: float) -> float:
    return float(np.percentile(np.asarray(xs), q)) if xs else 0.0


def run_fleet(workdir: str, policy: Optional[str], n_requests: int,
              rate: float, faults: Optional[List[Dict]] = None,
              spares: Optional[int] = None) -> Dict:
    """One fleet, one arrival trace, optionally injected faults.

    ``faults``: explicit fault list [{"iid", "step", "pid", "component"}]
    (defaults to the single canonical MoE fault when ``policy`` is set).
    """
    traffic = PoissonTraffic(rate, fleet_cfg().vocab_size, prompt_len=8,
                             max_new_tokens=12, seed=11,
                             limit=n_requests)
    if faults is None and policy is not None:
        faults = [{"iid": 0, "step": FAULT_STEP, "pid": FAULT_PID,
                   "component": "moe"}]
    if spares is None:
        spares = 1 if policy == "spare" else 0
    fleet = build_fleet(fleet_cfg(), fleet_ecfg(workdir), instances=3,
                        spares=spares, force_policy=policy,
                        traffic=traffic)
    for f in faults or []:
        fleet.instances[f["iid"]].engine.injector.schedule(
            f["step"], f["pid"], severity=Severity.L6,
            error_type=ErrorType.HBM_ECC, component=f["component"],
            mid_step=True)
    timeline: List[Dict] = []
    prev_tokens = 0
    t_wall = time.perf_counter()
    for _ in range(4000):
        fleet.tick()
        tokens = sum(len(r.output_tokens) for r in fleet.requests)
        timeline.append({"t_s": round(fleet.now_s, 4),
                         "new_tokens": tokens - prev_tokens})
        prev_tokens = tokens
        if traffic.exhausted and fleet.requests and not fleet.unfinished:
            break
    ttfts = fleet.ttfts()
    stall = max((b["t_s"] - a["t_s"] for a, b in
                 zip(timeline, timeline[1:])), default=0.0)
    return {
        "finished": len(fleet.requests) - fleet.unfinished,
        "n": len(fleet.requests),
        "p50_ttft_s": percentile(ttfts, 50),
        "p99_ttft_s": percentile(ttfts, 99),
        "virtual_makespan_s": round(fleet.now_s, 3),
        "wall_s": round(time.perf_counter() - t_wall, 3),
        "worst_tick_gap_s": round(stall, 4),
        "goodput_timeline": timeline,
        "arbiter_log": [d.summary() for d in fleet.arbiter.decisions],
    }
