"""Fleet-level Figure-5 analogue: recovery policy vs client SLO.

Three fleets serve the *same* Poisson arrival trace and take the *same*
injected MoE device fault on instance 0; the only difference is the
recovery policy the arbiter is forced to use:

* ``revive``  — ReviveMoE in-place recovery (paper's contribution),
* ``restart`` — drain-and-restart of the wounded instance (baseline),
* ``spare``   — live migration onto a pre-warmed standby (FailSafe-style
  KV-block streaming; the wounded instance's reachable executors ship
  their residents' live blocks, only dead-device requests re-prefill).

A no-fault run provides the TTFT reference.  The figure of merit is p99
TTFT *degradation* vs that baseline.  Two extra sections stress the
parts a single-fault trace cannot:

* ``compound`` — correlated / multi-fault traces (two devices of the
  same comm domain, and a second instance faulting while the first is
  still recovering), with the arbiter left free to choose per fault.
* ``prefix_sweep`` — migration cost vs prompt length: KV-block streaming
  is ~flat in the prefix (a block copy), token-replay re-prefill grows
  linearly with it; both paths are asserted token-exact.

Every run appends to ``BENCH_fleet_slo.json`` via benchmarks.trajectory.
"""
from __future__ import annotations

import dataclasses
import os
import tempfile
import time
from typing import Dict, List

import numpy as np

from benchmarks.fleet_harness import (fleet_cfg as _cfg,
                                      fleet_ecfg as _ecfg,
                                      percentile as _percentile,
                                      run_fleet as _run_fleet)
from repro.fleet import PoissonTraffic, build_fleet
from repro.serving.engine import EngineConfig

BENCH_PATH = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_fleet_slo.json")


# correlated / multi-fault traces (ROADMAP follow-up b): the arbiter is
# left free to choose per fault.  pids: 0-1 attention, 2-3 MoE.
COMPOUND_TRACES = {
    # two devices in the same comm domain (one host/switch takes both):
    # the MoE rank at step 10, then an attention rank of the *same*
    # instance two steps later — mid-recovery of the first
    "double_fault_same_domain": [
        {"iid": 0, "step": 10, "pid": 3, "component": "moe"},
        {"iid": 0, "step": 12, "pid": 1, "component": "attn"},
    ],
    # a second instance faults while the fleet is still absorbing the
    # first instance's recovery
    "fault_during_recovery": [
        {"iid": 0, "step": 10, "pid": 3, "component": "moe"},
        {"iid": 1, "step": 11, "pid": 1, "component": "attn"},
    ],
}


def _sweep_engines(workdir: str):
    """Two weight-identical engines sharing one compile cache: the
    migration source and target of the prefix sweep."""
    from repro.serving.engine import InferenceEngine
    cfg = _cfg()
    max_seq = 320
    ecfg = EngineConfig(mode="collocated", num_dp=1, max_batch=2,
                        max_seq=max_seq, block_size=16, num_blocks=48,
                        workdir=workdir)
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0,
                                     min_capacity=64))
    src = InferenceEngine(cfg, dataclasses.replace(ecfg))
    tgt = InferenceEngine(cfg, dataclasses.replace(ecfg))
    return cfg, src, tgt


def prefix_sweep(workdir: str, quick: bool = False) -> Dict:
    """Migration cost vs prompt length: KV-block streaming vs re-prefill.

    For each prefix length P, a request generated to mid-stream on the
    source engine is migrated to the target both ways; the measured cost
    is admission + the target's first step (decode-only when streamed, a
    P-token prefill when replayed).  Both paths must continue the exact
    token stream (position-seeded sampling), asserted per point.
    """
    prefixes = [16, 128] if quick else [16, 64, 128, 256]
    reps = 2 if quick else 3
    cfg, src, tgt = _sweep_engines(workdir)
    rng = np.random.default_rng(17)

    def migrate_once(prompt: List[int], stream: bool) -> Dict:
        req = src.submit(prompt, max_new_tokens=6)
        for _ in range(40):              # chunked prefill: step to mid-gen
            src.step()
            if len(req.output_tokens) >= 2:
                break
        assert len(req.output_tokens) >= 2, "must be mid-generation"
        pre_tokens = list(req.output_tokens)
        exported = src.export_live_requests(with_kv=True)
        (req2, kv), = exported
        assert req2 is req
        if not stream:
            kv = None
        # time to the next token on the target: one decode step when
        # streamed vs a chunked P-token re-prefill when replayed
        t0 = time.perf_counter()
        tgt.admit(req, kv=kv)
        for _ in range(40):
            tgt.step()
            if len(req.output_tokens) > len(pre_tokens):
                break
        dt = time.perf_counter() - t0
        assert len(req.output_tokens) == len(pre_tokens) + 1
        tgt.run(max_steps=60)
        assert req.state.value == "finished"
        return {"s": dt, "tokens": list(req.output_tokens)}

    def prompt_for(P: int) -> List[int]:
        return list(rng.integers(0, cfg.vocab_size, P))

    # warm every prefill bucket + the install/decode graphs off-clock
    for P in prefixes:
        migrate_once(prompt_for(P), stream=True)
        migrate_once(prompt_for(P), stream=False)

    points = []
    for P in prefixes:
        stream_runs, replay_runs = [], []
        for _ in range(reps):
            prompt = prompt_for(P)
            s = migrate_once(prompt, stream=True)
            r = migrate_once(prompt, stream=False)
            # parity: KV-stream and re-prefill continue identical tokens
            # (same prompt, position-seeded sampling — the token stream
            # must be independent of the migration mechanism)
            assert s["tokens"] == r["tokens"], (P, s["tokens"], r["tokens"])
            stream_runs.append(s["s"])
            replay_runs.append(r["s"])
        points.append({"prefix": P,
                       "stream_s": round(min(stream_runs), 5),
                       "replay_s": round(min(replay_runs), 5)})
    lo, hi = points[0], points[-1]
    stream_growth = hi["stream_s"] - lo["stream_s"]
    replay_growth = hi["replay_s"] - lo["replay_s"]
    return {
        "block_size": 16,
        "points": points,
        "stream_growth_s": round(stream_growth, 5),
        "replay_growth_s": round(replay_growth, 5),
        # streamed takeover must not inherit re-prefill's linear term
        "stream_flat_vs_replay_linear": bool(
            replay_growth > 0
            and stream_growth < 0.5 * replay_growth),
    }


def admission_bench(workdir: str, quick: bool = False) -> Dict:
    """Continuous-batching admission pipeline vs the one-prefill-per-step
    baseline, on the production-shaped workload it exists for: mixed
    long/short prompts, 80% opening with one shared system prompt.

    Both fleets serve the identical arrival trace; the only differences
    are ``EngineConfig.admission`` ('chunked' = token-budget chunked
    prefills + shared-prefix block cache, 'serial' = legacy whole-prompt,
    one per step) and prefix-affinity routing (chunked only).  Reported:
    p50/p99 TTFT and prefill tokens computed vs skipped via the cache.
    """
    n_requests = 18 if quick else 36
    rate = 40.0
    out: Dict = {"n_requests": n_requests, "rate_per_s": rate,
                 "shared_fraction": 0.8, "modes": {}}

    def _traffic():
        return PoissonTraffic(rate, _cfg().vocab_size,
                              prompt_len=(8, 40), max_new_tokens=10,
                              seed=23, limit=n_requests,
                              shared_prefix_len=24, shared_fraction=0.8)

    for mode in ("serial", "chunked"):
        wd = os.path.join(workdir, f"adm_{mode}")
        ecfg = dataclasses.replace(_ecfg(wd), admission=mode,
                                   prefill_chunk=16, workdir=wd)
        # warm the per-mode compile cache + checkpoint off the clock
        warm = build_fleet(_cfg(), dataclasses.replace(ecfg), instances=2,
                           traffic=PoissonTraffic(
                               rate, _cfg().vocab_size, prompt_len=(8, 40),
                               max_new_tokens=4, seed=5, limit=2,
                               shared_prefix_len=24, shared_fraction=0.8))
        warm.run(max_ticks=400)
        fleet = build_fleet(_cfg(), dataclasses.replace(ecfg), instances=2,
                            traffic=_traffic(),
                            prefix_affinity=(mode == "chunked"))
        t0 = time.perf_counter()
        fleet.run(max_ticks=4000)
        ttfts = fleet.ttfts()
        stats: Dict[str, int] = {}
        for inst in fleet.instances.values():
            for k, v in inst.engine.prefill_stats().items():
                stats[k] = stats.get(k, 0) + v
        done = len(fleet.requests) - fleet.unfinished
        out["modes"][mode] = {
            "finished": done, "n": len(fleet.requests),
            "p50_ttft_s": _percentile(ttfts, 50),
            "p99_ttft_s": _percentile(ttfts, 99),
            "virtual_makespan_s": round(fleet.now_s, 3),
            "wall_s": round(time.perf_counter() - t0, 3),
            "prefill_tokens_computed": stats.get(
                "prefill_tokens_computed", 0),
            "prefill_tokens_cached": stats.get("prefill_tokens_cached", 0),
        }
    ch, se = out["modes"]["chunked"], out["modes"]["serial"]
    out["p99_ttft_improvement_s"] = round(
        se["p99_ttft_s"] - ch["p99_ttft_s"], 4)
    out["prefill_tokens_saved"] = ch["prefill_tokens_cached"]
    out["chunked_beats_serial_p99"] = bool(
        ch["p99_ttft_s"] < se["p99_ttft_s"])
    # deterministic regression gates (CI runs --quick): every request
    # finished in both modes, and the prefix-heavy trace actually hit
    # the shared-prefix cache — a silent cache regression fails here
    assert ch["finished"] == ch["n"] and se["finished"] == se["n"], out
    assert ch["prefill_tokens_cached"] > 0, \
        "prefix-heavy trace produced zero shared-prefix cache hits"
    assert ch["prefill_tokens_computed"] < se["prefill_tokens_computed"], \
        "shared-prefix cache saved no prefill compute vs serial"
    return out


def frontend_bench(workdir: str, quick: bool = False,
                   slo_s: float = 30.0) -> Dict:
    """Wall-clock serving through the real HTTP front end: sustained
    req/s at a fixed p99 completion-latency SLO.

    Unlike the virtual-clock sections above, this measures the whole
    serving stack end to end — asyncio HTTP, SSE-free JSON completions,
    the fleet driver thread, and the async pipelined engine — with a
    closed-loop client pool hammering ``POST /v1/completions``.  The
    p99 bound is deliberately loose (CI boxes vary); the hard gates are
    that every request finishes and the SLO holds at the achieved rate.
    """
    import asyncio
    import http.client
    import json as _json
    import threading

    from repro.serving.frontend import ServingFrontend

    n_requests = 8 if quick else 24
    concurrency = 4 if quick else 6
    max_tokens = 8 if quick else 12
    ecfg = dataclasses.replace(_ecfg(workdir), overlap=True)
    fleet = build_fleet(_cfg(), ecfg, instances=2)
    fe = ServingFrontend(fleet, port=0)
    loop = asyncio.new_event_loop()
    started = threading.Event()

    def _serve():
        asyncio.set_event_loop(loop)
        loop.run_until_complete(fe.start())
        started.set()
        loop.run_forever()

    th = threading.Thread(target=_serve, daemon=True)
    th.start()
    assert started.wait(120), "front end failed to start"
    rng = np.random.default_rng(11)
    prompts = [list(map(int, rng.integers(0, _cfg().vocab_size, 10)))
               for _ in range(n_requests + concurrency)]

    def one(prompt: List[int]) -> float:
        conn = http.client.HTTPConnection("127.0.0.1", fe.port,
                                          timeout=600)
        try:
            t0 = time.perf_counter()
            conn.request("POST", "/v1/completions",
                         body=_json.dumps({
                             "prompt": prompt, "max_tokens": max_tokens,
                             "eos_token": None}),
                         headers={"Content-Type": "application/json"})
            resp = conn.getresponse()
            body = _json.loads(resp.read())
            assert resp.status == 200, body
            toks = body["choices"][0]["tokens"]
            assert len(toks) == max_tokens, (len(toks), body)
            return time.perf_counter() - t0
        finally:
            conn.close()

    # warm the compile caches + http path off the clock
    one(prompts[-1])
    lats: List[float] = []
    lock = threading.Lock()
    queue = list(range(n_requests))

    def worker():
        while True:
            with lock:
                if not queue:
                    return
                i = queue.pop()
            dt = one(prompts[i])
            with lock:
                lats.append(dt)

    t0 = time.perf_counter()
    workers = [threading.Thread(target=worker)
               for _ in range(concurrency)]
    for w in workers:
        w.start()
    for w in workers:
        w.join()
    wall = time.perf_counter() - t0
    gaps = [inst.engine.host_gap_fraction()
            for inst in fleet.instances.values()
            if inst.state.value != "dead"]
    asyncio.run_coroutine_threadsafe(fe.stop(), loop).result(timeout=30)
    loop.call_soon_threadsafe(loop.stop)
    th.join(timeout=10)
    assert len(lats) == n_requests
    p99 = _percentile(lats, 99)
    out = {
        "n": n_requests, "concurrency": concurrency,
        "max_tokens": max_tokens,
        "wall_s": round(wall, 3),
        "req_per_s": round(n_requests / wall, 3),
        "tokens_per_s": round(n_requests * max_tokens / wall, 2),
        "p50_latency_s": _percentile(lats, 50),
        "p99_latency_s": p99,
        "slo_s": slo_s,
        "p99_within_slo": bool(p99 <= slo_s),
        "host_gap_fraction": round(float(np.mean(gaps)), 4),
    }
    assert out["p99_within_slo"], out
    return out


def run(quick: bool = False) -> Dict:
    n_requests = 24 if quick else 48
    rate = 60.0          # open-loop: arrivals do not wait for recovery
    workdir = tempfile.mkdtemp(prefix="bench_fleet_slo_")
    out: Dict = {"unix_time": time.time(), "quick": quick,
                 "n_requests": n_requests, "rate_per_s": rate,
                 "policies": {}}
    # warmup: populate the shared on-disk compile cache + checkpoint so
    # the first measured fleet isn't charged for cold compiles
    _run_fleet(workdir, None, 2, rate)
    base = _run_fleet(workdir, None, n_requests, rate)
    out["baseline"] = base
    for policy in ("revive", "restart", "spare"):
        res = _run_fleet(workdir, policy, n_requests, rate)
        res["p99_degradation_s"] = round(
            res["p99_ttft_s"] - base["p99_ttft_s"], 4)
        res["p50_degradation_s"] = round(
            res["p50_ttft_s"] - base["p50_ttft_s"], 4)
        out["policies"][policy] = res
    out["revive_beats_restart"] = bool(
        out["policies"]["revive"]["p99_degradation_s"]
        < out["policies"]["restart"]["p99_degradation_s"])
    # compound failures: arbiter free, one warm spare available
    out["compound"] = {}
    for name, faults in COMPOUND_TRACES.items():
        res = _run_fleet(workdir, None, n_requests, rate,
                         faults=faults, spares=1)
        res["p99_degradation_s"] = round(
            res["p99_ttft_s"] - base["p99_ttft_s"], 4)
        res["all_finished"] = bool(res["finished"] == res["n"])
        out["compound"][name] = res
    out["prefix_sweep"] = prefix_sweep(
        tempfile.mkdtemp(prefix="bench_prefix_sweep_"), quick=quick)
    out["admission"] = admission_bench(
        tempfile.mkdtemp(prefix="bench_admission_"), quick=quick)
    out["frontend"] = frontend_bench(
        tempfile.mkdtemp(prefix="bench_frontend_"), quick=quick)
    return out


def save_json(out: Dict, path: str = BENCH_PATH) -> None:
    from benchmarks.trajectory import append_record
    slim = {k: v for k, v in out.items()}
    # the per-tick timelines are large; keep a downsampled copy
    slim["policies"] = {}
    for name, res in out["policies"].items():
        res = dict(res)
        tl = res.pop("goodput_timeline")
        res["goodput_timeline"] = tl[::max(1, len(tl) // 48)]
        slim["policies"][name] = res
    base = dict(slim["baseline"] if "baseline" in out else {})
    base.pop("goodput_timeline", None)
    slim["baseline"] = base
    if "compound" in out:
        slim["compound"] = {}
        for name, res in out["compound"].items():
            res = dict(res)
            res.pop("goodput_timeline", None)
            slim["compound"][name] = res
    append_record(path, slim)


def print_table(out: Dict) -> None:
    print("\n# Fleet SLO: recovery policy vs p50/p99 TTFT "
          "(same fault, same arrival trace)")
    base = out["baseline"]
    print(f"  open-loop Poisson {out['rate_per_s']:.0f} req/s, "
          f"{out['n_requests']} requests, 3 instances")
    print(f"  {'policy':10s} {'done':>7s} {'p50 TTFT':>10s} "
          f"{'p99 TTFT':>10s} {'p99 degr.':>10s} {'makespan':>9s}")
    print(f"  {'no-fault':10s} {base['finished']:3d}/{base['n']:<3d} "
          f"{base['p50_ttft_s'] * 1e3:8.0f}ms "
          f"{base['p99_ttft_s'] * 1e3:8.0f}ms {'—':>10s} "
          f"{base['virtual_makespan_s']:7.2f}s")
    for name, res in out["policies"].items():
        print(f"  {name:10s} {res['finished']:3d}/{res['n']:<3d} "
              f"{res['p50_ttft_s'] * 1e3:8.0f}ms "
              f"{res['p99_ttft_s'] * 1e3:8.0f}ms "
              f"{res['p99_degradation_s'] * 1e3:8.0f}ms "
              f"{res['virtual_makespan_s']:7.2f}s")
    verdict = "yes" if out["revive_beats_restart"] else "NO (!)"
    print(f"  revive beats restart on p99 TTFT degradation: {verdict}")
    for name, res in out["policies"].items():
        for line in res["arbiter_log"]:
            print(f"    [{name}] {line}")
    if "compound" in out:
        print("\n# Compound failures (arbiter free, 1 warm spare)")
        for name, res in out["compound"].items():
            print(f"  {name:26s} {res['finished']:3d}/{res['n']:<3d} "
                  f"p99 degr {res['p99_degradation_s'] * 1e3:7.0f}ms")
            for line in res["arbiter_log"]:
                print(f"    {line}")
    if "prefix_sweep" in out:
        sw = out["prefix_sweep"]
        print("\n# Migration cost vs prefix length "
              "(KV-block stream vs re-prefill, token-exact both ways)")
        print(f"  {'prefix':>7s} {'stream':>10s} {'re-prefill':>11s}")
        for pt in sw["points"]:
            print(f"  {pt['prefix']:7d} {pt['stream_s'] * 1e3:8.1f}ms "
                  f"{pt['replay_s'] * 1e3:9.1f}ms")
        flag = "yes" if sw["stream_flat_vs_replay_linear"] else "NO (!)"
        print(f"  stream ~flat while re-prefill grows with prefix: {flag}")
    if "admission" in out:
        adm = out["admission"]
        print("\n# Admission pipeline: chunked token-budget + prefix "
              "cache vs one-prefill-per-step\n"
              f"  mixed 8/40-token prompts, "
              f"{adm['shared_fraction'] * 100:.0f}% shared system prompt, "
              f"{adm['n_requests']} requests @ {adm['rate_per_s']:.0f}/s")
        print(f"  {'mode':10s} {'done':>7s} {'p50 TTFT':>10s} "
              f"{'p99 TTFT':>10s} {'prefill tok':>12s} {'cached':>8s}")
        for name, res in adm["modes"].items():
            print(f"  {name:10s} {res['finished']:3d}/{res['n']:<3d} "
                  f"{res['p50_ttft_s'] * 1e3:8.0f}ms "
                  f"{res['p99_ttft_s'] * 1e3:8.0f}ms "
                  f"{res['prefill_tokens_computed']:12d} "
                  f"{res['prefill_tokens_cached']:8d}")
        verdict = "yes" if adm["chunked_beats_serial_p99"] else "NO (!)"
        print(f"  chunked admission beats serial on p99 TTFT: {verdict} "
              f"({adm['p99_ttft_improvement_s'] * 1e3:+.0f}ms, "
              f"{adm['prefill_tokens_saved']} prefill tokens saved)")
    if "frontend" in out:
        fr = out["frontend"]
        print("\n# HTTP front end, wall clock (closed loop, async "
              "pipelined engine)")
        print(f"  {fr['n']} requests x {fr['max_tokens']} tokens @ "
              f"concurrency {fr['concurrency']}: "
              f"{fr['req_per_s']:.2f} req/s "
              f"({fr['tokens_per_s']:.1f} tok/s) in {fr['wall_s']:.1f}s")
        ok = "yes" if fr["p99_within_slo"] else "NO (!)"
        print(f"  p50 {fr['p50_latency_s'] * 1e3:.0f}ms  "
              f"p99 {fr['p99_latency_s'] * 1e3:.0f}ms  "
              f"(SLO {fr['slo_s']:.0f}s: {ok})  "
              f"host gap {fr['host_gap_fraction'] * 100:.1f}%")


if __name__ == "__main__":
    import sys
    out = run(quick="--quick" in sys.argv[1:])
    print_table(out)
    save_json(out)
    print(f"\nappended to {BENCH_PATH}")
