"""Fleet-level Figure-5 analogue: recovery policy vs client SLO.

Three fleets serve the *same* Poisson arrival trace and take the *same*
injected MoE device fault on instance 0; the only difference is the
recovery policy the arbiter is forced to use:

* ``revive``  — ReviveMoE in-place recovery (paper's contribution),
* ``restart`` — drain-and-restart of the wounded instance (baseline),
* ``spare``   — live migration onto a pre-warmed standby (FailSafe-style).

A no-fault run provides the TTFT reference.  The figure of merit is p99
TTFT *degradation* vs that baseline: restart stalls every request parked
on the instance for a full relaunch, revive stalls them for a mostly
precompiled recovery pipeline, spare pays one cross-instance re-prefill
per in-flight request.  Goodput timelines (tokens delivered per virtual
interval) show the same story over time.

Every run appends to ``BENCH_fleet_slo.json`` via benchmarks.trajectory.
"""
from __future__ import annotations

import dataclasses
import os
import tempfile
import time
from typing import Dict, List, Optional

import numpy as np

from repro.configs import get_smoke_config
from repro.core.fault_codes import ErrorType, Severity
from repro.fleet import PoissonTraffic, build_fleet
from repro.serving.engine import EngineConfig

BENCH_PATH = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_fleet_slo.json")

FAULT_STEP = 10         # engine step on instance 0 (mid-step MoE loss)
FAULT_PID = 3           # second MoE executor (pid = num_dp + 1)


def _cfg():
    cfg = get_smoke_config("qwen2-moe-a2.7b")
    # fully provisioned redundancy (§3.4's common case): the injected
    # fault is covered by replica slots, so revive is the pure
    # map-update + precompiled-graph path — no role switch, no capacity
    # loss.  Restart/spare handle the *same* covered fault, so the
    # comparison isolates the recovery mechanism itself.
    return dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, num_experts=4,
                                     num_redundant_experts=4, top_k=2))


def _ecfg(workdir: str) -> EngineConfig:
    return EngineConfig(mode="disaggregated", num_dp=2, num_moe=2,
                        max_batch=2, max_seq=64, block_size=8,
                        num_blocks=96, workdir=workdir)


def _percentile(xs: List[float], q: float) -> float:
    return float(np.percentile(np.asarray(xs), q)) if xs else 0.0


def _run_fleet(workdir: str, policy: Optional[str], n_requests: int,
               rate: float) -> Dict:
    """One fleet, one arrival trace, optionally one injected fault."""
    traffic = PoissonTraffic(rate, _cfg().vocab_size, prompt_len=8,
                             max_new_tokens=12, seed=11,
                             limit=n_requests)
    fleet = build_fleet(_cfg(), _ecfg(workdir), instances=3,
                        spares=(1 if policy == "spare" else 0),
                        force_policy=policy, traffic=traffic)
    if policy is not None:
        fleet.instances[0].engine.injector.schedule(
            FAULT_STEP, FAULT_PID, severity=Severity.L6,
            error_type=ErrorType.HBM_ECC, component="moe", mid_step=True)
    timeline: List[Dict] = []
    prev_tokens = 0
    t_wall = time.perf_counter()
    for _ in range(4000):
        fleet.tick()
        tokens = sum(len(r.output_tokens) for r in fleet.requests)
        timeline.append({"t_s": round(fleet.now_s, 4),
                         "new_tokens": tokens - prev_tokens})
        prev_tokens = tokens
        if traffic.exhausted and fleet.requests and not fleet.unfinished:
            break
    ttfts = fleet.ttfts()
    stall = max((b["t_s"] - a["t_s"] for a, b in
                 zip(timeline, timeline[1:])), default=0.0)
    return {
        "finished": len(fleet.requests) - fleet.unfinished,
        "n": len(fleet.requests),
        "p50_ttft_s": _percentile(ttfts, 50),
        "p99_ttft_s": _percentile(ttfts, 99),
        "virtual_makespan_s": round(fleet.now_s, 3),
        "wall_s": round(time.perf_counter() - t_wall, 3),
        "worst_tick_gap_s": round(stall, 4),
        "goodput_timeline": timeline,
        "arbiter_log": [d.summary() for d in fleet.arbiter.decisions],
    }


def run(quick: bool = False) -> Dict:
    n_requests = 24 if quick else 48
    rate = 60.0          # open-loop: arrivals do not wait for recovery
    workdir = tempfile.mkdtemp(prefix="bench_fleet_slo_")
    out: Dict = {"unix_time": time.time(), "quick": quick,
                 "n_requests": n_requests, "rate_per_s": rate,
                 "policies": {}}
    # warmup: populate the shared on-disk compile cache + checkpoint so
    # the first measured fleet isn't charged for cold compiles
    _run_fleet(workdir, None, 2, rate)
    base = _run_fleet(workdir, None, n_requests, rate)
    out["baseline"] = base
    for policy in ("revive", "restart", "spare"):
        res = _run_fleet(workdir, policy, n_requests, rate)
        res["p99_degradation_s"] = round(
            res["p99_ttft_s"] - base["p99_ttft_s"], 4)
        res["p50_degradation_s"] = round(
            res["p50_ttft_s"] - base["p50_ttft_s"], 4)
        out["policies"][policy] = res
    out["revive_beats_restart"] = bool(
        out["policies"]["revive"]["p99_degradation_s"]
        < out["policies"]["restart"]["p99_degradation_s"])
    return out


def save_json(out: Dict, path: str = BENCH_PATH) -> None:
    from benchmarks.trajectory import append_record
    slim = {k: v for k, v in out.items()}
    # the per-tick timelines are large; keep a downsampled copy
    slim["policies"] = {}
    for name, res in out["policies"].items():
        res = dict(res)
        tl = res.pop("goodput_timeline")
        res["goodput_timeline"] = tl[::max(1, len(tl) // 48)]
        slim["policies"][name] = res
    base = dict(slim["baseline"] if "baseline" in out else {})
    base.pop("goodput_timeline", None)
    slim["baseline"] = base
    append_record(path, slim)


def print_table(out: Dict) -> None:
    print("\n# Fleet SLO: recovery policy vs p50/p99 TTFT "
          "(same fault, same arrival trace)")
    base = out["baseline"]
    print(f"  open-loop Poisson {out['rate_per_s']:.0f} req/s, "
          f"{out['n_requests']} requests, 3 instances")
    print(f"  {'policy':10s} {'done':>7s} {'p50 TTFT':>10s} "
          f"{'p99 TTFT':>10s} {'p99 degr.':>10s} {'makespan':>9s}")
    print(f"  {'no-fault':10s} {base['finished']:3d}/{base['n']:<3d} "
          f"{base['p50_ttft_s'] * 1e3:8.0f}ms "
          f"{base['p99_ttft_s'] * 1e3:8.0f}ms {'—':>10s} "
          f"{base['virtual_makespan_s']:7.2f}s")
    for name, res in out["policies"].items():
        print(f"  {name:10s} {res['finished']:3d}/{res['n']:<3d} "
              f"{res['p50_ttft_s'] * 1e3:8.0f}ms "
              f"{res['p99_ttft_s'] * 1e3:8.0f}ms "
              f"{res['p99_degradation_s'] * 1e3:8.0f}ms "
              f"{res['virtual_makespan_s']:7.2f}s")
    verdict = "yes" if out["revive_beats_restart"] else "NO (!)"
    print(f"  revive beats restart on p99 TTFT degradation: {verdict}")
    for name, res in out["policies"].items():
        for line in res["arbiter_log"]:
            print(f"    [{name}] {line}")


if __name__ == "__main__":
    out = run()
    print_table(out)
    save_json(out)
    print(f"\nappended to {BENCH_PATH}")
