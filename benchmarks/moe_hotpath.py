"""MoE steady-state hot-path benchmark: dense-scatter vs fused pipeline,
plus the decode-step megakernel vs the composed kernel chain.

ReviveMoE's recovery races against the per-step MoE latency (§3.4 keeps
the compiled MoE graph alive across failures precisely so the steady
state stays fast), so this benchmark tracks the one number every future
kernel PR has to beat: time per MoE layer application for decode- and
prefill-shaped batches.

Two sections:

  * **MoE layer** — ``dense`` (``moe.dispatch_compute_combine``: argsort
    + scatter into an (E, cap, D) capacity buffer, batched einsum FFN,
    gather + unsort) vs ``fused`` (``ops.moe_dispatch_ffn_combine``: one
    sort pass to slot tables, then gather -> grouped SwiGLU ->
    scatter-combine in a single kernel).
  * **Decode step** — the ``composed`` chain one attention+MoE block
    runs per decode step (paged attention -> output projection ->
    residual -> norm -> router top-k -> replica select -> fused MoE)
    vs ``ops.decode_megastep``, which fuses the whole chain into one
    kernel launch.  On CPU both sides are jnp (one XLA jit each), so
    the numbers measure op-boundary overhead only; on TPU the megastep
    replaces a multi-kernel chain with one ``pallas_call``.

Every row carries ``metric_us`` — the number the CI trajectory gate
(``benchmarks/trajectory.py check``) compares against the best prior
record.  Results append to ``BENCH_moe_hotpath.json`` at the repo root —
machine-readable so later PRs diff against the trajectory.
"""
from __future__ import annotations

import os
import time
from typing import Dict, List

ROOT = os.path.normpath(os.path.join(os.path.dirname(__file__), ".."))
BENCH_PATH = os.path.join(ROOT, "BENCH_moe_hotpath.json")

# (name, kind, T, E_local, top_k, D, F) — CPU-sized; on TPU scale these
# up to serving shapes (decode_32k: T=128, kimi: E=384/ep, D=7168).
SWEEP = [
    ("decode_b8", "decode", 8, 8, 2, 256, 512),
    ("decode_b32", "decode", 32, 16, 2, 256, 512),
    ("decode_b128", "decode", 128, 32, 4, 256, 512),
    ("prefill_1k", "prefill", 1024, 8, 2, 256, 512),
    ("prefill_2k", "prefill", 2048, 16, 2, 256, 512),
]

# (name, B, max_blk, block_size, H, Hkv, Dh, E, top_k, D, F, Fs) — one
# attention+MoE block at decode shapes (CPU-sized; see SWEEP note).
# Fs > 0 adds the shared-expert SwiGLU the megakernel folds in-kernel.
# ``megastep_deploy`` is the deployment-shape row: deepseek_v3-class
# d_model=7168, where the D-blocked megakernel pages weights through
# VMEM instead of resident tiles (on CPU both sides are jnp, so the row
# tracks op-boundary overhead at real hidden sizes).
DECODE_STEP_SWEEP = [
    ("megastep_b8", 8, 8, 16, 8, 2, 64, 8, 2, 256, 512, 0),
    ("megastep_b32", 32, 8, 16, 8, 2, 64, 16, 2, 256, 512, 0),
    ("megastep_b128", 128, 16, 16, 8, 2, 64, 32, 4, 256, 512, 0),
    ("megastep_deploy", 8, 8, 16, 16, 2, 64, 8, 2, 7168, 512, 512),
]


def _time_fn(fn, *args, iters: int = 5, warmup: int = 2) -> float:
    import jax
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def run(quick: bool = False, use_pallas: bool = None,
        iters: int = 5) -> List[Dict]:
    """``iters``: timing repetitions per shape (best-of).  The CI gate
    passes a higher count — on small shared machines the best-of
    converges to the true minimum despite scheduling noise."""
    import jax
    import jax.numpy as jnp
    from repro.kernels import ops
    from repro.models.moe import capacity, dispatch_compute_combine

    if use_pallas is None:
        # interpret-mode Pallas is a correctness tool, not a benchmark;
        # CPU numbers compare the two jnp formulations instead
        use_pallas = jax.default_backend() not in ("cpu",)

    sweep = SWEEP[:3] if quick else SWEEP
    dense = jax.jit(dispatch_compute_combine,
                    static_argnames=("cap", "e_local"))
    rows = []
    for name, kind, T, E, k, D, F in sweep:
        ks = jax.random.split(jax.random.fold_in(
            jax.random.PRNGKey(7), T * E), 7)
        x = jax.random.normal(ks[0], (T, D)) * 0.1
        g = jax.random.normal(ks[1], (E, D, F)) * 0.05
        u = jax.random.normal(ks[2], (E, D, F)) * 0.05
        d = jax.random.normal(ks[3], (E, F, D)) * 0.05
        phys = jax.random.randint(ks[4], (T, k), 0, E)
        w = jax.nn.softmax(jax.random.normal(ks[5], (T, k)), -1)
        alive = jnp.ones((T, k), bool)
        cap = capacity(T * k, E, 1.25)
        off = jnp.int32(0)

        t_dense = _time_fn(
            lambda: dense(x, w, phys, alive, g, u, d, cap=cap,
                          expert_offset=off, e_local=E), iters=iters)
        t_fused = _time_fn(
            lambda: ops.moe_dispatch_ffn_combine(
                x, g, u, d, w, phys, alive, off, cap=cap, e_local=E,
                use_pallas=use_pallas), iters=iters)
        rows.append({
            "name": name, "kind": kind, "T": T, "E": E, "top_k": k,
            "D": D, "F": F, "cap": cap,
            "dense_us": t_dense * 1e6, "fused_us": t_fused * 1e6,
            "metric_us": t_fused * 1e6,
            "speedup": t_dense / max(t_fused, 1e-12),
            "backend": jax.default_backend(), "use_pallas": use_pallas,
        })
    rows.extend(run_decode_step(quick=quick, use_pallas=use_pallas,
                                iters=iters))
    rows.extend(run_spec_decode(quick=quick, iters=iters))
    rows.extend(run_engine_overlap(quick=quick, iters=iters))
    return rows


def run_decode_step(quick: bool = False, use_pallas: bool = None,
                    iters: int = 5) -> List[Dict]:
    """Decode-step section: composed attention->router->MoE chain vs the
    fused ``ops.decode_megastep`` (both jit'd whole, so on CPU the
    comparison isolates op-boundary overhead; on TPU it is one
    ``pallas_call`` vs the kernel chain)."""
    import functools

    import jax
    import jax.numpy as jnp
    from repro.kernels import ops
    from repro.models.moe import capacity

    if use_pallas is None:
        use_pallas = jax.default_backend() not in ("cpu",)
    # quick keeps the smallest shape plus the deployment-shape row (the
    # one the D-blocking work exists for), so CI gates both
    sweep = ([DECODE_STEP_SWEEP[0], DECODE_STEP_SWEEP[-1]] if quick
             else DECODE_STEP_SWEEP)
    rows = []
    for name, B, max_blk, bs, H, Hkv, Dh, E, k, D, F, Fs in sweep:
        nb = max_blk * B + 1
        ks = jax.random.split(jax.random.fold_in(
            jax.random.PRNGKey(11), B * E), 14)
        q = jax.random.normal(ks[0], (B, H, Dh)) * 0.3
        k_pool = jax.random.normal(ks[1], (nb, bs, Hkv, Dh)) * 0.3
        v_pool = jax.random.normal(ks[2], (nb, bs, Hkv, Dh)) * 0.3
        bt = jax.random.randint(ks[3], (B, max_blk), 0, nb)
        sl = jax.random.randint(ks[4], (B,), 1, max_blk * bs + 1)
        st = jnp.zeros((B,), jnp.int32)
        x = jax.random.normal(ks[5], (B, D)) * 0.2
        w_post = jax.random.normal(ks[6], (H * Dh, D)) * 0.1
        ln2 = jnp.ones((D,))
        router_w = jax.random.normal(ks[7], (D, E)) * 0.2
        l2p = jnp.stack([jnp.arange(E, dtype=jnp.int32),
                         jnp.zeros((E,), jnp.int32)], axis=1)
        rcnt = jnp.ones((E,), jnp.int32)
        mask = jnp.ones((E,), bool)
        g = jax.random.normal(ks[8], (E, D, F)) * 0.05
        u = jax.random.normal(ks[9], (E, D, F)) * 0.05
        d = jax.random.normal(ks[10], (E, F, D)) * 0.05
        if Fs:
            sg = jax.random.normal(ks[11], (D, Fs)) * 0.05
            su = jax.random.normal(ks[12], (D, Fs)) * 0.05
            sd = jax.random.normal(ks[13], (Fs, D)) * 0.05
        else:
            sg = su = sd = None
        cap = capacity(B * k, E, 1.25)
        off = jnp.int32(0)

        @functools.partial(jax.jit, static_argnames=())
        def composed(q, k_pool, v_pool, bt, sl, st, x, w_post, ln2,
                     router_w, rcnt, l2p, mask, g, u, d, off):
            o = ops.paged_attention(q, k_pool, v_pool, bt, sl, st,
                                    use_pallas=use_pallas)
            x2 = x + o.reshape(B, -1).astype(x.dtype) @ w_post
            xf = x2.astype(jnp.float32)
            var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
            h2 = (xf * jax.lax.rsqrt(var + 1e-5)).astype(x2.dtype) * ln2
            logits = (h2 @ router_w).astype(jnp.float32)
            logits = jnp.where(mask[None, :], logits, -jnp.inf)
            gates = jax.nn.softmax(logits, axis=-1)
            w, sel = jax.lax.top_k(gates, k)
            w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
            count = jnp.maximum(rcnt[sel], 1)
            rep = (jnp.arange(B)[:, None] + jnp.arange(k)[None, :]) % count
            phys = jnp.take_along_axis(l2p[sel], rep[..., None],
                                       axis=-1)[..., 0]
            alive = rcnt[sel] > 0
            y = ops.moe_dispatch_ffn_combine(
                h2, g, u, d, w, phys.astype(jnp.int32), alive, off,
                cap=cap, e_local=E, use_pallas=use_pallas)
            out = x2 + y
            if Fs:
                # the separate shared-expert launch the megakernel folds
                out = out + (jax.nn.silu(h2 @ sg) * (h2 @ su)) @ sd
            return out

        args = (q, k_pool, v_pool, bt, sl, st, x, w_post, ln2, router_w,
                rcnt, l2p, mask, g, u, d, off)
        t_comp = _time_fn(lambda: composed(*args), iters=iters)
        t_mega = _time_fn(lambda: ops.decode_megastep(
            q, k_pool, v_pool, bt, sl, st, x, w_post, ln2, router_w,
            l2p, rcnt, mask, g, u, d, off, sg, su, sd,
            top_k=k, cap=cap, e_local=E,
            use_pallas=use_pallas)[0], iters=iters)
        rows.append({
            "name": name, "kind": "decode_step", "T": B, "E": E,
            "top_k": k, "D": D, "F": F, "cap": cap, "F_shared": Fs,
            "composed_us": t_comp * 1e6, "mega_us": t_mega * 1e6,
            "metric_us": t_mega * 1e6,
            "speedup": t_comp / max(t_mega, 1e-12),
            "backend": jax.default_backend(), "use_pallas": use_pallas,
        })
    return rows


def run_spec_decode(quick: bool = False, iters: int = 5) -> List[Dict]:
    """Speculative-decode efficiency row: a small collocated engine
    serves a repetitive trace with self-speculation on (windows ride
    the compiled chunk graph), and the row records microseconds per
    emitted token (the gate metric) next to accepted tokens per
    speculative step and the planned-window-width histogram — the
    speculation-efficiency surface, not just latency.

    Serve repetitions are capped at min(iters, 3): one engine serve is
    seconds-long, so best-of-12 timing would dominate the gate job; the
    cap is recorded in the row as ``serves``.
    """
    import shutil
    import tempfile

    import jax
    from repro.configs import get_smoke_config
    from repro.serving.engine import EngineConfig, InferenceEngine
    from repro.serving.sampling import SamplingParams

    cfg = get_smoke_config("qwen2-moe-a2.7b")
    workdir = tempfile.mkdtemp(prefix="bench_spec_decode_")
    ec = EngineConfig(mode="collocated", num_dp=1, max_batch=4,
                      max_seq=96, block_size=8, num_blocks=96,
                      workdir=workdir, spec_window=6,
                      sampling=SamplingParams(temperature=0.0, seed=3))
    eng = InferenceEngine(cfg, ec)
    # repetitive trace: the n-gram proposer drafts from recurrence, so
    # this measures the accept path, not the empty-proposal fallback
    prompts = [[5, 9, 2, 7] * 5, [3, 1] * 8, [4, 4, 8] * 6, [2, 6] * 9]

    def serve():
        reqs = [eng.submit(list(p), 24) for p in prompts]
        t0 = time.perf_counter()
        eng.run(max_steps=600)
        dt = time.perf_counter() - t0
        assert all(r.state.value == "finished" for r in reqs)
        return dt, sum(len(r.output_tokens) for r in reqs)

    serve()                          # warmup: compiles off the clock
    serves = 1 if quick else min(iters, 3)
    best_us = float("inf")
    for _ in range(serves):
        dt, toks = serve()
        best_us = min(best_us, dt / max(toks, 1) * 1e6)
    stats = eng.prefill_stats()
    hist = eng.spec_histogram()
    shutil.rmtree(workdir, ignore_errors=True)
    windows = max(stats["spec_windows"], 1)
    return [{
        "name": "spec_decode_greedy", "kind": "spec_decode",
        "T": len(prompts), "metric_us": best_us,
        "accepted_per_step": stats["spec_emitted"] / windows,
        "spec_windows": stats["spec_windows"],
        "spec_drafts": stats["spec_drafts"],
        "spec_accepted": stats["spec_accepted"],
        "spec_emitted": stats["spec_emitted"],
        "window_hist": {str(g): n for g, n in sorted(hist.items())},
        "serves": serves,
        "backend": jax.default_backend(),
        # the engine picks its kernels per-backend; tag the row like the
        # kernel rows so the gate's row filter keeps it comparable
        "use_pallas": jax.default_backend() not in ("cpu",),
    }]


def run_engine_overlap(quick: bool = False, iters: int = 5) -> List[Dict]:
    """Engine rows: lockstep vs the async pipelined engine on the same
    workload.  ``metric_us`` is wall-clock time per emitted token
    (engine TPOT); each row also records ``host_gap_fraction`` — the
    share of executor wall time the device sat idle waiting on host
    planning/sampling/readback — which is the number the overlap
    pipeline exists to reduce.  The two modes' token streams are
    asserted identical (overlap is a schedule change, not a sampling
    change).

    Serve repetitions are capped like the spec row (engine serves are
    seconds-long).
    """
    import shutil
    import tempfile

    import jax
    from repro.configs import get_smoke_config
    from repro.serving.engine import EngineConfig, InferenceEngine
    from repro.serving.sampling import SamplingParams

    cfg = get_smoke_config("qwen2-moe-a2.7b")
    prompts = [[5, 9, 2, 7] * 4, [3, 1, 6] * 5, [4, 8] * 7,
               [2, 6, 1, 9] * 3]
    rows = []
    streams: Dict[str, List[List[int]]] = {}
    # one workdir for both modes: the jax persistent-cache dir is
    # process-global, and sharing it lets overlap reuse lockstep's
    # compiled graphs (overlap adds only the predict epilogue)
    workdir = tempfile.mkdtemp(prefix="bench_engine_")
    for mode in ("lockstep", "overlap"):
        ec = EngineConfig(mode="collocated", num_dp=1, max_batch=4,
                          max_seq=96, block_size=8, num_blocks=96,
                          workdir=workdir, overlap=(mode == "overlap"),
                          sampling=SamplingParams(temperature=0.0,
                                                  seed=3))
        eng = InferenceEngine(cfg, ec)

        def serve():
            reqs = [eng.submit(list(p), 24) for p in prompts]
            t0 = time.perf_counter()
            eng.run(max_steps=800)
            dt = time.perf_counter() - t0
            assert all(r.state.value == "finished" for r in reqs)
            return (dt, sum(len(r.output_tokens) for r in reqs),
                    [list(r.output_tokens) for r in reqs])

        serve()                      # warmup: compiles off the clock
        eng.perf["wall_s"] = 0.0     # gap measured on warm serves only
        for ex in eng.dp_executors:
            ex.perf["device_busy_s"] = 0.0
        serves = 1 if quick else min(iters, 3)
        best_us = float("inf")
        toks = None
        for _ in range(serves):
            dt, n, toks = serve()
            best_us = min(best_us, dt / max(n, 1) * 1e6)
        streams[mode] = toks
        row = {
            "name": f"engine_{mode}", "kind": "engine",
            "T": len(prompts), "metric_us": best_us,
            "host_gap_fraction": round(eng.host_gap_fraction(), 4),
            "serves": serves,
            "backend": jax.default_backend(),
            "use_pallas": jax.default_backend() not in ("cpu",),
        }
        if mode == "overlap":
            row["overlap"] = eng.overlap_stats()
        rows.append(row)
    shutil.rmtree(workdir, ignore_errors=True)
    assert streams["lockstep"] == streams["overlap"], \
        "overlap engine diverged from lockstep token streams"
    return rows


def print_table(rows: List[Dict]) -> None:
    impl = "pallas" if rows and rows[0]["use_pallas"] else "jnp fallback"
    backend = rows[0]["backend"] if rows else "?"
    layer = [r for r in rows if "fused_us" in r]
    step = [r for r in rows if "mega_us" in r]
    spec = [r for r in rows if "accepted_per_step" in r]
    engine = [r for r in rows if r.get("kind") == "engine"]
    if layer:
        print(f"\n# MoE hot path: dense-scatter vs fused ({impl}, "
              f"backend={backend})")
        print(f"{'shape':12s} {'kind':8s} {'T':>6s} {'E':>4s} {'k':>3s} "
              f"{'cap':>5s} {'dense us':>10s} {'fused us':>10s} "
              f"{'speedup':>8s}")
        for r in layer:
            print(f"{r['name']:12s} {r['kind']:8s} {r['T']:6d} {r['E']:4d} "
                  f"{r['top_k']:3d} {r['cap']:5d} {r['dense_us']:10.0f} "
                  f"{r['fused_us']:10.0f} {r['speedup']:7.2f}x")
    if step:
        print(f"\n# Decode step: composed chain vs megakernel ({impl}, "
              f"backend={backend})")
        print(f"{'shape':12s} {'kind':11s} {'B':>6s} {'E':>4s} {'k':>3s} "
              f"{'cap':>5s} {'composed us':>12s} {'mega us':>10s} "
              f"{'speedup':>8s}")
        for r in step:
            print(f"{r['name']:12s} {r['kind']:11s} {r['T']:6d} "
                  f"{r['E']:4d} {r['top_k']:3d} {r['cap']:5d} "
                  f"{r['composed_us']:12.0f} {r['mega_us']:10.0f} "
                  f"{r['speedup']:7.2f}x")
    if spec:
        print(f"\n# Speculative decode (engine, greedy, "
              f"backend={backend})")
        print(f"{'shape':18s} {'us/token':>10s} {'acc/step':>9s} "
              f"{'windows':>8s} {'drafts':>7s} {'accepted':>9s} "
              f"{'window hist':>20s}")
        for r in spec:
            hist = ",".join(f"{g}:{n}" for g, n in
                            sorted(r["window_hist"].items()))
            print(f"{r['name']:18s} {r['metric_us']:10.0f} "
                  f"{r['accepted_per_step']:9.2f} "
                  f"{r['spec_windows']:8d} {r['spec_drafts']:7d} "
                  f"{r['spec_accepted']:9d} {hist:>20s}")
    if engine:
        print(f"\n# Engine: lockstep vs async pipelined "
              f"(token-identical, backend={backend})")
        print(f"{'mode':18s} {'us/token':>10s} {'host gap':>9s} "
              f"{'planned ahead':>14s} {'replans':>8s}")
        for r in engine:
            ov = r.get("overlap", {})
            pa = str(ov.get("planned_ahead", "—"))
            rp = str(ov.get("replans", "—"))
            print(f"{r['name']:18s} {r['metric_us']:10.0f} "
                  f"{r['host_gap_fraction']:8.1%} {pa:>14s} {rp:>8s}")


def save_json(rows: List[Dict], path: str = BENCH_PATH, *,
              quick: bool = False) -> None:
    """Append this run to the perf trajectory (list of run records).

    ``quick`` is recorded so reduced sweeps are never mistaken for the
    full-sweep records future PRs must beat.
    """
    from benchmarks.trajectory import append_record, machine_id
    append_record(path, {
        "benchmark": "moe_hotpath",
        "unix_time": time.time(),
        "quick": quick,
        "machine": machine_id(),
        "rows": rows,
    })


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--pallas", action="store_true",
                    help="force the Pallas kernel (interpret mode on CPU)")
    args = ap.parse_args()
    rs = run(quick=args.quick, use_pallas=True if args.pallas else None)
    print_table(rs)
    save_json(rs, quick=args.quick)
    print(f"\nappended to {BENCH_PATH}")
