"""MoE steady-state hot-path benchmark: dense-scatter vs fused pipeline.

ReviveMoE's recovery races against the per-step MoE latency (§3.4 keeps
the compiled MoE graph alive across failures precisely so the steady
state stays fast), so this benchmark tracks the one number every future
kernel PR has to beat: time per MoE layer application for decode- and
prefill-shaped batches.

Two implementations of the identical routing semantics are timed:

  * ``dense``  — ``moe.dispatch_compute_combine``: argsort + scatter into
    an (E, cap, D) capacity buffer, batched einsum FFN, gather + unsort.
  * ``fused``  — ``ops.moe_dispatch_ffn_combine``: one sort pass to slot
    tables, then gather -> grouped SwiGLU -> scatter-combine in a single
    kernel (Pallas on TPU; the gather-first jnp fallback on CPU).

Results append to ``BENCH_moe_hotpath.json`` at the repo root —
machine-readable so later PRs diff against the trajectory.
"""
from __future__ import annotations

import os
import time
from typing import Dict, List

ROOT = os.path.normpath(os.path.join(os.path.dirname(__file__), ".."))
BENCH_PATH = os.path.join(ROOT, "BENCH_moe_hotpath.json")

# (name, kind, T, E_local, top_k, D, F) — CPU-sized; on TPU scale these
# up to serving shapes (decode_32k: T=128, kimi: E=384/ep, D=7168).
SWEEP = [
    ("decode_b8", "decode", 8, 8, 2, 256, 512),
    ("decode_b32", "decode", 32, 16, 2, 256, 512),
    ("decode_b128", "decode", 128, 32, 4, 256, 512),
    ("prefill_1k", "prefill", 1024, 8, 2, 256, 512),
    ("prefill_2k", "prefill", 2048, 16, 2, 256, 512),
]


def _time_fn(fn, *args, iters: int = 5, warmup: int = 2) -> float:
    import jax
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def run(quick: bool = False, use_pallas: bool = None) -> List[Dict]:
    import jax
    import jax.numpy as jnp
    from repro.kernels import ops
    from repro.models.moe import capacity, dispatch_compute_combine

    if use_pallas is None:
        # interpret-mode Pallas is a correctness tool, not a benchmark;
        # CPU numbers compare the two jnp formulations instead
        use_pallas = jax.default_backend() not in ("cpu",)

    sweep = SWEEP[:3] if quick else SWEEP
    dense = jax.jit(dispatch_compute_combine,
                    static_argnames=("cap", "e_local"))
    rows = []
    for name, kind, T, E, k, D, F in sweep:
        ks = jax.random.split(jax.random.fold_in(
            jax.random.PRNGKey(7), T * E), 7)
        x = jax.random.normal(ks[0], (T, D)) * 0.1
        g = jax.random.normal(ks[1], (E, D, F)) * 0.05
        u = jax.random.normal(ks[2], (E, D, F)) * 0.05
        d = jax.random.normal(ks[3], (E, F, D)) * 0.05
        phys = jax.random.randint(ks[4], (T, k), 0, E)
        w = jax.nn.softmax(jax.random.normal(ks[5], (T, k)), -1)
        alive = jnp.ones((T, k), bool)
        cap = capacity(T * k, E, 1.25)
        off = jnp.int32(0)

        t_dense = _time_fn(
            lambda: dense(x, w, phys, alive, g, u, d, cap=cap,
                          expert_offset=off, e_local=E))
        t_fused = _time_fn(
            lambda: ops.moe_dispatch_ffn_combine(
                x, g, u, d, w, phys, alive, off, cap=cap, e_local=E,
                use_pallas=use_pallas))
        rows.append({
            "name": name, "kind": kind, "T": T, "E": E, "top_k": k,
            "D": D, "F": F, "cap": cap,
            "dense_us": t_dense * 1e6, "fused_us": t_fused * 1e6,
            "speedup": t_dense / max(t_fused, 1e-12),
            "backend": jax.default_backend(), "use_pallas": use_pallas,
        })
    return rows


def print_table(rows: List[Dict]) -> None:
    impl = "pallas" if rows and rows[0]["use_pallas"] else "jnp fallback"
    print(f"\n# MoE hot path: dense-scatter vs fused ({impl}, "
          f"backend={rows[0]['backend'] if rows else '?'})")
    print(f"{'shape':12s} {'kind':8s} {'T':>6s} {'E':>4s} {'k':>3s} "
          f"{'cap':>5s} {'dense us':>10s} {'fused us':>10s} {'speedup':>8s}")
    for r in rows:
        print(f"{r['name']:12s} {r['kind']:8s} {r['T']:6d} {r['E']:4d} "
              f"{r['top_k']:3d} {r['cap']:5d} {r['dense_us']:10.0f} "
              f"{r['fused_us']:10.0f} {r['speedup']:7.2f}x")


def save_json(rows: List[Dict], path: str = BENCH_PATH, *,
              quick: bool = False) -> None:
    """Append this run to the perf trajectory (list of run records).

    ``quick`` is recorded so reduced sweeps are never mistaken for the
    full-sweep records future PRs must beat.
    """
    from benchmarks.trajectory import append_record
    append_record(path, {
        "benchmark": "moe_hotpath",
        "unix_time": time.time(),
        "quick": quick,
        "rows": rows,
    })


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--pallas", action="store_true",
                    help="force the Pallas kernel (interpret mode on CPU)")
    args = ap.parse_args()
    rs = run(quick=args.quick, use_pallas=True if args.pallas else None)
    print_table(rs)
    save_json(rs, quick=args.quick)
    print(f"\nappended to {BENCH_PATH}")
