"""Deliverable (g): roofline table from the dry-run artifacts.

Reads results/dryrun/*.json (written by repro.launch.dryrun_all) and
prints, per (arch × shape) on the single-pod mesh: the three roofline
terms, the dominant bottleneck, MODEL_FLOPS/HLO_FLOPS, and memory per
device.  Multi-pod rows report lower+compile success + memory only.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "../results/dryrun")


def load_records(mesh: str = "16x16") -> List[Dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(RESULTS_DIR, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if r.get("mesh") == mesh and "error" not in r:
            recs.append(r)
    return recs


def run() -> List[Dict]:
    rows = []
    for r in load_records("16x16"):
        t = r["roofline"]
        gb = 1 << 30
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "kind": r["kind"],
            "compute_ms": t["compute_s"] * 1e3,
            "memory_ms": t["memory_s"] * 1e3,
            "collective_ms": t["collective_s"] * 1e3,
            "dominant": t["dominant"].replace("_s", ""),
            "useful_ratio": r["useful_flops_ratio"],
            "args_gib": r["memory"]["argument_bytes"] / gb,
            "temp_gib": r["memory"]["temp_bytes"] / gb,
            "compile_s": r["compile_s"],
        })
    return rows


def print_table(rows: List[Dict]) -> None:
    print("\n# Roofline (single-pod 16x16, per chip, per step) — "
          "197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s ICI")
    hdr = (f"{'arch':24s} {'shape':12s} {'compute':>10s} {'memory':>10s} "
           f"{'collective':>11s} {'dominant':>10s} {'useful':>7s} "
           f"{'args GiB':>9s} {'temp GiB':>9s}")
    print(hdr)
    for r in sorted(rows, key=lambda x: (x["arch"], x["shape"])):
        print(f"{r['arch']:24s} {r['shape']:12s} "
              f"{r['compute_ms']:9.2f}m {r['memory_ms']:9.2f}m "
              f"{r['collective_ms']:10.2f}m {r['dominant']:>10s} "
              f"{r['useful_ratio']:7.3f} {r['args_gib']:9.2f} "
              f"{r['temp_gib']:9.2f}")
    # multi-pod summary
    multi = load_records("2x16x16")
    print(f"\n# Multi-pod 2x16x16: {len(multi)}/40 combos lower+compile OK "
          f"(proof of the 'pod' axis sharding)")


if __name__ == "__main__":
    print_table(run())
