"""Figure 5 analogue: recovery time per scenario, split by Table-1 category.

Scenarios (as in the paper):
  baseline            cached full reinitialization (engine+executors+
                      weights+groups+compile rebuilt)
  disagg attn         MA-disaggregated, attention rank fails
  disagg moe+redundant  MoE rank fails, redundant experts cover
  disagg moe+missing    MoE rank fails, lost experts masked
  disagg moe+role_switch MoE rank fails, DP rank switched + disk reload
  colloc fail         MA-collocated device fails (attn+expert paths both)

Absolute seconds are laptop-scale; the *structure* — which categories a
scenario pays for — is the paper's claim and is what this reproduces.
"""
from __future__ import annotations

import dataclasses
import os
import tempfile
from typing import Dict, List, Optional

import numpy as np

from repro.configs import get_smoke_config
from repro.core.fault_codes import Severity
from repro.core.revive import CATEGORIES
from repro.core.weights import RecoveryPolicy
from repro.serving.engine import EngineConfig, InferenceEngine


def _cfg(redundant: int, experts: int = 16, top_k: int = 2):
    """Bench-scale MoE: big enough that weight I/O is material (the
    paper's role-switch case is dominated by the 40.6 s weight load)."""
    cfg = get_smoke_config("qwen2-moe-a2.7b")
    return dataclasses.replace(
        cfg,
        d_model=512, num_heads=8, num_kv_heads=4, head_dim=64,
        num_layers=4, vocab_size=8192,
        moe=dataclasses.replace(cfg.moe, num_experts=experts,
                                num_redundant_experts=redundant,
                                expert_d_ff=512,
                                num_shared_experts=1,
                                top_k=top_k))


def _run(cfg, ec, fault_pid, component, policy_desc) -> Dict:
    eng = InferenceEngine(cfg, ec)
    rng = np.random.default_rng(0)
    reqs = [eng.submit(list(rng.integers(0, cfg.vocab_size, 8)), 8)
            for _ in range(4)]
    eng.injector.schedule(3, fault_pid, severity=Severity.L6,
                          component=component, mid_step=True)
    eng.run(max_steps=150)
    assert eng.reports, "no recovery happened"
    rep = eng.reports[0]
    done = sum(r.state.value == "finished" for r in reqs)
    return {"scenario": policy_desc, "timings": dict(rep.timings),
            "total_s": rep.total_s, "compile_source": rep.compile_source,
            "finished": f"{done}/{len(reqs)}",
            "detail": rep.moe_plan.describe() if rep.moe_plan else "attn"}


def run(workdir: Optional[str] = None) -> List[Dict]:
    workdir = workdir or tempfile.mkdtemp(prefix="bench_recovery_")
    rows: List[Dict] = []

    def ec(mode, policy=RecoveryPolicy(), sub="x", num_dp=3, num_moe=2):
        return EngineConfig(mode=mode, num_dp=num_dp, num_moe=num_moe,
                            max_batch=2, max_seq=64, block_size=8,
                            num_blocks=64, policy=policy,
                            workdir=os.path.join(workdir, sub))

    # -- baseline: cached full reinit (Fig. 1 / Fig. 5 leftmost bar) -----
    cfg = _cfg(redundant=2)
    eng = InferenceEngine(cfg, ec("disaggregated", sub="base"))
    t = eng.full_reinit()
    rows.append({"scenario": "baseline_cached_reinit",
                 "timings": {k: v for k, v in t.items()
                             if k != "precompile_failure_scenarios"},
                 "total_s": sum(v for k, v in t.items()
                                if k != "precompile_failure_scenarios"),
                 "compile_source": "cached", "finished": "-",
                 "detail": "full instance reinit"})

    # -- disaggregated: attention failure --------------------------------
    rows.append(_run(_cfg(2), ec("disaggregated", sub="attn"),
                     fault_pid=1, component="attn", policy_desc="disagg_attn"))

    # -- disaggregated: MoE failure, redundant experts -------------------
    rows.append(_run(_cfg(redundant=16), ec("disaggregated", sub="red"),
                     fault_pid=3, component="moe",
                     policy_desc="disagg_moe_redundant"))

    # -- disaggregated: MoE failure, missing experts ----------------------
    rows.append(_run(
        _cfg(redundant=0),
        ec("disaggregated",
           policy=RecoveryPolicy(allow_role_switch=False,
                                 min_ep_for_missing=2), sub="miss"),
        fault_pid=3, component="moe", policy_desc="disagg_moe_missing"))

    # -- disaggregated: MoE failure, role switch (weights from disk) ------
    rows.append(_run(_cfg(redundant=0),
                     ec("disaggregated", sub="switch"),
                     fault_pid=3, component="moe",
                     policy_desc="disagg_moe_role_switch"))

    # -- collocated failure ------------------------------------------------
    rows.append(_run(_cfg(redundant=16),
                     ec("collocated",
                        policy=RecoveryPolicy(allow_role_switch=False),
                        sub="col", num_dp=2),
                     fault_pid=1, component="attn+moe",
                     policy_desc="colloc_fail"))
    return rows


def print_table(rows: List[Dict]) -> None:
    cats = [c for c in CATEGORIES]
    print("\n# Figure-5 analogue: recovery time by category (seconds)")
    header = f"{'scenario':28s}" + "".join(f"{c[:10]:>11s}" for c in cats) \
        + f"{'TOTAL':>9s}  source"
    print(header)
    base_total = None
    for r in rows:
        t = r["timings"]
        line = f"{r['scenario']:28s}" + "".join(
            f"{t.get(c, 0.0):11.3f}" for c in cats)
        line += f"{r['total_s']:9.3f}  {r['compile_source']}"
        print(line)
        if r["scenario"] == "baseline_cached_reinit":
            base_total = r["total_s"]
    if base_total:
        print("\n# reduction vs baseline (paper: 87.8% best case, "
              "36.6% worst/role-switch):")
        for r in rows[1:]:
            red = 100 * (1 - r["total_s"] / base_total)
            print(f"  {r['scenario']:28s} {red:6.1f}%   ({r['detail']})")


if __name__ == "__main__":
    print_table(run())
