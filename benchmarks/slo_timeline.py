"""Client-visible SLO impact: token-throughput timeline across a failure.

The paper's figure of merit is recovery time because it IS the service
downtime.  This benchmark shows it from the client side: tokens delivered
per wall-clock interval, with a mid-stream MoE failure — the stall equals
the recovery report's total, and throughput resumes at the pre-failure
rate (redundant-experts path: no quality loss either).
"""
from __future__ import annotations

import dataclasses
import tempfile
import time
from typing import Dict, List

import numpy as np

from repro.configs import get_smoke_config
from repro.core.fault_codes import Severity
from repro.serving.engine import EngineConfig, InferenceEngine


def run() -> Dict:
    cfg = get_smoke_config("qwen2-moe-a2.7b")
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, num_experts=4,
                                     num_redundant_experts=4, top_k=2))
    ec = EngineConfig(mode="disaggregated", num_dp=3, num_moe=2,
                      max_batch=4, max_seq=128, block_size=8,
                      num_blocks=256,
                      workdir=tempfile.mkdtemp(prefix="bench_slo_"))
    eng = InferenceEngine(cfg, ec)
    rng = np.random.default_rng(0)
    reqs = [eng.submit(list(rng.integers(0, cfg.vocab_size, 8)), 40)
            for _ in range(10)]
    eng.injector.schedule(12, 3, severity=Severity.L6, component="moe",
                          mid_step=True)

    timeline: List[Dict] = []
    t0 = time.perf_counter()
    prev_tokens = 0
    while eng.unfinished and eng.step_no < 400:
        eng.step()
        tokens = sum(len(r.output_tokens) for r in reqs)
        now = time.perf_counter() - t0
        timeline.append({"step": eng.step_no, "t_s": now,
                         "new_tokens": tokens - prev_tokens,
                         "total_tokens": tokens})
        prev_tokens = tokens

    stall = max((b["t_s"] - a["t_s"]
                 for a, b in zip(timeline, timeline[1:])), default=0.0)
    recovery_total = eng.reports[0].total_s if eng.reports else 0.0
    # steady-state per-step time before the failure
    pre = [b["t_s"] - a["t_s"] for a, b in zip(timeline[2:10],
                                               timeline[3:11])]
    post = [b["t_s"] - a["t_s"] for a, b in zip(timeline[-8:], timeline[-7:])]
    return {
        "timeline": timeline,
        "stall_s": stall,
        "recovery_total_s": recovery_total,
        "pre_step_s": float(np.median(pre)) if pre else 0.0,
        "post_step_s": float(np.median(post)) if post else 0.0,
        "finished": sum(r.state.value == "finished" for r in reqs),
        "n": len(reqs),
    }


def print_table(res: Dict) -> None:
    print("\n# SLO timeline: token throughput across a MoE failure")
    print(f"  requests finished: {res['finished']}/{res['n']}")
    print(f"  steady step time pre-failure : {res['pre_step_s'] * 1e3:.1f} ms")
    print(f"  steady step time post-recovery: "
          f"{res['post_step_s'] * 1e3:.1f} ms")
    print(f"  worst client-visible stall    : {res['stall_s'] * 1e3:.0f} ms")
    print(f"  recovery-report total         : "
          f"{res['recovery_total_s'] * 1e3:.0f} ms")
    bars = res["timeline"]
    step = max(1, len(bars) // 24)
    for row in bars[::step]:
        bar = "#" * min(40, row["new_tokens"])
        print(f"  t={row['t_s']:6.2f}s step={row['step']:3d} "
              f"+{row['new_tokens']:3d} {bar}")


if __name__ == "__main__":
    print_table(run())
