"""Chaos campaign benchmark: arbiter vs forced policies under a seeded
fleet-scale fault schedule, scored by SLO-burn.

Four fleets (same instances, same warm spare, same diurnal arrival
trace, same fault schedule) differ only in recovery policy: the
measurement-fed arbiter free to choose, vs forced revive-only /
restart-only / spare-only (an infeasible forced policy degrades to
restart deterministically).  The campaign layers correlated rack loss,
flapping links, cascading stragglers, a spot-preemption wave with
advance notice, unplanned host losses and a rolling upgrade onto the
trace; each fleet is scored by SLO-burn — the integral of windowed p99
TTFT/TPOT excess over target.

Everything runs on the pinned :class:`VirtualCostProfile` clock, so the
whole campaign — including the emitted failure-forensics JSON with its
per-event counterfactual cost table — is byte-reproducible from the
seed; CI's nightly determinism gate diffs two runs.

A second section exercises a small multi-model fleet (two configs
behind one router): a spot preemption takes the minority model's only
instance, forcing evict-and-rebalance of an over-provisioned peer.

Appends to ``BENCH_fleet_campaign.json``; forensics JSONs land next to
it as ``FORENSICS_campaign_<policy>.json``.
"""
from __future__ import annotations

import dataclasses
import json
import os
import tempfile
import time
from typing import Dict, Optional

from benchmarks.fleet_harness import fleet_cfg, fleet_ecfg
from repro.fleet import (CampaignRunner, CampaignSchedule, DiurnalTraffic,
                         MixedTraffic, PoissonTraffic, VirtualCostProfile,
                         build_fleet, build_multi_model_fleet,
                         fleet_topology)

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BENCH_PATH = os.path.join(_ROOT, "BENCH_fleet_campaign.json")

CAMPAIGN_SEED = 5
TRAFFIC_SEED = 11
# tight targets relative to the pinned 20ms virtual step: a healthy
# fleet serves well under them, while a 2.5s restart stall (or a
# permanently lost instance queueing its arrivals) burns visibly
TTFT_TARGET_S = 0.15
TPOT_TARGET_S = 0.08
SLO_WINDOW_S = 5.0
PROFILE = VirtualCostProfile()

POLICIES = (None, "revive", "restart", "spare")   # None = arbiter free


def _policy_name(policy: Optional[str]) -> str:
    return policy or "arbiter"


def _traffic(quick: bool):
    # base 2.0/s with these limits spans the whole campaign horizon
    # (~38s of 45s quick, ~118s of 120s full) so the fault processes
    # land on live traffic rather than an idle fleet
    # heavy-tailed (lognormal, median 8) request shapes: SLO burn is
    # scored against the occasional huge request queueing through a
    # recovery stall, not a uniform-shape fiction; clamps keep
    # prompt + output inside the instances' max_seq=64
    return DiurnalTraffic(
        2.0, fleet_cfg().vocab_size, amplitude=0.5, period_s=40.0,
        prompt_len=8, max_new_tokens=8, seed=TRAFFIC_SEED,
        length_dist="lognormal", length_sigma=0.75,
        max_prompt_len=32, max_output_len=24,
        limit=80 if quick else 240)


def _schedule(topo: Dict, quick: bool):
    horizon = 45.0 if quick else 120.0
    sched = (CampaignSchedule(CAMPAIGN_SEED, horizon)
             .device_faults(topo, rate_per_s=0.04)
             .rack_loss(topo, rate_per_s=0.008)
             .flapping_link(topo, start_s=6.0, n_flaps=2,
                            down_s=2.0, up_s=4.0)
             .cascading_stragglers(topo, start_s=14.0, spacing_s=4.0,
                                   n=2, slowdown=4.0, duration_s=3.0)
             .spot_wave(topo, at_s=horizon * 0.55, n_instances=1,
                        notice_s=4.0)
             .rolling_upgrade(topo, start_s=horizon * 0.75,
                              spacing_s=6.0))
    if not quick:
        sched.instance_loss(topo, rate_per_s=0.01)
    return sched.build()


def run_campaign(workdir: str, policy: Optional[str],
                 quick: bool) -> Dict:
    """One policy arm: same seeds, same schedule, same resources."""
    fleet = build_fleet(
        fleet_cfg(), fleet_ecfg(workdir), instances=3, spares=1,
        force_policy=policy, traffic=_traffic(quick),
        replenish_spares=True, cost_profile=PROFILE)
    events = _schedule(fleet_topology(fleet), quick)
    runner = CampaignRunner(
        fleet, events, seed=CAMPAIGN_SEED, profile=PROFILE,
        ttft_target_s=TTFT_TARGET_S, tpot_target_s=TPOT_TARGET_S,
        slo_window_s=SLO_WINDOW_S)
    t0 = time.perf_counter()
    res = runner.run()
    finished = len(fleet.requests) - fleet.unfinished
    return {
        "policy": _policy_name(policy),
        "slo_burn_s": res.burn["total_burn_s"],
        "ttft_burn_s": res.burn["ttft_burn_s"],
        "tpot_burn_s": res.burn["tpot_burn_s"],
        "n_unserved": res.burn["n_unserved"],
        "finished": finished,
        "n": len(fleet.requests),
        "events_applied": res.events_applied,
        "events_skipped": res.events_skipped,
        "recoveries_by_policy": res.forensics["recoveries_by_policy"],
        "virtual_makespan_s": round(fleet.now_s, 3),
        "wall_s": round(time.perf_counter() - t0, 3),
        "forensics": res.forensics,
    }


def counterfactual_table(forensics: Dict) -> list:
    """Per recovery event: what the arbiter chose, what it was charged,
    and what the untaken actions were priced at — the 'why' behind the
    arbiter beating every single forced policy."""
    table = []
    for ev in forensics["recoveries"]:
        if "decision" not in ev:
            continue
        table.append({
            "seq": ev["seq"], "now_s": ev["now_s"], "iid": ev["iid"],
            "chosen": ev["policy"], "charged_s": ev["charged_s"],
            "counterfactual_s": ev.get("counterfactual_s", {}),
            "reason": ev["decision"]["reason"],
        })
    return table


def multi_model_section(workdir: str, quick: bool) -> Dict:
    """Two model configs behind one router; a spot preemption takes the
    minority model's only instance (no matching spare), so serving it
    again *requires* evict-and-rebalance of a majority-model instance."""
    cfg = fleet_cfg()
    models = {
        "alpha": (cfg, fleet_ecfg(os.path.join(workdir, "alpha"))),
        "beta": (cfg, fleet_ecfg(os.path.join(workdir, "beta"))),
    }
    n = 8 if quick else 16
    traffic = MixedTraffic([
        PoissonTraffic(1.0, cfg.vocab_size, prompt_len=8,
                       max_new_tokens=6, seed=TRAFFIC_SEED,
                       limit=n, model_id="alpha"),
        PoissonTraffic(0.7, cfg.vocab_size, prompt_len=8,
                       max_new_tokens=6, seed=TRAFFIC_SEED + 1,
                       limit=n, model_id="beta"),
    ])
    fleet = build_multi_model_fleet(
        models, counts={"alpha": 2, "beta": 1}, traffic=traffic,
        cost_profile=PROFILE, rebalance=True)
    beta_iid = next(i.iid for i in fleet.serving()
                    if i.model_id == "beta")
    # give the trace time to put beta requests in flight, then preempt
    for _ in range(12):
        fleet.tick()
    fleet.drain_instance(beta_iid, migrate=True,
                         reason="spot preemption notice")
    fleet.lose_instance(beta_iid, reason="spot preemption",
                        rebuild=False)
    health_mid = fleet.fleet_health()
    fleet.run(max_ticks=3000)
    rebalances = [e for e in fleet.forensics
                  if e["policy"] == "rebalance"]
    finished = len(fleet.requests) - fleet.unfinished
    out = {
        "finished": finished, "n": len(fleet.requests),
        "health_after_preempt": health_mid.state,
        "rebalanced": len(rebalances),
        "rebalance_detail": [e["detail"] for e in rebalances],
        "beta_served_after_rebalance": any(
            i.model_id == "beta" and i.accepting
            for i in fleet.instances.values()),
    }
    assert out["rebalanced"] >= 1, \
        "losing the only beta instance must trigger evict-and-rebalance"
    assert out["beta_served_after_rebalance"], out
    assert finished == out["n"], out
    return out


def run(quick: bool = False) -> Dict:
    workdir = tempfile.mkdtemp(prefix="bench_fleet_campaign_")
    out: Dict = {
        "unix_time": time.time(), "quick": quick,
        "campaign_seed": CAMPAIGN_SEED, "traffic_seed": TRAFFIC_SEED,
        "profile": dataclasses.asdict(PROFILE),
        "ttft_target_s": TTFT_TARGET_S, "tpot_target_s": TPOT_TARGET_S,
        "slo_window_s": SLO_WINDOW_S, "policies": {},
    }
    # warmup: shared checkpoint + compile cache off the clock
    warm = build_fleet(fleet_cfg(), fleet_ecfg(workdir), instances=1,
                       traffic=PoissonTraffic(
                           2.0, fleet_cfg().vocab_size, prompt_len=8,
                           max_new_tokens=4, seed=3, limit=2))
    warm.run(max_ticks=300)
    for policy in POLICIES:
        out["policies"][_policy_name(policy)] = run_campaign(
            workdir, policy, quick)
    arb = out["policies"]["arbiter"]
    forced_burns = {p: out["policies"][p]["slo_burn_s"]
                    for p in ("revive", "restart", "spare")}
    best_forced = min(forced_burns, key=lambda p: forced_burns[p])
    out["forced_burns_s"] = forced_burns
    out["best_forced_policy"] = best_forced
    out["arbiter_burn_s"] = arb["slo_burn_s"]
    out["arbiter_beats_best_forced"] = bool(
        arb["slo_burn_s"] <= forced_burns[best_forced] + 1e-9)
    out["counterfactuals"] = counterfactual_table(arb["forensics"])
    out["multi_model"] = multi_model_section(
        os.path.join(workdir, "mm"), quick)
    # acceptance gate: the measurement-fed arbiter never burns more SLO
    # than the best single forced policy on the standard campaign
    assert out["arbiter_beats_best_forced"], {
        "arbiter": arb["slo_burn_s"], "forced": forced_burns}
    return out


def write_forensics(out: Dict, directory: str = _ROOT) -> Dict[str, str]:
    """One forensics JSON per policy arm, sorted keys + fixed separators
    so identical campaigns produce byte-identical files (the nightly
    determinism gate diffs these across two runs)."""
    paths = {}
    for name, res in out["policies"].items():
        path = os.path.join(directory, f"FORENSICS_campaign_{name}.json")
        with open(path, "w") as f:
            json.dump(res["forensics"], f, sort_keys=True, indent=1,
                      separators=(",", ": "))
            f.write("\n")
        paths[name] = path
    return paths


def save_json(out: Dict, path: str = BENCH_PATH) -> None:
    from benchmarks.trajectory import append_record
    slim = dict(out)
    slim["policies"] = {}
    for name, res in out["policies"].items():
        res = dict(res)
        res.pop("forensics", None)      # full document lives in its file
        slim["policies"][name] = res
    append_record(path, slim)


def print_table(out: Dict) -> None:
    print("\n# Chaos campaign: SLO-burn by recovery policy "
          f"(seed {out['campaign_seed']}, same schedule + trace)")
    print(f"  {'policy':10s} {'SLO-burn':>10s} {'TTFT':>9s} "
          f"{'TPOT':>9s} {'done':>8s} {'recoveries':>30s}")
    for name, res in out["policies"].items():
        recov = ",".join(f"{k}:{v}" for k, v in
                         sorted(res["recoveries_by_policy"].items()))
        print(f"  {name:10s} {res['slo_burn_s']:9.3f}s "
              f"{res['ttft_burn_s']:8.3f}s {res['tpot_burn_s']:8.3f}s "
              f"{res['finished']:3d}/{res['n']:<3d} {recov:>30s}")
    verdict = ("yes" if out["arbiter_beats_best_forced"] else "NO (!)")
    print(f"  arbiter <= best forced ({out['best_forced_policy']}, "
          f"{out['forced_burns_s'][out['best_forced_policy']]:.3f}s): "
          f"{verdict}")
    print("\n# Arbiter counterfactuals (chosen vs untaken prices)")
    for row in out["counterfactuals"]:
        alts = ", ".join(f"{k}={v:.3f}s" for k, v in
                         sorted(row["counterfactual_s"].items()))
        print(f"  t={row['now_s']:7.2f}s inst {row['iid']}: "
              f"{row['chosen']:8s} charged {row['charged_s']:.3f}s "
              f"vs [{alts}]")
    mm = out["multi_model"]
    print("\n# Multi-model fleet: forced evict-and-rebalance")
    print(f"  health after preempt: {mm['health_after_preempt']}, "
          f"rebalances: {mm['rebalanced']}, finished "
          f"{mm['finished']}/{mm['n']}")
    for d in mm["rebalance_detail"]:
        print(f"    {d}")


if __name__ == "__main__":
    import sys
    args = sys.argv[1:]
    out = run(quick="--quick" in args)
    print_table(out)
    save_json(out)
    fdir = _ROOT
    for i, a in enumerate(args):
        if a == "--forensics-dir" and i + 1 < len(args):
            fdir = args[i + 1]
    paths = write_forensics(out, fdir)
    print(f"\nappended to {BENCH_PATH}")
    for name, p in sorted(paths.items()):
        print(f"forensics[{name}] -> {p}")
