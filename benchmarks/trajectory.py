"""Shared helper for BENCH-style JSON perf-trajectory files.

A trajectory file is a JSON list of run records; every benchmark that
appends to one goes through :func:`append_record` so the on-disk shape
stays uniform across writers.
"""
from __future__ import annotations

import json
import os
from typing import Dict


def append_record(path: str, record: Dict) -> None:
    trajectory = []
    if os.path.exists(path):
        try:
            with open(path) as f:
                trajectory = json.load(f)
        except (json.JSONDecodeError, OSError):
            # a previously interrupted write left a truncated file; keep
            # it for forensics and start a fresh trajectory
            os.replace(path, path + ".corrupt")
            trajectory = []
    trajectory.append(record)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(trajectory, f, indent=1)
    os.replace(tmp, path)    # atomic: no torn trajectory on interrupt
