"""BENCH-style JSON perf-trajectory files: shared writer + the CI gate.

A trajectory file is a JSON list of run records; every benchmark that
appends to one goes through :func:`append_record` so the on-disk shape
stays uniform across writers.

The ``check`` subcommand is the enforcement mechanism behind the
ROADMAP's "future perf PRs must beat the latest record" sentence: it
runs a fresh ``--quick`` sweep of the named benchmark (best-of-12
timing), compares each row's ``metric_us`` against the prior-record
**bar** for the same shape — the median of comparable prior runs'
bests, matched on ``quick`` flag / backend / pallas mode (numbers from
a TPU run never gate a CPU run) and recorded **machine id** (wall-clock
microseconds are not comparable across machine classes, so a record
taken on a developer box never spuriously fails a slower CI runner) —
re-measures once if it looks like a regression (transient scheduling
stalls don't repeat; real regressions do), appends the fresh run to
the trajectory, and exits nonzero on regression beyond ``--tolerance``.
An empty (or never-matching) trajectory seeds a baseline and exits
zero, so the first run on a new machine is green instead of failing:

    python -m benchmarks.trajectory check --bench moe_hotpath \
        --tolerance 0.1

Wired into the ``perf-smoke`` CI job.
"""
from __future__ import annotations

import json
import os
from typing import Dict, List, Optional

_ROOT = os.path.normpath(os.path.join(os.path.dirname(__file__), ".."))


def machine_id() -> str:
    """Coarse machine *class* of the timing host, stored per run record
    so the gate only compares wall-clock numbers taken on comparable
    hardware.  Deliberately hostname-free: ephemeral CI runners of one
    pool (same OS/arch/core count) must match each other across runs —
    the perf-smoke job persists its own trajectory via actions/cache,
    so CI gates against CI history, never against a developer box."""
    import platform
    return (f"{platform.system()}/{platform.machine()}"
            f"/{os.cpu_count()}cpu")


def append_record(path: str, record: Dict) -> None:
    trajectory = load(path)
    trajectory.append(record)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(trajectory, f, indent=1)
    os.replace(tmp, path)    # atomic: no torn trajectory on interrupt


def load(path: str) -> List[Dict]:
    """The trajectory as a list of run records ([] when absent)."""
    if not os.path.exists(path):
        return []
    try:
        with open(path) as f:
            return json.load(f)
    except (json.JSONDecodeError, OSError):
        # a previously interrupted write left a truncated file; keep
        # it for forensics and start a fresh trajectory
        os.replace(path, path + ".corrupt")
        return []


def row_metric(row: Dict) -> Optional[float]:
    """The row's gate metric: explicit ``metric_us``, else the fused-
    pipeline time (rows written before the gate existed)."""
    if "metric_us" in row:
        return row["metric_us"]
    if "fused_us" in row:
        return row["fused_us"]
    return None


def bar_metrics(records: List[Dict], *, benchmark: str, quick: bool,
                backend: Optional[str] = None,
                use_pallas: Optional[bool] = None,
                machine: Optional[str] = None) -> Dict[str, float]:
    """Per-shape gate bar over comparable prior records: the **median**
    of each run's (already best-of) metric.

    Records are comparable when they ran the same benchmark with the
    same ``quick`` flag on the same recorded machine id (records
    predating the machine field are skipped — unattributable timings
    must not gate); rows additionally match on backend and pallas mode
    so cross-backend numbers never gate each other.  The median — not
    the all-time minimum — is deliberate: with run-to-run scheduling
    noise, gating against the minimum ratchets the bar down to the
    luckiest measurement ever seen and unchanged code eventually fails;
    the median of run bests is what the machine reproducibly does,
    which is the record a perf PR must beat.
    """
    import statistics
    vals: Dict[str, List[float]] = {}
    for rec in records:
        if rec.get("benchmark") != benchmark:
            continue
        if bool(rec.get("quick")) != quick:
            continue
        if machine is not None and rec.get("machine") != machine:
            continue
        for row in rec.get("rows", []):
            if backend is not None and row.get("backend") != backend:
                continue
            if (use_pallas is not None
                    and bool(row.get("use_pallas")) != use_pallas):
                continue
            m = row_metric(row)
            if m is None:
                continue
            vals.setdefault(row["name"], []).append(m)
    return {name: statistics.median(v) for name, v in vals.items()}


# gate-able benchmarks: name -> (module path, trajectory file)
GATED_BENCHES = {
    "moe_hotpath": ("benchmarks.moe_hotpath",
                    os.path.join(_ROOT, "BENCH_moe_hotpath.json")),
}


def check(benchmark: str = "moe_hotpath", tolerance: float = 0.1,
          path: Optional[str] = None, quick: bool = True) -> int:
    """Run the benchmark fresh, gate it against the trajectory, append.

    Returns the process exit code: 0 = no regression (or baseline
    seeded), 1 = at least one shape regressed beyond ``tolerance``.
    """
    import importlib
    if benchmark not in GATED_BENCHES:
        raise SystemExit(f"no trajectory gate for {benchmark!r}; "
                         f"gate-able: {sorted(GATED_BENCHES)}")
    modname, default_path = GATED_BENCHES[benchmark]
    mod = importlib.import_module(modname)
    path = path or default_path

    prior = load(path)
    # gate runs time harder than plain benchmark runs: best-of-12 so a
    # scheduling stall on a small shared runner cannot fake a regression
    rows = mod.run(quick=quick, iters=12)
    mod.print_table(rows)
    backend = rows[0]["backend"] if rows else None
    use_pallas = bool(rows[0]["use_pallas"]) if rows else None
    mach = machine_id()
    bar = bar_metrics(prior, benchmark=benchmark, quick=quick,
                      backend=backend, use_pallas=use_pallas,
                      machine=mach)

    for _retry in range(2):
        if not (bar and _gate_regressions(rows, bar, tolerance,
                                          quiet=True)):
            break
        # apparent regression: re-measure before failing — transient
        # scheduling stalls do not repeat across independent sweeps, a
        # real regression does; each row keeps its best sweep
        print("\n[trajectory] apparent regression: re-measuring to "
              "rule out a transient stall...")
        rerun = {r["name"]: r for r in mod.run(quick=quick, iters=12)}
        for row in rows:
            again = rerun.get(row["name"])
            m0, m1 = row_metric(row), row_metric(again or {})
            if m1 is not None and (m0 is None or m1 < m0):
                row.update(again)

    # the fresh run always extends the trajectory — a regressing run
    # is recorded too (the bar is a median over runs, so one bad or one
    # lucky record moves it only marginally)
    mod.save_json(rows, path, quick=quick)

    if not bar:
        print(f"\n[trajectory] no comparable prior record in {path} "
              f"(quick={quick}, backend={backend}, machine={mach}): "
              f"baseline seeded, gate green")
        return 0

    print(f"\n[trajectory] gate vs prior-record bar on {mach} "
          f"(median of run bests, tolerance {tolerance:.0%}):")
    regressions = _gate_regressions(rows, bar, tolerance)
    if regressions:
        print(f"\n[trajectory] FAIL: {len(regressions)} shape(s) "
              f"slower than the trajectory bar beyond "
              f"{tolerance:.0%}")
        return 1
    print("\n[trajectory] PASS: no regression vs the trajectory bar")
    return 0


def _gate_regressions(rows: List[Dict], bar: Dict[str, float],
                      tolerance: float, quiet: bool = False) -> List:
    regressions = []
    for row in rows:
        m = row_metric(row)
        name = row["name"]
        if m is None or name not in bar:
            if not quiet:
                print(f"  {name:16s} {'(new shape, seeds baseline)':>32s}")
            continue
        ratio = m / max(bar[name], 1e-12)
        status = "ok" if ratio <= 1.0 + tolerance else "REGRESSION"
        if not quiet:
            print(f"  {name:16s} {m:10.0f} us vs bar "
                  f"{bar[name]:10.0f} us ({ratio:5.2f}x)  {status}")
        if ratio > 1.0 + tolerance:
            regressions.append((name, m, bar[name], ratio))
    return regressions


def main(argv=None) -> int:
    import argparse
    ap = argparse.ArgumentParser(
        description="perf-trajectory tools (BENCH_*.json)")
    sub = ap.add_subparsers(dest="cmd", required=True)
    chk = sub.add_parser(
        "check", help="run a fresh --quick sweep and gate it against "
        "the best prior trajectory record")
    chk.add_argument("--bench", default="moe_hotpath",
                     choices=sorted(GATED_BENCHES))
    chk.add_argument("--tolerance", type=float, default=0.1,
                     help="allowed fractional slowdown vs the best "
                     "prior record (default 0.1 = 10%%)")
    chk.add_argument("--path", default=None,
                     help="trajectory file (default: the benchmark's "
                     "BENCH_*.json)")
    chk.add_argument("--full", action="store_true",
                     help="gate the full sweep instead of --quick")
    args = ap.parse_args(argv)
    if args.cmd == "check":
        return check(args.bench, tolerance=args.tolerance,
                     path=args.path, quick=not args.full)
    return 0


if __name__ == "__main__":
    import sys
    sys.exit(main())
