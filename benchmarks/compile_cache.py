"""§3.6 / Figure 1 analogue: graph compilation tiers.

Measures, for the decode graph of the serving model:
  cold          first-ever compile (the paper's 12.9-min full compile,
                scaled to our model)
  cached        same HLO recompiled with the persistent on-disk
                compilation cache enabled (the paper's Dynamo/Ascend-IR
                cache -> "Read Cache" + short "Compile")
  precompiled   ReviveMoE's failure-scenario precompilation: recovery-time
                cost is a lookup (~0)
"""
from __future__ import annotations

import tempfile
import time
from typing import Dict, List

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.core.graph_cache import GraphCache
from repro.models.model import Model


def _specs(tree):
    return jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(jnp.shape(x), jnp.result_type(x)),
        tree)


def run() -> List[Dict]:
    cfg = get_smoke_config("qwen2-moe-a2.7b")
    model = Model(cfg)
    params = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    cache = jax.eval_shape(lambda: model.init_cache(4, 64))
    tok = jax.ShapeDtypeStruct((4,), jnp.int32)
    rt = jax.eval_shape(model.default_runtime)
    args = (params, cache, tok, rt)

    persist_dir = tempfile.mkdtemp(prefix="bench_xla_cache_")
    rows: List[Dict] = []

    def fresh_fn(tag):
        def fn(p, c, t, r):
            return model.decode_step(p, c, t, r)
        fn.__name__ = f"decode_{tag}"
        fn.__qualname__ = fn.__name__
        return fn

    # cold: no persistent cache
    gc_cold = GraphCache(persist_dir=None)
    _, tm = gc_cold.get_or_compile(("cold",), fresh_fn("cold"), args)
    rows.append({"tier": "cold_compile", "read_cache_s": tm.read_cache_s,
                 "compile_s": tm.compile_s})

    # populate the persistent cache, then measure a cached compile of the
    # SAME HLO under a new function identity (what recovery does)
    gc_warm = GraphCache(persist_dir=persist_dir)
    gc_warm.get_or_compile(("warm0",), fresh_fn("warm0"), args)
    _, tm = gc_warm.get_or_compile(("warm1",), fresh_fn("warm1"), args)
    rows.append({"tier": "cached_compile", "read_cache_s": tm.read_cache_s,
                 "compile_s": tm.compile_s})

    # precompiled failure-scenario executable: recovery does a lookup
    gc_pre = GraphCache(persist_dir=persist_dir)
    gc_pre.precompile(("v1",), fresh_fn("v1"), args)
    t0 = time.perf_counter()
    _, tm = gc_pre.get_or_compile(("v1",), None, None)
    rows.append({"tier": "precompiled_lookup",
                 "read_cache_s": tm.read_cache_s,
                 "compile_s": time.perf_counter() - t0})
    return rows


def print_table(rows: List[Dict]) -> None:
    print("\n# §3.6 analogue: compile tiers (seconds)")
    print(f"{'tier':22s} {'read_cache':>11s} {'compile':>9s}")
    for r in rows:
        print(f"{r['tier']:22s} {r['read_cache_s']:11.3f} "
              f"{r['compile_s']:9.4f}")
    cold = rows[0]["read_cache_s"] + rows[0]["compile_s"]
    pre = rows[2]["read_cache_s"] + rows[2]["compile_s"]
    print(f"\nprecompiled vs cold speedup: {cold / max(pre, 1e-9):.0f}x "
          f"(paper: 12.9 min -> <10 s)")


if __name__ == "__main__":
    print_table(run())
