"""CI smoke for the streaming HTTP front end + mid-stream recovery.

Boots a 2-instance fleet behind ``repro.launch.serve --http``, streams
one completion over SSE, injects a device fault on the instance serving
it mid-stream, and asserts:

* the stream completes with every requested token (the revive path
  keeps the position-seeded token stream bit-identical through the
  fault — no client-visible gap, no wrong tokens);
* ``/instances`` surfaces the arbiter's revive decision with its
  counterfactual cost table;
* ``/health`` reflects the degraded instance, and a planned restart
  through ``/control`` brings the fleet back to ``healthy``.

Run: ``python scripts/http_smoke.py`` (needs PYTHONPATH=src).
"""
from __future__ import annotations

import http.client
import json
import os
import re
import subprocess
import sys
import threading
import time

BOOT_TIMEOUT_S = 600      # first-ever jit compile on a cold CI runner
STREAM_TIMEOUT_S = 600
HEALTH_TIMEOUT_S = 300
MAX_TOKENS = 48


def wait_for_port(proc, lines):
    """Scrape the bound port off the launcher's banner line."""
    deadline = time.time() + BOOT_TIMEOUT_S
    while time.time() < deadline:
        for ln in list(lines):
            m = re.search(r"serving on http://[\d.]+:(\d+)", ln)
            if m:
                return int(m.group(1))
        if proc.poll() is not None:
            sys.exit(f"server exited early ({proc.returncode}):\n"
                     + "".join(lines))
        time.sleep(0.25)
    sys.exit("timed out waiting for the server banner:\n" + "".join(lines))


def get_json(port, path, method="GET", body=None):
    conn = http.client.HTTPConnection("127.0.0.1", port, timeout=120)
    try:
        payload = json.dumps(body) if body is not None else None
        conn.request(method, path, body=payload,
                     headers={"Content-Type": "application/json"})
        resp = conn.getresponse()
        data = resp.read()
        assert resp.status == 200, (path, resp.status, data[:300])
        return json.loads(data)
    finally:
        conn.close()


def loaded_instance(port):
    info = get_json(port, "/instances")
    for row in info["instances"]:
        if row["state"] != "dead" and row.get("load", 0) > 0:
            return row["iid"]
    raise AssertionError(f"no loaded instance: {info['instances']}")


def main() -> int:
    env = dict(os.environ, PYTHONPATH="src", JAX_PLATFORMS="cpu")
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.launch.serve", "--fleet", "2",
         "--mode", "collocated", "--num-dp", "2", "--overlap",
         "--http", "0", "--workdir", "/tmp/http_smoke"],
        stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True,
        env=env)
    lines: list = []
    threading.Thread(target=lambda: lines.extend(proc.stdout),
                     daemon=True).start()
    try:
        port = wait_for_port(proc, lines)
        print(f"server up on :{port}")

        health = get_json(port, "/health")
        assert health["state"] == "healthy", health
        assert health["serving"] == 2, health

        # stream one completion over SSE
        conn = http.client.HTTPConnection("127.0.0.1", port,
                                          timeout=STREAM_TIMEOUT_S)
        conn.request("POST", "/v1/completions", body=json.dumps({
            "prompt": [5, 9, 2, 7] * 3, "max_tokens": MAX_TOKENS,
            "stream": True, "eos_token": None,
        }), headers={"Content-Type": "application/json"})
        resp = conn.getresponse()
        assert resp.status == 200, resp.status

        tokens: list = []
        finish_reason = None
        faulted = False
        target = None
        buf = b""
        while True:
            chunk = resp.read1(65536)
            if not chunk:
                break
            buf += chunk
            while b"\n\n" in buf:
                ev, buf = buf.split(b"\n\n", 1)
                if not ev.startswith(b"data: "):
                    continue
                payload = ev[len(b"data: "):]
                if payload == b"[DONE]":
                    buf = b""
                    break
                choice = json.loads(payload)["choices"][0]
                tokens.extend(choice["tokens"])
                if choice["finish_reason"] is not None:
                    finish_reason = choice["finish_reason"]
            if not faulted and len(tokens) >= 6:
                # mid-stream: fail a device on the instance serving us
                target = loaded_instance(port)
                res = get_json(port, "/control", method="POST",
                               body={"op": "fail_device", "iid": target})
                print(f"injected device fault on instance {target}: {res}")
                faulted = True
            if finish_reason is not None:
                break
        conn.close()
        assert faulted, "stream finished before the fault was injected"
        assert len(tokens) == MAX_TOKENS, (len(tokens), MAX_TOKENS)
        assert finish_reason == "length", finish_reason
        print(f"stream completed through the fault: "
              f"{len(tokens)} tokens, finish_reason={finish_reason}")

        # the arbiter's decision must be visible with its cost table
        info = get_json(port, "/instances")
        revives = [d for d in info["decisions"]
                   if d.get("decision", {}).get("policy") == "revive"]
        assert revives, f"no revive decision recorded: {info['decisions']}"
        assert "est_cost_s" in revives[0]["decision"], revives[0]
        print(f"arbiter decision: {revives[0]['decision']}")

        # the revived instance serves degraded (a DP rank down / experts
        # masked) until a planned restart restores it
        health = get_json(port, "/health")
        inst = health["instances"][str(target)]
        assert inst["degraded"], inst
        assert health["state"] == "degraded", health["state"]
        print(f"health degraded as expected: instance {target} "
              f"healthy_dp={inst['healthy_dp']}/{inst['total_dp']} "
              f"masked={inst['masked_expert_fraction']:.3f}")

        get_json(port, "/control", method="POST",
                 body={"op": "planned_restart", "iid": target})
        deadline = time.time() + HEALTH_TIMEOUT_S
        while time.time() < deadline:
            health = get_json(port, "/health")
            inst = health["instances"][str(target)]
            if health["state"] == "healthy" and not inst["degraded"]:
                break
            time.sleep(1.0)
        assert health["state"] == "healthy", health
        assert not inst["degraded"], inst
        print("fleet healthy again after planned restart")
        print("HTTP smoke OK")
        return 0
    finally:
        proc.terminate()
        try:
            proc.wait(timeout=10)
        except subprocess.TimeoutExpired:
            proc.kill()


if __name__ == "__main__":
    sys.exit(main())
