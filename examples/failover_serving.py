"""End-to-end fleet failover demo — the paper's scenario at fleet scale:

A 3-instance fleet (each an MA-disaggregated FlowServe engine) plus one
pre-warmed hot spare serves an open-loop request stream.  Two failures
hit it live:

  ① an MoE NPU dies mid-step on instance 0 — the RecoveryArbiter weighs
     revive vs restart vs spare from its measured cost model and (with
     revive being orders cheaper) recovers in place, ReviveMoE-style;
  ② instance 1 is lost whole (host failure) — in-place revive is
     impossible, so the arbiter substitutes the hot spare and the
     router live-migrates every in-flight request onto it with
     prompt + generated-prefix re-prefill.

Every request still completes, and the per-request outcome table shows
who got hit, where each request ended up, and what it cost.

  PYTHONPATH=src python examples/failover_serving.py
"""
import dataclasses

from repro.configs import get_smoke_config
from repro.core.fault_codes import ErrorType, Severity
from repro.fleet import PoissonTraffic, build_fleet
from repro.serving.engine import EngineConfig


def main():
    cfg = get_smoke_config("qwen2-moe-a2.7b")
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, num_redundant_experts=2))
    ec = EngineConfig(mode="disaggregated", num_dp=2, num_moe=2,
                      max_batch=2, max_seq=96, block_size=8,
                      num_blocks=128, workdir="/tmp/repro_failover")
    traffic = PoissonTraffic(40.0, cfg.vocab_size, prompt_len=10,
                             max_new_tokens=16, seed=7, limit=18)
    fleet = build_fleet(cfg, ec, instances=3, spares=1, traffic=traffic)
    print(f"fleet: 3 instances x (2 DP + 2 MoE ranks, EP"
          f"{fleet.instances[0].engine.ep_size}) + 1 hot spare "
          f"(weights loaded, graphs precompiled)")

    # ① MoE NPU on instance 0 dies mid-step at its engine step 5
    fleet.instances[0].engine.injector.schedule(
        5, 2, severity=Severity.L6, error_type=ErrorType.HBM_ECC,
        component="moe", mid_step=True)

    lost = False
    for _ in range(3000):
        fleet.tick()
        # ② once instance 1 is mid-generation, its host goes away whole
        inst1 = fleet.instances[1]
        if (not lost and inst1.engine.unfinished > 0
                and any(r.output_tokens and r.state.value == "running"
                        for r in inst1.engine.all_requests)):
            fleet.lose_instance(1, "demo: host failure")
            lost = True
        if traffic.exhausted and fleet.requests and not fleet.unfinished:
            break

    print("\narbiter decisions + router actions:")
    for line in fleet.log:
        print("  ", line)

    print("\nper-request outcome:")
    for r in fleet.requests:
        m = fleet.meta[r.req_id]
        path = "->".join(str(i) for i in m["instances"])
        ttft = (f"{(m['first_token_s'] - m['arrival_s']) * 1e3:6.0f}ms"
                if m["first_token_s"] is not None else "   n/a")
        print(f"   req {r.req_id:3d}: {r.state.value:8s} "
              f"instances {path:9s} ttft {ttft} "
              f"tokens {len(r.output_tokens):2d} "
              f"xmigr {r.cross_instance_migrations} "
              f"re-prefilled {r.recomputed_tokens}")

    done = sum(r.state.value == "finished" for r in fleet.requests)
    migrated = sum(r.cross_instance_migrations for r in fleet.requests)
    states = {i.iid: i.state.value for i in fleet.instances.values()}
    print(f"\nfinished {done}/{len(fleet.requests)} requests; "
          f"{migrated} cross-instance migrations; instances: {states}")
    assert done == len(fleet.requests)
    revives = sum(len(i.engine.reports) for i in fleet.instances.values())
    assert revives >= 1, "expected at least one in-place revive"
    assert any(i.iid >= 1000 for i in fleet.instances.values()), \
        "expected the hot spare to have joined the serving set"
    print("OK — fleet survived a device fault (revived in place) and a "
          "full instance loss (spare substituted) without losing a "
          "single request")


if __name__ == "__main__":
    main()
