"""End-to-end driver — the paper's scenario, live:

Serve a batched request stream on an MA-disaggregated FlowServe instance,
kill an MoE NPU mid-step, watch ReviveMoE recover without a restart
(role switch with weights from disk), then kill an attention NPU and
watch sequences migrate with partial recomputation.  Every request still
completes.

  PYTHONPATH=src python examples/failover_serving.py
"""
import dataclasses

import numpy as np

from repro.configs import get_smoke_config
from repro.core.fault_codes import ErrorType, Severity
from repro.serving.engine import EngineConfig, InferenceEngine


def main():
    cfg = get_smoke_config("qwen2-moe-a2.7b")
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, num_redundant_experts=2))
    ec = EngineConfig(mode="disaggregated", num_dp=3, num_moe=2,
                      max_batch=2, max_seq=96, block_size=8,
                      num_blocks=128, workdir="/tmp/repro_failover")
    eng = InferenceEngine(cfg, ec)
    print(f"deployment: {ec.num_dp} DPExecutors + {ec.num_moe} MoEExecutors"
          f" (EP{eng.ep_size}), precompiled failure graphs ready")

    rng = np.random.default_rng(7)
    reqs = [eng.submit(list(rng.integers(0, cfg.vocab_size, 10)),
                       max_new_tokens=20) for _ in range(8)]

    # ① MoE NPU dies mid-step at step 5 (its experts are partially
    #    unreplicated -> Fig.4 routes to a role switch)
    eng.injector.schedule(5, ec.num_dp + 0, severity=Severity.L6,
                          error_type=ErrorType.HBM_ECC, component="moe",
                          mid_step=True)
    # ② an attention NPU hangs at step 12 -> heartbeat timeout path
    eng.injector.schedule(12, 0, severity=Severity.L5,
                          error_type=ErrorType.DRIVER_HANG,
                          component="attn", mid_step=True)

    eng.run(max_steps=300)

    print(f"\n{len(eng.reports)} recoveries:")
    for rep in eng.reports:
        print(" ", rep.summary())
        for a in rep.actions:
            print("    -", a)
    done = sum(r.state.value == "finished" for r in reqs)
    migrated = sum(r.migrations for r in reqs)
    print(f"\nfinished {done}/{len(reqs)} requests "
          f"({migrated} migrations, "
          f"{sum(r.recomputed_tokens for r in reqs)} tokens re-prefilled)")
    assert done == len(reqs)
    print("OK — service survived two hardware failures without a restart")


if __name__ == "__main__":
    main()
