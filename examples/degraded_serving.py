"""§4.2 + §4.3 live: serve with missing experts, restore in background.

Loses an unreplicated MoE rank with role-switching disabled: ReviveMoE
masks the lost experts (accuracy-degraded but alive), then we flip the
policy and show a later role switch restores full weight integrity —
the paper's 'techniques are not mutually exclusive' point.

  PYTHONPATH=src python examples/degraded_serving.py
"""
import dataclasses

import numpy as np

from repro.configs import get_smoke_config
from repro.core.fault_codes import Severity
from repro.core.weights import RecoveryPolicy
from repro.serving.engine import EngineConfig, InferenceEngine


def main():
    cfg = get_smoke_config("qwen2-moe-a2.7b")
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, num_redundant_experts=0))
    ec = EngineConfig(
        mode="disaggregated", num_dp=3, num_moe=2, max_batch=2,
        max_seq=64, block_size=8, num_blocks=64,
        workdir="/tmp/repro_degraded",
        policy=RecoveryPolicy(allow_role_switch=False,
                              min_ep_for_missing=2))
    eng = InferenceEngine(cfg, ec)
    rng = np.random.default_rng(3)
    reqs = [eng.submit(list(rng.integers(0, cfg.vocab_size, 8)), 12)
            for _ in range(6)]
    eng.injector.schedule(4, ec.num_dp, severity=Severity.L6,
                          component="moe", mid_step=True)
    eng.run(max_steps=200)

    rep = eng.reports[0]
    print("recovery:", rep.summary())
    mask = np.asarray(eng.runtime.expert_mask)
    print(f"serving DEGRADED: {(~mask).sum()}/{mask.size} experts masked "
          f"(coverage {eng.expert_map.coverage():.0%})")
    assert all(r.state.value == "finished" for r in reqs)

    # ... later: capacity is available again -> restore full integrity
    # (the role switch the policy deferred), as §4.3 describes
    from repro.serving.weights_util import load_expert_shard_from_checkpoint
    failed_rank = 0
    shard = load_expert_shard_from_checkpoint(
        eng.ckpt_path, eng.shards[failed_rank], failed_rank, eng.ep_size)
    donor = eng.dp_executors[2]
    donor.drop_attention_state()
    donor.ep_rank = failed_rank
    donor.shard = shard
    eng.expert_map.install_rank(failed_rank)
    eng.runtime = eng.expert_map.runtime()
    eng.reassemble_params()
    print(f"background role switch complete: coverage "
          f"{eng.expert_map.coverage():.0%}, masks cleared = "
          f"{bool(np.asarray(eng.runtime.expert_mask).all())}")

    reqs2 = [eng.submit(list(rng.integers(0, cfg.vocab_size, 8)), 8)
             for _ in range(3)]
    eng.run(max_steps=100)
    assert all(r.state.value == "finished" for r in reqs2)
    print("OK — degraded service + eventual full restoration")


if __name__ == "__main__":
    main()
