"""Quickstart: build a model, serve a few requests, inspect the engine.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.configs import get_smoke_config
from repro.serving.engine import EngineConfig, InferenceEngine

def main():
    cfg = get_smoke_config("qwen2-moe-a2.7b")
    print(f"model: {cfg.name} ({cfg.family}), "
          f"{cfg.moe.num_experts} experts top-{cfg.moe.top_k}")

    eng = InferenceEngine(cfg, EngineConfig(
        mode="collocated", num_dp=2, max_batch=2, max_seq=64,
        block_size=8, num_blocks=64, workdir="/tmp/repro_quickstart"))
    print("engine up:", {k: f"{v:.2f}s" for k, v in
                         eng.init_timings.items() if v > 0.01})

    rng = np.random.default_rng(0)
    reqs = [eng.submit(list(rng.integers(0, cfg.vocab_size, 8)),
                       max_new_tokens=12) for _ in range(4)]
    eng.run(max_steps=100)
    for r in reqs:
        print(f"req {r.req_id}: {r.state.value}, prompt={r.prompt_tokens}, "
              f"output={r.output_tokens}")
    assert all(r.state.value == "finished" for r in reqs)
    print("OK")

if __name__ == "__main__":
    main()
