"""Train a small MoE LM for a few hundred steps on the synthetic pattern
task, checkpoint it, and verify the checkpoint serves.

  PYTHONPATH=src python examples/train_moe.py [--steps 300]
"""
import argparse
import dataclasses

import jax

from repro.configs import get_smoke_config
from repro.models.model import Model
from repro.training.checkpoint import restore_like, save_checkpoint
from repro.training.data import DataConfig, lm_batches
from repro.training.optimizer import OptimizerConfig
from repro.training.train_loop import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    args = ap.parse_args()

    cfg = get_smoke_config("qwen2-moe-a2.7b")
    cfg = dataclasses.replace(
        cfg, vocab_size=64,
        moe=dataclasses.replace(cfg.moe, num_experts=16, top_k=2,
                                capacity_factor=2.0))
    model = Model(cfg)
    print(f"params: {model.count_params():,}")

    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=64, batch_size=16)
    opt = OptimizerConfig(lr=2e-3, warmup_steps=30, total_steps=args.steps)
    params, hist = train(model, lm_batches(dc), args.steps, opt_cfg=opt,
                         log_every=50)
    for h in hist:
        print(f"  step {h['step']:4d}  loss {h['loss']:.4f}  "
              f"aux {h['aux']:.3f}  ({h['elapsed_s']:.0f}s)")
    assert hist[-1]["loss"] < hist[0]["loss"] * 0.8, "did not learn"

    path = "/tmp/repro_train_moe/weights.npz"
    dt = save_checkpoint(path, params)
    print(f"checkpoint saved in {dt:.2f}s -> {path}")
    restore_like(path, jax.eval_shape(lambda: params))
    print("checkpoint restores OK")


if __name__ == "__main__":
    main()
