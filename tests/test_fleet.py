"""Fleet control plane tests: router, spares, arbiter, and the seeded
cross-instance migration replay guarantee.

Exact replay precondition: the fleet is weight-identical (shared
checkpoint) and the MoE runs drop-free (capacity >= offered load), so a
token is a pure function of (seed, prefix, position) — batch
composition, executor, and *instance* all cancel out.  With capacity
dropping, replay after migration is best-effort (already-emitted tokens
are still never changed).
"""
import dataclasses
from types import SimpleNamespace

import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.fault_codes import ErrorType, Severity
from repro.fleet import (CostModel, DiurnalTraffic, InstanceState,
                         PoissonTraffic, RecoveryArbiter, TraceTraffic,
                         build_fleet)
from repro.fleet.traffic import Arrival
from repro.serving.engine import EngineConfig
from repro.serving.sampling import SamplingParams


def fleet_cfg():
    cfg = get_smoke_config("qwen2-moe-a2.7b")
    # drop-free MoE: the precondition for exact cross-instance replay
    return dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, num_experts=4,
                                     num_redundant_experts=2, top_k=2,
                                     capacity_factor=8.0,
                                     min_capacity=64))


def fleet_ecfg(workdir, **kw):
    base = dict(mode="disaggregated", num_dp=2, num_moe=2, max_batch=2,
                max_seq=64, block_size=8, num_blocks=64, workdir=workdir)
    base.update(kw)
    return EngineConfig(**base)


@pytest.fixture(scope="module")
def shared_workdir(tmp_path_factory):
    # one workdir for every fleet in this module: all engines share the
    # same weights checkpoint + on-disk compile cache (weight-identical
    # fleet, fast warmup)
    return str(tmp_path_factory.mktemp("fleet"))


PROMPT = list(np.random.default_rng(3).integers(0, 512, 9))


@pytest.mark.parametrize("temperature", [0.0, 0.8])
def test_cross_instance_migration_exact_replay(shared_workdir,
                                               temperature):
    """Acceptance: a request migrated across instances mid-generation
    produces the exact token sequence of an unmigrated run."""
    sp = SamplingParams(temperature=temperature, top_p=0.9, seed=5)
    ecfg = fleet_ecfg(shared_workdir, sampling=sp)
    cfg = fleet_cfg()

    ref_fleet = build_fleet(cfg, ecfg, instances=1)
    ref = ref_fleet.submit(PROMPT, 14)
    ref_fleet.run(max_ticks=120)
    assert ref.state.value == "finished"

    fleet = build_fleet(cfg, ecfg, instances=2)
    req = fleet.submit(PROMPT, 14)
    for _ in range(5):
        fleet.tick()
    mid = len(req.output_tokens)
    assert 0 < mid < 14, "fault must land mid-generation"
    src = req.instance_id
    fleet.lose_instance(src, "test: host loss mid-generation")
    fleet.run(max_ticks=250)

    assert req.state.value == "finished"
    assert req.cross_instance_migrations == 1
    assert req.instance_id != src
    assert req.output_tokens == ref.output_tokens
    # the arbiter knew revive was impossible for a lost instance
    dec = fleet.arbiter.decisions[-1]
    assert dec.policy in ("restart", "spare")
    assert "impossible" in dec.reason or "forced" in dec.reason


def test_router_least_loaded_admission_and_drain(shared_workdir):
    fleet = build_fleet(fleet_cfg(), fleet_ecfg(shared_workdir),
                        instances=2)
    r1 = fleet.submit(PROMPT, 4)
    r2 = fleet.submit(PROMPT, 4)
    assert {r1.instance_id, r2.instance_id} == {0, 1}
    # a draining instance accepts no new work
    fleet.instances[0].state = InstanceState.DRAINING
    r3 = fleet.submit(PROMPT, 4)
    assert r3.instance_id == 1
    fleet.instances[0].state = InstanceState.SERVING
    fleet.run(max_ticks=120)
    assert all(r.state.value == "finished" for r in (r1, r2, r3))
    # TTFT metrics recorded on the virtual clock
    assert len(fleet.ttfts()) == 3
    assert all(t >= 0 for t in fleet.ttfts())


def test_straggler_soft_signal_drains_instance(shared_workdir):
    """Satellite: StragglerDetector output flows engine.health() ->
    arbiter soft pass -> proactive drain (no spare available)."""
    # soft_patience=1 so the proactive path wins the race against the
    # engine's own hard straggler isolation (patience 2 engine steps);
    # num_dp=3 because with 2 ranks a straggler drags the fleet median
    # up and the ratio rule mathematically cannot fire
    fleet = build_fleet(fleet_cfg(),
                        fleet_ecfg(shared_workdir, num_dp=3, max_batch=1),
                        instances=2, spares=0, soft_patience=1)
    # traffic on every rank of both engines so step-time samples
    # accumulate fleet-wide
    reqs = [fleet.submit(PROMPT, 24) for _ in range(6)]
    for _ in range(6):
        fleet.tick()
    victim = fleet.instances[0].engine.dp_executors[1]
    victim.simulated_slowdown_s = 1.0
    for _ in range(30):
        fleet.tick()
        if any(d.proactive for d in fleet.arbiter.decisions):
            break
    soft = [d for d in fleet.arbiter.decisions if d.proactive]
    assert soft, "soft signal never reached the arbiter"
    assert soft[0].instance_id == 0
    assert "straggler" in soft[0].reason
    # no spare -> the instance drains instead of substituting
    assert fleet.instances[0].state in (InstanceState.DRAINING,
                                        InstanceState.SERVING)
    fleet.run(max_ticks=400)
    assert all(r.state.value == "finished" for r in reqs)


@pytest.mark.slow
def test_spare_substitution_on_device_fault(shared_workdir):
    """A forced-spare arbitration: device fault -> live migration to a
    pre-warmed standby, wounded instance decommissioned."""
    fleet = build_fleet(fleet_cfg(), fleet_ecfg(shared_workdir),
                        instances=2, spares=1, force_policy="spare")
    assert fleet.spares.available == 1
    reqs = [fleet.submit(PROMPT, 12) for _ in range(4)]
    # MoE device on instance 0 dies mid-step at its engine step 3
    fleet.instances[0].engine.injector.schedule(
        3, 2, severity=Severity.L6, error_type=ErrorType.HBM_ECC,
        component="moe", mid_step=True)
    fleet.run(max_ticks=300)
    assert all(r.state.value == "finished" for r in reqs)
    assert fleet.instances[0].state is InstanceState.DEAD
    assert fleet.spares.available == 0 and fleet.spares.activations == 1
    spare_ids = [iid for iid in fleet.instances if iid >= 1000]
    assert spare_ids, "spare never joined the serving set"
    migrated = [r for r in reqs if r.cross_instance_migrations > 0]
    assert migrated
    assert any(d.policy == "spare" for d in fleet.arbiter.decisions)


@pytest.mark.slow
def test_open_loop_traffic_all_finish(shared_workdir):
    traffic = PoissonTraffic(200.0, 512, prompt_len=6, max_new_tokens=6,
                             seed=1, limit=10)
    fleet = build_fleet(fleet_cfg(), fleet_ecfg(shared_workdir),
                        instances=2, traffic=traffic)
    fleet.run(max_ticks=400)
    assert traffic.exhausted
    assert len(fleet.requests) == 10
    assert fleet.unfinished == 0


def test_arbiter_cost_model_decisions():
    """Pure cost-model arithmetic: no engines involved."""
    cm = CostModel({"engine": 0.1, "generator": 2.0, "xccl": 0.01,
                    "read_cache": 0.02, "compile": 0.5},
                   spare_opportunity_cost_s=10.0)
    # seeds: restart ~2.63s, revive ~0.03s
    assert cm.est_revive_s() < 0.1 < cm.est_restart_s()
    arb = RecoveryArbiter(cm)
    inst = SimpleNamespace(iid=7, load=3,
                           engine=SimpleNamespace(all_requests=[]))
    dec = arb.decide(inst, None, spare_available=True)
    assert dec.policy == "revive"          # cheapest by far
    dec = arb.decide(inst, None, spare_available=True, instance_lost=True)
    assert dec.policy != "revive"
    dec = arb.decide(inst, None, spare_available=False,
                     instance_lost=True)
    assert dec.policy == "restart"
    # measurements replace seeds: an expensive revive flips the decision
    cm.observe_revive({"total_s": 50.0})
    cm.observe_restart(0.2)
    dec = arb.decide(inst, None, spare_available=False)
    assert dec.policy == "restart"
    # forced policy wins when feasible
    arb2 = RecoveryArbiter(cm, force_policy="spare")
    assert arb2.decide(inst, None, spare_available=True).policy == "spare"
    assert arb2.decide(inst, None,
                       spare_available=False).policy != "spare"
    with pytest.raises(ValueError):
        RecoveryArbiter(cm, force_policy="bogus")


def test_cost_model_stream_and_quality_pricing():
    """Satellite: the cost model prices spare substitution on its real
    mechanics (KV blocks streamed vs tokens re-prefilled) and revive on
    stall *plus* degraded quality (masked-expert fraction)."""
    cm = CostModel({"engine": 1.0}, per_token_prefill_s=1e-3,
                   per_block_stream_s=1e-5,
                   degraded_quality_weight_s=2.0,
                   spare_opportunity_cost_s=0.0)
    # streaming 1024 tokens as 64 blocks is ~three orders cheaper than
    # re-prefilling them
    replay = cm.est_spare_s(1024, 0)
    stream = cm.est_spare_s(0, 64)
    assert stream < replay / 100
    # streamed-cost estimate is ~flat in prefix length, replay is linear
    assert cm.est_spare_s(0, 256) - cm.est_spare_s(0, 64) < 0.01 * (
        cm.est_spare_s(4096, 0) - cm.est_spare_s(1024, 0))
    # degraded quality: half the experts masked adds a real stall-
    # equivalent term to revive
    assert cm.quality_cost_s(0.0) == 0.0
    assert cm.quality_cost_s(0.5) == pytest.approx(1.0)
    # measurement feedback discounts both migration terms from the swap
    cm.observe_spare(0.5, tokens=100, streamed_blocks=100)
    assert cm.spare_swap.value == pytest.approx(0.5 - 0.1 - 1e-3)


def test_arbiter_prices_degraded_quality_into_revive():
    """A fault whose experts have no surviving replica makes revive pay
    the quality term; with full redundancy it doesn't."""
    cm = CostModel({"engine": 0.1}, degraded_quality_weight_s=50.0,
                   spare_opportunity_cost_s=10.0)
    cm.observe_revive({"total_s": 0.02})
    cm.observe_restart(0.5)
    arb = RecoveryArbiter(cm)
    ev = SimpleNamespace(rank=3)

    def inst(mask_frac):
        return SimpleNamespace(
            iid=1, load=2,
            engine=SimpleNamespace(
                all_requests=[],
                streamable_split=lambda: (0, 0),
                predict_masked_fraction=lambda rank: mask_frac,
                ecfg=SimpleNamespace(block_size=8)))

    covered = arb.decide(inst(0.0), ev, spare_available=False)
    assert covered.policy == "revive"
    degraded = arb.decide(inst(0.5), ev, spare_available=False)
    assert degraded.policy == "restart"      # quality term flipped it
    assert "masked" in degraded.reason
    assert degraded.est_cost["revive"] > covered.est_cost["revive"]


@pytest.mark.slow
def test_spare_pool_background_replenish(shared_workdir):
    """Satellite (ROADMAP a): after an activation the pool rebuilds a
    standby in the background instead of shrinking; KV-block streaming
    keeps the migrated request token-exact with zero recompute."""
    from repro.core.fault_codes import ErrorType, Severity
    sp = SamplingParams(temperature=0.8, top_p=0.9, seed=5)
    ecfg = fleet_ecfg(shared_workdir, sampling=sp)
    cfg = fleet_cfg()
    ref_fleet = build_fleet(cfg, ecfg, instances=1)
    ref = ref_fleet.submit(PROMPT, 14)
    ref_fleet.run(max_ticks=150)

    fleet = build_fleet(cfg, ecfg, instances=2, spares=1,
                        force_policy="spare", replenish_spares=True)
    req = fleet.submit(PROMPT, 14)
    for _ in range(5):
        fleet.tick()
    assert 0 < len(req.output_tokens) < 14
    eng = fleet.instances[req.instance_id].engine
    eng.injector.schedule(eng.step_no + 1, 3, severity=Severity.L6,
                          error_type=ErrorType.HBM_ECC, component="moe",
                          mid_step=True)
    fleet.run(max_ticks=300)
    assert req.state.value == "finished"
    assert req.output_tokens == ref.output_tokens
    # streamed takeover: the prefix was never re-prefilled
    assert req.cross_instance_migrations == 1
    assert req.recomputed_tokens == 0
    # the pool self-healed: one activation, one background replenishment
    assert fleet.spares.activations == 1
    assert fleet.spares.replenishments == 1
    assert fleet.spares.available == fleet.spares.target_size == 1
    assert any("replenished" in line for line in fleet.log)


def test_traffic_sources_deterministic():
    a = PoissonTraffic(100.0, 512, seed=9, limit=5)
    b = PoissonTraffic(100.0, 512, seed=9, limit=5)
    got_a = a.due(10.0)
    got_b = b.due(10.0)
    assert [x.at_s for x in got_a] == [x.at_s for x in got_b]
    assert [x.prompt_tokens for x in got_a] == [
        x.prompt_tokens for x in got_b]
    assert a.exhausted
    tr = TraceTraffic([Arrival(0.5, (1, 2), 4), Arrival(0.1, (3,), 4)])
    assert [x.at_s for x in tr.due(0.2)] == [0.1]
    assert [x.at_s for x in tr.due(9.0)] == [0.5]
    assert tr.exhausted
    with pytest.raises(ValueError):
        PoissonTraffic(0.0, 512)


def test_traffic_lognormal_lengths_seeded_and_heavy_tailed():
    """length_dist='lognormal' turns the configured prompt/output shape
    into medians of seeded heavy-tailed draws (campaign realism): same
    seed -> identical stream, lengths spread around the median with a
    real upper tail, clamps honored, fixed path untouched."""
    import numpy as np

    def stream(seed=9):
        t = PoissonTraffic(200.0, 512, seed=seed, limit=400,
                           prompt_len=8, max_new_tokens=8,
                           length_dist="lognormal", length_sigma=0.75,
                           max_prompt_len=64, max_output_len=48)
        return t.due(1e9)

    got = stream()
    same = stream()
    assert [(a.at_s, a.prompt_tokens, a.max_new_tokens) for a in got] \
        == [(a.at_s, a.prompt_tokens, a.max_new_tokens) for a in same]

    plens = np.array([len(a.prompt_tokens) for a in got])
    outs = np.array([a.max_new_tokens for a in got])
    for xs, cap in ((plens, 64), (outs, 48)):
        assert xs.min() >= 1 and xs.max() <= cap
        assert 6 <= np.median(xs) <= 10          # median ~ configured 8
        assert xs.max() >= 3 * np.median(xs)     # heavy upper tail
        assert len(set(xs.tolist())) > 5         # not a fixed shape

    # fixed path: no heavy-tail draws, shapes exactly as configured
    fixed = PoissonTraffic(200.0, 512, seed=9, limit=50,
                           prompt_len=(4, 8), max_new_tokens=6)
    for a in fixed.due(1e9):
        assert len(a.prompt_tokens) in (4, 8)
        assert a.max_new_tokens == 6

    # diurnal variant inherits the knobs
    d = DiurnalTraffic(50.0, 512, amplitude=0.5, period_s=10.0, seed=4,
                       limit=100, length_dist="lognormal")
    dlens = {len(a.prompt_tokens) for a in d.due(1e9)}
    assert len(dlens) > 3

    with pytest.raises(ValueError):
        PoissonTraffic(1.0, 512, length_dist="gauss")
    with pytest.raises(ValueError):
        PoissonTraffic(1.0, 512, length_dist="lognormal",
                       length_sigma=0.0)


def test_engine_config_validation_raises_value_error():
    """Satellite: config validation survives `python -O` (ValueError,
    not assert) and names the offending field."""
    with pytest.raises(ValueError, match="EngineConfig.mode"):
        EngineConfig(mode="sharded")
    with pytest.raises(ValueError, match="EngineConfig.num_dp"):
        EngineConfig(num_dp=0)
    with pytest.raises(ValueError, match="EngineConfig.num_moe"):
        EngineConfig(num_moe=-1)
    with pytest.raises(ValueError, match="EngineConfig.block_size"):
        EngineConfig(block_size=0)
    with pytest.raises(ValueError, match="heartbeat_timeout_steps"):
        EngineConfig(heartbeat_timeout_steps=0)
    with pytest.raises(ValueError, match="EngineConfig.moe_impl"):
        EngineConfig(moe_impl="turbofused")
    EngineConfig(moe_impl="fused")          # valid value still accepted
