"""Tests for §3.5 rank compaction and domain rebuild."""
import pytest

pytest.importorskip("hypothesis")

import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core.comm_domain import CommDomain


def test_compaction_closes_gap():
    d = CommDomain(4, 4, collocated=False)
    d.fail(5)  # moe rank with logical 1
    rec = d.rebuild()
    moe = d.group("moe")
    ranks = sorted(r.logical_rank for r in moe)
    assert ranks == [0, 1, 2]      # gap closed: ℓ_B=ℓ_A+1 -> ℓ_A
    assert rec["world_size"] == 7
    assert rec["version"] == 1


def test_role_switch_takes_failed_logical_rank():
    d = CommDomain(4, 4, collocated=False)
    d.rebuild()
    failed = d.device(6)           # moe logical rank 2
    failed_logical = failed.logical_rank
    d.fail(6)
    d.rebuild(role_switch_physical=1)         # dp rank 1 switches
    switched = d.device(1)
    assert switched.role == "moe"
    assert switched.logical_rank == failed_logical
    assert switched.alive
    moe_ranks = sorted(r.logical_rank for r in d.group("moe"))
    assert moe_ranks == [0, 1, 2, 3]


@settings(max_examples=50, deadline=None)
@given(n=st.integers(2, 12), fails=st.lists(st.integers(0, 11), min_size=1,
                                            max_size=4, unique=True))
def test_compaction_always_contiguous(n, fails):
    d = CommDomain(n, n, collocated=False)
    for f in fails:
        if f < n and sum(r.alive for r in d.group("attn")) > 1:
            d.fail(f)
    d.rebuild()
    ranks = sorted(r.logical_rank for r in d.group("attn"))
    assert ranks == list(range(len(ranks)))


def test_collocated_domain_stages():
    d = CommDomain(4, 0, collocated=True)
    rec = d.rebuild()
    assert "destroy_trampoline_domain" not in rec["stages"]
    d2 = CommDomain(4, 4, collocated=False)
    rec2 = d2.rebuild()
    assert rec2["stages"][0] == "destroy_trampoline_domain"
    assert rec2["stages"][-1] == "create_trampoline_domain"
