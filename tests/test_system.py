"""End-to-end behaviour tests for the whole system.

The full production-mesh story is exercised by launch/dryrun (512
placeholder devices, separate process); here we verify the same code
paths on the host mesh and the end-to-end serve → fail → recover → finish
flow that is the paper's contribution.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, INPUT_SHAPES, \
    get_config, get_smoke_config
from repro.models.model import Model

KEY = jax.random.PRNGKey(0)


def test_all_assigned_archs_registered():
    assert len(ASSIGNED_ARCHS) == 10
    assert len(INPUT_SHAPES) == 4
    families = {get_config(a).family for a in ASSIGNED_ARCHS}
    assert families == {"dense", "moe", "hybrid", "ssm", "audio", "vlm"}


def test_full_configs_match_assignment_table():
    c = get_config("kimi-k2-1t-a32b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads) == \
        (61, 7168, 64, 8)
    assert c.moe.num_experts == 384 and c.moe.top_k == 8
    assert c.vocab_size == 163840
    c = get_config("nemotron-4-340b")
    assert (c.num_layers, c.d_model, c.d_ff) == (96, 18432, 73728)
    assert c.activation == "relu2"
    c = get_config("falcon-mamba-7b")
    assert c.attention_type == "none" and c.mamba.d_state == 16
    c = get_config("jamba-1.5-large-398b")
    assert c.hybrid_period == 8 and c.moe.moe_layer_period == 2
    c = get_config("seamless-m4t-large-v2")
    assert c.encoder_layers == 24 and c.vocab_size == 256206
    c = get_config("minicpm3-4b")
    assert c.attention_type == "mla" and c.mla.kv_lora_rank == 256


def test_long_context_policy():
    # SSM natively sub-quadratic; dense archs get a window for long_500k
    assert get_config("falcon-mamba-7b", "long_500k").sliding_window == 0
    assert get_config("mistral-large-123b", "long_500k").sliding_window > 0
    assert get_config("jamba-1.5-large-398b", "long_500k").sliding_window > 0
    # window applies only to the long shape
    assert get_config("mistral-large-123b", "decode_32k").sliding_window == 0


@pytest.mark.parametrize("impl", ["gather_psum", "gather_psum_fused"])
def test_moe_dist_matches_local_on_host_mesh(impl):
    """The shard_map gather_psum path must be numerically identical to
    the single-rank path (mesh 1x1 -> collectives are identity), for both
    the dense-scatter and the fused local compute."""
    from repro.distributed.collectives import MoEDist
    cfg = get_smoke_config("qwen2-moe-a2.7b")
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=100.0),
        moe_impl=impl)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    # local reference stays on the dense-scatter path: the fused variant
    # must match it, not merely itself
    m_local = Model(dataclasses.replace(cfg, moe_impl="gather_psum"))
    m_dist = Model(cfg, moe_dist=MoEDist(mesh, dp_axes=("data",)))
    params = m_local.init(KEY)
    batch = {"tokens": jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size),
             "loss_mask": jnp.ones((2, 16), jnp.int32)}
    l1, _, a1 = m_local.logits_full(params, batch)
    l2, _, a2 = m_dist.logits_full(params, batch)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=2e-4,
                               atol=2e-4)


@pytest.mark.slow
@pytest.mark.parametrize("impl", ["a2a", "a2a_fused"])
def test_a2a_dist_matches_local_on_host_mesh(impl):
    from repro.distributed.collectives import MoEDistA2A
    cfg = get_smoke_config("qwen2-moe-a2.7b")
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=100.0),
        moe_impl=impl)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    m_local = Model(dataclasses.replace(cfg, moe_impl="gather_psum"))
    m_dist = Model(cfg, moe_dist=MoEDistA2A(mesh, dp_axes=("data",)))
    params = m_local.init(KEY)
    batch = {"tokens": jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size),
             "loss_mask": jnp.ones((2, 16), jnp.int32)}
    l1, _, _ = m_local.logits_full(params, batch)
    l2, _, _ = m_dist.logits_full(params, batch)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=2e-4,
                               atol=2e-4)


def test_serve_fail_recover_end_to_end(tmp_path):
    """The paper in one test: serve MoE traffic, kill a device mid-step,
    recover in-place (no reinit), all requests complete."""
    from repro.core.fault_codes import Severity
    from repro.serving.engine import EngineConfig, InferenceEngine
    cfg = get_smoke_config("qwen2-moe-a2.7b")
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, num_experts=4,
                                     num_redundant_experts=4, top_k=2))
    ec = EngineConfig(mode="disaggregated", num_dp=2, num_moe=2,
                      max_batch=2, max_seq=64, block_size=8, num_blocks=64,
                      workdir=str(tmp_path))
    eng = InferenceEngine(cfg, ec)
    rng = np.random.default_rng(1)
    reqs = [eng.submit(list(rng.integers(0, cfg.vocab_size, 8)), 8)
            for _ in range(4)]
    eng.injector.schedule(4, 2, severity=Severity.L6, component="moe",
                          mid_step=True)
    eng.run(max_steps=150)
    assert all(r.state.value == "finished" for r in reqs)
    assert len(eng.reports) == 1
    # recovery avoided the expensive stages: no engine/executor relaunch
    rep = eng.reports[0]
    assert rep.timings.get("engine", 0.0) == 0.0
    assert rep.timings.get("executor_processes", 0.0) == 0.0
    assert rep.compile_source == "precompiled"
    assert rep.total_s < 5.0
