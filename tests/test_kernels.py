"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.expert_ffn import expert_ffn_pallas
from repro.kernels.paged_attention import paged_attention_pallas
from repro.kernels.router_topk import router_topk_pallas
from repro.kernels.ssm_scan import ssm_scan_pallas

KEY = jax.random.PRNGKey(42)


@pytest.mark.parametrize("T,E,k", [(16, 8, 2), (100, 60, 4), (256, 64, 8),
                                   (33, 384, 8)])
@pytest.mark.parametrize("masked", [0, 3])
def test_router_topk(T, E, k, masked):
    ks = jax.random.split(jax.random.fold_in(KEY, T * E + k), 2)
    logits = jax.random.normal(ks[0], (T, E), jnp.float32)
    mask = jnp.ones((E,), bool)
    if masked:
        dead = jax.random.choice(ks[1], E, (masked,), replace=False)
        mask = mask.at[dead].set(False)
    w1, i1 = router_topk_pallas(logits, mask, k, interpret=True)
    w2, i2 = ref.router_topk_ref(logits, mask, k)
    np.testing.assert_allclose(np.sort(w1, -1), np.sort(np.asarray(w2), -1),
                               rtol=2e-5, atol=1e-6)
    # selected sets must match (order may differ on exact ties)
    np.testing.assert_array_equal(np.sort(i1, -1), np.sort(np.asarray(i2), -1))
    if masked:
        assert not np.isin(np.asarray(i1), np.asarray(dead)).any()
    # weights renormalized
    np.testing.assert_allclose(np.asarray(w1).sum(-1), 1.0, rtol=1e-5)


@pytest.mark.parametrize("E,C,D,F", [(2, 64, 128, 256), (3, 100, 256, 384),
                                     (8, 128, 512, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_expert_ffn(E, C, D, F, dtype):
    ks = jax.random.split(jax.random.fold_in(KEY, E * C), 4)
    x = (jax.random.normal(ks[0], (E, C, D)) * 0.1).astype(dtype)
    g = (jax.random.normal(ks[1], (E, D, F)) * 0.05).astype(dtype)
    u = (jax.random.normal(ks[2], (E, D, F)) * 0.05).astype(dtype)
    d = (jax.random.normal(ks[3], (E, F, D)) * 0.05).astype(dtype)
    y1 = expert_ffn_pallas(x, g, u, d, interpret=True)
    y2 = ref.expert_ffn_ref(x, g, u, d)
    tol = 1e-4 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(y1, np.float32),
                               np.asarray(y2, np.float32), rtol=tol,
                               atol=tol)


@pytest.mark.parametrize("B,H,Hkv,Dh,bs,mb", [
    (2, 4, 4, 64, 16, 3),      # MHA
    (3, 8, 2, 64, 16, 4),      # GQA
    (1, 16, 8, 128, 32, 2),
])
def test_paged_attention(B, H, Hkv, Dh, bs, mb):
    nb = mb * B + 2
    ks = jax.random.split(jax.random.fold_in(KEY, B * H * bs), 5)
    q = jax.random.normal(ks[0], (B, H, Dh), jnp.float32)
    kp = jax.random.normal(ks[1], (nb, bs, Hkv, Dh), jnp.float32)
    vp = jax.random.normal(ks[2], (nb, bs, Hkv, Dh), jnp.float32)
    bt = jax.random.randint(ks[3], (B, mb), 0, nb)
    sl = jax.random.randint(ks[4], (B,), 1, mb * bs + 1)
    o1 = paged_attention_pallas(q, kp, vp, bt, sl, interpret=True)
    o2 = ref.paged_attention_ref(q, kp, vp, bt, sl)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=1e-4,
                               atol=1e-5)


@pytest.mark.parametrize("B,S,d,N,block_d,chunk", [
    (1, 64, 256, 16, 256, 32),
    (2, 128, 512, 16, 128, 64),
    (2, 96, 256, 8, 256, 32),
])
def test_ssm_scan(B, S, d, N, block_d, chunk):
    ks = jax.random.split(jax.random.fold_in(KEY, S * d), 5)
    u = jax.random.normal(ks[0], (B, S, d)) * 0.1
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, d)) - 2)
    A = -jnp.exp(jax.random.normal(ks[2], (d, N)) * 0.3)
    Bs = jax.random.normal(ks[3], (B, S, N)) * 0.2
    Cs = jax.random.normal(ks[4], (B, S, N)) * 0.2
    y1, h1 = ssm_scan_pallas(u, dt, A, Bs, Cs, block_d=block_d, chunk=chunk,
                             interpret=True)
    y2, h2 = ref.ssm_scan_ref(u, dt, A, Bs, Cs)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-4,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), rtol=1e-4,
                               atol=1e-5)


# -- fused MoE dispatch->FFN->combine pipeline ------------------------------

MOE_FUSED_GRID = [
    # T, k, e_phys, e_local, off, D, F, cap
    (32, 2, 4, 4, 0, 128, 256, 12),     # aligned, all experts local
    (19, 3, 6, 3, 3, 96, 144, 4),       # odd shapes, offset slice, overflow
    (8, 2, 4, 2, 2, 64, 40, 8),         # tiny F (< one 128 lane tile)
    (100, 2, 8, 8, 0, 128, 128, 16),    # capacity overflow on hot experts
]


def _moe_fused_inputs(T, k, e_phys, e_local, D, F, dtype=jnp.float32,
                      alive_p=0.85):
    ks = jax.random.split(jax.random.fold_in(KEY, T * e_phys + k * D), 7)
    x = (jax.random.normal(ks[0], (T, D)) * 0.1).astype(dtype)
    g = (jax.random.normal(ks[1], (e_local, D, F)) * 0.05).astype(dtype)
    u = (jax.random.normal(ks[2], (e_local, D, F)) * 0.05).astype(dtype)
    d = (jax.random.normal(ks[3], (e_local, F, D)) * 0.05).astype(dtype)
    phys = jax.random.randint(ks[4], (T, k), 0, e_phys)
    w = jax.nn.softmax(jax.random.normal(ks[5], (T, k)), -1)
    alive = jax.random.bernoulli(ks[6], alive_p, (T, k))
    return x, g, u, d, phys, w, alive


@pytest.mark.parametrize("T,k,e_phys,e_local,off,D,F,cap", MOE_FUSED_GRID)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_moe_fused_parity(T, k, e_phys, e_local, off, D, F, cap, dtype):
    """Pallas fused pipeline == jnp fused oracle == dense-scatter path."""
    from repro.kernels.moe_fused import moe_fused_pallas
    from repro.models.moe import dispatch_compute_combine
    x, g, u, d, phys, w, alive = _moe_fused_inputs(
        T, k, e_phys, e_local, D, F, dtype)
    y_dense = dispatch_compute_combine(x, w, phys, alive, g, u, d,
                                       cap=cap, expert_offset=off,
                                       e_local=e_local)
    y_ref = ref.moe_fused_ref(x, g, u, d, w, phys, alive, cap=cap,
                              expert_offset=off, e_local=e_local)
    y_pal = moe_fused_pallas(x, g, u, d, w, phys, alive, cap=cap,
                             expert_offset=off, e_local=e_local,
                             interpret=True)
    tol = 1e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(y_ref, np.float32),
                               np.asarray(y_dense, np.float32),
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(y_pal, np.float32),
                               np.asarray(y_dense, np.float32),
                               rtol=tol, atol=tol)


def test_moe_fused_masked_and_lost_experts():
    """Fused path under real routing with a masked expert (§3.4) and a
    fully-lost expert (replica_count == 0) matches the dense path."""
    from repro.configs.base import MoEConfig
    from repro.models.moe import (MoERuntime, default_runtime,
                                  dispatch_compute_combine, route,
                                  select_replicas)
    from repro.kernels.moe_fused import moe_fused_pallas
    moe = MoEConfig(num_experts=4, top_k=2, expert_d_ff=64,
                    num_redundant_experts=2)
    e_phys = 6
    rt0 = default_runtime(moe)
    # expert 3 masked out of routing; expert 2 fully lost (tokens that
    # still select it are dropped via alive=False)
    rt = MoERuntime(rt0.logical_to_physical,
                    rt0.replica_count.at[2].set(0),
                    rt0.expert_mask.at[3].set(False))
    T, D, F, cap = 24, 64, 64, 10
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (T, D)) * 0.1
    router_w = jax.random.normal(ks[1], (D, 4)) * 0.1
    g = jax.random.normal(ks[2], (e_phys, D, F)) * 0.05
    u = jax.random.normal(ks[3], (e_phys, D, F)) * 0.05
    d = jax.random.normal(ks[4], (e_phys, F, D)) * 0.05
    w, sel, _ = route(router_w, x, rt, moe)
    assert not np.isin(np.asarray(sel), [3]).any()    # mask respected
    phys, alive = select_replicas(sel, rt)
    assert not np.asarray(alive).all()                # lost expert hit
    y_dense = dispatch_compute_combine(x, w, phys, alive, g, u, d,
                                       cap=cap, expert_offset=0,
                                       e_local=e_phys)
    y_pal = moe_fused_pallas(x, g, u, d, w, phys, alive, cap=cap,
                             expert_offset=0, e_local=e_phys,
                             interpret=True)
    np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_dense),
                               rtol=1e-4, atol=1e-5)


def test_moe_fused_runtime_mutation_no_recompile():
    """§3.4 for the fused pipeline: replica drop and expert mask are data
    (MoERuntime arrays), so recovery never retraces the compiled step."""
    import dataclasses
    from repro.configs import get_smoke_config
    from repro.models import moe as MoE
    cfg = get_smoke_config("qwen2-moe-a2.7b")
    cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
        cfg.moe, num_redundant_experts=2), moe_impl="fused")
    p = MoE.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(KEY, (16, cfg.d_model))
    f = jax.jit(lambda xx, rt: MoE.moe_apply_local(p, cfg, xx, rt, cap=8))
    rt = MoE.default_runtime(cfg.moe)
    y0, _ = f(x, rt)
    n0 = f._cache_size()
    # drop a replica + mask an expert — recovery's two mutations
    rt2 = MoE.MoERuntime(rt.logical_to_physical,
                         rt.replica_count.at[0].set(1),
                         rt.expert_mask.at[1].set(False))
    y1, _ = f(x, rt2)
    assert f._cache_size() == n0          # no retrace / recompile
    assert np.isfinite(np.asarray(y1)).all()
    assert not np.allclose(np.asarray(y0), np.asarray(y1))  # mask applied


def test_router_topk_mask_is_data_not_recompile():
    """The §3.4 property: changing the failure mask re-uses the same
    compiled kernel (mask is an argument, not a constant)."""
    from repro.kernels import ops
    T, E, k = 32, 16, 2
    logits = jax.random.normal(KEY, (T, E))
    m1 = jnp.ones((E,), bool)
    m2 = m1.at[0].set(False)
    f = jax.jit(lambda lg, m: ops.router_topk(lg, m, k, use_pallas=False))
    _ = f(logits, m1)
    n0 = f._cache_size()
    _ = f(logits, m2)
    assert f._cache_size() == n0  # no retrace/recompile


@pytest.mark.parametrize("B,S,H,Hkv,Dh,bq,bk", [
    (1, 128, 4, 4, 64, 64, 64),    # MHA
    (2, 128, 8, 2, 64, 32, 64),    # GQA, bq != bk
    (1, 256, 16, 8, 128, 128, 128),
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_prefill(B, S, H, Hkv, Dh, bq, bk, causal):
    from repro.kernels.flash_prefill import flash_prefill_pallas
    ks = jax.random.split(jax.random.fold_in(KEY, S * H + causal), 3)
    q = jax.random.normal(ks[0], (B, S, H, Dh), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, Hkv, Dh), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, Hkv, Dh), jnp.float32)
    o1 = flash_prefill_pallas(q, k, v, causal=causal, block_q=bq,
                              block_k=bk, interpret=True)
    o2 = ref.flash_prefill_ref(q, k, v, causal)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=2e-4,
                               atol=2e-4)


def test_flash_prefill_matches_model_attention():
    """Kernel semantics == the model's chunked-flash jnp implementation."""
    from repro.kernels.flash_prefill import flash_prefill_pallas
    from repro.models.attention import flash_attention
    B, S, H, Hkv, Dh = 2, 128, 8, 4, 64
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, Dh))
    k = jax.random.normal(ks[1], (B, S, Hkv, Dh))
    v = jax.random.normal(ks[2], (B, S, Hkv, Dh))
    pos = jnp.arange(S)
    o_model = flash_attention(q, k, v, pos, pos, causal=True)
    o_kernel = flash_prefill_pallas(q, k, v, causal=True, block_q=64,
                                    block_k=64, interpret=True)
    np.testing.assert_allclose(np.asarray(o_kernel), np.asarray(o_model),
                               rtol=2e-4, atol=2e-4)
