"""Multi-token self-speculative decode (PR 8 tentpole).

The speculation window rides the compiled chunk graph (a chunked-
prefill step already IS a fixed-width decode over per-token page
contexts), the n-gram proposer self-drafts from the sequence, and
``spec_verify`` accepts a draft only when it equals the seeded
sampler's output at that position — so the emitted stream must be
token-identical to plain decode, the rejected rows' pool writes must
roll back bit-exact (§3.3 row-level undo), and faults mid-window must
replay to the plain path's stream with zero fresh compiles.
"""
import dataclasses

import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.serving.engine import EngineConfig, InferenceEngine, _Ctx
from repro.serving.sampling import SamplingParams, sample, spec_verify
from repro.serving.scheduler import ngram_propose

# repetitive traces: the n-gram proposer drafts from recurrence, so
# these prompts make speculation windows (and acceptances) happen
PAT_A = [5, 9, 2, 7]
PAT_B = [3, 1]


def _prompts():
    return [PAT_A * 5, PAT_B * 8]


def _engine(tmp_path, sub, *, spec_window=0, temperature=0.0,
            num_dp=1, decode_impl=None, **over):
    cfg = get_smoke_config(over.pop("arch", "qwen2-moe-a2.7b"))
    cfg_fn = over.pop("cfg_fn", None)
    if cfg_fn:
        cfg = cfg_fn(cfg)
    ec = EngineConfig(mode="collocated", num_dp=num_dp, max_batch=2,
                      max_seq=over.pop("max_seq", 96), block_size=8,
                      num_blocks=64, workdir=str(tmp_path / sub),
                      decode_impl=decode_impl, spec_window=spec_window,
                      sampling=SamplingParams(temperature=temperature,
                                              top_p=0.9, seed=3), **over)
    return cfg, InferenceEngine(cfg, ec)


def _serve(eng, prompts, max_new=24):
    reqs = [eng.submit(list(p), max_new) for p in prompts]
    eng.run(max_steps=400)
    assert all(r.state.value == "finished" for r in reqs), \
        [r.state for r in reqs]
    return [list(r.output_tokens) for r in reqs]


# -- unit: proposer + deterministic accept/reject ---------------------------


def test_ngram_propose():
    # final bigram (2, 7) last recurred at index 2: propose what followed
    toks = [5, 9, 2, 7, 5, 9, 2, 7]
    assert ngram_propose(toks, 3) == (5, 9, 2)
    assert ngram_propose(toks, 1) == (5,)
    # no recurrence / too short / no budget -> no drafts
    assert ngram_propose([1, 2, 3, 4, 5], 3) == ()
    assert ngram_propose([1, 2], 3) == ()
    assert ngram_propose(toks, 0) == ()
    # most recent occurrence wins
    assert ngram_propose([1, 2, 9, 1, 2, 8, 1, 2], 2) == (8, 1)


@pytest.mark.parametrize("temperature", [0.0, 0.8])
def test_spec_verify_matches_sequential_sampling(temperature):
    """Every emitted token equals what the seeded sampler produces at
    that sequence position, the accepted prefix equals the drafts, and
    emission stops exactly at the first mismatch."""
    rng = np.random.default_rng(0)
    params = SamplingParams(temperature=temperature, top_p=0.9, seed=7)
    g, V, base = 5, 64, 40
    logits = rng.normal(size=(g, V)) * 3.0
    targets = [int(sample(logits[r][None], params, step=base + r)[0])
               for r in range(g)]
    # drafts agreeing for 2 rows then diverging
    drafts = [targets[0], targets[1], (targets[2] + 1) % V, 0]
    toks, accepted = spec_verify(logits, drafts, params, start_step=base)
    assert accepted == 2
    assert list(toks) == targets[:3]
    # fully accepted window: all g - 1 drafts match -> g tokens emitted
    toks, accepted = spec_verify(logits, targets[:g - 1], params,
                                 start_step=base)
    assert accepted == g - 1
    assert list(toks) == targets
    # immediate mismatch -> plain-decode behaviour (1 token)
    toks, accepted = spec_verify(logits, [(targets[0] + 1) % V], params,
                                 start_step=base)
    assert accepted == 0
    assert list(toks) == targets[:1]


# -- engine: token-exactness vs greedy non-speculative ----------------------


def _windowed(cfg):
    return dataclasses.replace(cfg, sliding_window=6)


SPEC_ARCHS = [
    ("qwen2-moe-a2.7b", None),       # GQA + MoE + shared experts
    ("deepseek-v3", None),           # MLA + MoE + first-k-dense
    ("qwen2-moe-a2.7b", _windowed),  # GQA + sliding window
]


@pytest.mark.parametrize("arch,cfg_fn", SPEC_ARCHS,
                         ids=["gqa_moe", "mla_moe", "windowed"])
def test_spec_token_exact_vs_greedy(tmp_path, arch, cfg_fn):
    _, base = _engine(tmp_path, "base", arch=arch, cfg_fn=cfg_fn)
    want = _serve(base, _prompts())
    _, eng = _engine(tmp_path, "spec", arch=arch, cfg_fn=cfg_fn,
                     spec_window=6)
    got = _serve(eng, _prompts())
    assert got == want
    stats = eng.prefill_stats()
    assert stats["spec_windows"] > 0          # speculation actually ran
    assert stats["spec_emitted"] >= stats["spec_windows"]
    hist = eng.spec_histogram()
    assert sum(hist.values()) == stats["spec_windows"]
    assert all(2 <= g <= 6 for g in hist)


def test_spec_token_exact_megakernel(tmp_path):
    """Speculation through the fused megakernel chunk path emits the
    same stream as plain composed decode."""
    _, base = _engine(tmp_path, "base")
    want = _serve(base, _prompts())
    _, eng = _engine(tmp_path, "mega_spec", decode_impl="megakernel",
                     spec_window=6)
    got = _serve(eng, _prompts())
    assert got == want
    assert eng.prefill_stats()["spec_windows"] > 0


# -- rejected-window pool-row rollback --------------------------------------


def test_spec_rejected_rows_rollback_bitexact(tmp_path):
    """Rows written for rejected drafts are restored bit-exact from the
    plan-time write-set capture; the committed row 0 write stands."""
    from repro.serving.cache_ops import capture_pool_rows
    _, eng = _engine(tmp_path, "rb", spec_window=6)
    req = eng.submit(PAT_A * 5, 24)
    ex = eng.dp_executors[0]
    ctx = _Ctx(eng)
    checked = False
    for step in range(60):
        if req.state.value == "finished":
            break
        plan = ex.plan()
        win = next((w for w in plan.spec if w.req is req), None)
        pre = None
        if win is not None:
            bs = ex.block_size
            table = ex.scheduler.block_tables[req.req_id].blocks
            pos = range(win.start, win.start + win.length)
            bids = np.asarray([table[p // bs] for p in pos], np.int32)
            offs = np.asarray([p % bs for p in pos], np.int32)
            pre = capture_pool_rows(ex.cache, ex.paged_axes, bids, offs)
            pre_rows = [None if r is None else np.asarray(r)
                        for r in pre["rows"]]
        n_before = req.num_tokens
        ex.compute(ctx, step)
        ex.commit()
        if win is None:
            continue
        emitted = req.num_tokens - n_before
        assert emitted >= 1
        post = capture_pool_rows(ex.cache, ex.paged_axes, bids, offs)
        changed_row0 = False
        for a, b, ax in zip(pre_rows, post["rows"], ex.paged_axes):
            if ax is not None:
                continue
            b = np.asarray(b)
            # rejected rows: bit-identical to the pre-step pool
            np.testing.assert_array_equal(b[:, emitted:], a[:, emitted:])
            if not np.array_equal(b[:, 0], a[:, 0]):
                changed_row0 = True
        # the window's committed write (last token's KV row) happened
        assert changed_row0
        if emitted < win.length:
            checked = True
    assert checked, "no speculation window was ever partially rejected"


# -- faults mid-window ------------------------------------------------------


def test_spec_fault_midwindow_replay_parity(tmp_path):
    """A mid-step L6 fault while speculation windows are in flight rolls
    back and replays to exactly the stream the non-speculative engine
    produces under the identical fault."""
    from repro.core.fault_codes import ErrorType, Severity

    def fault_run(sub, spec):
        _, eng = _engine(tmp_path, sub, num_dp=2, spec_window=spec)
        eng.injector.schedule(3, 1, severity=Severity.L6,
                              error_type=ErrorType.HBM_ECC,
                              component="attn", mid_step=True)
        out = _serve(eng, _prompts())
        surviving = [ex for ex in eng.dp_executors if ex.alive]
        assert surviving and all(
            ex.block_manager.num_allocated == 0 for ex in surviving)
        return out, eng

    want, _ = fault_run("fault_plain", 0)
    got, eng = fault_run("fault_spec", 6)
    assert got == want
    assert eng.prefill_stats()["spec_windows"] > 0


def test_spec_failrank_mask_zero_recompile(tmp_path):
    """fail_rank + mask_experts while speculating are pure MoERuntime
    data edits: the spec windows keep flowing through the precompiled
    chunk graph and the cache never sees a fresh compile."""
    cfg, eng = _engine(tmp_path, "zc", num_dp=2, spec_window=6,
                       precompile_failure_scenarios=False)

    def real_compiles():
        return sum(1 for t in eng.graph_cache.timings
                   if t.compile_s > 0.01)

    _serve(eng, [PAT_A * 4], max_new=8)
    n0 = real_compiles()
    eng.expert_map.fail_rank(1)
    eng.expert_map.mask_experts(
        [e for e in range(cfg.moe.num_experts)
         if not any(s not in set(eng.expert_map.rank_slots(1))
                    for s in eng.expert_map.replicas_of(e))])
    eng.runtime = eng.expert_map.runtime()
    _serve(eng, [PAT_B * 10], max_new=12)
    assert real_compiles() == n0
    assert eng.prefill_stats()["spec_windows"] > 0


# -- carry-over (f): decode-grown + imported block registration -------------


def test_prefix_cache_registers_decode_grown_blocks(tmp_path):
    """A multi-turn follow-up whose prompt embeds a finished request's
    prompt + outputs hits the cache past the original prompt: blocks
    filled by decode register at fill time, not just prefilled ones."""
    _, eng = _engine(tmp_path, "grown")
    rng = np.random.default_rng(5)
    prompt = list(rng.integers(0, 2048, 16))            # 2 full blocks
    r0 = eng.submit(prompt, 16)
    eng.run(max_steps=200)
    assert r0.state.value == "finished"
    bm = eng.dp_executors[0].block_manager
    # prompt-only registration would publish 2 blocks; decode growth
    # publishes every full block below the KV-complete bound (31 -> 3)
    assert bm.num_cached >= 3

    follow = list(r0.prompt_tokens) + list(r0.output_tokens[:12])  # 28
    eng.submit(follow, 2)
    eng.run(max_steps=200)
    stats = eng.prefill_stats()
    # >= 3 blocks (24 tokens) served from cache: past the prompt's 16
    assert stats["prefill_tokens_cached"] >= 24


def test_prefix_cache_registers_imported_blocks():
    """KV-stream-imported requests register their installed blocks on
    the target immediately — a migrated conversation is shareable there
    without re-prefill."""
    import jax
    from repro.models.model import Model
    from repro.serving.executor import DPExecutor
    from repro.serving.request import Request
    import jax.numpy as jnp

    cfg = get_smoke_config("internlm2-20b")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    class Ctx:
        runtime = model.default_runtime()

        def __init__(self):
            self.params = params

        def decode_fn(self, params, cache, tokens, page, runtime):
            page = {k: jnp.asarray(v) for k, v in page.items()}
            return model.decode_step_paged(params, cache,
                                           jnp.asarray(tokens), page,
                                           runtime)

        def chunk_fn(self):
            return self.decode_fn

    def executor(rank):
        return DPExecutor(physical_id=rank, dp_rank=rank, model=model,
                          max_batch=2, max_seq=32, num_blocks=16,
                          block_size=4, sampling=SamplingParams())

    ex = executor(0)
    ctx = Ctx()
    req = Request([7, 1, 7, 1, 7, 1], 8)
    ex.scheduler.add_request(req)
    for step in range(4):
        ex.plan()
        ex.compute(ctx, step)
        ex.commit()
    kv = ex.export_kv_blocks(req)
    assert kv is not None

    tgt = executor(1)
    assert tgt.block_manager.num_cached == 0
    assert tgt.import_kv_blocks(req, kv)
    # full blocks below valid_len registered on the importing manager
    assert tgt.block_manager.num_cached == (req.num_tokens - 1) // 4
    tgt.scheduler.check_consistent()
