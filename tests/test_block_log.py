"""Property tests for the §3.3 log-based block-table recovery.

Invariant: for ANY sequence of block operations inside a generation step,
``undo_all`` restores the (manager, tables) state to the step boundary
exactly — the core ARIES-style guarantee ReviveMoE relies on.
"""
from __future__ import annotations

import pytest

pytest.importorskip("hypothesis")

import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core.block_log import BlockLog, BlockManager, BlockTable


def _state(manager, tables):
    return (manager.snapshot(),
            tuple((sid, tuple(t.blocks)) for sid, t in sorted(tables.items())))


op_strategy = st.lists(
    st.tuples(st.sampled_from(["alloc_append", "free_last", "ref", "noop"]),
              st.integers(0, 3)),   # seq id
    min_size=0, max_size=40)


@settings(max_examples=200, deadline=None)
@given(pre_ops=op_strategy, step_ops=op_strategy)
def test_undo_restores_exact_state(pre_ops, step_ops):
    manager = BlockManager(num_blocks=64, block_size=16)
    tables = {i: BlockTable(i) for i in range(4)}
    log = BlockLog()

    def apply_unlogged(ops):
        for kind, sid in ops:
            t = tables[sid]
            if kind == "alloc_append" and manager.num_free:
                t.append_block(manager.allocate())
            elif kind == "free_last" and t.blocks:
                manager.free(t.blocks.pop())
            elif kind == "ref" and t.blocks:
                manager.add_ref(t.blocks[-1])

    # committed prefix (previous step): not logged
    apply_unlogged(pre_ops)
    log.begin_step()
    before = _state(manager, tables)

    # in-flight step: everything logged; restrict to invertible ops the
    # scheduler actually performs (alloc+append, ref)
    for kind, sid in step_ops:
        t = tables[sid]
        if kind in ("alloc_append", "noop"):
            if kind == "alloc_append" and manager.num_free > 0:
                bid = manager.allocate(log)
                t.append_block(bid, log)
        elif kind == "ref":
            if t.blocks:
                manager.add_ref(t.blocks[-1], log)
        elif kind == "free_last":
            if t.blocks and manager.ref_count(t.blocks[-1]) > 1:
                manager.free(t.blocks[-1], log)

    log.undo_all(manager, tables)
    assert _state(manager, tables) == before


@settings(max_examples=100, deadline=None)
@given(n=st.integers(1, 30))
def test_alloc_free_roundtrip(n):
    manager = BlockManager(num_blocks=32, block_size=16)
    log = BlockLog()
    log.begin_step()
    before = manager.snapshot()
    bids = [manager.allocate(log) for _ in range(min(n, 32))]
    for b in bids[: len(bids) // 2]:
        manager.add_ref(b, log)
    log.undo_all(manager, {})
    assert manager.snapshot() == before
    assert manager.num_free == 32


def test_committed_step_log_is_cleared():
    manager = BlockManager(8, 16)
    tables = {0: BlockTable(0)}
    log = BlockLog()
    log.begin_step()
    bid = manager.allocate(log)
    tables[0].append_block(bid, log)
    log.begin_step()          # commit: new step starts
    assert len(log) == 0
    # undo after commit is a no-op
    log.undo_all(manager, tables)
    assert tables[0].blocks == [bid]
    assert manager.ref_count(bid) == 1


def test_double_free_asserts():
    manager = BlockManager(4, 16)
    bid = manager.allocate()
    manager.free(bid)
    try:
        manager.free(bid)
        assert False, "double free must assert"
    except AssertionError:
        pass
