"""Serving substrate unit tests: scheduler, sampling, cache ops,
checkpoint, migration planning."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.block_log import BlockLog, BlockManager
from repro.core.migration import plan_migration, prepare_for_migration
from repro.models.model import Model
from repro.serving.cache_ops import infer_batch_axes, read_slot, write_slot
from repro.serving.request import Request, RequestState
from repro.serving.sampling import SamplingParams, sample
from repro.serving.scheduler import LocalScheduler


def _prefill_done(*reqs):
    """Simulate the compute phase completing each request's prefill."""
    for r in reqs:
        r.prefill_pos = len(r.tokens_so_far)


def test_scheduler_admission_and_block_accounting():
    bm = BlockManager(num_blocks=8, block_size=4)
    sched = LocalScheduler(max_batch=2, max_seq=32, block_manager=bm)
    log = BlockLog()
    r1 = Request(list(range(6)), max_new_tokens=4)   # needs 2 blocks
    r2 = Request(list(range(3)), max_new_tokens=4)
    r3 = Request(list(range(3)), max_new_tokens=4)
    for r in (r1, r2, r3):
        sched.add_request(r)
    log.begin_step()
    # multi-admission: both slots fill in one step; r3 must wait
    plan = sched.plan_step(log)
    assert plan.prefills == [r1, r2]
    assert sched.block_tables[r1.req_id].num_blocks() == 2
    assert r3.state is RequestState.WAITING
    _prefill_done(r1, r2)
    plan = sched.plan_step(log)
    assert plan.prefill is None                      # max_batch=2: no slot
    assert plan.decode == [r1, r2]


def test_scheduler_budget_caps_admissions_per_step():
    """The per-step token budget admits prompts until the budget runs
    out; the first prefill may overflow it (long prompts must admit)."""
    bm = BlockManager(num_blocks=16, block_size=4)
    sched = LocalScheduler(max_batch=4, max_seq=64, block_manager=bm,
                           token_budget=10)
    log = BlockLog()
    long = Request(list(range(12)), 4)     # 12 tokens > budget: admits alone
    s1 = Request(list(range(4)), 4)
    s2 = Request(list(range(4)), 4)
    for r in (long, s1, s2):
        sched.add_request(r)
    log.begin_step()
    plan = sched.plan_step(log)
    assert plan.prefills == [long]         # overflow allowed only first
    _prefill_done(long)
    plan = sched.plan_step(log)
    # 1 decode token + 4 + 4 prefill tokens <= 10
    assert plan.decode == [long] and plan.prefills == [s1, s2]


def test_scheduler_decode_allocates_on_boundary():
    bm = BlockManager(num_blocks=8, block_size=4)
    sched = LocalScheduler(max_batch=1, max_seq=32, block_manager=bm)
    log = BlockLog()
    r = Request([0, 1, 2, 3], max_new_tokens=8)      # fills block exactly
    sched.add_request(r)
    sched.plan_step(log)
    assert sched.block_tables[r.req_id].num_blocks() == 2  # +1 for next tok
    _prefill_done(r)
    used = bm.num_allocated
    r.output_tokens.extend([5, 6, 7])                # positions 4,5,6
    sched.plan_step(log)                             # pos 7 fits block 2
    assert bm.num_allocated == used
    r.output_tokens.append(8)                        # next pos 8 -> block 3
    sched.plan_step(log)
    assert sched.block_tables[r.req_id].num_blocks() == 3


def test_finish_releases_everything():
    bm = BlockManager(8, 4)
    sched = LocalScheduler(2, 32, bm)
    log = BlockLog()
    r = Request([1, 2, 3], 2)
    sched.add_request(r)
    sched.plan_step(log)
    sched.finish(r, log)
    assert bm.num_allocated == 0
    assert sched.num_requests == 0
    assert r.batch_slot is None


def test_sampling_deterministic_and_greedy():
    logits = np.array([[0.1, 3.0, -1.0], [2.0, 0.0, 0.1]])
    out = sample(logits, SamplingParams(temperature=0.0))
    np.testing.assert_array_equal(out, [1, 0])
    p = SamplingParams(temperature=1.0, seed=7)
    a = sample(logits, p, step=3)
    b = sample(logits, p, step=3)
    np.testing.assert_array_equal(a, b)


def test_cache_slot_roundtrip():
    cfg = get_smoke_config("internlm2-20b")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    axes = infer_batch_axes(model, max_seq=16)
    cache = model.init_cache(3, 16)
    batch = {"tokens": jnp.arange(8)[None, :] % cfg.vocab_size,
             "lengths": jnp.array([8], jnp.int32)}
    _, sub = model.prefill(params, batch, max_seq=16)
    cache2 = write_slot(cache, sub, 1, axes)
    back = read_slot(cache2, 1, axes)
    for a, b in zip(jax.tree_util.tree_leaves(back),
                    jax.tree_util.tree_leaves(sub)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b, a.dtype),
                                   rtol=1e-6)
    # slot 0 untouched
    z = read_slot(cache2, 0, axes)
    assert all(float(jnp.abs(x).sum()) == 0.0
               for x in jax.tree_util.tree_leaves(z)
               if x.dtype != jnp.int32)


def test_migration_planning_balances_load():
    reqs = [Request(list(range(4)), 4) for _ in range(6)]
    for r in reqs:
        r.state = RequestState.RUNNING
    loads = {0: 2, 1: 0, 2: 5}
    assignment = plan_migration(reqs, loads)
    counts = {0: 0, 1: 0, 2: 0}
    for _, rank in assignment:
        counts[rank] += 1
    assert counts[1] > counts[2]
    # partial recomputation accounting
    r = reqs[0]
    r.output_tokens = [9, 9]
    prepare_for_migration(r)
    assert r.state is RequestState.MIGRATING
    assert r.migrations == 1
    assert r.recomputed_tokens == 6
    assert r.tokens_so_far == list(range(4)) + [9, 9]


def test_checkpoint_roundtrip(tmp_path):
    from repro.training.checkpoint import restore_like, save_checkpoint
    cfg = get_smoke_config("qwen2-moe-a2.7b")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    path = str(tmp_path / "w.npz")
    save_checkpoint(path, params)
    restored = restore_like(path, jax.eval_shape(lambda: params))
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_expert_shard_split_assemble_roundtrip():
    from repro.serving.weights_util import assemble, split_experts
    cfg = get_smoke_config("qwen2-moe-a2.7b")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    from repro.serving.weights_util import is_expert_leaf
    base, shards = split_experts(params, ep_size=2)
    # base has no routed-expert weights (shared experts stay)
    assert all(float(jnp.abs(l).sum()) == 0
               for p, l in jax.tree_util.tree_flatten_with_path(base)[0]
               if is_expert_leaf(p))
    together = assemble(base, shards, [True, True])
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(together)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # dead shard -> zeros in its slice, rest intact
    half = assemble(base, shards, [True, False])
    leaves = {str(p): l for p, l in
              jax.tree_util.tree_flatten_with_path(half)[0]}
    gate = next(l for p, l in leaves.items()
                if "moe" in p and "gate" in p)
    E = gate.shape[1]
    assert float(jnp.abs(gate[:, E // 2:]).sum()) == 0.0
    assert float(jnp.abs(gate[:, : E // 2]).sum()) > 0.0

# -- LocalScheduler edge cases (the invariants cross-instance migration
# -- relies on): exhausted block pool, rollback-then-requeue consistency


def test_admission_deferred_when_block_pool_exhausted():
    """A request whose prefill cannot get enough blocks mid-stream stays
    WAITING (never half-admitted) and admits once blocks free up."""
    bm = BlockManager(num_blocks=4, block_size=4)
    sched = LocalScheduler(max_batch=2, max_seq=32, block_manager=bm)
    log = BlockLog()
    hog = Request(list(range(12)), max_new_tokens=4)    # needs 4 blocks
    late = Request(list(range(9)), max_new_tokens=4)    # needs 3 blocks
    sched.add_request(hog)
    sched.add_request(late)
    log.begin_step()
    plan = sched.plan_step(log)
    assert plan.prefill is hog and bm.num_free == 0
    # pool exhausted: late must NOT be admitted (no partial allocation)
    plan = sched.plan_step(log)
    assert plan.prefill is None
    assert late.state is RequestState.WAITING
    assert late.req_id not in sched.block_tables
    assert late.batch_slot is None
    sched.check_consistent()
    # finishing the hog frees its blocks; late admits cleanly
    sched.finish(hog, log)
    plan = sched.plan_step(log)
    assert plan.prefill is late
    assert sched.block_tables[late.req_id].num_blocks() == 3
    sched.check_consistent()


def test_rollback_then_requeue_keeps_slots_and_tables_consistent():
    """§3.3 rollback of an aborted admission must return the batch slot
    and block table exactly; requeue_front preserves FIFO-with-priority
    ordering.  (DPExecutor.rollback_inflight drives the same path.)"""
    bm = BlockManager(num_blocks=8, block_size=4)
    sched = LocalScheduler(max_batch=2, max_seq=32, block_manager=bm)
    log = BlockLog()
    r1 = Request(list(range(4)), max_new_tokens=4)
    r2 = Request(list(range(4)), max_new_tokens=4)
    sched.add_request(r1)
    log.begin_step()
    sched.plan_step(log)                    # admits r1
    _prefill_done(r1)
    log.begin_step()                        # commit r1's step
    free_before = bm.num_free
    slots_before = sorted(sched._free_slots)
    sched.add_request(r2)
    sched.plan_step(log)                    # admits r2 (uncommitted)
    # mid-step failure: undo r2's block ops, then requeue it
    log.undo_all(bm, sched.block_tables)
    aborted = sched.rollback_aborted()
    assert aborted == [r2]
    assert bm.num_free == free_before
    assert sorted(sched._free_slots) == slots_before
    assert sched.waiting[0] is r2           # requeued at the front
    assert r2.state is RequestState.WAITING
    sched.check_consistent()
    # the requeued request admits again on the next step
    plan = sched.plan_step(log)
    assert plan.prefill is r2
    sched.check_consistent()


def test_check_consistent_catches_corruption():
    bm = BlockManager(8, 4)
    sched = LocalScheduler(2, 32, bm)
    log = BlockLog()
    r = Request([1, 2, 3], 2)
    sched.add_request(r)
    log.begin_step()
    sched.plan_step(log)
    sched.check_consistent()
    sched._free_slots.append(r.batch_slot)   # corrupt: slot double-owned
    with pytest.raises(AssertionError, match="free and in use"):
        sched.check_consistent()


def test_sampling_per_row_positions_match_scalar():
    """Vector step: each row draws from its own (seed, step) stream, so
    a row's token is independent of its batch company — the property
    cross-instance replay depends on."""
    rng = np.random.default_rng(0)
    logits = rng.normal(size=(3, 16))
    p = SamplingParams(temperature=0.7, top_p=0.9, seed=11)
    batched = sample(logits, p, step=np.array([5, 9, 2]))
    for i, pos in enumerate([5, 9, 2]):
        solo = sample(logits[i:i + 1], p, step=pos)
        assert batched[i] == solo[0]
