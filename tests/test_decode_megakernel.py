"""Decode megakernel (ISSUE 5): the fused attention+MoE step must be
token-exact against the composed kernel chain across GQA / MLA /
windowed architectures, survive every ReviveMoE recovery mutation
(fail_rank / mask_experts / rollback) with zero recompiles, and its
Pallas kernel must match the jnp oracle in interpret mode.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.block_log import BlockManager, BlockTable
from repro.models import moe as MoE
from repro.models.model import Model
from repro.serving import cache_ops
from repro.serving.engine import EngineConfig, InferenceEngine
from repro.serving.kvcache import build_page_context, padded_block_ids
from repro.serving.sampling import SamplingParams

KEY = jax.random.PRNGKey(7)


# -- Pallas kernel vs jnp oracle (interpret mode) ---------------------------

def _megastep_inputs(*, B=3, H=4, Hkv=2, Dh=16, bs=4, nb=10, max_blk=3,
                     D=32, E_log=5, E=7, K=2, F=48, Fs=0, cap=5, seed=0,
                     lost=None, masked=None, window=False, offset=0):
    ks = jax.random.split(jax.random.fold_in(KEY, seed), 14)
    q = jax.random.normal(ks[0], (B, H, Dh)) * 0.3
    k_pool = jax.random.normal(ks[1], (nb, bs, Hkv, Dh)) * 0.3
    v_pool = jax.random.normal(ks[2], (nb, bs, Hkv, Dh)) * 0.3
    bt = jax.random.randint(ks[3], (B, max_blk), 0, nb)
    sl = jax.random.randint(ks[4], (B,), 0, max_blk * bs + 1)  # incl. idle
    st = (jnp.maximum(sl - 6, 0) if window
          else jnp.zeros((B,), jnp.int32))
    x = jax.random.normal(ks[5], (B, D)) * 0.2
    w_post = jax.random.normal(ks[6], (H * Dh, D)) * 0.1
    ln2 = jnp.ones((D,)) * 1.1
    router = jax.random.normal(ks[7], (D, E_log)) * 0.2
    # two replicas for the first couple of logical experts
    l2p = jnp.stack(
        [jnp.arange(E_log, dtype=jnp.int32),
         jnp.where(jnp.arange(E_log) < 2, E_log + jnp.arange(E_log),
                   0).astype(jnp.int32)], axis=1)
    rcnt = jnp.where(jnp.arange(E_log) < 2, 2, 1).astype(jnp.int32)
    mask = jnp.ones((E_log,), bool)
    if lost is not None:
        rcnt = rcnt.at[lost].set(0)
    if masked is not None:
        mask = mask.at[masked].set(False)
    g = jax.random.normal(ks[8], (E, D, F)) * 0.05
    u = jax.random.normal(ks[9], (E, D, F)) * 0.05
    d = jax.random.normal(ks[10], (E, F, D)) * 0.05
    if Fs:
        sg = jax.random.normal(ks[11], (D, Fs)) * 0.05
        su = jax.random.normal(ks[12], (D, Fs)) * 0.05
        sd = jax.random.normal(ks[13], (Fs, D)) * 0.05
    else:
        sg = su = sd = None
    args = (q, k_pool, v_pool, bt, sl, st, x, w_post, ln2, router, l2p,
            rcnt, mask, g, u, d, jnp.int32(offset), sg, su, sd)
    return args, dict(top_k=K, cap=cap, e_local=E)


@pytest.mark.parametrize("case", [
    dict(),                                      # plain GQA-shaped
    dict(Hkv=1, Dh=24, H=6),                     # MLA-shaped (Hkv=1 pool)
    dict(window=True),                           # sliding-window starts
    dict(lost=3, masked=4),                      # §3.4 recovery mutations
    dict(E=3, offset=2, E_log=6),                # EP shard slice
    dict(F=96, cap=3),                           # F blocking + tight cap
    dict(Fs=40),                                 # in-kernel shared experts
], ids=["gqa", "mla_shaped", "windowed", "lost_masked", "ep_offset",
        "fblocked", "shared"])
def test_megastep_kernel_matches_ref(case):
    from repro.kernels import ref
    from repro.kernels.decode_megakernel import decode_megastep_pallas
    args, kw = _megastep_inputs(**case)
    y_ref, h2_ref = ref.decode_megastep_ref(*args, **kw)
    # block_d=24 < D=32: every variant runs the blocked+padded D path
    y_pal, h2_pal = decode_megastep_pallas(*args, **kw, block_f=32,
                                           block_d=24, interpret=True)
    np.testing.assert_allclose(np.asarray(h2_pal), np.asarray(h2_ref),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("case", [
    dict(),                                      # plain
    dict(lost=2, masked=3),                      # recovery mutations
    dict(window=True),                           # sliding-window starts
], ids=["plain", "lost_masked", "windowed"])
def test_megastep_kernel_deployment_d_model(case):
    """Blocked-D parity at a deepseek_v3-class hidden size: weight
    matrices stream through (block_d)-wide VMEM pages while the (B, D)
    activations stay resident, so d_model = 7168 runs without a weight
    ever needing its full D extent on chip (carry-overs (a)/(d))."""
    from repro.kernels import ref
    from repro.kernels.decode_megakernel import decode_megastep_pallas
    args, kw = _megastep_inputs(B=2, H=2, Hkv=1, Dh=16, bs=4, nb=6,
                                max_blk=2, D=7168, E_log=4, E=4, K=2,
                                F=64, Fs=64, cap=4, **case)
    y_ref, h2_ref = ref.decode_megastep_ref(*args, **kw)
    y_pal, h2_pal = decode_megastep_pallas(*args, **kw, block_f=64,
                                           block_d=512, interpret=True)
    np.testing.assert_allclose(np.asarray(h2_pal), np.asarray(h2_ref),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)


def test_megastep_kernel_mutation_is_data_not_recompile():
    """The Pallas wrapper path recompiles for shapes only: mutated
    MoERuntime arrays, paging arrays and expert offsets reuse the same
    jitted executable (§3.4 for the megakernel)."""
    from repro.kernels import ops
    args, kw = _megastep_inputs()
    f = jax.jit(lambda *a: ops.decode_megastep(*a, **kw,
                                               use_pallas=False))
    y0, _ = f(*args)
    n0 = f._cache_size()
    a = list(args)
    a[11] = a[11].at[0].set(0)        # drop a replica (fail_rank's edit)
    a[12] = a[12].at[1].set(False)    # mask an expert
    a[4] = a[4] + 1                   # sequences grew a token
    y1, _ = f(*a)
    assert f._cache_size() == n0
    assert np.isfinite(np.asarray(y1)).all()
    assert not np.allclose(np.asarray(y0), np.asarray(y1))


# -- model-level token parity: megakernel vs composed -----------------------

def _decode_tokens(cfg, n_decode=5, runtime_fn=None):
    """Greedy-decode a prompt through decode_step_paged; returns the
    token ids and per-step logits."""
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    max_seq, bs, nb, max_batch = 32, 4, 24, 2
    max_blk = (max_seq + bs - 1) // bs
    rng = np.random.default_rng(0)
    toks = list(rng.integers(0, cfg.vocab_size, 9))
    Sp = len(toks)
    batch = {"tokens": jnp.asarray([toks + [0] * (16 - Sp)], jnp.int32),
             "lengths": jnp.asarray([Sp], jnp.int32)}
    runtime = (runtime_fn(model) if runtime_fn
               else model.default_runtime())
    last, raw = model.prefill_paged(params, batch, runtime)
    cache = model.init_paged_cache(max_batch, nb, bs)
    _, axes = cache_ops.infer_paged_axes(model, nb, bs)
    man = BlockManager(nb, bs)
    table = BlockTable(7)
    for _ in range((Sp + 1 + bs - 1) // bs):
        table.append_block(man.allocate())
    bids = padded_block_ids(table.blocks, (16 + bs - 1) // bs,
                            trash_block=nb)
    cache = cache_ops.install_prefill(cache, raw, axes,
                                      jnp.asarray(bids), jnp.int32(1))

    class _R:
        batch_slot, req_id = 1, 7
    req = _R()
    tok = int(np.argmax(np.asarray(last)[0]))
    ntok = Sp + 1
    tokens = np.zeros((max_batch,), np.int32)
    out_toks, out_logits = [], []
    for _ in range(n_decode):
        tokens[1] = tok
        req.num_tokens = ntok
        if (ntok - 1) // bs >= table.num_blocks():
            table.append_block(man.allocate())
        page = build_page_context([req], {7: table}, max_batch=max_batch,
                                  max_blk=max_blk, block_size=bs,
                                  trash_block=nb)
        page = {k: jnp.asarray(v) for k, v in page.items()}
        lg, cache = model.decode_step_paged(params, cache,
                                            jnp.asarray(tokens), page,
                                            runtime)
        out_logits.append(np.asarray(lg)[1])
        tok = int(np.argmax(np.asarray(lg)[1]))
        out_toks.append(tok)
        ntok += 1
    return out_toks, out_logits


def _windowed_qwen():
    cfg = get_smoke_config("qwen2-moe-a2.7b")
    return dataclasses.replace(cfg, sliding_window=6)


PARITY_ARCHS = [
    ("qwen2-moe-a2.7b", None),     # GQA + MoE + shared experts
    ("deepseek-v3", None),         # MLA + MoE + first-k-dense
    ("qwen2-moe-a2.7b", _windowed_qwen),   # GQA + sliding window
]


@pytest.mark.parametrize("arch,cfg_fn", PARITY_ARCHS,
                         ids=["gqa_moe", "mla_moe", "windowed"])
def test_megakernel_token_parity(arch, cfg_fn):
    cfg = cfg_fn() if cfg_fn else get_smoke_config(arch)
    t_c, l_c = _decode_tokens(cfg)
    t_m, l_m = _decode_tokens(
        dataclasses.replace(cfg, decode_impl="megakernel"))
    assert t_m == t_c
    for a, b in zip(l_c, l_m):
        np.testing.assert_allclose(b, a, rtol=2e-4, atol=2e-4)


def test_megakernel_token_parity_masked_and_lost_experts():
    """Recovery state (masked expert + fully lost expert) flows through
    the megakernel identically to the composed chain."""
    cfg = get_smoke_config("qwen2-moe-a2.7b")
    cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
        cfg.moe, num_redundant_experts=2))

    def hurt(model):
        rt = model.default_runtime()
        return MoE.MoERuntime(rt.logical_to_physical,
                              rt.replica_count.at[2].set(0),
                              rt.expert_mask.at[3].set(False))

    t_c, _ = _decode_tokens(cfg, runtime_fn=hurt)
    t_m, l_m = _decode_tokens(
        dataclasses.replace(cfg, decode_impl="megakernel"),
        runtime_fn=hurt)
    assert t_m == t_c
    assert all(np.isfinite(lg).all() for lg in l_m)


def test_megastep_zero_recompile_full_step():
    """A jitted megakernel decode_step_paged is retrace-free under every
    per-step change the engine performs: new tokens, new paging arrays,
    and recovery-mutated MoERuntime."""
    cfg = dataclasses.replace(get_smoke_config("qwen2-moe-a2.7b"),
                              decode_impl="megakernel")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    max_batch, nb, bs = 2, 16, 4
    cache = model.init_paged_cache(max_batch, nb, bs)
    f = jax.jit(model.decode_step_paged)
    page = {"tables": jnp.zeros((max_batch, 4), jnp.int32),
            "seq_lens": jnp.asarray([1, 0], jnp.int32),
            "write_bid": jnp.asarray([0, nb], jnp.int32),
            "write_off": jnp.zeros((max_batch,), jnp.int32)}
    toks = jnp.zeros((max_batch,), jnp.int32)
    rt = model.default_runtime()
    _, cache = f(params, cache, toks, page, rt)
    n0 = f._cache_size()
    rt2 = MoE.MoERuntime(rt.logical_to_physical,
                         rt.replica_count.at[0].set(0),
                         rt.expert_mask.at[1].set(False))
    page2 = dict(page, seq_lens=jnp.asarray([2, 0], jnp.int32),
                 write_bid=jnp.asarray([1, nb], jnp.int32),
                 write_off=jnp.asarray([1, 0], jnp.int32))
    lg, _ = f(params, cache, toks + 3, page2, rt2)
    assert f._cache_size() == n0          # §3.4: pure data, no retrace
    assert np.isfinite(np.asarray(lg)).all()


# -- engine-level: serving, recovery, rollback ------------------------------

def _engine(tmp_path, sub, decode_impl=None, num_dp=1, **over):
    cfg = get_smoke_config("qwen2-moe-a2.7b")
    ec = EngineConfig(mode="collocated", num_dp=num_dp, max_batch=2,
                      max_seq=over.pop("max_seq", 64), block_size=8,
                      num_blocks=64, workdir=str(tmp_path / sub),
                      decode_impl=decode_impl,
                      sampling=SamplingParams(temperature=0.8, top_p=0.9,
                                              seed=3), **over)
    return cfg, InferenceEngine(cfg, ec)


def _serve(eng, cfg, prompts, max_new=6):
    reqs = [eng.submit(list(p), max_new) for p in prompts]
    eng.run(max_steps=400)
    assert all(r.state.value == "finished" for r in reqs), \
        [r.state for r in reqs]
    return [list(r.output_tokens) for r in reqs]


def test_engine_chunked_token_parity_and_rollback(tmp_path):
    """Chunked prefill + decode through the compiled megakernel path is
    token-exact vs composed, and a mid-step fault during a megastep
    chunk rolls back via the row-level undo and replays to exactly the
    stream the composed path produces under the identical fault (the
    lost rank carries an expert shard, so the no-fault stream is not
    the reference — the composed engine under the same fault is)."""
    from repro.core.fault_codes import ErrorType, Severity
    rng = np.random.default_rng(9)
    cfg = get_smoke_config("qwen2-moe-a2.7b")
    prompts = [list(rng.integers(0, cfg.vocab_size, 60)),
               list(rng.integers(0, cfg.vocab_size, 58))]

    _, ref = _engine(tmp_path, "ref", None, num_dp=2, max_seq=96)
    want = _serve(ref, cfg, prompts)

    _, mega = _engine(tmp_path, "mega", "megakernel", num_dp=2,
                      max_seq=96)
    got = _serve(mega, cfg, prompts)
    assert got == want

    def fault_run(sub, decode_impl):
        _, eng = _engine(tmp_path, sub, decode_impl, num_dp=2,
                         max_seq=96)
        eng.injector.schedule(2, 1, severity=Severity.L6,
                              error_type=ErrorType.HBM_ECC,
                              component="attn", mid_step=True)
        out = _serve(eng, cfg, prompts)
        surviving = [ex for ex in eng.dp_executors if ex.alive]
        assert surviving and all(
            ex.block_manager.num_allocated == 0 for ex in surviving)
        return out

    want_f = fault_run("fault_ref", None)
    got_f = fault_run("fault_mega", "megakernel")
    assert got_f == want_f


def test_engine_fail_rank_and_mask_zero_recompile(tmp_path):
    """fail_rank + mask_experts on a serving megakernel engine are pure
    MoERuntime data edits: serving continues and the graph cache never
    sees a fresh compile."""
    cfg, eng = _engine(tmp_path, "m", "megakernel", num_dp=2,
                       precompile_failure_scenarios=False)
    rng = np.random.default_rng(4)

    def real_compiles():
        return sum(1 for t in eng.graph_cache.timings
                   if t.compile_s > 0.01)

    _serve(eng, cfg, [list(rng.integers(0, cfg.vocab_size, 12))])
    n0 = real_compiles()
    # recovery's two runtime mutations, applied as the §3.4 data edit
    eng.expert_map.fail_rank(1)
    eng.expert_map.mask_experts(
        [e for e in range(cfg.moe.num_experts)
         if not any(s not in set(eng.expert_map.rank_slots(1))
                    for s in eng.expert_map.replicas_of(e))])
    eng.runtime = eng.expert_map.runtime()
    out = _serve(eng, cfg, [list(rng.integers(0, cfg.vocab_size, 9))])
    assert real_compiles() == n0
    assert out and len(out[0]) == 6


# -- in-instance prefix affinity (ROADMAP paged-KV (i)) ---------------------

def test_assign_prefers_prefix_affine_executor(tmp_path):
    """_assign sends a shared-prefix arrival to the DP rank whose
    BlockManager holds the prefix digests (not the least-loaded one),
    unless that rank is beyond the load-slack guard."""
    cfg, eng = _engine(tmp_path, "aff", None, num_dp=2)
    rng = np.random.default_rng(11)
    sysp = list(rng.integers(0, cfg.vocab_size, 24))  # 3 full blocks

    r0 = eng.submit(sysp + list(rng.integers(0, cfg.vocab_size, 6)), 4)
    eng.run(max_steps=200)
    assert r0.state.value == "finished"
    owner = r0.dp_rank
    other = 1 - owner
    # cached-free blocks keep the digests addressable on the owner
    digests_held = eng.dp_executors[owner].block_manager.cache_hits >= 0

    # load the owner so plain least-loaded would pick the other rank
    from repro.serving.request import Request
    filler = Request(list(rng.integers(0, cfg.vocab_size, 4)), 30)
    eng.dp_executors[owner].scheduler.add_request(filler)

    r1 = eng.submit(sysp + list(rng.integers(0, cfg.vocab_size, 5)), 2)
    assert r1.dp_rank == owner, (r1.dp_rank, owner, digests_held)

    # beyond the slack guard the affinity yields to load balance
    for _ in range(eng.ASSIGN_AFFINITY_SLACK + 1):
        eng.dp_executors[owner].scheduler.add_request(
            Request(list(rng.integers(0, cfg.vocab_size, 4)), 30))
    r2 = eng.submit(sysp + list(rng.integers(0, cfg.vocab_size, 7)), 2)
    assert r2.dp_rank == other
