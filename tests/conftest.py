import os

# Smoke tests and benches must see the single real CPU device — the 512
# placeholder devices are ONLY for the dry-run (set inside dryrun.py).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
