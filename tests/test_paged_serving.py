"""Paged-KV serving path: the engine's block-pool cache must reproduce
the dense ring-cache decode exactly (the compiled serving path equals the
reference semantics) across GQA, MLA, windowed, hybrid and SSM configs —
including after a §3.3 rollback, and across KV-block-streamed migration
(token-exact vs the re-prefill fallback).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.block_log import BlockLog, BlockManager, BlockTable
from repro.models import attention as A
from repro.models.layers import apply_rope, rope_sincos
from repro.models.model import Model
from repro.serving import cache_ops
from repro.serving.kvcache import (PagedKVCache, build_page_context,
                                   padded_block_ids, table_array)

KEY = jax.random.PRNGKey(3)


def test_paged_attention_equals_ring_decode():
    """One GQA layer: write a prompt's K/V through block tables, then
    decode one token both ways (ring cache vs paged pools+kernel)."""
    cfg = get_smoke_config("internlm2-20b")
    p = A.gqa_init(KEY, cfg)
    B, S = 2, 13
    bs = 4
    x_prompt = jax.random.normal(KEY, (B, S, cfg.d_model)) * 0.3
    x_new = jax.random.normal(jax.random.fold_in(KEY, 1),
                              (B, cfg.d_model)) * 0.3
    positions = jnp.arange(S)

    # --- ring-cache reference path
    _, (k_full, v_full) = A.gqa_forward(p, cfg, x_prompt, positions,
                                        return_kv=True)
    from repro.models.model import _ring_from_full
    ring = _ring_from_full(k_full, v_full, positions, 0, max_seq=32)
    pos = jnp.full((B,), S, jnp.int32)
    y_ref, _ = A.gqa_decode(p, cfg, x_new, ring, pos)

    # --- paged path: allocate blocks through the (logged) manager
    manager = BlockManager(num_blocks=32, block_size=bs)
    log = BlockLog()
    log.begin_step()
    tables = {}
    need = (S + 1 + bs - 1) // bs
    for seq in range(B):
        t = BlockTable(seq)
        for _ in range(need):
            t.append_block(manager.allocate(log), log)
        tables[seq] = t

    cache = PagedKVCache(cfg, num_layers=1, num_blocks=32, block_size=bs)
    for seq in range(B):
        cache.write_prefill(0, tables[seq].blocks, k_full[seq], v_full[seq])

    # the new token's k/v (with rope at position S) lands in its slot
    Dh = cfg.resolved_head_dim()
    k_new = (x_new @ p["wk"]).reshape(B, cfg.num_kv_heads, Dh)
    v_new = (x_new @ p["wv"]).reshape(B, cfg.num_kv_heads, Dh)
    q_new = (x_new @ p["wq"]).reshape(B, cfg.num_heads, Dh)
    sin, cos = rope_sincos(pos, Dh, cfg.rope_theta)
    k_new = apply_rope(k_new, sin[:, None, :], cos[:, None, :])
    q_new = apply_rope(q_new, sin[:, None, :], cos[:, None, :])
    for seq in range(B):
        bid = tables[seq].blocks[S // bs]
        cache.write_token(0, bid, S % bs, k_new[seq], v_new[seq])

    bt = jnp.asarray(table_array(tables, [0, 1], max_blk=need))
    seq_lens = jnp.full((B,), S + 1, jnp.int32)
    # jnp oracle and Pallas kernel (interpret) must both match the ring
    for use_pallas in (False, True):
        attn = cache.attend(0, q_new, bt, seq_lens, use_pallas=use_pallas)
        y_paged = attn.reshape(B, -1) @ p["wo"]
        np.testing.assert_allclose(np.asarray(y_paged), np.asarray(y_ref),
                                   rtol=2e-4, atol=2e-4)


def test_paged_pools_survive_block_log_rollback():
    """Blocks allocated mid-step and rolled back are returned to the free
    list; the pool rows they touched are dead (never referenced again)."""
    manager = BlockManager(num_blocks=8, block_size=4)
    log = BlockLog()
    t = BlockTable(0)
    log.begin_step()
    committed = manager.allocate(log)
    t.append_block(committed, log)
    log.begin_step()          # commit
    free_before = manager.num_free
    # in-flight step allocates one more block, then the device fails
    b2 = manager.allocate(log)
    t.append_block(b2, log)
    log.undo_all(manager, {0: t})
    assert manager.num_free == free_before
    assert t.blocks == [committed]
    # re-allocation reuses the rolled-back block id: no leak
    b3 = manager.allocate()
    assert b3 == b2


# -- dense-vs-paged decode parity across architectures ----------------------
#
# The ring caches in repro.models are the reference decode semantics; the
# engine's compiled path is the paged cache.  For every family the engine
# serves, N decode steps through both paths must agree numerically.

def _windowed_internlm():
    cfg = get_smoke_config("internlm2-20b")
    return dataclasses.replace(cfg, sliding_window=16)


PARITY_ARCHS = [
    ("qwen2-moe-a2.7b", None),          # GQA + MoE
    ("minicpm3-4b", None),              # MLA (latent pool)
    ("internlm2-20b", _windowed_internlm),  # GQA + sliding window
]
PARITY_ARCHS_SLOW = [
    ("jamba-1.5-large-398b", None),     # hybrid: pools + SSM state
    ("falcon-mamba-7b", None),          # pure SSM: state only
]


def _run_parity(arch, cfg_fn, n_decode=5):
    cfg = cfg_fn() if cfg_fn else get_smoke_config(arch)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    max_seq, bs, nb, max_batch = 32, 4, 24, 2
    max_blk = (max_seq + bs - 1) // bs
    rng = np.random.default_rng(0)
    toks = list(rng.integers(0, cfg.vocab_size, 9))
    Sp = len(toks)
    batch = {"tokens": jnp.asarray([toks + [0] * (16 - Sp)], jnp.int32),
             "lengths": jnp.asarray([Sp], jnp.int32)}

    # ring reference: prefill into slot 1 of a batched ring cache
    last_r, sub = model.prefill(params, batch, max_seq=max_seq)
    ring = model.init_cache(max_batch, max_seq)
    axes_r = cache_ops.infer_batch_axes(model, max_seq)
    ring = cache_ops.write_slot(ring, sub, 1, axes_r)

    # paged: prefill raw K/V, scatter into blocks of slot 1
    last_p, raw = model.prefill_paged(params, batch)
    np.testing.assert_allclose(np.asarray(last_p), np.asarray(last_r),
                               rtol=1e-4, atol=1e-4)
    cache = model.init_paged_cache(max_batch, nb, bs)
    _, axes = cache_ops.infer_paged_axes(model, nb, bs)
    man = BlockManager(nb, bs)
    table = BlockTable(7)
    for _ in range((Sp + 1 + bs - 1) // bs):
        table.append_block(man.allocate())
    bids = padded_block_ids(table.blocks, (16 + bs - 1) // bs,
                            trash_block=nb)
    cache = cache_ops.install_prefill(cache, raw, axes,
                                      jnp.asarray(bids), jnp.int32(1))

    class _R:
        batch_slot, req_id = 1, 7
    req = _R()
    tok = int(np.argmax(np.asarray(last_r)[0]))
    ntok = Sp + 1
    tokens = np.zeros((max_batch,), np.int32)
    for _ in range(n_decode):
        tokens[1] = tok
        lr, ring = model.decode_step(params, ring, jnp.asarray(tokens))
        req.num_tokens = ntok
        if (ntok - 1) // bs >= table.num_blocks():
            table.append_block(man.allocate())
        page = build_page_context([req], {7: table}, max_batch=max_batch,
                                  max_blk=max_blk, block_size=bs,
                                  trash_block=nb)
        page = {k: jnp.asarray(v) for k, v in page.items()}
        lp, cache = model.decode_step_paged(params, cache,
                                            jnp.asarray(tokens), page)
        a, b = np.asarray(lr)[1], np.asarray(lp)[1]
        np.testing.assert_allclose(b, a, rtol=2e-4, atol=2e-4)
        tok = int(np.argmax(a))
        ntok += 1


@pytest.mark.parametrize("arch,cfg_fn", PARITY_ARCHS,
                         ids=[a for a, _ in PARITY_ARCHS])
def test_dense_vs_paged_decode_parity(arch, cfg_fn):
    _run_parity(arch, cfg_fn)


@pytest.mark.slow
@pytest.mark.parametrize("arch,cfg_fn", PARITY_ARCHS_SLOW,
                         ids=[a for a, _ in PARITY_ARCHS_SLOW])
def test_dense_vs_paged_decode_parity_slow(arch, cfg_fn):
    _run_parity(arch, cfg_fn)


# -- executor-level invariants: rollback-then-migrate -----------------------


class _DirectCtx:
    """Uncompiled executor context: model functions called eagerly."""

    def __init__(self, model, params, executor):
        self.model = model
        self.params = params
        self.runtime = model.default_runtime()
        self.ex = executor

    def decode_fn(self, params, cache, tokens, page, runtime):
        page = {k: jnp.asarray(v) for k, v in page.items()}
        return self.model.decode_step_paged(params, cache,
                                            jnp.asarray(tokens), page,
                                            runtime)

    def chunk_fn(self):
        # chunked prefill shares the decode step (virtual token slots)
        return self.decode_fn

    def prefill_fn(self, bucket):
        def fn(params, tokens, lengths, runtime):
            return self.model.prefill_paged(
                params, {"tokens": jnp.asarray(tokens),
                         "lengths": jnp.asarray(lengths)}, runtime)
        return fn

    def install_fn(self, bucket):
        def fn(cache, raw, bids, slot):
            return cache_ops.install_prefill(
                cache, raw, self.ex.paged_axes, jnp.asarray(bids),
                jnp.int32(slot))
        return fn


def _executor(model, dp_rank=0, pool_undo="rows"):
    from repro.serving.executor import DPExecutor
    from repro.serving.sampling import SamplingParams
    return DPExecutor(physical_id=dp_rank, dp_rank=dp_rank, model=model,
                      max_batch=2, max_seq=32, num_blocks=16, block_size=4,
                      sampling=SamplingParams(), pool_undo=pool_undo)


@pytest.mark.parametrize("pool_undo", ["rows", "snapshot"])
def test_rollback_then_migrate_pool_and_table_consistency(pool_undo):
    """§3.3 + §3.2 composed: a mid-step fault rolls the executor back to
    the step boundary (block tables from the op log, pools by restoring
    the captured write-set rows — or, legacy, the functional snapshot —
    bit-identical either way), and the rolled-back executor can then
    stream a resident's KV blocks to a peer that continues the exact
    token sequence."""
    from repro.serving.request import Request, RequestState
    cfg = get_smoke_config("internlm2-20b")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    ex = _executor(model, 0, pool_undo=pool_undo)
    ctx = _DirectCtx(model, params, ex)

    rng = np.random.default_rng(1)
    r1 = Request(list(rng.integers(0, cfg.vocab_size, 6)), 8)
    ex.scheduler.add_request(r1)
    # step 1: prefill r1; step 2: decode — both committed
    for step in (1, 2):
        ex.plan()
        ex.compute(ctx, step)
        ex.commit()
    cache_at_boundary = ex.cache
    snap = ex.block_manager.snapshot()
    tokens_before = list(r1.output_tokens)

    # reference: an identical unmolested executor decodes r1's next token
    ex_ref = _executor(model, 1)
    ctx_ref = _DirectCtx(model, params, ex_ref)
    r_ref = Request(list(r1.prompt_tokens), 8)
    ex_ref.scheduler.add_request(r_ref)
    for step in (1, 2, 3):
        ex_ref.plan()
        ex_ref.compute(ctx_ref, step)
        ex_ref.commit()

    # in-flight step admits r2 and allocates blocks... then the fault
    r2 = Request(list(rng.integers(0, cfg.vocab_size, 5)), 8)
    ex.scheduler.add_request(r2)
    ex.plan()
    assert len(ex.block_log) > 0
    undone = ex.rollback_inflight()
    assert undone > 0
    # pool consistency: the cache equals the step-boundary value exactly
    # (snapshot mode restores the identical object; row mode scatters
    # the captured write-set rows back), tables/manager match it
    if pool_undo == "snapshot":
        assert ex.cache is cache_at_boundary
    else:
        for a, b in zip(jax.tree_util.tree_leaves(ex.cache),
                        jax.tree_util.tree_leaves(cache_at_boundary)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert ex.block_manager.snapshot() == snap
    assert r1.output_tokens == tokens_before
    ex.scheduler.check_consistent()
    assert ex.scheduler.waiting[0] is r2     # aborted admission requeued

    # migrate r1 by KV-block stream to a fresh peer; its next decoded
    # token must equal the unmigrated reference's
    kv = ex.export_kv_blocks(r1)
    assert kv is not None and kv.valid_len == r1.num_tokens - 1
    ex2 = _executor(model, 2)
    ctx2 = _DirectCtx(model, params, ex2)
    assert ex2.import_kv_blocks(r1, kv)
    ex2.scheduler.check_consistent()
    ex2.plan()
    ex2.compute(ctx2, 1)
    ex2.commit()
    assert r1.output_tokens[-1] == r_ref.output_tokens[len(tokens_before)]
    assert r1.recomputed_tokens == 0


def test_import_kv_blocks_refuses_without_capacity():
    """The stream install is all-or-nothing: no slot or not enough free
    blocks -> False, and the target's accounting is untouched (callers
    fall back to token replay)."""
    from repro.serving.request import Request
    cfg = get_smoke_config("internlm2-20b")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    ex = _executor(model, 0)
    ctx = _DirectCtx(model, params, ex)
    r1 = Request(list(np.random.default_rng(2).integers(
        0, cfg.vocab_size, 6)), 8)
    ex.scheduler.add_request(r1)
    for step in (1, 2):
        ex.plan()
        ex.compute(ctx, step)
        ex.commit()
    kv = ex.export_kv_blocks(r1)
    assert kv is not None

    tgt = _executor(model, 1)
    tgt.scheduler._free_slots = []           # no batch slot
    before = tgt.block_manager.snapshot()
    assert not tgt.import_kv_blocks(r1, kv)
    assert tgt.block_manager.snapshot() == before

    tgt2 = _executor(model, 2)
    while tgt2.block_manager.num_free > 1:   # not enough blocks
        tgt2.block_manager.allocate()
    assert not tgt2.import_kv_blocks(r1, kv)
    tgt2.scheduler.check_consistent()


# -- engine-level: KV-stream vs re-prefill token-exact equivalence ----------


def test_kv_stream_equals_reprefill_tokens(tmp_path):
    """Acceptance: migrating a mid-generation request by KV-block stream
    and by token-replay re-prefill produces the identical token sequence;
    only the replay path pays recomputed tokens."""
    from repro.serving.engine import EngineConfig, InferenceEngine
    from repro.serving.sampling import SamplingParams
    cfg = get_smoke_config("qwen2-moe-a2.7b")
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, num_experts=4,
                                     num_redundant_experts=2, top_k=2,
                                     capacity_factor=8.0, min_capacity=64))
    ecfg = EngineConfig(mode="collocated", num_dp=1, max_batch=2,
                        max_seq=64, block_size=8, num_blocks=32,
                        workdir=str(tmp_path),
                        sampling=SamplingParams(temperature=0.8,
                                                top_p=0.9, seed=7))
    src = InferenceEngine(cfg, ecfg)
    tgt = InferenceEngine(cfg, ecfg)
    prompt = list(np.random.default_rng(5).integers(0, cfg.vocab_size, 9))

    outs = {}
    for mode in ("stream", "replay"):
        req = src.submit(list(prompt), 12)
        for _ in range(4):
            src.step()
        assert 0 < len(req.output_tokens) < 12
        if mode == "stream":
            (req2, kv), = src.export_live_requests(with_kv=True)
            assert req2 is req and kv is not None
        else:
            (req2,) = src.export_live_requests()
            assert req2 is req
            kv = None
        tgt.admit(req, kv=kv)
        tgt.run(max_steps=80)
        assert req.state.value == "finished"
        outs[mode] = (list(req.output_tokens), req.recomputed_tokens)

    assert outs["stream"][0] == outs["replay"][0]
    assert outs["stream"][1] == 0            # no re-prefill when streamed
    assert outs["replay"][1] > 0             # fallback pays the replay
