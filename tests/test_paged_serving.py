"""Paged-KV serving path: block tables + pools + paged attention must
reproduce the ring-cache decode exactly (the TPU data path equals the
reference semantics), including after a §3.3 rollback.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core.block_log import BlockLog, BlockManager, BlockTable
from repro.models import attention as A
from repro.models.layers import apply_rope, rope_sincos
from repro.serving.kvcache import PagedKVCache, table_array

KEY = jax.random.PRNGKey(3)


def test_paged_attention_equals_ring_decode():
    """One GQA layer: write a prompt's K/V through block tables, then
    decode one token both ways (ring cache vs paged pools+kernel)."""
    cfg = get_smoke_config("internlm2-20b")
    p = A.gqa_init(KEY, cfg)
    B, S = 2, 13
    bs = 4
    x_prompt = jax.random.normal(KEY, (B, S, cfg.d_model)) * 0.3
    x_new = jax.random.normal(jax.random.fold_in(KEY, 1),
                              (B, cfg.d_model)) * 0.3
    positions = jnp.arange(S)

    # --- ring-cache reference path
    _, (k_full, v_full) = A.gqa_forward(p, cfg, x_prompt, positions,
                                        return_kv=True)
    from repro.models.model import _ring_from_full
    ring = _ring_from_full(k_full, v_full, positions, 0, max_seq=32)
    pos = jnp.full((B,), S, jnp.int32)
    y_ref, _ = A.gqa_decode(p, cfg, x_new, ring, pos)

    # --- paged path: allocate blocks through the (logged) manager
    manager = BlockManager(num_blocks=32, block_size=bs)
    log = BlockLog()
    log.begin_step()
    tables = {}
    need = (S + 1 + bs - 1) // bs
    for seq in range(B):
        t = BlockTable(seq)
        for _ in range(need):
            t.append_block(manager.allocate(log), log)
        tables[seq] = t

    cache = PagedKVCache(cfg, num_layers=1, num_blocks=32, block_size=bs)
    for seq in range(B):
        cache.write_prefill(0, tables[seq].blocks, k_full[seq], v_full[seq])

    # the new token's k/v (with rope at position S) lands in its slot
    Dh = cfg.resolved_head_dim()
    k_new = (x_new @ p["wk"]).reshape(B, cfg.num_kv_heads, Dh)
    v_new = (x_new @ p["wv"]).reshape(B, cfg.num_kv_heads, Dh)
    q_new = (x_new @ p["wq"]).reshape(B, cfg.num_heads, Dh)
    sin, cos = rope_sincos(pos, Dh, cfg.rope_theta)
    k_new = apply_rope(k_new, sin[:, None, :], cos[:, None, :])
    q_new = apply_rope(q_new, sin[:, None, :], cos[:, None, :])
    for seq in range(B):
        bid = tables[seq].blocks[S // bs]
        cache.write_token(0, bid, S % bs, k_new[seq], v_new[seq])

    bt = jnp.asarray(table_array(tables, [0, 1], max_blk=need))
    seq_lens = jnp.full((B,), S + 1, jnp.int32)
    # jnp oracle and Pallas kernel (interpret) must both match the ring
    for use_pallas in (False, True):
        attn = cache.attend(0, q_new, bt, seq_lens, use_pallas=use_pallas)
        y_paged = attn.reshape(B, -1) @ p["wo"]
        np.testing.assert_allclose(np.asarray(y_paged), np.asarray(y_ref),
                                   rtol=2e-4, atol=2e-4)


def test_paged_pools_survive_block_log_rollback():
    """Blocks allocated mid-step and rolled back are returned to the free
    list; the pool rows they touched are dead (never referenced again)."""
    manager = BlockManager(num_blocks=8, block_size=4)
    log = BlockLog()
    t = BlockTable(0)
    log.begin_step()
    committed = manager.allocate(log)
    t.append_block(committed, log)
    log.begin_step()          # commit
    free_before = manager.num_free
    # in-flight step allocates one more block, then the device fails
    b2 = manager.allocate(log)
    t.append_block(b2, log)
    log.undo_all(manager, {0: t})
    assert manager.num_free == free_before
    assert t.blocks == [committed]
    # re-allocation reuses the rolled-back block id: no leak
    b3 = manager.allocate()
    assert b3 == b2
