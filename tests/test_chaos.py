"""Chaos campaign driver tests: seeded schedules, clearable faults and
device rejoin, advance-notice drain, backpressure under exhausted
capacity, arbiter decision boundaries, SLO-burn scoring, and campaign
forensics determinism.

Engine-backed tests share one module workdir (shared checkpoint +
compile cache), same as test_fleet.
"""
import dataclasses
import json
from types import SimpleNamespace

import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.fault_codes import ErrorType, Severity
from repro.core.faults import FaultInjector
from repro.fleet import (CampaignRunner, CampaignSchedule, DiurnalTraffic,
                         MixedTraffic, PoissonTraffic, RecoveryArbiter,
                         TraceTraffic, VirtualCostProfile, build_fleet,
                         build_multi_model_fleet, fleet_topology,
                         slo_burn)
from repro.serving.engine import EngineConfig, InferenceEngine

TOPO = {
    0: {"model_id": "a", "groups": {"attn": [0, 1], "moe": [2, 3]}},
    1: {"model_id": "a", "groups": {"attn": [0, 1], "moe": [2, 3]}},
    2: {"model_id": "b", "groups": {"attn": [0, 1], "moe": [2, 3]}},
}


def fleet_cfg():
    cfg = get_smoke_config("qwen2-moe-a2.7b")
    return dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, num_experts=4,
                                     num_redundant_experts=2, top_k=2,
                                     capacity_factor=8.0,
                                     min_capacity=64))


def fleet_ecfg(workdir, **kw):
    base = dict(mode="disaggregated", num_dp=2, num_moe=2, max_batch=2,
                max_seq=64, block_size=8, num_blocks=64, workdir=workdir)
    base.update(kw)
    return EngineConfig(**base)


@pytest.fixture(scope="module")
def shared_workdir(tmp_path_factory):
    return str(tmp_path_factory.mktemp("chaos"))


PROMPT = list(np.random.default_rng(3).integers(0, 512, 9))
PROFILE = VirtualCostProfile()


def _compose(seed):
    return (CampaignSchedule(seed, horizon_s=100.0)
            .device_faults(TOPO, rate_per_s=0.05)
            .rack_loss(TOPO, rate_per_s=0.02)
            .cascading_stragglers(TOPO, start_s=10.0, spacing_s=5.0,
                                  n=3)
            .flapping_link(TOPO, start_s=30.0, n_flaps=2)
            .spot_wave(TOPO, at_s=60.0, n_instances=2, notice_s=5.0)
            .rolling_upgrade(TOPO, start_s=80.0, spacing_s=5.0)
            .instance_loss(TOPO, rate_per_s=0.01)
            .build())


# -- schedule generation (pure) ---------------------------------------------------


def test_schedule_seeded_and_composable():
    a, b = _compose(7), _compose(7)
    assert a == b, "same seed + same composition must be identical"
    assert a != _compose(8), "different seed must differ"
    assert all(x.at_s <= y.at_s for x, y in zip(a, a[1:]))
    assert all(e.at_s < 100.0 for e in a)
    kinds = {e.kind for e in a}
    # every composed process contributed at least one event
    assert {"device_fault", "rack_loss", "straggler", "fault_clear",
            "spot_notice", "spot_preempt", "upgrade"} <= kinds
    # rack loss is correlated: every rank of one comm group together
    rack = next(e for e in a if e.kind == "rack_loss")
    assert sorted(rack.ranks) in ([0, 1], [2, 3])
    # spot preemptions carry advance notice
    notices = [e for e in a if e.kind == "spot_notice"]
    preempts = [e for e in a if e.kind == "spot_preempt"]
    assert len(notices) == len(preempts) == 2
    for n, p in zip(sorted(notices, key=lambda e: e.iid),
                    sorted(preempts, key=lambda e: e.iid)):
        assert n.iid == p.iid and n.at_s < p.at_s


# -- fault injector lifecycle (pure) ---------------------------------------------


def test_injector_dedup_cancel_clear_reset():
    inj = FaultInjector()
    f1 = inj.schedule(5, 0)
    assert inj.schedule(5, 0) is f1, "identical pending entry reused"
    assert inj.deduped == 1
    # cancel: by handle, by rank, then fire what remains
    f2 = inj.schedule(6, 1, mid_step=True)
    assert inj.cancel(f2) == 1
    inj.schedule(7, 1)
    inj.schedule(8, 1)
    assert inj.cancel(physical_id=1) == 2
    assert [f.physical_id for f in inj.scheduled] == [0]
    assert len(inj.pre_step_faults(5)) == 1
    # rank 0 is down: further faults on it are swallowed, not
    # re-annotated
    inj.schedule(9, 0)
    assert inj.pre_step_faults(9) == []
    assert inj.deduped == 2
    assert len(inj.annotations) == 1
    # clear re-opens the rank for new faults
    assert inj.clear(0) is True
    assert inj.clear(0) is False
    inj.schedule(11, 0)
    assert len(inj.pre_step_faults(11)) == 1
    # recurring: clear re-arms, and the re-armed fault fires on the next
    # step even though its at_step has passed (flapping-link shape)
    r = inj.schedule(20, 2, recurring=True)
    assert len(inj.pre_step_faults(20)) == 1
    inj.clear(2)
    assert r.fired is False
    assert len(inj.pre_step_faults(25)) == 1
    # reset: pristine injector, reusable across campaign episodes
    inj.reset()
    assert (inj.scheduled, inj.annotations, inj.deduped) == ([], [], 0)
    inj.schedule(5, 0)
    assert len(inj.pre_step_faults(5)) == 1


# -- SLO-burn scoring (pure) ------------------------------------------------------


def test_slo_burn_math():
    rows = [
        # window 0: worst TTFT 2.0s vs 1.0s target -> burns 1.0 * 10s
        {"arrival_s": 1.0, "first_token_s": 1.2, "finish_s": 5.0,
         "n_out": 11},
        {"arrival_s": 2.0, "first_token_s": 4.0, "finish_s": 6.0,
         "n_out": 11},
        # window 1: within target -> no burn
        {"arrival_s": 12.0, "first_token_s": 12.5, "finish_s": 14.0,
         "n_out": 11},
        # never served: censored at the horizon (20 - 15 = 5s TTFT)
        {"arrival_s": 15.0, "first_token_s": None, "finish_s": None,
         "n_out": 0},
    ]
    out = slo_burn(rows, ttft_target_s=1.0, window_s=10.0, q=1.0,
                   horizon_s=20.0)
    assert out["n_unserved"] == 1
    assert out["ttft_burn_s"] == pytest.approx((2.0 - 1.0) * 10.0
                                               + (5.0 - 1.0) * 10.0)
    # TPOT: window 0 worst is (5.0 - 1.2) / 10 = 0.38, window 1 worst
    # is (14.0 - 12.5) / 10 = 0.15, vs a 0.1 target
    out = slo_burn(rows, ttft_target_s=10.0, tpot_target_s=0.1,
                   window_s=10.0, q=1.0, horizon_s=20.0)
    assert out["ttft_burn_s"] == 0.0
    assert out["tpot_burn_s"] == pytest.approx(
        (0.38 - 0.1) * 10.0 + (0.15 - 0.1) * 10.0)
    assert slo_burn([], ttft_target_s=1.0)["total_burn_s"] == 0.0


# -- arbiter decision boundaries (property-style sweep) ---------------------------


def _fake_inst(iid, n_inflight, tokens_per_req):
    reqs = [SimpleNamespace(num_tokens=tokens_per_req,
                            state=SimpleNamespace(value="running"))
            for _ in range(n_inflight)]
    eng = SimpleNamespace(all_requests=reqs, unfinished=n_inflight)
    return SimpleNamespace(iid=iid, load=n_inflight, engine=eng,
                           model_id="default")


def test_arbiter_decision_boundaries_sweep():
    """Under every (load, spare availability, fault class, forced
    policy) combination: the chosen action is feasible, cost-minimal
    when free, the forced policy when feasible, and the deterministic
    restart fallback when forced-but-infeasible."""
    rng = np.random.default_rng(42)
    policies = (None, "revive", "restart", "spare")
    for trial in range(300):
        n = int(rng.integers(1, 12))
        tokens = int(rng.integers(4, 200))
        spare = bool(rng.integers(2))
        lost = bool(rng.integers(2))
        force = policies[int(rng.integers(4))]
        arb = RecoveryArbiter(PROFILE.cost_model(), force_policy=force)
        inst = _fake_inst(trial, n, tokens)
        dec = arb.decide(inst, None, spare_available=spare,
                         instance_lost=lost)
        feasible = {"revive", "restart", "spare"}
        if lost:
            feasible.discard("revive")
        if not spare:
            feasible.discard("spare")
        ctx = dict(n=n, tokens=tokens, spare=spare, lost=lost,
                   force=force, dec=dec)
        assert dec.policy in feasible, ctx
        assert set(dec.est_cost) == {"revive", "restart", "spare"}, ctx
        if force in feasible:
            assert dec.policy == force, ctx
        elif force is not None:
            assert dec.policy == "restart", ctx
            assert "fell back" in dec.reason, ctx
        else:
            best = min(feasible, key=lambda p: dec.est_cost[p])
            assert dec.est_cost[dec.policy] == dec.est_cost[best], ctx
        # estimates scale with in-flight load
        assert dec.est_cost["restart"] == pytest.approx(
            PROFILE.restart_s * n), ctx


# -- device rejoin after a cleared transient fault (engine level) -----------------


def test_flapping_link_clear_and_rejoin(shared_workdir):
    eng = InferenceEngine(fleet_cfg(), fleet_ecfg(shared_workdir))
    req = eng.submit(PROMPT, 10)
    eng.injector.schedule(3, 1, severity=Severity.L4,
                          error_type=ErrorType.LINK_DOWN,
                          component="attn")
    for _ in range(6):
        eng.step()
    assert len(eng.reports) == 1
    assert not eng.domain.device(1).alive
    # link restored: the device rejoins with a fresh logical rank
    ver = eng.domain.version
    assert eng.rejoin_device(1) is True
    assert eng.domain.device(1).alive
    assert eng.domain.version == ver + 1
    assert eng.rejoin_device(1) is False, "already alive: no-op"
    # and it is faultable again — the second flap re-annotates
    eng.injector.schedule(eng.step_no + 1, 1, severity=Severity.L4,
                          error_type=ErrorType.LINK_DOWN,
                          component="attn")
    for _ in range(4):
        eng.step()
    assert len(eng.reports) == 2, "second flap must fire after rejoin"
    eng.rejoin_device(1)
    eng.run(max_steps=200)
    assert req.state.value == "finished"


# -- advance-notice drain (planned faults migrate, not abort) ---------------------


def test_drain_with_notice_migrates_residents(shared_workdir):
    fleet = build_fleet(fleet_cfg(), fleet_ecfg(shared_workdir),
                        instances=2, cost_profile=PROFILE)
    req = fleet.submit(PROMPT, 12)
    for _ in range(4):
        fleet.tick()
    assert 0 < len(req.output_tokens) < 12, "must be mid-generation"
    src = req.instance_id
    moved = fleet.drain_instance(src, reason="spot notice")
    assert moved == 1
    assert req.instance_id != src, "resident migrated ahead of the fault"
    # the planned kill now hits an empty instance: nobody re-homes
    fleet.planned_restart(src)
    fleet.run(max_ticks=400)
    assert req.state.value == "finished"
    assert req.cross_instance_migrations == 1
    kinds = [e["policy"] for e in fleet.forensics]
    assert "drain" in kinds and "restart" in kinds
    restart_ev = next(e for e in fleet.forensics
                      if e["policy"] == "restart")
    assert restart_ev["planned"] is True
    assert restart_ev["charged_s"] == pytest.approx(PROFILE.restart_s)


# -- exhausted capacity: backpressure instead of dead-instance routing ------------


def test_spare_exhausted_burst_backpressure(shared_workdir):
    fleet = build_fleet(fleet_cfg(), fleet_ecfg(shared_workdir),
                        instances=2, spares=0, cost_profile=PROFILE,
                        max_backlog=2)
    r1 = fleet.submit(PROMPT, 6)
    fleet.tick()
    # multi-fault burst with no spares and no rebuildable hosts
    fleet.lose_instance(0, reason="spot preemption", rebuild=False)
    fleet.lose_instance(1, reason="spot preemption", rebuild=False)
    fleet.lose_instance(1, reason="duplicate loss", rebuild=False)  # no-op
    health = fleet.fleet_health()
    assert health.state == "critical"
    assert health.serving == 0
    assert health.backlog >= 1
    # new arrivals queue at the gateway (no RuntimeError, no routing to
    # a dead instance), and beyond max_backlog they shed
    r2 = fleet.submit(PROMPT, 4)
    assert r2.state.value == "waiting"
    r3 = fleet.submit(PROMPT, 4)
    assert r3.state.value == "failed" and fleet.shed_requests == 1
    fleet.tick()
    assert r1.state.value not in ("finished",) or True
    assert fleet.fleet_health().state == "critical"


def test_concurrent_instance_loss_with_rebuild(shared_workdir):
    """Regression: two lose_instance calls in one burst (the second
    while the first is still frozen in its rebuild) must re-home and
    finish everything."""
    fleet = build_fleet(fleet_cfg(), fleet_ecfg(shared_workdir),
                        instances=2, cost_profile=PROFILE)
    reqs = [fleet.submit(PROMPT, 8), fleet.submit(PROMPT, 8)]
    for _ in range(3):
        fleet.tick()
    fleet.lose_instance(0, "burst loss 1")
    fleet.lose_instance(1, "burst loss 2")
    fleet.run(max_ticks=600)
    assert all(r.state.value == "finished" for r in reqs)
    assert fleet.shed_requests == 0
    restarts = [e for e in fleet.forensics if e["policy"] == "restart"]
    assert len(restarts) == 2
    for e in restarts:
        assert e["charged_s"] == pytest.approx(PROFILE.restart_s)
        assert "counterfactual_s" in e


# -- spare substitution restores a starved model ----------------------------------


def test_backlog_drains_when_spare_joins(shared_workdir):
    fleet = build_fleet(fleet_cfg(), fleet_ecfg(shared_workdir),
                        instances=1, spares=1, cost_profile=PROFILE)
    # consume the only instance without rebuild while a spare is warm:
    # the arbiter substitutes, so service continues
    r1 = fleet.submit(PROMPT, 6)
    fleet.tick()
    fleet.lose_instance(0, "host loss", rebuild=False)
    assert any(i.accepting for i in fleet.instances.values())
    fleet.run(max_ticks=400)
    assert r1.state.value == "finished"
    assert fleet.spares.activations == 1


# -- multi-model fleets: routing + evict-and-rebalance ----------------------------


def test_multi_model_routing_and_rebalance(shared_workdir):
    cfg = fleet_cfg()
    ecfg = fleet_ecfg(shared_workdir)
    fleet = build_multi_model_fleet(
        {"alpha": (cfg, ecfg), "beta": (cfg, ecfg)},
        counts={"alpha": 2, "beta": 1}, cost_profile=PROFILE,
        rebalance=True)
    beta_iid = next(i.iid for i in fleet.serving()
                    if i.model_id == "beta")
    ra = fleet.submit(PROMPT, 6, model_id="alpha")
    rb = fleet.submit(PROMPT, 6, model_id="beta")
    assert fleet.instances[ra.instance_id].model_id == "alpha"
    assert rb.instance_id == beta_iid, "model routing must match"
    for _ in range(3):
        fleet.tick()
    # the only beta instance is preempted for good: serving beta again
    # requires evicting an over-provisioned alpha instance
    fleet.lose_instance(beta_iid, "spot preemption", rebuild=False)
    rebalances = [e for e in fleet.forensics
                  if e["policy"] == "rebalance"]
    assert len(rebalances) == 1
    assert any(i.model_id == "beta" and i.state.value in
               ("serving",) for i in fleet.instances.values())
    fleet.run(max_ticks=600)
    assert ra.state.value == "finished"
    assert rb.state.value == "finished"
    # fresh beta arrivals route to the rebuilt instance
    rb2 = fleet.submit(PROMPT, 4, model_id="beta")
    assert fleet.instances[rb2.instance_id].model_id == "beta"
    fleet.run(max_ticks=300)
    assert rb2.state.value == "finished"


# -- campaign end-to-end: determinism of the forensics document -------------------


def _mini_campaign(workdir):
    cfg, prof = fleet_cfg(), VirtualCostProfile()
    traffic = DiurnalTraffic(1.5, cfg.vocab_size, amplitude=0.5,
                             period_s=20.0, prompt_len=8,
                             max_new_tokens=6, seed=11, limit=12)
    fleet = build_fleet(cfg, fleet_ecfg(workdir), instances=2, spares=1,
                        traffic=traffic, cost_profile=prof)
    topo = fleet_topology(fleet)
    events = (CampaignSchedule(seed=9, horizon_s=20.0)
              .instance_loss(topo, rate_per_s=0.03)
              .flapping_link(topo, start_s=4.0, n_flaps=2, down_s=1.5,
                             up_s=3.0)
              .rolling_upgrade(topo, start_s=14.0, spacing_s=3.0)
              .build())
    runner = CampaignRunner(fleet, events, seed=9, profile=prof,
                            ttft_target_s=0.5, tpot_target_s=0.2,
                            slo_window_s=5.0)
    res = runner.run()
    return res, fleet


def test_campaign_forensics_deterministic(shared_workdir):
    res1, fleet1 = _mini_campaign(shared_workdir)
    res2, fleet2 = _mini_campaign(shared_workdir)
    assert fleet1.unfinished == 0
    assert res1.events_applied > 0
    j1 = json.dumps(res1.forensics, sort_keys=True)
    j2 = json.dumps(res2.forensics, sort_keys=True)
    assert j1 == j2, "same campaign seed must be byte-identical"
    # the document carries the decision + counterfactual table
    recov = res1.forensics["recoveries"]
    assert recov, "campaign produced no recovery events"
    decided = [e for e in recov if "decision" in e]
    assert decided and all("counterfactual_s" in e for e in decided)
    assert res1.forensics["slo"]["total_burn_s"] >= 0.0


# -- traffic sources --------------------------------------------------------------


def test_diurnal_and_mixed_traffic_deterministic():
    def draw():
        d = DiurnalTraffic(4.0, 512, amplitude=0.8, period_s=30.0,
                           seed=2, limit=50, model_id="a")
        p = PoissonTraffic(2.0, 512, seed=3, limit=20, model_id="b")
        return MixedTraffic([d, p])

    t1, t2 = draw(), draw()
    a1 = t1.due(60.0)
    a2 = t2.due(60.0)
    assert [(a.at_s, a.prompt_tokens, a.model_id) for a in a1] == \
        [(a.at_s, a.prompt_tokens, a.model_id) for a in a2]
    assert {a.model_id for a in a1} == {"a", "b"}
    assert all(x.at_s <= y.at_s for x, y in zip(a1, a1[1:]))
    # diurnal peak vs trough density differ (the sinusoid is real)
    d = DiurnalTraffic(4.0, 512, amplitude=0.8, period_s=1000.0,
                       seed=7, limit=10000)
    arrivals = d.due(1000.0)
    peak = sum(1 for a in arrivals if a.at_s < 500.0)
    trough = len(arrivals) - peak
    assert peak > trough * 1.5
    assert not t1.exhausted or t1.next_at is None


def test_trace_traffic_still_routes_by_model(shared_workdir):
    from repro.fleet.traffic import Arrival
    cfg = fleet_cfg()
    tr = TraceTraffic([
        Arrival(0.0, tuple(PROMPT), 4, model_id=None),
        Arrival(0.0, tuple(PROMPT), 4, model_id=None),
    ])
    fleet = build_fleet(cfg, fleet_ecfg(shared_workdir), instances=2,
                        traffic=tr, cost_profile=PROFILE)
    fleet.run(max_ticks=200)
    assert fleet.unfinished == 0
    assert len(fleet.requests) == 2


# -- seeded per-event recovery-cost dispersion -------------------------------------


def test_event_cost_jitter_zero_is_exact_base():
    """Default profiles (jitter=0) must reproduce the historical
    constant costs bit-exactly — the campaign CI determinism gate
    compares forensics produced before and after this knob existed."""
    p = VirtualCostProfile()
    for kind in ("revive", "restart", "spare"):
        for idx in range(4):
            assert p.event_cost(kind, idx, 0.123456) == 0.123456


def test_event_cost_jitter_deterministic_and_dispersed():
    p = VirtualCostProfile(jitter=0.4, jitter_seed=7)
    q = VirtualCostProfile(jitter=0.4, jitter_seed=7)
    base = 0.75
    costs = [p.event_cost("revive", i, base) for i in range(16)]
    # pure function of (seed, kind, index): a re-run reproduces each
    # event's cost exactly, which keeps campaign forensics byte-stable
    assert costs == [q.event_cost("revive", i, base) for i in range(16)]
    assert all(c > 0.0 for c in costs)              # lognormal support
    assert len(set(costs)) > 1                      # actually dispersed
    assert all(c == round(c, 6) for c in costs)     # forensics-ready
    # kinds draw from independent streams at the same index
    assert p.event_cost("restart", 0, base) != costs[0]
    # a different seed is a different campaign
    r = VirtualCostProfile(jitter=0.4, jitter_seed=8)
    assert r.event_cost("revive", 0, base) != costs[0]


def test_event_cost_jitter_flows_through_router_charges(shared_workdir):
    """Two identical fleets with a jittered profile charge identical
    per-event costs (forensics byte-stable), and the charged sequence
    differs from the constant-cost profile's."""
    def burn(prof):
        fleet = build_fleet(fleet_cfg(), fleet_ecfg(shared_workdir),
                            instances=2, cost_profile=prof)
        for _ in range(2):
            fleet.submit(list(PROMPT), 4)
            fleet.tick()
        fleet.lose_instance(0, reason="jitter drill")
        fleet.run(max_ticks=60)
        return [(e["policy"], e["charged_s"]) for e in fleet.forensics]

    jit = VirtualCostProfile(jitter=0.5, jitter_seed=3)
    a, b = burn(jit), burn(jit)
    assert a and a == b
    flat = burn(VirtualCostProfile())
    assert [p for p, _ in flat] == [p for p, _ in a]   # same decisions
    assert [c for _, c in flat] != [c for _, c in a]   # jittered costs
