"""End-to-end recovery integration tests (the paper's §3 pipeline).

Each test builds a small real engine, injects a hardware failure, and
checks both the recovery mechanics and that every request still finishes
with its tokens preserved.
"""
import dataclasses

import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.fault_codes import ErrorType, Severity
from repro.core.weights import MoERecoveryKind, RecoveryPolicy
from repro.serving.engine import EngineConfig, InferenceEngine


def small_moe_cfg(redundant=2, experts=4):
    cfg = get_smoke_config("qwen2-moe-a2.7b")
    return dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, num_experts=experts,
                                     num_redundant_experts=redundant,
                                     top_k=2))


def submit_all(eng, cfg, n=4, prompt_len=8, max_new=8):
    rng = np.random.default_rng(0)
    return [eng.submit(list(rng.integers(0, cfg.vocab_size, prompt_len)),
                       max_new) for _ in range(n)]


@pytest.fixture(scope="module")
def disagg():
    """Shared engine for the disaggregated scenarios (built once)."""
    cfg = small_moe_cfg(redundant=2)
    ec = EngineConfig(mode="disaggregated", num_dp=3, num_moe=2,
                      max_batch=2, max_seq=64, block_size=8, num_blocks=64,
                      workdir="/tmp/repro_test_disagg")
    return cfg, ec


def test_attention_failure_migrates_and_finishes(disagg, tmp_path):
    cfg, ec = disagg
    ec = dataclasses.replace(ec, workdir=str(tmp_path))
    eng = InferenceEngine(cfg, ec)
    reqs = submit_all(eng, cfg, n=5)
    eng.injector.schedule(3, 1, severity=Severity.L5,
                          error_type=ErrorType.DRIVER_HANG,
                          component="attn", mid_step=True)
    eng.run(max_steps=120)
    assert all(r.state.value == "finished" for r in reqs)
    assert len(eng.reports) == 1
    rep = eng.reports[0]
    assert rep.scenario == "attn"
    assert rep.migrated >= 1
    # the failed executor is isolated
    failed = next(ex for ex in eng.dp_executors if ex.physical_id == 1)
    assert not failed.alive
    # tokens preserved through migration: every migrated request kept
    # its prompt and its decoded prefix
    migrated = [r for r in reqs if r.migrations > 0]
    assert migrated
    for r in migrated:
        assert len(r.output_tokens) == r.max_new_tokens


@pytest.mark.slow
def test_moe_failure_role_switch(disagg, tmp_path):
    cfg, ec = disagg
    ec = dataclasses.replace(ec, workdir=str(tmp_path))
    eng = InferenceEngine(cfg, ec)
    reqs = submit_all(eng, cfg, n=4)
    # fail MoE rank 0 (pid = num_dp): its unreplicated experts force a
    # role switch (redundant covers only experts 0,1 of 4)
    eng.injector.schedule(3, 3, severity=Severity.L6, component="moe")
    eng.run(max_steps=120)
    assert all(r.state.value == "finished" for r in reqs)
    rep = eng.reports[0]
    assert rep.moe_plan is not None
    assert rep.moe_plan.kind is MoERecoveryKind.ROLE_SWITCH
    # donor DP rank now hosts the failed EP rank's experts
    checks, alive = eng.expert_integrity()
    assert all(alive)
    # graph was precompiled for the failure scenario -> cached hit
    assert rep.compile_source == "precompiled"
    assert rep.timings.get("generator", 0) > 0  # weight reload from disk


def test_moe_failure_missing_experts_masks_routing(tmp_path):
    cfg = small_moe_cfg(redundant=0)
    ec = EngineConfig(mode="disaggregated", num_dp=2, num_moe=2,
                      max_batch=2, max_seq=64, block_size=8, num_blocks=64,
                      workdir=str(tmp_path),
                      policy=RecoveryPolicy(allow_role_switch=False,
                                            min_ep_for_missing=2))
    eng = InferenceEngine(cfg, ec)
    reqs = submit_all(eng, cfg, n=3)
    eng.injector.schedule(3, 3, severity=Severity.L6, component="moe")
    eng.run(max_steps=120)
    assert all(r.state.value == "finished" for r in reqs)
    rep = eng.reports[0]
    assert rep.moe_plan.kind is MoERecoveryKind.MISSING_EXPERTS
    mask = np.asarray(eng.runtime.expert_mask)
    assert (~mask).sum() == 2      # EP rank 1's experts are masked
    # inference continued: the engine serves with the degraded expert set


@pytest.mark.slow
def test_collocated_failure_runs_both_paths(tmp_path):
    cfg = small_moe_cfg(redundant=4, experts=4)  # fully replicated
    ec = EngineConfig(mode="collocated", num_dp=2, max_batch=2, max_seq=64,
                      block_size=8, num_blocks=64, workdir=str(tmp_path),
                      policy=RecoveryPolicy(allow_role_switch=False))
    eng = InferenceEngine(cfg, ec)
    reqs = submit_all(eng, cfg, n=4)
    eng.injector.schedule(3, 1, severity=Severity.L6,
                          component="attn+moe", mid_step=True)
    eng.run(max_steps=120)
    assert all(r.state.value == "finished" for r in reqs)
    rep = eng.reports[0]
    # collocated failure = attention migration AND expert recovery
    assert rep.migrated >= 1
    assert rep.moe_plan.kind is MoERecoveryKind.REDUNDANT_EXPERTS


@pytest.mark.slow
def test_benign_fault_is_ignored(tmp_path):
    cfg = small_moe_cfg()
    ec = EngineConfig(mode="collocated", num_dp=2, max_batch=2, max_seq=64,
                      block_size=8, num_blocks=64, workdir=str(tmp_path))
    eng = InferenceEngine(cfg, ec)
    reqs = submit_all(eng, cfg, n=2)
    eng.injector.schedule(2, 0, severity=Severity.L1,
                          error_type=ErrorType.OVER_TEMP, component="attn")
    eng.run(max_steps=100)
    assert all(r.state.value == "finished" for r in reqs)
    # L1 -> logged only; the device was never isolated
    assert all(ex.alive for ex in eng.dp_executors)
    reps = [r for r in eng.reports if r.scenario != "benign"]
    assert not reps


@pytest.mark.slow
def test_block_log_rolls_back_on_mid_step_failure(tmp_path):
    cfg = small_moe_cfg()
    ec = EngineConfig(mode="collocated", num_dp=2, max_batch=2, max_seq=64,
                      block_size=4, num_blocks=64, workdir=str(tmp_path))
    eng = InferenceEngine(cfg, ec)
    reqs = submit_all(eng, cfg, n=4, prompt_len=7, max_new=6)
    # fail device 1 mid-step while device 0 is also mid-step: device 0's
    # in-flight block ops must be rolled back (§3.3)
    eng.injector.schedule(2, 1, severity=Severity.L6,
                          component="attn+moe", mid_step=True)
    eng.run(max_steps=120)
    rep = eng.reports[0]
    assert rep.blocks_rolled_back > 0
    assert all(r.state.value == "finished" for r in reqs)
    # block accounting consistent on the survivor
    survivor = eng.dp_executors[0]
    assert survivor.block_manager.num_allocated == 0  # all finished+freed


@pytest.mark.slow
def test_heartbeat_detection_path(tmp_path):
    """A device that dies silently (no annotation) is caught by the
    heartbeat monitor after timeout_steps."""
    cfg = small_moe_cfg(redundant=4, experts=4)
    ec = EngineConfig(mode="collocated", num_dp=2, max_batch=2, max_seq=64,
                      block_size=8, num_blocks=64, workdir=str(tmp_path),
                      heartbeat_timeout_steps=2,
                      policy=RecoveryPolicy(allow_role_switch=False))
    eng = InferenceEngine(cfg, ec)
    reqs = submit_all(eng, cfg, n=3)
    # silent death: mark the device dead without any annotation
    eng.run(max_steps=2)
    victim = eng.dp_executors[1]
    victim.device_alive = False   # hardware hang, no fault code
    eng.run(max_steps=150)
    assert any(r.event.error_type is ErrorType.HEARTBEAT_TIMEOUT
               for r in eng.reports)
    assert all(r.state.value == "finished" for r in reqs)


@pytest.mark.slow
def test_background_role_switch(tmp_path):
    """§4.3: mask lost experts now (downtime = missing-experts level),
    restore full integrity via a deferred role switch while serving."""
    cfg = small_moe_cfg(redundant=0)
    ec = EngineConfig(mode="disaggregated", num_dp=3, num_moe=2,
                      max_batch=2, max_seq=64, block_size=8, num_blocks=64,
                      workdir=str(tmp_path),
                      policy=RecoveryPolicy(background_role_switch=True,
                                            min_ep_for_missing=2))
    eng = InferenceEngine(cfg, ec)
    reqs = submit_all(eng, cfg, n=4, max_new=16)
    eng.injector.schedule(3, 3, severity=Severity.L6, component="moe")
    eng.run(max_steps=200)
    assert all(r.state.value == "finished" for r in reqs)
    rep = eng.reports[0]
    assert rep.moe_plan.kind is MoERecoveryKind.ROLE_SWITCH
    assert rep.moe_plan.background
    # downtime excludes the weight reload (it happened in the background)
    assert rep.timings.get("generator", 0.0) == 0.0
    assert rep.timings.get("role_switch", 0.0) == 0.0
    # the background switch completed and restored full integrity
    assert eng.background_reports
    assert eng.background_reports[0]["restored_experts"] == 2
    assert eng.expert_map.coverage() == 1.0
    import numpy as np
    assert bool(np.asarray(eng.runtime.expert_mask).all())


@pytest.mark.slow
def test_dense_ffn_tp_group_rebalance(tmp_path):
    """§3.4: kimi-style first-k dense layers — losing an MoE device's
    dense-FFN shard (without role switch) compromises its TP group and
    rebalances token routing over the healthy groups."""
    cfg = get_smoke_config("kimi-k2-1t-a32b")   # first_k_dense = 1
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, num_experts=4,
                                     num_redundant_experts=4, top_k=2,
                                     first_k_dense=1, dense_d_ff=256))
    ec = EngineConfig(mode="disaggregated", num_dp=2, num_moe=2,
                      max_batch=2, max_seq=64, block_size=8, num_blocks=64,
                      workdir=str(tmp_path),
                      policy=RecoveryPolicy(allow_role_switch=False,
                                            min_ep_for_missing=2))
    eng = InferenceEngine(cfg, ec)
    assert eng.dense_groups is not None
    reqs = submit_all(eng, cfg, n=3)
    eng.injector.schedule(3, 2, severity=Severity.L6, component="moe")
    eng.run(max_steps=150)
    assert all(r.state.value == "finished" for r in reqs)
    g = eng.dense_groups
    assert g.num_healthy() == g.num_groups - 1
    w = g.routing_weights()
    assert abs(sum(w) - 1.0) < 1e-9 and 0.0 in w
    assert any("dense-FFN TP group" in a for a in eng.reports[0].actions)


@pytest.mark.slow
def test_straggler_detection_and_isolation(tmp_path):
    """Slowdown handling (the paper's §6 future work, implemented): a
    device that silently slows 10x is detected by the straggler detector
    and isolated like a failed device; its sequences migrate."""
    cfg = small_moe_cfg(redundant=4, experts=4)
    ec = EngineConfig(mode="disaggregated", num_dp=3, num_moe=2,
                      max_batch=2, max_seq=96,
                      block_size=8, num_blocks=96, workdir=str(tmp_path),
                      policy=RecoveryPolicy(allow_role_switch=False))
    eng = InferenceEngine(cfg, ec)
    reqs = submit_all(eng, cfg, n=6, max_new=24)
    eng.run(max_steps=5)
    victim = eng.dp_executors[1]
    victim.simulated_slowdown_s = 1.0   # 10x+ the healthy step time
    eng.run(max_steps=250)
    assert all(r.state.value == "finished" for r in reqs)
    straggler_reports = [r for r in eng.reports
                         if "straggler" in r.event.detail]
    assert straggler_reports, [r.event for r in eng.reports]
    assert not victim.alive             # isolated
    assert straggler_reports[0].event.severity.name == "L4"


@pytest.mark.slow
def test_replica_rebalancing_follows_usage(tmp_path):
    """§3.4/§4.3: redundant replica slots re-point at the hottest experts
    (with weights copied), and the re-placement changes which failures
    are covered by redundancy."""
    cfg = small_moe_cfg(redundant=2, experts=4)   # replicas of 0,1 initially
    # 3 MoE ranks: bases on ranks 0-1, replica slots on rank 2 — so the
    # anti-affinity constraint can place any expert's replica
    ec = EngineConfig(mode="disaggregated", num_dp=2, num_moe=3,
                      max_batch=2, max_seq=64, block_size=8, num_blocks=64,
                      workdir=str(tmp_path))
    eng = InferenceEngine(cfg, ec)
    emap = eng.expert_map
    assert sorted(emap.replicas_of(0)) != [0]     # 0 starts replicated
    assert emap.replicas_of(3) == [3]             # 3 does not
    # usage says experts 3 and 2 are hottest
    moves = eng.rebalance_experts({0: 1, 1: 2, 2: 90, 3: 100})
    assert moves
    assert len(emap.replicas_of(3)) == 2
    assert len(emap.replicas_of(2)) == 2
    assert emap.replicas_of(0) == [0]
    # weights in the re-pointed slots are true copies
    for logical in (2, 3):
        slots = emap.replicas_of(logical)
        per = emap.slots_per_rank
        owners = [eng._shard_owner(emap.rank_of_slot(s)) for s in slots]
        for key in owners[0].shard:
            a = owners[0].shard[key][:, slots[0] % per]
            b = owners[1].shard[key][:, slots[1] % per]
            np.testing.assert_array_equal(a, b)
    # a failure hitting expert 3's base slot is now covered by redundancy
    rank_of_base3 = emap.rank_of_slot(3)
    emap.fail_rank(rank_of_base3)
    assert 3 not in emap.fully_lost()
    # serving still works end-to-end after the rebalance
    reqs = submit_all(eng, cfg, n=2)
    eng.run(max_steps=80)
    assert all(r.state.value == "finished" for r in reqs)


def test_dense_arch_attention_recovery(tmp_path):
    """Non-MoE architectures get the attention-side ReviveMoE paths:
    migration + block-log rollback + cached compile (DESIGN.md §4)."""
    cfg = get_smoke_config("internlm2-20b")
    ec = EngineConfig(mode="disaggregated", num_dp=3, max_batch=2,
                      max_seq=64, block_size=8, num_blocks=64,
                      workdir=str(tmp_path))
    eng = InferenceEngine(cfg, ec)
    assert eng.expert_map is None and not eng.moe_executors
    reqs = submit_all(eng, cfg, n=4, max_new=10)
    eng.injector.schedule(3, 1, severity=Severity.L6, component="attn",
                          mid_step=True)
    eng.run(max_steps=150)
    assert all(r.state.value == "finished" for r in reqs)
    rep = eng.reports[0]
    assert rep.scenario == "attn"
    assert rep.migrated >= 1
    assert rep.compile_source == "precompiled"


@pytest.mark.slow
def test_hybrid_arch_serving_and_recovery(tmp_path):
    """Jamba-family serving: Mamba state + windowed attention caches ride
    the same executor machinery; recovery re-prefills state like KV
    (DESIGN.md §4: Mamba state is rank-local like KV)."""
    cfg = get_smoke_config("jamba-1.5-large-398b")
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=4.0))
    ec = EngineConfig(mode="disaggregated", num_dp=2, num_moe=2,
                      max_batch=2, max_seq=64, block_size=8, num_blocks=64,
                      workdir=str(tmp_path))
    eng = InferenceEngine(cfg, ec)
    reqs = submit_all(eng, cfg, n=3, max_new=10)
    eng.injector.schedule(3, 1, severity=Severity.L6, component="attn",
                          mid_step=True)
    eng.run(max_steps=150)
    assert all(r.state.value == "finished" for r in reqs)
    assert eng.reports and eng.reports[0].migrated >= 1


@pytest.mark.slow
def test_ssm_arch_serving_and_recovery(tmp_path):
    """Attention-free falcon-mamba: no KV blocks to roll back, state
    rollback is the (free) discard of the uncommitted cache pytree."""
    cfg = get_smoke_config("falcon-mamba-7b")
    ec = EngineConfig(mode="collocated", num_dp=2, max_batch=2, max_seq=64,
                      block_size=8, num_blocks=64, workdir=str(tmp_path))
    eng = InferenceEngine(cfg, ec)
    reqs = submit_all(eng, cfg, n=3, max_new=10)
    eng.injector.schedule(3, 0, severity=Severity.L6, component="attn",
                          mid_step=True)
    eng.run(max_steps=150)
    assert all(r.state.value == "finished" for r in reqs)
    assert eng.reports and eng.reports[0].scenario == "attn"


def test_fused_moe_path_survives_fail_rank_and_mask(tmp_path):
    """ReviveMoE §3.4 on the fused Pallas pipeline: a failed expert rank
    (``fail_rank`` drops its replicas) plus ``mask_experts`` on the fully
    lost experts are pure MoERuntime mutations — the fused MoE step keeps
    serving from the same compiled graphs with zero fresh compilation."""
    cfg = small_moe_cfg(redundant=0)
    ec = EngineConfig(mode="disaggregated", num_dp=2, num_moe=2,
                      max_batch=2, max_seq=64, block_size=8, num_blocks=64,
                      workdir=str(tmp_path), moe_impl="fused",
                      policy=RecoveryPolicy(allow_role_switch=False,
                                            min_ep_for_missing=2))
    eng = InferenceEngine(cfg, ec)
    assert eng.cfg.moe_fused          # EngineConfig override took effect
    reqs = submit_all(eng, cfg, n=3)
    eng.injector.schedule(3, 3, severity=Severity.L6, component="moe")
    eng.run(max_steps=120)
    assert all(r.state.value == "finished" for r in reqs)
    rep = eng.reports[0]
    assert rep.moe_plan.kind is MoERecoveryKind.MISSING_EXPERTS
    # fail_rank dropped the dead rank's slots; mask_experts hides them
    mask = np.asarray(eng.runtime.expert_mask)
    assert (~mask).sum() == 2
    # zero recompiles: the post-failure graph came from the precompiled
    # cache and no real compilation happened during recovery
    assert rep.compile_source == "precompiled"
    assert rep.timings.get("compile", 0.0) < 0.01
